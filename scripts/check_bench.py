#!/usr/bin/env python3
"""Gate the committed bench JSON against a fresh `make bench` run.

Two checks, both hard failures (exit 1):

1. Schema staleness: the committed ``BENCH_perf_hotpath.json`` must
   carry the same section and metric labels the bench binary emits
   today. A drifted label set means the committed perf trajectory no
   longer describes the code — regenerate and re-commit the JSON.
2. Perf floor: the fresh run's event-driven simulator throughput on the
   fig6a topology must stay at or above the floor committed in PR 1
   (>= 60 Mcyc/s).
3. Fresh completeness: every section and metric in the *fresh* run must
   carry a real number. The committed file may hold nulls (the
   no-toolchain ``measurement status`` marker makes CI the measuring
   authority), but a fresh run that wrote ``null`` means a timing or
   derived metric silently produced a non-finite value.

Environment-dependent rows are exempt from the schema comparison: the
PJRT artifact sections (skipped when artifacts or the PJRT plugin are
absent) and the committed file's ``measurement status`` marker (present
when the JSON was committed from a machine without a toolchain and CI
is the measuring authority).
"""

import argparse
import json
import re
import sys

# Sections whose presence depends on the environment, by label prefix.
OPTIONAL_SECTION_PREFIXES = ("matmul_int8", "qnn_mlp")
# Metrics allowed in one file but not the other.
OPTIONAL_METRICS = frozenset({"measurement status"})

EVENT_DRIVEN_METRIC = "simulated cycles/sec event-driven"
EVENT_DRIVEN_FLOOR = 60.0
WHEEL_SPEEDUP_METRIC = "wheel speedup vs event-driven"
WHEEL_PARALLEL_METRIC = "sweep wall-clock speedup (wheel parallel)"
WS_FOLD_METRIC = "workingset fold throughput"
WS_DISABLED_METRIC = "ws trace-disabled cost vs untraced"
WS_DISABLED_GATE = 1.05
PACK_ADMISSIONS_METRIC = "pack sustained admissions (100k queue)"
PACK_DEEP_METRIC = "pack-only sustained admissions (1M queue)"
PACK_RATIO_METRIC = "pack packing ratio"
PACK_FFD_METRIC = "pack ffd win rate"
PACK_SLACK_METRIC = "pack best-fit-slack win rate"
PACK_LIBRARY_METRIC = "pack certificate-library hit rate"


def load(path):
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"check_bench: cannot read {path}: {e}")


def labels(doc, key):
    out = []
    for row in doc.get(key, []):
        label = row.get("label", "")
        if label in OPTIONAL_METRICS or label.startswith(OPTIONAL_SECTION_PREFIXES):
            continue
        # Section labels embed runtime values (grid sizes, thread
        # counts) that legitimately differ between the committing
        # machine and the CI runner; compare digit-normalized shapes.
        out.append(re.sub(r"\d+", "N", label))
    return out


def metric_value(doc, label):
    for row in doc.get("metrics", []):
        if row.get("label") == label:
            return row.get("value")
    return None


def null_rows(doc):
    """Labels in a fresh run whose measured value is null/missing."""
    out = []
    for row in doc.get("sections", []):
        label = row.get("label", "")
        if label.startswith(OPTIONAL_SECTION_PREFIXES):
            continue
        if not isinstance(row.get("mean_ms"), (int, float)):
            out.append(f"section {label!r}")
    for row in doc.get("metrics", []):
        label = row.get("label", "")
        if label in OPTIONAL_METRICS:
            continue
        if not isinstance(row.get("value"), (int, float)):
            out.append(f"metric {label!r}")
    return out


def diff(kind, committed, fresh):
    problems = []
    missing = [l for l in fresh if l not in committed]
    stale = [l for l in committed if l not in fresh]
    for l in missing:
        problems.append(f"committed JSON lacks {kind} {l!r} (bench schema grew)")
    for l in stale:
        problems.append(f"committed JSON carries {kind} {l!r} the bench no longer emits")
    if not problems and committed != fresh:
        problems.append(f"{kind} order drifted: committed {committed} vs fresh {fresh}")
    return problems


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("committed", help="BENCH JSON as committed in the repo")
    p.add_argument("fresh", help="BENCH JSON freshly written by `make bench`")
    args = p.parse_args()

    committed = load(args.committed)
    fresh = load(args.fresh)

    problems = []
    if committed.get("bench") != fresh.get("bench"):
        problems.append(
            f"bench name drifted: committed {committed.get('bench')!r} "
            f"vs fresh {fresh.get('bench')!r}"
        )
    problems += diff("section", labels(committed, "sections"), labels(fresh, "sections"))
    problems += diff("metric", labels(committed, "metrics"), labels(fresh, "metrics"))

    ed = metric_value(fresh, EVENT_DRIVEN_METRIC)
    if not isinstance(ed, (int, float)):
        problems.append(f"fresh run reports no {EVENT_DRIVEN_METRIC!r} value")
    elif ed < EVENT_DRIVEN_FLOOR:
        problems.append(
            f"event-driven throughput regressed: {ed:.1f} Mcyc/s "
            f"< floor {EVENT_DRIVEN_FLOOR:.0f} (PR 1 fig6a floor)"
        )
    else:
        print(f"check_bench: event-driven {ed:.1f} Mcyc/s >= floor {EVENT_DRIVEN_FLOOR:.0f}")

    wheel = metric_value(fresh, WHEEL_SPEEDUP_METRIC)
    if isinstance(wheel, (int, float)):
        print(f"check_bench: wheel speedup vs event-driven {wheel:.2f}x (acceptance >= 1.5)")

    wheel_par = metric_value(fresh, WHEEL_PARALLEL_METRIC)
    if isinstance(wheel_par, (int, float)):
        print(f"check_bench: wheel-parallel sweep speedup {wheel_par:.2f}x vs event-driven serial")

    ws_fold = metric_value(fresh, WS_FOLD_METRIC)
    if isinstance(ws_fold, (int, float)):
        print(f"check_bench: working-set fold throughput {ws_fold:.2f} Mevents/s")

    ws_disabled = metric_value(fresh, WS_DISABLED_METRIC)
    if isinstance(ws_disabled, (int, float)) and ws_disabled > WS_DISABLED_GATE:
        problems.append(
            f"address-tagged fills leaked into the disabled trace path: "
            f"{ws_disabled:.3f}x > gate {WS_DISABLED_GATE}"
        )

    pack = metric_value(fresh, PACK_ADMISSIONS_METRIC)
    pack_deep = metric_value(fresh, PACK_DEEP_METRIC)
    if isinstance(pack, (int, float)) and isinstance(pack_deep, (int, float)):
        print(
            f"check_bench: admission service {pack:,.0f} req/s full pipeline, "
            f"{pack_deep:,.0f} req/s pack-only"
        )
    ratio = metric_value(fresh, PACK_RATIO_METRIC)
    if isinstance(ratio, (int, float)):
        print(f"check_bench: packing ratio {ratio:.2f} req/mix")
    ffd = metric_value(fresh, PACK_FFD_METRIC)
    slack = metric_value(fresh, PACK_SLACK_METRIC)
    if isinstance(ffd, (int, float)) and isinstance(slack, (int, float)):
        print(
            f"check_bench: heuristic win rates ffd {ffd:.1f}% / "
            f"best-fit-slack {slack:.1f}%"
        )
    lib = metric_value(fresh, PACK_LIBRARY_METRIC)
    if isinstance(lib, (int, float)):
        print(f"check_bench: certificate-library hit rate {lib:.1f}%")

    for row in null_rows(fresh):
        problems.append(f"fresh run wrote null for {row} (non-finite measurement)")

    if problems:
        for problem in problems:
            print(f"check_bench: FAIL: {problem}", file=sys.stderr)
        sys.exit(1)
    print("check_bench: committed BENCH JSON matches the bench schema; floor holds")


if __name__ == "__main__":
    main()

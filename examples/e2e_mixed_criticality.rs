//! End-to-end driver: the full three-layer stack on a real small
//! workload, proving all layers compose.
//!
//! Functional path (L1/L2 via PJRT): loads the AOT artifacts
//! (`qnn_mlp`, `fft256`, `control_step`, `matmul_int8`), executes them on
//! the XLA CPU client with deterministic inputs, and cross-checks the
//! numerics against independent rust oracles.
//!
//! Timing path (L3): runs the same workload mix as mixed-criticality
//! tasks on the SoC simulator under the coordinator's isolation ladder,
//! reporting latency / throughput / deadline outcomes.
//!
//! Requires `make artifacts`. Run with:
//! `cargo run --release --example e2e_mixed_criticality`

use carfield::coordinator::task::Criticality;
use carfield::coordinator::{IsolationPolicy, McTask, Scenario, Scheduler, Workload};
use carfield::runtime::ArtifactRuntime;
use carfield::soc::amr::IntPrecision;
use carfield::soc::dma::DmaJob;
use carfield::soc::hostd::TctSpec;
use carfield::soc::vector::FpFormat;
use carfield::util::XorShift;

fn quant(v: &[f32], bits: u32) -> Vec<f32> {
    let lo = -(2f32.powi(bits as i32 - 1));
    let hi = 2f32.powi(bits as i32 - 1) - 1.0;
    // jnp.round is round-half-to-even (banker's); mirror it exactly.
    v.iter()
        .map(|x| x.round_ties_even().clamp(lo, hi))
        .collect()
}

fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            for j in 0..n {
                out[i * n + j] += av * b[kk * n + j];
            }
        }
    }
    out
}

/// QNN MLP oracle mirroring python/compile/model.py::qnn_mlp.
fn qnn_mlp_oracle(x: &[f32], w1: &[f32], w2: &[f32], w3: &[f32]) -> Vec<f32> {
    let (b, d0, d1, d2, d3) = (32, 256, 128, 64, 32);
    let relu_requant = |acc: Vec<f32>| -> Vec<f32> {
        quant(
            &acc.iter().map(|v| v * 2f32.powi(-6)).collect::<Vec<_>>(),
            8,
        )
        .iter()
        .map(|v| v.max(0.0))
        .collect()
    };
    let h1 = relu_requant(matmul(&quant(x, 8), &quant(w1, 8), b, d0, d1));
    let h2 = relu_requant(matmul(&h1, &quant(w2, 8), b, d1, d2));
    matmul(&h2, &quant(w3, 8), b, d2, d3)
}

fn functional_pass() -> anyhow::Result<()> {
    println!("== functional pass: PJRT artifacts vs rust oracles");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        dir.join("manifest.txt").exists(),
        "artifacts/ missing — run `make artifacts` first"
    );
    let mut rt = ArtifactRuntime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let mut rng = XorShift::new(0xE2E);

    // 1) Mission-critical QNN inference (AMR cluster functional model).
    let exe = rt.load("qnn_mlp")?;
    let bufs: Vec<Vec<f32>> = exe
        .input_shapes()
        .iter()
        .map(|s| {
            rng.fill_f32(s.iter().product(), 8.0)
                .iter()
                .map(|v| v.round())
                .collect()
        })
        .collect();
    let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
    let t0 = std::time::Instant::now();
    let out = exe.run_f32(&refs)?;
    let dt_mlp = t0.elapsed();
    let oracle = qnn_mlp_oracle(&bufs[0], &bufs[1], &bufs[2], &bufs[3]);
    anyhow::ensure!(out[0] == oracle, "qnn_mlp mismatch vs oracle");
    let preds: Vec<usize> = (0..32)
        .map(|b| {
            out[0][b * 32..b * 32 + 10]
                .iter()
                .enumerate()
                .max_by(|a, c| a.1.partial_cmp(c.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect();
    println!(
        "qnn_mlp: batch-32 inference exact vs oracle in {dt_mlp:?}; predictions[..8]={:?}",
        &preds[..8]
    );

    // 2) Radar FFT spectrum (vector cluster functional model).
    let exe = rt.load("fft256")?;
    let n = 256usize;
    let tone = 41usize;
    let xr: Vec<f32> = (0..n)
        .map(|t| (2.0 * std::f32::consts::PI * tone as f32 * t as f32 / n as f32).cos())
        .collect();
    let xi: Vec<f32> = (0..n)
        .map(|t| (2.0 * std::f32::consts::PI * tone as f32 * t as f32 / n as f32).sin())
        .collect();
    let win = vec![1f32; n];
    let spec = &exe.run_f32(&[&xr, &xi, &win])?[0];
    let peak = spec
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    anyhow::ensure!(peak == tone, "fft256: tone detected at bin {peak}, want {tone}");
    println!("fft256: pure tone at bin {tone} detected at bin {peak} (|X|={:.1})", spec[peak]);

    // 3) FP control step (vector cluster control task).
    let exe = rt.load("control_step")?;
    let s = 32usize;
    let a = rng.fill_f32(s * s, 0.4);
    let bmat = rng.fill_f32(s * s, 0.4);
    let k = rng.fill_f32(s * s, 0.4);
    let x = rng.fill_f32(s * s, 1.0);
    let got = &exe.run_f32(&[&a, &bmat, &k, &x])?[0];
    let u: Vec<f32> = matmul(&k, &x, s, s, s).iter().map(|v| -v).collect();
    let want: Vec<f32> = matmul(&a, &x, s, s, s)
        .iter()
        .zip(matmul(&bmat, &u, s, s, s).iter())
        .map(|(p, q)| p + q)
        .collect();
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs() / (1.0 + w.abs()))
        .fold(0f32, f32::max);
    anyhow::ensure!(max_err < 1e-3, "control_step error {max_err}");
    println!("control_step: closed-loop update matches oracle (max rel err {max_err:.2e})");
    Ok(())
}

fn timing_pass() {
    println!("\n== timing pass: same mix on the SoC simulator (coordinator ladder)");
    let mix = || {
        vec![
            McTask::new(
                "brake-control",
                Criticality::Hard,
                Workload::HostTct(TctSpec {
                    accesses: 512,
                    iterations: 6,
                    ..TctSpec::fig6a()
                }),
            )
            .with_deadline(150_000),
            McTask::new(
                "collision-qnn",
                Criticality::Safety,
                Workload::AmrMatMul {
                    precision: IntPrecision::Int8,
                    m: 96,
                    k: 96,
                    n: 96,
                    tile: 8,
                },
            )
            .with_deadline(400_000),
            McTask::new(
                "radar-fft",
                Criticality::Soft,
                Workload::VectorFft {
                    format: FpFormat::Fp32,
                    n: 256,
                    batch: 64,
                },
            ),
            McTask::new(
                "camera-dma",
                Criticality::BestEffort,
                Workload::DmaCopy(DmaJob::interferer()),
            ),
        ]
    };
    for (label, policy) in [
        ("unregulated", IsolationPolicy::NoIsolation),
        (
            "coordinator-managed",
            IsolationPolicy::TsuPlusLlcPartition {
                tct_fraction_percent: 50,
            },
        ),
    ] {
        let mut scenario = Scenario::new(label, policy);
        for t in mix() {
            scenario = scenario.with_task(t);
        }
        let r = Scheduler::run(&scenario);
        println!("{}", r.to_markdown());
        println!("  all deadlines met: {}\n", r.all_deadlines_met());
    }
}

fn main() -> anyhow::Result<()> {
    functional_pass()?;
    timing_pass();
    println!("e2e OK: functional numerics exact + timing reproduced under isolation policies");
    Ok(())
}

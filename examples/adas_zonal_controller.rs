//! ADAS zonal controller: the paper's motivating workload mix on one SoC.
//!
//! - radar DSP (windowed FFTs) on the vector cluster     — soft RT;
//! - collision-avoidance QNN on the AMR cluster (DLM)    — safety;
//! - brake control loop on the host domain               — hard RT;
//! - camera frame DMA                                    — best effort.
//!
//! The coordinator walks the isolation-policy ladder and reports whether
//! every deadline holds at each level — the decision procedure a real
//! integrator would run.
//!
//! Run with: `cargo run --release --example adas_zonal_controller`

use carfield::coordinator::task::Criticality;
use carfield::coordinator::{IsolationPolicy, McTask, Scenario, Scheduler, Workload};
use carfield::soc::amr::IntPrecision;
use carfield::soc::dma::DmaJob;
use carfield::soc::hostd::TctSpec;
use carfield::soc::vector::FpFormat;

fn task_mix() -> Vec<McTask> {
    vec![
        McTask::new(
            "brake-control",
            Criticality::Hard,
            Workload::HostTct(TctSpec {
                accesses: 512,
                iterations: 6,
                ..TctSpec::fig6a()
            }),
        )
        .with_deadline(150_000),
        McTask::new(
            "collision-qnn",
            Criticality::Safety,
            Workload::AmrMatMul {
                precision: IntPrecision::Int8,
                m: 96,
                k: 96,
                n: 96,
                tile: 8,
            },
        )
        .with_deadline(400_000),
        McTask::new(
            "radar-fft",
            Criticality::Soft,
            Workload::VectorFft {
                format: FpFormat::Fp32,
                n: 256,
                batch: 64,
            },
        )
        .with_deadline(600_000),
        McTask::new(
            "camera-dma",
            Criticality::BestEffort,
            Workload::DmaCopy(DmaJob::interferer()),
        ),
    ]
}

fn main() {
    let policies = [
        ("no isolation", IsolationPolicy::NoIsolation),
        ("TSU regulation", IsolationPolicy::TsuRegulation),
        (
            "TSU + DPLLC partition",
            IsolationPolicy::TsuPlusLlcPartition {
                tct_fraction_percent: 50,
            },
        ),
        ("private DCSPM paths", IsolationPolicy::PrivatePaths),
    ];
    let mut chosen = None;
    for (label, policy) in policies {
        let mut scenario = Scenario::new(label, policy);
        for t in task_mix() {
            scenario = scenario.with_task(t);
        }
        let report = Scheduler::run(&scenario);
        println!("{}", report.to_markdown());
        let ok = report.all_deadlines_met();
        println!("  -> all deadlines met: {ok}\n");
        if ok && chosen.is_none() {
            chosen = Some(label);
        }
    }
    match chosen {
        Some(label) => println!(
            "coordinator decision: weakest sufficient isolation policy = \"{label}\""
        ),
        None => println!("coordinator decision: no policy satisfies all deadlines — re-plan tasks"),
    }
}

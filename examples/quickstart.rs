//! Quickstart: assemble a Carfield SoC, run a time-critical task against
//! a bulk-DMA interferer, and watch the TSU restore its latency.
//!
//! Run with: `cargo run --release --example quickstart`

use carfield::coordinator::task::Criticality;
use carfield::coordinator::{IsolationPolicy, McTask, Scenario, Scheduler, Workload};
use carfield::soc::dma::DmaJob;
use carfield::soc::hostd::TctSpec;

fn main() {
    // A hard real-time task: walk a 48KiB buffer in HyperRAM, 8 times.
    let tct = || {
        McTask::new(
            "control-loop",
            Criticality::Hard,
            Workload::HostTct(TctSpec::fig6a()),
        )
        .with_deadline(2_000_000)
    };
    // A best-effort bulk copy hammering the same memory path.
    let dma = || {
        McTask::new(
            "camera-dma",
            Criticality::BestEffort,
            Workload::DmaCopy(DmaJob::interferer()),
        )
    };

    println!("1) TCT alone (isolated baseline):");
    let iso = Scheduler::run(&Scenario::new("isolated", IsolationPolicy::NoIsolation).with_task(tct()));
    println!("{}", iso.to_markdown());

    println!("2) TCT + DMA, nothing configured (unregulated interference):");
    let unreg = Scheduler::run(
        &Scenario::new("unregulated", IsolationPolicy::NoIsolation)
            .with_task(tct())
            .with_task(dma()),
    );
    println!("{}", unreg.to_markdown());

    println!("3) Same mix, coordinator programs the TSU + a 50% DPLLC partition:");
    let fixed = Scheduler::run(
        &Scenario::new(
            "regulated",
            IsolationPolicy::TsuPlusLlcPartition {
                tct_fraction_percent: 50,
            },
        )
        .with_task(tct())
        .with_task(dma()),
    );
    println!("{}", fixed.to_markdown());

    let l_iso = iso.task("control-loop").mean_latency;
    let l_unreg = unreg.task("control-loop").mean_latency;
    let l_fixed = fixed.task("control-loop").mean_latency;
    println!("summary:");
    println!("  isolated iteration latency : {l_iso:.0} cycles");
    println!(
        "  unregulated                : {l_unreg:.0} cycles ({:.0}x worse)",
        l_unreg / l_iso
    );
    println!(
        "  TSU + partition            : {l_fixed:.0} cycles ({:.0}% of isolated performance)",
        l_iso / l_fixed * 100.0
    );
}

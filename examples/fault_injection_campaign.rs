//! Fault-injection campaign: sweep transient-fault rates across AMR
//! redundancy modes and recovery mechanisms, plus a TCLS (safe-domain)
//! soak test.
//!
//! Demonstrates the paper's reliability story end to end: INDIP corrupts
//! silently, DLM/TLM detect everything, HFR keeps the overhead at 24
//! cycles per fault while software recovery and reboots blow it up.
//!
//! Run with: `cargo run --release --example fault_injection_campaign`

use carfield::coordinator::metrics::print_table;
use carfield::soc::amr::{AmrCluster, AmrMode, AmrTask, IntPrecision, Recovery};
use carfield::soc::axi::{InitiatorId, TargetModel};
use carfield::soc::mem::Dcspm;
use carfield::soc::safed::{Commit, Tcls};
use carfield::soc::tsu::TsuConfig;
use carfield::soc::SocSim;
use carfield::util::XorShift;

fn run_amr(mode: AmrMode, recovery: Recovery, fault_rate: f64, seed: u64) -> (u64, u64, u64, u64) {
    let mut cluster = AmrCluster::new(InitiatorId(0)).with_seed(seed);
    cluster.mode = mode;
    cluster.recovery = recovery;
    cluster.fault_per_kcycle = fault_rate;
    cluster.submit(
        AmrTask {
            precision: IntPrecision::Int8,
            m: 128,
            k: 128,
            n: 128,
            tile: 32,
            src_base: 0,
            dst_base: 0x8_0000,
            part_id: 0,
        },
        0,
    );
    let mut soc = SocSim::new(1, vec![Box::new(Dcspm::new()) as Box<dyn TargetModel>]);
    soc.attach(Box::new(cluster), TsuConfig::passthrough());
    assert!(soc.run_until_done(200_000_000));
    let c: &mut AmrCluster = soc.initiator_mut(InitiatorId(0));
    (
        c.stats.finished_at,
        c.stats.faults_detected,
        c.stats.faults_silent,
        c.stats.recovery_cycles,
    )
}

fn main() {
    println!("== AMR cluster campaign: 128^3 int8 MatMul under transient faults");
    let mut rows = Vec::new();
    for &rate in &[0.0, 0.2, 1.0, 5.0] {
        for (label, mode, rec) in [
            ("INDIP (no protection)", AmrMode::Indip, Recovery::Hfr),
            ("DLM + HFR", AmrMode::Dlm, Recovery::Hfr),
            ("TLM + HFR", AmrMode::Tlm, Recovery::Hfr),
            ("TLM + SW recovery", AmrMode::Tlm, Recovery::Software),
            ("DLM reboot-only", AmrMode::Dlm, Recovery::RebootOnly),
        ] {
            let (makespan, detected, silent, rec_cycles) = run_amr(mode, rec, rate, 42);
            rows.push(vec![
                format!("{rate:.1}"),
                label.to_string(),
                makespan.to_string(),
                detected.to_string(),
                silent.to_string(),
                rec_cycles.to_string(),
                format!("{:.2}%", rec_cycles as f64 / makespan as f64 * 100.0),
            ]);
        }
    }
    print_table(
        "faults/kcycle sweep",
        &["rate", "config", "makespan", "detected", "SILENT", "recovery cyc", "overhead"],
        &rows,
    );

    println!("\n== Safe-domain TCLS soak: 100k commits with random single-event upsets");
    let mut tcls = Tcls::new();
    let mut rng = XorShift::new(0xFA07);
    let mut corrected = 0u64;
    let mut fatal = 0u64;
    for now in 0..100_000u64 {
        if rng.chance(0.001) {
            tcls.inject_fault(rng.below(3) as usize, &mut rng);
        }
        match tcls.commit(now) {
            Commit::Corrected { .. } => corrected += 1,
            Commit::Fatal => fatal += 1,
            Commit::Clean => {}
        }
    }
    println!(
        "commits=100000 corrected={corrected} fatal={fatal} (single faults must never be fatal)"
    );
    assert_eq!(fatal, 0, "TCLS masked every single fault");
    println!("TCLS soak passed: all single-event upsets masked by the voter.");
}

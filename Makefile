# Carfield-sim top-level targets.
#
# `bench` is the perf-trajectory hook: it runs the hot-path bench and
# records machine-readable results in BENCH_perf_hotpath.json at the
# repo root, so simulator throughput (Mcyc/s) is tracked from PR to PR.

RUST_DIR := rust

.PHONY: build test bench wcet autotune dvfs faults trace workingset pack artifacts python-test

# Queue depth for the admission-service smoke run (the bench drives the
# full 10^5/10^6 depths; CI smokes the pipeline at 10^4).
PACK_DEPTH ?= 10000

build:
	cd $(RUST_DIR) && cargo build --release

test:
	cd $(RUST_DIR) && cargo test -q

bench:
	cd $(RUST_DIR) && CARFIELD_BENCH_JSON=$(abspath BENCH_perf_hotpath.json) \
		cargo bench --bench perf_hotpath

# Analytical WCET bounds vs measured worst case (fig6a/fig6b grids).
wcet: build
	$(RUST_DIR)/target/release/carfield wcet

# Bound-driven tuning-space search: mixes admitted by the fixed
# four-policy ladder vs the auto-tuner, with validating simulations.
autotune: build
	$(RUST_DIR)/target/release/carfield autotune

# Bound-driven DVFS governor: fig6a/fig6b deadline grids searched for
# energy-minimal provably-safe operating points, with validating
# simulations and measured energy columns.
dvfs: build
	$(RUST_DIR)/target/release/carfield dvfs

# Deterministic fault-injection grid: k-fault admission verdicts
# validated by seeded faulted simulations (fails on an unsound bound,
# an empty availability grid, or a fault dimension that never binds).
faults: build
	$(RUST_DIR)/target/release/carfield faults

# Bound gap attribution: the fig6a grid traced into per-resource
# interference ledgers printed next to the WCET breakdown terms; JSONL +
# Perfetto sink files land in rust/target/trace/ (fails on a ledger that
# does not re-sum to its makespan, a measured term over its bound, a
# perturbed report, or an invalid sink).
trace: build
	cd $(RUST_DIR) && target/release/carfield trace

# Working-set observability: traced fig6a profiles, the TCT's
# partition-fit certificate, and the admission flip it buys (fails on a
# profile-sum mismatch, an unsound certificate, or a missing
# cold-rejected/certified-admitted flip); certificate JSON lands in
# rust/target/workingset/.
workingset: build
	cd $(RUST_DIR) && target/release/carfield workingset

# Admission as a service: a seeded request queue packed into co-resident
# mixes by the racing bound-aware heuristics, governed, and confirmed by
# one batched validation sweep (fails on zero co-residency, an unsound
# packed mix, a refuted validation row, or race accounting that misses a
# batch). Results are bit-identical at any shard width.
pack: build
	cd $(RUST_DIR) && target/release/carfield pack --depth $(PACK_DEPTH)

# AOT-lower the JAX/Pallas kernels to HLO text artifacts consumed by the
# rust PJRT runtime (requires the python toolchain).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../$(RUST_DIR)/artifacts

python-test:
	cd python && python3 -m pytest -q tests

//! Deterministic, seeded fault-injection plans.
//!
//! A [`FaultPlan`] attaches a *reliability scenario* to a
//! [`Scenario`](crate::coordinator::Scenario): seeded lockstep-mismatch
//! events on the AMR cluster (forcing HFR recovery plus a full tile
//! re-execution), transient HyperRAM line retries with a bounded retry
//! count per line, and an ECC scrub engine emitting periodic background
//! read traffic. Everything is derived from the plan's seed — the same
//! plan injects the bit-identical fault sequence on every run, on every
//! thread count, and under both the naive and the event-driven
//! simulator — so faulted campaigns reproduce exactly, in the style of
//! `wcet::fuzz`.
//!
//! The analytic counterpart lives in `wcet::bound`: `analyze` prices the
//! same plan as (a) a per-line retry inflation of
//! `HyperRamTiming::worst_lines_cost`, (b) an extra regulated scrub
//! initiator in the interference model, and (c) a k-fault re-execution
//! term in [`TaskBound`](crate::wcet::TaskBound) so `Scheduler::admit`
//! answers "does this mix meet its deadlines with up to `k_faults`
//! recoveries?". The injection side caps AMR mismatches at `k_faults`
//! (the hypothesis admission certifies) while retries and scrub traffic
//! are *unbudgeted* — their worst case is already priced per line /
//! per window, so soundness needs no event count.
//!
//! With tracing armed ([`crate::trace`]), every consequence of a plan is
//! visible in the event stream: HFR recoveries and reboots as `recovery`
//! events (Perfetto instants on the cluster's track), retry overhead on
//! each `line_fill` event's `retry_cycles` field, and scrub traffic as
//! one more initiator's `delivery` lifecycle — so a faulted campaign's
//! ledger attributes recovery stalls per task next to the k-fault bound
//! term.

use crate::soc::clock::Cycle;

/// ECC scrub engine configuration: every `period` cycles the scrubber
/// reads `beats` bus beats of HyperRAM-backed memory in the background.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScrubConfig {
    /// Scrub period in uncore-referenced TSU cycles (the scrubber is
    /// TRU-regulated to exactly this cadence).
    pub period: Cycle,
    /// Beats read per scrub burst.
    pub beats: u32,
}

impl ScrubConfig {
    /// The Carfield patrol scrubber: one 64B line (8 beats) every 512
    /// cycles — ~1.5% of channel bandwidth, matching an ECC scrub pass
    /// over the 32MiB HyperRAM every few hundred ms at 1GHz.
    pub fn carfield() -> Self {
        ScrubConfig {
            period: 512,
            beats: 8,
        }
    }
}

/// A deterministic fault-injection plan for one scenario.
///
/// `FaultPlan::new(seed)` is the all-quiet plan (no faults of any
/// class, `k_faults = 0`); builders switch on individual fault classes.
/// The quiet plan is bit-identical to no plan at all, in both the
/// simulator and the bound engine (pinned by `tests/fault_soundness.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Campaign seed: every per-task fault RNG stream is derived from
    /// (this seed, the task's initiator slot) via [`Self::stream_seed`].
    pub seed: u64,
    /// Expected AMR lockstep mismatches per 1000 cluster cycles.
    pub amr_fault_per_kcycle: f64,
    /// Inject a transient retry burst on every n-th HyperRAM line fill
    /// (0 = never).
    pub retry_every_lines: u64,
    /// Retries per affected line (each costs a full row-miss re-fetch).
    pub retries_per_line: u32,
    /// Max AMR recoveries the admission bound must cover — and the
    /// injection budget: the simulator injects at most this many
    /// lockstep mismatches per cluster, so "measured ≤ k-fault bound"
    /// is the exact hypothesis being validated.
    pub k_faults: u32,
    /// Background ECC scrub traffic, if enabled.
    pub scrub: Option<ScrubConfig>,
}

impl FaultPlan {
    /// The all-quiet plan for `seed`: no fault class enabled.
    pub fn new(seed: u64) -> Self {
        FaultPlan {
            seed,
            amr_fault_per_kcycle: 0.0,
            retry_every_lines: 0,
            retries_per_line: 0,
            k_faults: 0,
            scrub: None,
        }
    }

    /// Enable seeded AMR lockstep mismatches at `rate` per kcycle.
    pub fn with_amr_rate(mut self, rate: f64) -> Self {
        self.amr_fault_per_kcycle = rate;
        self
    }

    /// Enable HyperRAM line retries: `retries` extra row-miss fetches on
    /// every `every`-th line fill.
    pub fn with_retries(mut self, every: u64, retries: u32) -> Self {
        self.retry_every_lines = every;
        self.retries_per_line = retries;
        self
    }

    /// Set the re-execution budget the admission bound covers.
    pub fn with_k(mut self, k: u32) -> Self {
        self.k_faults = k;
        self
    }

    /// Enable the background ECC scrubber.
    pub fn with_scrub(mut self, scrub: ScrubConfig) -> Self {
        self.scrub = Some(scrub);
        self
    }

    /// True when no fault class is enabled *and* `k_faults == 0` — the
    /// plan that must be indistinguishable from no plan.
    pub fn is_quiet(&self) -> bool {
        self.amr_fault_per_kcycle == 0.0
            && self.retry_every_lines == 0
            && self.k_faults == 0
            && self.scrub.is_none()
    }

    /// Derive the per-task fault RNG seed for initiator `slot`.
    ///
    /// SplitMix64-style finalizer over (campaign seed, slot): streams
    /// for different tasks are decorrelated, and — crucially for the
    /// sweep — a task's stream depends only on the scenario's plan and
    /// its own slot, never on sibling tasks or on which worker thread
    /// runs the scenario (`tests/fault_soundness.rs` pins bit-identical
    /// fault reports across `CARFIELD_THREADS` ∈ {1, 2, 8}).
    pub fn stream_seed(&self, slot: usize) -> u64 {
        let mut z = self
            .seed
            .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(slot as u64 + 1));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        // XorShift::new rejects 0; the finalizer output is 0 only for
        // one input in 2^64 — nudge it off the fixed point.
        (z ^ (z >> 31)) | 1
    }

    /// Extra HyperRAM cycles the plan can add to *one* line fill, given
    /// the per-retry cost (a full row-miss re-fetch of the line).
    pub fn retry_overhead(&self, per_retry: Cycle) -> Cycle {
        if self.retry_every_lines == 0 {
            0
        } else {
            self.retries_per_line as Cycle * per_retry
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quiet_plan_is_quiet() {
        assert!(FaultPlan::new(7).is_quiet());
        assert!(!FaultPlan::new(7).with_k(1).is_quiet());
        assert!(!FaultPlan::new(7).with_amr_rate(0.5).is_quiet());
        assert!(!FaultPlan::new(7).with_retries(64, 1).is_quiet());
        assert!(!FaultPlan::new(7).with_scrub(ScrubConfig::carfield()).is_quiet());
    }

    #[test]
    fn stream_seeds_are_deterministic_and_decorrelated() {
        let p = FaultPlan::new(42);
        let seeds: Vec<u64> = (0..8).map(|s| p.stream_seed(s)).collect();
        let again: Vec<u64> = (0..8).map(|s| p.stream_seed(s)).collect();
        assert_eq!(seeds, again);
        let unique: std::collections::HashSet<_> = seeds.iter().collect();
        assert_eq!(unique.len(), seeds.len(), "per-slot streams collide");
        assert!(seeds.iter().all(|&s| s != 0), "XorShift rejects seed 0");
        // A different campaign seed shifts every stream.
        let other = FaultPlan::new(43);
        assert!((0..8).all(|s| other.stream_seed(s) != p.stream_seed(s)));
    }

    #[test]
    fn retry_overhead_follows_the_knobs() {
        let p = FaultPlan::new(1).with_retries(64, 2);
        assert_eq!(p.retry_overhead(40), 80);
        assert_eq!(FaultPlan::new(1).retry_overhead(40), 0);
    }
}

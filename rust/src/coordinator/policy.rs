//! Isolation policies: how the coordinator programs the hardware IPs for
//! a given criticality mix.
//!
//! These mirror the four regimes of Fig. 6:
//!
//! - `NoIsolation` — reset state, everything unregulated (R-E2 /
//!   "unregulated interference");
//! - `TsuRegulation` — GBS+TRU throttle every best-effort initiator
//!   (Fig. 6a "regulated", Fig. 6b R-E3);
//! - `TsuPlusLlcPartition` — adds a DPLLC spatial partition for the TCT
//!   (Fig. 6a ">=50% partition");
//! - `PrivatePaths` — adds DCSPM contiguous aliasing so each cluster's
//!   buffers occupy disjoint banks/ports (Fig. 6b R-E4, "zero extra
//!   performance overhead").

use crate::soc::clock::Cycle;
use crate::soc::mem::dcspm::CONTIG_ALIAS_BIT;
use crate::soc::tsu::TsuConfig;

/// Coordinator-selectable isolation level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IsolationPolicy {
    NoIsolation,
    TsuRegulation,
    TsuPlusLlcPartition {
        /// Fraction of DPLLC sets granted to the TCT partition.
        tct_fraction_percent: u8,
    },
    PrivatePaths,
}

/// Concrete register-level settings derived from a policy.
#[derive(Debug, Clone)]
pub struct ResourceConfig {
    /// TSU program for initiators running best-effort work.
    pub nct_tsu: TsuConfig,
    /// TSU program for time-critical initiators (always passthrough —
    /// TCTs are never throttled).
    pub tct_tsu: TsuConfig,
    /// DPLLC set partitioning: `(first_set, n_sets)` per part_id.
    pub dpllc_partitions: Vec<(usize, usize)>,
    /// part_id handed to TCT traffic.
    pub tct_part_id: u8,
    /// Whether cluster L2 buffers use the contiguous alias window.
    pub dcspm_private_paths: bool,
}

impl IsolationPolicy {
    /// TRU parameters used across the Fig. 6 experiments: NCTs may move
    /// `budget` beats every `period` cycles in fragments of `gbs` beats.
    /// The budget leaves the NCT enough bandwidth to keep polluting a
    /// *shared* DPLLC (which is why the partition still matters — paper
    /// Fig. 6a), while bounding its interconnect occupancy.
    pub const NCT_GBS_BEATS: u32 = 8;
    pub const NCT_BUDGET_BEATS: u32 = 96;
    pub const NCT_PERIOD: Cycle = 512;

    pub fn resource_config(&self) -> ResourceConfig {
        let total_sets = 256;
        match *self {
            IsolationPolicy::NoIsolation => ResourceConfig {
                nct_tsu: TsuConfig::passthrough(),
                tct_tsu: TsuConfig::passthrough(),
                dpllc_partitions: vec![(0, total_sets)],
                tct_part_id: 0,
                dcspm_private_paths: false,
            },
            IsolationPolicy::TsuRegulation => ResourceConfig {
                nct_tsu: TsuConfig::regulated(
                    Self::NCT_GBS_BEATS,
                    Self::NCT_BUDGET_BEATS,
                    Self::NCT_PERIOD,
                ),
                // TCTs keep the WB (always-on TSU hardware) but are never
                // split or rate-limited.
                tct_tsu: TsuConfig::wb_only(),
                dpllc_partitions: vec![(0, total_sets)],
                tct_part_id: 0,
                dcspm_private_paths: false,
            },
            IsolationPolicy::TsuPlusLlcPartition {
                tct_fraction_percent,
            } => {
                let frac = (tct_fraction_percent as usize).clamp(1, 99);
                let tct_sets = (total_sets * frac / 100).clamp(1, total_sets - 1);
                ResourceConfig {
                    nct_tsu: TsuConfig::regulated(
                        Self::NCT_GBS_BEATS,
                        Self::NCT_BUDGET_BEATS,
                        Self::NCT_PERIOD,
                    ),
                    tct_tsu: TsuConfig::wb_only(),
                    // part 0: everyone else; part 1: the TCT.
                    dpllc_partitions: vec![
                        (0, total_sets - tct_sets),
                        (total_sets - tct_sets, tct_sets),
                    ],
                    tct_part_id: 1,
                    dcspm_private_paths: false,
                }
            }
            IsolationPolicy::PrivatePaths => ResourceConfig {
                // No rate limiting needed — paths are disjoint. WB stays
                // on (it is always-on TSU hardware, <=1 cycle).
                nct_tsu: TsuConfig::wb_only(),
                tct_tsu: TsuConfig::wb_only(),
                dpllc_partitions: vec![(0, total_sets / 2), (total_sets / 2, total_sets / 2)],
                tct_part_id: 1,
                dcspm_private_paths: true,
            },
        }
    }

    /// L2 staging base for the initiator with index `slot`, honouring the
    /// private-path aliasing. Slots alternate between the two DCSPM port
    /// halves (low/high 512KiB) so that in contiguous mode adjacent slots
    /// land on *different* ports and disjoint banks — the private paths
    /// of Fig. 6b R-E4.
    pub fn l2_base(&self, slot: usize) -> u64 {
        let cfg = self.resource_config();
        let s = slot as u64 % 4;
        let base = (s % 2) * (1 << 19) + (s / 2) * (1 << 18);
        if cfg.dcspm_private_paths {
            CONTIG_ALIAS_BIT | base
        } else {
            base
        }
    }

    /// Bytes of L2 each slot may touch (streams wrap within this window
    /// so private-path slots never spill onto the other port).
    pub const L2_SLOT_BYTES: u64 = 1 << 18; // 256 KiB
}

/// TSU program for a given initiator under a policy (helper used by the
/// scheduler when wiring a scenario).
pub fn tsu_for(policy: IsolationPolicy, time_critical: bool) -> TsuConfig {
    let cfg = policy.resource_config();
    if time_critical {
        cfg.tct_tsu
    } else {
        cfg.nct_tsu
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_isolation_is_reset_state() {
        let cfg = IsolationPolicy::NoIsolation.resource_config();
        assert_eq!(cfg.nct_tsu, TsuConfig::passthrough());
        assert_eq!(cfg.dpllc_partitions, vec![(0, 256)]);
        assert!(!cfg.dcspm_private_paths);
    }

    #[test]
    fn regulation_throttles_ncts_only() {
        let cfg = IsolationPolicy::TsuRegulation.resource_config();
        assert!(cfg.nct_tsu.tru_budget_beats > 0);
        assert!(cfg.nct_tsu.gbs_max_beats > 0);
        // TCT keeps only the write buffer — never split or rate-limited.
        assert_eq!(cfg.tct_tsu.gbs_max_beats, 0);
        assert_eq!(cfg.tct_tsu.tru_budget_beats, 0);
        assert!(cfg.tct_tsu.wb_enable);
    }

    #[test]
    fn partition_sizes_follow_percentage() {
        for pct in [25u8, 50, 75] {
            let cfg = IsolationPolicy::TsuPlusLlcPartition {
                tct_fraction_percent: pct,
            }
            .resource_config();
            let (_, tct_sets) = cfg.dpllc_partitions[1];
            assert_eq!(tct_sets, 256 * pct as usize / 100);
            let (_, rest) = cfg.dpllc_partitions[0];
            assert_eq!(rest + tct_sets, 256);
        }
    }

    #[test]
    fn partition_extremes_clamped() {
        let cfg = IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: 100,
        }
        .resource_config();
        let (_, tct_sets) = cfg.dpllc_partitions[1];
        assert!(tct_sets < 256);
    }

    #[test]
    fn private_paths_alias_l2() {
        let p = IsolationPolicy::PrivatePaths;
        assert!(p.l2_base(0) & CONTIG_ALIAS_BIT != 0);
        // Disjoint slots.
        assert_ne!(p.l2_base(0), p.l2_base(1));
        let n = IsolationPolicy::NoIsolation;
        assert_eq!(n.l2_base(0) & CONTIG_ALIAS_BIT, 0);
    }

    #[test]
    fn tsu_for_criticality() {
        let p = IsolationPolicy::TsuRegulation;
        assert_eq!(tsu_for(p, true).tru_budget_beats, 0);
        assert!(tsu_for(p, false).tru_budget_beats > 0);
    }
}

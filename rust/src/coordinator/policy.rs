//! Isolation tuning: how the coordinator programs the hardware IPs for
//! a given criticality mix.
//!
//! The paper's point is that the isolation IPs are *software-
//! configurable*: TSU budgets, DPLLC partitions and DCSPM aliasing are
//! registers, not fixed circuits. [`SocTuning`] is that register space —
//! a parameterized point the coordinator (and the bound-driven
//! auto-tuner in [`crate::coordinator::autotune`]) can place anywhere,
//! not just on the four regimes of Fig. 6.
//!
//! The legacy [`IsolationPolicy`] ladder survives as *named points* in
//! the space (kept as constructors for backward compatibility and proven
//! register-identical by `tests/legacy_policy_equivalence.rs`):
//!
//! - `NoIsolation` — reset state, everything unregulated (R-E2 /
//!   "unregulated interference");
//! - `TsuRegulation` — GBS+TRU throttle every best-effort initiator
//!   (Fig. 6a "regulated", Fig. 6b R-E3);
//! - `TsuPlusLlcPartition` — adds a DPLLC spatial partition for the TCT
//!   (Fig. 6a ">=50% partition");
//! - `PrivatePaths` — adds DCSPM contiguous aliasing so each cluster's
//!   buffers occupy disjoint banks/ports (Fig. 6b R-E4, "zero extra
//!   performance overhead").

use crate::soc::clock::Cycle;
use crate::soc::mem::dcspm::CONTIG_ALIAS_BIT;
use crate::soc::mem::dpllc;
use crate::soc::tsu::TsuConfig;

/// A misconfigured tuning point. Degenerate register settings (an empty
/// or over-full partition, a splitter coarser than the regulation
/// budget, a budget that never refills) are rejected loudly instead of
/// silently producing a useless configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TuningError {
    /// `TsuPlusLlcPartition` fraction outside 1..=100.
    PartitionPercentOutOfRange { percent: u8 },
    /// `tct_sets` would leave the shared partition empty (or is larger
    /// than the cache).
    PartitionTooLarge { tct_sets: usize, total_sets: usize },
    /// GBS fragments larger than the TRU budget can never pass without
    /// the oversize exception — the regulation is self-defeating.
    GbsExceedsBudget { gbs: u32, budget: u32 },
    /// A TRU budget with no refill period starves the initiator.
    BudgetWithoutPeriod { budget: u32 },
}

impl std::fmt::Display for TuningError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            TuningError::PartitionPercentOutOfRange { percent } => write!(
                f,
                "TCT partition fraction {percent}% is outside 1..=100: the \
                 DPLLC cannot grant more than every set (or fewer than one)"
            ),
            TuningError::PartitionTooLarge {
                tct_sets,
                total_sets,
            } => write!(
                f,
                "TCT partition of {tct_sets} sets does not fit a \
                 {total_sets}-set DPLLC while leaving the shared partition \
                 at least one set"
            ),
            TuningError::GbsExceedsBudget { gbs, budget } => write!(
                f,
                "GBS fragment size {gbs} beats exceeds the TRU budget \
                 {budget} beats/period: every fragment would need the \
                 oversize exception and the regulation is meaningless"
            ),
            TuningError::BudgetWithoutPeriod { budget } => write!(
                f,
                "TRU budget {budget} beats with period 0 never refills and \
                 starves the initiator; use budget 0 (unregulated) or a \
                 nonzero period"
            ),
        }
    }
}

impl std::error::Error for TuningError {}

/// One initiator class's TSU knobs — the software-visible shaper
/// registers, pre-validation (maps 1:1 onto [`TsuConfig`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TsuKnobs {
    /// GBS: max beats per fragment; 0 disables splitting.
    pub gbs_beats: u32,
    /// TRU: beats allowed per period; 0 disables regulation.
    pub budget_beats: u32,
    /// TRU: communication period in cycles.
    pub period: Cycle,
    /// WB: buffer writes so they never hold the W channel.
    pub write_buffer: bool,
}

impl TsuKnobs {
    /// Transparent shaper (reset state).
    pub const fn passthrough() -> Self {
        Self {
            gbs_beats: 0,
            budget_beats: 0,
            period: 0,
            write_buffer: false,
        }
    }

    /// Write buffering only — no splitting or rate limiting.
    pub const fn wb_only() -> Self {
        Self {
            gbs_beats: 0,
            budget_beats: 0,
            period: 0,
            write_buffer: true,
        }
    }

    /// GBS + TRU + WB throttling profile.
    pub const fn regulated(gbs_beats: u32, budget_beats: u32, period: Cycle) -> Self {
        Self {
            gbs_beats,
            budget_beats,
            period,
            write_buffer: true,
        }
    }

    /// Whether the TRU actually regulates (budget with a refill period).
    pub fn is_regulated(&self) -> bool {
        self.budget_beats > 0 && self.period > 0
    }

    pub fn validate(&self) -> Result<(), TuningError> {
        if self.budget_beats > 0 && self.period == 0 {
            return Err(TuningError::BudgetWithoutPeriod {
                budget: self.budget_beats,
            });
        }
        if self.budget_beats > 0 && self.gbs_beats > self.budget_beats {
            return Err(TuningError::GbsExceedsBudget {
                gbs: self.gbs_beats,
                budget: self.budget_beats,
            });
        }
        Ok(())
    }

    /// The concrete shaper registers. Reproduces the seed's
    /// `TsuConfig` constructors bit-for-bit on the named points
    /// (`passthrough`/`wb_only`/`regulated`).
    pub fn config(&self) -> TsuConfig {
        if !self.write_buffer {
            TsuConfig {
                gbs_max_beats: self.gbs_beats,
                wb_enable: false,
                wb_capacity_beats: 0,
                tru_budget_beats: self.budget_beats,
                tru_period: self.period,
            }
        } else if self.gbs_beats == 0 {
            // No splitter: keep the full wb_only-sized buffer (the
            // regulated profile sizes its buffer off the GBS fragment —
            // with gbs 0 that would shrink to 16 beats and silently
            // reintroduce multi-cycle write fills on long bursts).
            TsuConfig {
                tru_budget_beats: self.budget_beats,
                tru_period: self.period,
                ..TsuConfig::wb_only()
            }
        } else {
            TsuConfig::regulated(self.gbs_beats, self.budget_beats, self.period)
        }
    }

    /// Compact human-readable form for reports.
    pub fn describe(&self) -> String {
        let mut parts = Vec::new();
        if self.gbs_beats > 0 {
            parts.push(format!("gbs={}", self.gbs_beats));
        }
        if self.budget_beats > 0 {
            parts.push(format!("tru={}/{}", self.budget_beats, self.period));
        }
        if self.write_buffer {
            parts.push("wb".to_string());
        }
        if parts.is_empty() {
            "passthrough".to_string()
        } else {
            parts.join("+")
        }
    }
}

/// A point in the SoC's isolation-configuration space: the registers the
/// coordinator programs before launching a mix. Unlike the closed
/// [`IsolationPolicy`] ladder, every knob is free — which is what the
/// bound-driven auto-tuner searches over, and what the DVFS governor
/// ([`crate::power::governor`]) pairs with an
/// [`OperatingPoint`](crate::power::OperatingPoint) when it searches the
/// (voltage x tuning) product for the energy-minimal admissible pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SocTuning {
    /// TSU program for initiators running best-effort work.
    pub nct_tsu: TsuKnobs,
    /// TSU program for time-critical initiators (never TRU-throttled by
    /// any named point; the knob exists because the space is open).
    pub tct_tsu: TsuKnobs,
    /// DPLLC sets granted to an exclusive TCT partition; 0 keeps one
    /// shared partition spanning the whole cache.
    pub tct_sets: usize,
    /// Whether cluster L2 buffers use the contiguous alias window
    /// (disjoint DCSPM banks/ports per slot).
    pub dcspm_private_paths: bool,
}

impl SocTuning {
    /// TRU parameters used across the Fig. 6 experiments: NCTs may move
    /// `budget` beats every `period` cycles in fragments of `gbs` beats.
    /// The budget leaves the NCT enough bandwidth to keep polluting a
    /// *shared* DPLLC (which is why the partition still matters — paper
    /// Fig. 6a), while bounding its interconnect occupancy.
    pub const NCT_GBS_BEATS: u32 = 8;
    pub const NCT_BUDGET_BEATS: u32 = 96;
    pub const NCT_PERIOD: Cycle = 512;

    /// Bytes of L2 each slot may touch (streams wrap within this window
    /// so private-path slots never spill onto the other port).
    pub const L2_SLOT_BYTES: u64 = 1 << 18; // 256 KiB

    /// Reset state: everything unregulated, one shared partition.
    pub const fn no_isolation() -> Self {
        Self {
            nct_tsu: TsuKnobs::passthrough(),
            tct_tsu: TsuKnobs::passthrough(),
            tct_sets: 0,
            dcspm_private_paths: false,
        }
    }

    /// The Fig. 6 GBS+TRU throttle on every best-effort initiator; TCTs
    /// keep the (always-on) write buffer but are never rate-limited.
    pub const fn tsu_regulation() -> Self {
        Self {
            nct_tsu: TsuKnobs::regulated(
                Self::NCT_GBS_BEATS,
                Self::NCT_BUDGET_BEATS,
                Self::NCT_PERIOD,
            ),
            tct_tsu: TsuKnobs::wb_only(),
            tct_sets: 0,
            dcspm_private_paths: false,
        }
    }

    /// TSU regulation plus an exclusive DPLLC partition of
    /// `tct_fraction_percent` of the sets for the TCT. Panics
    /// (descriptively) outside 1..=100 — same loudness as the legacy
    /// enum path; 100% clamps to the seed's 99% behaviour.
    pub fn tsu_plus_llc_partition(tct_fraction_percent: u8) -> Self {
        if tct_fraction_percent == 0 || tct_fraction_percent > 100 {
            let e = TuningError::PartitionPercentOutOfRange {
                percent: tct_fraction_percent,
            };
            panic!("invalid SocTuning: {e}");
        }
        let total = dpllc::TOTAL_SETS;
        let frac = (tct_fraction_percent as usize).clamp(1, 99);
        Self {
            tct_sets: (total * frac / 100).clamp(1, total - 1),
            ..Self::tsu_regulation()
        }
    }

    /// Disjoint DCSPM banks/ports per cluster plus a half-cache DPLLC
    /// partition; no rate limiting needed — paths are disjoint.
    pub const fn private_paths() -> Self {
        Self {
            nct_tsu: TsuKnobs::wb_only(),
            tct_tsu: TsuKnobs::wb_only(),
            tct_sets: dpllc::TOTAL_SETS / 2,
            dcspm_private_paths: true,
        }
    }

    /// Validate every knob, returning the first violation.
    pub fn validate(&self) -> Result<(), TuningError> {
        self.nct_tsu.validate()?;
        self.tct_tsu.validate()?;
        if self.tct_sets >= dpllc::TOTAL_SETS {
            return Err(TuningError::PartitionTooLarge {
                tct_sets: self.tct_sets,
                total_sets: dpllc::TOTAL_SETS,
            });
        }
        Ok(())
    }

    /// `self` if valid, the violation otherwise.
    pub fn validated(self) -> Result<Self, TuningError> {
        self.validate().map(|()| self)
    }

    /// Concrete register-level settings. Panics (descriptively) on an
    /// invalid tuning — admission control and the auto-tuner must never
    /// program degenerate registers silently.
    pub fn resource_config(&self) -> ResourceConfig {
        if let Err(e) = self.validate() {
            panic!("invalid SocTuning: {e}");
        }
        let total = dpllc::TOTAL_SETS;
        let (dpllc_partitions, tct_part_id) = if self.tct_sets == 0 {
            (vec![(0, total)], 0)
        } else {
            // part 0: everyone else; part 1: the TCT.
            (
                vec![
                    (0, total - self.tct_sets),
                    (total - self.tct_sets, self.tct_sets),
                ],
                1,
            )
        };
        ResourceConfig {
            nct_tsu: self.nct_tsu.config(),
            tct_tsu: self.tct_tsu.config(),
            dpllc_partitions,
            tct_part_id,
            dcspm_private_paths: self.dcspm_private_paths,
        }
    }

    /// L2 staging base for the initiator with index `slot`, honouring the
    /// private-path aliasing. Slots alternate between the two DCSPM port
    /// halves (low/high 512KiB) so that in contiguous mode adjacent slots
    /// land on *different* ports and disjoint banks — the private paths
    /// of Fig. 6b R-E4.
    pub fn l2_base(&self, slot: usize) -> u64 {
        let s = slot as u64 % 4;
        let base = (s % 2) * (1 << 19) + (s / 2) * (1 << 18);
        if self.dcspm_private_paths {
            CONTIG_ALIAS_BIT | base
        } else {
            base
        }
    }

    /// TSU program for one initiator class.
    pub fn tsu_config(&self, time_critical: bool) -> TsuConfig {
        if time_critical {
            self.tct_tsu.config()
        } else {
            self.nct_tsu.config()
        }
    }

    /// Human-readable form; names the legacy ladder points.
    pub fn describe(&self) -> String {
        if *self == Self::no_isolation() {
            return "NoIsolation".to_string();
        }
        if *self == Self::tsu_regulation() {
            return "TsuRegulation".to_string();
        }
        if *self == Self::private_paths() {
            return "PrivatePaths".to_string();
        }
        if self.nct_tsu == Self::tsu_regulation().nct_tsu
            && self.tct_tsu == TsuKnobs::wb_only()
            && self.tct_sets > 0
            && !self.dcspm_private_paths
        {
            return format!("TsuPlusLlcPartition({} sets)", self.tct_sets);
        }
        format!(
            "SocTuning(nct[{}] tct[{}] llc[{}] dcspm[{}])",
            self.nct_tsu.describe(),
            self.tct_tsu.describe(),
            if self.tct_sets == 0 {
                "shared".to_string()
            } else {
                format!("{} TCT sets", self.tct_sets)
            },
            if self.dcspm_private_paths {
                "private"
            } else {
                "interleaved"
            }
        )
    }
}

impl From<IsolationPolicy> for SocTuning {
    fn from(policy: IsolationPolicy) -> Self {
        policy.tuning()
    }
}

/// Legacy coordinator-selectable isolation level — the four named points
/// of the Fig. 6 ladder, kept as constructors into [`SocTuning`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IsolationPolicy {
    NoIsolation,
    TsuRegulation,
    TsuPlusLlcPartition {
        /// Fraction of DPLLC sets granted to the TCT partition.
        tct_fraction_percent: u8,
    },
    PrivatePaths,
}

impl IsolationPolicy {
    /// Validate the ladder point (the partition fraction is the only
    /// free parameter).
    pub fn validate(&self) -> Result<(), TuningError> {
        if let IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent,
        } = *self
        {
            if tct_fraction_percent == 0 || tct_fraction_percent > 100 {
                return Err(TuningError::PartitionPercentOutOfRange {
                    percent: tct_fraction_percent,
                });
            }
        }
        Ok(())
    }

    /// The tuning-space point this ladder policy names. Panics
    /// (descriptively) on an out-of-range partition fraction.
    pub fn tuning(&self) -> SocTuning {
        if let Err(e) = self.validate() {
            panic!("invalid isolation policy: {e}");
        }
        match *self {
            IsolationPolicy::NoIsolation => SocTuning::no_isolation(),
            IsolationPolicy::TsuRegulation => SocTuning::tsu_regulation(),
            IsolationPolicy::TsuPlusLlcPartition {
                tct_fraction_percent,
            } => SocTuning::tsu_plus_llc_partition(tct_fraction_percent),
            IsolationPolicy::PrivatePaths => SocTuning::private_paths(),
        }
    }

    pub fn resource_config(&self) -> ResourceConfig {
        self.tuning().resource_config()
    }

    pub fn l2_base(&self, slot: usize) -> u64 {
        self.tuning().l2_base(slot)
    }
}

/// Concrete register-level settings derived from a tuning point.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceConfig {
    /// TSU program for initiators running best-effort work.
    pub nct_tsu: TsuConfig,
    /// TSU program for time-critical initiators (always passthrough or
    /// WB-only on the named points — TCTs are never throttled).
    pub tct_tsu: TsuConfig,
    /// DPLLC set partitioning: `(first_set, n_sets)` per part_id.
    pub dpllc_partitions: Vec<(usize, usize)>,
    /// part_id handed to TCT traffic.
    pub tct_part_id: u8,
    /// Whether cluster L2 buffers use the contiguous alias window.
    pub dcspm_private_paths: bool,
}

/// TSU program for a given initiator under a tuning. Legacy seed API
/// kept for compatibility — the scheduler and the WCET traffic models
/// now read [`SocTuning::tsu_config`] directly.
pub fn tsu_for(tuning: impl Into<SocTuning>, time_critical: bool) -> TsuConfig {
    tuning.into().tsu_config(time_critical)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_isolation_is_reset_state() {
        let cfg = IsolationPolicy::NoIsolation.resource_config();
        assert_eq!(cfg.nct_tsu, TsuConfig::passthrough());
        assert_eq!(cfg.dpllc_partitions, vec![(0, 256)]);
        assert!(!cfg.dcspm_private_paths);
    }

    #[test]
    fn regulation_throttles_ncts_only() {
        let cfg = IsolationPolicy::TsuRegulation.resource_config();
        assert!(cfg.nct_tsu.tru_budget_beats > 0);
        assert!(cfg.nct_tsu.gbs_max_beats > 0);
        // TCT keeps only the write buffer — never split or rate-limited.
        assert_eq!(cfg.tct_tsu.gbs_max_beats, 0);
        assert_eq!(cfg.tct_tsu.tru_budget_beats, 0);
        assert!(cfg.tct_tsu.wb_enable);
    }

    #[test]
    fn partition_sizes_follow_percentage() {
        for pct in [25u8, 50, 75] {
            let cfg = IsolationPolicy::TsuPlusLlcPartition {
                tct_fraction_percent: pct,
            }
            .resource_config();
            let (_, tct_sets) = cfg.dpllc_partitions[1];
            assert_eq!(tct_sets, 256 * pct as usize / 100);
            let (_, rest) = cfg.dpllc_partitions[0];
            assert_eq!(rest + tct_sets, 256);
        }
    }

    #[test]
    fn partition_extremes_clamped() {
        let cfg = IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: 100,
        }
        .resource_config();
        let (_, tct_sets) = cfg.dpllc_partitions[1];
        assert!(tct_sets < 256);
    }

    #[test]
    fn private_paths_alias_l2() {
        let p = IsolationPolicy::PrivatePaths;
        assert!(p.l2_base(0) & CONTIG_ALIAS_BIT != 0);
        // Disjoint slots.
        assert_ne!(p.l2_base(0), p.l2_base(1));
        let n = IsolationPolicy::NoIsolation;
        assert_eq!(n.l2_base(0) & CONTIG_ALIAS_BIT, 0);
    }

    #[test]
    fn tsu_for_criticality() {
        let p = IsolationPolicy::TsuRegulation;
        assert_eq!(tsu_for(p, true).tru_budget_beats, 0);
        assert!(tsu_for(p, false).tru_budget_beats > 0);
    }

    #[test]
    fn knobs_reproduce_tsu_config_constructors() {
        assert_eq!(TsuKnobs::passthrough().config(), TsuConfig::passthrough());
        assert_eq!(TsuKnobs::wb_only().config(), TsuConfig::wb_only());
        assert_eq!(
            TsuKnobs::regulated(8, 96, 512).config(),
            TsuConfig::regulated(8, 96, 512)
        );
        assert_eq!(
            TsuKnobs::regulated(32, 192, 512).config(),
            TsuConfig::regulated(32, 192, 512)
        );
        // Budget-only regulation (no splitter) keeps the full write
        // buffer rather than the GBS-derived 16-beat one.
        let budget_only = TsuKnobs::regulated(0, 96, 512).config();
        assert_eq!(budget_only.wb_capacity_beats, 512);
        assert_eq!(budget_only.tru_budget_beats, 96);
        assert!(budget_only.is_tru_regulated());
    }

    #[test]
    fn partition_percent_out_of_range_is_a_descriptive_error() {
        let over = IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: 120,
        };
        let err = over.validate().unwrap_err();
        assert_eq!(err, TuningError::PartitionPercentOutOfRange { percent: 120 });
        assert!(err.to_string().contains("120%"), "{err}");
        let zero = IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: 0,
        };
        assert!(zero.validate().is_err());
        // 100% is in range and clamps to the seed's 99% behaviour.
        let full = IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: 100,
        };
        assert!(full.validate().is_ok());
    }

    #[test]
    #[should_panic(expected = "outside 1..=100")]
    fn out_of_range_partition_panics_loudly_at_programming_time() {
        let _ = IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: 130,
        }
        .resource_config();
    }

    #[test]
    #[should_panic(expected = "outside 1..=100")]
    fn out_of_range_partition_panics_on_the_tuning_constructor_too() {
        let _ = SocTuning::tsu_plus_llc_partition(120);
    }

    #[test]
    fn tuning_knobs_validated_loudly() {
        let gbs_over = SocTuning {
            nct_tsu: TsuKnobs::regulated(64, 8, 512),
            ..SocTuning::tsu_regulation()
        };
        let err = gbs_over.validate().unwrap_err();
        assert_eq!(err, TuningError::GbsExceedsBudget { gbs: 64, budget: 8 });
        assert!(err.to_string().contains("oversize"), "{err}");

        let no_refill = SocTuning {
            nct_tsu: TsuKnobs {
                period: 0,
                ..TsuKnobs::regulated(8, 96, 512)
            },
            ..SocTuning::tsu_regulation()
        };
        assert_eq!(
            no_refill.validate().unwrap_err(),
            TuningError::BudgetWithoutPeriod { budget: 96 }
        );

        let cache_hog = SocTuning {
            tct_sets: 256,
            ..SocTuning::tsu_regulation()
        };
        assert_eq!(
            cache_hog.validate().unwrap_err(),
            TuningError::PartitionTooLarge {
                tct_sets: 256,
                total_sets: 256,
            }
        );
        assert!(SocTuning::tsu_regulation().validated().is_ok());
    }

    #[test]
    #[should_panic(expected = "invalid SocTuning")]
    fn invalid_tuning_cannot_program_registers() {
        let bad = SocTuning {
            nct_tsu: TsuKnobs::regulated(64, 8, 512),
            ..SocTuning::tsu_regulation()
        };
        let _ = bad.resource_config();
    }

    #[test]
    fn describe_names_the_ladder_points() {
        assert_eq!(SocTuning::no_isolation().describe(), "NoIsolation");
        assert_eq!(SocTuning::tsu_regulation().describe(), "TsuRegulation");
        assert_eq!(SocTuning::private_paths().describe(), "PrivatePaths");
        assert_eq!(
            SocTuning::tsu_plus_llc_partition(50).describe(),
            "TsuPlusLlcPartition(128 sets)"
        );
        let custom = SocTuning {
            nct_tsu: TsuKnobs::regulated(8, 64, 512),
            ..SocTuning::tsu_regulation()
        };
        let d = custom.describe();
        assert!(d.contains("tru=64/512"), "{d}");
    }

    #[test]
    fn partition_math_sourced_from_dpllc_geometry() {
        // The 256 in the partition formulas is the DPLLC's, not a local
        // literal: if the cache geometry changes, the policy follows.
        assert_eq!(
            dpllc::TOTAL_SETS,
            crate::soc::mem::dpllc::DpllcConfig::carfield().sets
        );
        let cfg = SocTuning::tsu_plus_llc_partition(50).resource_config();
        let total: usize = cfg.dpllc_partitions.iter().map(|&(_, n)| n).sum();
        assert_eq!(total, dpllc::TOTAL_SETS);
    }
}

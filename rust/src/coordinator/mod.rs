//! The mixed-criticality coordinator — the software half of the paper's
//! contribution.
//!
//! The paper's hardware IPs (TSU, DPLLC, DCSPM aliases, AMR modes) are
//! *software-programmable*: something must decide, per workload mix, how
//! to partition the shared resources. That something is this module:
//!
//! - [`task`]: the mixed-criticality task model (criticality levels,
//!   deadlines, workload kinds);
//! - [`policy`]: the [`SocTuning`] isolation-configuration space (TSU
//!   knobs, DPLLC partition split, DCSPM aliasing) with the four legacy
//!   [`IsolationPolicy`] regimes as named points;
//! - [`scheduler`]: admission, placement, scenario assembly and
//!   execution on the `SocSim` substrate — including bound-aware
//!   admission control ([`Scheduler::admit`]) backed by the analytical
//!   WCET engine in [`crate::wcet`];
//! - [`autotune`]: the bound-driven search that turns a rejected
//!   admission's binding resource into the least-restrictive tuning
//!   whose bounds admit the mix;
//! - [`faults`]: deterministic, seeded fault-injection plans
//!   ([`FaultPlan`]) whose consequences the WCET engine prices as a
//!   k-fault re-execution term, retry-inflated memory service and scrub
//!   interference — admission under a plan certifies deadlines *with
//!   faults*;
//! - [`metrics`]: per-task reports and experiment tables;
//! - [`sweep`]: parallel execution of independent scenario grids across
//!   OS threads (the experiment figures are embarrassingly parallel).

pub mod autotune;
pub mod faults;
pub mod metrics;
pub mod policy;
pub mod scheduler;
pub mod sweep;
pub mod task;

pub use autotune::{
    autotune, autotune_certified, Autotuner, SearchStrategy, TuneError, TuneOutcome,
};
pub use faults::{FaultPlan, ScrubConfig};
pub use metrics::{ScenarioReport, TaskIndex, TaskReport};
pub use policy::{IsolationPolicy, ResourceConfig, SocTuning, TsuKnobs, TuningError};
pub use scheduler::{AdmissionDecision, Rejection, Scenario, Scheduler, StepMode};
pub use task::{Criticality, McTask, Workload};

//! The mixed-criticality coordinator — the software half of the paper's
//! contribution.
//!
//! The paper's hardware IPs (TSU, DPLLC, DCSPM aliases, AMR modes) are
//! *software-programmable*: something must decide, per workload mix, how
//! to partition the shared resources. That something is this module:
//!
//! - [`task`]: the mixed-criticality task model (criticality levels,
//!   deadlines, workload kinds);
//! - [`policy`]: isolation profiles mapping criticality mixes onto
//!   concrete TSU/DPLLC/DCSPM/AMR configurations;
//! - [`scheduler`]: admission, placement, scenario assembly and
//!   execution on the `SocSim` substrate — including bound-aware
//!   admission control ([`Scheduler::admit`]) backed by the analytical
//!   WCET engine in [`crate::wcet`];
//! - [`metrics`]: per-task reports and experiment tables;
//! - [`sweep`]: parallel execution of independent scenario grids across
//!   OS threads (the experiment figures are embarrassingly parallel).

pub mod metrics;
pub mod policy;
pub mod scheduler;
pub mod sweep;
pub mod task;

pub use metrics::{ScenarioReport, TaskReport};
pub use policy::{IsolationPolicy, ResourceConfig};
pub use scheduler::{AdmissionDecision, Rejection, Scenario, Scheduler};
pub use task::{Criticality, McTask, Workload};

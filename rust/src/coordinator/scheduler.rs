//! Scenario assembly + execution: the coordinator's run loop.
//!
//! `Scheduler::run` takes a [`Scenario`] (a set of mixed-criticality
//! tasks + an isolation tuning), programs the hardware IPs accordingly
//! (TSUs per initiator, DPLLC partitions, DCSPM aliasing, AMR mode),
//! executes the assembled `SocSim` until every *measured* task drains
//! (endless interferers keep running), and returns per-task reports.

use crate::power::OperatingPoint;
use crate::soc::amr::{AmrCluster, AmrTask, Recovery};
use crate::soc::axi::{InitiatorId, Target, TargetModel};
use crate::soc::clock::{ClockTree, Cycle, Domain};
use crate::soc::dma::{DmaEngine, DmaJob};
use crate::soc::hostd::HostCore;
use crate::soc::mem::dpllc::DpllcConfig;
use crate::soc::mem::{Dcspm, HyperRamTiming, HyperramPath, Peripheral};
use crate::soc::tsu::TsuConfig;
use crate::soc::vector::{VectorCluster, VectorTask};
use crate::soc::SocSim;

use super::faults::FaultPlan;
use super::metrics::{ScenarioReport, TaskReport};
use super::policy::SocTuning;
use super::task::{McTask, Workload};
use crate::trace::{LedgerTask, TraceCapture, TraceConfig};
use crate::wcet::{self, Resource, WcetReport};

/// A bundle of tasks to run concurrently under one isolation tuning.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    /// The isolation-configuration point programmed before launch; the
    /// four legacy `IsolationPolicy` values convert implicitly.
    pub tuning: SocTuning,
    /// The DVFS operating point the mix runs at. `None` keeps the
    /// seed's lock-step timebase — every domain on the system clock
    /// (PLL ratio 1.0) and deadlines only expressible in cycles; the
    /// governor always pins `Some` point.
    pub op_point: Option<OperatingPoint>,
    /// The fault-injection plan the mix runs (and is admitted) under.
    /// `None` — and the quiet plan — keep simulator and bounds
    /// bit-identical to the fault-free engine.
    pub faults: Option<FaultPlan>,
    /// Event tracing (off by default — the hook sites then cost one
    /// branch each and reports stay bit-identical to the seed).
    pub trace: TraceConfig,
    pub tasks: Vec<McTask>,
    /// Simulation budget (guards against starvation bugs).
    pub max_cycles: Cycle,
}

impl Scenario {
    pub fn new(name: &str, tuning: impl Into<SocTuning>) -> Self {
        Self {
            name: name.to_string(),
            tuning: tuning.into(),
            op_point: None,
            faults: None,
            trace: TraceConfig::default(),
            tasks: Vec::new(),
            max_cycles: 200_000_000,
        }
    }

    pub fn with_task(mut self, task: McTask) -> Self {
        self.tasks.push(task);
        self
    }

    /// The same mix under a different tuning point (the auto-tuner's
    /// re-evaluation hook).
    pub fn with_tuning(mut self, tuning: impl Into<SocTuning>) -> Self {
        self.tuning = tuning.into();
        self
    }

    /// The same mix at a DVFS operating point (the governor's
    /// re-evaluation hook).
    pub fn with_op_point(mut self, op: OperatingPoint) -> Self {
        self.op_point = Some(op);
        self
    }

    /// The same mix under a fault-injection plan. Admission, the
    /// auto-tuner and the DVFS governor all evaluate the plan's k-fault
    /// bounds (their probe scenarios clone the plan along with the
    /// tasks), and `Scheduler::run` injects the plan's seeded faults.
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// The same mix with event tracing switched on (or off).
    pub fn with_trace(mut self, trace: TraceConfig) -> Self {
        self.trace = trace;
        self
    }

    /// The active fault plan, with quiet plans normalized away so the
    /// fault-free fast paths stay bit-identical.
    pub fn fault_plan(&self) -> Option<FaultPlan> {
        self.faults.filter(|p| !p.is_quiet())
    }

    /// The PLL tree the operating point programs, if one is pinned.
    pub fn clocks(&self) -> Option<ClockTree> {
        self.op_point.map(|p| p.clock_tree())
    }

    /// Cluster cycles per system cycle for `domain` — 1.0 on the legacy
    /// lock-step timebase, the PLL ratio at a pinned operating point.
    /// Consumed identically by the simulator's cluster FSMs and the WCET
    /// compute bounds, so soundness is preserved by construction.
    pub fn freq_ratio(&self, domain: Domain) -> f64 {
        match self.clocks() {
            Some(t) => t.ratio_to_system(domain),
            None => 1.0,
        }
    }
}

/// One rejected task in an admission decision.
#[derive(Debug, Clone, PartialEq)]
pub struct Rejection {
    pub task: String,
    /// Effective deadline in system cycles (nanosecond deadlines are
    /// resolved through the scenario's operating point).
    pub deadline: Cycle,
    /// The computed completion bound (`None` = unbounded/endless).
    pub bound: Option<Cycle>,
    /// The resource the bound is dominated by — what to reconfigure.
    pub binding: Resource,
}

/// Bound-aware admission verdict for a scenario (pure function of the
/// scenario — deterministic across thread counts and call sites).
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionDecision {
    pub admitted: bool,
    /// The full feasibility report (bounds for every critical task).
    pub report: WcetReport,
    pub rejections: Vec<Rejection>,
}

impl AdmissionDecision {
    /// Human-readable feasibility summary.
    pub fn summary(&self) -> String {
        if self.admitted {
            format!(
                "ADMIT {}: every critical-task completion bound fits its deadline",
                self.report.scenario
            )
        } else {
            let mut s = format!("REJECT {}:", self.report.scenario);
            for r in &self.rejections {
                match r.bound {
                    Some(b) => s.push_str(&format!(
                        " [{}: bound {} > deadline {} — binding resource: {}]",
                        r.task,
                        b,
                        r.deadline,
                        r.binding.describe()
                    )),
                    None => s.push_str(&format!(
                        " [{}: no completion bound ({}) but deadline {}]",
                        r.task,
                        r.binding.describe(),
                        r.deadline
                    )),
                }
            }
            s
        }
    }
}

/// Stateless scenario executor.
pub struct Scheduler;

/// Which of the three bit-identical stepping cores executes a scenario.
/// Public so grid sweeps ([`crate::coordinator::sweep`]) can compose the
/// wheel core's per-scenario speedup with cross-scenario parallelism.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepMode {
    /// Every component ticks every cycle (the reference semantics).
    Naive,
    /// Cycle-skipping over fabric-quiescent windows.
    EventDriven,
    /// The structure-of-arrays event wheel: per-cycle work touches only
    /// components whose wheel slot fired, and busy-but-inert windows
    /// (W-channel holds, parked grant scans) are jumped too.
    Wheel,
}

impl Default for StepMode {
    /// The promoted default core: the wheel is the fastest of the three
    /// and bit-identical by the equivalence matrix, so `Scheduler::run`,
    /// the grid sweeps and every figure driver inherit it. The
    /// event-driven core stays on as the second oracle behind the
    /// debug-build cross-check in [`Scheduler::run`].
    fn default() -> Self {
        StepMode::Wheel
    }
}

impl Scheduler {
    /// Bound-aware admission control: compute the analytical WCET
    /// bounds for the mix and reject it when any critical task's
    /// completion bound exceeds its deadline (or cannot be bounded at
    /// all), naming the binding resource. Tasks without a deadline
    /// (`deadline == 0`) are always admissible.
    pub fn admit(scenario: &Scenario) -> AdmissionDecision {
        Self::decision_from(scenario, wcet::analyze(scenario))
    }

    /// Certificate-aware admission: identical to [`Scheduler::admit`]
    /// except the bound engine may price the critical task's warm
    /// iterations from a matching [`PartitionCertificate`]
    /// (`crate::trace::PartitionCertificate`) when the tuning grants it
    /// an exclusive DPLLC partition. With an empty library — or no
    /// matching certificate — the decision is bit-identical to `admit`.
    pub fn admit_certified(
        scenario: &Scenario,
        lib: &mut crate::trace::CertificateLibrary,
    ) -> AdmissionDecision {
        Self::decision_from(scenario, wcet::analyze_certified(scenario, lib))
    }

    /// Turn a feasibility report into an admission verdict — the shared
    /// tail of the cold and certificate-backed admission paths.
    fn decision_from(scenario: &Scenario, report: WcetReport) -> AdmissionDecision {
        let clocks = scenario.clocks();
        let mut rejections = Vec::new();
        for task in &scenario.tasks {
            if !task.criticality.is_time_critical() {
                continue;
            }
            let deadline = task.deadline_cycles(clocks.as_ref());
            if deadline == 0 {
                continue;
            }
            let b = report.bound_for(&task.name);
            // Per-domain bounds compare in system cycles through the
            // scenario's clocks (uncore components round up — sound);
            // on the lock-step timebase this is the plain cycle total.
            let bound = b.completion_cycles(clocks.as_ref());
            let feasible = matches!(bound, Some(c) if c <= deadline);
            if !feasible {
                // Attribute the rejection: when the *nominal* (fault-
                // free) bound fits the deadline and only the k-fault
                // re-execution term pushes it over, faults — not load —
                // are the binding cost.
                let nominal = b.nominal_completion_cycles(clocks.as_ref());
                let nominal_fits = matches!(nominal, Some(c) if c <= deadline);
                rejections.push(Rejection {
                    task: task.name.clone(),
                    deadline,
                    bound,
                    binding: if bound.is_some() && nominal_fits {
                        Resource::FaultRecovery
                    } else {
                        b.completion_binding
                    },
                });
            }
        }
        AdmissionDecision {
            admitted: rejections.is_empty(),
            report,
            rejections,
        }
    }

    /// Admission-gated execution: run the scenario only if the bound
    /// engine proves every deadline feasible; otherwise return the
    /// feasibility report for the caller to act on.
    pub fn run_admitted(scenario: &Scenario) -> Result<ScenarioReport, Box<AdmissionDecision>> {
        let decision = Self::admit(scenario);
        if decision.admitted {
            Ok(Self::run(scenario))
        } else {
            Err(Box::new(decision))
        }
    }

    /// Build the target set with the tuning's DPLLC partitioning (and
    /// the fault plan's transient line-retry injection, if any).
    fn targets(tuning: SocTuning, faults: Option<FaultPlan>) -> Vec<Box<dyn TargetModel>> {
        let cfg = tuning.resource_config();
        let mut dpllc = DpllcConfig::carfield();
        dpllc.partitions = cfg.dpllc_partitions;
        let mut hyperram = HyperramPath::new(dpllc, HyperRamTiming::carfield());
        if let Some(plan) = faults {
            if plan.retry_every_lines > 0 {
                hyperram.set_fault_retries(
                    plan.retry_every_lines,
                    plan.retries_per_line,
                    plan.seed % plan.retry_every_lines,
                );
            }
        }
        vec![
            Box::new(Dcspm::new()),
            Box::new(hyperram),
            Box::new(Peripheral::new(Peripheral::DEFAULT_LATENCY)),
        ]
    }

    /// Execute the scenario; returns per-task reports. Runs on the
    /// structure-of-arrays wheel core (the promoted default fast path).
    /// The event-driven core is the second oracle: debug builds re-run
    /// every scenario through it and assert bit-identical reports, and
    /// release builds carry the same guarantee via
    /// `tests/wheel_equivalence.rs` / `tests/event_driven_equivalence.rs`.
    pub fn run(scenario: &Scenario) -> ScenarioReport {
        let report = Self::execute(scenario, StepMode::default()).0;
        #[cfg(debug_assertions)]
        {
            let oracle = Self::execute(scenario, StepMode::EventDriven).0;
            assert_eq!(
                report, oracle,
                "wheel core diverged from the event-driven oracle on {}",
                scenario.name
            );
        }
        report
    }

    /// Execute under an explicit stepping core — the sweep module's hook
    /// for wheel-accelerated grids. All three modes return bit-identical
    /// reports (`tests/wheel_equivalence.rs`), so callers pick purely on
    /// wall clock.
    pub fn run_mode(scenario: &Scenario, mode: StepMode) -> ScenarioReport {
        Self::execute(scenario, mode).0
    }

    /// Traced counterpart of [`Scheduler::run_mode`] (tracing forced on,
    /// capture returned) — the working-set determinism tests step the
    /// same mix through every core and demand bit-equal profiles.
    pub fn run_traced_mode(scenario: &Scenario, mode: StepMode) -> (ScenarioReport, TraceCapture) {
        let s = scenario.clone().with_trace(TraceConfig::on());
        let (report, cap) = Self::execute(&s, mode);
        (report, cap.expect("tracing was armed"))
    }

    /// Naive cycle-by-cycle reference executor, kept for the equivalence
    /// tests and for debugging suspected fast-path divergence.
    pub fn run_naive(scenario: &Scenario) -> ScenarioReport {
        Self::execute(scenario, StepMode::Naive).0
    }

    /// Wheel-core executor (the structure-of-arrays hot path) —
    /// bit-identical to both of the above; see
    /// `tests/wheel_equivalence.rs`.
    pub fn run_wheel(scenario: &Scenario) -> ScenarioReport {
        Self::execute(scenario, StepMode::Wheel).0
    }

    /// Execute with event tracing forced on; returns the report plus
    /// the full [`TraceCapture`] (merged event stream + task directory
    /// the interference ledger is built from). The report is
    /// bit-identical to an untraced `run` of the same scenario.
    pub fn run_traced(scenario: &Scenario) -> (ScenarioReport, TraceCapture) {
        let s = scenario.clone().with_trace(TraceConfig::on());
        let (report, cap) = Self::execute(&s, StepMode::EventDriven);
        (report, cap.expect("tracing was armed"))
    }

    /// Naive-stepping counterpart of [`Scheduler::run_traced`], kept for
    /// the trace-determinism equivalence tests.
    pub fn run_traced_naive(scenario: &Scenario) -> (ScenarioReport, TraceCapture) {
        let s = scenario.clone().with_trace(TraceConfig::on());
        let (report, cap) = Self::execute(&s, StepMode::Naive);
        (report, cap.expect("tracing was armed"))
    }

    /// Wheel-core counterpart of [`Scheduler::run_traced`]: event
    /// streams must be bit-identical across all three cores.
    pub fn run_traced_wheel(scenario: &Scenario) -> (ScenarioReport, TraceCapture) {
        let s = scenario.clone().with_trace(TraceConfig::on());
        let (report, cap) = Self::execute(&s, StepMode::Wheel);
        (report, cap.expect("tracing was armed"))
    }

    fn execute(scenario: &Scenario, mode: StepMode) -> (ScenarioReport, Option<TraceCapture>) {
        let tuning = scenario.tuning;
        let cfg = tuning.resource_config();
        let faults = scenario.fault_plan();
        // The ECC scrubber (when planned) occupies one extra initiator
        // slot *after* every task, so task placement is untouched.
        let scrub = faults.and_then(|p| p.scrub);
        let n_initiators = scenario.tasks.len() + usize::from(scrub.is_some());
        let mut soc = SocSim::new(n_initiators, Self::targets(tuning, faults));
        // Multi-rate timebase: at a pinned operating point the uncore
        // targets step on their own clock grid (identity converters when
        // the tree is coupled — the seed's single timebase, so op-free
        // scenarios and coupled points are bit-identical to the seed).
        if let Some(tree) = scenario.clocks() {
            soc.set_clocks(&tree);
        }

        // Placement: one initiator slot per task, in declaration order.
        let mut measured: Vec<InitiatorId> = Vec::new();
        for (slot, task) in scenario.tasks.iter().enumerate() {
            let id = InitiatorId(slot as u8);
            let tc = task.criticality.is_time_critical();
            let tsu = tuning.tsu_config(tc);
            let part_id = if tc { cfg.tct_part_id } else { 0 };
            match &task.workload {
                Workload::AmrMatMul {
                    precision,
                    m,
                    k,
                    n,
                    tile,
                } => {
                    let mut cluster = match faults {
                        // Per-task fault stream: seeded from (campaign
                        // seed, slot) only — deterministic across sweep
                        // threads and sibling-task changes.
                        Some(plan) => AmrCluster::new(id).with_seed(plan.stream_seed(slot)),
                        None => AmrCluster::new(id),
                    };
                    cluster.mode = task.required_amr_mode();
                    cluster.freq_ratio = scenario.freq_ratio(Domain::Amr);
                    if let Some(plan) = faults {
                        cluster.fault_per_kcycle = plan.amr_fault_per_kcycle;
                        // Lockstep mismatches under the plan recover via
                        // HFR and re-execute the interrupted tile — the
                        // event the k-fault bound prices.
                        cluster.recovery = Recovery::Hfr;
                        cluster.reexec_on_fault = true;
                        cluster.fault_budget = Some(plan.k_faults as u64);
                    }
                    cluster.submit(
                        AmrTask {
                            precision: *precision,
                            m: *m,
                            k: *k,
                            n: *n,
                            tile: *tile,
                            src_base: tuning.l2_base(slot),
                            dst_base: tuning.l2_base(slot) + (1 << 17),
                            part_id,
                        },
                        0,
                    );
                    soc.attach(Box::new(cluster), tsu);
                    measured.push(id);
                }
                Workload::VectorMatMul { format, m, k, n, tile } => {
                    let mut cluster = VectorCluster::new(id);
                    cluster.freq_ratio = scenario.freq_ratio(Domain::Vector);
                    cluster.submit(
                        VectorTask {
                            format: *format,
                            work: crate::soc::vector::VectorWork::MatMul {
                                m: *m,
                                k: *k,
                                n: *n,
                                tile: *tile,
                            },
                            src_base: tuning.l2_base(slot),
                            dst_base: tuning.l2_base(slot) + (1 << 17),
                            part_id,
                        },
                        0,
                    );
                    soc.attach(Box::new(cluster), tsu);
                    measured.push(id);
                }
                Workload::VectorFft { format, n, batch } => {
                    let mut cluster = VectorCluster::new(id);
                    cluster.freq_ratio = scenario.freq_ratio(Domain::Vector);
                    cluster.submit(
                        VectorTask {
                            format: *format,
                            work: crate::soc::vector::VectorWork::Fft {
                                n: *n,
                                batch: *batch,
                            },
                            src_base: tuning.l2_base(slot),
                            dst_base: tuning.l2_base(slot) + (1 << 17),
                            part_id,
                        },
                        0,
                    );
                    soc.attach(Box::new(cluster), tsu);
                    measured.push(id);
                }
                Workload::HostTct(spec) => {
                    let mut s = spec.clone();
                    s.part_id = part_id;
                    soc.attach(Box::new(HostCore::new(id, s)), tsu);
                    measured.push(id);
                }
                Workload::DmaCopy(job) => {
                    let mut engine = DmaEngine::new(id);
                    let mut j = job.clone();
                    j.part_id = 0; // interferer shares the default partition
                    let looping = j.looping;
                    engine.program(j);
                    soc.attach(Box::new(engine), tsu);
                    if !looping {
                        measured.push(id);
                    }
                }
            }
        }

        // The ECC scrub engine: an endless, TRU-regulated background
        // reader patrolling the HyperRAM space — never measured, never
        // reported, but fully visible to the crossbar (and priced by
        // the bound engine as one more regulated competitor).
        if let Some(sc) = scrub {
            let id = InitiatorId(scenario.tasks.len() as u8);
            let mut engine = DmaEngine::new(id);
            engine.program(DmaJob {
                src: Target::Hyperram,
                src_addr: 0x40_0000,
                dst: None,
                dst_addr: 0,
                bytes: 1 << 20,
                chunk_beats: sc.beats,
                outstanding: 1,
                looping: true,
                part_id: 0,
            });
            soc.attach(
                Box::new(engine),
                TsuConfig::regulated(sc.beats, sc.beats, sc.period),
            );
        }

        // Arm tracing last so every attached initiator gets a buffer.
        if scenario.trace.enabled {
            soc.set_trace(true);
        }

        // Run until all measured tasks drain (endless interferers keep
        // running); both loops suppress skips at the drain edge so the
        // reported cycle count matches naive stepping exactly.
        match mode {
            StepMode::Wheel => {
                soc.run_until_wheel(scenario.max_cycles, |soc| {
                    measured.iter().all(|&id| soc.finished(id))
                });
            }
            _ => {
                soc.run_until(scenario.max_cycles, mode == StepMode::EventDriven, |soc| {
                    measured.iter().all(|&id| soc.finished(id))
                });
            }
        }
        let cycles = soc.now;
        // Uncore activity: non-idle cycles of the fixed-clock memory
        // path (HyperRAM/DPLLC + peripheral island), in uncore cycles.
        let uncore_busy_cycles = soc
            .xbar
            .target_ref(crate::soc::axi::Target::Hyperram)
            .busy_cycles()
            + soc
                .xbar
                .target_ref(crate::soc::axi::Target::Peripheral)
                .busy_cycles();

        // Drain the event buffers (fixed component order) before the
        // report harvest takes its own mutable borrows.
        let events = if scenario.trace.enabled {
            Some(soc.take_trace())
        } else {
            None
        };

        // Harvest reports (nanosecond deadlines resolve through the
        // scenario's operating point).
        let clocks = scenario.clocks();
        let mut reports = Vec::new();
        for (slot, task) in scenario.tasks.iter().enumerate() {
            let id = InitiatorId(slot as u8);
            let deadline = task.deadline_cycles(clocks.as_ref());
            reports.push(Self::report_for(&mut soc, id, task, deadline, cycles));
        }

        // Assemble the capture: the task directory (makespans + fault-
        // recovery stalls) comes from the just-harvested reports, so the
        // ledger decomposes exactly the numbers the report shows.
        let capture = events.map(|events| {
            let mut cap = TraceCapture::new(
                &scenario.name,
                soc.xbar.rate_of(Target::Hyperram),
            );
            cap.events = events;
            for (slot, task) in scenario.tasks.iter().enumerate() {
                let rep = &reports[slot];
                cap.tasks.push(LedgerTask {
                    name: task.name.clone(),
                    initiator: InitiatorId(slot as u8),
                    makespan: rep.makespan,
                    recovery_cycles: rep
                        .extra_value("recovery_cycles")
                        .unwrap_or(0.0) as Cycle,
                });
            }
            cap.finish();
            cap
        });

        let report = ScenarioReport {
            scenario: scenario.name.clone(),
            policy: tuning.describe(),
            cycles,
            uncore_busy_cycles,
            tasks: reports,
        };
        (report, capture)
    }

    fn report_for(
        soc: &mut SocSim,
        id: InitiatorId,
        task: &McTask,
        deadline: Cycle,
        total_cycles: Cycle,
    ) -> TaskReport {
        let mut makespan = 0;
        let mean_latency;
        let mut jitter = 0.0;
        let mut extra = Vec::new();
        match &task.workload {
            Workload::AmrMatMul { .. } => {
                let c: &mut AmrCluster = soc.initiator_mut(id);
                makespan = c.stats.finished_at;
                mean_latency = c.stats.effective_mac_per_cyc(0);
                extra.push(("mac_per_cyc".into(), c.stats.effective_mac_per_cyc(0)));
                extra.push(("stall_cycles".into(), c.stats.stall_cycles as f64));
                extra.push(("faults".into(), c.stats.faults_detected as f64));
                extra.push(("faults_silent".into(), c.stats.faults_silent as f64));
                extra.push(("reboots".into(), c.stats.reboots as f64));
                extra.push(("recovery_cycles".into(), c.stats.recovery_cycles as f64));
                extra.push(("mem_max".into(), c.mem_latency_max() as f64));
            }
            Workload::VectorMatMul { .. } | Workload::VectorFft { .. } => {
                let c: &mut VectorCluster = soc.initiator_mut(id);
                makespan = c.stats.finished_at;
                mean_latency = c.stats.effective_flop_per_cyc(0);
                extra.push(("flop_per_cyc".into(), c.stats.effective_flop_per_cyc(0)));
                extra.push(("stall_cycles".into(), c.stats.stall_cycles as f64));
                extra.push(("mem_max".into(), c.mem_latency_max() as f64));
            }
            Workload::HostTct(_) => {
                let h: &mut HostCore = soc.initiator_mut(id);
                makespan = if h.done() { h.finished_at } else { 0 };
                mean_latency = h.iteration_latency.mean();
                jitter = h.iteration_latency.jitter();
                extra.push(("l1_misses".into(), h.l1_misses as f64));
                extra.push(("access_mean".into(), h.access_latency.mean()));
                extra.push(("access_max".into(), h.access_latency.max().max(0.0)));
                extra.push(("iter_max".into(), h.iteration_latency.max().max(0.0)));
            }
            Workload::DmaCopy(_) => {
                let d: &mut DmaEngine = soc.initiator_mut(id);
                // First-issue-to-drain span: nonzero for finished finite
                // jobs, so measured system-domain utilization (and
                // deadline checks) stop undercounting them; endless
                // interferers stay at 0 as before.
                makespan = d.makespan();
                extra.push(("bytes_moved".into(), d.stats.bytes_moved as f64));
                extra.push(("loops".into(), d.stats.loops as f64));
                mean_latency = d.stats.bytes_moved as f64 / total_cycles.max(1) as f64;
            }
        }
        let deadline_met = deadline == 0 || (makespan > 0 && makespan <= deadline);
        TaskReport {
            name: task.name.clone(),
            kind: task.workload.kind(),
            criticality: task.criticality,
            makespan,
            deadline,
            deadline_met,
            mean_latency,
            jitter,
            extra,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::policy::IsolationPolicy;
    use super::super::task::Criticality;
    use crate::soc::amr::IntPrecision;
    use crate::soc::dma::DmaJob;
    use crate::soc::hostd::TctSpec;
    use crate::soc::vector::FpFormat;

    fn tct_task() -> McTask {
        McTask::new(
            "tct",
            Criticality::Hard,
            Workload::HostTct(TctSpec {
                accesses: 256,
                iterations: 4,
                ..TctSpec::fig6a()
            }),
        )
    }

    fn dma_interferer() -> McTask {
        McTask::new(
            "dma",
            Criticality::BestEffort,
            Workload::DmaCopy(DmaJob {
                src: crate::soc::axi::Target::Hyperram,
                src_addr: 0x10_0000,
                dst: Some(crate::soc::axi::Target::Dcspm),
                dst_addr: 0,
                bytes: 1 << 20,
                chunk_beats: 256,
                outstanding: 4,
                looping: true,
                part_id: 0,
            }),
        )
    }

    #[test]
    fn isolated_tct_baseline() {
        let s = Scenario::new("isolated", IsolationPolicy::NoIsolation).with_task(tct_task());
        let r = Scheduler::run(&s);
        assert!(r.task("tct").mean_latency > 0.0);
        assert!(r.cycles < 10_000_000);
    }

    #[test]
    fn policy_ladder_monotonically_improves_tct() {
        let run = |policy| {
            let s = Scenario::new("x", policy)
                .with_task(tct_task())
                .with_task(dma_interferer());
            Scheduler::run(&s).task("tct").mean_latency
        };
        let unregulated = run(IsolationPolicy::NoIsolation);
        let regulated = run(IsolationPolicy::TsuRegulation);
        let partitioned = run(IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: 50,
        });
        // (The reduced working set here keeps the unit test fast; the
        // paper-scale factors are exercised by experiments::fig6a.)
        assert!(
            regulated < unregulated / 2.0,
            "TSU must help: {unregulated:.0} -> {regulated:.0}"
        );
        assert!(
            partitioned <= regulated * 1.05,
            "partition must not hurt: {regulated:.0} -> {partitioned:.0}"
        );
    }

    #[test]
    fn cluster_pair_scenario_runs() {
        let s = Scenario::new("clusters", IsolationPolicy::PrivatePaths)
            .with_task(McTask::new(
                "amr",
                Criticality::Safety,
                Workload::AmrMatMul {
                    precision: IntPrecision::Int8,
                    m: 64,
                    k: 64,
                    n: 64,
                    tile: 16,
                },
            ))
            .with_task(McTask::new(
                "vec",
                Criticality::BestEffort,
                Workload::VectorMatMul {
                    format: FpFormat::Fp16,
                    m: 64,
                    k: 64,
                    n: 64,
                    tile: 32,
                },
            ));
        let r = Scheduler::run(&s);
        assert!(r.task("amr").makespan > 0);
        assert!(r.task("vec").makespan > 0);
        assert!(r.task("amr").extra_value("mac_per_cyc").unwrap() > 0.0);
    }

    #[test]
    fn deadlines_checked() {
        let s = Scenario::new("dl", IsolationPolicy::NoIsolation)
            .with_task(tct_task().with_deadline(1));
        let r = Scheduler::run(&s);
        assert!(!r.task("tct").deadline_met, "1-cycle deadline is impossible");
        assert!(!r.all_deadlines_met());
    }

    #[test]
    fn admission_accepts_feasible_and_rejects_infeasible() {
        let tct = || {
            McTask::new(
                "tct",
                Criticality::Hard,
                Workload::HostTct(TctSpec::fig6a()),
            )
        };
        // The regulated mix's completion bound converges (~1.1M cycles):
        // a generous deadline admits, a tight one rejects and names the
        // binding resource.
        let ok = Scenario::new("ok", IsolationPolicy::TsuRegulation)
            .with_task(tct().with_deadline(5_000_000))
            .with_task(dma_interferer());
        let d = Scheduler::admit(&ok);
        assert!(d.admitted, "{}", d.summary());
        assert!(d.rejections.is_empty());

        let bad = Scenario::new("bad", IsolationPolicy::TsuRegulation)
            .with_task(tct().with_deadline(100_000))
            .with_task(dma_interferer());
        let d = Scheduler::admit(&bad);
        assert!(!d.admitted);
        assert_eq!(d.rejections.len(), 1);
        assert_eq!(d.rejections[0].task, "tct");
        assert!(d.summary().contains("REJECT"), "{}", d.summary());
        assert!(Scheduler::run_admitted(&bad).is_err());
    }

    #[test]
    fn admission_ignores_tasks_without_deadlines() {
        let s = Scenario::new("no-deadline", IsolationPolicy::NoIsolation)
            .with_task(tct_task())
            .with_task(dma_interferer());
        let d = Scheduler::admit(&s);
        assert!(d.admitted, "deadline-free mixes always admissible");
        assert_eq!(d.report.bounds.len(), 1, "one critical task bounded");
    }

    #[test]
    fn ns_deadlines_resolve_through_the_operating_point() {
        use crate::power::OperatingPoint;
        let mix = |op: OperatingPoint| {
            Scenario::new("ns", IsolationPolicy::TsuRegulation)
                .with_task(
                    McTask::new(
                        "tct",
                        Criticality::Hard,
                        Workload::HostTct(TctSpec::fig6a()),
                    )
                    .with_deadline_ns(2_000_000.0),
                )
                .with_task(dma_interferer())
                .with_op_point(op)
        };
        // 2ms of wall clock fits the regulated bound at 1GHz but not at
        // 350MHz: the same mix flips verdict purely on the point.
        let fast = Scheduler::admit(&mix(OperatingPoint::max_perf()));
        assert!(fast.admitted, "{}", fast.summary());
        let slow = Scheduler::admit(&mix(OperatingPoint::uniform(0.6).unwrap()));
        assert!(!slow.admitted, "{}", slow.summary());
        assert_eq!(slow.rejections[0].deadline, 700_000, "2ms at 350MHz");
    }

    #[test]
    fn cluster_compute_scales_with_the_domain_ratio() {
        use crate::power::OperatingPoint;
        let amr = || {
            Scenario::new("amr", IsolationPolicy::PrivatePaths).with_task(McTask::new(
                "amr",
                Criticality::Safety,
                Workload::AmrMatMul {
                    precision: IntPrecision::Int8,
                    m: 64,
                    k: 64,
                    n: 64,
                    tile: 16,
                },
            ))
        };
        let lockstep = Scheduler::run(&amr()).task("amr").makespan;
        // max_perf runs the AMR PLL at 0.9x the system clock: the same
        // task takes more *system* cycles (but less wall clock).
        let scaled = Scheduler::run(&amr().with_op_point(OperatingPoint::max_perf()))
            .task("amr")
            .makespan;
        assert!(
            scaled > lockstep,
            "0.9x AMR clock must stretch system-cycle makespan: {lockstep} -> {scaled}"
        );
    }

    #[test]
    fn fft_workload_schedules_on_vector() {
        let s = Scenario::new("fft", IsolationPolicy::NoIsolation).with_task(McTask::new(
            "radar",
            Criticality::Soft,
            Workload::VectorFft {
                format: FpFormat::Fp32,
                n: 256,
                batch: 8,
            },
        ));
        let r = Scheduler::run(&s);
        assert!(r.task("radar").makespan > 0);
    }
}

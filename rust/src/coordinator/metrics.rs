//! Per-task and per-scenario reports (the numbers Fig. 6 plots).

use crate::soc::clock::Cycle;

use super::task::Criticality;

/// Outcome of one task in a scenario run.
///
/// `PartialEq` is bit-exact (f64 included): the equivalence tests assert
/// that event-driven and naive stepping produce *identical* reports.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskReport {
    pub name: String,
    pub kind: &'static str,
    pub criticality: Criticality,
    /// First-issue to completion, in system cycles (0 for endless NCTs).
    pub makespan: Cycle,
    pub deadline: Cycle,
    pub deadline_met: bool,
    /// Mean per-iteration latency (host TCTs) or effective rate proxy.
    pub mean_latency: f64,
    /// Max-min latency across iterations.
    pub jitter: f64,
    /// Workload-specific extras (misses, MAC/cyc, bytes moved, ...).
    pub extra: Vec<(String, f64)>,
}

impl TaskReport {
    pub fn extra_value(&self, key: &str) -> Option<f64> {
        self.extra
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| *v)
    }
}

/// Aggregated result of a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioReport {
    pub scenario: String,
    pub policy: String,
    /// Total simulated cycles until the measured set drained.
    pub cycles: Cycle,
    /// Uncore-domain cycles the memory path (HyperRAM/DPLLC channel +
    /// peripheral island) spent non-idle — the measured activity feed
    /// for the uncore power domain. On the lock-step timebase these are
    /// system cycles (the grids coincide).
    pub uncore_busy_cycles: u64,
    pub tasks: Vec<TaskReport>,
}

impl ScenarioReport {
    pub fn task(&self, name: &str) -> &TaskReport {
        self.tasks
            .iter()
            .find(|t| t.name == name)
            .unwrap_or_else(|| panic!("no task report named {name}"))
    }

    /// All TCT deadlines met?
    pub fn all_deadlines_met(&self) -> bool {
        self.tasks
            .iter()
            .filter(|t| t.criticality.is_time_critical() && t.deadline > 0)
            .all(|t| t.deadline_met)
    }

    /// Render a GitHub-flavoured markdown table.
    pub fn to_markdown(&self) -> String {
        let mut out = format!(
            "### {} (policy: {}, {} cycles)\n\n",
            self.scenario, self.policy, self.cycles
        );
        out.push_str("| task | kind | crit | makespan | mean lat | jitter | deadline |\n");
        out.push_str("|---|---|---|---:|---:|---:|---|\n");
        for t in &self.tasks {
            let dl = if t.deadline == 0 {
                "-".to_string()
            } else if t.deadline_met {
                format!("met ({})", t.deadline)
            } else {
                format!("MISSED ({})", t.deadline)
            };
            out.push_str(&format!(
                "| {} | {} | {:?} | {} | {:.1} | {:.1} | {} |\n",
                t.name, t.kind, t.criticality, t.makespan, t.mean_latency, t.jitter, dl
            ));
        }
        for t in &self.tasks {
            if !t.extra.is_empty() {
                out.push_str(&format!("\n`{}`:", t.name));
                for (k, v) in &t.extra {
                    out.push_str(&format!(" {k}={v:.2}"));
                }
                out.push('\n');
            }
        }
        out
    }
}

/// A simple aligned-rows printer for bench tables (criterion substitute).
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title}");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> ScenarioReport {
        ScenarioReport {
            scenario: "test".into(),
            policy: "NoIsolation".into(),
            cycles: 1000,
            uncore_busy_cycles: 0,
            tasks: vec![TaskReport {
                name: "tct".into(),
                kind: "host-tct",
                criticality: Criticality::Hard,
                makespan: 900,
                deadline: 1000,
                deadline_met: true,
                mean_latency: 10.0,
                jitter: 2.0,
                extra: vec![("misses".into(), 5.0)],
            }],
        }
    }

    #[test]
    fn lookup_and_deadlines() {
        let r = report();
        assert_eq!(r.task("tct").makespan, 900);
        assert!(r.all_deadlines_met());
        assert_eq!(r.task("tct").extra_value("misses"), Some(5.0));
        assert_eq!(r.task("tct").extra_value("nope"), None);
    }

    #[test]
    #[should_panic(expected = "no task report")]
    fn missing_task_panics() {
        report().task("ghost");
    }

    #[test]
    fn markdown_contains_rows() {
        let md = report().to_markdown();
        assert!(md.contains("| tct |"));
        assert!(md.contains("met (1000)"));
        assert!(md.contains("misses=5.00"));
    }

    #[test]
    fn missed_deadline_is_loud() {
        let mut r = report();
        r.tasks[0].deadline_met = false;
        assert!(!r.all_deadlines_met());
        assert!(r.to_markdown().contains("MISSED"));
    }
}

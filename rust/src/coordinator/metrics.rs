//! Per-task and per-scenario reports (the numbers Fig. 6 plots).

use crate::soc::clock::Cycle;

use super::task::Criticality;

/// Outcome of one task in a scenario run.
///
/// `PartialEq` is bit-exact (f64 included): the equivalence tests assert
/// that event-driven and naive stepping produce *identical* reports.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskReport {
    pub name: String,
    pub kind: &'static str,
    pub criticality: Criticality,
    /// First-issue to completion, in system cycles (0 for endless NCTs).
    pub makespan: Cycle,
    pub deadline: Cycle,
    pub deadline_met: bool,
    /// Mean per-iteration latency (host TCTs) or effective rate proxy.
    pub mean_latency: f64,
    /// Max-min latency across iterations.
    pub jitter: f64,
    /// Workload-specific extras (misses, MAC/cyc, bytes moved, ...).
    pub extra: Vec<(String, f64)>,
}

impl TaskReport {
    pub fn extra_value(&self, key: &str) -> Option<f64> {
        // Byte compare with a length pre-check: extras keys are short
        // ASCII literals, and the common case in a grid harvest is a
        // miss on every row but one — rejecting on `len` avoids the
        // memcmp (and any Unicode-aware `str` comparison machinery).
        let key = key.as_bytes();
        self.extra
            .iter()
            .find(|(k, _)| k.len() == key.len() && k.as_bytes() == key)
            .map(|(_, v)| *v)
    }
}

/// Aggregated result of a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioReport {
    pub scenario: String,
    pub policy: String,
    /// Total simulated cycles until the measured set drained.
    pub cycles: Cycle,
    /// Uncore-domain cycles the memory path (HyperRAM/DPLLC channel +
    /// peripheral island) spent non-idle — the measured activity feed
    /// for the uncore power domain. On the lock-step timebase these are
    /// system cycles (the grids coincide).
    pub uncore_busy_cycles: u64,
    pub tasks: Vec<TaskReport>,
}

impl ScenarioReport {
    pub fn task(&self, name: &str) -> &TaskReport {
        let key = name.as_bytes();
        self.tasks
            .iter()
            .find(|t| t.name.len() == key.len() && t.name.as_bytes() == key)
            .unwrap_or_else(|| panic!("no task report named {name}"))
    }

    /// Precomputed name -> slot lookup for repeated `task()` calls: the
    /// experiment grids and the trace gap-attribution table resolve the
    /// same few names once per row per metric, and the repeated linear
    /// String scans were measurable in the sweep harvest. Build once
    /// per report; lookups binary-search a sorted slice of borrowed
    /// names (no interning table to maintain, nothing added to the
    /// frozen report shape).
    pub fn index(&self) -> TaskIndex<'_> {
        let mut by_name: Vec<(&str, usize)> = self
            .tasks
            .iter()
            .enumerate()
            .map(|(i, t)| (t.name.as_str(), i))
            .collect();
        by_name.sort_unstable_by_key(|&(n, i)| (n, i));
        TaskIndex {
            tasks: &self.tasks,
            by_name,
        }
    }

    /// All TCT deadlines met?
    pub fn all_deadlines_met(&self) -> bool {
        self.tasks
            .iter()
            .filter(|t| t.criticality.is_time_critical() && t.deadline > 0)
            .all(|t| t.deadline_met)
    }

    /// Render a GitHub-flavoured markdown table.
    pub fn to_markdown(&self) -> String {
        let mut out = format!(
            "### {} (policy: {}, {} cycles)\n\n",
            self.scenario, self.policy, self.cycles
        );
        out.push_str("| task | kind | crit | makespan | mean lat | jitter | deadline |\n");
        out.push_str("|---|---|---|---:|---:|---:|---|\n");
        for t in &self.tasks {
            let dl = if t.deadline == 0 {
                "-".to_string()
            } else if t.deadline_met {
                format!("met ({})", t.deadline)
            } else {
                format!("MISSED ({})", t.deadline)
            };
            out.push_str(&format!(
                "| {} | {} | {:?} | {} | {:.1} | {:.1} | {} |\n",
                t.name, t.kind, t.criticality, t.makespan, t.mean_latency, t.jitter, dl
            ));
        }
        for t in &self.tasks {
            if !t.extra.is_empty() {
                out.push_str(&format!("\n`{}`:", t.name));
                for (k, v) in &t.extra {
                    out.push_str(&format!(" {k}={v:.2}"));
                }
                out.push('\n');
            }
        }
        out
    }
}

/// Sorted-name lookup over one report's tasks (see
/// [`ScenarioReport::index`]). Duplicate task names resolve to the
/// first declaration, matching the linear scan's behaviour.
pub struct TaskIndex<'a> {
    tasks: &'a [TaskReport],
    by_name: Vec<(&'a str, usize)>,
}

impl<'a> TaskIndex<'a> {
    pub fn get(&self, name: &str) -> Option<&'a TaskReport> {
        let i = self.by_name.partition_point(|&(n, _)| n < name);
        match self.by_name.get(i) {
            Some(&(n, slot)) if n == name => Some(&self.tasks[slot]),
            _ => None,
        }
    }

    /// Panicking counterpart of [`TaskIndex::get`], mirroring
    /// [`ScenarioReport::task`].
    pub fn task(&self, name: &str) -> &'a TaskReport {
        self.get(name)
            .unwrap_or_else(|| panic!("no task report named {name}"))
    }
}

/// A simple aligned-rows printer for bench tables (criterion substitute).
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title}");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> ScenarioReport {
        ScenarioReport {
            scenario: "test".into(),
            policy: "NoIsolation".into(),
            cycles: 1000,
            uncore_busy_cycles: 0,
            tasks: vec![TaskReport {
                name: "tct".into(),
                kind: "host-tct",
                criticality: Criticality::Hard,
                makespan: 900,
                deadline: 1000,
                deadline_met: true,
                mean_latency: 10.0,
                jitter: 2.0,
                extra: vec![("misses".into(), 5.0)],
            }],
        }
    }

    #[test]
    fn lookup_and_deadlines() {
        let r = report();
        assert_eq!(r.task("tct").makespan, 900);
        assert!(r.all_deadlines_met());
        assert_eq!(r.task("tct").extra_value("misses"), Some(5.0));
        assert_eq!(r.task("tct").extra_value("nope"), None);
    }

    #[test]
    #[should_panic(expected = "no task report")]
    fn missing_task_panics() {
        report().task("ghost");
    }

    #[test]
    fn task_index_matches_linear_scan() {
        let mut r = report();
        r.tasks.push(TaskReport {
            name: "aaa".into(),
            ..r.tasks[0].clone()
        });
        // A duplicate name must resolve to the first declaration, like
        // the linear scan does.
        r.tasks.push(TaskReport {
            makespan: 1,
            ..r.tasks[0].clone()
        });
        let idx = r.index();
        for name in ["tct", "aaa"] {
            assert!(std::ptr::eq(idx.task(name), r.task(name)), "{name}");
        }
        assert!(idx.get("ghost").is_none());
        assert_eq!(idx.task("tct").makespan, 900);
    }

    #[test]
    fn markdown_contains_rows() {
        let md = report().to_markdown();
        assert!(md.contains("| tct |"));
        assert!(md.contains("met (1000)"));
        assert!(md.contains("misses=5.00"));
    }

    #[test]
    fn missed_deadline_is_loud() {
        let mut r = report();
        r.tasks[0].deadline_met = false;
        assert!(!r.all_deadlines_met());
        assert!(r.to_markdown().contains("MISSED"));
    }
}

//! Parallel scenario sweeps.
//!
//! The paper's evaluation is a grid of *independent* scenario runs
//! (Fig. 3c/5/6a/6b): each scenario owns its `SocSim` and is fully
//! deterministic, so the grid is embarrassingly parallel. This module
//! fans a work list out over `std::thread::scope` workers (no external
//! dependencies) while preserving input order in the results — a
//! parallel sweep returns exactly what the serial sweep would.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use super::metrics::ScenarioReport;
use super::scheduler::{Scenario, Scheduler, StepMode};

/// Worker count to saturate this host (>= 1). The `CARFIELD_THREADS`
/// environment variable overrides it (clamped to >= 1) so CI and
/// benchmarks can pin parallelism for reproducible wall-clock numbers.
pub fn default_threads() -> usize {
    threads_from(std::env::var("CARFIELD_THREADS").ok().as_deref())
}

/// Resolve a thread-count override string (the testable core of
/// [`default_threads`]): a parseable value is clamped to >= 1; anything
/// else falls back to the host's available parallelism.
pub fn threads_from(raw: Option<&str>) -> usize {
    if let Some(raw) = raw {
        if let Ok(n) = raw.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every item on up to `threads` OS threads, returning the
/// results in input order. Work is claimed from a shared atomic cursor,
/// so long and short items balance across workers. With `threads <= 1`
/// (or a single item) this degenerates to a plain serial map — the
/// baseline the bench compares against.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.clamp(1, n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        return items.iter().map(|item| f(item)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let cursor = &cursor;
            let f = &f;
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                if tx.send((i, f(&items[i]))).is_err() {
                    break;
                }
            });
        }
        drop(tx); // scope joins the workers; rx then drains fully
    });
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        slots[i] = Some(r);
    }
    slots
        .into_iter()
        .map(|r| r.expect("every item produced a result"))
        .collect()
}

/// Run independent scenarios across threads (order-preserving). Each
/// scenario is deterministic, so `run_scenarios(g, 1)` and
/// `run_scenarios(g, n)` return identical reports — only wall-clock
/// changes. Steps on [`StepMode::default()`] (the promoted wheel core);
/// use [`run_scenarios_mode`] to pin another core explicitly.
pub fn run_scenarios(scenarios: &[Scenario], threads: usize) -> Vec<ScenarioReport> {
    run_scenarios_mode(scenarios, threads, StepMode::default())
}

/// Run independent scenarios across threads under an explicit stepping
/// core. The wheel core is the fastest of the three bit-identical
/// executors, so grid sweeps compose the two speedup levels — per-
/// scenario cycle-skipping times cross-scenario parallelism — without
/// changing a single reported number.
pub fn run_scenarios_mode(
    scenarios: &[Scenario],
    threads: usize,
    mode: StepMode,
) -> Vec<ScenarioReport> {
    parallel_map(scenarios, threads, |s| Scheduler::run_mode(s, mode))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::Criticality;
    use crate::coordinator::{IsolationPolicy, McTask, Workload};
    use crate::soc::hostd::TctSpec;

    #[test]
    fn threads_override_parses_and_clamps() {
        assert_eq!(threads_from(Some("3")), 3);
        assert_eq!(threads_from(Some(" 8 ")), 8);
        assert_eq!(threads_from(Some("0")), 1, "clamped to >= 1");
        assert!(threads_from(Some("not-a-number")) >= 1, "junk falls back");
        assert!(threads_from(None) >= 1);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..23).collect();
        let serial = parallel_map(&items, 1, |&x| x * x);
        let parallel = parallel_map(&items, 4, |&x| x * x);
        assert_eq!(serial, parallel);
        assert_eq!(serial[7], 49);
    }

    #[test]
    fn parallel_map_handles_empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(&empty, 8, |&x| x).is_empty());
        assert_eq!(parallel_map(&[5u32], 8, |&x| x + 1), vec![6]);
    }

    #[test]
    fn scenario_sweep_matches_serial() {
        let grid: Vec<Scenario> = (0..3)
            .map(|i| {
                Scenario::new(&format!("s{i}"), IsolationPolicy::NoIsolation).with_task(
                    McTask::new(
                        "tct",
                        Criticality::Hard,
                        Workload::HostTct(TctSpec {
                            accesses: 32 + 16 * i,
                            iterations: 2,
                            ..TctSpec::fig6a()
                        }),
                    ),
                )
            })
            .collect();
        let serial = run_scenarios(&grid, 1);
        let parallel = run_scenarios(&grid, 3);
        assert_eq!(serial, parallel);
        assert_eq!(serial.len(), 3);
        assert!(serial[0].task("tct").mean_latency > 0.0);

        // The wheel core composes with the sweep: same grid, same
        // reports, on every (mode, thread-count) combination.
        for mode in [StepMode::Naive, StepMode::Wheel] {
            assert_eq!(run_scenarios_mode(&grid, 1, mode), serial);
            assert_eq!(run_scenarios_mode(&grid, 3, mode), serial);
        }
    }
}

//! Bound-driven auto-tuning: close the loop from a rejected admission to
//! a reprogrammed SoC.
//!
//! `Scheduler::admit` rejects a mix by naming the *binding resource* —
//! the shared resource whose worst-case interference dominates the
//! violated completion bound. This module turns that name into a knob:
//!
//! 1. **Coordinate descent over the binding knob.** Each binding
//!    resource maps to the tuning axis that relaxes it (HyperRAM or
//!    DCSPM contention -> throttle the NCT TSUs harder; W-channel holds
//!    -> enable the NCT write buffer; DCSPM port contention -> flip the
//!    contiguous-alias private paths first, they are free). The axis is
//!    scanned from least- to most-restrictive, so the first feasible
//!    point is the least-restrictive tuning *on that axis* whose bounds
//!    admit the mix.
//! 2. **Coarse lattice fallback.** When no single axis admits the mix,
//!    the knob lattice (throttle ladder x DCSPM aliasing; the DPLLC
//!    partition axis stays parked on the cold path — cold bounds cannot
//!    see it) is swept in ascending [`restrictiveness`] order; again the
//!    first feasible point wins.
//! 3. **Certified partition axis** ([`Autotuner::tune_certified`]).
//!    When even the lattice exhausts, a trace-minted
//!    [`PartitionCertificate`](crate::trace::PartitionCertificate) for
//!    the mix's critical TCT shape unlocks the `tct_sets` axis: lattice
//!    points crossed with the certified set counts are evaluated under
//!    the certificate-backed warm bounds of
//!    [`Scheduler::admit_certified`].
//!
//! Every evaluation is *analytic* — one `Scheduler::admit` call
//! (microseconds) — so a full search costs less than a millisecond of
//! wall clock; no simulation runs until [`validate`] confirms the winner
//! with one real execution. That cheapness is what lets the DVFS
//! governor ([`crate::power::governor`]) re-run this whole search at
//! every voltage candidate of its grid — the "tuning x DVFS
//! composition" the PR 3 follow-ons called for: admission deadlines
//! resolve through the probe scenario's operating point, so the same
//! search finds the least-restrictive tuning per V/f point. The search is a pure function of the
//! scenario: same mix in, same tuning out, regardless of thread count,
//! call order or wall clock. A handful of points are deliberately
//! re-evaluated (the base tuning can reappear on its axis, and the
//! lattice repeats the descent's ladder): deduplication would save ~10
//! microsecond-scale evaluations per exhausted search at the cost of
//! memoization state, and the fixed candidate order keeps the
//! evaluation counts the tests and bench pin down trivially stable.

use crate::soc::clock::Cycle;
use crate::soc::mem::dpllc;
use crate::wcet::Resource;

use super::metrics::ScenarioReport;
use super::policy::{SocTuning, TsuKnobs};
use super::scheduler::{AdmissionDecision, Scenario, Scheduler};
use super::task::Workload;
use crate::trace::CertificateLibrary;

/// NCT throttle ladder swept by the descent, least- to most-restrictive
/// (descending budget/period bandwidth). Points keep `gbs <= budget`,
/// `budget % gbs == 0` and the DMA chunk size a multiple of `gbs`, the
/// regime the bound engine's arrival curves are fuzz-validated on.
pub const THROTTLE_LADDER: [(u32, u32, Cycle); 11] = [
    (32, 256, 512),
    (32, 192, 512),
    (16, 128, 512),
    (8, 96, 512), // the legacy TsuRegulation point
    (8, 64, 512),
    (8, 48, 512),
    (8, 32, 512),
    (8, 24, 512),
    (8, 16, 512),
    (8, 16, 1024),
    (8, 8, 1024),
];

// NOTE: the DPLLC partition split (`SocTuning::tct_sets`) is part of the
// tuning space but NOT swept by the *cold* lattice: cold completion
// bounds price every line fill at the row-open worst case, so the bound
// engine is blind to the partition and every `tct_sets` variant would
// evaluate identically (pure duplicate work that could also never win
// the least-restrictive ordering). The axis activates in
// [`Autotuner::tune_certified`]: a [`PartitionCertificate`]
// (`crate::trace::PartitionCertificate`) supplies empirical
// warm-iteration evidence for specific set counts, and only those
// certified counts are swept — under `Scheduler::admit_certified`, whose
// warm bounds actually see the partition.

/// How the winning tuning was found.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchStrategy {
    /// The scenario's own tuning already admits the mix.
    AlreadyFeasible,
    /// Coordinate descent over the binding knob succeeded.
    CoordinateDescent,
    /// The descent failed; the coarse lattice sweep found a point.
    LatticeSweep,
    /// The whole cold space is infeasible; a certificate-backed DPLLC
    /// partition point admitted the mix via its warm-iteration bound.
    CertifiedPartition,
}

/// A successful search: the least-restrictive tuning found whose bounds
/// admit the mix.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    pub tuning: SocTuning,
    pub strategy: SearchStrategy,
    /// The formerly binding resource the search relaxed (`None` when the
    /// mix was already feasible).
    pub relaxed: Option<Resource>,
    /// Analytic admission evaluations spent (search iterations).
    pub evaluations: u64,
    /// The admitting decision under `tuning` (carries every bound).
    pub decision: AdmissionDecision,
}

/// The knob space is exhausted: no point admits the mix.
#[derive(Debug, Clone)]
pub struct TuneError {
    pub evaluations: u64,
    /// True when the search stopped at the evaluation cap with
    /// candidates left — the space was cut short, not proven exhausted.
    pub capped: bool,
    /// Tightest completion bound seen anywhere in the space, vs the
    /// deadline it still misses — bound, deadline and binding all come
    /// from the *same* near-miss rejection, so the report is coherent
    /// even for mixes with several critical tasks.
    pub best_bound: Option<Cycle>,
    pub deadline: Cycle,
    pub binding: Resource,
}

impl std::fmt::Display for TuneError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let verdict = if self.capped {
            "evaluation cap reached (space cut short, not exhausted)"
        } else {
            "no tuning admits the mix"
        };
        match self.best_bound {
            Some(b) => write!(
                f,
                "{verdict} after {} evaluations: best completion bound {} \
                 still exceeds deadline {} (binding resource: {})",
                self.evaluations,
                b,
                self.deadline,
                self.binding.describe()
            ),
            None => write!(
                f,
                "{verdict} after {} evaluations: no finite completion \
                 bound exists (binding resource: {})",
                self.evaluations,
                self.binding.describe()
            ),
        }
    }
}

impl std::error::Error for TuneError {}

/// Simulation-backed confirmation of an analytically chosen tuning.
#[derive(Debug, Clone)]
pub struct TuneValidation {
    pub report: ScenarioReport,
    /// `(task, measured makespan, completion bound)` per bounded task.
    pub checks: Vec<(String, Cycle, Cycle)>,
    /// Measured makespan within its bound for every bounded task.
    pub sound: bool,
    pub deadlines_met: bool,
}

impl TuneValidation {
    pub fn confirmed(&self) -> bool {
        self.sound && self.deadlines_met
    }
}

/// Integer restrictiveness score for a tuning point (parts-per-million
/// of NCT service taken away; lower = less restrictive). Orders the
/// lattice sweep and documents what "cheapest configuration" means:
/// TRU bandwidth taken from the NCTs dominates, then the DPLLC sets
/// taken from the shared partition, then GBS fragmentation overhead,
/// then the (nearly free) DCSPM aliasing flip.
pub fn restrictiveness(t: &SocTuning) -> u64 {
    let bw = if t.nct_tsu.is_regulated() {
        1_000_000u64
            .saturating_sub(t.nct_tsu.budget_beats as u64 * 1_000_000 / t.nct_tsu.period.max(1))
    } else {
        0
    };
    let gbs = if t.nct_tsu.gbs_beats > 0 {
        1_000_000 / (64 * t.nct_tsu.gbs_beats as u64)
    } else {
        0
    };
    let partition = t.tct_sets as u64 * 1_000_000 / (4 * dpllc::TOTAL_SETS as u64);
    let alias = if t.dcspm_private_paths { 10 } else { 0 };
    bw + gbs + partition + alias
}

/// The deterministic bound-driven search.
pub struct Autotuner {
    /// Hard cap on analytic evaluations (the full lattice is well under
    /// this; the cap guards future axis growth).
    pub max_evaluations: u64,
}

impl Default for Autotuner {
    fn default() -> Self {
        Self {
            max_evaluations: 4096,
        }
    }
}

impl Autotuner {
    /// A tuner with an explicit (usually small) evaluation budget — the
    /// admission service's rescue pass runs one of these over a merged
    /// mix the packing probe rejected, so a repair attempt costs a
    /// bounded number of analytic evaluations instead of the full
    /// lattice.
    pub fn budgeted(max_evaluations: u64) -> Self {
        Self {
            max_evaluations: max_evaluations.max(1),
        }
    }

    /// Search the tuning space for the least-restrictive point whose
    /// completion bounds admit `scenario`'s mix. Purely analytic; see
    /// [`validate`] for the simulation-backed confirmation.
    pub fn tune(&self, scenario: &Scenario) -> Result<TuneOutcome, TuneError> {
        let mut evaluations = 0u64;
        // Near-miss rejection seen anywhere in the space, as a
        // `(bound, deadline, binding)` triple from one rejection, so the
        // exhaustion report can never pair one task's bound with another
        // task's deadline or binding resource.
        let mut best: Option<(Cycle, Cycle, Resource)> = None;
        // One probe scenario reused across every evaluation — only the
        // Copy tuning field changes per admit() call.
        let mut probe = scenario.clone();
        let mut evaluate = |tuning: SocTuning| -> AdmissionDecision {
            probe.tuning = tuning;
            Scheduler::admit(&probe)
        };

        let decision = evaluate(scenario.tuning);
        evaluations += 1;
        if decision.admitted {
            return Ok(TuneOutcome {
                tuning: scenario.tuning,
                strategy: SearchStrategy::AlreadyFeasible,
                relaxed: None,
                evaluations,
                decision,
            });
        }
        // The binding knob the descent turns comes from the *initial*
        // rejection (that is the resource the report told us to relax).
        let binding = decision.rejections[0].binding;
        let fallback = (decision.rejections[0].deadline, binding);
        track_best(&decision, &mut best);

        let mut capped = false;

        // Phase 1: coordinate descent over the binding knob.
        for candidate in binding_axis(binding, scenario.tuning) {
            if evaluations >= self.max_evaluations {
                capped = true;
                break;
            }
            let decision = evaluate(candidate);
            evaluations += 1;
            if decision.admitted {
                return Ok(TuneOutcome {
                    tuning: candidate,
                    strategy: SearchStrategy::CoordinateDescent,
                    relaxed: Some(binding),
                    evaluations,
                    decision,
                });
            }
            track_best(&decision, &mut best);
        }

        // Phase 2: coarse lattice sweep, least restrictive first.
        for candidate in lattice() {
            if evaluations >= self.max_evaluations {
                capped = true;
                break;
            }
            let decision = evaluate(candidate);
            evaluations += 1;
            if decision.admitted {
                return Ok(TuneOutcome {
                    tuning: candidate,
                    strategy: SearchStrategy::LatticeSweep,
                    relaxed: Some(binding),
                    evaluations,
                    decision,
                });
            }
            track_best(&decision, &mut best);
        }

        let (best_bound, deadline, binding) = match best {
            Some((bound, deadline, binding)) => (Some(bound), deadline, binding),
            None => (None, fallback.0, fallback.1),
        };
        Err(TuneError {
            evaluations,
            capped,
            best_bound,
            deadline,
            binding,
        })
    }

    /// Certificate-aware search: the cold search first (bit-identical to
    /// [`Autotuner::tune`], and always preferred — a cold-feasible point
    /// needs no empirical evidence), then, on cold exhaustion, the
    /// parked DPLLC partition axis activates. Every (throttle, aliasing)
    /// lattice point is crossed with every set count the library's
    /// certificate for the mix's critical TCT shape can vouch for, and
    /// the variants are evaluated under [`Scheduler::admit_certified`]
    /// in ascending restrictiveness order. A `CertifiedPartition`
    /// outcome therefore names a tuning *no cold bound admits* — its
    /// feasibility rests on the certificate's measured warm-iteration
    /// hit rates, which the one-simulation [`validate`] call confirms.
    pub fn tune_certified(
        &self,
        scenario: &Scenario,
        lib: &mut CertificateLibrary,
    ) -> Result<TuneOutcome, TuneError> {
        let err = match self.tune(scenario) {
            Ok(outcome) => return Ok(outcome),
            Err(e) => e,
        };

        // The certified partition axis: every set count the library can
        // vouch for on a critical HostTct shape in this mix.
        let mut sets: Vec<u32> = Vec::new();
        for task in &scenario.tasks {
            if !task.criticality.is_time_critical() {
                continue;
            }
            if let Workload::HostTct(spec) = &task.workload {
                if let Some(cert) = lib.lookup(&crate::trace::shape_key(spec)) {
                    sets.extend(cert.entries.iter().map(|e| e.sets));
                }
            }
        }
        sets.sort_unstable();
        sets.dedup();
        if sets.is_empty() {
            return Err(err);
        }

        let mut evaluations = err.evaluations;
        let mut capped = err.capped;
        // Seed the near-miss tracker with the cold search's best so the
        // exhaustion report stays the tightest gap seen *anywhere*.
        let mut best = err.best_bound.map(|b| (b, err.deadline, err.binding));
        let mut probe = scenario.clone();
        for candidate in certified_lattice(&sets) {
            if evaluations >= self.max_evaluations {
                capped = true;
                break;
            }
            probe.tuning = candidate;
            let decision = Scheduler::admit_certified(&probe, lib);
            evaluations += 1;
            if decision.admitted {
                return Ok(TuneOutcome {
                    tuning: candidate,
                    strategy: SearchStrategy::CertifiedPartition,
                    relaxed: Some(err.binding),
                    evaluations,
                    decision,
                });
            }
            track_best(&decision, &mut best);
        }
        let (best_bound, deadline, binding) = match best {
            Some((b, d, r)) => (Some(b), d, r),
            None => (None, err.deadline, err.binding),
        };
        Err(TuneError {
            evaluations,
            capped,
            best_bound,
            deadline,
            binding,
        })
    }
}

/// Track the near-miss rejection — the smallest bound-over-deadline gap
/// seen anywhere — keeping bound, deadline and binding from the same
/// rejection.
fn track_best(decision: &AdmissionDecision, best: &mut Option<(Cycle, Cycle, Resource)>) {
    for r in &decision.rejections {
        if let Some(b) = r.bound {
            let closer = match *best {
                Some((cur_b, cur_d, _)) => {
                    b.saturating_sub(r.deadline) < cur_b.saturating_sub(cur_d)
                }
                None => true,
            };
            if closer {
                *best = Some((b, r.deadline, r.binding));
            }
        }
    }
}

/// The candidate sequence for one binding resource, least- to most-
/// restrictive, holding every other knob at `base`'s value.
fn binding_axis(binding: Resource, base: SocTuning) -> Vec<SocTuning> {
    let mut candidates = Vec::new();
    match binding {
        // Contention on a shared service channel: throttle the NCTs.
        Resource::HyperramChannel => candidates.extend(throttle_axis(base)),
        // DCSPM port contention: the aliasing flip is free — try it
        // before taking bandwidth away from anyone.
        Resource::DcspmPort => {
            if !base.dcspm_private_paths {
                candidates.push(SocTuning {
                    dcspm_private_paths: true,
                    ..base
                });
            }
            candidates.extend(throttle_axis(base));
        }
        // W-channel holds come from unbuffered writers: buffering them
        // is <=1 cycle of cost for the writer and removes the holds.
        Resource::WChannel => {
            if !base.nct_tsu.write_buffer {
                candidates.push(SocTuning {
                    nct_tsu: TsuKnobs {
                        write_buffer: true,
                        ..base.nct_tsu
                    },
                    ..base
                });
            }
            candidates.extend(throttle_axis(base));
        }
        // The task's own shaping, its own compute, an endless stream, or
        // the fault plan's k-fault recovery budget: no isolation knob
        // helps — fall through to the lattice (which documents the
        // exhaustion in the error; for FaultRecovery the fix is a lower
        // k / fault rate or a relaxed deadline, not a reprogrammed TSU).
        Resource::TsuShaping
        | Resource::Compute
        | Resource::Endless
        | Resource::Peripheral
        | Resource::FaultRecovery => {}
    }
    candidates
}

fn throttle_axis(base: SocTuning) -> Vec<SocTuning> {
    THROTTLE_LADDER
        .iter()
        .map(|&(gbs, budget, period)| SocTuning {
            nct_tsu: TsuKnobs::regulated(gbs, budget, period),
            ..base
        })
        .collect()
}

/// The coarse fallback lattice over every knob, sorted by ascending
/// restrictiveness (stable: ties keep generation order).
fn lattice() -> Vec<SocTuning> {
    let mut throttles = vec![TsuKnobs::wb_only()];
    throttles.extend(
        THROTTLE_LADDER
            .iter()
            .map(|&(gbs, budget, period)| TsuKnobs::regulated(gbs, budget, period)),
    );
    let mut points = Vec::new();
    for &nct_tsu in &throttles {
        for &dcspm_private_paths in &[false, true] {
            points.push(SocTuning {
                nct_tsu,
                tct_tsu: TsuKnobs::wb_only(),
                tct_sets: 0,
                dcspm_private_paths,
            });
        }
    }
    points.sort_by_key(restrictiveness);
    points
}

/// The partition axis the cold lattice parks: every (throttle, aliasing)
/// lattice point crossed with every certified TCT set count, sorted by
/// ascending restrictiveness. Only reachable through a certificate —
/// cold bounds evaluate every `tct_sets` variant identically, so these
/// points are meaningful solely under `Scheduler::admit_certified`.
fn certified_lattice(sets: &[u32]) -> Vec<SocTuning> {
    let mut points = Vec::new();
    for base in lattice() {
        for &s in sets {
            points.push(SocTuning {
                tct_sets: s as usize,
                ..base
            });
        }
    }
    points.sort_by_key(restrictiveness);
    points
}

/// Convenience entry point with the default evaluation budget.
pub fn autotune(scenario: &Scenario) -> Result<TuneOutcome, TuneError> {
    Autotuner::default().tune(scenario)
}

/// Certificate-aware convenience entry point: cold search first, then
/// the certified DPLLC partition axis (see [`Autotuner::tune_certified`]).
pub fn autotune_certified(
    scenario: &Scenario,
    lib: &mut CertificateLibrary,
) -> Result<TuneOutcome, TuneError> {
    Autotuner::default().tune_certified(scenario, lib)
}

/// Confirm an analytically chosen tuning with one real simulation:
/// every bounded critical task must measure within its completion bound
/// (engine soundness, end to end) and meet its deadline.
pub fn validate(scenario: &Scenario, outcome: &TuneOutcome) -> TuneValidation {
    let report = Scheduler::run(&scenario.clone().with_tuning(outcome.tuning));
    let mut checks = Vec::new();
    let mut sound = true;
    for b in &outcome.decision.report.bounds {
        if let Some(bound) = b.completion_cycles(scenario.clocks().as_ref()) {
            let t = report.task(&b.task);
            sound &= t.makespan > 0 && t.makespan <= bound;
            checks.push((b.task.clone(), t.makespan, bound));
        }
    }
    let deadlines_met = report.all_deadlines_met();
    TuneValidation {
        report,
        checks,
        sound,
        deadlines_met,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    // The fig6a reference mix (hard TCT with a deadline vs the endless
    // system-DMA interferer) — shared with the grid experiment so the
    // two suites can never drift apart.
    use crate::experiments::autotune::reference_mix;

    #[test]
    fn feasible_mix_returns_unchanged_tuning() {
        let s = reference_mix(2_500_000);
        let o = autotune(&s).expect("feasible");
        assert_eq!(o.strategy, SearchStrategy::AlreadyFeasible);
        assert_eq!(o.tuning, s.tuning);
        assert_eq!(o.relaxed, None);
        assert_eq!(o.evaluations, 1);
    }

    #[test]
    fn descent_finds_least_restrictive_feasible_throttle() {
        // Deadline 800k: rejected at the TsuRegulation start (bound
        // ~1.06M) but admitted by the next-tighter throttle points; the
        // descent must return the least restrictive of them.
        let s = reference_mix(800_000);
        let o = autotune(&s).expect("tunable");
        assert_eq!(o.strategy, SearchStrategy::CoordinateDescent);
        assert_eq!(o.relaxed, Some(Resource::HyperramChannel));
        assert_eq!(o.tuning.nct_tsu, TsuKnobs::regulated(8, 64, 512));
        // Other knobs untouched by the coordinate descent.
        assert_eq!(o.tuning.tct_sets, s.tuning.tct_sets);
        assert!(!o.tuning.dcspm_private_paths);
        assert!(o.decision.admitted);
    }

    #[test]
    fn search_is_deterministic() {
        let s = reference_mix(800_000);
        let a = autotune(&s).expect("tunable");
        let b = autotune(&s).expect("tunable");
        assert_eq!(a.tuning, b.tuning);
        assert_eq!(a.evaluations, b.evaluations);
        assert_eq!(a.strategy, b.strategy);
    }

    #[test]
    fn impossible_deadline_exhausts_the_lattice_with_a_report() {
        // 100k is below every achievable bound: the descent and the
        // lattice both exhaust, and the error names the binding resource
        // and the best bound the space could reach.
        let s = reference_mix(100_000);
        let e = autotune(&s).expect_err("infeasible");
        assert_eq!(e.binding, Resource::HyperramChannel);
        assert_eq!(e.deadline, 100_000);
        assert!(!e.capped, "the default budget covers the whole space");
        let best = e.best_bound.expect("finite bounds exist");
        assert!(best > 100_000, "else it would have been admitted");
        assert!(best < 600_000, "tightest throttle bound expected, got {best}");
        assert!(e.to_string().contains("binding resource"), "{e}");
        // Initial + full descent axis + full lattice.
        assert_eq!(e.evaluations, 1 + THROTTLE_LADDER.len() as u64 + 12 * 2);
    }

    #[test]
    fn capped_search_is_reported_as_cut_short_not_exhausted() {
        // A budget too small to reach the admitting (8, 64, 512) point:
        // the error must say the space was cut short.
        let s = reference_mix(800_000);
        let tuner = Autotuner { max_evaluations: 3 };
        let e = tuner.tune(&s).expect_err("budget below the feasible point");
        assert!(e.capped);
        assert_eq!(e.evaluations, 3);
        assert!(e.to_string().contains("cut short"), "{e}");
    }

    #[test]
    fn certified_partition_axis_admits_what_every_cold_bound_rejects() {
        use crate::soc::hostd::TctSpec;
        use crate::trace::{shape_key, CertEntry, CertificateLibrary, PartitionCertificate};

        // B_cold: the tightest completion bound any cold tuning reaches
        // (the 1-cycle deadline makes the near-miss tracker report it).
        let e = autotune(&reference_mix(1)).expect_err("1-cycle deadline");
        let b_cold = e.best_bound.expect("finite cold bounds exist");
        let cold_space = 1 + THROTTLE_LADDER.len() as u64 + 12 * 2;

        // Just below it every cold point rejects, and an empty library
        // leaves the partition axis locked: same exhaustion as tune().
        let s = reference_mix(b_cold - 1);
        let mut lib = CertificateLibrary::new();
        let err = Autotuner::default()
            .tune_certified(&s, &mut lib)
            .expect_err("empty library cannot unlock the axis");
        assert_eq!(err.evaluations, cold_space);

        // A fig6a working-set certificate (768 distinct lines fit 96
        // sets x 8 ways) flips the verdict: the certified sweep finds a
        // partition point whose warm bound admits the mix.
        lib.insert(PartitionCertificate {
            task: "tct".into(),
            shape_key: shape_key(&TctSpec::fig6a()),
            ways: 8,
            accesses: 6144,
            distinct_lines: 768,
            entries: vec![CertEntry {
                sets: 96,
                max_fills: 768,
                warm_hit_ppm: 1_000_000,
            }],
        });
        let o = Autotuner::default()
            .tune_certified(&s, &mut lib)
            .expect("certificate admits");
        assert_eq!(o.strategy, SearchStrategy::CertifiedPartition);
        assert_eq!(o.tuning.tct_sets, 96, "the certified set count");
        assert!(o.decision.admitted);
        assert_eq!(
            o.decision.report.bound_for("tct").warm_sets,
            Some(96),
            "the admitting bound must be the certificate-backed warm one"
        );
        assert!(o.evaluations > cold_space, "cold space searched first");
        // A cold-feasible mix never reaches the certified axis.
        let easy = Autotuner::default()
            .tune_certified(&reference_mix(2_500_000), &mut lib)
            .expect("feasible");
        assert_eq!(easy.strategy, SearchStrategy::AlreadyFeasible);
    }

    #[test]
    fn lattice_is_sorted_by_restrictiveness() {
        let points = lattice();
        assert_eq!(points.len(), 12 * 2);
        for w in points.windows(2) {
            assert!(restrictiveness(&w[0]) <= restrictiveness(&w[1]));
        }
        // Every lattice point is a valid register setting.
        for p in &points {
            p.validate().expect("lattice point invalid");
        }
        // The unregulated point is least restrictive; ladder order holds.
        assert!(!points[0].nct_tsu.is_regulated());
        assert_eq!(points[0].tct_sets, 0);
        assert!(!points[0].dcspm_private_paths);
    }

    #[test]
    fn restrictiveness_orders_the_knobs_sensibly() {
        let open = SocTuning::no_isolation();
        let tsu = SocTuning::tsu_regulation();
        let tighter = SocTuning {
            nct_tsu: TsuKnobs::regulated(8, 16, 512),
            ..tsu
        };
        assert!(restrictiveness(&open) < restrictiveness(&tsu));
        assert!(restrictiveness(&tsu) < restrictiveness(&tighter));
        let partitioned = SocTuning::tsu_plus_llc_partition(50);
        assert!(restrictiveness(&tsu) < restrictiveness(&partitioned));
    }
}

//! Mixed-criticality task model.
//!
//! Criticality levels follow the paper's task taxonomy: time-critical
//! tasks (TCTs) must meet deadlines with bounded WCET; non-critical
//! tasks (NCTs) get best-effort service and absorb the cost of
//! regulation. Mission-critical AI additionally needs *reliable*
//! execution (AMR lockstep modes).

use crate::soc::amr::{AmrMode, IntPrecision};
use crate::soc::clock::{ClockTree, Cycle};
use crate::soc::dma::DmaJob;
use crate::soc::hostd::TctSpec;
use crate::soc::vector::FpFormat;

/// Criticality bands (descending).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Criticality {
    /// Safety-critical: must execute reliably (lockstep) and on time.
    Safety,
    /// Hard real-time: deadline must hold, reliability optional.
    Hard,
    /// Soft real-time: deadline misses degrade quality only.
    Soft,
    /// Best effort (NCT): throughput-oriented, regulated first.
    BestEffort,
}

impl Criticality {
    pub fn is_time_critical(&self) -> bool {
        matches!(self, Criticality::Safety | Criticality::Hard)
    }
}

/// What the task actually runs.
#[derive(Debug, Clone)]
pub enum Workload {
    /// Integer MatMul (DNN layer) on the AMR cluster.
    AmrMatMul {
        precision: IntPrecision,
        m: u32,
        k: u32,
        n: u32,
        tile: u32,
    },
    /// FP MatMul on the vector cluster.
    VectorMatMul {
        format: FpFormat,
        m: u32,
        k: u32,
        n: u32,
        tile: u32,
    },
    /// Batched FFTs on the vector cluster.
    VectorFft { format: FpFormat, n: u32, batch: u32 },
    /// Strided HyperRAM walker on a host core (the Fig. 6a TCT).
    HostTct(TctSpec),
    /// Bulk copy on the system DMA (the canonical interferer).
    DmaCopy(DmaJob),
}

impl Workload {
    /// Human-readable kind for reports.
    pub fn kind(&self) -> &'static str {
        match self {
            Workload::AmrMatMul { .. } => "amr-matmul",
            Workload::VectorMatMul { .. } => "vector-matmul",
            Workload::VectorFft { .. } => "vector-fft",
            Workload::HostTct(_) => "host-tct",
            Workload::DmaCopy(_) => "dma-copy",
        }
    }

    /// The AOT artifact implementing the functional side, if any.
    pub fn artifact(&self) -> Option<&'static str> {
        match self {
            Workload::AmrMatMul { precision, .. } => Some(precision.artifact()),
            Workload::VectorMatMul { format, .. } => Some(format.artifact()),
            Workload::VectorFft { .. } => Some("fft256"),
            _ => None,
        }
    }
}

/// One task in a scenario.
#[derive(Debug, Clone)]
pub struct McTask {
    pub name: String,
    pub criticality: Criticality,
    /// Relative deadline in system cycles (0 = none). Cycle deadlines
    /// are clock-invariant budgets (the seed's timebase).
    pub deadline: Cycle,
    /// Relative deadline in wall-clock nanoseconds (0 = none). The real
    /// currency of the DVFS governor: its cycle equivalent depends on
    /// the scenario's operating point and is resolved by
    /// [`McTask::deadline_cycles`].
    pub deadline_ns: f64,
    pub workload: Workload,
}

impl McTask {
    pub fn new(name: &str, criticality: Criticality, workload: Workload) -> Self {
        Self {
            name: name.to_string(),
            criticality,
            deadline: 0,
            deadline_ns: 0.0,
            workload,
        }
    }

    pub fn with_deadline(mut self, deadline: Cycle) -> Self {
        self.deadline = deadline;
        self
    }

    /// Deadline in wall-clock nanoseconds — requires the scenario to run
    /// at an explicit operating point so the conversion has a clock.
    pub fn with_deadline_ns(mut self, deadline_ns: f64) -> Self {
        assert!(
            deadline_ns.is_finite() && deadline_ns >= 0.0,
            "nanosecond deadline must be finite and non-negative"
        );
        self.deadline_ns = deadline_ns;
        self
    }

    /// The effective deadline in system cycles at `clocks`. An explicit
    /// cycle deadline wins (clock-invariant budget); a nanosecond
    /// deadline converts through the system clock, rounded *down* so
    /// meeting the cycle budget provably meets the wall-clock one — but
    /// never below 1 cycle: a positive wall-clock deadline shorter than
    /// one clock period is an (infeasible) 1-cycle budget, not an
    /// absent deadline (0 means "none" downstream, which would admit
    /// the task vacuously). Panics (descriptively) when a nanosecond
    /// deadline is used without an operating point — there is no clock
    /// to convert with.
    pub fn deadline_cycles(&self, clocks: Option<&ClockTree>) -> Cycle {
        if self.deadline > 0 {
            return self.deadline;
        }
        if self.deadline_ns > 0.0 {
            let clocks = clocks.unwrap_or_else(|| {
                panic!(
                    "task {}: a nanosecond deadline needs an operating point \
                     (Scenario::with_op_point) to fix the clock",
                    self.name
                )
            });
            let cycles = (self.deadline_ns * clocks.system.freq_mhz / 1e3).floor() as Cycle;
            return cycles.max(1);
        }
        0
    }

    /// The AMR mode a task of this criticality requires.
    pub fn required_amr_mode(&self) -> AmrMode {
        match self.criticality {
            Criticality::Safety => AmrMode::Dlm,
            Criticality::Hard | Criticality::Soft => AmrMode::Indip,
            Criticality::BestEffort => AmrMode::Indip,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn criticality_ordering() {
        assert!(Criticality::Safety < Criticality::BestEffort);
        assert!(Criticality::Safety.is_time_critical());
        assert!(Criticality::Hard.is_time_critical());
        assert!(!Criticality::Soft.is_time_critical());
    }

    #[test]
    fn safety_tasks_demand_lockstep() {
        let t = McTask::new(
            "detect",
            Criticality::Safety,
            Workload::AmrMatMul {
                precision: IntPrecision::Int8,
                m: 64,
                k: 64,
                n: 64,
                tile: 16,
            },
        );
        assert_eq!(t.required_amr_mode(), AmrMode::Dlm);
        assert_eq!(t.workload.artifact(), Some("matmul_int8"));
    }

    #[test]
    fn workload_kinds_and_artifacts() {
        let w = Workload::VectorMatMul {
            format: FpFormat::Fp8,
            m: 64,
            k: 64,
            n: 64,
            tile: 32,
        };
        assert_eq!(w.kind(), "vector-matmul");
        assert_eq!(w.artifact(), Some("matmul_fp8"));
        let f = Workload::VectorFft {
            format: FpFormat::Fp32,
            n: 256,
            batch: 4,
        };
        assert_eq!(f.artifact(), Some("fft256"));
    }

    #[test]
    fn deadline_builder() {
        let spec = TctSpec::fig6a();
        let t = McTask::new("tct", Criticality::Hard, Workload::HostTct(spec)).with_deadline(1000);
        assert_eq!(t.deadline, 1000);
        assert_eq!(t.deadline_cycles(None), 1000, "cycle deadlines need no clock");
    }

    #[test]
    fn ns_deadline_converts_through_the_system_clock() {
        let t = McTask::new("tct", Criticality::Hard, Workload::HostTct(TctSpec::fig6a()))
            .with_deadline_ns(1_000_000.0);
        let max = ClockTree::max_perf(); // 1GHz system: 1 cycle = 1ns
        assert_eq!(t.deadline_cycles(Some(&max)), 1_000_000);
        let low = ClockTree::at_voltages(0.6, 0.6, 0.6); // 350MHz
        assert_eq!(t.deadline_cycles(Some(&low)), 350_000);
        // An explicit cycle budget wins over the wall-clock one.
        let both = t.clone().with_deadline(42);
        assert_eq!(both.deadline_cycles(Some(&max)), 42);
        // A positive deadline shorter than one clock period is an
        // infeasible 1-cycle budget, never a silent "no deadline".
        let tiny = McTask::new("t", Criticality::Hard, Workload::HostTct(TctSpec::fig6a()))
            .with_deadline_ns(2.0);
        assert_eq!(tiny.deadline_cycles(Some(&low)), 1);
    }

    #[test]
    #[should_panic(expected = "needs an operating point")]
    fn ns_deadline_without_a_clock_panics_loudly() {
        let t = McTask::new("tct", Criticality::Hard, Workload::HostTct(TctSpec::fig6a()))
            .with_deadline_ns(1000.0);
        let _ = t.deadline_cycles(None);
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn invalid_ns_deadline_rejected_at_the_builder() {
        let _ = McTask::new("tct", Criticality::Hard, Workload::HostTct(TctSpec::fig6a()))
            .with_deadline_ns(f64::NAN);
    }
}

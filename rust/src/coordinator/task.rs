//! Mixed-criticality task model.
//!
//! Criticality levels follow the paper's task taxonomy: time-critical
//! tasks (TCTs) must meet deadlines with bounded WCET; non-critical
//! tasks (NCTs) get best-effort service and absorb the cost of
//! regulation. Mission-critical AI additionally needs *reliable*
//! execution (AMR lockstep modes).

use crate::soc::amr::{AmrMode, IntPrecision};
use crate::soc::clock::Cycle;
use crate::soc::dma::DmaJob;
use crate::soc::hostd::TctSpec;
use crate::soc::vector::FpFormat;

/// Criticality bands (descending).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Criticality {
    /// Safety-critical: must execute reliably (lockstep) and on time.
    Safety,
    /// Hard real-time: deadline must hold, reliability optional.
    Hard,
    /// Soft real-time: deadline misses degrade quality only.
    Soft,
    /// Best effort (NCT): throughput-oriented, regulated first.
    BestEffort,
}

impl Criticality {
    pub fn is_time_critical(&self) -> bool {
        matches!(self, Criticality::Safety | Criticality::Hard)
    }
}

/// What the task actually runs.
#[derive(Debug, Clone)]
pub enum Workload {
    /// Integer MatMul (DNN layer) on the AMR cluster.
    AmrMatMul {
        precision: IntPrecision,
        m: u32,
        k: u32,
        n: u32,
        tile: u32,
    },
    /// FP MatMul on the vector cluster.
    VectorMatMul {
        format: FpFormat,
        m: u32,
        k: u32,
        n: u32,
        tile: u32,
    },
    /// Batched FFTs on the vector cluster.
    VectorFft { format: FpFormat, n: u32, batch: u32 },
    /// Strided HyperRAM walker on a host core (the Fig. 6a TCT).
    HostTct(TctSpec),
    /// Bulk copy on the system DMA (the canonical interferer).
    DmaCopy(DmaJob),
}

impl Workload {
    /// Human-readable kind for reports.
    pub fn kind(&self) -> &'static str {
        match self {
            Workload::AmrMatMul { .. } => "amr-matmul",
            Workload::VectorMatMul { .. } => "vector-matmul",
            Workload::VectorFft { .. } => "vector-fft",
            Workload::HostTct(_) => "host-tct",
            Workload::DmaCopy(_) => "dma-copy",
        }
    }

    /// The AOT artifact implementing the functional side, if any.
    pub fn artifact(&self) -> Option<&'static str> {
        match self {
            Workload::AmrMatMul { precision, .. } => Some(precision.artifact()),
            Workload::VectorMatMul { format, .. } => Some(format.artifact()),
            Workload::VectorFft { .. } => Some("fft256"),
            _ => None,
        }
    }
}

/// One task in a scenario.
#[derive(Debug, Clone)]
pub struct McTask {
    pub name: String,
    pub criticality: Criticality,
    /// Relative deadline in system cycles (0 = none).
    pub deadline: Cycle,
    pub workload: Workload,
}

impl McTask {
    pub fn new(name: &str, criticality: Criticality, workload: Workload) -> Self {
        Self {
            name: name.to_string(),
            criticality,
            deadline: 0,
            workload,
        }
    }

    pub fn with_deadline(mut self, deadline: Cycle) -> Self {
        self.deadline = deadline;
        self
    }

    /// The AMR mode a task of this criticality requires.
    pub fn required_amr_mode(&self) -> AmrMode {
        match self.criticality {
            Criticality::Safety => AmrMode::Dlm,
            Criticality::Hard | Criticality::Soft => AmrMode::Indip,
            Criticality::BestEffort => AmrMode::Indip,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn criticality_ordering() {
        assert!(Criticality::Safety < Criticality::BestEffort);
        assert!(Criticality::Safety.is_time_critical());
        assert!(Criticality::Hard.is_time_critical());
        assert!(!Criticality::Soft.is_time_critical());
    }

    #[test]
    fn safety_tasks_demand_lockstep() {
        let t = McTask::new(
            "detect",
            Criticality::Safety,
            Workload::AmrMatMul {
                precision: IntPrecision::Int8,
                m: 64,
                k: 64,
                n: 64,
                tile: 16,
            },
        );
        assert_eq!(t.required_amr_mode(), AmrMode::Dlm);
        assert_eq!(t.workload.artifact(), Some("matmul_int8"));
    }

    #[test]
    fn workload_kinds_and_artifacts() {
        let w = Workload::VectorMatMul {
            format: FpFormat::Fp8,
            m: 64,
            k: 64,
            n: 64,
            tile: 32,
        };
        assert_eq!(w.kind(), "vector-matmul");
        assert_eq!(w.artifact(), Some("matmul_fp8"));
        let f = Workload::VectorFft {
            format: FpFormat::Fp32,
            n: 256,
            batch: 4,
        };
        assert_eq!(f.artifact(), Some("fft256"));
    }

    #[test]
    fn deadline_builder() {
        let spec = TctSpec::fig6a();
        let t = McTask::new("tct", Criticality::Hard, Workload::HostTct(spec)).with_deadline(1000);
        assert_eq!(t.deadline, 1000);
    }
}

//! Seeded random scenario generator for the soundness fuzz
//! (`tests/wcet_soundness.rs`).
//!
//! Deterministic: a seed fully determines the mix (xorshift64*, fixed
//! draw order), so failures reproduce exactly. The generated space —
//! 1-2 critical tasks (host TCT, AMR/vector MatMul, vector FFT) plus
//! 0-2 interferers (looping/finite DMA, best-effort vector) under all
//! four isolation policies — is the space the bound engine's formulas
//! were empirically validated on (1200 mixes, zero violations).

use crate::coordinator::task::Criticality;
use crate::coordinator::{FaultPlan, IsolationPolicy, McTask, Scenario, ScrubConfig, Workload};
use crate::soc::amr::IntPrecision;
use crate::soc::axi::Target;
use crate::soc::dma::DmaJob;
use crate::soc::hostd::TctSpec;
use crate::soc::vector::FpFormat;
use crate::util::XorShift;

/// Generate the deterministic random mix for `seed`.
pub fn random_scenario(seed: u64) -> Scenario {
    let mut rng = XorShift::new(seed);
    let policy_idx = rng.below(4);
    let pct = [12u8, 25, 50, 75][rng.below(4) as usize];
    let policy = match policy_idx {
        0 => IsolationPolicy::NoIsolation,
        1 => IsolationPolicy::TsuRegulation,
        2 => IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: pct,
        },
        _ => IsolationPolicy::PrivatePaths,
    };
    let n_crit = 1 + rng.below(2);
    let n_int = rng.below(3);
    let mut scenario = Scenario::new(&format!("fuzz-{seed}"), policy);
    let mut slot = 0usize;
    for _ in 0..n_crit {
        let name = format!("t{slot}");
        let task = match rng.below(4) {
            0 => {
                let accesses = rng.in_range(32, 192) as u32;
                let iterations = rng.in_range(1, 3) as u32;
                let stride = 64u64 << rng.below(3);
                let think = rng.in_range(1, 8);
                McTask::new(
                    &name,
                    Criticality::Hard,
                    Workload::HostTct(TctSpec {
                        base: 0,
                        stride,
                        accesses,
                        iterations,
                        think_cycles: think,
                        part_id: 1,
                    }),
                )
            }
            1 => {
                let dim = 32 * rng.in_range(1, 2) as u32;
                let tile = 8u32 << rng.below(2);
                let dlm = rng.below(2) == 0;
                McTask::new(
                    &name,
                    if dlm {
                        Criticality::Safety
                    } else {
                        Criticality::Hard
                    },
                    Workload::AmrMatMul {
                        precision: IntPrecision::Int8,
                        m: dim,
                        k: dim,
                        n: dim,
                        tile,
                    },
                )
            }
            2 => {
                let dim = 32 * rng.in_range(1, 2) as u32;
                let tile = 16u32 << rng.below(2);
                McTask::new(
                    &name,
                    Criticality::Hard,
                    Workload::VectorMatMul {
                        format: FpFormat::Fp16,
                        m: dim,
                        k: dim,
                        n: dim,
                        tile,
                    },
                )
            }
            _ => {
                let batch = rng.in_range(2, 6) as u32;
                McTask::new(
                    &name,
                    Criticality::Hard,
                    Workload::VectorFft {
                        format: FpFormat::Fp32,
                        n: 256,
                        batch,
                    },
                )
            }
        };
        scenario.tasks.push(task);
        slot += 1;
    }
    for _ in 0..n_int {
        let name = format!("t{slot}");
        let task = match rng.below(3) {
            0 => {
                let chunk = 64u32 << rng.below(3);
                let outstanding = rng.in_range(1, 4) as u32;
                McTask::new(
                    &name,
                    Criticality::BestEffort,
                    Workload::DmaCopy(DmaJob {
                        src: Target::Hyperram,
                        src_addr: 0x10_0000,
                        dst: Some(Target::Dcspm),
                        dst_addr: 0,
                        bytes: 1 << 18,
                        chunk_beats: chunk,
                        outstanding,
                        looping: true,
                        part_id: 0,
                    }),
                )
            }
            1 => {
                let chunk = 64u32 << rng.below(3);
                let outstanding = rng.in_range(1, 4) as u32;
                let with_dst = rng.below(2) == 0;
                McTask::new(
                    &name,
                    Criticality::BestEffort,
                    Workload::DmaCopy(DmaJob {
                        src: Target::Hyperram,
                        src_addr: 0x10_0000,
                        dst: if with_dst { Some(Target::Dcspm) } else { None },
                        dst_addr: 0,
                        bytes: 1 << 16,
                        chunk_beats: chunk,
                        outstanding,
                        looping: false,
                        part_id: 0,
                    }),
                )
            }
            _ => {
                let dim = 32 * rng.in_range(1, 2) as u32;
                McTask::new(
                    &name,
                    Criticality::BestEffort,
                    Workload::VectorMatMul {
                        format: FpFormat::Fp16,
                        m: dim,
                        k: dim,
                        n: dim,
                        tile: 32,
                    },
                )
            }
        };
        scenario.tasks.push(task);
        slot += 1;
    }
    scenario
}

/// Generate the deterministic random fault plan for `seed` at the
/// `k`-fault hypothesis (`tests/fault_soundness.rs`).
///
/// Uses its *own* RNG stream (domain-separated from the scenario
/// generator's), so pairing a plan with `random_scenario(seed)` never
/// perturbs the mix's draw order — the same seed yields the same mix
/// with and without faults.
pub fn random_fault_plan(seed: u64, k: u32) -> FaultPlan {
    let mut rng = XorShift::new(seed ^ 0xFA17_0000_FA17_0001);
    let rate = [0.0, 0.25, 1.0, 3.0][rng.below(4) as usize];
    let retry_every = [0u64, 32, 64, 128][rng.below(4) as usize];
    let retries_per_line = 1 + rng.below(2) as u32;
    let mut plan = FaultPlan::new(seed).with_amr_rate(rate).with_k(k);
    if retry_every > 0 {
        plan = plan.with_retries(retry_every, retries_per_line);
    }
    if rng.below(2) == 0 {
        plan = plan.with_scrub(ScrubConfig::carfield());
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        for seed in 1..20 {
            let a = random_scenario(seed);
            let b = random_scenario(seed);
            assert_eq!(a.tasks.len(), b.tasks.len());
            assert_eq!(a.tuning, b.tuning);
            for (x, y) in a.tasks.iter().zip(&b.tasks) {
                assert_eq!(x.name, y.name);
                assert_eq!(x.criticality, y.criticality);
                assert_eq!(format!("{:?}", x.workload), format!("{:?}", y.workload));
            }
        }
    }

    #[test]
    fn generator_covers_policies_and_mix_sizes() {
        let mut policies = std::collections::HashSet::new();
        let mut max_tasks = 0;
        let mut has_crit = true;
        for seed in 1..200 {
            let s = random_scenario(seed);
            policies.insert(s.tuning.describe());
            max_tasks = max_tasks.max(s.tasks.len());
            has_crit &= s.tasks.iter().any(|t| t.criticality.is_time_critical());
        }
        assert!(policies.len() >= 4, "policies seen: {policies:?}");
        assert!(max_tasks >= 3);
        assert!(has_crit, "every mix carries a critical task");
    }
}

//! Analytical WCET bound engine — the paper's time-predictability claim
//! ("tight upper bounds on execution times of critical applications"),
//! *computed* instead of merely measured.
//!
//! Given a [`Scenario`](crate::coordinator::Scenario) and the resource
//! configuration its isolation policy programs, the engine derives — per
//! time-critical task and **without simulating** — two upper bounds:
//!
//! 1. a **memory-latency bound**: the worst-case latency of one memory
//!    transaction (a host line fill, a cluster tile transfer), and
//! 2. a **completion-time bound** on the whole task.
//!
//! # Service-curve composition
//!
//! The analysis composes three per-IP worst-case characterizations that
//! the hardware models themselves export:
//!
//! * **TSU arrival curves** (`TsuConfig::max_beats_in_window`): a
//!   TRU-regulated initiator releases at most `budget * (t/period + 2)`
//!   beats into the crossbar in any window `t` (a window can straddle a
//!   partial period at both ends of a refill boundary), fragmented to
//!   the GBS size. Unregulated initiators have unbounded arrival and
//!   only structural bounds apply.
//! * **Crossbar arbitration** (`Crossbar::worst_bursts_ahead`): per-lane
//!   round-robin admits at most the burst in service, a full admission
//!   queue, and one turn per competitor ahead of a newly queued burst;
//!   unbuffered writes anywhere stall every grant for the write's length
//!   (W-channel holds), chained at most `write_chain_cap` deep per
//!   writer.
//! * **Target service models**: the HyperRAM channel is deterministic
//!   per line (`HyperRamTiming::worst_lines_cost` — row-open worst
//!   case, plus a victim writeback when any task writes the HyperRAM
//!   space); DCSPM ports serve one beat per cycle, doubled under
//!   cross-port bank conflicts (`Dcspm::worst_burst_cycles`).
//!
//! The **memory-latency bound** is purely structural (sound for any
//! competitor behaviour). The **completion bound** takes the minimum of
//! the structural path (per-transaction bound x transaction count) and a
//! classical busy-window fixed point driven by the TRU arrival curves —
//! the latter only when every competitor is TRU-regulated and no
//! unbuffered writer exists, which is exactly the regime the paper's
//! isolation policies establish.
//!
//! # Clock domains and wall-clock composition
//!
//! Every timed resource belongs to an explicit clock domain: TSU
//! shaping, pipeline edges, W-channel holds and DCSPM service ride the
//! DVFS-scaled **system** clock, while HyperRAM/DPLLC service and
//! peripheral access ride the fixed-frequency **uncore** clock. Bounds
//! are carried as per-domain [`CostSplit`]s and composed in wall-clock
//! nanoseconds ([`TaskBound::completion_ns`] is the exact per-domain
//! sum): with a decoupled uncore, lowering the core voltage stretches
//! only the system-side terms, so memory-bound completion bounds stay
//! flat in wall clock — the property that lets the DVFS governor admit
//! low-voltage points the cycle-constant model falsely rejected. On the
//! seed's single timebase the domains coincide and every formula is
//! bit-identical to the original cycles-only engine.
//!
//! # The fault dimension
//!
//! A scenario's [`FaultPlan`](crate::coordinator::FaultPlan) threads
//! through the analysis: HyperRAM timing is inflated by the bounded
//! per-line retry overhead, the ECC scrub engine joins the model set as
//! one more TRU-regulated competitor (its interference composes through
//! the same arrival curves), and lockstep AMR tasks carry a **k-fault
//! re-execution term** ([`TaskBound::fault_bound`]) pricing up to
//! `k_faults` HFR recoveries. A quiet plan is normalised away, so the
//! k=0 path is byte-identical to the fault-free engine.
//!
//! Soundness (`measured <= bound`) is enforced empirically by the seeded
//! scenario fuzzer in `tests/wcet_soundness.rs` (under seeded fault
//! injection by `tests/fault_soundness.rs`, and across mixed
//! uncore/core frequency ratios by `tests/uncore_equivalence.rs`) and,
//! for the paper grids, by `experiments::bounds`; tightness on the
//! TSU-regulated rows (`bound <= 2x measured worst case`) is asserted
//! there too.

pub mod bound;
pub mod fuzz;
pub mod model;

pub use bound::{
    analyze, analyze_certified, min_slack, CostSplit, Resource, SlackProbe, TaskBound, WarmSpec,
    WcetReport,
};
pub use model::{models_of, InitiatorModel, StreamModel, TaskShape};

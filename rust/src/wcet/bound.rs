//! The compositional bound computation (see the module docs in
//! [`crate::wcet`] for the model). Transliterated 1:1 from the
//! empirically-validated prototype: every formula here was checked for
//! soundness (`measured <= bound`) against 1200 randomized mixes and
//! the fig6a/fig6b grids, and for tightness (`bound <= 2x measured`) on
//! the TSU-regulated rows.
//!
//! # Multi-domain composition
//!
//! Every timed term belongs to an explicit clock domain and bounds are
//! carried as a per-domain [`CostSplit`]:
//!
//! * **system cycles** — think/compute time, TSU shaping delays,
//!   pipeline edges, W-channel holds, DCSPM service;
//! * **uncore cycles** — HyperRAM/DPLLC service
//!   ([`HyperRamTiming::worst_lines_cost`]) and peripheral access, which
//!   run on the fixed uncore clock and do not stretch under core DVFS.
//!
//! On the single-timebase seed (no operating point, or a coupled
//! uncore) the two grids coincide and all arithmetic is bit-identical to
//! the original cycles-only engine. With a *decoupled* uncore the
//! busy-window fixed point iterates in wall-clock nanoseconds (arrival
//! windows converted to system cycles per-resource, service priced
//! through the uncore clock) and [`TaskBound::completion_ns`] is the
//! exact per-domain sum — not a post-hoc single-clock conversion —
//! which is what makes memory-bound completion bounds wall-clock-flat
//! as the core voltage drops. Each uncore service activation is
//! additionally charged one uncore plus one system cycle of CDC
//! synchronization margin, covering the simulator's exact edge
//! conversions at the initiator->crossbar->target boundary.

use crate::coordinator::Scenario;
use crate::soc::axi::xbar::Crossbar;
use crate::soc::axi::{Target, BEAT_BYTES};
use crate::soc::clock::{ClockTree, Cycle};
use crate::soc::mem::dcspm::Dcspm;
use crate::soc::mem::hyperram;
use crate::soc::mem::peripheral::Peripheral;
use crate::soc::mem::HyperRamTiming;

use super::model::{models_of, InitiatorModel, StreamModel, TaskShape};

/// Pipeline edges budget per transaction: issue, grant, service start
/// and response delivery each cost at most one cycle (system domain).
pub const EDGES: Cycle = 4;
/// DPLLC / L1 line size (bytes) — constant across the Carfield models
/// (asserted against `DpllcConfig::carfield()` in [`analyze`]).
const LINE_BYTES: u64 = 64;
/// Busy-window divergence cap: beyond this the fixed point will not
/// converge and the structural bound is used instead.
const WINDOW_CAP: f64 = 1e12;

/// The shared resource a bound is dominated by (feasibility reports name
/// it so the coordinator knows which knob to turn).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resource {
    /// The single HyperBUS channel behind the DPLLC.
    HyperramChannel,
    /// A DCSPM subordinate port (or cross-port bank conflicts).
    DcspmPort,
    /// The constant-latency peripheral region.
    Peripheral,
    /// The shared W channel, held by unbuffered writes.
    WChannel,
    /// The task's own TSU shaping (GBS/TRU/WB fill).
    TsuShaping,
    /// The cluster's own compute pipeline.
    Compute,
    /// An endless stream — no completion bound exists.
    Endless,
    /// The fault plan's k-fault re-execution budget: the nominal bound
    /// fits the deadline, the faulted one does not. No isolation knob
    /// helps — lower k, lower the fault rate, or relax the deadline.
    FaultRecovery,
}

impl Resource {
    pub fn describe(&self) -> &'static str {
        match self {
            Resource::HyperramChannel => "HyperRAM channel contention",
            Resource::DcspmPort => "DCSPM port contention",
            Resource::Peripheral => "peripheral access latency",
            Resource::WChannel => "W-channel holds by unbuffered writers",
            Resource::TsuShaping => "own TSU shaping",
            Resource::Compute => "compute pipeline",
            Resource::Endless => "endless workload (no completion bound)",
            Resource::FaultRecovery => "k-fault recovery budget",
        }
    }
}

/// A bound decomposed into per-clock-domain cycle components. The sum
/// is only meaningful through a clock tree (or on the lock-step seed
/// timebase, where the grids coincide and the plain total is exact).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CostSplit {
    /// System-domain cycles (think/compute, edges, TSU, W holds, DCSPM).
    pub system: Cycle,
    /// Uncore-domain cycles (HyperRAM/DPLLC channel, peripheral).
    pub uncore: Cycle,
}

impl CostSplit {
    pub const ZERO: CostSplit = CostSplit { system: 0, uncore: 0 };

    pub fn sys(c: Cycle) -> Self {
        Self { system: c, uncore: 0 }
    }

    pub fn unc(c: Cycle) -> Self {
        Self { system: 0, uncore: c }
    }

    pub fn plus(self, o: Self) -> Self {
        Self {
            system: self.system + o.system,
            uncore: self.uncore + o.uncore,
        }
    }

    pub fn times(self, n: u64) -> Self {
        Self {
            system: self.system * n,
            uncore: self.uncore * n,
        }
    }

    /// The plain cycle total — exact only on the lock-step timebase
    /// (seed semantics, where uncore cycles *are* system cycles).
    pub fn lockstep_total(&self) -> Cycle {
        self.system + self.uncore
    }

    /// Exact wall-clock value: each component converted through its own
    /// domain's clock, then summed.
    pub fn ns(&self, clocks: &ClockTree) -> f64 {
        clocks.system.cycles_to_ns(self.system) + clocks.uncore.cycles_to_ns(self.uncore)
    }

    /// Sound system-cycle equivalent for cycle-domain comparisons
    /// (admission against `McTask::deadline_cycles`): uncore cycles
    /// convert through the tree rounded *up*, so a bound that fits a
    /// cycle budget provably fits it in wall clock too. Without a tree
    /// (or with a coupled uncore) the grids coincide and the total is
    /// exact, bit-identical to the seed engine.
    pub fn system_cycles(&self, clocks: Option<&ClockTree>) -> Cycle {
        match clocks {
            Some(t) if t.uncore_decoupled() => {
                self.system + t.uncore.to_system(self.uncore, &t.system)
            }
            _ => self.lockstep_total(),
        }
    }
}

/// Bounds for one time-critical task, per clock domain.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskBound {
    pub task: String,
    /// Worst-case latency of a single memory transaction.
    pub mem_bound: CostSplit,
    pub mem_binding: Resource,
    /// Worst-case *nominal* completion time (`None` for endless
    /// workloads) — the fault-free term.
    pub completion_bound: Option<CostSplit>,
    pub completion_binding: Resource,
    /// k-fault re-execution term from the scenario's `FaultPlan`: up to
    /// `k_faults` HFR recoveries, each restoring core state
    /// (`HFR_RESTORE_CYCLES`) and re-executing the interrupted tile.
    /// Recovery runs on the cluster's own pipeline, so the term lands in
    /// the system domain (it stretches with core DVFS, not with the
    /// uncore clock). `ZERO` without a plan — every accessor is then
    /// bit-identical to the fault-free engine.
    pub fault_bound: CostSplit,
    /// The *nominal* completion bound decomposed along the [`Resource`]
    /// axis: the terms sum exactly (per domain component) to
    /// `completion_bound`, so the trace ledger's measured column lines
    /// up row-for-row with the bound column ("gap attribution").
    /// Structural bounds carve out own-TSU shaping, W-channel holds and
    /// the per-target service+interference term per stream; a winning
    /// busy-window bound keeps its compute term and charges the whole
    /// window remainder to the binding resource (on a decoupled
    /// timebase the busy value is a single uncore-priced quantity and
    /// stays whole on the binding resource). Empty for endless tasks.
    /// The k-fault term is *not* included — append it with
    /// [`TaskBound::breakdown_with_fault`].
    pub breakdown: Vec<(Resource, CostSplit)>,
    /// The exclusive-partition size whose certificate-backed warm
    /// pricing produced the winning completion bound ([`analyze_certified`]).
    /// `None` on every cold path — plain [`analyze`] never sets it.
    pub warm_sets: Option<u32>,
}

impl TaskBound {
    /// Completion bound in system cycles at the scenario's clocks (the
    /// admission test's currency), *including* the k-fault re-execution
    /// term. Sound: uncore components round up.
    pub fn completion_cycles(&self, clocks: Option<&ClockTree>) -> Option<Cycle> {
        self.completion_bound
            .map(|c| c.plus(self.fault_bound).system_cycles(clocks))
    }

    /// The fault-free completion bound in system cycles — what admission
    /// compares to attribute a rejection to [`Resource::FaultRecovery`]
    /// (deadline fits nominally, misses with the k-fault term).
    pub fn nominal_completion_cycles(&self, clocks: Option<&ClockTree>) -> Option<Cycle> {
        self.completion_bound.map(|c| c.system_cycles(clocks))
    }

    /// Memory-latency bound in system cycles at the scenario's clocks.
    pub fn mem_cycles(&self, clocks: Option<&ClockTree>) -> Cycle {
        self.mem_bound.system_cycles(clocks)
    }

    /// Admission slack against `deadline`, in system cycles at the
    /// scenario's clocks: `deadline - completion bound` (k-fault term
    /// included). Positive = margin, negative = infeasible by that
    /// many cycles. `None` for endless workloads, which have no
    /// completion bound to compare.
    pub fn slack_cycles(&self, deadline: Cycle, clocks: Option<&ClockTree>) -> Option<i64> {
        self.completion_cycles(clocks)
            .map(|bound| deadline as i64 - bound as i64)
    }

    /// Completion bound as wall-clock nanoseconds at an operating
    /// point's clock tree — the DVFS governor's currency, k-fault term
    /// included. *Exact*: each domain's cycles convert through their own
    /// clock and the results sum in wall-clock, so a decoupled uncore's
    /// service time does not falsely stretch with the system voltage.
    pub fn completion_ns(&self, clocks: &ClockTree) -> Option<f64> {
        self.completion_bound
            .map(|c| c.plus(self.fault_bound).ns(clocks))
    }

    /// Memory-latency bound in nanoseconds at `clocks` (exact
    /// per-domain composition, like [`TaskBound::completion_ns`]).
    pub fn mem_ns(&self, clocks: &ClockTree) -> f64 {
        self.mem_bound.ns(clocks)
    }

    /// The breakdown term for one resource (`ZERO` when absent).
    pub fn breakdown_term(&self, r: Resource) -> CostSplit {
        self.breakdown
            .iter()
            .find(|(res, _)| *res == r)
            .map(|(_, c)| *c)
            .unwrap_or(CostSplit::ZERO)
    }

    /// The breakdown including the k-fault re-execution term, summing
    /// exactly to `completion_bound + fault_bound` — what the `carfield
    /// trace` gap-attribution table prints. (The fault term is applied
    /// by [`analyze`] *after* the per-model decomposition, so it lives
    /// outside `breakdown` and is appended lazily here.)
    pub fn breakdown_with_fault(&self) -> Vec<(Resource, CostSplit)> {
        let mut rows = self.breakdown.clone();
        if self.fault_bound != CostSplit::ZERO {
            rows.push((Resource::FaultRecovery, self.fault_bound));
        }
        rows
    }
}

/// The analysis result for a scenario: one entry per critical task.
#[derive(Debug, Clone, PartialEq)]
pub struct WcetReport {
    pub scenario: String,
    pub policy: String,
    pub bounds: Vec<TaskBound>,
}

impl WcetReport {
    pub fn bound_for(&self, task: &str) -> &TaskBound {
        self.bounds
            .iter()
            .find(|b| b.task == task)
            .unwrap_or_else(|| panic!("no bound for critical task {task}"))
    }
}

/// The binding admission margin of a mix: the deadline task whose
/// completion bound sits closest to (or furthest past) its deadline,
/// tagged with the resource that dominates the bound.
#[derive(Debug, Clone, PartialEq)]
pub struct SlackProbe {
    pub task: String,
    /// The resource dominating the binding task's completion bound —
    /// the mix's scarce axis (what slack-aware packing bins on, and
    /// what to reconfigure when the slack goes negative).
    pub binding: Resource,
    /// `deadline - completion bound` in system cycles (negative =
    /// infeasible; `i64::MIN` marks a deadline on an endless task,
    /// which no configuration can admit).
    pub slack: i64,
}

/// Extract the tightest admission margin from an analyzed scenario:
/// for every deadline-carrying time-critical task, the slack of its
/// completion bound (k-fault term included) against the deadline, in
/// system cycles at the scenario's clocks; the row with the minimum
/// slack wins. `None` when no task carries a deadline — nothing
/// binds. Deterministic tie-break: the first task in declaration
/// order keeps the probe.
pub fn min_slack(scenario: &Scenario, report: &WcetReport) -> Option<SlackProbe> {
    let clocks = scenario.clocks();
    let mut best: Option<SlackProbe> = None;
    for task in &scenario.tasks {
        if !task.criticality.is_time_critical() {
            continue;
        }
        let deadline = task.deadline_cycles(clocks.as_ref());
        if deadline == 0 {
            continue;
        }
        let b = report.bound_for(&task.name);
        let slack = b
            .slack_cycles(deadline, clocks.as_ref())
            .unwrap_or(i64::MIN);
        if best.as_ref().map(|p| slack < p.slack).unwrap_or(true) {
            best = Some(SlackProbe {
                task: task.name.clone(),
                binding: b.completion_binding,
                slack,
            });
        }
    }
    best
}

/// How a scenario's bounds are priced for comparison and for the
/// busy-window fixed point.
#[derive(Debug, Clone, Copy)]
enum Pricing {
    /// Single timebase (no operating point, or a coupled uncore): bound
    /// units are system cycles and every formula is bit-identical to
    /// the seed's cycles-only engine.
    Lockstep,
    /// Decoupled uncore: bound units are wall-clock nanoseconds; each
    /// domain's cycles convert through its own clock (the same
    /// [`ClockDomain`] conversions — and rounding directions — the rest
    /// of the stack uses).
    ///
    /// [`ClockDomain`]: crate::soc::clock::ClockDomain
    WallClock {
        sys: crate::soc::clock::ClockDomain,
        unc: crate::soc::clock::ClockDomain,
    },
}

impl Pricing {
    fn of(scenario: &Scenario) -> Self {
        match scenario.clocks() {
            Some(t) if t.uncore_decoupled() => Pricing::WallClock {
                sys: t.system,
                unc: t.uncore,
            },
            _ => Pricing::Lockstep,
        }
    }

    /// System cycles -> bound units.
    fn sys(&self, c: f64) -> f64 {
        match self {
            Pricing::Lockstep => c,
            Pricing::WallClock { sys, .. } => sys.cycles_to_ns(1) * c,
        }
    }

    /// Uncore cycles -> bound units.
    fn unc(&self, c: f64) -> f64 {
        match self {
            Pricing::Lockstep => c,
            Pricing::WallClock { unc, .. } => unc.cycles_to_ns(1) * c,
        }
    }

    /// Scalar value of a split in bound units (for comparisons).
    fn units(&self, c: CostSplit) -> f64 {
        self.sys(c.system as f64) + self.unc(c.uncore as f64)
    }

    /// A window in bound units, as the system-cycle count the TSU
    /// arrival curves consume — [`ClockDomain::ns_to_cycles`] rounds
    /// up, so no reachable arrival is ever excluded.
    ///
    /// [`ClockDomain::ns_to_cycles`]: crate::soc::clock::ClockDomain::ns_to_cycles
    fn window_sys_cycles(&self, units: f64) -> Cycle {
        match self {
            Pricing::Lockstep => units as Cycle,
            Pricing::WallClock { sys, .. } => sys.ns_to_cycles(units),
        }
    }

    /// CDC synchronization margin charged per uncore service activation
    /// when the grids are decoupled: entry sync to the next uncore edge
    /// plus completion visibility at the next system edge. Zero on the
    /// lock-step timebase (there is no boundary to cross), keeping seed
    /// arithmetic untouched.
    fn sync(&self) -> CostSplit {
        match self {
            Pricing::Lockstep => CostSplit::ZERO,
            Pricing::WallClock { .. } => CostSplit { system: 1, uncore: 1 },
        }
    }

    /// A converged busy-window value (bound units) as a split: system
    /// cycles on the lock-step timebase, uncore cycles (rounded up —
    /// sound) when decoupled.
    fn busy_split(&self, units: f64) -> CostSplit {
        match self {
            Pricing::Lockstep => CostSplit::sys(units.ceil() as Cycle),
            Pricing::WallClock { unc, .. } => CostSplit::unc(unc.ns_to_cycles(units)),
        }
    }
}

/// Empirical warm-iteration evidence for one task: a
/// [`PartitionCertificate`](crate::trace::PartitionCertificate) entry
/// matched to the scenario's exact `tct_sets` setting. At most
/// `max_fills` of the task's accesses pay the cold line-fill cost; the
/// rest are certified DPLLC hits priced at hit latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WarmSpec {
    pub sets: u32,
    pub max_fills: u64,
}

/// Analyze a scenario: derive bounds for every time-critical task
/// without simulating. Pure and deterministic — identical output for
/// identical scenarios, regardless of thread count or call order.
pub fn analyze(scenario: &Scenario) -> WcetReport {
    analyze_with(scenario, &[])
}

/// [`analyze`], with certificate-backed warm-iteration pricing for host
/// TCTs. Strictly sound fallback: a task gets a [`WarmSpec`] only when
/// **(a)** the scenario actually programs an exclusive TCT partition
/// whose set count matches a certified entry *exactly* (no
/// interpolation — hit rate is not monotone in set count), **(b)** that
/// task is the only time-critical HyperRAM initiator (placement puts
/// every critical task in the TCT partition, so a second one would
/// break exclusivity), and **(c)** the certificate's associativity
/// matches the live cache geometry. Everything else — and every
/// non-HostTct task — takes the cold path, bit-identical to
/// [`analyze`]. Library lookups count hits/misses like
/// [`UtilizationLibrary`](crate::power::certificates::UtilizationLibrary).
pub fn analyze_certified(
    scenario: &Scenario,
    lib: &mut crate::trace::CertificateLibrary,
) -> WcetReport {
    use crate::coordinator::Workload;
    let tct_sets = scenario.tuning.tct_sets as u32;
    let mut warm: Vec<(String, WarmSpec)> = Vec::new();
    if tct_sets > 0 {
        let models = models_of(scenario);
        let hyperram_criticals = models
            .iter()
            .filter(|m| m.critical && m.streams.iter().any(|s| s.target == Target::Hyperram))
            .count();
        if hyperram_criticals == 1 {
            for t in &scenario.tasks {
                if !t.criticality.is_time_critical() {
                    continue;
                }
                let Workload::HostTct(spec) = &t.workload else {
                    continue;
                };
                let key = crate::trace::shape_key(spec);
                let Some(cert) = lib.lookup(&key) else {
                    continue;
                };
                if cert.ways as usize != crate::soc::mem::dpllc::DpllcConfig::carfield().ways {
                    continue;
                }
                if let Some(e) = cert.entry_for(tct_sets) {
                    warm.push((
                        t.name.clone(),
                        WarmSpec {
                            sets: e.sets,
                            max_fills: e.max_fills,
                        },
                    ));
                }
            }
        }
    }
    analyze_with(scenario, &warm)
}

fn analyze_with(scenario: &Scenario, warm: &[(String, WarmSpec)]) -> WcetReport {
    // Tie the engine's geometry constants to the simulator's: if the
    // cache/bus geometry ever drifts, fail loudly (release builds
    // included — `carfield wcet` and admission control must never emit
    // silently unsound bounds).
    assert_eq!(
        crate::soc::mem::dpllc::DpllcConfig::carfield().line_bytes,
        LINE_BYTES,
        "WCET engine geometry drifted from DpllcConfig::carfield()"
    );
    let models = models_of(scenario);
    let plan = scenario.fault_plan();
    // Transient-retry inflation: under a fault plan with line retries
    // every HyperRAM line fill may pay `retries_per_line` full row-miss
    // re-fetches; the inflated timing flows through every service-curve
    // and interference formula below. Zero overhead without a plan —
    // bit-identical to the fault-free engine.
    let timing = {
        let base = HyperRamTiming::carfield();
        match plan {
            Some(p) => base.with_retry_overhead(p.retry_overhead(base.line_retry_cost(LINE_BYTES))),
            None => base,
        }
    };
    let pricing = Pricing::of(scenario);
    let bounds = (0..models.len())
        .filter(|&i| models[i].critical)
        .map(|i| {
            let w = warm
                .iter()
                .find(|(n, _)| *n == models[i].name)
                .map(|&(_, s)| s);
            let mut tb = analyze_model(i, &models, &timing, pricing, w);
            tb.fault_bound = fault_term(&models[i], plan);
            tb
        })
        .collect();
    WcetReport {
        scenario: scenario.name.clone(),
        policy: scenario.tuning.describe(),
        bounds,
    }
}

/// The k-fault re-execution term for one critical initiator: each of up
/// to `k_faults` detected lockstep mismatches costs an HFR restore plus
/// a re-execution of the interrupted tile — exactly the worst per-event
/// penalty the AMR simulator charges under a plan. Lockstep detection
/// exists only on AMR cluster tasks (the model's compute window *is*
/// `AmrCluster::tile_compute_bound`, so bound and simulator agree on the
/// window by construction); INDIP tasks take silent faults with no time
/// penalty, and non-cluster tasks have no lockstep hardware at all.
fn fault_term(me: &InitiatorModel, plan: Option<crate::coordinator::FaultPlan>) -> CostSplit {
    let Some(p) = plan else {
        return CostSplit::ZERO;
    };
    if p.k_faults == 0 || !me.lockstep {
        return CostSplit::ZERO;
    }
    match me.shape {
        TaskShape::Cluster {
            compute_per_tile, ..
        } => CostSplit::sys(
            p.k_faults as Cycle * (crate::soc::amr::HFR_RESTORE_CYCLES + compute_per_tile),
        ),
        _ => CostSplit::ZERO,
    }
}

fn ceil_div(a: u64, b: u64) -> u64 {
    a.div_ceil(b.max(1))
}

/// Lines a fragment of `beats` beats touches (streams are line-aligned).
fn lines_of_fragment(beats: u32) -> u64 {
    ceil_div(beats as u64 * BEAT_BYTES, LINE_BYTES).max(1)
}

/// Any stream in the scenario writing the HyperRAM space can leave dirty
/// LLC lines, so every fill may additionally drain a victim.
fn dirty_possible(models: &[InitiatorModel]) -> bool {
    models
        .iter()
        .any(|m| m.streams.iter().any(|s| s.write && s.target == Target::Hyperram))
}

fn banks_overlap(a: Option<u64>, b: Option<u64>) -> bool {
    match (a, b) {
        (None, _) | (_, None) => true, // interleaved spans every bank
        (Some(x), Some(y)) => x == y,
    }
}

/// Can a stream on the *other* DCSPM port steal beat slots from `s`
/// through bank conflicts?
fn stream_conflict(models: &[InitiatorModel], owner: usize, s: &StreamModel) -> bool {
    if s.target != Target::Dcspm {
        return false;
    }
    let port = Dcspm::port_of_addr(s.addr);
    let banks = Dcspm::bank_half_of_addr(s.addr);
    models.iter().enumerate().any(|(i, m)| {
        i != owner
            && m.streams.iter().any(|o| {
                o.target == Target::Dcspm
                    && Dcspm::port_of_addr(o.addr) != port
                    && banks_overlap(banks, Dcspm::bank_half_of_addr(o.addr))
            })
    })
}

/// Worst service time of one shaped fragment of initiator `owner`'s
/// stream `s`, in its owning domain's cycles (HyperRAM and peripheral:
/// uncore; DCSPM: system), including the per-activation CDC sync margin
/// for uncore targets on a decoupled timebase.
fn fragment_cost(
    models: &[InitiatorModel],
    owner: usize,
    s: &StreamModel,
    timing: &HyperRamTiming,
    dirty: bool,
    pricing: Pricing,
) -> CostSplit {
    let frag = models[owner].tsu.fragment_beats(s.beats);
    match s.target {
        Target::Hyperram => {
            CostSplit::unc(timing.worst_lines_cost(lines_of_fragment(frag), LINE_BYTES, dirty))
                .plus(pricing.sync())
        }
        Target::Dcspm => {
            CostSplit::sys(Dcspm::worst_burst_cycles(frag, stream_conflict(models, owner, s)))
        }
        Target::Peripheral => {
            CostSplit::unc(Peripheral::new(Peripheral::DEFAULT_LATENCY).worst_burst_cycles(frag))
                .plus(pricing.sync())
        }
    }
}

/// Worst shaping delay of the task's own TSU for one logical burst
/// (system cycles — the shapers are clocked with the interconnect).
fn own_tsu_delay(me: &InitiatorModel, s: &StreamModel) -> Cycle {
    let tsu = &me.tsu;
    let mut d: Cycle = 0;
    if s.write && tsu.wb_enable {
        d += if s.beats > tsu.wb_capacity_beats {
            s.beats as Cycle
        } else {
            1
        };
    }
    if tsu.is_tru_regulated() {
        let frag = tsu.fragment_beats(s.beats);
        let n_frags = ceil_div(s.beats as u64, frag as u64);
        let per_period = ((tsu.tru_budget_beats / frag).max(1)) as u64;
        d += ceil_div(n_frags, per_period) * tsu.tru_period;
    }
    d
}

/// Per-stream structural bound components.
struct StreamBound {
    total: CostSplit,
    own: CostSplit,
    w_term: CostSplit,
    /// Own-TSU shaping delay component of `total`.
    tsu_d: CostSplit,
    /// The stream's target resource (where `total - tsu_d - w_term` is
    /// attributed in the completion-bound breakdown).
    resource: Resource,
    endless: bool,
}

/// Componentwise subtraction for breakdown carving. Callers only
/// subtract terms that are componentwise summands of `a`; saturation
/// is a belt-and-braces guard, not an expected path.
fn minus(a: CostSplit, b: CostSplit) -> CostSplit {
    CostSplit {
        system: a.system.saturating_sub(b.system),
        uncore: a.uncore.saturating_sub(b.uncore),
    }
}

/// Decompose a structural completion bound `(compute + sum(totals)) * n`
/// into per-resource terms. Row order mirrors the trace ledger's
/// (TsuShaping, WChannel, targets, Compute); zero rows are dropped
/// except Compute, so the terms always re-sum to the bound exactly.
fn structural_rows(
    per_stream: &[StreamBound],
    compute: CostSplit,
    n: u64,
) -> Vec<(Resource, CostSplit)> {
    let order = [
        Resource::HyperramChannel,
        Resource::DcspmPort,
        Resource::Peripheral,
    ];
    let mut tsu = CostSplit::ZERO;
    let mut w = CostSplit::ZERO;
    let mut per_target = [CostSplit::ZERO; 3];
    for s in per_stream {
        tsu = tsu.plus(s.tsu_d);
        w = w.plus(s.w_term);
        let rest = minus(minus(s.total, s.tsu_d), s.w_term);
        let ti = order.iter().position(|r| *r == s.resource).unwrap();
        per_target[ti] = per_target[ti].plus(rest);
    }
    let mut rows = Vec::new();
    if tsu != CostSplit::ZERO {
        rows.push((Resource::TsuShaping, tsu.times(n)));
    }
    if w != CostSplit::ZERO {
        rows.push((Resource::WChannel, w.times(n)));
    }
    for (ti, r) in order.iter().enumerate() {
        if per_target[ti] != CostSplit::ZERO {
            rows.push((*r, per_target[ti].times(n)));
        }
    }
    rows.push((Resource::Compute, compute.times(n)));
    rows
}

/// Decompose a winning busy-window bound: keep the compute term (the
/// window's base charges at least `compute` per activation, so the
/// remainder never underflows on the lock-step timebase) and attribute
/// everything else to the binding resource. On a decoupled timebase the
/// window is one uncore-priced quantity; carving a system-domain
/// compute term out of it would be cross-domain, so the whole window
/// stays on the binding resource (documented caveat on
/// [`TaskBound::breakdown`]).
fn busy_rows(
    busy: CostSplit,
    compute: CostSplit,
    binding: Resource,
    pricing: Pricing,
) -> Vec<(Resource, CostSplit)> {
    match pricing {
        Pricing::Lockstep => vec![
            (binding, minus(busy, compute)),
            (Resource::Compute, compute),
        ],
        Pricing::WallClock { .. } => vec![(binding, busy)],
    }
}

fn analyze_model(
    my_idx: usize,
    models: &[InitiatorModel],
    timing: &HyperRamTiming,
    pricing: Pricing,
    warm: Option<WarmSpec>,
) -> TaskBound {
    let me = &models[my_idx];
    let dirty = dirty_possible(models);

    // W-channel holds: worst unbuffered-write fragment anywhere else and
    // the total back-to-back chain those writers can sustain.
    let mut w_frag: u32 = 0;
    let mut w_chain: u64 = 0;
    for (i, m) in models.iter().enumerate() {
        if i == my_idx {
            continue;
        }
        let mut writes = false;
        for s in &m.streams {
            if s.write && s.unbuffered_write {
                w_frag = w_frag.max(m.tsu.fragment_beats(s.beats));
                writes = true;
            }
        }
        if writes {
            w_chain += m.write_chain_cap;
        }
    }

    let mut per_stream: Vec<StreamBound> = Vec::new();
    let mut mem_bound = CostSplit::ZERO;
    let mut mem_binding = Resource::HyperramChannel;
    for s in &me.streams {
        let own_frag = me.tsu.fragment_beats(s.beats);
        let n_frags = ceil_div(s.beats as u64, own_frag as u64);
        let own = fragment_cost(models, my_idx, s, timing, dirty, pricing).times(n_frags);
        let own_resource = match s.target {
            Target::Hyperram => Resource::HyperramChannel,
            Target::Dcspm => Resource::DcspmPort,
            Target::Peripheral => Resource::Peripheral,
        };
        let queue = match s.target {
            Target::Hyperram => hyperram::QUEUE_DEPTH,
            _ => 0,
        };
        // Competing streams: same target, and for the DCSPM the same
        // subordinate port (per-lane arbitration).
        let my_port = Dcspm::port_of_addr(s.addr);
        let competitors: Vec<(usize, &StreamModel)> = models
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != my_idx)
            .flat_map(|(i, m)| m.streams.iter().map(move |c| (i, c)))
            .filter(|&(_, c)| {
                c.target == s.target
                    && (s.target != Target::Dcspm || Dcspm::port_of_addr(c.addr) == my_port)
            })
            .collect();
        let n_comp_inits = {
            let mut ids: Vec<usize> = competitors.iter().map(|&(i, _)| i).collect();
            ids.sort_unstable();
            ids.dedup();
            ids.len()
        };
        let ahead = Crossbar::worst_bursts_ahead(n_comp_inits, queue);
        let worst_comp = competitors
            .iter()
            .map(|&(i, c)| fragment_cost(models, i, c, timing, dirty, pricing))
            .fold(CostSplit::ZERO, |acc, c| {
                if pricing.units(c) > pricing.units(acc) {
                    c
                } else {
                    acc
                }
            });
        // Every own fragment can wait out a full arbitration round; each
        // serviced burst ahead may additionally be preceded by one
        // W-channel hold, plus each writer's provable back-to-back chain.
        let interference = worst_comp.times(n_frags * ahead);
        let w_term = if w_frag > 0 {
            let count = ahead + 1 + w_chain;
            let sys_cand = CostSplit::sys(count * w_frag as Cycle);
            match pricing {
                Pricing::Lockstep => sys_cand,
                Pricing::WallClock { .. } => {
                    // W data dribbles on the *target's* clock grid: a
                    // hold on an uncore target runs `w_frag` PHY cycles
                    // plus one system cycle of edge rounding. Neither
                    // candidate dominates at every frequency ratio, so
                    // take the units-max of the all-system and
                    // all-uncore extremes — an upper bound on any mix
                    // of hold targets.
                    let unc_cand = CostSplit {
                        system: count,
                        uncore: count * w_frag as Cycle,
                    };
                    if pricing.units(unc_cand) > pricing.units(sys_cand) {
                        unc_cand
                    } else {
                        sys_cand
                    }
                }
            }
        } else {
            CostSplit::ZERO
        };
        let tsu_d = CostSplit::sys(own_tsu_delay(me, s));
        let total = tsu_d
            .plus(interference)
            .plus(w_term)
            .plus(own)
            .plus(CostSplit::sys(EDGES));
        if pricing.units(total) > pricing.units(mem_bound) {
            mem_bound = total;
            let own_u = pricing.units(own);
            let w_u = pricing.units(w_term);
            let tsu_u = pricing.units(tsu_d);
            mem_binding = if pricing.units(interference) >= own_u.max(w_u).max(tsu_u) {
                own_resource
            } else if w_u > own_u.max(tsu_u) {
                Resource::WChannel
            } else if tsu_u > own_u {
                Resource::TsuShaping
            } else {
                own_resource
            };
        }
        per_stream.push(StreamBound {
            total,
            own,
            w_term,
            tsu_d,
            resource: own_resource,
            endless: s.count.is_none(),
        });
    }

    let (completion, completion_binding, breakdown, warm_sets) = completion_of(
        my_idx,
        models,
        &per_stream,
        timing,
        dirty,
        w_frag,
        mem_binding,
        pricing,
        warm,
    );
    TaskBound {
        task: me.name.clone(),
        mem_bound,
        mem_binding,
        completion_bound: completion,
        completion_binding,
        fault_bound: CostSplit::ZERO,
        breakdown,
        warm_sets,
    }
}

/// Are all competitors on `target` TRU-regulated (bounded arrival)?
fn competitors_regulated(models: &[InitiatorModel], my_idx: usize, target: Target) -> bool {
    models.iter().enumerate().all(|(i, m)| {
        i == my_idx
            || !m.streams.iter().any(|s| s.target == target)
            || m.tsu.is_tru_regulated()
    })
}

/// Worst service (bound units) competitors' arrivals (TRU curves) plus
/// carried-in backlog can consume on `target` within a window of
/// `window` bound units. Arrival curves count in system cycles (the
/// TSUs' clock); service prices through the target's owning domain.
/// Only called when every competitor on `target` is regulated.
#[allow(clippy::too_many_arguments)]
fn window_interference(
    models: &[InitiatorModel],
    my_idx: usize,
    target: Target,
    window: f64,
    timing: &HyperRamTiming,
    dirty: bool,
    pricing: Pricing,
) -> f64 {
    let sync_u = pricing.units(pricing.sync());
    let window_sys = pricing.window_sys_cycles(window);
    let mut total = 0.0;
    for (i, m) in models.iter().enumerate() {
        if i == my_idx {
            continue;
        }
        let streams: Vec<&StreamModel> =
            m.streams.iter().filter(|s| s.target == target).collect();
        if streams.is_empty() {
            continue;
        }
        let tsu = &m.tsu;
        let frag = streams
            .iter()
            .map(|s| tsu.fragment_beats(s.beats))
            .max()
            .unwrap();
        let (per_period_frags, per_period_beats) = if frag >= tsu.tru_budget_beats {
            (1u64, frag) // an oversize fragment passes once per period
        } else {
            let full = (tsu.tru_budget_beats / frag) as u64;
            // A burst whose length is not a multiple of the GBS size
            // ends in a sub-fragment tail that can squeeze through
            // leftover budget — one extra service activation per burst
            // startable in the period (plus one straddling its start).
            let min_beats = streams.iter().map(|s| s.beats.max(1)).min().unwrap();
            let has_tail = streams
                .iter()
                .any(|s| s.beats % tsu.fragment_beats(s.beats) != 0);
            let tails = if has_tail {
                (tsu.tru_budget_beats as u64).div_ceil(min_beats as u64) + 1
            } else {
                0
            };
            (full + tails, tsu.tru_budget_beats)
        };
        // Periods derive from the TSU's own arrival curve (which covers
        // windows straddling a partial period at both ends).
        let max_beats = tsu
            .max_beats_in_window(window_sys)
            .expect("caller guarantees regulated competitors");
        let periods = (max_beats / tsu.tru_budget_beats as u64) as f64;
        let carry_frags: u64 = m.inflight_cap
            * streams
                .iter()
                .map(|s| ceil_div(s.beats as u64, tsu.fragment_beats(s.beats) as u64))
                .max()
                .unwrap();
        if target == Target::Hyperram {
            let lines = per_period_frags * lines_of_fragment(frag);
            total += periods
                * (pricing.unc(timing.worst_lines_cost(lines, LINE_BYTES, dirty) as f64)
                    + per_period_frags as f64 * sync_u);
            total += pricing.unc(timing.worst_lines_cost(
                carry_frags * lines_of_fragment(frag),
                LINE_BYTES,
                dirty,
            ) as f64)
                + carry_frags as f64 * sync_u;
        } else {
            let conflict = streams.iter().any(|s| stream_conflict(models, i, s));
            let per = Dcspm::worst_burst_cycles(per_period_beats, conflict) + per_period_frags;
            total += periods * pricing.sys(per as f64);
            total +=
                carry_frags as f64 * pricing.sys(Dcspm::worst_burst_cycles(frag, conflict) as f64);
        }
    }
    total
}

/// Iterate the busy-window fixed point `t = base + I(t)` from `base_u`
/// (bound units); `None` when it diverges.
fn busy_converge(
    models: &[InitiatorModel],
    my_idx: usize,
    target: Target,
    base_u: f64,
    timing: &HyperRamTiming,
    dirty: bool,
    pricing: Pricing,
) -> Option<f64> {
    let mut t = base_u;
    for _ in 0..200 {
        let nxt = base_u + window_interference(models, my_idx, target, t, timing, dirty, pricing);
        if nxt > WINDOW_CAP {
            return None;
        }
        if nxt - t <= 1.0 {
            return Some(nxt);
        }
        t = nxt;
    }
    None
}

#[allow(clippy::too_many_arguments)]
fn completion_of(
    my_idx: usize,
    models: &[InitiatorModel],
    per_stream: &[StreamBound],
    timing: &HyperRamTiming,
    dirty: bool,
    w_frag: u32,
    mem_binding: Resource,
    pricing: Pricing,
    warm: Option<WarmSpec>,
) -> (
    Option<CostSplit>,
    Resource,
    Vec<(Resource, CostSplit)>,
    Option<u32>,
) {
    let me = &models[my_idx];
    if per_stream.iter().any(|s| s.endless) {
        return (None, Resource::Endless, Vec::new(), None);
    }
    // ---- structural path (always finite, always sound) ----
    let (structural, structural_binding, base, warm_base, target, compute, mult) = match me.shape {
        TaskShape::HostTct { think, accesses } => {
            let structural = CostSplit::sys(think + 2)
                .plus(per_stream[0].total)
                .times(accesses);
            let has_comp = models.iter().enumerate().any(|(i, m)| {
                i != my_idx && m.streams.iter().any(|s| s.target == Target::Hyperram)
            });
            // Competitor interleaving destroys the walker's row
            // locality: charge one extra row open per access.
            let reopen = if has_comp {
                timing.t_row_miss - timing.t_row_hit
            } else {
                0
            };
            let base = CostSplit::sys(think + EDGES)
                .plus(CostSplit::unc(
                    timing.worst_lines_cost(1, LINE_BYTES, dirty) + reopen,
                ))
                .plus(pricing.sync())
                .times(accesses);
            // Certificate-backed warm base: at most `max_fills` accesses
            // pay the cold fill (with the competitor reopen), the rest
            // are certified DPLLC hits served by the parallel hit port
            // at `llc_hit + 1` uncore cycles (the simulator's exact
            // hit-port service for one line) — they never touch the
            // HyperBUS channel, so the only channel time in the warm
            // window is the fills' own plus the competitors' (the same
            // arrival-curve interference the fixed point adds).
            let warm_base = warm.map(|w| {
                let fills = w.max_fills.min(accesses);
                let hits = accesses - fills;
                CostSplit::sys(think + EDGES)
                    .times(accesses)
                    .plus(
                        CostSplit::unc(timing.worst_lines_cost(1, LINE_BYTES, dirty) + reopen)
                            .plus(pricing.sync())
                            .times(fills),
                    )
                    .plus(
                        CostSplit::unc(timing.llc_hit + 1)
                            .plus(pricing.sync())
                            .times(hits),
                    )
            });
            (
                structural,
                mem_binding,
                base,
                warm_base,
                Target::Hyperram,
                CostSplit::sys(think + 2),
                accesses,
            )
        }
        TaskShape::Cluster {
            tiles,
            compute_per_tile,
        } => {
            let per_tile = per_stream
                .iter()
                .fold(CostSplit::ZERO, |acc, s| acc.plus(s.total));
            let structural = per_tile
                .plus(CostSplit::sys(compute_per_tile + 4))
                .times(tiles);
            let binding = if pricing.sys((compute_per_tile + 4) as f64) > pricing.units(per_tile)
            {
                Resource::Compute
            } else {
                mem_binding
            };
            let own = per_stream
                .iter()
                .fold(CostSplit::ZERO, |acc, s| acc.plus(s.own).plus(s.w_term))
                .plus(CostSplit::sys(2 * EDGES));
            let base = own
                .plus(CostSplit::sys(compute_per_tile + 4))
                .times(tiles);
            (
                structural,
                binding,
                base,
                None,
                Target::Dcspm,
                CostSplit::sys(compute_per_tile + 4),
                tiles,
            )
        }
        TaskShape::Dma { chunks } => {
            let chunks = chunks.unwrap_or(0); // endless handled above
            let structural = per_stream
                .iter()
                .fold(CostSplit::ZERO, |acc, s| acc.plus(s.total))
                .plus(CostSplit::sys(2))
                .times(chunks);
            return (
                Some(structural),
                mem_binding,
                structural_rows(per_stream, CostSplit::sys(2), chunks),
                None,
            );
        }
    };
    // ---- busy-window path (tighter; needs regulated competitors and no
    // unbuffered writers — W-channel holds stall every grant and are not
    // captured by per-target arrival curves) ----
    let mut best = structural;
    let mut binding = structural_binding;
    let mut rows = structural_rows(per_stream, compute, mult);
    let mut warm_sets = None;
    if competitors_regulated(models, my_idx, target) && w_frag == 0 {
        if let Some(t) =
            busy_converge(models, my_idx, target, pricing.units(base), timing, dirty, pricing)
        {
            let busy = pricing.busy_split(t);
            if pricing.units(busy) < pricing.units(structural) {
                best = busy;
                binding = match target {
                    Target::Hyperram => Resource::HyperramChannel,
                    _ => Resource::DcspmPort,
                };
                rows = busy_rows(busy, compute.times(mult), binding, pricing);
            }
        }
        // The warm window needs the same regime: every hit the
        // certificate prices assumes the exclusive partition is intact
        // and no unbuffered writer stalls the hit-port grants.
        if let (Some(wb), Some(w)) = (warm_base, warm) {
            if let Some(t) =
                busy_converge(models, my_idx, target, pricing.units(wb), timing, dirty, pricing)
            {
                let busy = pricing.busy_split(t);
                if pricing.units(busy) < pricing.units(best) {
                    best = busy;
                    binding = Resource::HyperramChannel;
                    rows = busy_rows(busy, compute.times(mult), binding, pricing);
                    warm_sets = Some(w.sets);
                }
            }
        }
    }
    (Some(best), binding, rows, warm_sets)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::Criticality;
    use crate::coordinator::{IsolationPolicy, McTask, Workload};
    use crate::soc::dma::DmaJob;
    use crate::soc::hostd::TctSpec;

    fn fig6a_scenario(policy: IsolationPolicy) -> Scenario {
        Scenario::new("s", policy)
            .with_task(McTask::new(
                "tct",
                Criticality::Hard,
                Workload::HostTct(TctSpec::fig6a()),
            ))
            .with_task(McTask::new(
                "dma",
                Criticality::BestEffort,
                Workload::DmaCopy(DmaJob::interferer()),
            ))
    }

    #[test]
    fn isolated_tct_bound_is_own_service_plus_edges() {
        let s = Scenario::new("iso", IsolationPolicy::NoIsolation).with_task(McTask::new(
            "tct",
            Criticality::Hard,
            Workload::HostTct(TctSpec::fig6a()),
        ));
        let r = analyze(&s);
        let b = r.bound_for("tct");
        // One 64B line: row miss (24) + 8 beats x 2 cycles + 4 edges.
        assert_eq!(b.mem_cycles(None), 44);
        // The split types the terms by owning domain: the line fill is
        // uncore service, the edges are system cycles.
        assert_eq!(b.mem_bound, CostSplit { system: 4, uncore: 40 });
        assert!(b.completion_bound.is_some());
    }

    #[test]
    fn regulated_interference_composes_queue_and_arbitration() {
        let r = analyze(&fig6a_scenario(IsolationPolicy::TsuRegulation));
        let b = r.bound_for("tct");
        // own 40 + edges 4 + (1 in service + 4 queue + 1 RR turn) x 40.
        assert_eq!(b.mem_cycles(None), 284);
        assert_eq!(b.mem_binding, Resource::HyperramChannel);
        // All service is uncore-domain; only the edges ride the system
        // clock.
        assert_eq!(b.mem_bound.system, 4);
        assert_eq!(b.mem_bound.uncore, 280);
        // The busy window converges: the regulated DMA leaves headroom.
        let c = b.completion_cycles(None).expect("finite");
        assert!(c < 2_000_000, "busy window diverged: {c}");
    }

    #[test]
    fn unregulated_interference_is_finite_but_far_larger() {
        let reg = analyze(&fig6a_scenario(IsolationPolicy::TsuRegulation));
        let unreg = analyze(&fig6a_scenario(IsolationPolicy::NoIsolation));
        let b_reg = reg.bound_for("tct");
        let b_unreg = unreg.bound_for("tct");
        // Unsplit 256-beat bursts + W-channel holds blow the bound up by
        // over an order of magnitude — the Fig. 6a story, analytically.
        assert!(b_unreg.mem_cycles(None) > 10 * b_reg.mem_cycles(None));
        assert!(
            b_unreg.completion_cycles(None).unwrap() > 10 * b_reg.completion_cycles(None).unwrap(),
            "unreg {:?} vs reg {:?}",
            b_unreg.completion_bound,
            b_reg.completion_bound
        );
    }

    #[test]
    fn endless_critical_task_has_no_completion_bound() {
        let job = DmaJob::interferer();
        let s = Scenario::new("endless", IsolationPolicy::TsuRegulation).with_task(
            McTask::new("dma", Criticality::Hard, Workload::DmaCopy(job)),
        );
        let r = analyze(&s);
        let b = r.bound_for("dma");
        assert_eq!(b.completion_bound, None);
        assert_eq!(b.completion_binding, Resource::Endless);
    }

    #[test]
    fn bounds_reprice_in_nanoseconds_per_operating_point() {
        use crate::power::OperatingPoint;
        let s = fig6a_scenario(IsolationPolicy::TsuRegulation);
        let r = analyze(&s);
        let b = r.bound_for("tct");
        let fast = OperatingPoint::max_perf().clock_tree();
        let slow = OperatingPoint::uniform(0.6).unwrap().clock_tree();
        let c = b.completion_cycles(None).unwrap() as f64;
        // 1GHz system clock: 1 cycle = 1ns, exactly.
        assert_eq!(b.completion_ns(&fast), Some(c));
        // A *coupled* tree stretches the whole bound with the system
        // clock — the seed's post-hoc conversion, recovered exactly.
        let slow_ns = b.completion_ns(&slow).unwrap();
        assert!((slow_ns - c * 1e3 / 350.0).abs() < 1e-6);
        assert!(b.mem_ns(&fast) < b.mem_ns(&slow));
    }

    #[test]
    fn decoupled_uncore_keeps_memory_bounds_wall_clock_flat() {
        use crate::power::OperatingPoint;
        // The same regulated fig6a mix analyzed at 0.6V and 1.1V with
        // the uncore parked at its fixed 1000MHz: the memory-latency
        // bound's wall-clock value barely moves (only the 4 system-side
        // edge cycles stretch), instead of scaling ~2.9x with the core
        // clock as the coupled model does.
        let at = |v: f64| {
            let op = OperatingPoint::uniform(v).unwrap().decoupled_uncore();
            let s = fig6a_scenario(IsolationPolicy::TsuRegulation).with_op_point(op);
            analyze(&s).bound_for("tct").mem_ns(&op.clock_tree())
        };
        let low_ns = at(0.6);
        let high_ns = at(1.1);
        // Uncore component identical; only the system-side edges (and,
        // at genuinely split frequencies, the CDC sync margin — at the
        // 1.1V anchor the grids coincide and pricing collapses to the
        // seed path with no sync) differ: the low-voltage bound stays
        // within ~13% of the peak one instead of scaling 2.9x.
        assert!(
            low_ns < high_ns * 1.15,
            "memory bound scaled with core voltage: {low_ns:.1} vs {high_ns:.1} ns"
        );
        assert!(low_ns >= high_ns, "slower edges cannot shrink the bound");
        // The coupled model at 0.6V stretches the same bound ~2.9x: the
        // whole 284-cycle bound rides the 350MHz system clock.
        let coupled_op = OperatingPoint::uniform(0.6).unwrap();
        let coupled = analyze(
            &fig6a_scenario(IsolationPolicy::TsuRegulation).with_op_point(coupled_op),
        );
        let coupled_ns = coupled.bound_for("tct").mem_ns(&coupled_op.clock_tree());
        assert!(
            coupled_ns > low_ns * 1.5,
            "coupled {coupled_ns:.1}ns vs decoupled {low_ns:.1}ns"
        );
    }

    #[test]
    fn decoupled_completion_cycles_round_soundly() {
        use crate::power::OperatingPoint;
        let op = OperatingPoint::uniform(0.6).unwrap().decoupled_uncore();
        let s = fig6a_scenario(IsolationPolicy::TsuRegulation).with_op_point(op);
        let r = analyze(&s);
        let b = r.bound_for("tct");
        let tree = op.clock_tree();
        let cycles = b.completion_cycles(Some(&tree)).unwrap();
        let ns = b.completion_ns(&tree).unwrap();
        // The cycle-domain bound must cover the exact wall-clock bound
        // (rounded up through the 350MHz system clock) and be tighter
        // than the naive single-clock total (which would price uncore
        // service at system speed).
        let wall_in_sys = ns * tree.system.freq_mhz / 1e3;
        assert!(cycles as f64 >= wall_in_sys - 1e-6);
        assert!(cycles as f64 <= wall_in_sys + 2.0, "conversion too loose");
        let naive_total = b.completion_bound.unwrap().lockstep_total();
        assert!(cycles < naive_total, "decoupling must shrink the cycle bound");
    }

    #[test]
    fn breakdown_sums_to_the_completion_bound_on_both_paths() {
        // NoIsolation takes the structural path (unregulated DMA), the
        // TSU rows take the busy-window path: the per-resource terms
        // must re-sum to the headline bound exactly on both.
        for policy in [
            IsolationPolicy::NoIsolation,
            IsolationPolicy::TsuRegulation,
            IsolationPolicy::TsuPlusLlcPartition {
                tct_fraction_percent: 50,
            },
        ] {
            let r = analyze(&fig6a_scenario(policy));
            let b = r.bound_for("tct");
            let total = b
                .breakdown
                .iter()
                .fold(CostSplit::ZERO, |acc, (_, c)| acc.plus(*c));
            assert_eq!(Some(total), b.completion_bound, "{policy:?}");
            assert_ne!(
                b.breakdown_term(Resource::Compute),
                CostSplit::ZERO,
                "{policy:?}: think time must be carved out"
            );
            assert_ne!(
                b.breakdown_term(Resource::HyperramChannel),
                CostSplit::ZERO,
                "{policy:?}: the walker's memory term must be present"
            );
        }
        // Endless critical streams have no bound and no breakdown.
        let job = DmaJob::interferer();
        let s = Scenario::new("endless", IsolationPolicy::TsuRegulation).with_task(
            McTask::new("dma", Criticality::Hard, Workload::DmaCopy(job)),
        );
        assert!(analyze(&s).bound_for("dma").breakdown.is_empty());
    }

    #[test]
    fn breakdown_with_fault_appends_the_k_term() {
        use crate::coordinator::FaultPlan;
        let s = fig6a_scenario(IsolationPolicy::TsuRegulation);
        let b = analyze(&s);
        let tb = b.bound_for("tct");
        // No plan: identical to the plain breakdown.
        assert_eq!(tb.breakdown_with_fault(), tb.breakdown);
        // Host tasks have no lockstep hardware — the term stays zero
        // even under a plan (soundness of the omission is covered by
        // fault_term_prices_k_recoveries_on_lockstep_clusters_only).
        let planned = analyze(&s.clone().with_faults(FaultPlan::new(3).with_k(2)));
        let ptb = planned.bound_for("tct");
        assert_eq!(ptb.breakdown_with_fault(), ptb.breakdown);
    }

    #[test]
    fn analyze_is_deterministic() {
        let s = fig6a_scenario(IsolationPolicy::TsuRegulation);
        assert_eq!(analyze(&s), analyze(&s));
    }

    #[test]
    fn cost_split_arithmetic() {
        let a = CostSplit { system: 3, uncore: 5 };
        let b = CostSplit::sys(2).plus(CostSplit::unc(7));
        let sum = a.plus(b);
        assert_eq!(sum, CostSplit { system: 5, uncore: 12 });
        assert_eq!(sum.times(2), CostSplit { system: 10, uncore: 24 });
        assert_eq!(sum.lockstep_total(), 17);
        assert_eq!(sum.system_cycles(None), 17);
        // ns composition at the 1GHz max_perf corner: 1 cycle = 1ns in
        // both domains, so the exact per-domain sum is the plain total.
        let tree = crate::soc::clock::ClockTree::max_perf();
        assert_eq!(sum.ns(&tree), 17.0);
        // Decoupled: 5 sys cycles @ 500MHz = 10ns + 12 unc @ 1GHz = 12ns.
        let dec = crate::soc::clock::ClockTree {
            system: crate::soc::clock::ClockDomain::new(crate::soc::clock::Domain::System, 500.0),
            ..tree
        };
        assert_eq!(sum.ns(&dec), 22.0);
        assert_eq!(sum.system_cycles(Some(&dec)), 5 + 6, "12 unc @ 1GHz = 6 sys @ 500MHz");
    }

    #[test]
    fn resource_descriptions_cover_all_variants() {
        for r in [
            Resource::HyperramChannel,
            Resource::DcspmPort,
            Resource::Peripheral,
            Resource::WChannel,
            Resource::TsuShaping,
            Resource::Compute,
            Resource::Endless,
            Resource::FaultRecovery,
        ] {
            assert!(!r.describe().is_empty());
        }
    }

    #[test]
    fn certified_warm_bound_beats_cold_and_falls_back_soundly() {
        use crate::coordinator::SocTuning;
        use crate::trace::{CertEntry, CertificateLibrary, PartitionCertificate};

        let spec = TctSpec::fig6a();
        let cert = || PartitionCertificate {
            task: "tct".into(),
            shape_key: crate::trace::shape_key(&spec),
            ways: 8,
            accesses: 6144,
            distinct_lines: 768,
            entries: vec![CertEntry {
                sets: 96,
                max_fills: 768,
                warm_hit_ppm: 1_000_000,
            }],
        };
        let part = |sets: usize| SocTuning {
            tct_sets: sets,
            ..SocTuning::tsu_regulation()
        };
        let s = fig6a_scenario(IsolationPolicy::TsuRegulation).with_tuning(part(96));

        // Empty library: bit-identical to the cold engine (and the miss
        // is counted, like the utilization library's).
        let mut empty = CertificateLibrary::new();
        assert_eq!(analyze_certified(&s, &mut empty), analyze(&s));
        assert_eq!(empty.misses, 1);

        let mut lib = CertificateLibrary::new();
        lib.insert(cert());
        let cold = analyze(&s);
        let warm = analyze_certified(&s, &mut lib);
        let cb = cold.bound_for("tct");
        let wb = warm.bound_for("tct");
        assert_eq!(cb.warm_sets, None, "plain analyze never warms");
        assert_eq!(wb.warm_sets, Some(96));
        let (c, w) = (
            cb.completion_cycles(None).unwrap(),
            wb.completion_cycles(None).unwrap(),
        );
        // 768 cold fills + 5376 certified hits must price well under
        // 6144 cold fills: the warm busy window starts from a base less
        // than a third of the cold one.
        assert!(w * 10 < c * 9, "warm {w} not tighter than cold {c}");
        // The per-transaction memory bound stays structural (one access
        // can always miss) and the breakdown still re-sums exactly.
        assert_eq!(wb.mem_bound, cb.mem_bound);
        let total = wb
            .breakdown
            .iter()
            .fold(CostSplit::ZERO, |acc, (_, x)| acc.plus(*x));
        assert_eq!(Some(total), wb.completion_bound);

        // No exclusive partition programmed: cold, even with the
        // certificate in the library.
        let shared = fig6a_scenario(IsolationPolicy::TsuRegulation);
        assert_eq!(analyze_certified(&shared, &mut lib), analyze(&shared));
        // A partition size the certificate has no entry for: cold (no
        // interpolation — hit rate is not monotone in set count).
        let other = fig6a_scenario(IsolationPolicy::TsuRegulation).with_tuning(part(128));
        assert_eq!(analyze_certified(&other, &mut lib), analyze(&other));
        // An associativity mismatch with the live geometry: cold.
        let mut stale = CertificateLibrary::new();
        stale.insert(PartitionCertificate {
            ways: 4,
            ..cert()
        });
        assert_eq!(analyze_certified(&s, &mut stale), analyze(&s));
    }

    #[test]
    fn certified_warm_path_requires_an_exclusive_critical_initiator() {
        use crate::coordinator::SocTuning;
        use crate::trace::{CertEntry, CertificateLibrary, PartitionCertificate};
        let spec = TctSpec::fig6a();
        let mut lib = CertificateLibrary::new();
        lib.insert(PartitionCertificate {
            task: "tct".into(),
            shape_key: crate::trace::shape_key(&spec),
            ways: 8,
            accesses: 6144,
            distinct_lines: 768,
            entries: vec![CertEntry {
                sets: 96,
                max_fills: 768,
                warm_hit_ppm: 1_000_000,
            }],
        });
        // Two critical HyperRAM initiators share the TCT partition —
        // exclusivity is gone, so the certificate must NOT apply.
        let part = SocTuning {
            tct_sets: 96,
            ..SocTuning::tsu_regulation()
        };
        let s = Scenario::new("pair", part)
            .with_task(McTask::new(
                "tct",
                Criticality::Hard,
                Workload::HostTct(TctSpec::fig6a()),
            ))
            .with_task(McTask::new(
                "tct2",
                Criticality::Hard,
                Workload::HostTct(TctSpec::fig6a()),
            ));
        let r = analyze_certified(&s, &mut lib);
        assert_eq!(r, analyze(&s));
        assert_eq!(r.bound_for("tct").warm_sets, None);
        assert_eq!(lib.hits + lib.misses, 0, "no lookup without exclusivity");
    }

    #[test]
    fn fault_term_prices_k_recoveries_on_lockstep_clusters_only() {
        use crate::coordinator::FaultPlan;
        use crate::soc::amr::{AmrCluster, AmrMode, HFR_RESTORE_CYCLES};
        use crate::soc::amr::{AmrTask, IntPrecision};
        let amr = |crit| {
            Scenario::new("f", IsolationPolicy::PrivatePaths).with_task(McTask::new(
                "amr",
                crit,
                Workload::AmrMatMul {
                    precision: IntPrecision::Int8,
                    m: 64,
                    k: 64,
                    n: 64,
                    tile: 16,
                },
            ))
        };
        let plan = FaultPlan::new(3).with_amr_rate(1.0).with_k(2);
        // Safety -> DLM lockstep: the k-term is k x (HFR + tile window).
        let s = amr(Criticality::Safety).with_faults(plan);
        let b = analyze(&s);
        let tb = b.bound_for("amr");
        let window = AmrCluster::tile_compute_bound(
            &AmrTask {
                precision: IntPrecision::Int8,
                m: 64,
                k: 64,
                n: 64,
                tile: 16,
                src_base: 0,
                dst_base: 0,
                part_id: 0,
            },
            AmrMode::Dlm,
            1.0,
        );
        assert_eq!(
            tb.fault_bound,
            CostSplit::sys(2 * (HFR_RESTORE_CYCLES + window))
        );
        assert_eq!(
            tb.completion_cycles(None).unwrap(),
            tb.nominal_completion_cycles(None).unwrap() + tb.fault_bound.system
        );
        // Hard -> INDIP: faults are silent, no time penalty, no term.
        let indip = analyze(&amr(Criticality::Hard).with_faults(plan));
        assert_eq!(indip.bound_for("amr").fault_bound, CostSplit::ZERO);
        // k = 0 (and no plan at all) are bit-identical.
        let k0 = analyze(&amr(Criticality::Safety).with_faults(FaultPlan::new(3)));
        let none = analyze(&amr(Criticality::Safety));
        assert_eq!(k0, none);
    }
}

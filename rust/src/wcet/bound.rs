//! The compositional bound computation (see the module docs in
//! [`crate::wcet`] for the model). Transliterated 1:1 from the
//! empirically-validated prototype: every formula here was checked for
//! soundness (`measured <= bound`) against 1200 randomized mixes and
//! the fig6a/fig6b grids, and for tightness (`bound <= 2x measured`) on
//! the TSU-regulated rows.

use crate::coordinator::Scenario;
use crate::soc::axi::xbar::Crossbar;
use crate::soc::axi::{Target, BEAT_BYTES};
use crate::soc::clock::{ClockTree, Cycle};
use crate::soc::mem::dcspm::Dcspm;
use crate::soc::mem::hyperram;
use crate::soc::mem::peripheral::Peripheral;
use crate::soc::mem::HyperRamTiming;

use super::model::{models_of, InitiatorModel, StreamModel, TaskShape};

/// Pipeline edges budget per transaction: issue, grant, service start
/// and response delivery each cost at most one cycle.
pub const EDGES: Cycle = 4;
/// DPLLC / L1 line size (bytes) — constant across the Carfield models
/// (asserted against `DpllcConfig::carfield()` in [`analyze`]).
const LINE_BYTES: u64 = 64;
/// Busy-window divergence cap: beyond this the fixed point will not
/// converge and the structural bound is used instead.
const WINDOW_CAP: f64 = 1e12;

/// The shared resource a bound is dominated by (feasibility reports name
/// it so the coordinator knows which knob to turn).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resource {
    /// The single HyperBUS channel behind the DPLLC.
    HyperramChannel,
    /// A DCSPM subordinate port (or cross-port bank conflicts).
    DcspmPort,
    /// The constant-latency peripheral region.
    Peripheral,
    /// The shared W channel, held by unbuffered writes.
    WChannel,
    /// The task's own TSU shaping (GBS/TRU/WB fill).
    TsuShaping,
    /// The cluster's own compute pipeline.
    Compute,
    /// An endless stream — no completion bound exists.
    Endless,
}

impl Resource {
    pub fn describe(&self) -> &'static str {
        match self {
            Resource::HyperramChannel => "HyperRAM channel contention",
            Resource::DcspmPort => "DCSPM port contention",
            Resource::Peripheral => "peripheral access latency",
            Resource::WChannel => "W-channel holds by unbuffered writers",
            Resource::TsuShaping => "own TSU shaping",
            Resource::Compute => "compute pipeline",
            Resource::Endless => "endless workload (no completion bound)",
        }
    }
}

/// Bounds for one time-critical task.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskBound {
    pub task: String,
    /// Worst-case latency of a single memory transaction.
    pub mem_bound: Cycle,
    pub mem_binding: Resource,
    /// Worst-case completion time (`None` for endless workloads).
    pub completion_bound: Option<Cycle>,
    pub completion_binding: Resource,
}

impl TaskBound {
    /// Completion bound as wall-clock nanoseconds at an operating
    /// point's clock tree — the DVFS governor's currency. Bounds are
    /// computed in system cycles, so one analysis re-prices in
    /// microseconds at every voltage candidate.
    pub fn completion_ns(&self, clocks: &ClockTree) -> Option<f64> {
        self.completion_bound
            .map(|c| clocks.system.cycles_to_ns(c))
    }

    /// Memory-latency bound in nanoseconds at `clocks`.
    pub fn mem_ns(&self, clocks: &ClockTree) -> f64 {
        clocks.system.cycles_to_ns(self.mem_bound)
    }
}

/// The analysis result for a scenario: one entry per critical task.
#[derive(Debug, Clone, PartialEq)]
pub struct WcetReport {
    pub scenario: String,
    pub policy: String,
    pub bounds: Vec<TaskBound>,
}

impl WcetReport {
    pub fn bound_for(&self, task: &str) -> &TaskBound {
        self.bounds
            .iter()
            .find(|b| b.task == task)
            .unwrap_or_else(|| panic!("no bound for critical task {task}"))
    }
}

/// Analyze a scenario: derive bounds for every time-critical task
/// without simulating. Pure and deterministic — identical output for
/// identical scenarios, regardless of thread count or call order.
pub fn analyze(scenario: &Scenario) -> WcetReport {
    // Tie the engine's geometry constants to the simulator's: if the
    // cache/bus geometry ever drifts, fail loudly (release builds
    // included — `carfield wcet` and admission control must never emit
    // silently unsound bounds).
    assert_eq!(
        crate::soc::mem::dpllc::DpllcConfig::carfield().line_bytes,
        LINE_BYTES,
        "WCET engine geometry drifted from DpllcConfig::carfield()"
    );
    let models = models_of(scenario);
    let timing = HyperRamTiming::carfield();
    let bounds = (0..models.len())
        .filter(|&i| models[i].critical)
        .map(|i| analyze_model(i, &models, &timing))
        .collect();
    WcetReport {
        scenario: scenario.name.clone(),
        policy: scenario.tuning.describe(),
        bounds,
    }
}

fn ceil_div(a: u64, b: u64) -> u64 {
    a.div_ceil(b.max(1))
}

/// Lines a fragment of `beats` beats touches (streams are line-aligned).
fn lines_of_fragment(beats: u32) -> u64 {
    ceil_div(beats as u64 * BEAT_BYTES, LINE_BYTES).max(1)
}

/// Any stream in the scenario writing the HyperRAM space can leave dirty
/// LLC lines, so every fill may additionally drain a victim.
fn dirty_possible(models: &[InitiatorModel]) -> bool {
    models
        .iter()
        .any(|m| m.streams.iter().any(|s| s.write && s.target == Target::Hyperram))
}

fn banks_overlap(a: Option<u64>, b: Option<u64>) -> bool {
    match (a, b) {
        (None, _) | (_, None) => true, // interleaved spans every bank
        (Some(x), Some(y)) => x == y,
    }
}

/// Can a stream on the *other* DCSPM port steal beat slots from `s`
/// through bank conflicts?
fn stream_conflict(models: &[InitiatorModel], owner: usize, s: &StreamModel) -> bool {
    if s.target != Target::Dcspm {
        return false;
    }
    let port = Dcspm::port_of_addr(s.addr);
    let banks = Dcspm::bank_half_of_addr(s.addr);
    models.iter().enumerate().any(|(i, m)| {
        i != owner
            && m.streams.iter().any(|o| {
                o.target == Target::Dcspm
                    && Dcspm::port_of_addr(o.addr) != port
                    && banks_overlap(banks, Dcspm::bank_half_of_addr(o.addr))
            })
    })
}

/// Worst service time of one shaped fragment of initiator `owner`'s
/// stream `s`.
fn fragment_cost(
    models: &[InitiatorModel],
    owner: usize,
    s: &StreamModel,
    timing: &HyperRamTiming,
    dirty: bool,
) -> Cycle {
    let frag = models[owner].tsu.fragment_beats(s.beats);
    match s.target {
        Target::Hyperram => timing.worst_lines_cost(lines_of_fragment(frag), LINE_BYTES, dirty),
        Target::Dcspm => Dcspm::worst_burst_cycles(frag, stream_conflict(models, owner, s)),
        Target::Peripheral => Peripheral::new(Peripheral::DEFAULT_LATENCY).worst_burst_cycles(frag),
    }
}

/// Worst shaping delay of the task's own TSU for one logical burst.
fn own_tsu_delay(me: &InitiatorModel, s: &StreamModel) -> Cycle {
    let tsu = &me.tsu;
    let mut d: Cycle = 0;
    if s.write && tsu.wb_enable {
        d += if s.beats > tsu.wb_capacity_beats {
            s.beats as Cycle
        } else {
            1
        };
    }
    if tsu.is_tru_regulated() {
        let frag = tsu.fragment_beats(s.beats);
        let n_frags = ceil_div(s.beats as u64, frag as u64);
        let per_period = ((tsu.tru_budget_beats / frag).max(1)) as u64;
        d += ceil_div(n_frags, per_period) * tsu.tru_period;
    }
    d
}

/// Per-stream structural bound components.
struct StreamBound {
    total: Cycle,
    own: Cycle,
    w_term: Cycle,
    endless: bool,
}

fn analyze_model(my_idx: usize, models: &[InitiatorModel], timing: &HyperRamTiming) -> TaskBound {
    let me = &models[my_idx];
    let dirty = dirty_possible(models);

    // W-channel holds: worst unbuffered-write fragment anywhere else and
    // the total back-to-back chain those writers can sustain.
    let mut w_frag: u32 = 0;
    let mut w_chain: u64 = 0;
    for (i, m) in models.iter().enumerate() {
        if i == my_idx {
            continue;
        }
        let mut writes = false;
        for s in &m.streams {
            if s.write && s.unbuffered_write {
                w_frag = w_frag.max(m.tsu.fragment_beats(s.beats));
                writes = true;
            }
        }
        if writes {
            w_chain += m.write_chain_cap;
        }
    }

    let mut per_stream: Vec<StreamBound> = Vec::new();
    let mut mem_bound: Cycle = 0;
    let mut mem_binding = Resource::HyperramChannel;
    for s in &me.streams {
        let own_frag = me.tsu.fragment_beats(s.beats);
        let n_frags = ceil_div(s.beats as u64, own_frag as u64);
        let own = n_frags * fragment_cost(models, my_idx, s, timing, dirty);
        let own_resource = match s.target {
            Target::Hyperram => Resource::HyperramChannel,
            Target::Dcspm => Resource::DcspmPort,
            Target::Peripheral => Resource::Peripheral,
        };
        let queue = match s.target {
            Target::Hyperram => hyperram::QUEUE_DEPTH,
            _ => 0,
        };
        // Competing streams: same target, and for the DCSPM the same
        // subordinate port (per-lane arbitration).
        let my_port = Dcspm::port_of_addr(s.addr);
        let competitors: Vec<(usize, &StreamModel)> = models
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != my_idx)
            .flat_map(|(i, m)| m.streams.iter().map(move |c| (i, c)))
            .filter(|&(_, c)| {
                c.target == s.target
                    && (s.target != Target::Dcspm || Dcspm::port_of_addr(c.addr) == my_port)
            })
            .collect();
        let n_comp_inits = {
            let mut ids: Vec<usize> = competitors.iter().map(|&(i, _)| i).collect();
            ids.sort_unstable();
            ids.dedup();
            ids.len()
        };
        let ahead = Crossbar::worst_bursts_ahead(n_comp_inits, queue);
        let worst_comp = competitors
            .iter()
            .map(|&(i, c)| fragment_cost(models, i, c, timing, dirty))
            .max()
            .unwrap_or(0);
        // Every own fragment can wait out a full arbitration round; each
        // serviced burst ahead may additionally be preceded by one
        // W-channel hold, plus each writer's provable back-to-back chain.
        let interference = n_frags * ahead * worst_comp;
        let w_term = if w_frag > 0 {
            (ahead + 1 + w_chain) * w_frag as Cycle
        } else {
            0
        };
        let tsu_d = own_tsu_delay(me, s);
        let total = tsu_d + interference + w_term + own + EDGES;
        if total > mem_bound {
            mem_bound = total;
            mem_binding = if interference >= own.max(w_term).max(tsu_d) {
                own_resource
            } else if w_term > own.max(tsu_d) {
                Resource::WChannel
            } else if tsu_d > own {
                Resource::TsuShaping
            } else {
                own_resource
            };
        }
        per_stream.push(StreamBound {
            total,
            own,
            w_term,
            endless: s.count.is_none(),
        });
    }

    let (completion, completion_binding) =
        completion_of(my_idx, models, &per_stream, timing, dirty, w_frag, mem_binding);
    TaskBound {
        task: me.name.clone(),
        mem_bound,
        mem_binding,
        completion_bound: completion,
        completion_binding,
    }
}

/// Are all competitors on `target` TRU-regulated (bounded arrival)?
fn competitors_regulated(models: &[InitiatorModel], my_idx: usize, target: Target) -> bool {
    models.iter().enumerate().all(|(i, m)| {
        i == my_idx
            || !m.streams.iter().any(|s| s.target == target)
            || m.tsu.is_tru_regulated()
    })
}

/// Worst service time competitors' arrivals (TRU curves) plus carried-in
/// backlog can consume on `target` within `window` cycles. Only called
/// when every competitor on `target` is regulated.
fn window_interference(
    models: &[InitiatorModel],
    my_idx: usize,
    target: Target,
    window: f64,
    timing: &HyperRamTiming,
    dirty: bool,
) -> f64 {
    let mut total = 0.0;
    for (i, m) in models.iter().enumerate() {
        if i == my_idx {
            continue;
        }
        let streams: Vec<&StreamModel> =
            m.streams.iter().filter(|s| s.target == target).collect();
        if streams.is_empty() {
            continue;
        }
        let tsu = &m.tsu;
        let frag = streams
            .iter()
            .map(|s| tsu.fragment_beats(s.beats))
            .max()
            .unwrap();
        let (per_period_frags, per_period_beats) = if frag >= tsu.tru_budget_beats {
            (1u64, frag) // an oversize fragment passes once per period
        } else {
            let full = (tsu.tru_budget_beats / frag) as u64;
            // A burst whose length is not a multiple of the GBS size
            // ends in a sub-fragment tail that can squeeze through
            // leftover budget — one extra service activation per burst
            // startable in the period (plus one straddling its start).
            let min_beats = streams.iter().map(|s| s.beats.max(1)).min().unwrap();
            let has_tail = streams
                .iter()
                .any(|s| s.beats % tsu.fragment_beats(s.beats) != 0);
            let tails = if has_tail {
                (tsu.tru_budget_beats as u64).div_ceil(min_beats as u64) + 1
            } else {
                0
            };
            (full + tails, tsu.tru_budget_beats)
        };
        // Periods derive from the TSU's own arrival curve (which covers
        // windows straddling a partial period at both ends).
        let max_beats = tsu
            .max_beats_in_window(window as Cycle)
            .expect("caller guarantees regulated competitors");
        let periods = (max_beats / tsu.tru_budget_beats as u64) as f64;
        let carry_frags: u64 = m.inflight_cap
            * streams
                .iter()
                .map(|s| ceil_div(s.beats as u64, tsu.fragment_beats(s.beats) as u64))
                .max()
                .unwrap();
        if target == Target::Hyperram {
            let lines = per_period_frags * lines_of_fragment(frag);
            total += periods * timing.worst_lines_cost(lines, LINE_BYTES, dirty) as f64;
            total += timing.worst_lines_cost(
                carry_frags * lines_of_fragment(frag),
                LINE_BYTES,
                dirty,
            ) as f64;
        } else {
            let conflict = streams.iter().any(|s| stream_conflict(models, i, s));
            let per = Dcspm::worst_burst_cycles(per_period_beats, conflict) + per_period_frags;
            total += periods * per as f64;
            total += carry_frags as f64 * Dcspm::worst_burst_cycles(frag, conflict) as f64;
        }
    }
    total
}

fn completion_of(
    my_idx: usize,
    models: &[InitiatorModel],
    per_stream: &[StreamBound],
    timing: &HyperRamTiming,
    dirty: bool,
    w_frag: u32,
    mem_binding: Resource,
) -> (Option<Cycle>, Resource) {
    let me = &models[my_idx];
    if per_stream.iter().any(|s| s.endless) {
        return (None, Resource::Endless);
    }
    // ---- structural path (always finite, always sound) ----
    let (structural, structural_binding, base, target) = match me.shape {
        TaskShape::HostTct { think, accesses } => {
            let structural = accesses * (think + 2 + per_stream[0].total);
            let has_comp = models.iter().enumerate().any(|(i, m)| {
                i != my_idx && m.streams.iter().any(|s| s.target == Target::Hyperram)
            });
            // Competitor interleaving destroys the walker's row
            // locality: charge one extra row open per access.
            let reopen = if has_comp {
                timing.t_row_miss - timing.t_row_hit
            } else {
                0
            };
            let base = accesses
                * (think + EDGES + timing.worst_lines_cost(1, LINE_BYTES, dirty) + reopen);
            (structural, mem_binding, base, Target::Hyperram)
        }
        TaskShape::Cluster {
            tiles,
            compute_per_tile,
        } => {
            let per_tile: Cycle = per_stream.iter().map(|s| s.total).sum();
            let structural = tiles * (per_tile + compute_per_tile + 4);
            let binding = if compute_per_tile + 4 > per_tile {
                Resource::Compute
            } else {
                mem_binding
            };
            let own: Cycle =
                per_stream.iter().map(|s| s.own + s.w_term).sum::<Cycle>() + 2 * EDGES;
            let base = tiles * (own + compute_per_tile + 4);
            (structural, binding, base, Target::Dcspm)
        }
        TaskShape::Dma { chunks } => {
            let chunks = chunks.unwrap_or(0); // endless handled above
            let structural = chunks * (per_stream.iter().map(|s| s.total).sum::<Cycle>() + 2);
            return (Some(structural), mem_binding);
        }
    };
    // ---- busy-window path (tighter; needs regulated competitors and no
    // unbuffered writers — W-channel holds stall every grant and are not
    // captured by per-target arrival curves) ----
    let mut best = structural;
    let mut binding = structural_binding;
    if competitors_regulated(models, my_idx, target) && w_frag == 0 {
        let base_f = base as f64;
        let mut t = base_f;
        let mut converged = false;
        for _ in 0..200 {
            let nxt = base_f + window_interference(models, my_idx, target, t, timing, dirty);
            if nxt > WINDOW_CAP {
                break;
            }
            if nxt - t <= 1.0 {
                t = nxt;
                converged = true;
                break;
            }
            t = nxt;
        }
        if converged && (t.ceil() as Cycle) < best {
            best = t.ceil() as Cycle;
            binding = match target {
                Target::Hyperram => Resource::HyperramChannel,
                _ => Resource::DcspmPort,
            };
        }
    }
    (Some(best), binding)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::Criticality;
    use crate::coordinator::{IsolationPolicy, McTask, Workload};
    use crate::soc::dma::DmaJob;
    use crate::soc::hostd::TctSpec;

    fn fig6a_scenario(policy: IsolationPolicy) -> Scenario {
        Scenario::new("s", policy)
            .with_task(McTask::new(
                "tct",
                Criticality::Hard,
                Workload::HostTct(TctSpec::fig6a()),
            ))
            .with_task(McTask::new(
                "dma",
                Criticality::BestEffort,
                Workload::DmaCopy(DmaJob::interferer()),
            ))
    }

    #[test]
    fn isolated_tct_bound_is_own_service_plus_edges() {
        let s = Scenario::new("iso", IsolationPolicy::NoIsolation).with_task(McTask::new(
            "tct",
            Criticality::Hard,
            Workload::HostTct(TctSpec::fig6a()),
        ));
        let r = analyze(&s);
        let b = r.bound_for("tct");
        // One 64B line: row miss (24) + 8 beats x 2 cycles + 4 edges.
        assert_eq!(b.mem_bound, 44);
        assert!(b.completion_bound.is_some());
    }

    #[test]
    fn regulated_interference_composes_queue_and_arbitration() {
        let r = analyze(&fig6a_scenario(IsolationPolicy::TsuRegulation));
        let b = r.bound_for("tct");
        // own 40 + edges 4 + (1 in service + 4 queue + 1 RR turn) x 40.
        assert_eq!(b.mem_bound, 284);
        assert_eq!(b.mem_binding, Resource::HyperramChannel);
        // The busy window converges: the regulated DMA leaves headroom.
        let c = b.completion_bound.expect("finite");
        assert!(c < 2_000_000, "busy window diverged: {c}");
    }

    #[test]
    fn unregulated_interference_is_finite_but_far_larger() {
        let reg = analyze(&fig6a_scenario(IsolationPolicy::TsuRegulation));
        let unreg = analyze(&fig6a_scenario(IsolationPolicy::NoIsolation));
        let b_reg = reg.bound_for("tct");
        let b_unreg = unreg.bound_for("tct");
        // Unsplit 256-beat bursts + W-channel holds blow the bound up by
        // over an order of magnitude — the Fig. 6a story, analytically.
        assert!(b_unreg.mem_bound > 10 * b_reg.mem_bound);
        assert!(
            b_unreg.completion_bound.unwrap() > 10 * b_reg.completion_bound.unwrap(),
            "unreg {:?} vs reg {:?}",
            b_unreg.completion_bound,
            b_reg.completion_bound
        );
    }

    #[test]
    fn endless_critical_task_has_no_completion_bound() {
        let job = DmaJob::interferer();
        let s = Scenario::new("endless", IsolationPolicy::TsuRegulation).with_task(
            McTask::new("dma", Criticality::Hard, Workload::DmaCopy(job)),
        );
        let r = analyze(&s);
        let b = r.bound_for("dma");
        assert_eq!(b.completion_bound, None);
        assert_eq!(b.completion_binding, Resource::Endless);
    }

    #[test]
    fn bounds_reprice_in_nanoseconds_per_operating_point() {
        use crate::power::OperatingPoint;
        let s = fig6a_scenario(IsolationPolicy::TsuRegulation);
        let r = analyze(&s);
        let b = r.bound_for("tct");
        let fast = OperatingPoint::max_perf().clock_tree();
        let slow = OperatingPoint::uniform(0.6).unwrap().clock_tree();
        let c = b.completion_bound.unwrap() as f64;
        // 1GHz system clock: 1 cycle = 1ns, exactly.
        assert_eq!(b.completion_ns(&fast), Some(c));
        let slow_ns = b.completion_ns(&slow).unwrap();
        assert!((slow_ns - c * 1e3 / 350.0).abs() < 1e-6);
        assert!(b.mem_ns(&fast) < b.mem_ns(&slow));
    }

    #[test]
    fn analyze_is_deterministic() {
        let s = fig6a_scenario(IsolationPolicy::TsuRegulation);
        assert_eq!(analyze(&s), analyze(&s));
    }

    #[test]
    fn resource_descriptions_cover_all_variants() {
        for r in [
            Resource::HyperramChannel,
            Resource::DcspmPort,
            Resource::Peripheral,
            Resource::WChannel,
            Resource::TsuShaping,
            Resource::Compute,
            Resource::Endless,
        ] {
            assert!(!r.describe().is_empty());
        }
    }
}

//! Scenario -> analyzable traffic model.
//!
//! Mirrors the coordinator's placement (`Scheduler::execute`): one
//! initiator slot per task in declaration order, TSU programs and L2
//! staging bases from the scenario's [`SocTuning`] point (arrival curves
//! and service bounds therefore follow *any* knob setting, not just the
//! legacy policy ladder). Each initiator becomes a set of
//! [`StreamModel`]s (the bursts it puts on the bus) plus a [`TaskShape`]
//! describing how transactions compose into a completion time.
//!
//! [`SocTuning`]: crate::coordinator::SocTuning

use crate::coordinator::task::Workload;
use crate::coordinator::{McTask, Scenario};
use crate::soc::amr::{AmrCluster, AmrMode, AmrTask};
use crate::soc::axi::{Target, BEAT_BYTES};
use crate::soc::clock::{Cycle, Domain};
use crate::soc::tiles::{TileStreamer, CLUSTER_BUFFER_DEPTH};
use crate::soc::tsu::TsuConfig;
use crate::soc::vector::{VectorCluster, VectorTask, VectorWork};

/// One traffic stream an initiator puts on the fabric.
#[derive(Debug, Clone)]
pub struct StreamModel {
    pub target: Target,
    /// Logical burst size in beats (pre-GBS).
    pub beats: u32,
    pub write: bool,
    /// Representative address (decides DCSPM port / bank half).
    pub addr: u64,
    /// Logical bursts over the task's lifetime; `None` = endless.
    pub count: Option<u64>,
    /// Write issued without a write buffer: holds the shared W channel
    /// while its data dribbles through.
    pub unbuffered_write: bool,
}

/// How an initiator's transactions compose into a completion time.
#[derive(Debug, Clone)]
pub enum TaskShape {
    /// Blocking strided walker: `accesses` line fills with `think`
    /// cycles of address generation between them (every access assumed
    /// an L1 + LLC miss — the cache-cold worst case).
    HostTct { think: Cycle, accesses: u64 },
    /// Double-buffered tile pipeline: fetch + compute + writeback per
    /// tile, fully serialized in the worst case.
    Cluster { tiles: u64, compute_per_tile: Cycle },
    /// Pipelined chunk copy (`None` chunks = endless interferer).
    Dma { chunks: Option<u64> },
}

/// The analyzable model of one bus initiator.
#[derive(Debug, Clone)]
pub struct InitiatorModel {
    pub name: String,
    pub critical: bool,
    pub tsu: TsuConfig,
    /// Max logical bursts kept in flight simultaneously.
    pub inflight_cap: u64,
    /// Max back-to-back unbuffered writes without an intervening read of
    /// its own (bounds W-channel hold chains; see
    /// `TileStreamer::worst_write_chain`).
    pub write_chain_cap: u64,
    /// Runs in an AMR lockstep mode (DLM/TLM): mismatches are *detected*
    /// and recovered — the initiator the k-fault re-execution term
    /// applies to. INDIP and non-AMR initiators take no timed fault
    /// penalty (INDIP corruptions are silent).
    pub lockstep: bool,
    pub shape: TaskShape,
    pub streams: Vec<StreamModel>,
}

/// Derive the per-initiator traffic models for a scenario — one per
/// task in declaration order, plus the fault plan's ECC scrub engine
/// (when enabled) as a trailing regulated background initiator,
/// mirroring `Scheduler::execute`'s attach order exactly.
pub fn models_of(scenario: &Scenario) -> Vec<InitiatorModel> {
    let mut models: Vec<InitiatorModel> = scenario
        .tasks
        .iter()
        .enumerate()
        .map(|(slot, task)| model_of(scenario, slot, task))
        .collect();
    if let Some(sc) = scenario.fault_plan().and_then(|p| p.scrub) {
        models.push(scrub_model(sc));
    }
    models
}

/// The ECC patrol scrubber as an interference source: an endless,
/// TRU-regulated HyperRAM reader. Its arrival curve
/// (`TsuConfig::max_beats_in_window`) feeds the busy-window fixed point
/// like any other regulated competitor, and its mere presence charges
/// the TCT walker's row-reopen penalty — background scrub traffic
/// destroys row locality just like a DMA does.
fn scrub_model(sc: crate::coordinator::ScrubConfig) -> InitiatorModel {
    InitiatorModel {
        name: "ecc-scrub".to_string(),
        critical: false,
        tsu: TsuConfig::regulated(sc.beats, sc.beats, sc.period),
        inflight_cap: 1,
        write_chain_cap: 0,
        lockstep: false,
        shape: TaskShape::Dma { chunks: None },
        streams: vec![StreamModel {
            target: Target::Hyperram,
            beats: sc.beats,
            write: false,
            addr: 0x40_0000,
            count: None,
            unbuffered_write: false,
        }],
    }
}

fn model_of(scenario: &Scenario, slot: usize, task: &McTask) -> InitiatorModel {
    let tuning = scenario.tuning;
    let critical = task.criticality.is_time_critical();
    let tsu = tuning.tsu_config(critical);
    let wb = tsu.wb_enable;
    match &task.workload {
        Workload::HostTct(spec) => {
            let accesses = spec.accesses as u64 * spec.iterations as u64;
            InitiatorModel {
                name: task.name.clone(),
                critical,
                tsu,
                inflight_cap: 1,
                write_chain_cap: 0,
                lockstep: false,
                shape: TaskShape::HostTct {
                    think: spec.think_cycles,
                    accesses,
                },
                streams: vec![StreamModel {
                    target: Target::Hyperram,
                    beats: 8, // one 64B line fill
                    write: false,
                    addr: spec.base,
                    count: Some(accesses),
                    unbuffered_write: false,
                }],
            }
        }
        Workload::DmaCopy(job) => {
            let chunks = if job.looping {
                None
            } else {
                Some(job.bytes.div_ceil(job.chunk_beats as u64 * BEAT_BYTES))
            };
            let mut streams = vec![StreamModel {
                target: job.src,
                beats: job.chunk_beats,
                write: false,
                addr: job.src_addr,
                count: chunks,
                unbuffered_write: false,
            }];
            if let Some(dst) = job.dst {
                streams.push(StreamModel {
                    target: dst,
                    beats: job.chunk_beats,
                    write: true,
                    addr: job.dst_addr,
                    count: chunks,
                    unbuffered_write: !wb,
                });
            }
            InitiatorModel {
                name: task.name.clone(),
                critical,
                tsu,
                inflight_cap: job.outstanding as u64,
                write_chain_cap: job.outstanding as u64,
                lockstep: false,
                shape: TaskShape::Dma { chunks },
                streams,
            }
        }
        Workload::AmrMatMul {
            precision,
            m,
            k,
            n,
            tile,
        } => {
            let amr = AmrTask {
                precision: *precision,
                m: *m,
                k: *k,
                n: *n,
                tile: *tile,
                src_base: tuning.l2_base(slot),
                dst_base: tuning.l2_base(slot) + (1 << 17),
                part_id: 0,
            };
            let tiles = amr.tiles() as u64;
            // Compute time follows the AMR PLL ratio at the scenario's
            // operating point — the exact duration the cluster FSM uses,
            // so bound and simulator can never disagree on it.
            let compute = AmrCluster::tile_compute_bound(
                &amr,
                task.required_amr_mode(),
                scenario.freq_ratio(Domain::Amr),
            );
            let mut m = cluster_model(
                task,
                critical,
                tsu,
                tiles,
                compute,
                amr.in_beats_per_tile(),
                amr.out_beats_per_tile(),
                amr.src_base,
                amr.dst_base,
            );
            m.lockstep = task.required_amr_mode() != AmrMode::Indip;
            m
        }
        Workload::VectorMatMul { format, m, k, n, tile } => {
            let vt = VectorTask {
                format: *format,
                work: VectorWork::MatMul {
                    m: *m,
                    k: *k,
                    n: *n,
                    tile: *tile,
                },
                src_base: tuning.l2_base(slot),
                dst_base: tuning.l2_base(slot) + (1 << 17),
                part_id: 0,
            };
            vector_model(task, critical, tsu, &vt, scenario.freq_ratio(Domain::Vector))
        }
        Workload::VectorFft { format, n, batch } => {
            let vt = VectorTask {
                format: *format,
                work: VectorWork::Fft { n: *n, batch: *batch },
                src_base: tuning.l2_base(slot),
                dst_base: tuning.l2_base(slot) + (1 << 17),
                part_id: 0,
            };
            vector_model(task, critical, tsu, &vt, scenario.freq_ratio(Domain::Vector))
        }
    }
}

fn vector_model(
    task: &McTask,
    critical: bool,
    tsu: TsuConfig,
    vt: &VectorTask,
    freq_ratio: f64,
) -> InitiatorModel {
    let (tiles, _, in_beats, out_beats) = vt.tiling();
    let compute = VectorCluster::tile_compute_bound(vt, freq_ratio);
    cluster_model(
        task,
        critical,
        tsu,
        tiles as u64,
        compute,
        in_beats,
        out_beats,
        vt.src_base,
        vt.dst_base,
    )
}

#[allow(clippy::too_many_arguments)]
fn cluster_model(
    task: &McTask,
    critical: bool,
    tsu: TsuConfig,
    tiles: u64,
    compute_per_tile: Cycle,
    in_beats: u32,
    out_beats: u32,
    src_base: u64,
    dst_base: u64,
) -> InitiatorModel {
    let wb = tsu.wb_enable;
    let mut streams = vec![StreamModel {
        target: Target::Dcspm,
        beats: in_beats,
        write: false,
        addr: src_base,
        count: Some(tiles),
        unbuffered_write: false,
    }];
    if out_beats > 0 {
        streams.push(StreamModel {
            target: Target::Dcspm,
            beats: out_beats,
            write: true,
            addr: dst_base,
            count: Some(tiles),
            unbuffered_write: !wb,
        });
    }
    InitiatorModel {
        name: task.name.clone(),
        critical,
        tsu,
        inflight_cap: 1,
        write_chain_cap: TileStreamer::worst_write_chain(CLUSTER_BUFFER_DEPTH),
        lockstep: false,
        shape: TaskShape::Cluster {
            tiles,
            compute_per_tile,
        },
        streams,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::Criticality;
    use crate::coordinator::IsolationPolicy;
    use crate::soc::dma::DmaJob;
    use crate::soc::hostd::TctSpec;

    #[test]
    fn tct_model_counts_total_accesses() {
        let s = Scenario::new("m", IsolationPolicy::TsuRegulation).with_task(McTask::new(
            "tct",
            Criticality::Hard,
            Workload::HostTct(TctSpec::fig6a()),
        ));
        let m = models_of(&s);
        assert_eq!(m.len(), 1);
        assert!(m[0].critical);
        assert_eq!(m[0].streams.len(), 1);
        assert_eq!(m[0].streams[0].count, Some(768 * 8));
        assert!(!m[0].tsu.is_tru_regulated(), "TCTs are never throttled");
    }

    #[test]
    fn looping_dma_is_endless_and_regulated_under_tsu_policy() {
        let s = Scenario::new("m", IsolationPolicy::TsuRegulation).with_task(McTask::new(
            "dma",
            Criticality::BestEffort,
            Workload::DmaCopy(DmaJob::interferer()),
        ));
        let m = models_of(&s);
        assert!(m[0].tsu.is_tru_regulated());
        assert_eq!(m[0].streams.len(), 2, "read + write sides");
        assert!(m[0].streams.iter().all(|st| st.count.is_none()));
        assert!(
            !m[0].streams[1].unbuffered_write,
            "regulated profile write-buffers the DMA"
        );
    }

    #[test]
    fn cluster_compute_bound_follows_the_op_point_ratio() {
        use crate::power::OperatingPoint;
        use crate::soc::amr::IntPrecision;
        let mk = || {
            Scenario::new("m", IsolationPolicy::PrivatePaths).with_task(McTask::new(
                "amr",
                Criticality::Hard,
                Workload::AmrMatMul {
                    precision: IntPrecision::Int8,
                    m: 64,
                    k: 64,
                    n: 64,
                    tile: 16,
                },
            ))
        };
        let compute_of = |m: &[InitiatorModel]| match m[0].shape {
            TaskShape::Cluster {
                compute_per_tile, ..
            } => compute_per_tile,
            _ => panic!("cluster shape expected"),
        };
        let lockstep = compute_of(&models_of(&mk()));
        // max_perf runs the AMR PLL at 0.9x the system clock: the
        // compute bound stretches exactly as the simulator's FSM does.
        let scaled = compute_of(&models_of(&mk().with_op_point(OperatingPoint::max_perf())));
        assert!(
            scaled > lockstep,
            "0.9x AMR PLL must stretch the compute bound: {lockstep} -> {scaled}"
        );
    }

    #[test]
    fn scrub_plan_appends_a_regulated_endless_reader() {
        use crate::coordinator::{FaultPlan, ScrubConfig};
        let base = Scenario::new("m", IsolationPolicy::TsuRegulation).with_task(McTask::new(
            "tct",
            Criticality::Hard,
            Workload::HostTct(TctSpec::fig6a()),
        ));
        assert_eq!(models_of(&base).len(), 1);
        let faulted = base.with_faults(FaultPlan::new(5).with_scrub(ScrubConfig::carfield()));
        let m = models_of(&faulted);
        assert_eq!(m.len(), 2, "scrub trails the task initiators");
        let scrub = &m[1];
        assert_eq!(scrub.name, "ecc-scrub");
        assert!(!scrub.critical && !scrub.lockstep);
        assert!(scrub.tsu.is_tru_regulated(), "scrub must stay analyzable");
        assert!(scrub.streams[0].count.is_none(), "patrol never drains");
        // Lockstep marking: Safety AMR is DLM (lockstep), Hard is INDIP.
        use crate::soc::amr::IntPrecision;
        let amr = |crit| {
            let s = Scenario::new("m", IsolationPolicy::PrivatePaths).with_task(McTask::new(
                "amr",
                crit,
                Workload::AmrMatMul {
                    precision: IntPrecision::Int8,
                    m: 32,
                    k: 32,
                    n: 32,
                    tile: 8,
                },
            ));
            models_of(&s)[0].lockstep
        };
        assert!(amr(Criticality::Safety));
        assert!(!amr(Criticality::Hard));
    }

    #[test]
    fn unregulated_dma_write_holds_w_channel() {
        let s = Scenario::new("m", IsolationPolicy::NoIsolation).with_task(McTask::new(
            "dma",
            Criticality::BestEffort,
            Workload::DmaCopy(DmaJob::interferer()),
        ));
        let m = models_of(&s);
        assert!(m[0].streams[1].unbuffered_write);
        assert_eq!(m[0].write_chain_cap, 4);
    }
}

//! Zero-cost-when-disabled interference tracing (PR 7, observability).
//!
//! The simulator's hot path is instrumented at every shared-resource
//! decision point — TSU throttle releases, crossbar grants and W-channel
//! holds, HyperRAM line fills and fault retries, DCSPM cross-port bank
//! conflicts, AMR fault recoveries, and completion deliveries — and each
//! site records a [`TraceEvent`] *only* when its component has been armed
//! with an event buffer. Disabled tracing costs one `Option::is_some`
//! branch per site and leaves every `ScenarioReport` bit-identical
//! (asserted by `tests/trace_determinism.rs` and gated in the
//! `perf_hotpath` bench).
//!
//! Timestamps are **per-domain cycles**: system-domain events carry the
//! master grid directly, uncore-domain events (HyperRAM line engine)
//! carry their local grid and cross into system time through the same
//! exact [`RateConverter`] the crossbar uses — so a decoupled uncore
//! never smears event order.
//!
//! Three consumers:
//! - [`InterferenceLedger`]: per-task measured cycles keyed by the WCET
//!   engine's [`Resource`] axis, summing exactly to the task's observed
//!   makespan — the measured column of the *bound gap attribution*
//!   table printed by `carfield trace`.
//! - [`to_jsonl`]: one structured JSON object per event, for ad-hoc
//!   scripting.
//! - [`to_perfetto`]: Chrome `trace_event` JSON (open in Perfetto /
//!   `chrome://tracing`): one track per initiator, one per target lane,
//!   fault recoveries and bank conflicts as instant events.
//!
//! Determinism: events are only recorded in *stepped* cycles (every hook
//! site sits on a path that `next_event` pins — see the per-component
//! notes at the hook sites), so naive and event-driven runs produce
//! bit-identical streams, and the per-scenario capture makes sweep
//! results independent of `CARFIELD_THREADS`.

use crate::soc::axi::{InitiatorId, Target};
use crate::soc::clock::{Cycle, Domain, RateConverter};
use crate::wcet::Resource;

pub mod service;
pub mod workingset;

pub use service::{ServiceCounters, ServiceSnapshot, SERVICE_RESOURCES};
pub use workingset::{
    profiles_of, shape_key, CertEntry, CertificateLibrary, FitPoint, PartitionCertificate,
    ReuseSummary, WorkingSetProfile, CERT_WARM_THRESHOLD_PPM,
};

/// What happened at a hook site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// A TSU released one fragment into the crossbar admission queue
    /// after GBS/WB/TRU shaping (system domain).
    TsuRelease { beats: u32, write: bool },
    /// The crossbar granted a burst to a target lane (system domain).
    Grant { beats: u32, write: bool },
    /// An unbuffered write grant holds the shared W channel, stalling
    /// every other grant. `beats` counts cycles of the *target's* clock
    /// grid (PHY edges for uncore targets, system cycles otherwise);
    /// the event timestamp itself is system-domain.
    WHold { beats: u32 },
    /// The HyperRAM channel serviced one DPLLC line access (uncore-local
    /// timestamp). `retry_cycles` is the injected ECC-retry overhead
    /// folded into `service_cycles`. `line` is the 64B-line-granular
    /// address (`addr / LINE_BYTES`) and `set` the *absolute* DPLLC set
    /// it indexed under the access's partition — computed by the cache
    /// model itself (`Dpllc::set_of`), so the working-set profiler
    /// ([`workingset`]) can never drift from the hardware's partition
    /// arithmetic. Hit-port fast-path bursts emit one `hit: true` event
    /// per line so a capture sees the *full* DPLLC access stream.
    LineFill {
        hit: bool,
        dirty_victim: bool,
        retry_cycles: Cycle,
        service_cycles: Cycle,
        line: u64,
        set: u32,
    },
    /// A DCSPM port lost its turn to a cross-port bank conflict
    /// (system domain).
    BankConflict,
    /// AMR lockstep mismatch recovery: `penalty` stall cycles (HFR
    /// restore or full reboot).
    Recovery { penalty: Cycle, reboot: bool },
    /// A completion was delivered back to the initiator. Carries the
    /// full per-fragment lifecycle so the ledger can decompose latency
    /// without re-matching event streams.
    Delivery {
        beats: u32,
        write: bool,
        last_fragment: bool,
        issued_at: Cycle,
        released_at: Cycle,
        granted_at: Cycle,
    },
}

impl TraceKind {
    /// Stable lowercase name used by both sinks.
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::TsuRelease { .. } => "tsu_release",
            TraceKind::Grant { .. } => "grant",
            TraceKind::WHold { .. } => "w_hold",
            TraceKind::LineFill { .. } => "line_fill",
            TraceKind::BankConflict => "bank_conflict",
            TraceKind::Recovery { .. } => "recovery",
            TraceKind::Delivery { .. } => "delivery",
        }
    }
}

/// One recorded event. `at` is in `domain`-local cycles; use
/// [`TraceCapture::system_ts`] to place it on the master grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub at: Cycle,
    pub domain: Domain,
    pub initiator: InitiatorId,
    pub target: Option<Target>,
    pub lane: u8,
    pub tag: u64,
    pub kind: TraceKind,
}

/// The per-component event sink. `None` (the default everywhere) means
/// tracing is disabled: every hook site guards on `is_some()` before
/// even constructing the event, so the disabled path costs one branch.
/// The `Box` keeps the slot pointer-sized inside hot structs.
pub type TraceBuf = Option<Box<Vec<TraceEvent>>>;

/// A fresh armed buffer.
pub fn armed() -> TraceBuf {
    Some(Box::new(Vec::new()))
}

/// Per-scenario tracing switch, carried on
/// [`Scenario`](crate::coordinator::Scenario) and defaulting to off.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TraceConfig {
    pub enabled: bool,
}

impl TraceConfig {
    pub fn on() -> Self {
        Self { enabled: true }
    }
}

/// Ledger input describing one measured task of the scenario.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LedgerTask {
    pub name: String,
    pub initiator: InitiatorId,
    /// Observed completion time in system cycles.
    pub makespan: Cycle,
    /// Stall cycles spent in fault recovery (AMR HFR / reboot).
    pub recovery_cycles: Cycle,
}

/// Everything one traced scenario run produced: the merged event stream
/// (sorted by system timestamp) plus the task directory the ledger is
/// built from.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceCapture {
    pub scenario: String,
    pub events: Vec<TraceEvent>,
    /// Uncore-grid-to-system-grid converter of the run (identity on the
    /// seed's coupled timebase).
    pub uncore: RateConverter,
    pub tasks: Vec<LedgerTask>,
}

impl TraceCapture {
    pub fn new(scenario: &str, uncore: RateConverter) -> Self {
        Self {
            scenario: scenario.to_string(),
            events: Vec::new(),
            uncore,
            tasks: Vec::new(),
        }
    }

    /// The event's timestamp on the system master grid.
    pub fn system_ts(&self, e: &TraceEvent) -> Cycle {
        match e.domain {
            Domain::Uncore => self.uncore.to_system_edge(e.at),
            _ => e.at,
        }
    }

    /// Stable-sort the stream by system timestamp. Buffers are appended
    /// in a fixed component order before sorting, so equal-timestamp
    /// ordering is deterministic.
    pub fn finish(&mut self) {
        let unc = self.uncore;
        self.events.sort_by_key(|e| match e.domain {
            Domain::Uncore => unc.to_system_edge(e.at),
            _ => e.at,
        });
    }
}

/// Maps a crossbar target to the WCET resource its service is priced
/// under.
pub fn resource_of(t: Target) -> Resource {
    match t {
        Target::Hyperram => Resource::HyperramChannel,
        Target::Dcspm => Resource::DcspmPort,
        Target::Peripheral => Resource::Peripheral,
    }
}

/// One task's measured interference decomposition. `rows` are system
/// cycles per resource and sum exactly to `makespan`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskLedger {
    pub task: String,
    pub initiator: InitiatorId,
    pub makespan: Cycle,
    pub rows: Vec<(Resource, Cycle)>,
}

impl TaskLedger {
    pub fn measured(&self, r: Resource) -> Cycle {
        self.rows
            .iter()
            .find(|(res, _)| *res == r)
            .map(|(_, c)| *c)
            .unwrap_or(0)
    }

    /// The measured column must always re-sum to the makespan — the
    /// ledger's defining invariant.
    pub fn sums_to_makespan(&self) -> bool {
        self.rows.iter().map(|(_, c)| c).sum::<Cycle>() == self.makespan
    }
}

/// Per-task interference ledger of one traced scenario run.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct InterferenceLedger {
    pub tasks: Vec<TaskLedger>,
}

/// Merge intervals and return them sorted and disjoint.
fn merge_intervals(mut iv: Vec<(Cycle, Cycle)>) -> Vec<(Cycle, Cycle)> {
    iv.retain(|(a, b)| b > a);
    iv.sort_unstable();
    let mut out: Vec<(Cycle, Cycle)> = Vec::with_capacity(iv.len());
    for (a, b) in iv {
        match out.last_mut() {
            Some((_, pb)) if a <= *pb => *pb = (*pb).max(b),
            _ => out.push((a, b)),
        }
    }
    out
}

fn union_len(iv: &[(Cycle, Cycle)]) -> Cycle {
    iv.iter().map(|(a, b)| b - a).sum()
}

/// Length of `[a, b)` covered by the merged, sorted interval set.
fn overlap_len(merged: &[(Cycle, Cycle)], a: Cycle, b: Cycle) -> Cycle {
    merged
        .iter()
        .map(|&(x, y)| y.min(b).saturating_sub(x.max(a)))
        .sum()
}

impl InterferenceLedger {
    /// Decompose each task's makespan along the WCET [`Resource`] axis
    /// from the delivery lifecycles in `cap`.
    ///
    /// Per delivered fragment (all timestamps system cycles):
    /// - `released_at - issued_at` → [`Resource::TsuShaping`] (GBS/WB/
    ///   TRU shaping delay);
    /// - the part of `[released_at, granted_at)` covered by W-channel
    ///   holds → [`Resource::WChannel`];
    /// - the rest of `delivered - released_at` (queue wait behind
    ///   competitors + target service + return edges) → the fragment's
    ///   target resource.
    ///
    /// For pipelined initiators the per-fragment spans overlap, so the
    /// raw sums can exceed wall-clock memory-active time. The rows are
    /// shrunk proportionally (largest-remainder on the cumulative sums,
    /// exact integer arithmetic) onto the *union* of the spans; for a
    /// strictly sequential initiator (the Fig. 6a host TCT) the union
    /// equals the raw sum and the scaling is the identity. The remainder
    /// `makespan - union - recovery` is [`Resource::Compute`] (issue
    /// gaps: think time / tile compute), and fault-recovery stalls close
    /// the sum as [`Resource::FaultRecovery`] — so the rows always re-sum
    /// to the makespan exactly.
    pub fn build(cap: &TraceCapture) -> Self {
        // Global W-hold windows: an unbuffered write's W-channel hold
        // stalls every initiator's grants, whoever issued it.
        let holds = merge_intervals(
            cap.events
                .iter()
                .filter_map(|e| match e.kind {
                    // The hold runs on the granted target's clock grid:
                    // `beats` PHY edges for an uncore target (converted
                    // back to the system edge the crossbar unblocks at,
                    // identity when coupled), system cycles otherwise.
                    TraceKind::WHold { beats } => {
                        let end = match e.target {
                            Some(Target::Hyperram) | Some(Target::Peripheral) => cap
                                .uncore
                                .to_system_edge(cap.uncore.local_of(e.at) + beats as Cycle),
                            _ => e.at + beats as Cycle,
                        };
                        Some((e.at, end))
                    }
                    _ => None,
                })
                .collect(),
        );
        let tasks = cap
            .tasks
            .iter()
            .map(|t| Self::build_task(cap, t, &holds))
            .collect();
        Self { tasks }
    }

    fn build_task(cap: &TraceCapture, t: &LedgerTask, holds: &[(Cycle, Cycle)]) -> TaskLedger {
        let mut tsu: u128 = 0;
        let mut wchan: u128 = 0;
        // Fixed resource order keeps output deterministic.
        let targets = [
            Resource::HyperramChannel,
            Resource::DcspmPort,
            Resource::Peripheral,
        ];
        let mut per_target: [u128; 3] = [0; 3];
        let mut spans: Vec<(Cycle, Cycle)> = Vec::new();
        for e in &cap.events {
            if e.initiator != t.initiator {
                continue;
            }
            let TraceKind::Delivery {
                issued_at,
                released_at,
                granted_at,
                ..
            } = e.kind
            else {
                continue;
            };
            let delivered = e.at;
            tsu += (released_at - issued_at) as u128;
            let held = overlap_len(holds, released_at, granted_at);
            wchan += held as u128;
            let rest = (delivered - released_at).saturating_sub(held);
            if let Some(tgt) = e.target {
                let ti = targets
                    .iter()
                    .position(|r| *r == resource_of(tgt))
                    .unwrap();
                per_target[ti] += rest as u128;
            }
            spans.push((issued_at, delivered.min(t.makespan)));
        }
        let active = union_len(&merge_intervals(spans)).min(t.makespan);
        let raw: Vec<(Resource, u128)> = [
            (Resource::TsuShaping, tsu),
            (Resource::WChannel, wchan),
            (targets[0], per_target[0]),
            (targets[1], per_target[1]),
            (targets[2], per_target[2]),
        ]
        .into_iter()
        .collect();
        let raw_total: u128 = raw.iter().map(|(_, c)| c).sum();
        // Shrink the raw (possibly overlapping) attribution onto the
        // wall-clock active window: cumulative floor scaling sums to
        // `active` exactly and is the identity when raw_total == active.
        let mut rows: Vec<(Resource, Cycle)> = Vec::new();
        let mut run_raw: u128 = 0;
        let mut run_scaled: u128 = 0;
        for (res, c) in &raw {
            run_raw += c;
            let cum = if raw_total == 0 {
                0
            } else {
                run_raw * active as u128 / raw_total
            };
            let v = (cum - run_scaled) as Cycle;
            run_scaled = cum;
            if v > 0 {
                rows.push((*res, v));
            }
        }
        let recovery = t.recovery_cycles.min(t.makespan - active);
        let compute = t.makespan - active - recovery;
        rows.push((Resource::Compute, compute));
        if recovery > 0 {
            rows.push((Resource::FaultRecovery, recovery));
        }
        TaskLedger {
            task: t.name.clone(),
            initiator: t.initiator,
            makespan: t.makespan,
            rows,
        }
    }

    pub fn task(&self, name: &str) -> Option<&TaskLedger> {
        self.tasks.iter().find(|t| t.task == name)
    }
}

// ---------------------------------------------------------------------
// Sinks: hand-built JSON (no external deps), following the escaping
// idiom of `util::bench`.

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn domain_name(d: Domain) -> &'static str {
    match d {
        Domain::System => "system",
        Domain::Vector => "vector",
        Domain::Amr => "amr",
        Domain::Uncore => "uncore",
    }
}

fn target_name(t: Target) -> &'static str {
    match t {
        Target::Dcspm => "dcspm",
        Target::Hyperram => "hyperram",
        Target::Peripheral => "peripheral",
    }
}

fn kind_fields(k: &TraceKind, out: &mut String) {
    use std::fmt::Write;
    match *k {
        TraceKind::TsuRelease { beats, write } | TraceKind::Grant { beats, write } => {
            write!(out, ",\"beats\":{beats},\"write\":{write}").unwrap()
        }
        TraceKind::WHold { beats } => write!(out, ",\"beats\":{beats}").unwrap(),
        TraceKind::LineFill {
            hit,
            dirty_victim,
            retry_cycles,
            service_cycles,
            line,
            set,
        } => write!(
            out,
            ",\"hit\":{hit},\"dirty_victim\":{dirty_victim},\"retry_cycles\":{retry_cycles},\"service_cycles\":{service_cycles},\"line\":{line},\"set\":{set}"
        )
        .unwrap(),
        TraceKind::BankConflict => {}
        TraceKind::Recovery { penalty, reboot } => {
            write!(out, ",\"penalty\":{penalty},\"reboot\":{reboot}").unwrap()
        }
        TraceKind::Delivery {
            beats,
            write,
            last_fragment,
            issued_at,
            released_at,
            granted_at,
        } => write!(
            out,
            ",\"beats\":{beats},\"write\":{write},\"last_fragment\":{last_fragment},\"issued_at\":{issued_at},\"released_at\":{released_at},\"granted_at\":{granted_at}"
        )
        .unwrap(),
    }
}

/// Structured JSONL sink: one JSON object per line, chronological.
/// `sys` is the event's system-grid timestamp; `at` stays in the
/// owning domain's local cycles.
pub fn to_jsonl(cap: &TraceCapture) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    for e in &cap.events {
        write!(
            out,
            "{{\"scenario\":\"{}\",\"kind\":\"{}\",\"sys\":{},\"at\":{},\"domain\":\"{}\",\"initiator\":{},\"lane\":{},\"tag\":{}",
            esc(&cap.scenario),
            e.kind.name(),
            cap.system_ts(e),
            e.at,
            domain_name(e.domain),
            e.initiator.0,
            e.lane,
            e.tag,
        )
        .unwrap();
        if let Some(t) = e.target {
            write!(out, ",\"target\":\"{}\"", target_name(t)).unwrap();
        }
        kind_fields(&e.kind, &mut out);
        out.push_str("}\n");
    }
    out
}

/// Chrome/Perfetto `trace_event` JSON. Track layout:
/// - `pid 1` "initiators": one thread per initiator; delivery
///   lifecycles as complete (`X`) slices `[released_at, delivered)`,
///   TSU releases / W-holds / fault recoveries as instant events.
/// - `pid 2` "targets": one thread per (target, lane); in-service
///   windows `[granted_at, delivered)` as `X` slices, bank conflicts as
///   instants.
/// - `pid 3` "hyperram line engine": line fills (with retry overhead)
///   as `X` slices on the uncore grid converted to system edges.
/// - `pid 4` "dpllc occupancy": one counter (`C`) track per touched set,
///   stepping on every allocating fill — resident lines capped at the
///   associativity, so a saturated counter reads "set full" directly.
///
/// `ts`/`dur` are system-clock cycles (Perfetto renders them as µs —
/// only the relative scale matters).
pub fn to_perfetto(cap: &TraceCapture) -> String {
    use std::fmt::Write;
    let mut ev: Vec<String> = Vec::new();
    let meta = |pid: u32, tid: u64, name: String| {
        format!(
            "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"name\":\"thread_name\",\"args\":{{\"name\":\"{}\"}}}}",
            esc(&name)
        )
    };
    ev.push(format!(
        "{{\"ph\":\"M\",\"pid\":1,\"name\":\"process_name\",\"args\":{{\"name\":\"initiators ({})\"}}}}",
        esc(&cap.scenario)
    ));
    ev.push("{\"ph\":\"M\",\"pid\":2,\"name\":\"process_name\",\"args\":{\"name\":\"targets\"}}".into());
    ev.push(
        "{\"ph\":\"M\",\"pid\":3,\"name\":\"process_name\",\"args\":{\"name\":\"hyperram line engine\"}}"
            .into(),
    );
    let mut init_threads: Vec<u64> = Vec::new();
    let mut lane_threads: Vec<u64> = Vec::new();
    // Per-set resident-line counters for the occupancy track: fills
    // allocate, capped at the associativity (evictions replace in
    // place, so a saturated set stays saturated).
    let ways = crate::soc::mem::dpllc::DpllcConfig::carfield().ways as u64;
    let mut occupancy: std::collections::BTreeMap<u32, u64> = std::collections::BTreeMap::new();
    let mut occupancy_meta = false;
    let lane_tid = |t: Target, lane: u8| -> u64 {
        let ti = match t {
            Target::Dcspm => 0u64,
            Target::Hyperram => 1,
            Target::Peripheral => 2,
        };
        ti * 8 + lane as u64
    };
    for e in &cap.events {
        let tid = e.initiator.0 as u64;
        if !init_threads.contains(&tid) {
            init_threads.push(tid);
            let name = if let Some(t) = cap.tasks.iter().find(|t| t.initiator == e.initiator) {
                format!("init {} ({})", tid, t.name)
            } else {
                format!("init {tid}")
            };
            ev.push(meta(1, tid, name));
        }
        if let Some(t) = e.target {
            let lt = lane_tid(t, e.lane);
            if !lane_threads.contains(&lt) {
                lane_threads.push(lt);
                ev.push(meta(2, lt, format!("{} lane {}", target_name(t), e.lane)));
            }
        }
        let sys = cap.system_ts(e);
        let mut args = String::from("{\"tag\":");
        write!(args, "{}", e.tag).unwrap();
        kind_fields_args(&e.kind, &mut args);
        args.push('}');
        match e.kind {
            TraceKind::Delivery {
                released_at,
                granted_at,
                ..
            } => {
                let dur = sys.saturating_sub(released_at).max(1);
                ev.push(format!(
                    "{{\"ph\":\"X\",\"pid\":1,\"tid\":{tid},\"ts\":{released_at},\"dur\":{dur},\"name\":\"xact\",\"cat\":\"bus\",\"args\":{args}}}"
                ));
                if let Some(t) = e.target {
                    let lt = lane_tid(t, e.lane);
                    let sdur = sys.saturating_sub(granted_at).max(1);
                    ev.push(format!(
                        "{{\"ph\":\"X\",\"pid\":2,\"tid\":{lt},\"ts\":{granted_at},\"dur\":{sdur},\"name\":\"serve init {}\",\"cat\":\"bus\",\"args\":{args}}}",
                        e.initiator.0
                    ));
                }
            }
            TraceKind::LineFill {
                hit,
                service_cycles,
                set,
                ..
            } => {
                let end = cap.uncore.to_system_edge(e.at + service_cycles);
                let dur = end.saturating_sub(sys).max(1);
                ev.push(format!(
                    "{{\"ph\":\"X\",\"pid\":3,\"tid\":0,\"ts\":{sys},\"dur\":{dur},\"name\":\"line fill\",\"cat\":\"mem\",\"args\":{args}}}"
                ));
                if !hit {
                    if !occupancy_meta {
                        occupancy_meta = true;
                        ev.push(
                            "{\"ph\":\"M\",\"pid\":4,\"name\":\"process_name\",\"args\":{\"name\":\"dpllc occupancy\"}}"
                                .into(),
                        );
                    }
                    let occ = {
                        let c = occupancy.entry(set).or_insert(0);
                        *c = (*c + 1).min(ways);
                        *c
                    };
                    ev.push(format!(
                        "{{\"ph\":\"C\",\"pid\":4,\"tid\":0,\"ts\":{sys},\"name\":\"set {set}\",\"args\":{{\"lines\":{occ}}}}}"
                    ));
                }
            }
            TraceKind::BankConflict => {
                if let Some(t) = e.target {
                    let lt = lane_tid(t, e.lane);
                    ev.push(format!(
                        "{{\"ph\":\"i\",\"pid\":2,\"tid\":{lt},\"ts\":{sys},\"s\":\"t\",\"name\":\"bank conflict\",\"cat\":\"mem\",\"args\":{args}}}"
                    ));
                }
            }
            _ => {
                ev.push(format!(
                    "{{\"ph\":\"i\",\"pid\":1,\"tid\":{tid},\"ts\":{sys},\"s\":\"t\",\"name\":\"{}\",\"cat\":\"bus\",\"args\":{args}}}",
                    e.kind.name()
                ));
            }
        }
    }
    let mut out = String::from("{\"traceEvents\":[\n");
    out.push_str(&ev.join(",\n"));
    out.push_str("\n]}\n");
    out
}

fn kind_fields_args(k: &TraceKind, out: &mut String) {
    // Reuse the flat field encoding; inside an args object the leading
    // comma after "tag" is already correct.
    kind_fields(k, out);
}

// ---------------------------------------------------------------------
// Schema checks: a dependency-free JSON validator used by the sink
// tests and the `carfield trace` gate.

/// Validate that `s` is one well-formed JSON value (RFC 8259 subset:
/// no surrogate-pair checking). Returns the byte offset of the first
/// error.
pub fn validate_json(s: &str) -> Result<(), String> {
    let mut p = JsonParser {
        b: s.as_bytes(),
        i: 0,
    };
    p.ws();
    p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(())
}

/// First integer value of `"key":` in a flat JSON line (the sinks emit
/// unnested numeric fields, so a string scan is exact here).
fn field_i64(line: &str, key: &str) -> Option<i64> {
    let pat = format!("\"{key}\":");
    let i = line.find(&pat)? + pat.len();
    let rest = line[i..].as_bytes();
    let mut j = usize::from(rest.first() == Some(&b'-'));
    let start = j;
    while j < rest.len() && rest[j].is_ascii_digit() {
        j += 1;
    }
    if j == start {
        return None;
    }
    line[i..i + j].parse().ok()
}

/// Validate a JSONL document: every non-empty line is a JSON object
/// containing the required keys, and per (initiator, lane) track the
/// `sys` stamps never regress. A capture is sorted on the system master
/// grid, so a backwards-running track means an uncore-domain event was
/// serialized with a raw local timestamp instead of crossing through
/// the [`RateConverter`] — previously such a stamp slipped through the
/// schema check silently.
pub fn validate_jsonl(s: &str, required_keys: &[&str]) -> Result<(), String> {
    let mut last_sys: std::collections::BTreeMap<(i64, i64), i64> = std::collections::BTreeMap::new();
    for (n, line) in s.lines().enumerate() {
        if line.is_empty() {
            continue;
        }
        validate_json(line).map_err(|e| format!("line {}: {e}", n + 1))?;
        if !line.starts_with('{') {
            return Err(format!("line {}: not an object", n + 1));
        }
        for k in required_keys {
            if !line.contains(&format!("\"{k}\":")) {
                return Err(format!("line {}: missing key {k:?}", n + 1));
            }
        }
        if let Some(sys) = field_i64(line, "sys") {
            let track = (
                field_i64(line, "initiator").unwrap_or(-1),
                field_i64(line, "lane").unwrap_or(-1),
            );
            if let Some(prev) = last_sys.insert(track, sys) {
                if sys < prev {
                    return Err(format!(
                        "line {}: sys {sys} regresses below {prev} on track (initiator {}, lane {}) — uncore timestamp not converted to the system grid?",
                        n + 1,
                        track.0,
                        track.1
                    ));
                }
            }
        }
    }
    Ok(())
}

struct JsonParser<'a> {
    b: &'a [u8],
    i: usize,
}

impl JsonParser<'_> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<(), String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string(),
            Some(b't') => self.lit("true"),
            Some(b'f') => self.lit("false"),
            Some(b'n') => self.lit("null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("expected value at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str) -> Result<(), String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(())
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<(), String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let digits = |p: &mut Self| {
            let s = p.i;
            while matches!(p.peek(), Some(c) if c.is_ascii_digit()) {
                p.i += 1;
            }
            p.i > s
        };
        if !digits(self) {
            return Err(format!("bad number at byte {start}"));
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            if !digits(self) {
                return Err(format!("bad fraction at byte {start}"));
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            if !digits(self) {
                return Err(format!("bad exponent at byte {start}"));
            }
        }
        Ok(())
    }

    fn string(&mut self) -> Result<(), String> {
        debug_assert_eq!(self.peek(), Some(b'"'));
        self.i += 1;
        while let Some(c) = self.peek() {
            match c {
                b'"' => {
                    self.i += 1;
                    return Ok(());
                }
                b'\\' => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't') => {
                            self.i += 1
                        }
                        Some(b'u') => {
                            self.i += 1;
                            for _ in 0..4 {
                                if !matches!(self.peek(), Some(h) if h.is_ascii_hexdigit()) {
                                    return Err(format!("bad \\u escape at byte {}", self.i));
                                }
                                self.i += 1;
                            }
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                c if c < 0x20 => return Err(format!("raw control char at byte {}", self.i)),
                _ => self.i += 1,
            }
        }
        Err("unterminated string".into())
    }

    fn object(&mut self) -> Result<(), String> {
        self.i += 1; // '{'
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.ws();
            if self.peek() != Some(b'"') {
                return Err(format!("expected key at byte {}", self.i));
            }
            self.string()?;
            self.ws();
            if self.peek() != Some(b':') {
                return Err(format!("expected ':' at byte {}", self.i));
            }
            self.i += 1;
            self.ws();
            self.value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<(), String> {
        self.i += 1; // '['
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delivery(
        init: u8,
        tag: u64,
        issued: Cycle,
        released: Cycle,
        granted: Cycle,
        done: Cycle,
        target: Target,
    ) -> TraceEvent {
        TraceEvent {
            at: done,
            domain: Domain::System,
            initiator: InitiatorId(init),
            target: Some(target),
            lane: 0,
            tag,
            kind: TraceKind::Delivery {
                beats: 8,
                write: false,
                last_fragment: true,
                issued_at: issued,
                released_at: released,
                granted_at: granted,
            },
        }
    }

    fn capture(events: Vec<TraceEvent>, tasks: Vec<LedgerTask>) -> TraceCapture {
        let mut cap = TraceCapture::new("test", RateConverter::lockstep());
        cap.events = events;
        cap.tasks = tasks;
        cap.finish();
        cap
    }

    #[test]
    fn ledger_decomposes_a_sequential_task_exactly() {
        // Two back-to-back accesses: issue 0, shaped 2, granted 5,
        // delivered 20; then issue 30 (10 cycles of think), shaped 30,
        // granted 31, delivered 45. Makespan 50.
        let cap = capture(
            vec![
                delivery(0, 1, 0, 2, 5, 20, Target::Hyperram),
                delivery(0, 2, 30, 30, 31, 45, Target::Hyperram),
            ],
            vec![LedgerTask {
                name: "tct".into(),
                initiator: InitiatorId(0),
                makespan: 50,
                recovery_cycles: 0,
            }],
        );
        let ledger = InterferenceLedger::build(&cap);
        let t = ledger.task("tct").unwrap();
        assert!(t.sums_to_makespan());
        // Sequential task: the scaling is the identity.
        assert_eq!(t.measured(Resource::TsuShaping), 2);
        assert_eq!(t.measured(Resource::HyperramChannel), (20 - 2) + (45 - 30));
        // Compute = makespan - union([0,20) u [30,45)) = 50 - 35.
        assert_eq!(t.measured(Resource::Compute), 15);
        assert_eq!(t.measured(Resource::WChannel), 0);
    }

    #[test]
    fn ledger_attributes_w_channel_holds() {
        let mut ev = vec![delivery(0, 1, 0, 0, 8, 20, Target::Hyperram)];
        // A competitor's unbuffered write holds W for [2, 8).
        ev.push(TraceEvent {
            at: 2,
            domain: Domain::System,
            initiator: InitiatorId(1),
            target: Some(Target::Hyperram),
            lane: 0,
            tag: 0,
            kind: TraceKind::WHold { beats: 6 },
        });
        let cap = capture(
            ev,
            vec![LedgerTask {
                name: "tct".into(),
                initiator: InitiatorId(0),
                makespan: 20,
                recovery_cycles: 0,
            }],
        );
        let t = InterferenceLedger::build(&cap);
        let t = t.task("tct").unwrap();
        // Queue wait [0, 8) overlaps the hold [2, 8) for 6 cycles.
        assert_eq!(t.measured(Resource::WChannel), 6);
        assert_eq!(t.measured(Resource::HyperramChannel), 20 - 6);
        assert!(t.sums_to_makespan());
    }

    #[test]
    fn ledger_shrinks_pipelined_overlap_onto_wall_clock() {
        // Two fully overlapping spans [0, 20): raw attribution 40 must
        // shrink onto the 20-cycle active window.
        let cap = capture(
            vec![
                delivery(0, 1, 0, 0, 0, 20, Target::Hyperram),
                delivery(0, 2, 0, 0, 0, 20, Target::Hyperram),
            ],
            vec![LedgerTask {
                name: "dma".into(),
                initiator: InitiatorId(0),
                makespan: 25,
                recovery_cycles: 0,
            }],
        );
        let t = InterferenceLedger::build(&cap);
        let t = t.task("dma").unwrap();
        assert_eq!(t.measured(Resource::HyperramChannel), 20);
        assert_eq!(t.measured(Resource::Compute), 5);
        assert!(t.sums_to_makespan());
    }

    #[test]
    fn ledger_closes_with_fault_recovery() {
        let cap = capture(
            vec![delivery(0, 1, 0, 0, 0, 10, Target::Dcspm)],
            vec![LedgerTask {
                name: "amr".into(),
                initiator: InitiatorId(0),
                makespan: 100,
                recovery_cycles: 24,
            }],
        );
        let t = InterferenceLedger::build(&cap);
        let t = t.task("amr").unwrap();
        assert_eq!(t.measured(Resource::FaultRecovery), 24);
        assert_eq!(t.measured(Resource::DcspmPort), 10);
        assert_eq!(t.measured(Resource::Compute), 100 - 10 - 24);
        assert!(t.sums_to_makespan());
    }

    #[test]
    fn capture_sorts_uncore_events_on_the_system_grid() {
        let mut cap = TraceCapture::new("s", RateConverter::new(1000.0, 500.0));
        cap.events.push(TraceEvent {
            at: 10, // uncore-local -> system edge 5
            domain: Domain::Uncore,
            initiator: InitiatorId(0),
            target: Some(Target::Hyperram),
            lane: 0,
            tag: 0,
            kind: TraceKind::LineFill {
                hit: false,
                dirty_victim: false,
                retry_cycles: 0,
                service_cycles: 24,
                line: 0,
                set: 0,
            },
        });
        cap.events.push(delivery(0, 1, 0, 0, 1, 3, Target::Hyperram));
        cap.finish();
        assert_eq!(cap.events[0].kind.name(), "delivery");
        assert_eq!(cap.system_ts(&cap.events[1]), 5);
    }

    #[test]
    fn jsonl_sink_is_schema_valid() {
        let cap = capture(
            vec![delivery(0, 7, 0, 1, 2, 9, Target::Hyperram)],
            vec![],
        );
        let jsonl = to_jsonl(&cap);
        validate_jsonl(&jsonl, &["kind", "sys", "at", "initiator", "tag"]).unwrap();
        assert!(jsonl.contains("\"kind\":\"delivery\""));
    }

    fn fill(at: Cycle, hit: bool, line: u64, set: u32) -> TraceEvent {
        TraceEvent {
            at,
            domain: Domain::Uncore,
            initiator: InitiatorId(0),
            target: Some(Target::Hyperram),
            lane: 0,
            tag: line,
            kind: TraceKind::LineFill {
                hit,
                dirty_victim: false,
                retry_cycles: 0,
                service_cycles: if hit { 4 } else { 24 },
                line,
                set,
            },
        }
    }

    #[test]
    fn jsonl_carries_line_and_set_fields() {
        let cap = capture(vec![fill(0, false, 161, 33)], vec![]);
        let jsonl = to_jsonl(&cap);
        validate_jsonl(&jsonl, &["kind", "sys", "line", "set"]).unwrap();
        assert!(jsonl.contains("\"line\":161"));
        assert!(jsonl.contains("\"set\":33"));
    }

    #[test]
    fn jsonl_validator_rejects_unconverted_uncore_stamps() {
        // Same (initiator, lane) track, sys running backwards: the
        // second stamp was serialized raw instead of grid-converted.
        let bad = "{\"kind\":\"line_fill\",\"sys\":40,\"initiator\":0,\"lane\":0}\n\
                   {\"kind\":\"line_fill\",\"sys\":20,\"initiator\":0,\"lane\":0}\n";
        let err = validate_jsonl(bad, &["kind", "sys"]).unwrap_err();
        assert!(err.contains("regresses"), "unexpected error: {err}");
        // Distinct tracks may interleave arbitrarily.
        let ok = "{\"kind\":\"grant\",\"sys\":40,\"initiator\":0,\"lane\":0}\n\
                  {\"kind\":\"grant\",\"sys\":20,\"initiator\":1,\"lane\":0}\n\
                  {\"kind\":\"grant\",\"sys\":20,\"initiator\":0,\"lane\":1}\n";
        validate_jsonl(ok, &["kind", "sys"]).unwrap();
        // Equal stamps on one track are fine (same-cycle events).
        let eq = "{\"sys\":7,\"initiator\":2,\"lane\":0}\n{\"sys\":7,\"initiator\":2,\"lane\":0}\n";
        validate_jsonl(eq, &[]).unwrap();
    }

    #[test]
    fn real_capture_passes_the_monotone_track_check() {
        // Decoupled uncore (2:1): local stamps 10 and 30 land on system
        // edges 5 and 15 — converted stamps keep the track monotone.
        let mut cap = TraceCapture::new("s", RateConverter::new(1000.0, 500.0));
        cap.events.push(fill(30, false, 2, 2));
        cap.events.push(fill(10, false, 1, 1));
        cap.finish();
        validate_jsonl(&to_jsonl(&cap), &["kind", "sys", "line", "set"]).unwrap();
    }

    #[test]
    fn perfetto_emits_per_set_occupancy_counters() {
        // Three allocating fills into set 5 (8-way: counter 1, 2, 3)
        // plus a hit that must not step any counter.
        let cap = capture(
            vec![
                fill(0, false, 100, 5),
                fill(24, false, 101, 5),
                fill(48, true, 100, 5),
                fill(52, false, 102, 5),
            ],
            vec![],
        );
        let json = to_perfetto(&cap);
        validate_json(&json).unwrap();
        assert!(json.contains("dpllc occupancy"));
        assert!(json.contains("\"ph\":\"C\""));
        assert!(json.contains("\"name\":\"set 5\",\"args\":{\"lines\":1}"));
        assert!(json.contains("\"name\":\"set 5\",\"args\":{\"lines\":3}"));
        // The hit contributed a line-engine slice but no counter step.
        assert_eq!(json.matches("\"ph\":\"C\"").count(), 3);
    }

    #[test]
    fn perfetto_sink_is_valid_json() {
        let mut ev = vec![delivery(0, 1, 0, 0, 2, 9, Target::Hyperram)];
        ev.push(TraceEvent {
            at: 3,
            domain: Domain::System,
            initiator: InitiatorId(1),
            target: None,
            lane: 0,
            tag: 0,
            kind: TraceKind::Recovery {
                penalty: 24,
                reboot: false,
            },
        });
        let cap = capture(
            ev,
            vec![LedgerTask {
                name: "tct \"quoted\"".into(),
                initiator: InitiatorId(0),
                makespan: 9,
                recovery_cycles: 0,
            }],
        );
        let json = to_perfetto(&cap);
        validate_json(&json).unwrap();
        assert!(json.contains("\"traceEvents\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"ph\":\"i\""));
    }

    #[test]
    fn json_validator_accepts_and_rejects() {
        validate_json("{\"a\":[1,2.5,-3e4,true,null,\"x\\n\"]}").unwrap();
        validate_json("  [ ]  ").unwrap();
        assert!(validate_json("{\"a\":}").is_err());
        assert!(validate_json("[1,]").is_err());
        assert!(validate_json("{} extra").is_err());
        assert!(validate_json("\"unterminated").is_err());
        // Lenient where RFC 8259 is strict: leading zeros still parse.
        validate_json("01").unwrap();
    }

    #[test]
    fn interval_helpers() {
        let m = merge_intervals(vec![(5, 9), (0, 3), (2, 4), (9, 9)]);
        assert_eq!(m, vec![(0, 4), (5, 9)]);
        assert_eq!(union_len(&m), 8);
        assert_eq!(overlap_len(&m, 1, 7), 3 + 2);
        assert_eq!(overlap_len(&m, 10, 20), 0);
    }
}

//! Trace-driven working-set profiling and empirical partition-fit
//! certificates (PR 9, observability).
//!
//! A [`TraceCapture`] whose line-fill events carry the 64B-granular line
//! address and the DPLLC set index (see [`TraceKind::LineFill`]) is a
//! complete record of the DPLLC access stream. This module folds it into
//! per-task [`WorkingSetProfile`]s:
//!
//! - distinct-line counts and a per-set fill histogram whose rows
//!   **re-sum exactly** to the observed fill count (the same exact-sum
//!   discipline as the interference ledger);
//! - a reuse summary (reused vs singleton lines, refill count,
//!   worst per-line touch count);
//! - a *partition-fit curve*: the task's access stream replayed through
//!   hypothetical exclusive LRU partitions of S sets x the hardware's
//!   associativity, for a ladder of candidate sizes.
//!
//! The replay uses the exact indexing arithmetic of the cache model
//! (`set = line % n_sets`, per-set LRU — [`Dpllc::set_of`] pins the
//! correspondence), and the simulated task replays a deterministic
//! address stream, so a replay point is not an estimate: a real
//! simulation with an exclusive partition of S sets reproduces the
//! predicted fills **exactly** (asserted in
//! `tests/workingset_determinism.rs`).
//!
//! On top of the curve, [`PartitionCertificate::mint`] certifies every
//! size whose *warm* hit rate (compulsory first-touch misses excluded)
//! clears [`CERT_WARM_THRESHOLD_PPM`]: "task T fits an exclusive
//! partition of S sets with >= H ppm warm hits, at most `max_fills`
//! channel fills". Certificates are keyed by workload *shape*
//! ([`shape_key`] — task names excluded) and persist across runs in a
//! [`CertificateLibrary`], mirroring
//! [`power::certificates::UtilizationLibrary`]. The WCET engine's
//! certificate-backed warm path ([`crate::wcet::analyze_certified`])
//! prices certified hits at hit latency only when the scenario's
//! `tct_sets` matches a certified entry exactly — hit rate is *not*
//! monotone in set count for general access patterns, so there is no
//! interpolation between entries.
//!
//! [`Dpllc::set_of`]: crate::soc::mem::dpllc::Dpllc::set_of
//! [`power::certificates::UtilizationLibrary`]: crate::power::certificates::UtilizationLibrary

use std::collections::BTreeMap;

use super::{TraceCapture, TraceKind};
use crate::soc::axi::InitiatorId;
use crate::soc::hostd::TctSpec;
use crate::soc::mem::dpllc::{DpllcConfig, TOTAL_SETS};

/// Warm-hit-rate floor (parts per million of non-compulsory accesses)
/// a partition size must clear on the fit curve to be certified.
pub const CERT_WARM_THRESHOLD_PPM: u32 = 950_000;

/// Reuse structure of one task's line stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ReuseSummary {
    /// Distinct lines touched two or more times.
    pub reused_lines: u64,
    /// Distinct lines touched exactly once (streaming traffic).
    pub singleton_lines: u64,
    /// Fills beyond each line's compulsory first one (capacity/conflict
    /// misses under the *observed* tuning).
    pub refills: u64,
    /// Worst per-line touch count (fills + hits).
    pub max_touches: u64,
}

/// One point of the partition-fit curve: the task's access stream
/// replayed through an exclusive LRU partition of `sets` sets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FitPoint {
    pub sets: u32,
    /// Channel fills the replay predicts (compulsory + capacity).
    pub fills: u64,
    /// Replay hits — every one is a warm (non-first-touch) access.
    pub warm_hits: u64,
    /// Non-compulsory accesses: `accesses - distinct_lines`.
    pub warm_accesses: u64,
}

impl FitPoint {
    /// Warm hit rate in ppm; a stream with no reuse is vacuously warm.
    pub fn warm_hit_ppm(&self) -> u32 {
        if self.warm_accesses == 0 {
            1_000_000
        } else {
            (self.warm_hits * 1_000_000 / self.warm_accesses) as u32
        }
    }
}

/// Per-task cache-occupancy profile folded from one traced run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkingSetProfile {
    pub task: String,
    pub initiator: InitiatorId,
    /// Observed allocating fills (`hit: false` events).
    pub fills: u64,
    /// Observed DPLLC hits (`hit: true` events, both lanes).
    pub hits: u64,
    /// Distinct 64B lines touched.
    pub distinct_lines: u64,
    /// Observed fills per absolute DPLLC set. The defining invariant:
    /// the values re-sum exactly to `fills`.
    pub set_fills: BTreeMap<u32, u64>,
    pub reuse: ReuseSummary,
    /// Exclusive-partition replay at ascending candidate sizes.
    pub fit_curve: Vec<FitPoint>,
}

impl WorkingSetProfile {
    /// Total observed DPLLC accesses.
    pub fn accesses(&self) -> u64 {
        self.fills + self.hits
    }

    /// The exact-sum invariants: per-set rows re-sum to the observed
    /// fill count, and every fill is either a compulsory first touch or
    /// a counted refill.
    pub fn sums_exactly(&self) -> bool {
        self.set_fills.values().sum::<u64>() == self.fills
            && self.distinct_lines + self.reuse.refills == self.fills
    }

    /// Smallest replayed size whose warm hit rate clears `ppm`.
    pub fn minimal_fitting_sets(&self, ppm: u32) -> Option<u32> {
        self.fit_curve
            .iter()
            .find(|p| p.warm_hit_ppm() >= ppm)
            .map(|p| p.sets)
    }
}

/// Candidate partition sizes for the fit curve: a fixed ladder plus the
/// analytic fit point `ceil(distinct / ways)` (the smallest size whose
/// capacity covers the working set), everything below the full cache.
fn candidate_sizes(distinct: u64, ways: u32) -> Vec<u32> {
    let mut sizes: Vec<u32> = vec![1, 2, 4, 8, 16, 32, 48, 64, 96, 128, 192];
    let fit = distinct.div_ceil(ways.max(1) as u64);
    if fit >= 1 && fit < TOTAL_SETS as u64 {
        sizes.push(fit as u32);
    }
    sizes.retain(|&s| (s as usize) < TOTAL_SETS && s >= 1);
    sizes.sort_unstable();
    sizes.dedup();
    sizes
}

/// Replay `stream` (line addresses, in observed order) through an
/// exclusive `sets` x `ways` LRU partition — the exact arithmetic of
/// [`Dpllc`](crate::soc::mem::dpllc::Dpllc) with `first_set` rebased to
/// zero, which a modulo index makes irrelevant.
fn replay(stream: &[u64], sets: u32, ways: u32) -> (u64, u64) {
    let mut part: Vec<Vec<u64>> = vec![Vec::with_capacity(ways as usize); sets as usize];
    let (mut fills, mut hits) = (0u64, 0u64);
    for &line in stream {
        let set = &mut part[(line % sets as u64) as usize];
        if let Some(pos) = set.iter().position(|&l| l == line) {
            hits += 1;
            let l = set.remove(pos);
            set.push(l); // LRU: most recent last
        } else {
            fills += 1;
            if set.len() == ways as usize {
                set.remove(0);
            }
            set.push(line);
        }
    }
    (fills, hits)
}

/// Fold a capture into per-task profiles, in initiator order. Only
/// initiators with at least one line-fill event appear; task names come
/// from the capture's ledger directory (`init N` for unnamed ones).
pub fn profiles_of(cap: &TraceCapture) -> Vec<WorkingSetProfile> {
    let ways = DpllcConfig::carfield().ways as u32;
    // Per-initiator observed stream, in capture (system-grid) order.
    let mut streams: BTreeMap<u8, Vec<(u64, u32, bool)>> = BTreeMap::new();
    for e in &cap.events {
        if let TraceKind::LineFill { hit, line, set, .. } = e.kind {
            streams
                .entry(e.initiator.0)
                .or_default()
                .push((line, set, hit));
        }
    }
    streams
        .into_iter()
        .map(|(init, accesses)| {
            let initiator = InitiatorId(init);
            let task = cap
                .tasks
                .iter()
                .find(|t| t.initiator == initiator)
                .map(|t| t.name.clone())
                .unwrap_or_else(|| format!("init {init}"));
            let mut fills = 0u64;
            let mut hits = 0u64;
            let mut set_fills: BTreeMap<u32, u64> = BTreeMap::new();
            let mut touches: BTreeMap<u64, u64> = BTreeMap::new();
            for &(line, set, hit) in &accesses {
                *touches.entry(line).or_insert(0) += 1;
                if hit {
                    hits += 1;
                } else {
                    fills += 1;
                    *set_fills.entry(set).or_insert(0) += 1;
                }
            }
            let distinct_lines = touches.len() as u64;
            let reuse = ReuseSummary {
                reused_lines: touches.values().filter(|&&t| t > 1).count() as u64,
                singleton_lines: touches.values().filter(|&&t| t == 1).count() as u64,
                refills: fills - distinct_lines.min(fills),
                max_touches: touches.values().copied().max().unwrap_or(0),
            };
            let stream: Vec<u64> = accesses.iter().map(|&(line, _, _)| line).collect();
            let warm_accesses = stream.len() as u64 - distinct_lines;
            let fit_curve = candidate_sizes(distinct_lines, ways)
                .into_iter()
                .map(|sets| {
                    let (rfills, rhits) = replay(&stream, sets, ways);
                    FitPoint {
                        sets,
                        fills: rfills,
                        warm_hits: rhits,
                        warm_accesses,
                    }
                })
                .collect();
            WorkingSetProfile {
                task,
                initiator,
                fills,
                hits,
                distinct_lines,
                set_fills,
                reuse,
                fit_curve,
            }
        })
        .collect()
}

/// One certified partition size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CertEntry {
    pub sets: u32,
    /// Channel fills an exclusive partition of `sets` sets admits —
    /// exact for the replayed stream, an upper bound the validating
    /// simulation must meet.
    pub max_fills: u64,
    pub warm_hit_ppm: u32,
}

/// "Task-shaped-like-this fits an exclusive partition of S sets with
/// >= H ppm warm hits": the empirical evidence the WCET warm path and
/// the autotuner's `tct_sets` axis are gated on. Only replay-certified
/// sizes appear in `entries` — warm pricing applies only to an *exact*
/// entry match (no interpolation; see the module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartitionCertificate {
    /// Task the profile was folded from (informational — the library
    /// key is `shape_key`).
    pub task: String,
    pub shape_key: String,
    /// Associativity the replay assumed; consumers must re-check it
    /// against the live cache geometry.
    pub ways: u32,
    pub accesses: u64,
    pub distinct_lines: u64,
    /// Ascending by `sets`, every entry clears the minting threshold.
    pub entries: Vec<CertEntry>,
}

impl PartitionCertificate {
    /// Certify every fit-curve size clearing
    /// [`CERT_WARM_THRESHOLD_PPM`]; `None` when no size fits (a
    /// streaming task with no reuse to protect still certifies — its
    /// warm rate is vacuously 1M ppm — but an over-capacity thrasher
    /// does not).
    pub fn mint(profile: &WorkingSetProfile, shape_key: &str) -> Option<Self> {
        let entries: Vec<CertEntry> = profile
            .fit_curve
            .iter()
            .filter(|p| p.warm_hit_ppm() >= CERT_WARM_THRESHOLD_PPM)
            .map(|p| CertEntry {
                sets: p.sets,
                max_fills: p.fills,
                warm_hit_ppm: p.warm_hit_ppm(),
            })
            .collect();
        if entries.is_empty() {
            return None;
        }
        Some(Self {
            task: profile.task.clone(),
            shape_key: shape_key.to_string(),
            ways: DpllcConfig::carfield().ways as u32,
            accesses: profile.accesses(),
            distinct_lines: profile.distinct_lines,
            entries,
        })
    }

    /// The smallest certified partition.
    pub fn minimal(&self) -> &CertEntry {
        &self.entries[0]
    }

    /// The entry for exactly `sets` sets, if certified.
    pub fn entry_for(&self, sets: u32) -> Option<&CertEntry> {
        self.entries.iter().find(|e| e.sets == sets)
    }

    /// Persistable JSON form (dependency-free, like the trace sinks).
    pub fn to_json(&self) -> String {
        use std::fmt::Write;
        let mut out = format!(
            "{{\"task\":\"{}\",\"shape_key\":\"{}\",\"ways\":{},\"accesses\":{},\"distinct_lines\":{},\"entries\":[",
            super::esc(&self.task),
            super::esc(&self.shape_key),
            self.ways,
            self.accesses,
            self.distinct_lines,
        );
        for (i, e) in self.entries.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            write!(
                out,
                "{{\"sets\":{},\"max_fills\":{},\"warm_hit_ppm\":{}}}",
                e.sets, e.max_fills, e.warm_hit_ppm
            )
            .unwrap();
        }
        out.push_str("]}");
        out
    }
}

/// Workload-shape key for a host TCT: everything that determines the
/// address stream (and hence the profile), nothing that names the task
/// or depends on the tuning — `part_id` is a placement decision, not a
/// shape property, so two scenarios differing only in partition
/// assignment share one certificate.
pub fn shape_key(spec: &TctSpec) -> String {
    format!(
        "host-tct/base{:x}/stride{}/acc{}x{}/think{}",
        spec.base, spec.stride, spec.accesses, spec.iterations, spec.think_cycles
    )
}

/// Keyed certificate store with hit/miss counters, mirroring
/// [`UtilizationLibrary`](crate::power::certificates::UtilizationLibrary):
/// repeat analyses of the same workload shape skip re-profiling.
#[derive(Debug, Clone, Default)]
pub struct CertificateLibrary {
    entries: BTreeMap<String, PartitionCertificate>,
    pub hits: u64,
    pub misses: u64,
}

impl CertificateLibrary {
    pub fn new() -> Self {
        Self::default()
    }

    /// Look a shape key up, counting the outcome.
    pub fn lookup(&mut self, key: &str) -> Option<&PartitionCertificate> {
        match self.entries.get(key) {
            Some(c) => {
                self.hits += 1;
                Some(c)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Store a certificate under its own shape key (replacing any
    /// previous evidence for that shape).
    pub fn insert(&mut self, cert: PartitionCertificate) {
        self.entries.insert(cert.shape_key.clone(), cert);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::axi::Target;
    use crate::soc::clock::{Domain, RateConverter};
    use crate::trace::{LedgerTask, TraceEvent};

    /// A capture whose initiator-0 stream walks `lines` cyclically
    /// `rounds` times: first round all fills, later rounds tagged `hit`
    /// per `warm_hit`.
    fn walk_capture(lines: u64, rounds: u64, warm_hit: bool) -> TraceCapture {
        let mut cap = TraceCapture::new("ws", RateConverter::lockstep());
        let mut at = 0;
        for r in 0..rounds {
            for l in 0..lines {
                let hit = r > 0 && warm_hit;
                cap.events.push(TraceEvent {
                    at,
                    domain: Domain::Uncore,
                    initiator: InitiatorId(0),
                    target: Some(Target::Hyperram),
                    lane: u8::from(hit),
                    tag: l,
                    kind: TraceKind::LineFill {
                        hit,
                        dirty_victim: false,
                        retry_cycles: 0,
                        service_cycles: if hit { 4 } else { 40 },
                        line: l,
                        set: (l % TOTAL_SETS as u64) as u32,
                    },
                });
                at += 1;
            }
        }
        cap.tasks.push(LedgerTask {
            name: "tct".into(),
            initiator: InitiatorId(0),
            makespan: at,
            recovery_cycles: 0,
        });
        cap.finish();
        cap
    }

    #[test]
    fn profile_counts_and_exact_sum_invariants() {
        // 16 lines x 4 rounds, warm rounds hit: 16 fills, 48 hits.
        let cap = walk_capture(16, 4, true);
        let ps = profiles_of(&cap);
        assert_eq!(ps.len(), 1);
        let p = &ps[0];
        assert_eq!(p.task, "tct");
        assert_eq!((p.fills, p.hits, p.accesses()), (16, 48, 64));
        assert_eq!(p.distinct_lines, 16);
        assert!(p.sums_exactly());
        assert_eq!(p.set_fills.len(), 16, "one fill per touched set");
        assert_eq!(p.reuse.reused_lines, 16);
        assert_eq!(p.reuse.singleton_lines, 0);
        assert_eq!(p.reuse.refills, 0);
        assert_eq!(p.reuse.max_touches, 4);
    }

    #[test]
    fn refills_close_the_fill_sum_when_the_observed_run_thrashes() {
        // Same walk, but the observed run never hit (e.g. a shared
        // partition being thrashed): every access is a fill.
        let cap = walk_capture(16, 4, false);
        let p = &profiles_of(&cap)[0];
        assert_eq!((p.fills, p.hits), (64, 0));
        assert_eq!(p.distinct_lines, 16);
        assert_eq!(p.reuse.refills, 48);
        assert!(p.sums_exactly());
    }

    #[test]
    fn fit_curve_finds_the_minimal_exclusive_partition() {
        // 16 distinct lines, 8 ways: a cyclic walk thrashes an LRU
        // partition of 1 set (capacity 8) completely, and hits fully
        // from 2 sets (capacity 16) up.
        let cap = walk_capture(16, 4, false);
        let p = &profiles_of(&cap)[0];
        let at = |sets: u32| p.fit_curve.iter().find(|f| f.sets == sets).unwrap();
        assert_eq!(at(1).warm_hits, 0, "LRU + cyclic over-capacity thrashes");
        assert_eq!(at(1).fills, 64);
        assert_eq!(at(2).warm_hits, 48);
        assert_eq!(at(2).fills, 16);
        assert_eq!(at(2).warm_hit_ppm(), 1_000_000);
        assert_eq!(p.minimal_fitting_sets(CERT_WARM_THRESHOLD_PPM), Some(2));
        // The analytic fit point ceil(16/8) = 2 is on the ladder.
        assert!(p.fit_curve.iter().any(|f| f.sets == 2));
    }

    #[test]
    fn fit_points_preserve_the_access_total() {
        let cap = walk_capture(48, 3, false);
        let p = &profiles_of(&cap)[0];
        for f in &p.fit_curve {
            assert_eq!(
                f.fills + f.warm_hits,
                p.accesses(),
                "replay at {} sets must account for every access",
                f.sets
            );
            assert!(f.fills >= p.distinct_lines, "compulsory misses are floor");
        }
    }

    #[test]
    fn certificates_gate_on_the_warm_threshold() {
        let cap = walk_capture(16, 4, false);
        let p = &profiles_of(&cap)[0];
        let cert = PartitionCertificate::mint(p, "k").expect("fits from 2 sets");
        assert_eq!(cert.minimal().sets, 2);
        assert_eq!(cert.minimal().max_fills, 16);
        assert_eq!(cert.minimal().warm_hit_ppm, 1_000_000);
        assert!(cert.entry_for(1).is_none(), "thrashing size not certified");
        assert!(cert.entry_for(2).is_some());
        assert!(cert.entry_for(3).is_none(), "no interpolation entries");
        assert_eq!(cert.accesses, 64);
        assert_eq!(cert.distinct_lines, 16);
        crate::trace::validate_json(&cert.to_json()).unwrap();
    }

    #[test]
    fn oversized_working_set_mints_nothing() {
        // More distinct lines than the whole cache holds under any
        // sub-total partition: 2048 lines, 8 ways -> needs 256 sets,
        // but candidates stop below TOTAL_SETS.
        let cap = walk_capture(2048, 2, false);
        let p = &profiles_of(&cap)[0];
        assert_eq!(p.minimal_fitting_sets(CERT_WARM_THRESHOLD_PPM), None);
        assert!(PartitionCertificate::mint(p, "k").is_none());
    }

    #[test]
    fn library_counts_hits_and_misses_by_shape() {
        let cap = walk_capture(16, 4, false);
        let p = &profiles_of(&cap)[0];
        let spec = TctSpec::fig6a();
        let key = shape_key(&spec);
        assert!(key.contains("host-tct") && key.contains("acc768x8"));
        let mut lib = CertificateLibrary::new();
        assert!(lib.is_empty());
        assert!(lib.lookup(&key).is_none());
        lib.insert(PartitionCertificate::mint(p, &key).unwrap());
        assert_eq!(lib.len(), 1);
        assert_eq!(lib.lookup(&key).unwrap().minimal().sets, 2);
        assert_eq!((lib.hits, lib.misses), (1, 1));
        // part_id is placement, not shape: it must not split the key.
        let mut moved = spec;
        moved.part_id = 0;
        assert_eq!(shape_key(&moved), key);
    }

    #[test]
    fn replay_is_exact_lru() {
        // Stream touching lines 0,1,2,0 in a 1-set x 2-way partition:
        // 0 fills, 1 fills, 2 evicts 0 (LRU), 0 refills.
        let (fills, hits) = replay(&[0, 1, 2, 0], 1, 2);
        assert_eq!((fills, hits), (4, 0));
        // With a re-reference keeping 0 warm: 0,1,0,2,0 -> 2 evicts 1.
        let (fills, hits) = replay(&[0, 1, 0, 2, 0], 1, 2);
        assert_eq!((fills, hits), (3, 2));
    }
}

//! Service-mode counters: running per-resource cycle totals harvested
//! from counters the simulator already keeps at its trace hook sites.
//!
//! Unlike the event tracer ([`crate::trace`]'s buffers), nothing here
//! buffers per-event records: every number is a plain running counter
//! the hot path maintains anyway (TSU TRU-stall cycles, W-channel hold
//! stalls, per-target busy cycles), so reading them costs nothing and
//! arming nothing. This is the substrate for admission-as-a-service
//! re-packing: a long-lived coordinator owning one [`ServiceCounters`]
//! per simulator can [`harvest`](ServiceCounters::harvest) between
//! runs and attribute each window's service demand per WCET
//! [`Resource`] without replaying an event stream.

use crate::soc::axi::{InitiatorId, Target};
use crate::soc::SocSim;
use crate::wcet::Resource;

/// The fixed harvest order — stable across runs so delta rows line up.
pub const SERVICE_RESOURCES: [Resource; 5] = [
    Resource::TsuShaping,
    Resource::WChannel,
    Resource::HyperramChannel,
    Resource::DcspmPort,
    Resource::Peripheral,
];

/// Cumulative per-resource service/stall cycles of one simulator.
///
/// Counter domains follow the hardware: target busy counters run on
/// the owning target's clock grid (uncore for HyperRAM/peripheral,
/// system for the DCSPM), TSU and W-channel stalls on the system grid
/// — the same currencies the WCET bound engine prices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServiceSnapshot {
    /// TRU stall cycles summed over every initiator's shaper.
    pub tsu_shaping: u64,
    /// Cycles the shared W channel blocked all grants.
    pub w_channel: u64,
    /// HyperRAM/DPLLC channel non-idle cycles.
    pub hyperram: u64,
    /// DCSPM cycles with at least one port in service.
    pub dcspm: u64,
    /// Peripheral region non-idle cycles.
    pub peripheral: u64,
}

impl ServiceSnapshot {
    /// Read the running totals off the simulator's own counters.
    pub fn of(soc: &SocSim) -> Self {
        let tsu_shaping = (0..soc.n_initiators())
            .map(|i| soc.tsu_stats(InitiatorId(i as u8)).tru_stall_cycles)
            .sum();
        Self {
            tsu_shaping,
            w_channel: soc.xbar.w_stall_cycles,
            hyperram: soc.xbar.target_ref(Target::Hyperram).busy_cycles(),
            dcspm: soc.xbar.target_ref(Target::Dcspm).busy_cycles(),
            peripheral: soc.xbar.target_ref(Target::Peripheral).busy_cycles(),
        }
    }

    /// `(resource, cycles)` rows in [`SERVICE_RESOURCES`] order.
    pub fn rows(&self) -> [(Resource, u64); 5] {
        [
            (Resource::TsuShaping, self.tsu_shaping),
            (Resource::WChannel, self.w_channel),
            (Resource::HyperramChannel, self.hyperram),
            (Resource::DcspmPort, self.dcspm),
            (Resource::Peripheral, self.peripheral),
        ]
    }

    /// Component-wise difference (`self` must be the later snapshot of
    /// the same simulator — counters are monotone).
    pub fn since(&self, earlier: &Self) -> Self {
        Self {
            tsu_shaping: self.tsu_shaping - earlier.tsu_shaping,
            w_channel: self.w_channel - earlier.w_channel,
            hyperram: self.hyperram - earlier.hyperram,
            dcspm: self.dcspm - earlier.dcspm,
            peripheral: self.peripheral - earlier.peripheral,
        }
    }
}

/// Between-runs harvester: remembers the last snapshot and returns
/// per-window deltas.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServiceCounters {
    last: ServiceSnapshot,
}

impl ServiceCounters {
    pub fn new() -> Self {
        Self::default()
    }

    /// Per-resource cycles accumulated since the previous harvest (or
    /// since construction), advancing the watermark.
    pub fn harvest(&mut self, soc: &SocSim) -> ServiceSnapshot {
        let now = ServiceSnapshot::of(soc);
        let delta = now.since(&self.last);
        self.last = now;
        delta
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::axi::Target;
    use crate::soc::dma::{DmaEngine, DmaJob};
    use crate::soc::hostd::{HostCore, TctSpec};
    use crate::soc::tsu::TsuConfig;
    use crate::soc::SocSim;

    fn contended_soc() -> SocSim {
        let mut soc = SocSim::new(2, SocSim::carfield_targets());
        soc.attach(
            Box::new(HostCore::new(
                InitiatorId(0),
                TctSpec {
                    accesses: 64,
                    iterations: 2,
                    ..TctSpec::fig6a()
                },
            )),
            TsuConfig::passthrough(),
        );
        let mut dma = DmaEngine::new(InitiatorId(1));
        dma.program(DmaJob {
            src: Target::Hyperram,
            src_addr: 0x10_0000,
            dst: Some(Target::Dcspm),
            dst_addr: 0,
            bytes: 1 << 16,
            chunk_beats: 64,
            outstanding: 2,
            looping: false,
            part_id: 0,
        });
        soc.attach(Box::new(dma), TsuConfig::passthrough());
        soc
    }

    #[test]
    fn harvest_deltas_resum_to_totals_and_drain_to_zero() {
        let mut soc = contended_soc();
        let mut counters = ServiceCounters::new();
        // Harvest in two windows; the deltas must re-sum to the totals.
        soc.run_cycles(2_000);
        let first = counters.harvest(&soc);
        soc.run_cycles(2_000);
        let second = counters.harvest(&soc);
        let totals = ServiceSnapshot::of(&soc);
        assert_eq!(first.since(&ServiceSnapshot::default()), first);
        assert_eq!(
            totals,
            ServiceSnapshot {
                tsu_shaping: first.tsu_shaping + second.tsu_shaping,
                w_channel: first.w_channel + second.w_channel,
                hyperram: first.hyperram + second.hyperram,
                dcspm: first.dcspm + second.dcspm,
                peripheral: first.peripheral + second.peripheral,
            }
        );
        // The mix actually exercised the memory path.
        assert!(totals.hyperram > 0, "HyperRAM never busy: {totals:?}");
        assert!(totals.dcspm > 0, "DCSPM never busy: {totals:?}");
        // An idle window harvests all-zero — counters never drift.
        let idle = counters.harvest(&soc);
        assert_eq!(idle, ServiceSnapshot::default());
        // Rows come out in the stable published order.
        let rows = totals.rows();
        for (row, resource) in rows.iter().zip(SERVICE_RESOURCES) {
            assert_eq!(row.0, resource);
        }
    }
}

//! DMA engines.
//!
//! Three flavours in the SoC (paper Fig. 1): the *system* DMA (the
//! Fig. 6a interferer, streaming HyperRAM -> DCSPM), and the per-cluster
//! DMAs (AMR: 64b/cyc, vector: 512b/cyc toward L1) used for
//! double-buffered L2<->L1 tile transfers.
//!
//! A `DmaEngine` is an AXI initiator: it walks a `DmaJob` chunk by chunk.
//! The system DMA (Carfield's iDMA) is deeply pipelined: it keeps up to
//! `outstanding` read chunks in flight, which fills the downstream
//! memory-controller queue — the mechanism that lets an unregulated bulk
//! copy bury a TCT's cache refills (Fig. 6a). Each completed read spawns
//! the matching write burst when the job has a bus-visible destination.

use std::collections::HashMap;

use super::axi::{Burst, Completion, InitiatorId, Target};
use super::clock::Cycle;
use super::tsu::Tsu;

/// A (possibly looping) memory-to-memory copy descriptor.
#[derive(Debug, Clone)]
pub struct DmaJob {
    pub src: Target,
    pub src_addr: u64,
    /// `None` models a device sink (e.g. the cluster's private L1, which
    /// is not behind the system crossbar): only the read side issues.
    pub dst: Option<Target>,
    pub dst_addr: u64,
    pub bytes: u64,
    /// Chunk size in beats per logical burst (pre-GBS).
    pub chunk_beats: u32,
    /// Read chunks kept in flight simultaneously (iDMA pipelining).
    pub outstanding: u32,
    /// Restart from the beginning upon finishing.
    pub looping: bool,
    /// DPLLC partition for the job's traffic.
    pub part_id: u8,
}

impl DmaJob {
    /// The Fig. 6a interferer: endless HyperRAM -> DCSPM stream with a
    /// deep pipeline.
    pub fn interferer() -> Self {
        Self {
            src: Target::Hyperram,
            src_addr: 0x10_0000,
            dst: Some(Target::Dcspm),
            dst_addr: 0,
            bytes: 1 << 20,
            chunk_beats: 256,
            outstanding: 4,
            looping: true,
            part_id: 0,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Side {
    Read { offset: u64, beats: u32 },
    Write { beats: u32 },
}

/// Per-engine counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct DmaStats {
    pub bytes_moved: u64,
    pub chunks: u64,
    pub loops: u64,
    /// Cycles spent with at least one transfer outstanding.
    pub busy_cycles: u64,
    /// Cycle the first burst of the programmed job was issued (`None`
    /// until something has been put on the bus).
    pub first_issue_at: Option<Cycle>,
    /// Cycle a *finite* job drained its last transfer (0 while running
    /// or looping) — with `first_issue_at`, the makespan feed that lets
    /// `TaskReport.makespan` be nonzero for finite DMA jobs so measured
    /// system-domain utilization stops undercounting them.
    pub drained_at: Cycle,
}

/// The engine.
pub struct DmaEngine {
    pub id: InitiatorId,
    job: Option<DmaJob>,
    /// Next source offset to issue.
    next_offset: u64,
    /// Chunks fully retired (read+write) this pass.
    chunks_done_bytes: u64,
    in_flight: HashMap<u64, Side>,
    tag_seq: u64,
    pub stats: DmaStats,
    /// Completion cycle of the most recent chunk (throughput probes).
    pub last_activity: Cycle,
}

impl DmaEngine {
    pub fn new(id: InitiatorId) -> Self {
        Self {
            id,
            job: None,
            next_offset: 0,
            chunks_done_bytes: 0,
            in_flight: HashMap::new(),
            tag_seq: 0,
            stats: DmaStats::default(),
            last_activity: 0,
        }
    }

    /// Program a job (previous one is replaced).
    pub fn program(&mut self, job: DmaJob) {
        assert!(job.bytes > 0 && job.chunk_beats > 0);
        assert!(job.outstanding >= 1);
        self.job = Some(job);
        self.next_offset = 0;
        self.chunks_done_bytes = 0;
        self.in_flight.clear();
    }

    pub fn abort(&mut self) {
        self.job = None;
        self.in_flight.clear();
    }

    pub fn active(&self) -> bool {
        self.job.is_some()
    }

    /// Transfers currently in flight (pipeline occupancy probe).
    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// True when a non-looping job has moved all its bytes.
    pub fn done(&self) -> bool {
        match &self.job {
            None => true,
            Some(j) => !j.looping && self.chunks_done_bytes >= j.bytes && self.in_flight.is_empty(),
        }
    }

    /// First-issue-to-drain span of a finished finite job (0 while
    /// running, for looping jobs, or before anything was issued).
    pub fn makespan(&self) -> Cycle {
        if self.stats.drained_at == 0 {
            return 0;
        }
        self.stats
            .drained_at
            .saturating_sub(self.stats.first_issue_at.unwrap_or(0))
    }

    fn chunk_beats_at(job: &DmaJob, offset: u64) -> u32 {
        let left = job.bytes - offset;
        let beats_left = left.div_ceil(super::axi::BEAT_BYTES) as u32;
        job.chunk_beats.min(beats_left)
    }

    /// Event-driven hook: `Some(now)` while the engine can issue a new
    /// chunk this cycle (pipeline not full, bytes left); `None` while it
    /// is drained or waiting on completions to free pipeline slots.
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        let job = self.job.as_ref()?;
        if (self.in_flight.len() as u32) < job.outstanding
            && (self.next_offset < job.bytes || job.looping)
        {
            return Some(now);
        }
        None
    }

    /// Replay per-cycle busy accounting over a skipped window `[from,
    /// to)`: a naive run ticks every cycle and counts one busy cycle per
    /// tick with transfers outstanding.
    pub fn fast_forward(&mut self, from: Cycle, to: Cycle) {
        if self.job.is_some() && !self.in_flight.is_empty() {
            self.stats.busy_cycles += to - from;
        }
    }

    /// Issue work into this engine's TSU; call once per cycle.
    pub fn tick(&mut self, now: Cycle, tsu: &mut Tsu) {
        let Some(job) = self.job.clone() else {
            return;
        };
        if !self.in_flight.is_empty() {
            self.stats.busy_cycles += 1;
        }
        // Keep the read pipeline full (one new issue per cycle).
        if (self.in_flight.len() as u32) < job.outstanding {
            if self.next_offset >= job.bytes {
                if job.looping {
                    self.next_offset = 0;
                    self.stats.loops += 1;
                } else {
                    return;
                }
            }
            let offset = self.next_offset;
            let beats = Self::chunk_beats_at(&job, offset);
            self.tag_seq += 1;
            let mut b = Burst::read(self.id, job.src, job.src_addr + offset, beats)
                .with_part(job.part_id)
                .with_tag(self.tag_seq);
            b.issued_at = now;
            tsu.submit(b, now);
            if self.stats.first_issue_at.is_none() {
                self.stats.first_issue_at = Some(now);
            }
            self.in_flight.insert(self.tag_seq, Side::Read { offset, beats });
            self.next_offset += beats as u64 * super::axi::BEAT_BYTES;
        }
    }

    /// Deliver a bus completion; reads chain into their writes.
    pub fn complete(&mut self, c: Completion, now: Cycle, tsu: &mut Tsu) {
        if !c.last_fragment {
            return;
        }
        let Some(side) = self.in_flight.remove(&c.tag) else {
            return;
        };
        let Some(job) = self.job.clone() else {
            return;
        };
        match side {
            Side::Read { offset, beats } => {
                if let Some(dst) = job.dst {
                    self.tag_seq += 1;
                    let mut w = Burst::write(self.id, dst, job.dst_addr + offset % (1 << 19), beats)
                        .with_part(job.part_id)
                        .with_tag(self.tag_seq);
                    w.issued_at = now;
                    tsu.submit(w, now);
                    self.in_flight.insert(self.tag_seq, Side::Write { beats });
                } else {
                    self.finish_chunk(beats, now);
                }
            }
            Side::Write { beats } => self.finish_chunk(beats, now),
        }
    }

    fn finish_chunk(&mut self, beats: u32, now: Cycle) {
        let bytes = beats as u64 * super::axi::BEAT_BYTES;
        self.chunks_done_bytes += bytes;
        if let Some(j) = &self.job {
            if j.looping {
                self.chunks_done_bytes %= j.bytes.max(1);
            }
        }
        self.stats.bytes_moved += bytes;
        self.stats.chunks += 1;
        self.last_activity = now;
        if self.stats.drained_at == 0 && self.done() {
            self.stats.drained_at = now;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::axi::xbar::Crossbar;
    use crate::soc::axi::TargetModel;
    use crate::soc::mem::Dcspm;
    use crate::soc::tsu::TsuConfig;

    /// Drive one DMA engine against a DCSPM-only crossbar.
    fn drive(engine: &mut DmaEngine, tsu: &mut Tsu, cycles: Cycle) -> Vec<Completion> {
        let mut xbar = Crossbar::new(1, vec![Box::new(Dcspm::new()) as Box<dyn TargetModel>]);
        let mut all = Vec::new();
        let mut staged = Vec::new();
        for now in 0..cycles {
            engine.tick(now, tsu);
            staged.clear();
            tsu.release(now, &mut staged);
            for b in staged.drain(..) {
                xbar.push(b);
            }
            xbar.tick(now);
            for c in xbar.take_completions() {
                engine.complete(c, now, tsu);
                all.push(c);
            }
        }
        all
    }

    fn job(bytes: u64, looping: bool) -> DmaJob {
        DmaJob {
            src: Target::Dcspm,
            src_addr: 0,
            dst: Some(Target::Dcspm),
            dst_addr: 0x8000,
            bytes,
            chunk_beats: 16,
            outstanding: 1,
            looping,
            part_id: 0,
        }
    }

    #[test]
    fn copies_all_bytes_then_stops() {
        let mut e = DmaEngine::new(InitiatorId(0));
        let mut tsu = Tsu::new(TsuConfig::passthrough());
        e.program(job(1024, false));
        drive(&mut e, &mut tsu, 4000);
        assert!(e.done());
        // bytes_moved counts logical bytes copied once per chunk pair.
        assert_eq!(e.stats.bytes_moved, 1024);
        assert_eq!(e.stats.chunks, 1024 / (16 * 8));
    }

    #[test]
    fn finite_job_records_first_issue_to_drain_makespan() {
        let mut e = DmaEngine::new(InitiatorId(0));
        let mut tsu = Tsu::new(TsuConfig::passthrough());
        e.program(job(1024, false));
        assert_eq!(e.makespan(), 0, "no makespan before the job drains");
        drive(&mut e, &mut tsu, 4000);
        assert!(e.done());
        assert_eq!(e.stats.first_issue_at, Some(0), "issues on the first tick");
        let span = e.makespan();
        assert!(span > 0 && span < 4000, "span={span}");
        assert_eq!(span, e.stats.drained_at, "first issue at cycle 0");
    }

    #[test]
    fn looping_job_never_reports_a_makespan() {
        let mut e = DmaEngine::new(InitiatorId(0));
        let mut tsu = Tsu::new(TsuConfig::passthrough());
        e.program(job(256, true));
        drive(&mut e, &mut tsu, 3000);
        assert_eq!(e.stats.drained_at, 0);
        assert_eq!(e.makespan(), 0);
    }

    #[test]
    fn looping_job_never_finishes() {
        let mut e = DmaEngine::new(InitiatorId(0));
        let mut tsu = Tsu::new(TsuConfig::passthrough());
        e.program(job(256, true));
        drive(&mut e, &mut tsu, 3000);
        assert!(!e.done());
        assert!(e.stats.loops > 1, "loops={}", e.stats.loops);
    }

    #[test]
    fn read_only_job_skips_write_side() {
        let mut e = DmaEngine::new(InitiatorId(0));
        let mut tsu = Tsu::new(TsuConfig::passthrough());
        let mut j = job(512, false);
        j.dst = None;
        e.program(j);
        let comps = drive(&mut e, &mut tsu, 2000);
        assert!(e.done());
        assert!(comps.iter().all(|c| !c.write));
    }

    #[test]
    fn gbs_fragments_do_not_confuse_progress() {
        let mut e = DmaEngine::new(InitiatorId(0));
        let mut tsu = Tsu::new(TsuConfig {
            gbs_max_beats: 4,
            ..TsuConfig::passthrough()
        });
        e.program(job(512, false));
        drive(&mut e, &mut tsu, 8000);
        assert!(e.done());
        assert_eq!(e.stats.bytes_moved, 512);
    }

    #[test]
    fn outstanding_keeps_pipeline_full() {
        // The point of `outstanding` is occupancy: a deep pipeline keeps
        // the downstream controller queue full (the Fig. 6a interference
        // mechanism), whereas a serial engine holds one chunk at most.
        let probe = |outstanding: u32| {
            let mut e = DmaEngine::new(InitiatorId(0));
            let mut tsu = Tsu::new(TsuConfig::passthrough());
            let mut j = job(1 << 20, true);
            j.dst = None;
            j.outstanding = outstanding;
            e.program(j);
            let mut xbar =
                Crossbar::new(1, vec![Box::new(Dcspm::new()) as Box<dyn TargetModel>]);
            let mut staged = Vec::new();
            let mut peak = 0;
            for now in 0..2000 {
                e.tick(now, &mut tsu);
                staged.clear();
                tsu.release(now, &mut staged);
                for b in staged.drain(..) {
                    xbar.push(b);
                }
                xbar.tick(now);
                for c in xbar.take_completions() {
                    e.complete(c, now, &mut tsu);
                }
                peak = peak.max(e.in_flight());
            }
            peak
        };
        assert_eq!(probe(1), 1);
        assert_eq!(probe(4), 4);
    }

    #[test]
    fn abort_stops_engine() {
        let mut e = DmaEngine::new(InitiatorId(0));
        let mut tsu = Tsu::new(TsuConfig::passthrough());
        e.program(job(4096, true));
        e.abort();
        assert!(e.done());
        drive(&mut e, &mut tsu, 100);
        assert_eq!(e.stats.bytes_moved, 0);
    }

    #[test]
    fn partial_last_chunk() {
        let mut e = DmaEngine::new(InitiatorId(0));
        let mut tsu = Tsu::new(TsuConfig::passthrough());
        // 300 bytes = 2 full chunks + a 38-beat tail.
        let mut j = job(300, false);
        j.dst = None;
        e.program(j);
        drive(&mut e, &mut tsu, 2000);
        assert!(e.done());
        assert!(e.stats.bytes_moved >= 300);
    }
}

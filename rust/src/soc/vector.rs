//! Vector cluster: two compact RISC-V Vector Units (RVVU, Zve64d,
//! VLEN=512) behind a 16-bank 1024b/cyc L1 SPM, with a third scalar core
//! managing a 512b/cyc DMA for double-buffered L2-L1 transfers (paper
//! §II "Compact, Efficient, RV Vector Cluster").
//!
//! Performance calibration (paper Fig. 5c/d, Fig. 8):
//! - MatMul FLOP/cyc: FP64 15.67 (97.9% FPU utilization of the 16-lane
//!   peak), FP32 31.3, FP16/BF16 61.5, FP8 121.8 — peak 122 GFLOPS @1GHz.
//! - FFT runs at a lower utilization (strided/indexed VLSU accesses eat
//!   issue slots): ~55% of the MatMul rate.
//! - 23.8x–190.3x speedup over the HOSTD scalar core (0.65 FLOP/cyc).

use super::axi::{Completion, InitiatorId};
use super::clock::Cycle;
use super::tiles::{TileStream, TileStreamer};
use super::tsu::Tsu;

/// FP formats supported by the RVVUs (full range, incl. mixed FP8xFP16).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FpFormat {
    Fp64,
    Fp32,
    Fp16,
    Bf16,
    Fp8,
    Fp8x16,
}

impl FpFormat {
    pub const ALL: [FpFormat; 6] = [
        FpFormat::Fp64,
        FpFormat::Fp32,
        FpFormat::Fp16,
        FpFormat::Bf16,
        FpFormat::Fp8,
        FpFormat::Fp8x16,
    ];

    /// Element bytes of the wider operand (DMA footprint).
    pub fn elem_bytes(&self) -> u64 {
        match self {
            FpFormat::Fp64 => 8,
            FpFormat::Fp32 => 4,
            FpFormat::Fp16 | FpFormat::Bf16 | FpFormat::Fp8x16 => 2,
            FpFormat::Fp8 => 1,
        }
    }

    /// Cluster MatMul FLOP/cyc (both RVVUs, paper-calibrated; 2 FLOP =
    /// 1 MAC). Mixed FP8xFP16 runs at the FP16 rate (wider operand).
    pub fn matmul_flop_per_cyc(&self) -> f64 {
        match self {
            FpFormat::Fp64 => 15.67,
            FpFormat::Fp32 => 31.3,
            FpFormat::Fp16 | FpFormat::Bf16 | FpFormat::Fp8x16 => 61.5,
            FpFormat::Fp8 => 121.8,
        }
    }

    /// Hardware peak FLOP/cyc (2 units x lanes); utilization =
    /// matmul rate / peak (97.9% at FP64).
    pub fn peak_flop_per_cyc(&self) -> f64 {
        match self {
            FpFormat::Fp64 => 16.0,
            FpFormat::Fp32 => 32.0,
            FpFormat::Fp16 | FpFormat::Bf16 | FpFormat::Fp8x16 => 64.0,
            FpFormat::Fp8 => 128.0,
        }
    }

    /// Relative dynamic-power factor vs the FP64 datapath at equal
    /// frequency: narrower formats toggle fewer FPU lanes per FLOP and
    /// less VRF width per operand. Calibrated so the four per-format
    /// efficiencies of Fig. 8 (86.9 / 197.8 / 457.8 / 1068.7 GFLOPS/W)
    /// all come out of one DVFS curve.
    pub fn power_factor(&self) -> f64 {
        match self {
            FpFormat::Fp64 => 1.0,
            FpFormat::Fp32 => 0.878,
            FpFormat::Fp16 | FpFormat::Bf16 | FpFormat::Fp8x16 => 0.745,
            FpFormat::Fp8 => 0.632,
        }
    }

    /// Matching AOT artifact (functional model).
    pub fn artifact(&self) -> &'static str {
        match self {
            FpFormat::Fp64 => "matmul_fp64",
            FpFormat::Fp32 => "matmul_fp32",
            FpFormat::Fp16 => "matmul_fp16",
            FpFormat::Bf16 => "matmul_bf16",
            FpFormat::Fp8 => "matmul_fp8",
            FpFormat::Fp8x16 => "matmul_fp8x16",
        }
    }
}

/// HOSTD scalar FP rate (FLOP/cyc) used for the paper's 23.8x–190.3x
/// speedup comparison.
pub const HOST_FLOP_PER_CYC: f64 = 0.65;

/// FFT utilization factor relative to MatMul (VLSU indexed accesses).
pub const FFT_UTIL: f64 = 0.55;

/// Work submitted to the cluster.
#[derive(Debug, Clone)]
pub enum VectorWork {
    /// C[m,n] = A[m,k] B[k,n], tiled t x t x t.
    MatMul { m: u32, k: u32, n: u32, tile: u32 },
    /// `batch` independent n-point complex FFTs.
    Fft { n: u32, batch: u32 },
}

/// A vector-cluster task with its L2 staging layout.
#[derive(Debug, Clone)]
pub struct VectorTask {
    pub format: FpFormat,
    pub work: VectorWork,
    pub src_base: u64,
    pub dst_base: u64,
    pub part_id: u8,
}

impl VectorTask {
    /// (tiles, flops/tile, in_beats/tile, out_beats/tile).
    pub fn tiling(&self) -> (u32, u64, u32, u32) {
        match self.work {
            VectorWork::MatMul { m, k, n, tile } => {
                let tm = m.div_ceil(tile);
                let tk = k.div_ceil(tile);
                let tn = n.div_ceil(tile);
                let flops = 2 * (tile as u64).pow(3);
                let in_bytes = 2 * (tile as u64 * tile as u64) * self.format.elem_bytes();
                let out_bytes = tile as u64 * tile as u64 * 4; // f32 acc
                (
                    tm * tk * tn,
                    flops,
                    in_bytes.div_ceil(8).max(1) as u32,
                    out_bytes.div_ceil(8).max(1) as u32,
                )
            }
            VectorWork::Fft { n, batch } => {
                let flops = 5 * n as u64 * (n as f64).log2() as u64;
                let bytes = 2 * n as u64 * self.format.elem_bytes().max(4);
                (
                    batch,
                    flops,
                    bytes.div_ceil(8).max(1) as u32,
                    bytes.div_ceil(8).max(1) as u32,
                )
            }
        }
    }

    /// Effective FLOP/cyc for this work type.
    pub fn flop_per_cyc(&self) -> f64 {
        match self.work {
            VectorWork::MatMul { .. } => self.format.matmul_flop_per_cyc(),
            VectorWork::Fft { .. } => self.format.matmul_flop_per_cyc() * FFT_UTIL,
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct VectorStats {
    pub compute_cycles: u64,
    pub stall_cycles: u64,
    pub flops: u64,
    pub tiles_done: u32,
    pub finished_at: Cycle,
}

impl VectorStats {
    pub fn effective_flop_per_cyc(&self, start: Cycle) -> f64 {
        let span = self.finished_at.saturating_sub(start).max(1);
        self.flops as f64 / span as f64
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Idle,
    Computing { until: Cycle, tile: u32 },
}

/// The dual-RVVU cluster simulator (bus initiator = its DMA).
pub struct VectorCluster {
    pub id: InitiatorId,
    /// Cluster cycles per system cycle.
    pub freq_ratio: f64,
    task: Option<VectorTask>,
    streamer: Option<TileStreamer>,
    state: State,
    pub stats: VectorStats,
    flops_per_tile: u64,
    task_started: Cycle,
    tiles_total: u32,
}

impl VectorCluster {
    pub fn new(id: InitiatorId) -> Self {
        Self {
            id,
            freq_ratio: 1.0,
            task: None,
            streamer: None,
            state: State::Idle,
            stats: VectorStats::default(),
            flops_per_tile: 0,
            task_started: 0,
            tiles_total: 0,
        }
    }

    pub fn submit(&mut self, task: VectorTask, now: Cycle) {
        let (tiles, flops, in_beats, out_beats) = task.tiling();
        self.streamer = Some(TileStreamer::new(
            self.id,
            TileStream {
                tiles,
                in_beats,
                out_beats,
                src_base: task.src_base,
                dst_base: task.dst_base,
                part_id: task.part_id,
                buffer_depth: super::tiles::CLUSTER_BUFFER_DEPTH,
                wrap_bytes: crate::coordinator::policy::SocTuning::L2_SLOT_BYTES / 2,
            },
        ));
        self.flops_per_tile = flops;
        self.tiles_total = tiles;
        self.task = Some(task);
        self.task_started = now;
        self.stats = VectorStats::default();
    }

    fn tile_cycles(&self) -> Cycle {
        let task = self.task.as_ref().expect("no task");
        Self::tile_compute_bound(task, self.freq_ratio)
    }

    /// Deterministic per-tile compute time — the exact duration the FSM
    /// uses, exposed for the WCET engine.
    pub fn tile_compute_bound(task: &VectorTask, freq_ratio: f64) -> Cycle {
        let (_, flops, _, _) = task.tiling();
        let rate = task.flop_per_cyc() * freq_ratio;
        (flops as f64 / rate).ceil() as Cycle
    }

    /// Worst observed L2 transfer latency (WCET measured counterpart).
    pub fn mem_latency_max(&self) -> Cycle {
        self.streamer.as_ref().map_or(0, |s| s.max_latency)
    }

    pub fn task_done(&self) -> bool {
        match &self.streamer {
            Some(s) => s.done() && self.state == State::Idle,
            None => true,
        }
    }

    /// Event-driven hook: min of the tile-DMA side and the compute
    /// completion time; `None` while waiting on bus completions or done.
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        let mut earliest = self.streamer.as_ref().and_then(|s| s.next_event(now));
        let engine = match self.state {
            State::Idle => {
                if self.task.is_some()
                    && self.streamer.as_ref().is_some_and(|s| s.ready_tiles() > 0)
                {
                    Some(now)
                } else {
                    None
                }
            }
            State::Computing { until, .. } => Some(until.max(now)),
        };
        if let Some(t) = engine {
            earliest = super::clock::merge_event(earliest, t);
        }
        earliest
    }

    /// Replay per-cycle accounting over a skipped window `[from, to)`.
    pub fn fast_forward(&mut self, from: Cycle, to: Cycle) {
        if let Some(s) = self.streamer.as_mut() {
            s.fast_forward(from, to);
        }
        if self.state == State::Idle && self.task.is_some() {
            if let Some(s) = &self.streamer {
                if s.ready_tiles() == 0 && !s.fetches_done() {
                    self.stats.stall_cycles += to - from;
                }
            }
        }
    }

    pub fn tick(&mut self, now: Cycle, tsu: &mut Tsu) {
        if let Some(s) = self.streamer.as_mut() {
            s.tick(now, tsu);
        }
        match self.state {
            State::Computing { until, tile } => {
                if now >= until {
                    self.stats.flops += self.flops_per_tile;
                    self.stats.tiles_done += 1;
                    if let Some(s) = self.streamer.as_mut() {
                        s.push_writeback(tile);
                    }
                    self.state = State::Idle;
                    self.update_finish(now);
                }
            }
            State::Idle => {
                if self.task.is_none() {
                    return;
                }
                if let Some(s) = self.streamer.as_mut() {
                    if let Some(tile) = s.pop_ready() {
                        let dur = self.tile_cycles();
                        self.stats.compute_cycles += dur;
                        self.state = State::Computing {
                            until: now + dur,
                            tile,
                        };
                    } else if !s.fetches_done() {
                        self.stats.stall_cycles += 1;
                    }
                }
                self.update_finish(now);
            }
        }
    }

    fn update_finish(&mut self, now: Cycle) {
        if let Some(s) = &self.streamer {
            if s.done() && self.stats.tiles_done >= self.tiles_total && self.stats.finished_at == 0
            {
                self.stats.finished_at = now;
            }
        }
    }

    pub fn complete(&mut self, c: Completion, now: Cycle) {
        if let Some(s) = self.streamer.as_mut() {
            s.complete(c, now);
        }
        self.update_finish(now);
    }

    /// Analytic peak GFLOPS at voltage `v` (Fig. 5c).
    pub fn peak_gflops(format: FpFormat, v: f64) -> f64 {
        let f = super::power::DvfsCurve::vector().freq_mhz(v);
        format.matmul_flop_per_cyc() * f / 1000.0
    }

    /// Active power at voltage `v` when running `format` work (mW).
    pub fn power_mw(format: FpFormat, v: f64) -> f64 {
        let curve = super::power::DvfsCurve::vector();
        let f = curve.freq_mhz(v);
        // Scale the dynamic term by the format's datapath activity.
        curve.k * v.powf(curve.alpha) * f * format.power_factor() + curve.idle_mw
    }

    /// Analytic efficiency in GFLOPS/W at voltage `v` (Fig. 5d).
    pub fn efficiency_gflops_w(format: FpFormat, v: f64) -> f64 {
        Self::peak_gflops(format, v) / (Self::power_mw(format, v) / 1000.0)
    }

    /// Speedup over the HOSTD scalar core for a MatMul in `format`.
    pub fn speedup_vs_host(format: FpFormat) -> f64 {
        format.matmul_flop_per_cyc() / HOST_FLOP_PER_CYC
    }
}

impl super::BusInitiator for VectorCluster {
    fn id(&self) -> InitiatorId {
        self.id
    }
    fn tick(&mut self, now: Cycle, tsu: &mut Tsu) {
        VectorCluster::tick(self, now, tsu)
    }
    fn complete(&mut self, c: Completion, now: Cycle, _tsu: &mut Tsu) {
        VectorCluster::complete(self, c, now)
    }
    fn finished(&self) -> bool {
        self.task_done()
    }
    fn next_event(&self, now: Cycle) -> Option<Cycle> {
        VectorCluster::next_event(self, now)
    }
    fn fast_forward(&mut self, from: Cycle, to: Cycle) {
        VectorCluster::fast_forward(self, from, to)
    }
    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::axi::TargetModel;
    use crate::soc::mem::Dcspm;
    use crate::soc::tsu::TsuConfig;
    use crate::soc::SocSim;

    fn matmul(format: FpFormat) -> VectorTask {
        VectorTask {
            format,
            work: VectorWork::MatMul {
                m: 64,
                k: 64,
                n: 64,
                tile: 32,
            },
            src_base: 0,
            dst_base: 0x8_0000,
            part_id: 0,
        }
    }

    fn run(mut cluster: VectorCluster, t: VectorTask) -> VectorStats {
        let mut soc = SocSim::new(1, vec![Box::new(Dcspm::new()) as Box<dyn TargetModel>]);
        cluster.submit(t, 0);
        soc.attach(Box::new(cluster), TsuConfig::passthrough());
        assert!(soc.run_until_done(50_000_000));
        let c: &mut VectorCluster = soc.initiator_mut(InitiatorId(0));
        c.stats
    }

    #[test]
    fn peak_gflops_match_fig8() {
        let cases = [
            (FpFormat::Fp64, 15.7),
            (FpFormat::Fp32, 31.3),
            (FpFormat::Fp16, 61.5),
            (FpFormat::Fp8, 121.8),
        ];
        for (f, want) in cases {
            let got = VectorCluster::peak_gflops(f, 1.1);
            assert!((got - want).abs() / want < 0.01, "{f:?}: {got} vs {want}");
        }
    }

    #[test]
    fn fpu_utilization_is_paper_level() {
        let u = FpFormat::Fp64.matmul_flop_per_cyc() / FpFormat::Fp64.peak_flop_per_cyc();
        assert!((u - 0.979).abs() < 0.001, "{u}");
    }

    #[test]
    fn efficiency_matches_fig8_at_low_v() {
        // Paper Fig. 8: 86.9 / 197.8 / 457.8 / 1068.7 GFLOPS/W.
        let cases = [
            (FpFormat::Fp64, 86.9),
            (FpFormat::Fp32, 197.8),
            (FpFormat::Fp16, 457.8),
            (FpFormat::Fp8, 1068.7),
        ];
        for (f, want) in cases {
            let got = VectorCluster::efficiency_gflops_w(f, 0.6);
            assert!((got - want).abs() / want < 0.05, "{f:?}: {got} vs {want}");
        }
    }

    #[test]
    fn speedups_over_host_match_paper_range() {
        let lo = VectorCluster::speedup_vs_host(FpFormat::Fp64);
        let hi = VectorCluster::speedup_vs_host(FpFormat::Fp8);
        assert!((lo - 23.8).abs() / 23.8 < 0.05, "{lo}");
        assert!((hi - 190.3).abs() / 190.3 < 0.05, "{hi}");
    }

    #[test]
    fn matmul_task_completes() {
        let s = run(VectorCluster::new(InitiatorId(0)), matmul(FpFormat::Fp32));
        assert_eq!(s.tiles_done, 8);
        assert_eq!(s.flops, 8 * 2 * 32u64.pow(3));
    }

    #[test]
    fn fp8_outruns_fp64() {
        let s8 = run(VectorCluster::new(InitiatorId(0)), matmul(FpFormat::Fp8));
        let s64 = run(VectorCluster::new(InitiatorId(0)), matmul(FpFormat::Fp64));
        assert!(s8.finished_at < s64.finished_at);
    }

    #[test]
    fn fft_task_completes_at_reduced_utilization() {
        let t = VectorTask {
            format: FpFormat::Fp32,
            work: VectorWork::Fft { n: 256, batch: 16 },
            src_base: 0,
            dst_base: 0x8_0000,
            part_id: 0,
        };
        let s = run(VectorCluster::new(InitiatorId(0)), t.clone());
        assert_eq!(s.tiles_done, 16);
        // Effective rate is below the MatMul rate.
        let eff = s.effective_flop_per_cyc(0);
        assert!(eff < FpFormat::Fp32.matmul_flop_per_cyc());
        assert!(eff > 0.2 * FpFormat::Fp32.matmul_flop_per_cyc());
        let _ = t;
    }

    #[test]
    fn artifact_names_exist_for_all_formats() {
        for f in FpFormat::ALL {
            assert!(f.artifact().starts_with("matmul_"));
        }
    }
}

//! Traffic Shaper Unit (TSU) — paper Fig. 2a.
//!
//! One TSU fronts each AXI initiator, between the initiator and its
//! crossbar input queue. Three software-programmable components:
//!
//! 1. **GBS** (granular burst splitter): fragments long AXI4 bursts to a
//!    configurable size so asynchronous burst-capable initiators running
//!    NCTs arbitrate fairly against higher-priority TCT initiators.
//! 2. **WB** (write buffer): buffers AW+W and forwards the request only
//!    once the write data is fully inside the buffer, so a slow initiator
//!    can never stall the W channel. Costs at most 1 extra cycle of
//!    latency (measured by the Fig. 6a bench).
//! 3. **TRU** (traffic regulation unit): a fixed transfer budget (beats)
//!    per configurable communication period; bursts beyond the budget
//!    wait for the next period.
//!
//! All three are runtime-(re)configurable — the coordinator programs them
//! when criticality mixes change (paper: "software-programmable ... at
//! zero performance overhead").
//!
//! Owning clock domain: **system**. The TSUs sit at each initiator's bus
//! entry, clocked with the host/interconnect domain — so `tru_period`
//! and the arrival curve of [`TsuConfig::max_beats_in_window`] are
//! system-clock cycles. That keeps arrival curves frequency-invariant
//! *in cycles* across DVFS points (the governor's domain-flooring
//! argument), while the uncore split makes the *service* side of the
//! bound wall-clock-invariant instead.

use std::collections::VecDeque;

use crate::soc::axi::Burst;
use crate::soc::clock::Cycle;

/// Software-visible TSU configuration registers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TsuConfig {
    /// GBS: max beats per fragment; 0 disables splitting.
    pub gbs_max_beats: u32,
    /// WB: enable write buffering.
    pub wb_enable: bool,
    /// WB capacity in beats (AXI-REALM-style small SRAM).
    pub wb_capacity_beats: u32,
    /// TRU: beats allowed per period; 0 disables regulation.
    pub tru_budget_beats: u32,
    /// TRU: communication period in cycles.
    pub tru_period: Cycle,
}

impl TsuConfig {
    /// Transparent shaper (reset state): everything passes through.
    pub fn passthrough() -> Self {
        Self {
            gbs_max_beats: 0,
            wb_enable: false,
            wb_capacity_beats: 0,
            tru_budget_beats: 0,
            tru_period: 0,
        }
    }

    /// Write buffering only — no splitting or rate limiting. This is the
    /// "TSU present but not regulating" configuration: it removes
    /// W-channel holds at <=1 cycle cost (paper §II).
    pub fn wb_only() -> Self {
        Self {
            gbs_max_beats: 0,
            wb_enable: true,
            wb_capacity_beats: 512,
            tru_budget_beats: 0,
            tru_period: 0,
        }
    }

    /// A typical NCT-throttling profile used in the Fig. 6 experiments.
    pub fn regulated(max_beats: u32, budget: u32, period: Cycle) -> Self {
        Self {
            gbs_max_beats: max_beats,
            wb_enable: true,
            wb_capacity_beats: 2 * max_beats.max(8),
            tru_budget_beats: budget,
            tru_period: period,
        }
    }

    /// Whether the TRU actually regulates (a budget with a refill
    /// period). A budget with `tru_period == 0` would never refill and
    /// silently starve the initiator; [`Tsu::new`] rejects it.
    pub fn is_tru_regulated(&self) -> bool {
        self.tru_budget_beats > 0 && self.tru_period > 0
    }

    /// GBS fragment size for a logical burst of `beats`.
    pub fn fragment_beats(&self, beats: u32) -> u32 {
        if self.gbs_max_beats == 0 {
            beats
        } else {
            self.gbs_max_beats.min(beats).max(1)
        }
    }

    /// TRU arrival curve: the most beats this shaper can release into
    /// the crossbar in *any* window of `window` cycles. A window can
    /// straddle partial periods at *both* ends — an initiator that sat
    /// on an untouched budget can drain it in the last cycle of one
    /// period and drain the refilled budget right after the boundary —
    /// so the sound count is `floor(window/period) + 2` budgets, not
    /// `+1`. `None` when unregulated — the arrival is unbounded and
    /// only structural (queue-depth) interference bounds apply.
    ///
    /// This is the compositional hook the `wcet` bound engine builds its
    /// busy-window analysis on.
    pub fn max_beats_in_window(&self, window: Cycle) -> Option<u64> {
        if !self.is_tru_regulated() {
            return None;
        }
        Some(self.tru_budget_beats as u64 * (window / self.tru_period + 2))
    }
}

/// Counters exposed for observability (the paper stresses observability
/// *and* controllability of shared resources). Aggregate totals only —
/// the per-release picture (which fragment waited, how long, on which
/// budget) surfaces as `TsuRelease` events through `SocSim` tracing
/// ([`crate::trace`]) when a scenario arms it.
#[derive(Debug, Clone, Copy, Default)]
pub struct TsuStats {
    pub bursts_in: u64,
    pub fragments_out: u64,
    pub beats_out: u64,
    pub tru_stall_cycles: u64,
    pub wb_extra_cycles: u64,
}

/// The shaper instance for one initiator.
#[derive(Debug)]
pub struct Tsu {
    pub config: TsuConfig,
    /// Fragments waiting for TRU budget / WB fill.
    pending: VecDeque<PendingFragment>,
    /// Remaining TRU budget in the current period.
    budget_left: u32,
    /// Cycle at which the current TRU period started.
    period_start: Cycle,
    pub stats: TsuStats,
}

#[derive(Debug)]
struct PendingFragment {
    burst: Burst,
    /// Earliest cycle this fragment may be released (WB fill time).
    eligible_at: Cycle,
}

impl Tsu {
    /// A TRU budget whose period never elapses (`tru_period == 0`) can
    /// never refill: after the first budget's worth of beats the shaper
    /// would silently starve its initiator forever. That is a
    /// misconfiguration, not a regulation profile — reject it loudly at
    /// programming time instead of deadlocking at runtime.
    fn check(config: &TsuConfig) {
        assert!(
            config.tru_budget_beats == 0 || config.tru_period > 0,
            "TSU misconfiguration: TRU budget {} with period 0 never \
             refills and starves the initiator; use budget 0 \
             (unregulated) or a nonzero period",
            config.tru_budget_beats
        );
    }

    pub fn new(config: TsuConfig) -> Self {
        Self::check(&config);
        Self {
            budget_left: config.tru_budget_beats,
            period_start: 0,
            pending: VecDeque::new(),
            config,
            stats: TsuStats::default(),
        }
    }

    /// Reprogram at runtime (zero-cost, like the memory-mapped regs).
    /// Fragments already buffered inside the shaper are preserved — a
    /// reconfiguration must never drop beats in flight; only the
    /// regulation applied to them changes.
    pub fn reconfigure(&mut self, config: TsuConfig) {
        Self::check(&config);
        self.config = config;
        self.budget_left = config.tru_budget_beats;
    }

    /// Accept a burst from the initiator. GBS fragments it; WB schedules
    /// write eligibility.
    pub fn submit(&mut self, burst: Burst, now: Cycle) {
        self.stats.bursts_in += 1;
        let max = if self.config.gbs_max_beats == 0 {
            burst.beats
        } else {
            self.config.gbs_max_beats.min(burst.beats).max(1)
        };
        let n_frags = burst.beats.div_ceil(max);
        let mut remaining = burst.beats;
        let mut addr = burst.addr;
        for f in 0..n_frags {
            let beats = remaining.min(max);
            let mut frag = burst.clone();
            frag.addr = addr;
            frag.beats = beats;
            frag.fragments_left = n_frags - 1 - f;
            // WB: a write fragment becomes eligible once its data has
            // streamed into the buffer — 1 cycle when the buffer has
            // room (the paper's "at most 1 clock cycle" overhead),
            // `beats` cycles when it must drain first. Buffered writes
            // release the W channel in a single clean burst.
            frag.wb_buffered = burst.write && self.config.wb_enable;
            let eligible_at = if burst.write && self.config.wb_enable {
                let fill = if self.buffered_beats() + beats <= self.config.wb_capacity_beats {
                    1
                } else {
                    beats as Cycle
                };
                self.stats.wb_extra_cycles += 1;
                now + fill
            } else {
                now
            };
            self.pending.push_back(PendingFragment {
                burst: frag,
                eligible_at,
            });
            addr += beats as u64 * crate::soc::axi::BEAT_BYTES;
            remaining -= beats;
            self.stats.fragments_out += 1;
        }
    }

    fn buffered_beats(&self) -> u32 {
        self.pending
            .iter()
            .filter(|p| p.burst.write)
            .map(|p| p.burst.beats)
            .sum()
    }

    /// Number of fragments queued inside the shaper.
    pub fn queued(&self) -> usize {
        self.pending.len()
    }

    /// Event-driven hook: the earliest cycle `>= now` at which
    /// [`Tsu::release`] can make progress (release a fragment) — the
    /// head fragment's WB-eligibility time, or the next TRU period
    /// boundary when the head is budget-blocked. `None` when the shaper
    /// is empty or blocked forever (budget enabled with period 0).
    ///
    /// Release calls in `now..event` are no-ops apart from the per-cycle
    /// TRU stall accounting, which [`Tsu::fast_forward`] replays.
    pub fn next_release_at(&self, now: Cycle) -> Option<Cycle> {
        let head = self.pending.front()?;
        if head.eligible_at > now {
            return Some(head.eligible_at);
        }
        if self.head_blocked() {
            if self.config.tru_period == 0 {
                return None; // budget never refills: dormant forever
            }
            // `release` ran last cycle (the shaper is non-empty), so
            // `period_start` is caught up and the budget refills at
            // exactly the next boundary.
            return Some((self.period_start + self.config.tru_period).max(now));
        }
        Some(now)
    }

    /// Replay the per-cycle accounting of a skipped quiescent window
    /// `[from, to)`: a naive run calls `release` once per cycle, which
    /// counts one TRU stall per cycle while the head fragment is
    /// eligible but over budget.
    pub fn fast_forward(&mut self, from: Cycle, to: Cycle) {
        let Some(head) = self.pending.front() else {
            return;
        };
        if head.eligible_at <= from && self.head_blocked() {
            self.stats.tru_stall_cycles += to - from;
        }
    }

    /// The single TRU blocking predicate shared by [`Tsu::release`],
    /// [`Tsu::next_release_at`] and [`Tsu::fast_forward`]: the head
    /// fragment exceeds the remaining budget AND is not the oversize
    /// exception (a fragment larger than the whole per-period budget
    /// passes when the budget is untouched — regulators must not
    /// deadlock oversize transactions).
    fn head_blocked(&self) -> bool {
        let Some(head) = self.pending.front() else {
            return false;
        };
        if self.config.tru_budget_beats == 0 || head.burst.beats <= self.budget_left {
            return false;
        }
        let oversize = head.burst.beats > self.config.tru_budget_beats
            && self.budget_left == self.config.tru_budget_beats;
        !oversize
    }

    /// Release eligible fragments for this cycle, respecting the TRU
    /// budget. Returned bursts go straight into the crossbar queue.
    pub fn release(&mut self, now: Cycle, out: &mut Vec<Burst>) {
        // TRU period rollover.
        if self.config.tru_period > 0 && now >= self.period_start + self.config.tru_period {
            let periods = (now - self.period_start) / self.config.tru_period;
            self.period_start += periods * self.config.tru_period;
            self.budget_left = self.config.tru_budget_beats;
        }
        while let Some(head) = self.pending.front() {
            if head.eligible_at > now {
                break;
            }
            if self.config.tru_budget_beats > 0 {
                if head.burst.beats > self.budget_left {
                    if self.head_blocked() {
                        self.stats.tru_stall_cycles += 1;
                        break;
                    }
                    // Oversize fragment passing on an untouched budget.
                    self.budget_left = 0;
                } else {
                    self.budget_left -= head.burst.beats;
                }
            }
            let frag = self.pending.pop_front().unwrap();
            self.stats.beats_out += frag.burst.beats as u64;
            out.push(frag.burst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::axi::{InitiatorId, Target};

    fn burst(beats: u32) -> Burst {
        Burst::read(InitiatorId(0), Target::Dcspm, 0x1000, beats)
    }

    fn drain(tsu: &mut Tsu, upto: Cycle) -> Vec<Burst> {
        let mut out = Vec::new();
        for c in 0..upto {
            tsu.release(c, &mut out);
        }
        out
    }

    #[test]
    fn passthrough_forwards_unchanged() {
        let mut tsu = Tsu::new(TsuConfig::passthrough());
        tsu.submit(burst(200), 0);
        let out = drain(&mut tsu, 2);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].beats, 200);
    }

    #[test]
    fn gbs_splits_long_bursts() {
        let cfg = TsuConfig {
            gbs_max_beats: 16,
            ..TsuConfig::passthrough()
        };
        let mut tsu = Tsu::new(cfg);
        tsu.submit(burst(100), 0);
        let out = drain(&mut tsu, 2);
        assert_eq!(out.len(), 7); // 6 x 16 + 1 x 4
        assert_eq!(out.iter().map(|b| b.beats).sum::<u32>(), 100);
        assert_eq!(out[0].fragments_left, 6);
        assert_eq!(out[6].fragments_left, 0);
        assert_eq!(out[6].beats, 4);
        // Fragment addresses are contiguous.
        assert_eq!(out[1].addr, 0x1000 + 16 * 8);
    }

    #[test]
    fn gbs_preserves_original_issue_time_and_tag() {
        let cfg = TsuConfig {
            gbs_max_beats: 8,
            ..TsuConfig::passthrough()
        };
        let mut tsu = Tsu::new(cfg);
        let mut b = burst(32).with_tag(42);
        b.issued_at = 7;
        tsu.submit(b, 7);
        let out = drain(&mut tsu, 9);
        assert!(out.iter().all(|f| f.tag == 42 && f.issued_at == 7));
    }

    #[test]
    fn tru_enforces_budget_per_period() {
        let cfg = TsuConfig {
            tru_budget_beats: 8,
            tru_period: 100,
            ..TsuConfig::passthrough()
        };
        let mut tsu = Tsu::new(cfg);
        for _ in 0..4 {
            tsu.submit(burst(8), 0);
        }
        let mut out = Vec::new();
        tsu.release(0, &mut out);
        assert_eq!(out.len(), 1, "only one 8-beat burst fits the budget");
        tsu.release(50, &mut out);
        assert_eq!(out.len(), 1, "no refill mid-period");
        tsu.release(100, &mut out);
        assert_eq!(out.len(), 2, "second period releases one more");
        tsu.release(200, &mut out);
        tsu.release(300, &mut out);
        assert_eq!(out.len(), 4);
        assert!(tsu.stats.tru_stall_cycles > 0);
    }

    #[test]
    fn tru_zero_budget_means_unregulated() {
        let mut tsu = Tsu::new(TsuConfig::passthrough());
        for _ in 0..10 {
            tsu.submit(burst(256), 0);
        }
        let out = drain(&mut tsu, 1);
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn wb_adds_at_most_one_cycle_when_buffer_fits() {
        let cfg = TsuConfig {
            wb_enable: true,
            wb_capacity_beats: 64,
            ..TsuConfig::passthrough()
        };
        let mut tsu = Tsu::new(cfg);
        let w = Burst::write(InitiatorId(0), Target::Dcspm, 0, 16);
        tsu.submit(w, 10);
        let mut out = Vec::new();
        tsu.release(10, &mut out);
        assert!(out.is_empty(), "write not yet buffered");
        tsu.release(11, &mut out);
        assert_eq!(out.len(), 1, "released exactly 1 cycle later");
    }

    #[test]
    fn wb_backpressures_when_full() {
        let cfg = TsuConfig {
            wb_enable: true,
            wb_capacity_beats: 8,
            ..TsuConfig::passthrough()
        };
        let mut tsu = Tsu::new(cfg);
        tsu.submit(Burst::write(InitiatorId(0), Target::Dcspm, 0, 8), 0);
        tsu.submit(Burst::write(InitiatorId(0), Target::Dcspm, 64, 8), 0);
        let mut out = Vec::new();
        tsu.release(1, &mut out);
        assert_eq!(out.len(), 1);
        // Second write was scheduled with full-drain latency (8 cycles).
        tsu.release(2, &mut out);
        assert_eq!(out.len(), 1);
        tsu.release(8, &mut out);
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn reads_bypass_wb() {
        let cfg = TsuConfig {
            wb_enable: true,
            wb_capacity_beats: 64,
            ..TsuConfig::passthrough()
        };
        let mut tsu = Tsu::new(cfg);
        tsu.submit(burst(8), 5);
        let mut out = Vec::new();
        tsu.release(5, &mut out);
        assert_eq!(out.len(), 1, "reads are not write-buffered");
    }

    #[test]
    fn reconfigure_at_runtime() {
        let mut tsu = Tsu::new(TsuConfig::passthrough());
        tsu.submit(burst(100), 0);
        let out = drain(&mut tsu, 1);
        assert_eq!(out[0].beats, 100);
        tsu.reconfigure(TsuConfig::regulated(16, 32, 128));
        tsu.submit(burst(100), 1);
        let mut out2 = Vec::new();
        tsu.release(1, &mut out2);
        assert!(out2.iter().all(|b| b.beats <= 16));
        assert!(out2.iter().map(|b| b.beats).sum::<u32>() <= 32);
    }

    #[test]
    fn tru_budget_equal_to_burst_passes_each_period_boundary() {
        // Budget exactly equal to the burst's beats: one burst passes
        // per period, released exactly at the refill boundary.
        let cfg = TsuConfig {
            tru_budget_beats: 16,
            tru_period: 64,
            ..TsuConfig::passthrough()
        };
        let mut tsu = Tsu::new(cfg);
        for _ in 0..3 {
            tsu.submit(burst(16), 0);
        }
        let mut out = Vec::new();
        tsu.release(0, &mut out);
        assert_eq!(out.len(), 1, "first budget-exact burst passes at once");
        tsu.release(63, &mut out);
        assert_eq!(out.len(), 1, "no release one cycle before the boundary");
        tsu.release(64, &mut out);
        assert_eq!(out.len(), 2, "refill exactly at period_start + period");
        tsu.release(128, &mut out);
        assert_eq!(out.len(), 3);
        assert_eq!(out.iter().map(|b| b.beats).sum::<u32>(), 48);
    }

    #[test]
    #[should_panic(expected = "starves the initiator")]
    fn tru_budget_without_period_is_rejected() {
        // A budget that never refills would silently starve a TCT; the
        // shaper must reject the configuration explicitly.
        Tsu::new(TsuConfig {
            tru_budget_beats: 8,
            tru_period: 0,
            ..TsuConfig::passthrough()
        });
    }

    #[test]
    #[should_panic(expected = "starves the initiator")]
    fn tru_budget_without_period_rejected_on_reconfigure() {
        let mut tsu = Tsu::new(TsuConfig::passthrough());
        tsu.reconfigure(TsuConfig {
            tru_budget_beats: 8,
            tru_period: 0,
            ..TsuConfig::passthrough()
        });
    }

    #[test]
    fn reconfigure_preserves_buffered_beats() {
        // Fragments buffered inside the shaper survive a mid-flight
        // reconfiguration — no beat is ever dropped.
        let mut tsu = Tsu::new(TsuConfig::regulated(8, 8, 1000));
        tsu.submit(burst(64), 0); // 8 fragments; only 1 passes this period
        let mut out = Vec::new();
        tsu.release(0, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(tsu.queued(), 7, "seven fragments buffered");
        tsu.reconfigure(TsuConfig::passthrough());
        tsu.release(1, &mut out);
        assert_eq!(tsu.queued(), 0, "reconfigure kept every buffered beat");
        assert_eq!(out.iter().map(|b| b.beats).sum::<u32>(), 64);
    }

    #[test]
    fn reconfigure_preserves_wb_buffered_write() {
        let mut tsu = Tsu::new(TsuConfig::wb_only());
        let w = Burst::write(InitiatorId(0), Target::Dcspm, 0, 16);
        tsu.submit(w, 0); // eligible at cycle 1 (WB fill)
        tsu.reconfigure(TsuConfig::regulated(8, 96, 512));
        let mut out = Vec::new();
        tsu.release(1, &mut out);
        assert_eq!(out.iter().map(|b| b.beats).sum::<u32>(), 16);
    }

    #[test]
    fn arrival_curve_covers_boundary_straddling_windows() {
        let cfg = TsuConfig::regulated(8, 96, 512);
        assert!(cfg.is_tru_regulated());
        // A window shorter than a period can still see two full budgets:
        // one drained just before a refill boundary, one just after.
        assert_eq!(cfg.max_beats_in_window(2), Some(192));
        assert_eq!(cfg.max_beats_in_window(511), Some(192));
        assert_eq!(cfg.max_beats_in_window(512), Some(288));
        assert_eq!(cfg.max_beats_in_window(5 * 512), Some(7 * 96));
        assert_eq!(TsuConfig::passthrough().max_beats_in_window(1000), None);
        assert_eq!(cfg.fragment_beats(100), 8);
        assert_eq!(cfg.fragment_beats(3), 3);
        assert_eq!(TsuConfig::passthrough().fragment_beats(100), 100);
    }

    #[test]
    fn release_can_straddle_a_refill_boundary_with_two_budgets() {
        // The reachable worst case behind the `+2` in the arrival curve.
        let cfg = TsuConfig {
            tru_budget_beats: 16,
            tru_period: 100,
            ..TsuConfig::passthrough()
        };
        let mut tsu = Tsu::new(cfg);
        // Idle (untouched budget) until the last cycle of the period.
        for _ in 0..4 {
            tsu.submit(burst(8), 99);
        }
        let mut out = Vec::new();
        tsu.release(99, &mut out);
        assert_eq!(out.len(), 2, "full budget drained at cycle 99");
        tsu.release(100, &mut out);
        assert_eq!(out.len(), 4, "refilled budget drained at cycle 100");
        // 32 beats released within a 2-cycle window = 2x budget.
        assert_eq!(out.iter().map(|b| b.beats).sum::<u32>(), 32);
    }

    #[test]
    fn stats_accounting() {
        let mut tsu = Tsu::new(TsuConfig::regulated(8, 64, 100));
        tsu.submit(burst(32), 0);
        let _ = drain(&mut tsu, 3);
        assert_eq!(tsu.stats.bursts_in, 1);
        assert_eq!(tsu.stats.fragments_out, 4);
        assert_eq!(tsu.stats.beats_out, 32);
    }
}

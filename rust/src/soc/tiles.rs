//! Double-buffered tile streaming shared by both accelerator clusters.
//!
//! Both the AMR and the vector cluster move work tiles between the L2
//! DCSPM and their private L1 SPMs through a cluster DMA, overlapping the
//! transfer of tile i+1 with the computation of tile i (paper: "A 64b/cyc
//! DMA enables double-buffered L2-L1 data transfers"). The streamer is
//! the bus-facing half of that pipeline: it prefetches up to
//! `buffer_depth` tiles ahead and writes back results.

use std::collections::VecDeque;

use super::axi::{Burst, Completion, InitiatorId, Target};
use super::clock::Cycle;
use super::tsu::Tsu;

/// Prefetch depth both accelerator clusters program (classic double
/// buffering). Shared so the WCET traffic model provably matches the
/// streamers the scheduler actually builds.
pub const CLUSTER_BUFFER_DEPTH: u32 = 1;

/// Description of a tiled transfer stream.
#[derive(Debug, Clone)]
pub struct TileStream {
    /// Total tiles in the task.
    pub tiles: u32,
    /// Input beats per tile (operand slabs).
    pub in_beats: u32,
    /// Output beats per tile (accumulator writeback); 0 disables.
    pub out_beats: u32,
    /// L2 source base (DCSPM address; set the contiguous-alias bit for a
    /// private-path configuration).
    pub src_base: u64,
    /// L2 destination base for writebacks.
    pub dst_base: u64,
    pub part_id: u8,
    /// Prefetch depth (1 = classic double buffering).
    pub buffer_depth: u32,
    /// Wrap window in bytes: tile offsets wrap modulo this so the stream
    /// stays within its L2 staging slot (0 = no wrapping).
    pub wrap_bytes: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Flight {
    Fetch(u32),
    Writeback(u32),
}

/// Bus-side engine: issues fetches/writebacks, reports ready tiles.
#[derive(Debug)]
pub struct TileStreamer {
    pub id: InitiatorId,
    stream: TileStream,
    next_fetch: u32,
    ready: VecDeque<u32>,
    /// Consumed-but-unfetched budget: tiles currently buffered (ready +
    /// in-fetch) must stay <= buffer_depth + 1.
    in_flight: Option<(u64, Flight)>,
    pending_wb: VecDeque<u32>,
    wb_done: u32,
    tag_seq: u64,
    /// Completed input beats (bandwidth accounting).
    pub beats_in: u64,
    pub beats_out: u64,
    /// Cycles with a transfer outstanding.
    pub busy_cycles: u64,
    /// Worst observed transfer latency (issue to last beat) — the
    /// measured counterpart of the WCET memory-latency bound.
    pub max_latency: Cycle,
}

impl TileStreamer {
    pub fn new(id: InitiatorId, stream: TileStream) -> Self {
        assert!(stream.tiles > 0 && stream.in_beats > 0);
        Self {
            id,
            stream,
            next_fetch: 0,
            ready: VecDeque::new(),
            in_flight: None,
            pending_wb: VecDeque::new(),
            wb_done: 0,
            tag_seq: 0,
            beats_in: 0,
            beats_out: 0,
            busy_cycles: 0,
            max_latency: 0,
        }
    }

    /// Max write bursts a streamer with `buffer_depth` prefetch slots
    /// can emit back to back without an intervening fetch: pending
    /// writebacks are fed by computes, which drain the
    /// `buffer_depth + 1` prefetched tiles plus the one in the pipe;
    /// writeback priority blocks refills meanwhile (WCET hook for the
    /// W-channel hold-chain bound, used by `wcet::model`).
    pub fn worst_write_chain(buffer_depth: u32) -> u64 {
        buffer_depth as u64 + 3
    }

    /// Tiles fetched and awaiting compute.
    pub fn ready_tiles(&self) -> usize {
        self.ready.len()
    }

    /// Pop the next compute-ready tile.
    pub fn pop_ready(&mut self) -> Option<u32> {
        self.ready.pop_front()
    }

    /// Queue a result tile for writeback.
    pub fn push_writeback(&mut self, tile: u32) {
        if self.stream.out_beats > 0 {
            self.pending_wb.push_back(tile);
        } else {
            self.wb_done += 1;
        }
    }

    /// All fetches issued and all writebacks drained?
    pub fn done(&self) -> bool {
        self.next_fetch >= self.stream.tiles
            && self.ready.is_empty()
            && self.in_flight.is_none()
            && self.pending_wb.is_empty()
            && self.wb_done >= self.stream.tiles
    }

    /// True when every tile's data has been fetched (compute may still run).
    pub fn fetches_done(&self) -> bool {
        self.next_fetch >= self.stream.tiles && self.in_flight.is_none()
    }

    fn wrap(&self, offset: u64) -> u64 {
        if self.stream.wrap_bytes == 0 {
            offset
        } else {
            offset % self.stream.wrap_bytes
        }
    }

    fn tile_src(&self, tile: u32) -> u64 {
        self.stream.src_base + self.wrap(tile as u64 * self.stream.in_beats as u64 * 8)
    }

    fn tile_dst(&self, tile: u32) -> u64 {
        self.stream.dst_base + self.wrap(tile as u64 * self.stream.out_beats as u64 * 8)
    }

    /// Event-driven hook: `Some(now)` while the streamer can issue a
    /// transfer this cycle; `None` while its single channel waits on a
    /// completion or there is nothing left to move.
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        if self.in_flight.is_some() {
            return None; // woken by the bus completion
        }
        if !self.pending_wb.is_empty() {
            return Some(now);
        }
        if self.next_fetch < self.stream.tiles
            && (self.ready.len() as u32) <= self.stream.buffer_depth
        {
            return Some(now);
        }
        None
    }

    /// Replay per-cycle busy accounting over a skipped window `[from,
    /// to)` (one busy cycle per naive tick with a transfer outstanding).
    pub fn fast_forward(&mut self, from: Cycle, to: Cycle) {
        if self.in_flight.is_some() {
            self.busy_cycles += to - from;
        }
    }

    /// Issue at most one transfer per cycle (single DMA channel).
    /// Writebacks take priority (they free L1 buffers).
    pub fn tick(&mut self, now: Cycle, tsu: &mut Tsu) {
        if self.in_flight.is_some() {
            self.busy_cycles += 1;
            return;
        }
        if let Some(tile) = self.pending_wb.pop_front() {
            self.tag_seq += 1;
            let mut b = Burst::write(self.id, Target::Dcspm, self.tile_dst(tile), self.stream.out_beats)
                .with_part(self.stream.part_id)
                .with_tag(self.tag_seq);
            b.issued_at = now;
            tsu.submit(b, now);
            self.in_flight = Some((self.tag_seq, Flight::Writeback(tile)));
            self.busy_cycles += 1;
            return;
        }
        let buffered = self.ready.len() as u32;
        if self.next_fetch < self.stream.tiles && buffered <= self.stream.buffer_depth {
            let tile = self.next_fetch;
            self.tag_seq += 1;
            let mut b = Burst::read(self.id, Target::Dcspm, self.tile_src(tile), self.stream.in_beats)
                .with_part(self.stream.part_id)
                .with_tag(self.tag_seq);
            b.issued_at = now;
            tsu.submit(b, now);
            self.in_flight = Some((self.tag_seq, Flight::Fetch(tile)));
            self.next_fetch += 1;
            self.busy_cycles += 1;
        }
    }

    /// Deliver a bus completion.
    pub fn complete(&mut self, c: Completion, _now: Cycle) {
        let Some((tag, flight)) = self.in_flight else {
            return;
        };
        if c.tag != tag || !c.last_fragment {
            return;
        }
        self.max_latency = self.max_latency.max(c.latency());
        match flight {
            Flight::Fetch(tile) => {
                self.beats_in += self.stream.in_beats as u64;
                self.ready.push_back(tile);
            }
            Flight::Writeback(_) => {
                self.beats_out += self.stream.out_beats as u64;
                self.wb_done += 1;
            }
        }
        self.in_flight = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::axi::xbar::Crossbar;
    use crate::soc::axi::TargetModel;
    use crate::soc::mem::Dcspm;
    use crate::soc::tsu::TsuConfig;

    fn stream(tiles: u32) -> TileStream {
        TileStream {
            tiles,
            in_beats: 32,
            out_beats: 16,
            src_base: 0,
            dst_base: 0x4_0000,
            part_id: 0,
            buffer_depth: 1,
            wrap_bytes: crate::coordinator::policy::SocTuning::L2_SLOT_BYTES / 2,
        }
    }

    /// Drive the streamer with an immediate-consume compute model.
    fn drive(ts: &mut TileStreamer, cycles: Cycle, consume: bool) {
        let mut tsu = Tsu::new(TsuConfig::passthrough());
        let mut xbar = Crossbar::new(1, vec![Box::new(Dcspm::new()) as Box<dyn TargetModel>]);
        let mut staged = Vec::new();
        for now in 0..cycles {
            ts.tick(now, &mut tsu);
            staged.clear();
            tsu.release(now, &mut staged);
            for b in staged.drain(..) {
                xbar.push(b);
            }
            xbar.tick(now);
            for c in xbar.take_completions() {
                ts.complete(c, now);
            }
            if consume {
                if let Some(t) = ts.pop_ready() {
                    ts.push_writeback(t);
                }
            }
            if ts.done() {
                break;
            }
        }
    }

    #[test]
    fn streams_all_tiles_in_order() {
        let mut ts = TileStreamer::new(InitiatorId(0), stream(4));
        let mut tsu = Tsu::new(TsuConfig::passthrough());
        let mut xbar = Crossbar::new(1, vec![Box::new(Dcspm::new()) as Box<dyn TargetModel>]);
        let mut got = Vec::new();
        let mut staged = Vec::new();
        for now in 0..10_000 {
            ts.tick(now, &mut tsu);
            staged.clear();
            tsu.release(now, &mut staged);
            for b in staged.drain(..) {
                xbar.push(b);
            }
            xbar.tick(now);
            for c in xbar.take_completions() {
                ts.complete(c, now);
            }
            while let Some(t) = ts.pop_ready() {
                got.push(t);
                ts.push_writeback(t);
            }
            if ts.done() {
                break;
            }
        }
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert!(ts.done());
        assert_eq!(ts.beats_in, 4 * 32);
        assert_eq!(ts.beats_out, 4 * 16);
    }

    #[test]
    fn respects_buffer_depth() {
        let mut ts = TileStreamer::new(InitiatorId(0), stream(16));
        // Never consume: fetches must stop at buffer_depth+1 tiles ready.
        drive(&mut ts, 5000, false);
        assert!(ts.ready_tiles() <= 2, "ready={}", ts.ready_tiles());
        assert!(!ts.done());
    }

    #[test]
    fn no_writeback_stream() {
        let mut s = stream(3);
        s.out_beats = 0;
        let mut ts = TileStreamer::new(InitiatorId(0), s);
        drive(&mut ts, 5000, true);
        assert!(ts.done());
        assert_eq!(ts.beats_out, 0);
    }

    #[test]
    fn done_requires_writebacks() {
        let mut ts = TileStreamer::new(InitiatorId(0), stream(2));
        drive(&mut ts, 3000, true);
        assert!(ts.done());
        assert_eq!(ts.beats_out, 2 * 16);
    }
}

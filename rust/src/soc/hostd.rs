//! Host domain (HOSTD): dual-core RV64GCH (Cheshire-based) running soft
//! real-time tasks under virtualization (RTOS + GPOS guests).
//!
//! For the interference experiments the host core is a latency-sensitive
//! *traffic generator*: it executes a time-critical task (TCT) that walks
//! a HyperRAM-resident buffer with a configurable stride through its
//! private 32KiB L1 D$ and the shared DPLLC (Fig. 6a). Each access is
//! blocking (in-order CVA6 load), so interconnect interference shows up
//! directly as task latency and jitter.
//!
//! The vCLIC model captures the paper's virtualized interrupt path:
//! direct guest delivery without hypervisor intervention.

use super::axi::{Burst, Completion, InitiatorId, Target};
use super::clock::Cycle;
use super::mem::dpllc::{Access, Dpllc, DpllcConfig};
use super::tsu::Tsu;
use crate::util::Summary;

/// Private L1 data cache geometry: 32KiB, 4-way, 64B lines -> 128 sets.
fn l1_config() -> DpllcConfig {
    DpllcConfig {
        ways: 4,
        sets: 128,
        line_bytes: 64,
        partitions: vec![(0, 128)],
    }
}

/// The strided TCT the paper measures in Fig. 6a.
#[derive(Debug, Clone)]
pub struct TctSpec {
    /// Base address of the buffer in HyperRAM space.
    pub base: u64,
    /// Byte stride between consecutive loads ("contiguous stride").
    pub stride: u64,
    /// Loads per task iteration.
    pub accesses: u32,
    /// Task iterations to run (latency sample per iteration).
    pub iterations: u32,
    /// Think cycles between loads (address generation + compute).
    pub think_cycles: Cycle,
    /// DPLLC partition assigned to this task.
    pub part_id: u8,
}

impl TctSpec {
    /// Fig. 6a-like default: a 48KiB working set re-walked every
    /// iteration — larger than the 32KiB L1 D$ (so the DPLLC is on the
    /// critical path every iteration) but smaller than a >=50% DPLLC
    /// partition (64KiB), which is exactly the regime Fig. 6a explores.
    pub fn fig6a() -> Self {
        Self {
            base: 0,
            stride: 64,
            accesses: 768,
            iterations: 8,
            think_cycles: 4,
            part_id: 1,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Thinking { until: Cycle },
    WaitingBus,
    Done,
}

/// Host-core TCT driver (one core; the second host core is modelled by
/// the coordinator as an additional initiator when needed).
pub struct HostCore {
    pub id: InitiatorId,
    l1: Dpllc,
    spec: TctSpec,
    state: State,
    access_idx: u32,
    iter_idx: u32,
    iter_start: Cycle,
    access_start: Cycle,
    tag_seq: u64,
    /// Per-iteration task latency samples (cycles).
    pub iteration_latency: Summary,
    /// Per-access load-to-use latency samples (cycles).
    pub access_latency: Summary,
    pub l1_hits: u64,
    pub l1_misses: u64,
    /// Cycle the task completed its final iteration (0 while running).
    pub finished_at: u64,
}

impl HostCore {
    pub fn new(id: InitiatorId, spec: TctSpec) -> Self {
        Self {
            id,
            l1: Dpllc::new(l1_config()),
            state: State::Thinking { until: 0 },
            access_idx: 0,
            iter_idx: 0,
            iter_start: 0,
            access_start: 0,
            tag_seq: 0,
            iteration_latency: Summary::new(),
            access_latency: Summary::new(),
            l1_hits: 0,
            l1_misses: 0,
            finished_at: 0,
            spec,
        }
    }

    pub fn done(&self) -> bool {
        self.state == State::Done
    }

    /// Event-driven hook: the cycle the core next does anything on its
    /// own. While waiting on a line fill it is woken by the completion
    /// (`None`); while thinking it acts exactly at `until`.
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        match self.state {
            State::Done | State::WaitingBus => None,
            State::Thinking { until } => Some(until.max(now)),
        }
    }

    fn current_addr(&self) -> u64 {
        self.spec.base + self.access_idx as u64 * self.spec.stride
    }

    /// Advance one cycle; may issue a line-fill burst through the TSU.
    pub fn tick(&mut self, now: Cycle, tsu: &mut Tsu) {
        match self.state {
            State::Done | State::WaitingBus => {}
            State::Thinking { until } => {
                if now < until {
                    return;
                }
                if self.access_idx == 0 {
                    self.iter_start = now;
                }
                let addr = self.current_addr();
                self.access_start = now;
                match self.l1.access(addr, 0, false) {
                    Access::Hit => {
                        self.l1_hits += 1;
                        self.access_latency.push(1.0);
                        self.advance(now + 1);
                    }
                    Access::Miss { .. } => {
                        self.l1_misses += 1;
                        // Line fill: 64B = 8 beats from the HyperRAM path.
                        self.tag_seq += 1;
                        let line = addr / 64 * 64;
                        let mut b = Burst::read(self.id, Target::Hyperram, line, 8)
                            .with_part(self.spec.part_id)
                            .with_tag(self.tag_seq);
                        b.issued_at = now;
                        tsu.submit(b, now);
                        self.state = State::WaitingBus;
                    }
                }
            }
        }
    }

    /// Deliver the line-fill completion.
    pub fn complete(&mut self, c: Completion, now: Cycle) {
        if self.state != State::WaitingBus || c.tag != self.tag_seq || !c.last_fragment {
            return;
        }
        self.access_latency
            .push((now.saturating_sub(self.access_start)) as f64);
        self.advance(now + 1);
    }

    fn advance(&mut self, now: Cycle) {
        self.access_idx += 1;
        if self.access_idx >= self.spec.accesses {
            self.iteration_latency
                .push((now.saturating_sub(self.iter_start)) as f64);
            self.access_idx = 0;
            self.iter_idx += 1;
            if self.iter_idx >= self.spec.iterations {
                self.state = State::Done;
                self.finished_at = now;
                return;
            }
        }
        self.state = State::Thinking {
            until: now + self.spec.think_cycles,
        };
    }
}

/// vCLIC interrupt delivery model (paper Fig. 7 row "Interrupt Latency").
///
/// CV32RT cores take interrupts in 6 cycles; virtualized delivery to a
/// running guest adds no hypervisor exit (direct link to the requester
/// VG), only the vCLIC arbitration stage.
#[derive(Debug, Clone, Copy)]
pub struct VClic {
    /// Hardware pipeline cycles from IRQ assert to first handler fetch.
    pub base_latency: Cycle,
    /// Extra cycles when the target VG is not currently scheduled
    /// (context switch performed by hardware, not hypervisor).
    pub vg_switch_penalty: Cycle,
}

impl VClic {
    pub fn carfield() -> Self {
        Self {
            base_latency: 6,
            vg_switch_penalty: 13,
        }
    }

    /// Latency for an interrupt targeting `running_vg == target_vg`.
    pub fn latency(&self, running_vg: u8, target_vg: u8) -> Cycle {
        if running_vg == target_vg {
            self.base_latency
        } else {
            self.base_latency + self.vg_switch_penalty
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::axi::xbar::Crossbar;
    use crate::soc::axi::TargetModel;
    use crate::soc::mem::HyperramPath;
    use crate::soc::tsu::TsuConfig;

    fn drive(core: &mut HostCore, cycles: Cycle) {
        let mut tsu = Tsu::new(TsuConfig::passthrough());
        let mut xbar = Crossbar::new(
            1,
            vec![Box::new(HyperramPath::carfield()) as Box<dyn TargetModel>],
        );
        let mut staged = Vec::new();
        for now in 0..cycles {
            core.tick(now, &mut tsu);
            staged.clear();
            tsu.release(now, &mut staged);
            for b in staged.drain(..) {
                xbar.push(b);
            }
            xbar.tick(now);
            for c in xbar.take_completions() {
                core.complete(c, now);
            }
            if core.done() {
                break;
            }
        }
    }

    #[test]
    fn tct_completes_and_collects_samples() {
        let spec = TctSpec {
            accesses: 32,
            iterations: 4,
            ..TctSpec::fig6a()
        };
        let mut core = HostCore::new(InitiatorId(0), spec);
        drive(&mut core, 2_000_000);
        assert!(core.done());
        assert_eq!(core.iteration_latency.len(), 4);
        assert_eq!(core.access_latency.len(), 32 * 4);
    }

    #[test]
    fn second_iteration_hits_l1() {
        // Working set 32 lines * 64B = 2KiB << 32KiB L1: after the first
        // walk everything hits.
        let spec = TctSpec {
            accesses: 32,
            iterations: 3,
            ..TctSpec::fig6a()
        };
        let mut core = HostCore::new(InitiatorId(0), spec);
        drive(&mut core, 2_000_000);
        assert_eq!(core.l1_misses, 32, "only the cold walk misses");
        assert_eq!(core.l1_hits, 64);
        // Warm iterations are much faster than the cold one.
        assert!(core.iteration_latency.min() * 4.0 < core.iteration_latency.max());
    }

    #[test]
    fn stride_beyond_line_defeats_spatial_locality() {
        let spec = TctSpec {
            stride: 256,
            accesses: 64,
            iterations: 1,
            ..TctSpec::fig6a()
        };
        let mut core = HostCore::new(InitiatorId(0), spec);
        drive(&mut core, 2_000_000);
        assert_eq!(core.l1_misses, 64);
    }

    #[test]
    fn vclic_latencies() {
        let v = VClic::carfield();
        assert_eq!(v.latency(0, 0), 6);
        assert_eq!(v.latency(0, 1), 19);
    }
}

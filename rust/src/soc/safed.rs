//! Safe domain (SAFED): triple-core lockstep (TCLS) RV32 island for hard
//! real-time, safety-critical control, with ECC-protected private
//! instruction/data scratchpads for deterministic memory access and an
//! enhanced CLIC with 6-cycle interrupt latency (paper §II).
//!
//! The lockstep model commits one "instruction bundle" per domain cycle
//! through a majority voter. Injected faults flip one replica's
//! architectural state; the voter masks the error and triggers a
//! re-synchronization of the faulty replica while the other two keep
//! executing — the domain never misses a deadline for a single fault.

use super::clock::Cycle;
use crate::util::XorShift;

/// CLIC timing (paper Fig. 7: "6 clock cycles (CV32RT)").
#[derive(Debug, Clone, Copy)]
pub struct Clic {
    pub irq_latency: Cycle,
}

impl Clic {
    pub fn carfield() -> Self {
        Self { irq_latency: 6 }
    }
}

/// Result of one voted commit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Commit {
    /// All three replicas agreed.
    Clean,
    /// One replica disagreed; majority masked it, replica resyncing.
    Corrected { faulty: usize },
    /// Two or more replicas disagreed — unrecoverable by voting.
    Fatal,
}

/// Per-domain counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct TclsStats {
    pub commits: u64,
    pub corrected: u64,
    pub fatal: u64,
    pub resync_cycles: u64,
}

/// The triple-core lockstep pipeline.
pub struct Tcls {
    /// Architectural state checksum per replica (abstracted).
    state: [u64; 3],
    /// Replica currently re-synchronizing (unavailable for voting
    /// divergence detection but state is being rebuilt from the majority).
    resync_until: [Cycle; 3],
    /// Cycles to rebuild a replica's state from the voted copy.
    pub resync_latency: Cycle,
    pub clic: Clic,
    pub stats: TclsStats,
}

impl Tcls {
    pub fn new() -> Self {
        Self {
            state: [0; 3],
            resync_until: [0; 3],
            resync_latency: 38,
            clic: Clic::carfield(),
            stats: TclsStats::default(),
        }
    }

    /// Inject a state-flip fault into replica `r` (test/fault campaign).
    pub fn inject_fault(&mut self, r: usize, rng: &mut XorShift) {
        self.state[r] ^= 1 << rng.below(64);
    }

    /// Execute + vote one instruction bundle at `now`.
    ///
    /// A replica in resync executes in shadow of the voted state (its
    /// pipeline is being refilled from the majority copy), so it cannot
    /// diverge again until resync completes.
    pub fn commit(&mut self, now: Cycle) -> Commit {
        self.stats.commits += 1;
        for r in 0..3 {
            self.state[r] = self.state[r]
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(1);
        }
        for r in 0..3 {
            if self.resync_until[r] > now {
                let donor = if r == 0 { 1 } else { 0 };
                self.state[r] = self.state[donor];
            }
        }
        let votes = self.state;
        let agree01 = votes[0] == votes[1];
        let agree02 = votes[0] == votes[2];
        let agree12 = votes[1] == votes[2];
        match (agree01, agree02, agree12) {
            (true, true, true) => Commit::Clean,
            (true, false, false) => self.correct(2, now),
            (false, true, false) => self.correct(1, now),
            (false, false, true) => self.correct(0, now),
            _ => {
                self.stats.fatal += 1;
                Commit::Fatal
            }
        }
    }

    fn correct(&mut self, faulty: usize, now: Cycle) -> Commit {
        // Copy the majority state into the faulty replica and hold it in
        // resync for `resync_latency` cycles.
        let majority = if faulty == 0 { self.state[1] } else { self.state[0] };
        self.state[faulty] = majority;
        self.resync_until[faulty] = now + self.resync_latency;
        self.stats.corrected += 1;
        self.stats.resync_cycles += self.resync_latency;
        Commit::Corrected { faulty }
    }

    /// Interrupt response time from the CLIC.
    pub fn irq_latency(&self) -> Cycle {
        self.clic.irq_latency
    }
}

impl Default for Tcls {
    fn default() -> Self {
        Self::new()
    }
}

/// ECC-protected scratchpad: single-bit errors corrected inline (SECDED),
/// double-bit errors detected. Deterministic access latency — the reason
/// the safe domain's WCET is exact.
#[derive(Debug)]
pub struct EccSpm {
    pub size_bytes: u64,
    pub access_latency: Cycle,
    pub corrected: u64,
    pub detected_uncorrectable: u64,
    /// Addresses with a latched single-bit upset.
    upset: std::collections::HashSet<u64>,
    double: std::collections::HashSet<u64>,
}

impl EccSpm {
    pub fn new(size_bytes: u64) -> Self {
        Self {
            size_bytes,
            access_latency: 1,
            corrected: 0,
            detected_uncorrectable: 0,
            upset: Default::default(),
            double: Default::default(),
        }
    }

    pub fn inject_single(&mut self, addr: u64) {
        self.upset.insert(addr % self.size_bytes);
    }

    pub fn inject_double(&mut self, addr: u64) {
        self.double.insert(addr % self.size_bytes);
    }

    /// Returns (latency, fatal). Single-bit upsets are scrubbed.
    pub fn access(&mut self, addr: u64) -> (Cycle, bool) {
        let a = addr % self.size_bytes;
        if self.double.remove(&a) {
            self.detected_uncorrectable += 1;
            return (self.access_latency, true);
        }
        if self.upset.remove(&a) {
            self.corrected += 1;
        }
        (self.access_latency, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_commits_by_default() {
        let mut t = Tcls::new();
        for now in 0..100 {
            assert_eq!(t.commit(now), Commit::Clean);
        }
        assert_eq!(t.stats.commits, 100);
        assert_eq!(t.stats.corrected, 0);
    }

    #[test]
    fn single_fault_is_masked_and_corrected() {
        let mut t = Tcls::new();
        let mut rng = XorShift::new(1);
        t.commit(0);
        t.inject_fault(1, &mut rng);
        match t.commit(1) {
            Commit::Corrected { faulty } => assert_eq!(faulty, 1),
            other => panic!("expected correction, got {other:?}"),
        }
        // Execution continues cleanly afterwards (replica resynced).
        for now in 2..200 {
            assert_eq!(t.commit(now), Commit::Clean, "at {now}");
        }
        assert_eq!(t.stats.corrected, 1);
    }

    #[test]
    fn double_fault_is_fatal() {
        let mut t = Tcls::new();
        let mut rng = XorShift::new(2);
        t.inject_fault(0, &mut rng);
        t.inject_fault(1, &mut rng);
        assert_eq!(t.commit(0), Commit::Fatal);
        assert_eq!(t.stats.fatal, 1);
    }

    #[test]
    fn faults_in_each_replica_detected() {
        for r in 0..3 {
            let mut t = Tcls::new();
            let mut rng = XorShift::new(3 + r as u64);
            t.commit(0);
            t.inject_fault(r, &mut rng);
            assert!(matches!(t.commit(1), Commit::Corrected { faulty } if faulty == r));
        }
    }

    #[test]
    fn irq_latency_is_six_cycles() {
        assert_eq!(Tcls::new().irq_latency(), 6);
    }

    #[test]
    fn ecc_corrects_single_detects_double() {
        let mut spm = EccSpm::new(64 * 1024);
        spm.inject_single(0x100);
        let (lat, fatal) = spm.access(0x100);
        assert_eq!(lat, 1);
        assert!(!fatal);
        assert_eq!(spm.corrected, 1);
        spm.inject_double(0x200);
        let (_, fatal) = spm.access(0x200);
        assert!(fatal);
        assert_eq!(spm.detected_uncorrectable, 1);
    }

    #[test]
    fn ecc_latency_is_deterministic() {
        let mut spm = EccSpm::new(1024);
        let mut rng = XorShift::new(5);
        for _ in 0..1000 {
            let (lat, _) = spm.access(rng.next_u64());
            assert_eq!(lat, 1, "WCET must be exact");
        }
    }

    #[test]
    fn fault_burst_campaign_survives_singles() {
        let mut t = Tcls::new();
        let mut rng = XorShift::new(7);
        let mut now = 0;
        for _ in 0..50 {
            t.inject_fault(rng.below(3) as usize, &mut rng);
            // Commit enough cycles for resync to complete between faults.
            for _ in 0..50 {
                let c = t.commit(now);
                assert_ne!(c, Commit::Fatal);
                now += 1;
            }
        }
        assert_eq!(t.stats.corrected, 50);
    }
}

//! AMR cluster: 12 RV32IMFC cores with runtime-adaptive modular
//! redundancy for mission-critical integer AI (paper §II, Fig. 3).
//!
//! - **INDIP**: all 12 cores MIMD — maximum performance.
//! - **DLM** (dual lockstep): 6 main + 6 shadow cores, commit after a
//!   checker; 1.89x performance penalty vs INDIP.
//! - **TLM** (triple lockstep): 4 main + 8 shadow, majority vote; 2.85x
//!   penalty.
//!
//! Mode switches are runtime-programmable and cost 82–183 cycles
//! depending on the transition (Fig. 3c). On a detected fault, **HFR**
//! (hardware fast recovery) restores the faulty core from ECC-protected
//! recovery registers in 24 cycles — 15x faster than TLM software
//! recovery, and it saves DLM from a full cluster reboot.
//!
//! Compute model: custom SIMD `sdotp` + `mac-load` reach 94% MAC-unit
//! utilization; cluster-level MAC/cyc per precision is calibrated to the
//! paper's Fig. 8 peaks (78.5 / 152.3 / 304.9 GOPS at 8/4/2-bit and
//! 900MHz, with 2 OP = 1 MAC). The functional result of a task is the
//! corresponding AOT artifact (`matmul_int*`), executed by the runtime at
//! the coordinator level.

use super::axi::{Completion, InitiatorId};
use super::clock::{Cycle, Domain};
use super::tiles::{TileStream, TileStreamer};
use super::tsu::Tsu;
use crate::trace::{TraceBuf, TraceEvent, TraceKind};
use crate::util::XorShift;

/// Integer operand precisions (uniform and mixed), paper Fig. 5a/b.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IntPrecision {
    Int16,
    Int8,
    Int8x4,
    Int8x2,
    Int4,
    Int4x2,
    Int2,
}

impl IntPrecision {
    pub const ALL: [IntPrecision; 7] = [
        IntPrecision::Int16,
        IntPrecision::Int8,
        IntPrecision::Int8x4,
        IntPrecision::Int8x2,
        IntPrecision::Int4,
        IntPrecision::Int4x2,
        IntPrecision::Int2,
    ];

    /// Wider operand width decides SIMD lane count (paper groups mixed
    /// formats by the wider operand: "8x(8-4-2)" all run at the 8b rate).
    pub fn lane_width(&self) -> u32 {
        match self {
            IntPrecision::Int16 => 16,
            IntPrecision::Int8 | IntPrecision::Int8x4 | IntPrecision::Int8x2 => 8,
            IntPrecision::Int4 | IntPrecision::Int4x2 => 4,
            IntPrecision::Int2 => 2,
        }
    }

    /// Cluster MAC/cyc in INDIP at 94% MAC utilization (Fig. 8 peaks:
    /// 78.5/152.3/304.9 GOPS = 43.6/84.6/169.4 MAC/cyc @900MHz; 16b is
    /// half the 8b rate).
    pub fn cluster_mac_per_cyc(&self) -> f64 {
        match self.lane_width() {
            16 => 21.8,
            8 => 43.6,
            4 => 84.6,
            2 => 169.4,
            _ => unreachable!(),
        }
    }

    /// Matching AOT artifact name (functional model).
    pub fn artifact(&self) -> &'static str {
        match self {
            IntPrecision::Int16 => "matmul_int16",
            IntPrecision::Int8 => "matmul_int8",
            IntPrecision::Int8x4 => "matmul_int8x4",
            IntPrecision::Int8x2 => "matmul_int8x2",
            IntPrecision::Int4 => "matmul_int4",
            IntPrecision::Int4x2 => "matmul_int4x2",
            IntPrecision::Int2 => "matmul_int2",
        }
    }
}

/// Redundancy modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AmrMode {
    Indip,
    Dlm,
    Tlm,
}

impl AmrMode {
    /// Throughput penalty vs INDIP (paper Fig. 3c: 1.89x / 2.85x).
    pub fn perf_factor(&self) -> f64 {
        match self {
            AmrMode::Indip => 1.0,
            AmrMode::Dlm => 1.0 / 1.89,
            AmrMode::Tlm => 1.0 / 2.85,
        }
    }

    /// Cores committing architectural results.
    pub fn active_cores(&self) -> u32 {
        match self {
            AmrMode::Indip => 12,
            AmrMode::Dlm => 6,
            AmrMode::Tlm => 4,
        }
    }

    /// Reconfiguration cost in cycles (paper: 82–183 depending on the
    /// transition; lockstep entry costs more than exit because recovery
    /// registers and shadow PCs must be seeded).
    pub fn switch_cycles(from: AmrMode, to: AmrMode) -> Cycle {
        use AmrMode::*;
        match (from, to) {
            (a, b) if a == b => 0,
            (Indip, Dlm) => 97,
            (Dlm, Indip) => 82,
            (Indip, Tlm) => 183,
            (Tlm, Indip) => 124,
            (Dlm, Tlm) => 151,
            (Tlm, Dlm) => 96,
            _ => unreachable!(),
        }
    }
}

/// Fault recovery flavours (Fig. 3a/b).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Recovery {
    /// HFR: ECC recovery registers, cycle-accurate state restore.
    Hfr,
    /// Software re-execution from the last checkpoint (TLM baseline).
    Software,
    /// No checkpointing: a detected fault forces a cluster reboot.
    RebootOnly,
}

/// HFR restore latency (paper: "as few as 24 clock cycles").
pub const HFR_RESTORE_CYCLES: Cycle = 24;
/// Software recovery is 15x slower than HFR (paper Fig. 3b).
pub const SW_RECOVERY_CYCLES: Cycle = 15 * HFR_RESTORE_CYCLES;
/// Cluster reboot (reset, SPM scrub, task restart overhead).
pub const REBOOT_CYCLES: Cycle = 5_000;

/// A MatMul job for the cluster.
#[derive(Debug, Clone)]
pub struct AmrTask {
    pub precision: IntPrecision,
    /// Problem size (elements): C[m,n] += A[m,k] * B[k,n].
    pub m: u32,
    pub k: u32,
    pub n: u32,
    /// Tile edge (square tiles t x t x t).
    pub tile: u32,
    /// L2 staging addresses (DCSPM).
    pub src_base: u64,
    pub dst_base: u64,
    pub part_id: u8,
}

impl AmrTask {
    pub fn tiles(&self) -> u32 {
        let tm = self.m.div_ceil(self.tile);
        let tk = self.k.div_ceil(self.tile);
        let tn = self.n.div_ceil(self.tile);
        tm * tk * tn
    }

    pub fn macs_per_tile(&self) -> u64 {
        (self.tile as u64).pow(3)
    }

    /// Input beats per tile: A-slab + B-slab at the operand width
    /// (packed SIMD sub-words), rounded to 64b beats.
    pub fn in_beats_per_tile(&self) -> u32 {
        let elems = 2 * (self.tile as u64 * self.tile as u64);
        let bits = self.precision.lane_width() as u64;
        let bytes = (elems * bits).div_ceil(8);
        bytes.div_ceil(8).max(1) as u32
    }

    /// Output beats per tile: 32b accumulators.
    pub fn out_beats_per_tile(&self) -> u32 {
        ((self.tile as u64 * self.tile as u64 * 4).div_ceil(8)).max(1) as u32
    }
}

/// Counters for Fig. 3c / Fig. 6b.
#[derive(Debug, Clone, Copy, Default)]
pub struct AmrStats {
    pub compute_cycles: u64,
    pub stall_cycles: u64,
    pub switch_cycles: u64,
    pub recovery_cycles: u64,
    pub macs: u64,
    pub tiles_done: u32,
    pub faults_detected: u64,
    pub faults_silent: u64,
    pub reboots: u64,
    pub finished_at: Cycle,
}

impl AmrStats {
    /// Effective cluster MAC/cyc over the task's makespan.
    pub fn effective_mac_per_cyc(&self, start: Cycle) -> f64 {
        let span = self.finished_at.saturating_sub(start).max(1);
        self.macs as f64 / span as f64
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EngineState {
    Idle,
    Switching { until: Cycle, to: AmrMode },
    Recovering { until: Cycle },
    Rebooting { until: Cycle },
    Computing { until: Cycle, tile: u32 },
}

/// The cluster simulator: a bus initiator (its DMA) + compute pipeline.
pub struct AmrCluster {
    pub id: InitiatorId,
    pub mode: AmrMode,
    pub recovery: Recovery,
    /// Cluster-clock cycles per system cycle (PLL ratio).
    pub freq_ratio: f64,
    /// Fault probability per 1k compute cycles (fault-injection knob).
    pub fault_per_kcycle: f64,
    /// Max faults to inject over the task's lifetime (`None` =
    /// unbounded — the legacy knob). A `FaultPlan` pins this to its
    /// `k_faults` so "measured under injection ≤ k-fault bound" tests
    /// exactly the hypothesis admission certified.
    pub fault_budget: Option<u64>,
    /// Re-execute the interrupted tile after a detected HFR recovery
    /// (adds the tile's compute window to each recovery penalty — the
    /// per-event cost the k-fault bound prices).
    pub reexec_on_fault: bool,
    rng: XorShift,
    task: Option<AmrTask>,
    streamer: Option<TileStreamer>,
    state: EngineState,
    task_started: Cycle,
    /// Armed by `SocSim::set_trace`: fault-recovery events land here.
    trace: TraceBuf,
    pub stats: AmrStats,
}

impl AmrCluster {
    pub fn new(id: InitiatorId) -> Self {
        Self {
            id,
            mode: AmrMode::Indip,
            recovery: Recovery::Hfr,
            freq_ratio: 1.0,
            fault_per_kcycle: 0.0,
            fault_budget: None,
            reexec_on_fault: false,
            rng: XorShift::new(0xA31),
            task: None,
            streamer: None,
            state: EngineState::Idle,
            task_started: 0,
            trace: None,
            stats: AmrStats::default(),
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.rng = XorShift::new(seed);
        self
    }

    /// Request a runtime mode switch (takes effect after the FSM delay).
    pub fn switch_mode(&mut self, to: AmrMode, now: Cycle) {
        if to == self.mode {
            return;
        }
        let cost = AmrMode::switch_cycles(self.mode, to);
        self.stats.switch_cycles += cost;
        self.state = EngineState::Switching {
            until: now + cost,
            to,
        };
    }

    /// Submit a MatMul task; the cluster streams tiles from the DCSPM.
    pub fn submit(&mut self, task: AmrTask, now: Cycle) {
        let stream = TileStream {
            tiles: task.tiles(),
            in_beats: task.in_beats_per_tile(),
            out_beats: task.out_beats_per_tile(),
            src_base: task.src_base,
            dst_base: task.dst_base,
            part_id: task.part_id,
            buffer_depth: super::tiles::CLUSTER_BUFFER_DEPTH,
            wrap_bytes: crate::coordinator::policy::SocTuning::L2_SLOT_BYTES / 2,
        };
        self.streamer = Some(TileStreamer::new(self.id, stream));
        self.task = Some(task);
        self.task_started = now;
        self.stats = AmrStats::default();
    }

    /// Cycles to compute one tile at the current mode/precision, in
    /// system cycles.
    fn tile_compute_cycles(&self, task: &AmrTask) -> Cycle {
        Self::tile_compute_bound(task, self.mode, self.freq_ratio)
    }

    /// Deterministic per-tile compute time for `task` under `mode` — the
    /// exact duration the FSM uses, exposed so the WCET engine composes
    /// the same number instead of re-deriving it. Fault recoveries are
    /// priced separately: under a `FaultPlan` the k-fault re-execution
    /// term adds `k * (HFR_RESTORE_CYCLES + this bound)` per lockstep
    /// task, which is exactly the worst per-event penalty
    /// `fault_penalty` can charge with `reexec_on_fault` set.
    pub fn tile_compute_bound(task: &AmrTask, mode: AmrMode, freq_ratio: f64) -> Cycle {
        let rate = task.precision.cluster_mac_per_cyc() * mode.perf_factor() * freq_ratio;
        (task.macs_per_tile() as f64 / rate).ceil() as Cycle
    }

    /// Worst observed L2 transfer latency (WCET measured counterpart).
    pub fn mem_latency_max(&self) -> Cycle {
        self.streamer.as_ref().map_or(0, |s| s.max_latency)
    }

    /// Sample fault events over a compute window and return the total
    /// recovery penalty (applied after the tile completes).
    fn fault_penalty(&mut self, window: Cycle) -> Cycle {
        if self.fault_per_kcycle <= 0.0 {
            return 0;
        }
        let expected = self.fault_per_kcycle * window as f64 / 1000.0;
        let mut events = expected.floor() as u64;
        if self.rng.chance(expected - events as f64) {
            events += 1;
        }
        // A pinned budget caps injection at the k faults the admission
        // bound was asked to cover (sampling the RNG first keeps the
        // stream position — and so any unbudgeted run — unchanged).
        if let Some(budget) = self.fault_budget {
            let injected = self.stats.faults_detected + self.stats.faults_silent;
            events = events.min(budget.saturating_sub(injected));
        }
        if events == 0 {
            return 0;
        }
        let mut penalty = 0;
        for _ in 0..events {
            match (self.mode, self.recovery) {
                (AmrMode::Indip, _) => {
                    // Undetected by hardware: silent corruption.
                    self.stats.faults_silent += 1;
                }
                (_, Recovery::Hfr) => {
                    self.stats.faults_detected += 1;
                    penalty += HFR_RESTORE_CYCLES
                        + if self.reexec_on_fault { window } else { 0 };
                }
                (AmrMode::Tlm, Recovery::Software) => {
                    self.stats.faults_detected += 1;
                    penalty += SW_RECOVERY_CYCLES;
                }
                // DLM cannot re-execute from a software checkpoint
                // without knowing which replica is right; without HFR a
                // detected divergence forces a cluster reboot.
                (AmrMode::Dlm, Recovery::Software)
                | (AmrMode::Dlm, Recovery::RebootOnly)
                | (AmrMode::Tlm, Recovery::RebootOnly) => {
                    self.stats.faults_detected += 1;
                    self.stats.reboots += 1;
                    penalty += REBOOT_CYCLES;
                }
            }
        }
        self.stats.recovery_cycles += penalty;
        penalty
    }

    pub fn task_done(&self) -> bool {
        match (&self.task, &self.streamer) {
            (Some(_), Some(s)) => s.done() && matches!(self.state, EngineState::Idle),
            _ => true,
        }
    }

    /// Event-driven hook: min of the tile-DMA side (issue-ready) and the
    /// compute FSM (switch/recovery/compute completion times). `None`
    /// while everything waits on bus completions or the task is done.
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        let mut earliest = self.streamer.as_ref().and_then(|s| s.next_event(now));
        let engine = match self.state {
            EngineState::Idle => {
                if self.task.is_some()
                    && self.streamer.as_ref().is_some_and(|s| s.ready_tiles() > 0)
                {
                    Some(now) // a tile is ready: compute starts this cycle
                } else {
                    None
                }
            }
            EngineState::Switching { until, .. }
            | EngineState::Recovering { until }
            | EngineState::Rebooting { until }
            | EngineState::Computing { until, .. } => Some(until.max(now)),
        };
        if let Some(t) = engine {
            earliest = super::clock::merge_event(earliest, t);
        }
        earliest
    }

    /// Replay per-cycle accounting over a skipped window `[from, to)`:
    /// streamer busy cycles plus the compute pipeline's data-starvation
    /// stall counter (one per naive idle tick without a ready tile).
    pub fn fast_forward(&mut self, from: Cycle, to: Cycle) {
        if let Some(s) = self.streamer.as_mut() {
            s.fast_forward(from, to);
        }
        if matches!(self.state, EngineState::Idle) && self.task.is_some() {
            if let Some(s) = &self.streamer {
                if s.ready_tiles() == 0 && !s.fetches_done() {
                    self.stats.stall_cycles += to - from;
                }
            }
        }
    }

    /// One system cycle of the compute pipeline + DMA.
    pub fn tick(&mut self, now: Cycle, tsu: &mut Tsu) {
        // DMA side always advances (double buffering).
        if let Some(s) = self.streamer.as_mut() {
            s.tick(now, tsu);
        }
        match self.state {
            EngineState::Switching { until, to } => {
                if now >= until {
                    self.mode = to;
                    self.state = EngineState::Idle;
                }
            }
            EngineState::Recovering { until } | EngineState::Rebooting { until } => {
                if now >= until {
                    self.state = EngineState::Idle;
                }
            }
            EngineState::Computing { until, tile } => {
                if now >= until {
                    let task = self.task.clone().expect("computing without task");
                    self.stats.macs += task.macs_per_tile();
                    self.stats.tiles_done += 1;
                    if let Some(s) = self.streamer.as_mut() {
                        s.push_writeback(tile);
                    }
                    let penalty = self.fault_penalty(self.tile_compute_cycles(&task));
                    // Determinism: this arm only runs when `now >= until`,
                    // and `next_event` pins `until` — the event-driven run
                    // steps this exact cycle, so naive and event-driven
                    // runs record identical recovery events.
                    if penalty > 0 {
                        if let Some(tb) = self.trace.as_deref_mut() {
                            tb.push(TraceEvent {
                                at: now,
                                domain: Domain::System,
                                initiator: self.id,
                                target: None,
                                lane: 0,
                                tag: tile as u64,
                                kind: TraceKind::Recovery {
                                    penalty,
                                    reboot: penalty >= REBOOT_CYCLES,
                                },
                            });
                        }
                    }
                    self.state = if penalty >= REBOOT_CYCLES {
                        EngineState::Rebooting {
                            until: now + penalty,
                        }
                    } else if penalty > 0 {
                        EngineState::Recovering {
                            until: now + penalty,
                        }
                    } else {
                        EngineState::Idle
                    };
                    self.update_finish(now);
                }
            }
            EngineState::Idle => {
                let Some(task) = self.task.clone() else {
                    return;
                };
                if let Some(s) = self.streamer.as_mut() {
                    if let Some(tile) = s.pop_ready() {
                        let dur = self.tile_compute_cycles(&task);
                        self.stats.compute_cycles += dur;
                        self.state = EngineState::Computing {
                            until: now + dur,
                            tile,
                        };
                    } else if !s.fetches_done() {
                        self.stats.stall_cycles += 1;
                    }
                }
                self.update_finish(now);
            }
        }
    }

    fn update_finish(&mut self, now: Cycle) {
        if let (Some(task), Some(s)) = (&self.task, &self.streamer) {
            if s.done() && self.stats.tiles_done >= task.tiles() && self.stats.finished_at == 0 {
                self.stats.finished_at = now;
            }
        }
    }

    /// Deliver a DMA completion.
    pub fn complete(&mut self, c: Completion, now: Cycle) {
        if let Some(s) = self.streamer.as_mut() {
            s.complete(c, now);
        }
        self.update_finish(now);
    }

    /// Analytic peak GOPS at voltage `v` (Fig. 5a): 2 OP = 1 MAC.
    pub fn peak_gops(precision: IntPrecision, mode: AmrMode, v: f64) -> f64 {
        let f = super::power::DvfsCurve::amr().freq_mhz(v);
        precision.cluster_mac_per_cyc() * mode.perf_factor() * 2.0 * f / 1000.0
    }

    /// Analytic energy efficiency in GOPS/W at voltage `v` (Fig. 5b).
    pub fn efficiency_gops_w(precision: IntPrecision, mode: AmrMode, v: f64) -> f64 {
        let gops = Self::peak_gops(precision, mode, v);
        // Lockstep shadows burn the same dynamic power as mains: the
        // cluster's utilization stays ~1 in every mode.
        let p_w = super::power::DvfsCurve::amr().power_at_v(v, 1.0) / 1000.0;
        gops / p_w
    }
}

impl super::BusInitiator for AmrCluster {
    fn id(&self) -> InitiatorId {
        self.id
    }
    fn tick(&mut self, now: Cycle, tsu: &mut Tsu) {
        AmrCluster::tick(self, now, tsu)
    }
    fn complete(&mut self, c: Completion, now: Cycle, _tsu: &mut Tsu) {
        AmrCluster::complete(self, c, now)
    }
    fn finished(&self) -> bool {
        self.task_done()
    }
    fn next_event(&self, now: Cycle) -> Option<Cycle> {
        AmrCluster::next_event(self, now)
    }
    fn fast_forward(&mut self, from: Cycle, to: Cycle) {
        AmrCluster::fast_forward(self, from, to)
    }
    fn set_trace(&mut self, on: bool) {
        self.trace = if on { crate::trace::armed() } else { None };
    }
    fn take_trace(&mut self) -> Vec<TraceEvent> {
        match self.trace.as_deref_mut() {
            Some(v) => std::mem::take(v),
            None => Vec::new(),
        }
    }
    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::axi::TargetModel;
    use crate::soc::mem::Dcspm;
    use crate::soc::tsu::TsuConfig;
    use crate::soc::SocSim;

    fn task(precision: IntPrecision) -> AmrTask {
        AmrTask {
            precision,
            m: 64,
            k: 64,
            n: 64,
            tile: 32,
            src_base: 0,
            dst_base: 0x8_0000,
            part_id: 0,
        }
    }

    fn run_cluster(mut cluster: AmrCluster, t: AmrTask) -> AmrStats {
        let mut soc = SocSim::new(1, vec![Box::new(Dcspm::new()) as Box<dyn TargetModel>]);
        cluster.submit(t, 0);
        soc.attach(Box::new(cluster), TsuConfig::passthrough());
        assert!(soc.run_until_done(50_000_000), "cluster never drained");
        let c: &mut AmrCluster = soc.initiator_mut(InitiatorId(0));
        c.stats
    }

    #[test]
    fn mode_switch_costs_in_paper_range() {
        use AmrMode::*;
        for from in [Indip, Dlm, Tlm] {
            for to in [Indip, Dlm, Tlm] {
                let c = AmrMode::switch_cycles(from, to);
                if from == to {
                    assert_eq!(c, 0);
                } else {
                    assert!((82..=183).contains(&c), "{from:?}->{to:?}: {c}");
                }
            }
        }
    }

    #[test]
    fn dlm_tlm_penalties_match_paper() {
        // 8b: INDIP 43.6 -> DLM 23.07 (paper: 23.1), TLM 15.3.
        let dlm = IntPrecision::Int8.cluster_mac_per_cyc() * AmrMode::Dlm.perf_factor();
        let tlm = IntPrecision::Int8.cluster_mac_per_cyc() * AmrMode::Tlm.perf_factor();
        assert!((dlm - 23.1).abs() < 0.05, "{dlm}");
        assert!((tlm - 15.3).abs() < 0.05, "{tlm}");
    }

    #[test]
    fn peak_gops_match_fig8() {
        let cases = [
            (IntPrecision::Int8, 78.5),
            (IntPrecision::Int4, 152.3),
            (IntPrecision::Int2, 304.9),
        ];
        for (p, want) in cases {
            let got = AmrCluster::peak_gops(p, AmrMode::Indip, 1.1);
            assert!((got - want).abs() / want < 0.01, "{p:?}: {got} vs {want}");
        }
        // DLM 2b: 161.4 GOPS.
        let dlm2 = AmrCluster::peak_gops(IntPrecision::Int2, AmrMode::Dlm, 1.1);
        assert!((dlm2 - 161.4).abs() / 161.4 < 0.01, "{dlm2}");
    }

    #[test]
    fn efficiency_peaks_at_min_voltage() {
        let lo = AmrCluster::efficiency_gops_w(IntPrecision::Int2, AmrMode::Indip, 0.6);
        let hi = AmrCluster::efficiency_gops_w(IntPrecision::Int2, AmrMode::Indip, 1.1);
        assert!((lo - 1607.0).abs() / 1607.0 < 0.05, "{lo}");
        assert!(lo > 3.0 * hi);
    }

    #[test]
    fn task_runs_to_completion_indip() {
        let stats = run_cluster(AmrCluster::new(InitiatorId(0)), task(IntPrecision::Int8));
        assert_eq!(stats.tiles_done, 8); // (64/32)^3
        assert_eq!(stats.macs, 8 * 32u64.pow(3));
        assert_eq!(stats.faults_detected + stats.faults_silent, 0);
    }

    #[test]
    fn dlm_is_slower_than_indip() {
        let t = task(IntPrecision::Int8);
        let s_ind = run_cluster(AmrCluster::new(InitiatorId(0)), t.clone());
        let mut dlm = AmrCluster::new(InitiatorId(0));
        dlm.mode = AmrMode::Dlm;
        let s_dlm = run_cluster(dlm, t);
        let ratio = s_dlm.finished_at as f64 / s_ind.finished_at as f64;
        // Compute-bound here, so the makespan ratio approaches 1.89.
        assert!((1.6..2.1).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn faults_trigger_hfr_and_cost_24_cycles_each() {
        let mut c = AmrCluster::new(InitiatorId(0)).with_seed(7);
        c.mode = AmrMode::Dlm;
        c.fault_per_kcycle = 1.0;
        let stats = run_cluster(c, task(IntPrecision::Int8));
        assert!(stats.faults_detected > 0);
        assert_eq!(
            stats.recovery_cycles,
            stats.faults_detected * HFR_RESTORE_CYCLES
        );
        assert_eq!(stats.reboots, 0, "HFR avoids reboots");
    }

    #[test]
    fn tlm_software_recovery_is_15x_slower() {
        assert_eq!(SW_RECOVERY_CYCLES, 15 * HFR_RESTORE_CYCLES);
        let mut c = AmrCluster::new(InitiatorId(0)).with_seed(9);
        c.mode = AmrMode::Tlm;
        c.recovery = Recovery::Software;
        c.fault_per_kcycle = 1.0;
        let stats = run_cluster(c, task(IntPrecision::Int8));
        assert!(stats.faults_detected > 0);
        assert_eq!(
            stats.recovery_cycles,
            stats.faults_detected * SW_RECOVERY_CYCLES
        );
    }

    #[test]
    fn indip_faults_are_silent() {
        let mut c = AmrCluster::new(InitiatorId(0)).with_seed(11);
        c.fault_per_kcycle = 2.0;
        let stats = run_cluster(c, task(IntPrecision::Int8));
        assert!(stats.faults_silent > 0);
        assert_eq!(stats.faults_detected, 0);
        assert_eq!(stats.recovery_cycles, 0);
    }

    #[test]
    fn dlm_without_hfr_reboots() {
        let mut c = AmrCluster::new(InitiatorId(0)).with_seed(13);
        c.mode = AmrMode::Dlm;
        c.recovery = Recovery::RebootOnly;
        c.fault_per_kcycle = 0.5;
        let stats = run_cluster(c, task(IntPrecision::Int8));
        assert!(stats.reboots > 0);
        assert!(stats.recovery_cycles >= stats.reboots * REBOOT_CYCLES);
    }

    #[test]
    fn fault_budget_caps_injection_and_reexec_prices_the_window() {
        let t = task(IntPrecision::Int8);
        let mk = |budget, reexec| {
            let mut c = AmrCluster::new(InitiatorId(0)).with_seed(7);
            c.mode = AmrMode::Dlm;
            c.fault_per_kcycle = 1.0;
            c.fault_budget = budget;
            c.reexec_on_fault = reexec;
            c
        };
        let unbudgeted = run_cluster(mk(None, false), t.clone());
        assert!(unbudgeted.faults_detected > 1, "seed 7 injects several");
        // Budget 1: exactly one fault lands; budget 0: none (the k=0
        // path is injection-free regardless of the rate knob).
        let one = run_cluster(mk(Some(1), false), t.clone());
        assert_eq!(one.faults_detected, 1);
        let zero = run_cluster(mk(Some(0), false), t.clone());
        assert_eq!(zero.faults_detected + zero.faults_silent, 0);
        assert_eq!(zero.recovery_cycles, 0);
        // Re-execution charges the interrupted tile's window on top of
        // the HFR restore, per event.
        let window = AmrCluster::tile_compute_bound(&t, AmrMode::Dlm, 1.0);
        let re = run_cluster(mk(Some(1), true), t);
        assert_eq!(re.faults_detected, 1);
        assert_eq!(re.recovery_cycles, HFR_RESTORE_CYCLES + window);
    }

    #[test]
    fn mode_switch_applies_after_delay() {
        let mut c = AmrCluster::new(InitiatorId(0));
        let mut tsu = Tsu::new(TsuConfig::passthrough());
        c.switch_mode(AmrMode::Tlm, 0);
        assert_eq!(c.mode, AmrMode::Indip);
        for now in 0..=200 {
            c.tick(now, &mut tsu);
        }
        assert_eq!(c.mode, AmrMode::Tlm);
        assert_eq!(c.stats.switch_cycles, 183);
    }

    #[test]
    fn int2_is_faster_than_int8() {
        let s8 = run_cluster(AmrCluster::new(InitiatorId(0)), task(IntPrecision::Int8));
        let s2 = run_cluster(AmrCluster::new(InitiatorId(0)), task(IntPrecision::Int2));
        assert!(s2.finished_at < s8.finished_at);
    }

    #[test]
    fn artifacts_cover_all_precisions() {
        for p in IntPrecision::ALL {
            assert!(p.artifact().starts_with("matmul_int"));
        }
    }
}

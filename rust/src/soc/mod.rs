//! The SoC substrate: every hardware block the paper's evaluation
//! depends on, modelled cycle-approximately.
//!
//! `SocSim` is the top-level harness: it owns the AXI crossbar with its
//! target models, one TSU per initiator, and the initiator state machines
//! (host cores, DMA engines, accelerator clusters). One call to `step()`
//! advances the whole SoC by a single system-clock cycle:
//!
//! 1. initiators generate traffic into their TSUs,
//! 2. TSUs release shaped fragments into the crossbar queues,
//! 3. the crossbar grants bursts to targets and advances them,
//! 4. completions route back to their initiators.

pub mod amr;
pub mod axi;
pub mod clock;
pub mod dma;
pub mod hostd;
pub mod mem;
pub mod power;
pub mod safed;
pub mod secd;
pub mod tiles;
pub mod tsu;
pub mod vector;

use std::any::Any;

use axi::{xbar::Crossbar, Burst, Completion, InitiatorId, TargetModel};
use clock::{Cycle, Domain};
use tsu::{Tsu, TsuConfig};

use crate::trace::{TraceBuf, TraceEvent, TraceKind};

/// Anything that drives traffic onto the AXI fabric.
pub trait BusInitiator: Any {
    fn id(&self) -> InitiatorId;
    /// Generate work for this cycle (submit bursts into `tsu`).
    fn tick(&mut self, now: Cycle, tsu: &mut Tsu);
    /// Receive a completion (may immediately submit follow-up bursts).
    fn complete(&mut self, c: Completion, now: Cycle, tsu: &mut Tsu);
    /// True when this initiator has no more work (drain condition).
    fn finished(&self) -> bool;
    /// Event-driven hook: the earliest cycle `>= now` at which ticking
    /// this initiator does anything on its own (issue a burst, finish a
    /// compute phase), assuming no completion arrives in between; `None`
    /// while it is dormant until a completion wakes it.
    ///
    /// Contract: ticks in `now..event` must be no-ops except for
    /// per-cycle counters, which [`BusInitiator::fast_forward`] replays
    /// exactly. The default (an event every cycle) disables skipping for
    /// initiators that do not opt in.
    fn next_event(&self, now: Cycle) -> Option<Cycle> {
        Some(now)
    }
    /// Replay per-cycle bookkeeping for a skipped window `[from, to)` so
    /// a skipped run stays bit-identical to naive stepping.
    fn fast_forward(&mut self, from: Cycle, to: Cycle) {
        let _ = (from, to);
    }
    /// Arm or disarm this initiator's own trace hooks (e.g. AMR fault
    /// recoveries). Initiators without hook sites ignore it.
    fn set_trace(&mut self, _on: bool) {}
    /// Drain recorded trace events (empty unless instrumented).
    fn take_trace(&mut self) -> Vec<TraceEvent> {
        Vec::new()
    }
    /// Downcast hook for result extraction by experiments.
    fn as_any(&mut self) -> &mut dyn Any;
}

impl BusInitiator for hostd::HostCore {
    fn id(&self) -> InitiatorId {
        self.id
    }
    fn tick(&mut self, now: Cycle, tsu: &mut Tsu) {
        hostd::HostCore::tick(self, now, tsu)
    }
    fn complete(&mut self, c: Completion, now: Cycle, _tsu: &mut Tsu) {
        hostd::HostCore::complete(self, c, now)
    }
    fn finished(&self) -> bool {
        self.done()
    }
    fn next_event(&self, now: Cycle) -> Option<Cycle> {
        hostd::HostCore::next_event(self, now)
    }
    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

impl BusInitiator for dma::DmaEngine {
    fn id(&self) -> InitiatorId {
        self.id
    }
    fn tick(&mut self, now: Cycle, tsu: &mut Tsu) {
        dma::DmaEngine::tick(self, now, tsu)
    }
    fn complete(&mut self, c: Completion, now: Cycle, tsu: &mut Tsu) {
        dma::DmaEngine::complete(self, c, now, tsu)
    }
    fn finished(&self) -> bool {
        self.done()
    }
    fn next_event(&self, now: Cycle) -> Option<Cycle> {
        dma::DmaEngine::next_event(self, now)
    }
    fn fast_forward(&mut self, from: Cycle, to: Cycle) {
        dma::DmaEngine::fast_forward(self, from, to)
    }
    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// Release everything `tsu` can shape at `now` into the fabric,
/// stamping release times and trace events. Shared verbatim by every
/// stepping core (the wheel calls it only at processed cycles, where
/// `Tsu::release` has lazily caught up on any skipped period
/// rollovers).
fn release_into_fabric(
    tsu: &mut Tsu,
    staged: &mut Vec<Burst>,
    xbar: &mut Crossbar,
    trace: &mut TraceBuf,
    now: Cycle,
) {
    staged.clear();
    tsu.release(now, staged);
    for mut b in staged.drain(..) {
        b.released_at = now;
        if let Some(tb) = trace.as_deref_mut() {
            tb.push(TraceEvent {
                at: now,
                domain: Domain::System,
                initiator: b.initiator,
                target: Some(b.target),
                lane: 0,
                tag: b.tag,
                kind: TraceKind::TsuRelease {
                    beats: b.beats,
                    write: b.write,
                },
            });
        }
        xbar.push(b);
    }
}

/// Flat next-event arrays for the wheel core (the structure-of-arrays
/// hot state): one slot per port, `Cycle::MAX` = dormant. `clean[i]` is
/// the replay watermark — every cycle `< clean[i]` is fully accounted
/// on port `i`'s initiator and TSU; the window up to the current cycle
/// is replayed lazily through the `fast_forward` hooks before the port
/// next acts (or at the end of the run).
#[derive(Default)]
struct WheelState {
    init_next: Vec<Cycle>,
    tsu_next: Vec<Cycle>,
    clean: Vec<Cycle>,
    /// Completion-delivery bookkeeping, flattened into the same SoA
    /// layout: `comp_stamp[i]` is the delivery cycle (keyed to
    /// `now + 1`) port `i` last received a completion at, and
    /// `comp_dirty` lists the ports touched by the in-flight delivery
    /// batch. Together they collapse the per-completion sync/recompute
    /// pair — two virtual `next_event` calls per boxed completion — into
    /// one sync before a port's first completion and one slot refresh
    /// after its last.
    comp_stamp: Vec<Cycle>,
    comp_dirty: Vec<usize>,
}

/// The assembled SoC.
///
/// Three stepping regimes share one cycle-accurate semantics:
///
/// - [`SocSim::step`] — naive: every component ticks every cycle;
/// - [`SocSim::step_fast`] — event-driven: after a normal step, if the
///   crossbar is idle, `now` jumps straight to the earliest pending
///   event (TSU release times, compute/service completion times) and
///   per-cycle counters are replayed via the `fast_forward` hooks. The
///   two regimes produce bit-identical results (enforced by
///   `tests/event_driven_equivalence.rs`, and cross-checkable at runtime
///   with [`SocSim::validate_skips`]);
/// - [`SocSim::run_until_wheel`] — the wheel core: flat per-port and
///   per-target arrays of next-event times drive both *which* components
///   a processed cycle touches (only those whose wheel slot fired) and
///   *how far* the clock can jump between processed cycles — including
///   across busy-but-inert windows (W-channel holds, parked grant
///   scans) the event-driven core must step through. Bit-identical to
///   both of the above (`tests/wheel_equivalence.rs`).
pub struct SocSim {
    pub xbar: Crossbar,
    ports: Vec<(Box<dyn BusInitiator>, Tsu)>,
    staged: Vec<Burst>,
    /// Reused completion scratch (avoids per-cycle reallocation).
    comp_scratch: Vec<Completion>,
    /// Wheel-core state; inert unless a `*_wheel` entry point runs.
    wheel: WheelState,
    pub now: Cycle,
    /// Whether `run_until_done` uses the event-driven fast path.
    pub event_driven: bool,
    /// Debug cross-check: instead of jumping over a quiescent window,
    /// step through it naively and assert that it really was quiescent
    /// (no grants, no completions). Keeps naive state; catches wrong
    /// `next_event` implementations.
    pub validate_skips: bool,
    /// Cycles elided by the fast path (observability).
    pub skipped_cycles: u64,
    /// Completions delivered to initiators so far (skip validation).
    pub completions_delivered: u64,
    /// Harness-level trace sink: TSU release and completion-delivery
    /// events (both fire only in stepped cycles — releases are pinned by
    /// `Tsu::next_release_at`, deliveries by the crossbar's queued-work
    /// events — so naive and event-driven streams are bit-identical).
    /// `None` (the default) disables tracing at one branch per site.
    trace: TraceBuf,
}

impl SocSim {
    /// Standard Carfield target set: DCSPM + DPLLC/HyperRAM + peripherals.
    pub fn carfield_targets() -> Vec<Box<dyn TargetModel>> {
        vec![
            Box::new(mem::Dcspm::new()),
            Box::new(mem::HyperramPath::carfield()),
            Box::new(mem::Peripheral::new(mem::Peripheral::DEFAULT_LATENCY)),
        ]
    }

    /// Build with `n_initiators` port slots and the given targets.
    pub fn new(n_initiators: usize, targets: Vec<Box<dyn TargetModel>>) -> Self {
        Self {
            xbar: Crossbar::new(n_initiators, targets),
            ports: Vec::new(),
            staged: Vec::new(),
            comp_scratch: Vec::new(),
            wheel: WheelState::default(),
            now: 0,
            event_driven: true,
            validate_skips: false,
            skipped_cycles: 0,
            completions_delivered: 0,
            trace: None,
        }
    }

    /// Arm or disarm tracing SoC-wide: the crossbar and its targets,
    /// this harness's release/delivery hooks, and every attached
    /// initiator. Call after `attach`; arming mid-run starts a partial
    /// stream but never perturbs simulation state.
    pub fn set_trace(&mut self, on: bool) {
        self.trace = if on { crate::trace::armed() } else { None };
        self.xbar.set_trace(on);
        for (init, _) in self.ports.iter_mut() {
            init.set_trace(on);
        }
    }

    /// Drain every component's recorded events (harness, fabric +
    /// targets, initiators — a fixed order, so the capture's stable
    /// sort stays deterministic).
    pub fn take_trace(&mut self) -> Vec<TraceEvent> {
        let mut out = match self.trace.as_deref_mut() {
            Some(v) => std::mem::take(v),
            None => Vec::new(),
        };
        out.extend(self.xbar.take_trace());
        for (init, _) in self.ports.iter_mut() {
            out.extend(init.take_trace());
        }
        out
    }

    /// Attach an initiator with its TSU configuration. The initiator's
    /// `InitiatorId` must match its port index.
    pub fn attach(&mut self, init: Box<dyn BusInitiator>, cfg: TsuConfig) {
        assert_eq!(
            init.id().0 as usize,
            self.ports.len(),
            "attach order must follow InitiatorId"
        );
        self.ports.push((init, Tsu::new(cfg)));
    }

    /// Reprogram one initiator's TSU at runtime (the coordinator's knob).
    pub fn reconfigure_tsu(&mut self, id: InitiatorId, cfg: TsuConfig) {
        self.ports[id.0 as usize].1.reconfigure(cfg);
    }

    /// Program the multi-rate timebase from a clock tree: every crossbar
    /// target steps on its own domain's cycle grid (the uncore targets
    /// decouple from the system clock when the tree says so). Without
    /// this call — or with a coupled tree — every converter is the
    /// identity and stepping is bit-identical to the single-timebase
    /// seed. Initiators (host cores, DMA, cluster FSMs) stay on the
    /// system grid; clusters scale their compute internally via
    /// `freq_ratio`, exactly as before.
    pub fn set_clocks(&mut self, tree: &clock::ClockTree) {
        self.xbar.set_clocks(tree);
    }

    /// Borrow an attached initiator back as concrete type `T`.
    pub fn initiator_mut<T: 'static>(&mut self, id: InitiatorId) -> &mut T {
        self.ports[id.0 as usize]
            .0
            .as_any()
            .downcast_mut::<T>()
            .expect("initiator type mismatch")
    }

    pub fn tsu_stats(&self, id: InitiatorId) -> tsu::TsuStats {
        self.ports[id.0 as usize].1.stats
    }

    /// Advance one system cycle.
    pub fn step(&mut self) {
        let now = self.now;
        for (init, tsu) in self.ports.iter_mut() {
            init.tick(now, tsu);
            if tsu.queued() == 0 {
                continue; // nothing shaped this cycle
            }
            release_into_fabric(tsu, &mut self.staged, &mut self.xbar, &mut self.trace, now);
        }
        self.xbar.tick(now);
        self.deliver_completions(now, false);
        self.now += 1;
    }

    /// Route this cycle's completions back to their initiators (shared
    /// by every stepping core). With `wheel` set, each receiving port's
    /// lazy replay window is flushed through this cycle's no-op tick
    /// before its *first* completion lands — running counters must see
    /// the pre-completion state, exactly as under naive stepping — and
    /// its wheel slots are refreshed once after its *last* (the slots
    /// are only read again after delivery returns, so deferring the
    /// refresh past later completions is last-write-wins identical to
    /// refreshing per completion, minus the repeated virtual
    /// `next_event` calls per boxed completion).
    fn deliver_completions(&mut self, now: Cycle, wheel: bool) {
        if self.xbar.completions.is_empty() {
            return;
        }
        // Swap into the reusable scratch so the crossbar keeps an
        // allocated-but-empty buffer (hot-loop optimization, see
        // EXPERIMENTS.md §Perf).
        std::mem::swap(&mut self.comp_scratch, &mut self.xbar.completions);
        self.completions_delivered += self.comp_scratch.len() as u64;
        debug_assert!(self.wheel.comp_dirty.is_empty());
        for i in 0..self.comp_scratch.len() {
            let c = self.comp_scratch[i];
            if let Some(tb) = self.trace.as_deref_mut() {
                tb.push(TraceEvent {
                    at: now,
                    domain: Domain::System,
                    initiator: c.initiator,
                    target: Some(c.target),
                    lane: 0,
                    tag: c.tag,
                    kind: TraceKind::Delivery {
                        beats: c.beats,
                        write: c.write,
                        last_fragment: c.last_fragment,
                        issued_at: c.issued_at,
                        released_at: c.released_at,
                        granted_at: c.granted_at,
                    },
                });
            }
            let port = c.initiator.0 as usize;
            if wheel && self.wheel.comp_stamp[port] != now + 1 {
                self.wheel_sync_port(port, now + 1);
                self.wheel.comp_stamp[port] = now + 1;
                self.wheel.comp_dirty.push(port);
            }
            let (init, tsu) = &mut self.ports[port];
            init.complete(c, now, tsu);
            // A completion may have queued follow-up bursts eligible
            // this cycle; release immediately so back-to-back chains
            // don't pay a phantom cycle.
            release_into_fabric(tsu, &mut self.staged, &mut self.xbar, &mut self.trace, now);
        }
        while let Some(port) = self.wheel.comp_dirty.pop() {
            self.wheel_recompute_port(port, now + 1);
        }
        self.comp_scratch.clear();
    }

    /// All initiators drained and the fabric empty.
    pub fn drained(&self) -> bool {
        self.ports.iter().all(|(i, _)| i.finished()) && self.xbar.idle()
    }

    /// The earliest cycle `>= self.now` at which *anything* in the SoC
    /// can act: a queued burst (grant scan), a target's service edge, an
    /// initiator's own event, or a TSU release. `None` when the whole
    /// fabric is dormant until the simulation budget runs out.
    fn next_event_cycle(&self) -> Option<Cycle> {
        let now = self.now;
        let mut earliest = self.xbar.next_event(now);
        if earliest == Some(now) {
            return earliest;
        }
        for (init, tsu) in &self.ports {
            for ev in [init.next_event(now), tsu.next_release_at(now)] {
                if let Some(t) = ev {
                    let t = t.max(now);
                    earliest = clock::merge_event(earliest, t);
                    if t == now {
                        return earliest;
                    }
                }
            }
        }
        earliest
    }

    /// Jump `now` to the earliest pending event (clamped to `deadline`),
    /// replaying per-cycle counters through the `fast_forward` hooks.
    /// With nothing pending at all, jumps to `deadline` so budget-bound
    /// loops terminate without spinning. No-op when something can act
    /// this very cycle.
    pub fn skip_to_next_event(&mut self, deadline: Cycle) {
        let target = match self.next_event_cycle() {
            Some(t) => t.min(deadline),
            None => deadline,
        };
        if target <= self.now {
            return;
        }
        if self.validate_skips {
            self.validate_quiescent(target);
        } else {
            let (from, to) = (self.now, target);
            for (init, tsu) in self.ports.iter_mut() {
                init.fast_forward(from, to);
                tsu.fast_forward(from, to);
            }
            self.xbar.fast_forward(from, to);
            self.skipped_cycles += to - from;
            self.now = target;
        }
    }

    /// Debug cross-check for the event computation: instead of jumping,
    /// step the window naively and assert it is quiescent — no bursts
    /// granted, no completions delivered, nothing new queued. State ends
    /// up exactly as a naive run's (per-cycle counters included).
    fn validate_quiescent(&mut self, target: Cycle) {
        while self.now < target {
            let granted: u64 = self.xbar.granted_beats.iter().sum();
            let delivered = self.completions_delivered;
            let at = self.now;
            self.step();
            assert_eq!(
                self.xbar.queued_bursts(),
                0,
                "skip window not quiescent: burst queued at cycle {at}"
            );
            let granted_after: u64 = self.xbar.granted_beats.iter().sum();
            assert_eq!(
                granted, granted_after,
                "skip window not quiescent: grant at cycle {at}"
            );
            assert_eq!(
                delivered, self.completions_delivered,
                "skip window not quiescent: completion at cycle {at}"
            );
        }
    }

    /// One event-driven step: a normal cycle, then (if the fabric is
    /// quiescent) a jump to the next event, clamped to `deadline`.
    pub fn step_fast(&mut self, deadline: Cycle) {
        self.step();
        if self.now < deadline {
            self.skip_to_next_event(deadline);
        }
    }

    /// The shared run loop: step (with event skipping when
    /// `event_driven`) until `done` holds or `deadline` is reached.
    /// The skip is suppressed the moment `done` holds so the cycle
    /// count callers observe matches naive stepping exactly. Returns
    /// true when `done` held before the deadline.
    pub fn run_until(
        &mut self,
        deadline: Cycle,
        event_driven: bool,
        mut done: impl FnMut(&SocSim) -> bool,
    ) -> bool {
        while self.now < deadline {
            if done(self) {
                return true;
            }
            self.step();
            if event_driven && !done(self) {
                self.skip_to_next_event(deadline);
            }
        }
        false
    }

    /// Step until every initiator reports finished (or budget exhausted).
    /// Returns true if drained. Uses the event-driven fast path unless
    /// [`SocSim::event_driven`] is cleared; both paths are bit-identical.
    pub fn run_until_done(&mut self, max_cycles: Cycle) -> bool {
        let deadline = self.now + max_cycles;
        let fast = self.event_driven;
        self.run_until(deadline, fast, |soc| soc.drained())
    }

    /// Step a fixed number of cycles, one at a time (naive reference).
    pub fn run_cycles(&mut self, cycles: Cycle) {
        for _ in 0..cycles {
            self.step();
        }
    }

    /// Advance a fixed number of simulated cycles on the event-driven
    /// path (the bench's fast counterpart to [`SocSim::run_cycles`]).
    pub fn run_cycles_fast(&mut self, cycles: Cycle) {
        let deadline = self.now + cycles;
        self.run_until(deadline, true, |_| false);
    }

    // --- Wheel core -----------------------------------------------------

    /// Arm the wheel: size the flat arrays to the attached ports and
    /// compute every slot's next-event time at the current cycle.
    fn wheel_init(&mut self) {
        let now = self.now;
        let n = self.ports.len();
        self.wheel.init_next.resize(n, Cycle::MAX);
        self.wheel.tsu_next.resize(n, Cycle::MAX);
        self.wheel.clean.resize(n, now);
        // Stamp 0 is safe as the "no completion delivered" sentinel:
        // deliveries key the stamp to `now + 1 >= 1`.
        self.wheel.comp_stamp.clear();
        self.wheel.comp_stamp.resize(n, 0);
        self.wheel.comp_dirty.clear();
        for i in 0..n {
            self.wheel.clean[i] = now;
            self.wheel_recompute_port(i, now);
        }
        self.xbar.wheel_init(now);
    }

    /// Refresh port `i`'s wheel slots (initiator event, TSU release
    /// deadline) as seen from cycle `at`.
    fn wheel_recompute_port(&mut self, i: usize, at: Cycle) {
        let (init, tsu) = &self.ports[i];
        self.wheel.init_next[i] = match init.next_event(at) {
            Some(t) => t.max(at),
            None => Cycle::MAX,
        };
        self.wheel.tsu_next[i] = match tsu.next_release_at(at) {
            Some(t) => t.max(at),
            None => Cycle::MAX,
        };
    }

    /// Replay port `i`'s lazy window `[clean, to)` — no-op cycles by the
    /// `next_event` contracts; only running counters (DMA busy cycles,
    /// TRU stall cycles, ...) accrue, through the same `fast_forward`
    /// hooks the event-driven core uses.
    fn wheel_sync_port(&mut self, i: usize, to: Cycle) {
        let from = self.wheel.clean[i];
        if from < to {
            let (init, tsu) = &mut self.ports[i];
            init.fast_forward(from, to);
            tsu.fast_forward(from, to);
            self.wheel.clean[i] = to;
        }
    }

    /// One processed wheel cycle: phase 1 touches only ports whose
    /// wheel slot fired (everything else is provably a no-op and gets
    /// replayed lazily), phase 2 runs the crossbar's wheel cycle, phase
    /// 3 delivers completions — the same three phases as [`SocSim::step`]
    /// in the same order.
    fn step_wheel(&mut self) {
        let now = self.now;
        for i in 0..self.ports.len() {
            if self.wheel.init_next[i] > now && self.wheel.tsu_next[i] > now {
                continue; // dormant this cycle
            }
            self.wheel_sync_port(i, now);
            let (init, tsu) = &mut self.ports[i];
            init.tick(now, tsu);
            if tsu.queued() > 0 {
                release_into_fabric(tsu, &mut self.staged, &mut self.xbar, &mut self.trace, now);
            }
            self.wheel.clean[i] = now + 1;
            self.wheel_recompute_port(i, now + 1);
        }
        self.xbar.wheel_cycle(now);
        self.deliver_completions(now, true);
        self.now = now + 1;
    }

    /// The earliest cycle `>= self.now` at which any wheel slot fires —
    /// ports, targets, or the crossbar's grant-scan/hold schedule.
    fn wheel_next_due(&self) -> Cycle {
        let mut due = self.xbar.wheel_next(self.now);
        for (&a, &b) in self.wheel.init_next.iter().zip(&self.wheel.tsu_next) {
            due = due.min(a).min(b);
        }
        due
    }

    /// Flush every lazy replay window (ports, TSUs, targets) so stats
    /// and counters read exactly as after a naive run.
    fn wheel_flush(&mut self) {
        let now = self.now;
        for i in 0..self.ports.len() {
            self.wheel_sync_port(i, now);
        }
        self.xbar.wheel_flush(now);
    }

    /// Validate-skips analog for the wheel: step the proposed jump
    /// window through the wheel one cycle at a time and assert nothing
    /// effectful happened — no grants, no deliveries, no queue-length
    /// change. Unlike the event-driven validator, parked bursts may
    /// legitimately sit queued across the window (a W-channel hold, a
    /// grant scan waiting out a busy target); they must merely be
    /// *frozen*.
    fn wheel_validate_inert(&mut self, target: Cycle) {
        while self.now < target {
            let granted: u64 = self.xbar.granted_beats.iter().sum();
            let delivered = self.completions_delivered;
            let queued = self.xbar.queued_bursts();
            let at = self.now;
            self.step_wheel();
            assert_eq!(
                queued,
                self.xbar.queued_bursts(),
                "wheel window not inert: queue changed at cycle {at}"
            );
            let granted_after: u64 = self.xbar.granted_beats.iter().sum();
            assert_eq!(
                granted, granted_after,
                "wheel window not inert: grant at cycle {at}"
            );
            assert_eq!(
                delivered, self.completions_delivered,
                "wheel window not inert: completion at cycle {at}"
            );
        }
    }

    /// The wheel-core run loop: processed cycles touch only components
    /// whose wheel slot fired; the windows in between are jumped in
    /// O(ports + targets) and replayed lazily. Bit-identical to
    /// [`SocSim::run_until`] on either stepping path (enforced by
    /// `tests/wheel_equivalence.rs`); like there, the jump is suppressed
    /// the moment `done` holds so the observed cycle count matches
    /// naive stepping exactly. With [`SocSim::validate_skips`] set,
    /// jumped windows are stepped through the wheel cycle-by-cycle and
    /// asserted inert instead.
    pub fn run_until_wheel(
        &mut self,
        deadline: Cycle,
        mut done: impl FnMut(&SocSim) -> bool,
    ) -> bool {
        self.wheel_init();
        let mut held = false;
        while self.now < deadline {
            if done(self) {
                held = true;
                break;
            }
            self.step_wheel();
            if self.now < deadline && !done(self) {
                let target = self.wheel_next_due().min(deadline);
                if target > self.now {
                    if self.validate_skips {
                        self.wheel_validate_inert(target);
                    } else {
                        self.xbar.wheel_skip(self.now, target);
                        self.skipped_cycles += target - self.now;
                        self.now = target;
                    }
                }
            }
        }
        self.wheel_flush();
        held
    }

    /// Advance a fixed number of simulated cycles on the wheel core
    /// (the bench's counterpart to [`SocSim::run_cycles_fast`]).
    pub fn run_cycles_wheel(&mut self, cycles: Cycle) {
        let deadline = self.now + cycles;
        self.run_until_wheel(deadline, |_| false);
    }

    /// Number of attached initiator ports.
    pub fn n_initiators(&self) -> usize {
        self.ports.len()
    }

    /// Whether a specific initiator finished.
    pub fn finished(&self, id: InitiatorId) -> bool {
        self.ports[id.0 as usize].0.finished()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use dma::{DmaEngine, DmaJob};
    use hostd::{HostCore, TctSpec};

    #[test]
    fn host_tct_runs_standalone() {
        let mut soc = SocSim::new(1, SocSim::carfield_targets());
        let spec = TctSpec {
            accesses: 64,
            iterations: 4,
            ..TctSpec::fig6a()
        };
        soc.attach(
            Box::new(HostCore::new(InitiatorId(0), spec)),
            TsuConfig::passthrough(),
        );
        assert!(soc.run_until_done(10_000_000));
        let host: &mut HostCore = soc.initiator_mut(InitiatorId(0));
        assert_eq!(host.iteration_latency.len(), 4);
    }

    #[test]
    fn dma_interferes_with_host() {
        // Isolated run.
        let isolated = {
            let mut soc = SocSim::new(1, SocSim::carfield_targets());
            soc.attach(
                Box::new(HostCore::new(InitiatorId(0), TctSpec::fig6a())),
                TsuConfig::passthrough(),
            );
            assert!(soc.run_until_done(50_000_000));
            let host: &mut HostCore = soc.initiator_mut(InitiatorId(0));
            host.iteration_latency.mean()
        };
        // Interfered run: system DMA streams HyperRAM -> DCSPM.
        let interfered = {
            let mut soc = SocSim::new(2, SocSim::carfield_targets());
            soc.attach(
                Box::new(HostCore::new(InitiatorId(0), TctSpec::fig6a())),
                TsuConfig::passthrough(),
            );
            let mut dma = DmaEngine::new(InitiatorId(1));
            dma.program(DmaJob {
                src: axi::Target::Hyperram,
                src_addr: 0x10_0000,
                dst: Some(axi::Target::Dcspm),
                dst_addr: 0,
                bytes: 1 << 20,
                chunk_beats: 256,
                outstanding: 4,
                looping: true,
                part_id: 0,
            });
            soc.attach(Box::new(dma), TsuConfig::passthrough());
            let deadline = 100_000_000;
            let mut cycles = 0;
            while !soc.finished(InitiatorId(0)) && cycles < deadline {
                soc.step();
                cycles += 1;
            }
            assert!(soc.finished(InitiatorId(0)), "TCT starved forever");
            let host: &mut HostCore = soc.initiator_mut(InitiatorId(0));
            host.iteration_latency.mean()
        };
        assert!(
            interfered > 5.0 * isolated,
            "expected heavy interference: isolated={isolated:.0} interfered={interfered:.0}"
        );
    }

    /// The fig6a-shaped topology on all three stepping regimes: the fast
    /// path must actually skip cycles yet land bit-identical to naive
    /// stepping, and the validate mode must accept every skip window.
    #[test]
    fn fast_path_skips_and_matches_naive() {
        let build = || {
            let mut soc = SocSim::new(2, SocSim::carfield_targets());
            soc.attach(
                Box::new(HostCore::new(
                    InitiatorId(0),
                    TctSpec {
                        accesses: 64,
                        iterations: 2,
                        ..TctSpec::fig6a()
                    },
                )),
                TsuConfig::passthrough(),
            );
            let mut dma = DmaEngine::new(InitiatorId(1));
            dma.program(DmaJob {
                src: axi::Target::Hyperram,
                src_addr: 0x10_0000,
                dst: Some(axi::Target::Dcspm),
                dst_addr: 0,
                bytes: 1 << 16,
                chunk_beats: 64,
                outstanding: 2,
                looping: false,
                part_id: 0,
            });
            soc.attach(Box::new(dma), TsuConfig::regulated(8, 16, 512));
            soc
        };
        let mut naive = build();
        naive.event_driven = false;
        assert!(naive.run_until_done(50_000_000));

        let mut fast = build();
        assert!(fast.run_until_done(50_000_000));
        assert!(fast.skipped_cycles > 0, "fast path never skipped");
        assert_eq!(fast.now, naive.now, "drain cycle diverged");
        assert_eq!(
            fast.tsu_stats(InitiatorId(1)).tru_stall_cycles,
            naive.tsu_stats(InitiatorId(1)).tru_stall_cycles,
            "TRU stall accounting diverged"
        );
        let (f_mean, f_misses) = {
            let h: &mut HostCore = fast.initiator_mut(InitiatorId(0));
            (h.iteration_latency.mean(), h.l1_misses)
        };
        let h: &mut HostCore = naive.initiator_mut(InitiatorId(0));
        assert_eq!(f_mean, h.iteration_latency.mean());
        assert_eq!(f_misses, h.l1_misses);

        // Validate mode: every proposed skip window is stepped naively
        // and asserted quiescent.
        let mut checked = build();
        checked.validate_skips = true;
        assert!(checked.run_until_done(50_000_000));
        assert_eq!(checked.now, naive.now);

        // Wheel core: must skip at least as much as the event-driven
        // path (it also jumps busy-but-inert windows) and still land
        // bit-identical to naive stepping.
        let mut wheel = build();
        assert!(wheel.run_until_wheel(50_000_000, |soc| soc.drained()));
        assert!(
            wheel.skipped_cycles >= fast.skipped_cycles,
            "wheel skipped {} < event-driven {}",
            wheel.skipped_cycles,
            fast.skipped_cycles
        );
        assert_eq!(wheel.now, naive.now, "wheel drain cycle diverged");
        assert_eq!(
            wheel.tsu_stats(InitiatorId(1)).tru_stall_cycles,
            naive.tsu_stats(InitiatorId(1)).tru_stall_cycles,
            "wheel TRU stall accounting diverged"
        );
        assert_eq!(wheel.completions_delivered, naive.completions_delivered);
        let (w_mean, w_misses) = {
            let h: &mut HostCore = wheel.initiator_mut(InitiatorId(0));
            (h.iteration_latency.mean(), h.l1_misses)
        };
        assert_eq!(w_mean, f_mean);
        assert_eq!(w_misses, f_misses);

        // Wheel validate mode: every proposed wheel jump window is
        // stepped through the wheel and asserted inert.
        let mut wchecked = build();
        wchecked.validate_skips = true;
        assert!(wchecked.run_until_wheel(50_000_000, |soc| soc.drained()));
        assert_eq!(wchecked.now, naive.now);
        assert_eq!(
            wchecked.tsu_stats(InitiatorId(1)).tru_stall_cycles,
            naive.tsu_stats(InitiatorId(1)).tru_stall_cycles
        );
    }

    #[test]
    fn tsu_regulation_restores_host_latency() {
        let run = |dma_cfg: TsuConfig| {
            let mut soc = SocSim::new(2, SocSim::carfield_targets());
            soc.attach(
                Box::new(HostCore::new(InitiatorId(0), TctSpec::fig6a())),
                TsuConfig::passthrough(),
            );
            let mut dma = DmaEngine::new(InitiatorId(1));
            dma.program(DmaJob {
                src: axi::Target::Hyperram,
                src_addr: 0x10_0000,
                dst: Some(axi::Target::Dcspm),
                dst_addr: 0,
                bytes: 1 << 20,
                chunk_beats: 256,
                outstanding: 4,
                looping: true,
                part_id: 0,
            });
            soc.attach(Box::new(dma), dma_cfg);
            let mut cycles: u64 = 0;
            while !soc.finished(InitiatorId(0)) && cycles < 200_000_000 {
                soc.step();
                cycles += 1;
            }
            let host: &mut HostCore = soc.initiator_mut(InitiatorId(0));
            host.iteration_latency.mean()
        };
        let unregulated = run(TsuConfig::passthrough());
        let regulated = run(TsuConfig::regulated(8, 16, 512));
        assert!(
            regulated * 3.0 < unregulated,
            "TSU should cut latency: unreg={unregulated:.0} reg={regulated:.0}"
        );
    }
}

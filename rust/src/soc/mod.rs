//! The SoC substrate: every hardware block the paper's evaluation
//! depends on, modelled cycle-approximately.
//!
//! `SocSim` is the top-level harness: it owns the AXI crossbar with its
//! target models, one TSU per initiator, and the initiator state machines
//! (host cores, DMA engines, accelerator clusters). One call to `step()`
//! advances the whole SoC by a single system-clock cycle:
//!
//! 1. initiators generate traffic into their TSUs,
//! 2. TSUs release shaped fragments into the crossbar queues,
//! 3. the crossbar grants bursts to targets and advances them,
//! 4. completions route back to their initiators.

pub mod amr;
pub mod axi;
pub mod clock;
pub mod dma;
pub mod hostd;
pub mod mem;
pub mod power;
pub mod safed;
pub mod secd;
pub mod tiles;
pub mod tsu;
pub mod vector;

use std::any::Any;

use axi::{xbar::Crossbar, Burst, Completion, InitiatorId, TargetModel};
use clock::Cycle;
use tsu::{Tsu, TsuConfig};

/// Anything that drives traffic onto the AXI fabric.
pub trait BusInitiator: Any {
    fn id(&self) -> InitiatorId;
    /// Generate work for this cycle (submit bursts into `tsu`).
    fn tick(&mut self, now: Cycle, tsu: &mut Tsu);
    /// Receive a completion (may immediately submit follow-up bursts).
    fn complete(&mut self, c: Completion, now: Cycle, tsu: &mut Tsu);
    /// True when this initiator has no more work (drain condition).
    fn finished(&self) -> bool;
    /// Downcast hook for result extraction by experiments.
    fn as_any(&mut self) -> &mut dyn Any;
}

impl BusInitiator for hostd::HostCore {
    fn id(&self) -> InitiatorId {
        self.id
    }
    fn tick(&mut self, now: Cycle, tsu: &mut Tsu) {
        hostd::HostCore::tick(self, now, tsu)
    }
    fn complete(&mut self, c: Completion, now: Cycle, _tsu: &mut Tsu) {
        hostd::HostCore::complete(self, c, now)
    }
    fn finished(&self) -> bool {
        self.done()
    }
    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

impl BusInitiator for dma::DmaEngine {
    fn id(&self) -> InitiatorId {
        self.id
    }
    fn tick(&mut self, now: Cycle, tsu: &mut Tsu) {
        dma::DmaEngine::tick(self, now, tsu)
    }
    fn complete(&mut self, c: Completion, now: Cycle, tsu: &mut Tsu) {
        dma::DmaEngine::complete(self, c, now, tsu)
    }
    fn finished(&self) -> bool {
        self.done()
    }
    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// The assembled SoC.
pub struct SocSim {
    pub xbar: Crossbar,
    ports: Vec<(Box<dyn BusInitiator>, Tsu)>,
    staged: Vec<Burst>,
    /// Reused completion scratch (avoids per-cycle reallocation).
    comp_scratch: Vec<Completion>,
    pub now: Cycle,
}

impl SocSim {
    /// Standard Carfield target set: DCSPM + DPLLC/HyperRAM + peripherals.
    pub fn carfield_targets() -> Vec<Box<dyn TargetModel>> {
        vec![
            Box::new(mem::Dcspm::new()),
            Box::new(mem::HyperramPath::carfield()),
            Box::new(mem::Peripheral::new(20)),
        ]
    }

    /// Build with `n_initiators` port slots and the given targets.
    pub fn new(n_initiators: usize, targets: Vec<Box<dyn TargetModel>>) -> Self {
        Self {
            xbar: Crossbar::new(n_initiators, targets),
            ports: Vec::new(),
            staged: Vec::new(),
            comp_scratch: Vec::new(),
            now: 0,
        }
    }

    /// Attach an initiator with its TSU configuration. The initiator's
    /// `InitiatorId` must match its port index.
    pub fn attach(&mut self, init: Box<dyn BusInitiator>, cfg: TsuConfig) {
        assert_eq!(
            init.id().0 as usize,
            self.ports.len(),
            "attach order must follow InitiatorId"
        );
        self.ports.push((init, Tsu::new(cfg)));
    }

    /// Reprogram one initiator's TSU at runtime (the coordinator's knob).
    pub fn reconfigure_tsu(&mut self, id: InitiatorId, cfg: TsuConfig) {
        self.ports[id.0 as usize].1.reconfigure(cfg);
    }

    /// Borrow an attached initiator back as concrete type `T`.
    pub fn initiator_mut<T: 'static>(&mut self, id: InitiatorId) -> &mut T {
        self.ports[id.0 as usize]
            .0
            .as_any()
            .downcast_mut::<T>()
            .expect("initiator type mismatch")
    }

    pub fn tsu_stats(&self, id: InitiatorId) -> tsu::TsuStats {
        self.ports[id.0 as usize].1.stats
    }

    /// Advance one system cycle.
    pub fn step(&mut self) {
        let now = self.now;
        for (init, tsu) in self.ports.iter_mut() {
            init.tick(now, tsu);
            if tsu.queued() == 0 {
                continue; // nothing shaped this cycle
            }
            self.staged.clear();
            tsu.release(now, &mut self.staged);
            for b in self.staged.drain(..) {
                self.xbar.push(b);
            }
        }
        self.xbar.tick(now);
        if !self.xbar.completions.is_empty() {
            // Swap into the reusable scratch so the crossbar keeps an
            // allocated-but-empty buffer (hot-loop optimization, see
            // EXPERIMENTS.md §Perf).
            std::mem::swap(&mut self.comp_scratch, &mut self.xbar.completions);
            for i in 0..self.comp_scratch.len() {
                let c = self.comp_scratch[i];
                let (init, tsu) = &mut self.ports[c.initiator.0 as usize];
                init.complete(c, now, tsu);
                // A completion may have queued follow-up bursts eligible
                // this cycle; release immediately so back-to-back chains
                // don't pay a phantom cycle.
                self.staged.clear();
                tsu.release(now, &mut self.staged);
                for b in self.staged.drain(..) {
                    self.xbar.push(b);
                }
            }
            self.comp_scratch.clear();
        }
        self.now += 1;
    }

    /// Step until every initiator reports finished (or budget exhausted).
    /// Returns true if drained.
    pub fn run_until_done(&mut self, max_cycles: Cycle) -> bool {
        let deadline = self.now + max_cycles;
        while self.now < deadline {
            if self.ports.iter().all(|(i, _)| i.finished()) && self.xbar.idle() {
                return true;
            }
            self.step();
        }
        false
    }

    /// Step a fixed number of cycles.
    pub fn run_cycles(&mut self, cycles: Cycle) {
        for _ in 0..cycles {
            self.step();
        }
    }

    /// Whether a specific initiator finished.
    pub fn finished(&self, id: InitiatorId) -> bool {
        self.ports[id.0 as usize].0.finished()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use dma::{DmaEngine, DmaJob};
    use hostd::{HostCore, TctSpec};

    #[test]
    fn host_tct_runs_standalone() {
        let mut soc = SocSim::new(1, SocSim::carfield_targets());
        let spec = TctSpec {
            accesses: 64,
            iterations: 4,
            ..TctSpec::fig6a()
        };
        soc.attach(
            Box::new(HostCore::new(InitiatorId(0), spec)),
            TsuConfig::passthrough(),
        );
        assert!(soc.run_until_done(10_000_000));
        let host: &mut HostCore = soc.initiator_mut(InitiatorId(0));
        assert_eq!(host.iteration_latency.len(), 4);
    }

    #[test]
    fn dma_interferes_with_host() {
        // Isolated run.
        let isolated = {
            let mut soc = SocSim::new(1, SocSim::carfield_targets());
            soc.attach(
                Box::new(HostCore::new(InitiatorId(0), TctSpec::fig6a())),
                TsuConfig::passthrough(),
            );
            assert!(soc.run_until_done(50_000_000));
            let host: &mut HostCore = soc.initiator_mut(InitiatorId(0));
            host.iteration_latency.mean()
        };
        // Interfered run: system DMA streams HyperRAM -> DCSPM.
        let interfered = {
            let mut soc = SocSim::new(2, SocSim::carfield_targets());
            soc.attach(
                Box::new(HostCore::new(InitiatorId(0), TctSpec::fig6a())),
                TsuConfig::passthrough(),
            );
            let mut dma = DmaEngine::new(InitiatorId(1));
            dma.program(DmaJob {
                src: axi::Target::Hyperram,
                src_addr: 0x10_0000,
                dst: Some(axi::Target::Dcspm),
                dst_addr: 0,
                bytes: 1 << 20,
                chunk_beats: 256,
                outstanding: 4,
                looping: true,
                part_id: 0,
            });
            soc.attach(Box::new(dma), TsuConfig::passthrough());
            let deadline = 100_000_000;
            let mut cycles = 0;
            while !soc.finished(InitiatorId(0)) && cycles < deadline {
                soc.step();
                cycles += 1;
            }
            assert!(soc.finished(InitiatorId(0)), "TCT starved forever");
            let host: &mut HostCore = soc.initiator_mut(InitiatorId(0));
            host.iteration_latency.mean()
        };
        assert!(
            interfered > 5.0 * isolated,
            "expected heavy interference: isolated={isolated:.0} interfered={interfered:.0}"
        );
    }

    #[test]
    fn tsu_regulation_restores_host_latency() {
        let run = |dma_cfg: TsuConfig| {
            let mut soc = SocSim::new(2, SocSim::carfield_targets());
            soc.attach(
                Box::new(HostCore::new(InitiatorId(0), TctSpec::fig6a())),
                TsuConfig::passthrough(),
            );
            let mut dma = DmaEngine::new(InitiatorId(1));
            dma.program(DmaJob {
                src: axi::Target::Hyperram,
                src_addr: 0x10_0000,
                dst: Some(axi::Target::Dcspm),
                dst_addr: 0,
                bytes: 1 << 20,
                chunk_beats: 256,
                outstanding: 4,
                looping: true,
                part_id: 0,
            });
            soc.attach(Box::new(dma), dma_cfg);
            let mut cycles: u64 = 0;
            while !soc.finished(InitiatorId(0)) && cycles < 200_000_000 {
                soc.step();
                cycles += 1;
            }
            let host: &mut HostCore = soc.initiator_mut(InitiatorId(0));
            host.iteration_latency.mean()
        };
        let unregulated = run(TsuConfig::passthrough());
        let regulated = run(TsuConfig::regulated(8, 16, 512));
        assert!(
            regulated * 3.0 < unregulated,
            "TSU should cut latency: unreg={unregulated:.0} reg={regulated:.0}"
        );
    }
}

//! AXI4 interconnect model: 64-bit data bus, burst transactions,
//! per-target round-robin crossbar (paper §II "system interconnect is
//! based on a 64b AXI4 bus").
//!
//! Granularity: the simulator tracks *bursts* (AR/AW+W groups) and
//! *beats* (64b data transfers). Once a target grants a burst, the burst
//! occupies that target port until its last beat — exactly the property
//! that lets a long NCT burst delay a TCT, and that the TSU's granular
//! burst splitter (GBS) breaks up.

pub mod xbar;

use crate::soc::clock::Cycle;

/// Bytes per AXI data beat (64-bit bus).
pub const BEAT_BYTES: u64 = 8;

/// Max AXI4 INCR burst length in beats.
pub const MAX_BURST_BEATS: u32 = 256;

/// Identifies a bus initiator (master port).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct InitiatorId(pub u8);

/// Addressable targets behind the crossbar.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Target {
    /// On-chip 1MiB L2 scratchpad (DCSPM).
    Dcspm,
    /// External HyperRAM, reached through the DPLLC.
    Hyperram,
    /// Conventional peripherals (UART, SPI, ...) — constant latency.
    Peripheral,
}

/// One AXI burst (read or write).
#[derive(Debug, Clone)]
pub struct Burst {
    pub initiator: InitiatorId,
    pub target: Target,
    pub addr: u64,
    pub beats: u32,
    pub write: bool,
    /// DPLLC partition id, carried on AXI user signals (paper Fig. 2c).
    pub part_id: u8,
    /// Cycle the *original* transaction was issued by the initiator
    /// (preserved across GBS fragmentation for latency accounting).
    pub issued_at: Cycle,
    /// Cycle this fragment left its TSU for the crossbar admission
    /// queue (stamped by `SocSim::step`; system cycles). With
    /// `issued_at` and `granted_at` it decomposes a completion's
    /// latency into shaping / queueing / service for the trace ledger.
    pub released_at: Cycle,
    /// Cycle the crossbar granted this fragment to its target lane
    /// (stamped by the grant loop; system cycles).
    pub granted_at: Cycle,
    /// Initiator-private tag; completions echo it.
    pub tag: u64,
    /// Non-zero when this burst is a GBS fragment: fragments of one
    /// parent share the tag and count down `fragments_left`.
    pub fragments_left: u32,
    /// True when a TSU write buffer holds this write's data: the W
    /// channel is released in one burst instead of dribbling at the
    /// initiator's pace. Unbuffered writes hold the shared W channel and
    /// stall the interconnect (the failure mode the paper's WB removes).
    pub wb_buffered: bool,
}

impl Burst {
    pub fn read(initiator: InitiatorId, target: Target, addr: u64, beats: u32) -> Self {
        Self {
            initiator,
            target,
            addr,
            beats,
            write: false,
            part_id: 0,
            issued_at: 0,
            released_at: 0,
            granted_at: 0,
            tag: 0,
            fragments_left: 0,
            wb_buffered: false,
        }
    }

    pub fn write(initiator: InitiatorId, target: Target, addr: u64, beats: u32) -> Self {
        Self {
            write: true,
            ..Self::read(initiator, target, addr, beats)
        }
    }

    pub fn with_part(mut self, part_id: u8) -> Self {
        self.part_id = part_id;
        self
    }

    pub fn with_tag(mut self, tag: u64) -> Self {
        self.tag = tag;
        self
    }

    pub fn bytes(&self) -> u64 {
        self.beats as u64 * BEAT_BYTES
    }

    pub fn end_addr(&self) -> u64 {
        self.addr + self.bytes()
    }
}

/// Completion event delivered back to the initiator.
///
/// GBS fragmentation means one logical transaction can yield several
/// completions; fragments are served in order, so the one carrying
/// `last_fragment == true` ends the logical transaction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    pub initiator: InitiatorId,
    /// Target that serviced the burst (trace-ledger attribution).
    pub target: Target,
    pub tag: u64,
    pub write: bool,
    /// Beats carried by this (fragment) burst.
    pub beats: u32,
    /// True when this completes the last fragment of the logical burst.
    pub last_fragment: bool,
    /// Cycle of the last beat / B response.
    pub finished_at: Cycle,
    /// Cycle the original transaction was issued (for latency stats).
    pub issued_at: Cycle,
    /// TSU-release and crossbar-grant cycles, copied from the burst so
    /// the trace ledger can decompose latency without re-matching
    /// per-fragment event streams.
    pub released_at: Cycle,
    pub granted_at: Cycle,
}

impl Completion {
    /// Build the completion for `burst` finishing at `finished_at`.
    pub fn of(burst: &Burst, finished_at: Cycle) -> Self {
        Self {
            initiator: burst.initiator,
            target: burst.target,
            tag: burst.tag,
            write: burst.write,
            beats: burst.beats,
            last_fragment: burst.fragments_left == 0,
            finished_at,
            issued_at: burst.issued_at,
            released_at: burst.released_at,
            granted_at: burst.granted_at,
        }
    }

    pub fn latency(&self) -> Cycle {
        self.finished_at.saturating_sub(self.issued_at)
    }
}

/// A target-side service model plugged into the crossbar.
///
/// Contract: the crossbar calls `can_accept` / `start` for queued
/// bursts once per *system* cycle, then `tick` once per cycle of the
/// target's own clock domain ([`TargetModel::domain`]); completions are
/// appended to `done`. All `Cycle` arguments (`start`'s `now`, `tick`'s
/// `now`, `next_event`, `fast_forward`) are in the target's *local*
/// domain cycles — the crossbar converts at the boundary with an exact
/// [`RateConverter`], which is the identity for system-domain targets
/// and for a coupled uncore (the seed timebase).
///
/// [`RateConverter`]: crate::soc::clock::RateConverter
pub trait TargetModel {
    /// Which target address space this model serves.
    fn target(&self) -> Target;

    /// The clock domain this target's service timing is priced in.
    /// System-domain targets (the default) tick in lock-step with the
    /// master grid; uncore-domain targets tick on the uncore grid.
    fn domain(&self) -> crate::soc::clock::Domain {
        crate::soc::clock::Domain::System
    }

    /// Whether a service slot is available for this burst *this cycle*.
    fn can_accept(&self, burst: &Burst) -> bool;

    /// Begin servicing (must follow a true `can_accept`).
    fn start(&mut self, burst: Burst, now: Cycle);

    /// Advance one cycle; push finished bursts into `done`.
    fn tick(&mut self, now: Cycle, done: &mut Vec<Completion>);

    /// True if nothing is in flight (used by drain loops in tests).
    fn idle(&self) -> bool;

    /// Independent arbitration lanes (subordinate ports) this target
    /// exposes. The crossbar keeps one round-robin pointer per lane so
    /// contention on one port can never skew arbitration on another —
    /// the per-port fairness the WCET bound engine's `1 + competitors`
    /// interference term relies on.
    fn lanes(&self) -> usize {
        1
    }

    /// Which lane `burst` must be granted on (`< lanes()`).
    fn lane_of(&self, _burst: &Burst) -> usize {
        0
    }

    /// Event-driven hook: the earliest cycle `>= now` at which ticking
    /// this target has an *observable* effect (a completion, a service
    /// transition), assuming no new burst is granted in between; `None`
    /// when the target is drained and dormant.
    ///
    /// Contract: every tick in `now..event` must either be a no-op or
    /// have its per-cycle effects exactly reproduced by
    /// [`TargetModel::fast_forward`] over the same window. The default is
    /// maximally conservative (an event every cycle), which disables
    /// cycle skipping for targets that do not opt in.
    fn next_event(&self, now: Cycle) -> Option<Cycle> {
        Some(now)
    }

    /// Account for a skipped quiescent window `[from, to)`: replay any
    /// per-cycle bookkeeping (beats served, busy counters) a naive
    /// cycle-by-cycle run would have accumulated. Must leave the target
    /// in exactly the state a naive run would reach at `to`.
    fn fast_forward(&mut self, from: Cycle, to: Cycle) {
        let _ = (from, to);
    }

    /// Cycles (of this target's own domain) spent non-idle so far — the
    /// activity counter behind measured uncore utilization. Targets that
    /// do not track it report 0.
    fn busy_cycles(&self) -> u64 {
        0
    }

    /// Arm (or disarm, with `None`) this target's trace event sink.
    /// Targets without hook sites ignore it — the default drops the
    /// buffer, so un-instrumented targets stay trace-free rather than
    /// silently losing events.
    fn set_trace(&mut self, _buf: crate::trace::TraceBuf) {}

    /// Drain the recorded events (empty for un-instrumented targets).
    fn take_trace(&mut self) -> Vec<crate::trace::TraceEvent> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_constructors() {
        let b = Burst::read(InitiatorId(1), Target::Dcspm, 0x100, 8);
        assert!(!b.write);
        assert_eq!(b.bytes(), 64);
        assert_eq!(b.end_addr(), 0x140);
        let w = Burst::write(InitiatorId(2), Target::Hyperram, 0, 4).with_part(3).with_tag(9);
        assert!(w.write);
        assert_eq!(w.part_id, 3);
        assert_eq!(w.tag, 9);
    }

    #[test]
    fn completion_latency() {
        let mut b = Burst::read(InitiatorId(0), Target::Dcspm, 0, 4).with_tag(1);
        b.issued_at = 10;
        let c = Completion::of(&b, 110);
        assert_eq!(c.latency(), 100);
        assert!(c.last_fragment);
        assert_eq!(c.beats, 4);
    }

    #[test]
    fn fragment_completion_flags() {
        let mut b = Burst::read(InitiatorId(0), Target::Dcspm, 0, 4);
        b.fragments_left = 2;
        assert!(!Completion::of(&b, 5).last_fragment);
        b.fragments_left = 0;
        assert!(Completion::of(&b, 5).last_fragment);
    }
}

//! Round-robin AXI4 crossbar.
//!
//! Each initiator owns an input FIFO (filled through its TSU); each
//! target model exposes service slots. Per cycle the crossbar grants
//! head-of-line bursts to targets in round-robin order over initiators —
//! fair at *burst* granularity, which is precisely why unsplit long
//! bursts starve latency-critical initiators (Fig. 6 "unregulated").

use std::collections::VecDeque;

use super::{Burst, Completion, InitiatorId, Target, TargetModel};
use crate::soc::clock::{ClockTree, Cycle, Domain, RateConverter};
use crate::trace::{TraceBuf, TraceEvent, TraceKind};

/// Per-initiator input queue.
#[derive(Debug, Default)]
struct InputQueue {
    fifo: VecDeque<Burst>,
}

/// The crossbar fabric: N initiator queues in front of M target models.
pub struct Crossbar {
    queues: Vec<InputQueue>,
    /// Total bursts across all input queues, maintained on push/pop so
    /// the per-cycle idle check is O(1) instead of re-scanning every
    /// queue (hot-loop bookkeeping for the fast path below).
    queued: usize,
    /// Round-robin pointer per target *lane* (subordinate port). A
    /// single shared pointer would let grants on one port re-park the
    /// pointer and starve a contender on the other (multi-ported DCSPM);
    /// per-lane pointers give the per-port fairness real AXI subordinate
    /// arbiters have — and that the WCET analysis assumes.
    rr: Vec<Vec<usize>>,
    targets: Vec<Box<dyn TargetModel>>,
    /// Per-target edge converter from the target's clock domain to the
    /// system master grid. Lockstep (the identity) until
    /// [`Crossbar::set_clocks`] installs a tree with a decoupled uncore;
    /// every boundary crossing — grant time, service ticks, completion
    /// timestamps, event skips — goes through this, so the 1:1 case is
    /// bit-identical to the single-timebase seed by construction.
    rates: Vec<RateConverter>,
    /// Completed bursts this cycle (drained by the SoC).
    pub completions: Vec<Completion>,
    /// Total bursts granted per initiator (bandwidth accounting).
    pub granted_beats: Vec<u64>,
    /// Queue-occupancy high-water mark per initiator.
    pub hwm: Vec<usize>,
    /// W-channel head-of-line blocking: while an *unbuffered* write
    /// dribbles its data, the shared W mux is held and no new bursts are
    /// granted anywhere (paper §II: the TSU write buffer "prevents an
    /// initiator from holding the W channel, avoiding interconnect
    /// stalls"). The hold expires on the system edge at which the write
    /// data has cleared the *target's* clock grid — `beats` edges of the
    /// PHY clock for uncore targets — which collapses to `now + beats`
    /// for lock-step targets (the seed timebase).
    w_hold_until: Cycle,
    /// Cycles lost to W-channel holds (observability).
    pub w_stall_cycles: u64,
    /// Wheel core state (structure-of-arrays next-event times): the
    /// system-grid cycle at which each target next does effectful work
    /// (`Cycle::MAX` = dormant), and a per-target replay watermark —
    /// every system cycle `< target_clean[t]` is fully accounted on
    /// target `t`; the window up to `now` is replayed lazily through
    /// `fast_forward` before the target next acts. Only the `wheel_*`
    /// entry points read these; `tick`/`next_event`/`fast_forward` (the
    /// naive and event-driven cores) ignore them entirely.
    target_next: Vec<Cycle>,
    target_clean: Vec<Cycle>,
    /// Next cycle a wheel grant scan could possibly succeed. After a
    /// failed scan nothing can change its outcome before a push, a
    /// grant, or a target's next effectful tick (service frees slots in
    /// the *service* phase, visible to the next cycle's grant phase —
    /// hence the `+ 1` when the scan parks on `min(target_next)`).
    scan_at: Cycle,
    /// A burst was pushed since the last completed scan (re-arms the
    /// scan immediately: a new head may be grantable right away).
    scan_pushed: bool,
    /// Trace sink for grant / W-hold events. `None` (default) disables
    /// tracing at the cost of one branch in the grant loop; grants only
    /// happen while `queued > 0`, a state `next_event` pins to stepped
    /// cycles, so event streams are identical under naive and
    /// event-driven stepping.
    trace: TraceBuf,
}

impl Crossbar {
    pub fn new(n_initiators: usize, targets: Vec<Box<dyn TargetModel>>) -> Self {
        let rr = targets.iter().map(|t| vec![0; t.lanes().max(1)]).collect();
        let rates = vec![RateConverter::lockstep(); targets.len()];
        let n_targets = targets.len();
        Self {
            queues: (0..n_initiators).map(|_| InputQueue::default()).collect(),
            queued: 0,
            rr,
            rates,
            targets,
            completions: Vec::new(),
            granted_beats: vec![0; n_initiators],
            hwm: vec![0; n_initiators],
            w_hold_until: 0,
            w_stall_cycles: 0,
            target_next: vec![0; n_targets],
            target_clean: vec![0; n_targets],
            scan_at: 0,
            scan_pushed: false,
            trace: None,
        }
    }

    /// Arm or disarm tracing on the fabric and every target model.
    pub fn set_trace(&mut self, on: bool) {
        self.trace = if on { crate::trace::armed() } else { None };
        for t in &mut self.targets {
            t.set_trace(if on { crate::trace::armed() } else { None });
        }
    }

    /// Drain recorded events: fabric grants/W-holds first, then each
    /// target's buffer in target order (a fixed order — the capture's
    /// stable sort keeps equal-timestamp events deterministic).
    pub fn take_trace(&mut self) -> Vec<TraceEvent> {
        let mut out = match self.trace.as_deref_mut() {
            Some(v) => std::mem::take(v),
            None => Vec::new(),
        };
        for t in &mut self.targets {
            out.extend(t.take_trace());
        }
        out
    }

    /// Enqueue a shaped burst from an initiator's TSU.
    pub fn push(&mut self, burst: Burst) {
        self.queues[burst.initiator.0 as usize].fifo.push_back(burst);
        self.queued += 1;
        self.scan_pushed = true;
    }

    /// Bursts waiting across all input queues (O(1)).
    pub fn queued_bursts(&self) -> usize {
        self.queued
    }

    /// Number of bursts waiting for an initiator (TSU backpressure).
    pub fn backlog(&self, id: InitiatorId) -> usize {
        self.queues[id.0 as usize].fifo.len()
    }

    /// Access a target model (for configuration / inspection).
    pub fn target_mut(&mut self, t: Target) -> &mut dyn TargetModel {
        self.targets
            .iter_mut()
            .find(|m| m.target() == t)
            .map(|m| m.as_mut())
            .expect("unknown target")
    }

    pub fn target_ref(&self, t: Target) -> &dyn TargetModel {
        self.targets
            .iter()
            .find(|m| m.target() == t)
            .map(|m| m.as_ref())
            .expect("unknown target")
    }

    /// Program the per-target rate converters from a clock tree: each
    /// target's grid follows its [`TargetModel::domain`]. Without this
    /// call every target runs in lock-step with the system grid (the
    /// seed's single timebase); a coupled tree installs the identity
    /// converters, so behaviour is bit-identical either way.
    pub fn set_clocks(&mut self, tree: &ClockTree) {
        for (t_idx, target) in self.targets.iter().enumerate() {
            self.rates[t_idx] = tree.converter(target.domain());
        }
    }

    /// The installed converter for `t`'s domain (observability).
    pub fn rate_of(&self, t: Target) -> RateConverter {
        self.targets
            .iter()
            .position(|m| m.target() == t)
            .map(|i| self.rates[i])
            .expect("unknown target")
    }

    /// Advance target `t_idx` across system step `now`: one local tick
    /// per edge of the target's own clock grid within `[now, now + 1)`,
    /// with completion timestamps converted back to the system grid at
    /// the boundary (a faster uncore ticks several times per step, a
    /// slower one sometimes not at all; lock-step targets tick exactly
    /// once, bit-identical to the single-timebase seed).
    fn tick_target(&mut self, t_idx: usize, now: Cycle) {
        let rate = self.rates[t_idx];
        let target = &mut self.targets[t_idx];
        if rate.is_lockstep() {
            target.tick(now, &mut self.completions);
            return;
        }
        let (lo, hi) = (rate.local_of(now), rate.local_of(now + 1));
        if lo == hi {
            return; // no local edge falls inside this system step
        }
        let before = self.completions.len();
        for local in lo..hi {
            target.tick(local, &mut self.completions);
        }
        for c in &mut self.completions[before..] {
            c.finished_at = rate.to_system_edge(c.finished_at);
        }
    }

    /// One system cycle: grant + advance targets.
    pub fn tick(&mut self, now: Cycle) {
        let n_init = self.queues.len();
        // Fast path: nothing queued anywhere — skip the grant scan and
        // only advance the targets (hot-loop optimization; see
        // EXPERIMENTS.md §Perf). The queued-burst counter makes this an
        // O(1) check instead of an O(n_initiators) scan per cycle.
        if self.queued == 0 {
            for t_idx in 0..self.targets.len() {
                self.tick_target(t_idx, now);
            }
            return;
        }
        // Record high-water marks.
        for (i, q) in self.queues.iter().enumerate() {
            if q.fifo.len() > self.hwm[i] {
                self.hwm[i] = q.fifo.len();
            }
        }
        // Grant phase: an unbuffered write in flight holds the shared W
        // channel — no grants at all until its data has dribbled
        // through.
        if now < self.w_hold_until {
            self.w_stall_cycles += 1;
        } else {
            self.grant_scan(now);
        }
        // Service phase: each target advances on its own clock grid.
        for t_idx in 0..self.targets.len() {
            self.tick_target(t_idx, now);
        }
    }

    /// Grant phase: per target, rotate over initiators, admitting every
    /// head-of-line burst the target can still accept this cycle.
    /// Shared verbatim by all three stepping cores; returns whether any
    /// burst was granted (the wheel core re-arms its scan schedule on
    /// grants).
    fn grant_scan(&mut self, now: Cycle) -> bool {
        let n_init = self.queues.len();
        let mut granted_some = false;
        'targets: for (t_idx, target) in self.targets.iter_mut().enumerate() {
            let twhich = target.target();
            // Grants happen on the system grid; a burst enters the
            // target's service at the target-domain time of this step.
            let rate = self.rates[t_idx];
            let local_now = rate.local_of(now);
            for lane in 0..self.rr[t_idx].len() {
                let start = self.rr[t_idx][lane];
                let mut granted_any = false;
                for off in 0..n_init {
                    let i = (start + off) % n_init;
                    let Some(head) = self.queues[i].fifo.front() else {
                        continue;
                    };
                    if head.target != twhich
                        || target.lane_of(head) != lane
                        || !target.can_accept(head)
                    {
                        continue;
                    }
                    let mut burst = self.queues[i].fifo.pop_front().unwrap();
                    self.queued -= 1;
                    self.granted_beats[i] += burst.beats as u64;
                    granted_some = true;
                    burst.granted_at = now;
                    let holds_w = burst.write && !burst.wb_buffered;
                    let beats = burst.beats as Cycle;
                    if let Some(tb) = self.trace.as_deref_mut() {
                        tb.push(TraceEvent {
                            at: now,
                            domain: Domain::System,
                            initiator: burst.initiator,
                            target: Some(twhich),
                            lane: lane as u8,
                            tag: burst.tag,
                            kind: TraceKind::Grant {
                                beats: burst.beats,
                                write: burst.write,
                            },
                        });
                        if holds_w {
                            tb.push(TraceEvent {
                                at: now,
                                domain: Domain::System,
                                initiator: burst.initiator,
                                target: Some(twhich),
                                lane: lane as u8,
                                tag: burst.tag,
                                kind: TraceKind::WHold { beats: burst.beats },
                            });
                        }
                    }
                    target.start(burst, local_now);
                    if !granted_any {
                        // Advance this lane's RR past the first
                        // grantee for fairness.
                        self.rr[t_idx][lane] = (i + 1) % n_init;
                        granted_any = true;
                    }
                    if holds_w {
                        // W data dribbles at the *target's* beat rate:
                        // the hold clears on the first system edge at or
                        // after `beats` edges of the target's own clock
                        // grid. Identity — `now + beats` — for lock-step
                        // targets, so the single-timebase seed is
                        // bit-identical; for a slower PHY the hold
                        // honestly covers the longer dribble instead of
                        // under-pricing it on the system grid.
                        self.w_hold_until = rate.to_system_edge(local_now + beats);
                        break 'targets;
                    }
                }
            }
        }
        granted_some
    }

    /// Drain completions accumulated so far.
    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// True when all queues and targets are empty/idle.
    pub fn idle(&self) -> bool {
        self.queued == 0 && self.targets.iter().all(|t| t.idle())
    }

    /// Earliest pending event across the fabric: `Some(now)` while any
    /// burst is queued (the grant scan must run every cycle), otherwise
    /// the minimum of the targets' own next events. `None` when queues
    /// and targets are all dormant.
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        if self.queued > 0 {
            return Some(now);
        }
        let mut earliest: Option<Cycle> = None;
        for (t_idx, target) in self.targets.iter().enumerate() {
            let rate = self.rates[t_idx];
            let local_now = rate.local_of(now);
            if let Some(e) = target.next_event(local_now) {
                // Convert the local-domain event to the system step that
                // processes it (identity at lockstep), clamped to `now`.
                let t = if rate.is_lockstep() {
                    e
                } else {
                    rate.system_step_of(e.max(local_now))
                };
                let t = t.max(now);
                earliest = crate::soc::clock::merge_event(earliest, t);
                if t <= now {
                    break; // cannot get earlier than "this cycle"
                }
            }
        }
        earliest
    }

    /// Replay a skipped quiescent window on every target model (each in
    /// its own clock domain's cycles).
    pub fn fast_forward(&mut self, from: Cycle, to: Cycle) {
        for (t_idx, target) in self.targets.iter_mut().enumerate() {
            let rate = self.rates[t_idx];
            target.fast_forward(rate.local_of(from), rate.local_of(to));
        }
    }

    // --- Wheel core -----------------------------------------------------
    //
    // The entry points below implement the structure-of-arrays hot path:
    // per-cycle work touches only targets whose `target_next` slot fired
    // (everything in between is replayed lazily through `fast_forward`
    // windows, exactly like the event-driven core's skip windows), and
    // grant scans run only when their outcome could have changed — after
    // a push, a grant, a W-hold expiry, or a target's effectful tick.
    // With only uncore-domain targets active the scan schedule therefore
    // lands on uncore edges, batching the per-system-step wakeups the
    // event-driven core still pays.

    /// Arm the wheel state at `now` (start of a wheel run). Idempotent;
    /// the naive/event-driven cores may have run before this.
    pub(crate) fn wheel_init(&mut self, now: Cycle) {
        self.scan_at = now;
        self.scan_pushed = self.queued > 0;
        for t_idx in 0..self.targets.len() {
            self.target_clean[t_idx] = now;
            self.wheel_recompute_target(t_idx, now);
        }
    }

    /// Replay target `t_idx`'s lazy window `[target_clean, to)` (no-op
    /// cycles by the `next_event` contract — only running counters).
    fn wheel_sync_target(&mut self, t_idx: usize, to: Cycle) {
        let from = self.target_clean[t_idx];
        if from < to {
            let rate = self.rates[t_idx];
            self.targets[t_idx].fast_forward(rate.local_of(from), rate.local_of(to));
            self.target_clean[t_idx] = to;
        }
    }

    /// Refresh `target_next[t_idx]` with the system-grid cycle of the
    /// target's next effectful tick as seen from `at` (same conversion
    /// as [`Crossbar::next_event`]).
    fn wheel_recompute_target(&mut self, t_idx: usize, at: Cycle) {
        let rate = self.rates[t_idx];
        let local_at = rate.local_of(at);
        self.target_next[t_idx] = match self.targets[t_idx].next_event(local_at) {
            Some(e) => {
                let t = if rate.is_lockstep() {
                    e
                } else {
                    rate.system_step_of(e.max(local_at))
                };
                t.max(at)
            }
            None => Cycle::MAX,
        };
    }

    /// One processed wheel cycle: busy-cycle bookkeeping, a grant scan
    /// when one could succeed, and service ticks for due targets only.
    /// Bit-identical to [`Crossbar::tick`] at every processed cycle; the
    /// cycles the wheel never processes are provably inert here (their
    /// only effects — W-stall accounting and lazy target windows — are
    /// replayed by [`Crossbar::wheel_skip`] and `wheel_sync_target`).
    pub(crate) fn wheel_cycle(&mut self, now: Cycle) {
        let mut scanned = false;
        if self.queued > 0 {
            // High-water marks are maxima: queue lengths only change at
            // processed cycles (pushes and grants both happen here), so
            // recording at processed busy cycles is exact.
            for (i, q) in self.queues.iter().enumerate() {
                if q.fifo.len() > self.hwm[i] {
                    self.hwm[i] = q.fifo.len();
                }
            }
            if now < self.w_hold_until {
                self.w_stall_cycles += 1;
            } else if self.scan_pushed || now >= self.scan_at {
                self.scan_pushed = false;
                scanned = true;
                // `start`/`can_accept` must see fully replayed state.
                for t_idx in 0..self.targets.len() {
                    self.wheel_sync_target(t_idx, now);
                }
                if self.grant_scan(now) {
                    // Service may free slots for the remaining heads as
                    // early as the next cycle's grant phase (or at the
                    // hold expiry if this grant holds W).
                    self.scan_at = self.w_hold_until.max(now + 1);
                } else {
                    // Nothing grantable: frozen until a push (re-arms
                    // via `scan_pushed`) or a target's next effectful
                    // tick, whose service-phase effect is first visible
                    // to the *following* cycle's grant phase.
                    let soonest = self.target_next.iter().copied().min();
                    self.scan_at = match soonest {
                        Some(t) if t < Cycle::MAX => t.saturating_add(1),
                        _ => now + 1,
                    };
                }
            }
        }
        // Service phase: due targets only — plus every target on scan
        // cycles, where a fresh grant may have re-armed any of them (all
        // already synced to `now`; idle targets tick as no-ops exactly
        // like under naive stepping).
        for t_idx in 0..self.targets.len() {
            if scanned || self.target_next[t_idx] <= now {
                self.wheel_sync_target(t_idx, now);
                self.tick_target(t_idx, now);
                self.target_clean[t_idx] = now + 1;
                self.wheel_recompute_target(t_idx, now + 1);
            }
        }
    }

    /// Earliest cycle >= `now` the wheel must process the fabric:
    /// the soonest due target, and — while bursts are queued — the hold
    /// expiry or the armed scan.
    pub(crate) fn wheel_next(&self, now: Cycle) -> Cycle {
        let mut due = self.target_next.iter().copied().min().unwrap_or(Cycle::MAX);
        if self.queued > 0 {
            let scan = if now < self.w_hold_until {
                // The hold window itself is inert (stall cycles are
                // bulk-accounted by `wheel_skip`); the scan resumes at
                // its expiry.
                self.w_hold_until
            } else if self.scan_pushed {
                now
            } else {
                self.scan_at
            };
            due = due.min(scan);
        }
        due
    }

    /// Bulk-account a jumped window `[from, to)`: the only per-cycle
    /// fabric effect in an inert window is W-stall counting, and both
    /// `queued` and the hold deadline are frozen across it.
    pub(crate) fn wheel_skip(&mut self, from: Cycle, to: Cycle) {
        if self.queued > 0 && from < self.w_hold_until {
            self.w_stall_cycles += self.w_hold_until.min(to) - from;
        }
    }

    /// Flush every target's lazy replay window up to `now` (end of a
    /// wheel run, before counters are harvested).
    pub(crate) fn wheel_flush(&mut self, now: Cycle) {
        for t_idx in 0..self.targets.len() {
            self.wheel_sync_target(t_idx, now);
        }
    }

    /// WCET hook: with per-lane round-robin arbitration, an upper bound
    /// on how many bursts can be serviced before a newly queued burst on
    /// a lane with `competitors` other initiators and `queue_slots`
    /// admission slots behind the grant point: the burst in service, a
    /// full admission queue, and one RR turn per competitor.
    pub fn worst_bursts_ahead(competitors: usize, queue_slots: usize) -> u64 {
        if competitors == 0 {
            0
        } else {
            1 + queue_slots as u64 + competitors as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trivial single-slot target: `beats` cycles per burst, FIFO.
    struct StubTarget {
        which: Target,
        busy_until: Cycle,
        current: Option<Burst>,
        served: Vec<InitiatorId>,
    }

    impl StubTarget {
        fn new(which: Target) -> Self {
            Self {
                which,
                busy_until: 0,
                current: None,
                served: Vec::new(),
            }
        }
    }

    impl TargetModel for StubTarget {
        fn target(&self) -> Target {
            self.which
        }
        fn can_accept(&self, _b: &Burst) -> bool {
            self.current.is_none()
        }
        fn start(&mut self, b: Burst, now: Cycle) {
            self.busy_until = now + b.beats as Cycle;
            self.served.push(b.initiator);
            self.current = Some(b);
        }
        fn tick(&mut self, now: Cycle, done: &mut Vec<Completion>) {
            if let Some(b) = &self.current {
                if now + 1 >= self.busy_until {
                    done.push(Completion::of(b, now + 1));
                    self.current = None;
                }
            }
        }
        fn idle(&self) -> bool {
            self.current.is_none()
        }
    }

    fn xbar2() -> Crossbar {
        Crossbar::new(2, vec![Box::new(StubTarget::new(Target::Dcspm))])
    }

    #[test]
    fn single_burst_completes() {
        let mut x = xbar2();
        x.push(Burst::read(InitiatorId(0), Target::Dcspm, 0, 4).with_tag(7));
        let mut done = Vec::new();
        for c in 0..10 {
            x.tick(c);
            done.extend(x.take_completions());
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tag, 7);
        assert!(x.idle());
    }

    #[test]
    fn round_robin_alternates_initiators() {
        let mut x = xbar2();
        // Four bursts from each initiator, all same length.
        for i in 0..4 {
            x.push(Burst::read(InitiatorId(0), Target::Dcspm, i * 64, 4));
            x.push(Burst::read(InitiatorId(1), Target::Dcspm, i * 64, 4));
        }
        for c in 0..100 {
            x.tick(c);
        }
        // Fairness: both initiators moved the same number of beats.
        assert_eq!(x.granted_beats[0], x.granted_beats[1]);
        assert!(x.idle());
    }

    #[test]
    fn long_burst_delays_short_one() {
        let mut x = xbar2();
        // NCT long burst enters service, then a TCT single-beat read
        // arrives one cycle later and must wait out the whole burst.
        x.push(Burst::read(InitiatorId(1), Target::Dcspm, 0, 200).with_tag(1));
        x.tick(0);
        x.push(Burst::read(InitiatorId(0), Target::Dcspm, 0, 1).with_tag(2));
        let mut done = Vec::new();
        for c in 1..400 {
            x.tick(c);
            done.extend(x.take_completions());
        }
        done.extend(x.take_completions());
        assert_eq!(done.len(), 2);
        let tct = done.iter().find(|c| c.tag == 2).unwrap();
        // TCT had to wait out the entire 200-beat burst.
        assert!(tct.finished_at > 200, "finished_at={}", tct.finished_at);
    }

    /// Dual-port target (DCSPM-like): two independent single-slot lanes
    /// selected by address bit 20.
    struct TwoLaneStub {
        slots: [Option<(Burst, Cycle)>; 2],
    }

    impl TargetModel for TwoLaneStub {
        fn target(&self) -> Target {
            Target::Dcspm
        }
        fn lanes(&self) -> usize {
            2
        }
        fn lane_of(&self, b: &Burst) -> usize {
            ((b.addr >> 20) & 1) as usize
        }
        fn can_accept(&self, b: &Burst) -> bool {
            self.slots[self.lane_of(b)].is_none()
        }
        fn start(&mut self, b: Burst, now: Cycle) {
            let lane = self.lane_of(&b);
            let until = now + b.beats as Cycle;
            self.slots[lane] = Some((b, until));
        }
        fn tick(&mut self, now: Cycle, done: &mut Vec<Completion>) {
            for slot in self.slots.iter_mut() {
                if let Some((b, t)) = slot {
                    if now + 1 >= *t {
                        done.push(Completion::of(b, *t));
                        *slot = None;
                    }
                }
            }
        }
        fn idle(&self) -> bool {
            self.slots.iter().all(|s| s.is_none())
        }
    }

    #[test]
    fn lane_grants_do_not_skew_other_lane_arbitration() {
        // Regression for the shared-RR starvation pathology: initiator 2
        // streams long bursts on lane 0, initiator 1 hammers lane 1 with
        // short bursts (each grant used to re-park the shared pointer
        // right on initiator 2), and initiator 0 queues one short lane-0
        // burst. With per-lane pointers initiator 0 waits out at most
        // one long burst plus one RR turn.
        let mut x = Crossbar::new(3, vec![Box::new(TwoLaneStub { slots: [None, None] })]);
        let lane1 = 1u64 << 20;
        x.push(Burst::read(InitiatorId(2), Target::Dcspm, 0, 100).with_tag(90));
        x.tick(0);
        x.push(Burst::read(InitiatorId(0), Target::Dcspm, 0, 4).with_tag(7));
        let mut victim_done = 0;
        for c in 1..1000 {
            // Keep both aggressors' queues non-empty.
            if x.backlog(InitiatorId(2)) == 0 {
                x.push(Burst::read(InitiatorId(2), Target::Dcspm, 0, 100));
            }
            if x.backlog(InitiatorId(1)) == 0 {
                x.push(Burst::read(InitiatorId(1), Target::Dcspm, lane1, 2));
            }
            x.tick(c);
            for comp in x.take_completions() {
                if comp.tag == 7 {
                    victim_done = comp.finished_at;
                }
            }
            if victim_done > 0 {
                break;
            }
        }
        assert!(
            victim_done > 0 && victim_done <= 250,
            "victim starved on lane 0: finished_at={victim_done}"
        );
    }

    #[test]
    fn worst_bursts_ahead_formula() {
        assert_eq!(Crossbar::worst_bursts_ahead(0, 4), 0);
        assert_eq!(Crossbar::worst_bursts_ahead(1, 4), 6);
        assert_eq!(Crossbar::worst_bursts_ahead(2, 0), 3);
    }

    #[test]
    fn backlog_reports_queue_depth() {
        let mut x = xbar2();
        for _ in 0..3 {
            x.push(Burst::read(InitiatorId(0), Target::Dcspm, 0, 4));
        }
        assert_eq!(x.backlog(InitiatorId(0)), 3);
        assert_eq!(x.backlog(InitiatorId(1)), 0);
    }
}

//! Secure domain (SECD): the SoC's hardware root of trust — secure boot
//! sequencing and crypto services (AES, KMAC, HMAC/SHA; paper Fig. 1 and
//! Fig. 7 "Security Features" row).
//!
//! Modelled as a service-latency state machine: boot walks the
//! measured-boot stages with deterministic per-stage cost; runtime crypto
//! requests are served FIFO with throughput-derived latencies. This is a
//! *substrate* model — enough to (a) account for boot-time before the
//! coordinator starts scheduling and (b) give the comparison table's
//! feature row a measurable artifact.

use super::clock::Cycle;

/// Boot stages of the HWRoT.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BootStage {
    PowerOn,
    RomHash,
    VerifySignature,
    LoadFirmware,
    ReleaseCores,
    Done,
}

/// Crypto service kinds with silicon-calibrated throughputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CryptoOp {
    /// AES-256-GCM, ~1 B/cycle engine.
    Aes { bytes: u64 },
    /// SHA-2/HMAC, ~0.5 B/cycle.
    Hmac { bytes: u64 },
    /// KMAC (Keccak), ~0.75 B/cycle.
    Kmac { bytes: u64 },
}

impl CryptoOp {
    /// Deterministic service time (setup + streaming).
    pub fn cycles(&self) -> Cycle {
        match *self {
            CryptoOp::Aes { bytes } => 40 + bytes,
            CryptoOp::Hmac { bytes } => 60 + bytes * 2,
            CryptoOp::Kmac { bytes } => 50 + bytes * 4 / 3,
        }
    }
}

/// The secure-domain controller.
pub struct SecureDomain {
    pub stage: BootStage,
    stage_done_at: Cycle,
    /// FIFO of (op, enqueue cycle).
    queue: std::collections::VecDeque<(CryptoOp, Cycle)>,
    busy_until: Cycle,
    pub ops_served: u64,
    pub boot_finished_at: Option<Cycle>,
}

/// Firmware image size used for boot-time accounting (512KiB).
const FIRMWARE_BYTES: u64 = 512 * 1024;

impl SecureDomain {
    pub fn new() -> Self {
        Self {
            stage: BootStage::PowerOn,
            stage_done_at: 0,
            queue: Default::default(),
            busy_until: 0,
            ops_served: 0,
            boot_finished_at: None,
        }
    }

    fn stage_cost(stage: BootStage) -> Cycle {
        match stage {
            BootStage::PowerOn => 100,
            BootStage::RomHash => CryptoOp::Hmac { bytes: 64 * 1024 }.cycles(),
            BootStage::VerifySignature => 12_000, // ECDSA-P256 verify
            BootStage::LoadFirmware => FIRMWARE_BYTES / 8, // 64b/cyc copy
            BootStage::ReleaseCores => 16,
            BootStage::Done => 0,
        }
    }

    fn next_stage(stage: BootStage) -> BootStage {
        match stage {
            BootStage::PowerOn => BootStage::RomHash,
            BootStage::RomHash => BootStage::VerifySignature,
            BootStage::VerifySignature => BootStage::LoadFirmware,
            BootStage::LoadFirmware => BootStage::ReleaseCores,
            BootStage::ReleaseCores => BootStage::Done,
            BootStage::Done => BootStage::Done,
        }
    }

    /// True once the boot chain released the application cores.
    pub fn booted(&self) -> bool {
        self.stage == BootStage::Done
    }

    /// Enqueue a runtime crypto request; returns nothing (completion is
    /// observable through `ops_served` / `tick`'s return).
    pub fn request(&mut self, op: CryptoOp, now: Cycle) {
        self.queue.push_back((op, now));
    }

    /// Advance; returns completed (op, enqueue, finish) events.
    pub fn tick(&mut self, now: Cycle) -> Vec<(CryptoOp, Cycle, Cycle)> {
        // Boot FSM.
        if !self.booted() {
            if self.stage_done_at == 0 {
                self.stage_done_at = now + Self::stage_cost(self.stage);
            }
            if now >= self.stage_done_at {
                self.stage = Self::next_stage(self.stage);
                if self.booted() {
                    self.boot_finished_at = Some(now);
                    self.stage_done_at = 0;
                } else {
                    self.stage_done_at = now + Self::stage_cost(self.stage);
                }
            }
            return Vec::new();
        }
        // Crypto service FIFO.
        let mut out = Vec::new();
        if now >= self.busy_until {
            if let Some((op, enq)) = self.queue.pop_front() {
                let fin = now + op.cycles();
                self.busy_until = fin;
                self.ops_served += 1;
                out.push((op, enq, fin));
            }
        }
        out
    }

    /// Total boot latency in cycles (sum of stage costs) — deterministic.
    pub fn boot_cycles() -> Cycle {
        let mut total = 0;
        let mut s = BootStage::PowerOn;
        while s != BootStage::Done {
            total += Self::stage_cost(s);
            s = Self::next_stage(s);
        }
        total
    }
}

impl Default for SecureDomain {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boot_progresses_to_done() {
        let mut sd = SecureDomain::new();
        let budget = SecureDomain::boot_cycles() + 10;
        for now in 0..budget {
            sd.tick(now);
        }
        assert!(sd.booted());
        assert!(sd.boot_finished_at.is_some());
    }

    #[test]
    fn boot_time_is_deterministic() {
        let run = || {
            let mut sd = SecureDomain::new();
            let mut now = 0;
            while !sd.booted() {
                sd.tick(now);
                now += 1;
            }
            now
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn crypto_waits_for_boot() {
        let mut sd = SecureDomain::new();
        sd.request(CryptoOp::Aes { bytes: 64 }, 0);
        let done = sd.tick(0);
        assert!(done.is_empty());
        assert_eq!(sd.ops_served, 0);
    }

    #[test]
    fn crypto_fifo_after_boot() {
        let mut sd = SecureDomain::new();
        let mut now = 0;
        while !sd.booted() {
            sd.tick(now);
            now += 1;
        }
        sd.request(CryptoOp::Aes { bytes: 100 }, now);
        sd.request(CryptoOp::Kmac { bytes: 99 }, now);
        let mut served = Vec::new();
        for _ in 0..2000 {
            served.extend(sd.tick(now));
            now += 1;
        }
        assert_eq!(served.len(), 2);
        assert_eq!(sd.ops_served, 2);
        // FIFO order preserved.
        assert!(matches!(served[0].0, CryptoOp::Aes { .. }));
        assert!(matches!(served[1].0, CryptoOp::Kmac { .. }));
        assert!(served[1].2 > served[0].2);
    }

    #[test]
    fn op_latencies_scale_with_bytes() {
        assert!(CryptoOp::Aes { bytes: 1024 }.cycles() > CryptoOp::Aes { bytes: 64 }.cycles());
        assert_eq!(CryptoOp::Aes { bytes: 64 }.cycles(), 104);
        assert_eq!(CryptoOp::Hmac { bytes: 64 }.cycles(), 188);
    }
}

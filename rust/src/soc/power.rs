//! DVFS and power/energy model (Fig. 5 substrate).
//!
//! The paper sweeps supply voltage 0.6–1.1V at the max frequency per
//! point and reports performance + energy efficiency for both clusters.
//! We model each cluster with:
//!
//! - `f(V)`: linear interpolation through the two published corners
//!   (e.g. AMR: 300MHz @ 0.6V, 900MHz @ 1.1V) — matching both endpoints
//!   exactly, which is what Fig. 5's x-axis needs;
//! - `P(V, f) = k · V^alpha · f · util + idle`: an alpha-power-law fit
//!   through the published (power, efficiency) corners. Solving the two
//!   corners for (k, alpha) reproduces the paper's peak-efficiency points
//!   to <1% (see tests).
//!
//! Silicon substitution per DESIGN.md: we cannot measure a chip, so the
//! model *is* the instrument; the sweep's shape (perf ∝ f, efficiency
//! peaking at low V) follows from the same physics the chip obeys.

/// Nominal supply voltage (the paper's balanced 0.8V operating point).
pub const NOMINAL_V: f64 = 0.8;
/// Peak supply voltage (the 1.1V max-performance corner).
pub const MAX_V: f64 = 1.1;

/// Uncore (HyperBUS PHY + memory controller + DPLLC) dynamic power per
/// MHz of its clock at full activity — sized so the fixed 1GHz PHY
/// point burns 25mW active, a realistic figure for a 400MB/s 8b-DDR
/// HyperBUS PHY plus its controller/LLC pipeline. The uncore is not
/// voltage-scaled, so there is no `V^alpha` term: power follows its
/// clock (the system clock when coupled, the fixed PHY clock when
/// decoupled) linearly, like any fixed-voltage CMOS block.
pub const UNCORE_MW_PER_MHZ: f64 = 0.025;
/// Uncore retention/idle floor in mW (PHY bias + controller clock gate).
pub const UNCORE_IDLE_MW: f64 = 2.0;

/// Uncore power at `freq_mhz` with an activity factor in [0, 1]
/// (validated like the curve-based models: NaN/out-of-range utilization
/// is rejected loudly).
pub fn uncore_power_mw(freq_mhz: f64, util: f64) -> f64 {
    let util = DvfsCurve::validate_util(util)
        .unwrap_or_else(|e| panic!("invalid DVFS request: {e}"));
    UNCORE_MW_PER_MHZ * freq_mhz * util + UNCORE_IDLE_MW
}

/// Absolute slack accepted on range checks so voltages assembled by
/// float arithmetic (grid steps, interpolation) are not rejected for
/// representation error.
const RANGE_TOLERANCE: f64 = 1e-9;

/// A request outside a curve's validated envelope. NaN or out-of-range
/// inputs are rejected loudly at the API boundary instead of silently
/// clamped — a governor that asks for 1.4V must hear "no", not get
/// 1.1V behaviour back.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DvfsError {
    /// NaN or infinite supply voltage.
    VoltageNotFinite { curve: &'static str },
    /// Voltage outside the curve's published corner range.
    VoltageOutOfRange {
        curve: &'static str,
        v: f64,
        v_min: f64,
        v_max: f64,
    },
    /// NaN or infinite activity factor.
    UtilizationNotFinite,
    /// Activity factor outside [0, 1].
    UtilizationOutOfRange { util: f64 },
    /// Requested fixed uncore frequency is NaN, infinite or non-positive.
    UncoreFrequencyInvalid { mhz: f64 },
}

impl std::fmt::Display for DvfsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            DvfsError::VoltageNotFinite { curve } => {
                write!(f, "supply voltage for the {curve} curve is not finite")
            }
            DvfsError::VoltageOutOfRange {
                curve,
                v,
                v_min,
                v_max,
            } => write!(
                f,
                "supply voltage {v:.3}V is outside the {curve} curve's \
                 validated {v_min:.2}-{v_max:.2}V range"
            ),
            DvfsError::UtilizationNotFinite => {
                write!(f, "activity/utilization factor is not finite")
            }
            DvfsError::UtilizationOutOfRange { util } => write!(
                f,
                "activity/utilization factor {util:.3} is outside [0, 1]"
            ),
            DvfsError::UncoreFrequencyInvalid { mhz } => write!(
                f,
                "fixed uncore frequency {mhz}MHz is not a positive finite value"
            ),
        }
    }
}

impl std::error::Error for DvfsError {}

/// Voltage/frequency/power law for one cluster.
#[derive(Debug, Clone, Copy)]
pub struct DvfsCurve {
    pub name: &'static str,
    pub v_min: f64,
    pub v_max: f64,
    /// Frequency (MHz) at `v_min` / `v_max`.
    pub f_min_mhz: f64,
    pub f_max_mhz: f64,
    /// Power-law coefficient: P[mW] = k * V^alpha * f[MHz] * util + idle.
    pub k: f64,
    pub alpha: f64,
    /// Idle floor in mW (clock-gated core complex + SPM retention).
    pub idle_mw: f64,
}

impl DvfsCurve {
    /// AMR cluster: corners from the paper — 300MHz/0.6V to 900MHz/1.1V,
    /// 747mW peak power, 1.607 TOPS/W peak efficiency at 2b (Fig. 5a/b,
    /// Fig. 8).
    pub fn amr() -> Self {
        // Solve P(1.1, 900) = 747 and P(0.6, 300) = 63.3 (= 101.63 GOPS
        // at 2b / 1.607 TOPS/W): alpha = ln(747*300 / (63.3*900)) /
        // ln(1.1/0.6) ~= 2.26, k = 747 / (900 * 1.1^2.26) ~= 0.668.
        Self {
            name: "amr",
            v_min: 0.6,
            v_max: 1.1,
            f_min_mhz: 300.0,
            f_max_mhz: 900.0,
            k: 0.668,
            alpha: 2.26,
            idle_mw: 2.0,
        }
    }

    /// Vector cluster: 250MHz/0.6V to 1000MHz/1.1V, 600mW peak power
    /// (FP64 datapath at 1.1V), 1.069 TFLOPS/W peak FP8 efficiency.
    ///
    /// The base curve is the FP64 (widest-activity) datapath; per-format
    /// activity factors live in `FpFormat::power_factor`. (k, alpha)
    /// solve P(1.1, 1000) = 600mW and P_fp8(0.6, 250) = 28.5mW
    /// (= 30.45 GFLOPS / 1068.7 GFLOPS/W): alpha ~= 2.07, k ~= 0.491.
    pub fn vector() -> Self {
        Self {
            name: "vector",
            v_min: 0.6,
            v_max: 1.1,
            f_min_mhz: 250.0,
            f_max_mhz: 1000.0,
            k: 0.491,
            alpha: 2.068,
            idle_mw: 1.5,
        }
    }

    /// Host domain (CVA6 @ 1GHz max): coarse fit within the SoC's 1.2W
    /// envelope (host + uncore ≈ remaining budget).
    pub fn host() -> Self {
        Self {
            name: "host",
            v_min: 0.6,
            v_max: 1.1,
            f_min_mhz: 350.0,
            f_max_mhz: 1000.0,
            k: 0.25,
            alpha: 2.3,
            idle_mw: 5.0,
        }
    }

    /// Validate a supply voltage for this curve, returning it snapped
    /// exactly onto the `[v_min, v_max]` envelope (tolerance covers only
    /// float representation error, not genuine out-of-range requests).
    pub fn validate_voltage(&self, v: f64) -> Result<f64, DvfsError> {
        if !v.is_finite() {
            return Err(DvfsError::VoltageNotFinite { curve: self.name });
        }
        if v < self.v_min - RANGE_TOLERANCE || v > self.v_max + RANGE_TOLERANCE {
            return Err(DvfsError::VoltageOutOfRange {
                curve: self.name,
                v,
                v_min: self.v_min,
                v_max: self.v_max,
            });
        }
        Ok(v.clamp(self.v_min, self.v_max))
    }

    /// Validate an activity/utilization factor, snapping representation
    /// error back onto [0, 1].
    pub fn validate_util(util: f64) -> Result<f64, DvfsError> {
        if !util.is_finite() {
            return Err(DvfsError::UtilizationNotFinite);
        }
        if !(-RANGE_TOLERANCE..=1.0 + RANGE_TOLERANCE).contains(&util) {
            return Err(DvfsError::UtilizationOutOfRange { util });
        }
        Ok(util.clamp(0.0, 1.0))
    }

    /// Max frequency at supply `v` (linear corner interpolation; the
    /// published corners themselves are returned exactly).
    pub fn try_freq_mhz(&self, v: f64) -> Result<f64, DvfsError> {
        let v = self.validate_voltage(v)?;
        if v == self.v_min {
            return Ok(self.f_min_mhz);
        }
        if v == self.v_max {
            return Ok(self.f_max_mhz);
        }
        Ok(self.f_min_mhz
            + (v - self.v_min) / (self.v_max - self.v_min) * (self.f_max_mhz - self.f_min_mhz))
    }

    /// Max frequency at supply `v`. Panics (descriptively) on NaN or
    /// out-of-range voltage — callers wanting a verdict instead use
    /// [`DvfsCurve::try_freq_mhz`].
    pub fn freq_mhz(&self, v: f64) -> f64 {
        self.try_freq_mhz(v)
            .unwrap_or_else(|e| panic!("invalid DVFS request: {e}"))
    }

    /// Active power in mW at supply `v`, frequency `f_mhz`, with an
    /// activity/utilization factor in [0, 1].
    pub fn try_power_mw(&self, v: f64, f_mhz: f64, util: f64) -> Result<f64, DvfsError> {
        let v = self.validate_voltage(v)?;
        let util = Self::validate_util(util)?;
        Ok(self.k * v.powf(self.alpha) * f_mhz * util + self.idle_mw)
    }

    /// Active power in mW. Panics (descriptively) on NaN/out-of-range
    /// voltage or utilization — see [`DvfsCurve::try_power_mw`].
    pub fn power_mw(&self, v: f64, f_mhz: f64, util: f64) -> f64 {
        self.try_power_mw(v, f_mhz, util)
            .unwrap_or_else(|e| panic!("invalid DVFS request: {e}"))
    }

    /// Convenience: power at the DVFS-selected max frequency for `v`.
    pub fn power_at_v(&self, v: f64, util: f64) -> f64 {
        self.power_mw(v, self.freq_mhz(v), util)
    }
}

/// Accumulates energy over simulated intervals.
#[derive(Debug, Clone, Default)]
pub struct EnergyMeter {
    pub energy_mj: f64,
}

impl EnergyMeter {
    /// Integrate `power_mw` over `cycles` at `freq_mhz`.
    pub fn add(&mut self, power_mw: f64, cycles: u64, freq_mhz: f64) {
        let seconds = cycles as f64 / (freq_mhz * 1e6);
        self.energy_mj += power_mw * seconds; // mW * s = mJ
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn amr_frequency_corners() {
        let c = DvfsCurve::amr();
        assert_eq!(c.freq_mhz(0.6), 300.0);
        assert_eq!(c.freq_mhz(1.1), 900.0);
        assert!((c.freq_mhz(0.85) - 600.0).abs() < 1e-9);
    }

    #[test]
    fn vector_frequency_corners() {
        let c = DvfsCurve::vector();
        assert_eq!(c.freq_mhz(0.6), 250.0);
        assert_eq!(c.freq_mhz(1.1), 1000.0);
    }

    #[test]
    fn amr_power_reproduces_paper_corners() {
        let c = DvfsCurve::amr();
        // Peak power at 1.1V/900MHz ~ 747mW (Fig. 8 "50 - 747 mW").
        let p_hi = c.power_at_v(1.1, 1.0);
        assert!((p_hi - 747.0).abs() / 747.0 < 0.02, "{p_hi}");
        // 2b GOPS at 0.6V = 304.9 * 300/900 = 101.63; efficiency should
        // come out at ~1.607 TOPS/W.
        let p_lo = c.power_at_v(0.6, 1.0);
        let eff = 101.63 / (p_lo / 1000.0); // GOPS / W
        assert!((eff - 1607.0).abs() / 1607.0 < 0.05, "eff={eff}");
    }

    #[test]
    fn vector_power_reproduces_paper_corners() {
        let c = DvfsCurve::vector();
        // Peak power (FP64 activity) at 1.1V/1GHz ~ 600mW (Fig. 8).
        let p_hi = c.power_at_v(1.1, 1.0);
        assert!((p_hi - 600.0).abs() / 600.0 < 0.02, "{p_hi}");
        // FP8 GFLOPS at 0.6V = 30.45 at 0.632x datapath activity ->
        // ~1.069 TFLOPS/W (Fig. 8).
        let p_lo = c.power_mw(0.6, c.freq_mhz(0.6), 0.632);
        let eff = 30.45 / (p_lo / 1000.0);
        assert!((eff - 1068.7).abs() / 1068.7 < 0.06, "eff={eff}");
    }

    #[test]
    fn efficiency_peaks_at_low_voltage() {
        // Fig. 5's headline shape: TOPS/W decreases monotonically with V.
        let c = DvfsCurve::amr();
        let mut prev = f64::INFINITY;
        for i in 0..=10 {
            let v = 0.6 + i as f64 * 0.05;
            let gops = 304.9 * c.freq_mhz(v) / 900.0;
            let eff = gops / (c.power_at_v(v, 1.0) / 1000.0);
            assert!(eff < prev, "efficiency must fall as V rises");
            prev = eff;
        }
    }

    #[test]
    fn utilization_scales_dynamic_power_only() {
        let c = DvfsCurve::amr();
        let full = c.power_at_v(0.8, 1.0);
        let idle = c.power_at_v(0.8, 0.0);
        assert_eq!(idle, c.idle_mw);
        assert!(full > 10.0 * idle);
    }

    #[test]
    fn out_of_range_voltage_is_a_descriptive_error() {
        let c = DvfsCurve::vector();
        let err = c.try_freq_mhz(1.4).unwrap_err();
        assert_eq!(
            err,
            DvfsError::VoltageOutOfRange {
                curve: "vector",
                v: 1.4,
                v_min: 0.6,
                v_max: 1.1,
            }
        );
        assert!(err.to_string().contains("1.400V"), "{err}");
        assert!(c.try_freq_mhz(0.3).is_err());
        assert!(c.try_freq_mhz(f64::NAN).is_err());
        assert!(c.try_power_mw(f64::INFINITY, 500.0, 1.0).is_err());
        // Representation error from grid arithmetic is snapped, not
        // rejected: 0.6 + 10 * 0.05 lands a hair above 1.1.
        let v = 0.6 + 10.0 * 0.05;
        assert_eq!(c.try_freq_mhz(v).unwrap(), c.f_max_mhz);
    }

    #[test]
    #[should_panic(expected = "outside the vector curve")]
    fn out_of_range_voltage_panics_loudly_on_the_infallible_api() {
        let _ = DvfsCurve::vector().freq_mhz(1.4);
    }

    #[test]
    fn negative_utilization_is_a_descriptive_error() {
        let c = DvfsCurve::amr();
        let err = c.try_power_mw(0.8, c.freq_mhz(0.8), -0.25).unwrap_err();
        assert_eq!(err, DvfsError::UtilizationOutOfRange { util: -0.25 });
        assert!(err.to_string().contains("outside [0, 1]"), "{err}");
        assert!(c.try_power_mw(0.8, 600.0, 1.5).is_err());
        assert!(c.try_power_mw(0.8, 600.0, f64::NAN).is_err());
        // The exact endpoints are of course valid.
        assert!(c.try_power_mw(0.8, 600.0, 0.0).is_ok());
        assert!(c.try_power_mw(0.8, 600.0, 1.0).is_ok());
    }

    #[test]
    #[should_panic(expected = "outside [0, 1]")]
    fn negative_utilization_panics_loudly_on_the_infallible_api() {
        let c = DvfsCurve::amr();
        let _ = c.power_mw(0.8, 600.0, -1.0);
    }

    #[test]
    fn energy_meter_integrates() {
        let mut m = EnergyMeter::default();
        // 100mW for 1e6 cycles at 1000MHz = 1ms -> 0.1mJ.
        m.add(100.0, 1_000_000, 1000.0);
        assert!((m.energy_mj - 0.1).abs() < 1e-12);
    }

    #[test]
    fn soc_envelope_at_nominal() {
        // Sum of cluster powers at nominal 0.8V stays within the 1.2W
        // envelope the paper claims — uncore included.
        let total = DvfsCurve::amr().power_at_v(0.8, 1.0)
            + DvfsCurve::vector().power_at_v(0.8, 1.0)
            + DvfsCurve::host().power_at_v(0.8, 1.0)
            + uncore_power_mw(1000.0, 1.0);
        assert!(total < 1200.0, "total={total}mW exceeds envelope");
    }

    #[test]
    fn uncore_power_follows_its_clock_linearly() {
        assert_eq!(uncore_power_mw(1000.0, 0.0), UNCORE_IDLE_MW);
        assert_eq!(uncore_power_mw(1000.0, 1.0), 25.0 + UNCORE_IDLE_MW);
        // Coupled at the 350MHz low-voltage system clock: the memory
        // path's dynamic power shrinks with it (no V^alpha term).
        assert_eq!(uncore_power_mw(350.0, 1.0), 8.75 + UNCORE_IDLE_MW);
        assert!(uncore_power_mw(1000.0, 0.5) < uncore_power_mw(1000.0, 1.0));
    }

    #[test]
    #[should_panic(expected = "outside [0, 1]")]
    fn uncore_power_rejects_bad_utilization() {
        let _ = uncore_power_mw(1000.0, 1.5);
    }
}

//! Dynamically Partitionable Last-Level Cache (DPLLC) — paper Fig. 2c.
//!
//! 128KiB shared LLC in front of the HyperRAM, 8-way set-associative,
//! 64B lines (256 sets). Set-based *spatial partitions* of configurable
//! size are isolated in hardware and assigned to tasks via `part_id`
//! identifiers carried on AXI user signals. Selective partition flushing
//! preserves the isolation of other partitions.
//!
//! A task's accesses index only the sets of its partition, so an
//! interfering task in another partition can never evict its lines —
//! the mechanism behind Fig. 6a's "75% of isolated performance with a
//! >50% partition".

/// Paper geometry: 128KiB / 8 ways / 64B lines -> 256 sets. The single
/// source of truth for every partition-math consumer (the coordinator's
/// tuning space and the WCET engine both derive from it, so partition
/// arithmetic can never drift from the cache model).
pub const TOTAL_SETS: usize = 256;

/// Geometry + partition table.
#[derive(Debug, Clone)]
pub struct DpllcConfig {
    pub ways: usize,
    pub sets: usize,
    pub line_bytes: u64,
    /// `part_id -> (first_set, n_sets)`; id 0 is the default partition.
    pub partitions: Vec<(usize, usize)>,
}

impl DpllcConfig {
    /// Paper geometry: 128KiB, 8-way, 64B lines -> 256 sets; one default
    /// partition spanning the whole cache.
    pub fn carfield() -> Self {
        Self {
            ways: 8,
            sets: TOTAL_SETS,
            line_bytes: 64,
            partitions: vec![(0, TOTAL_SETS)],
        }
    }

    /// Split the sets into two partitions: `frac` of the sets for
    /// part_id 1 (the TCT), the rest for part_id 0 (everyone else).
    pub fn split(frac: f64) -> Self {
        let mut cfg = Self::carfield();
        let tct_sets = ((cfg.sets as f64 * frac).round() as usize).clamp(1, cfg.sets - 1);
        cfg.partitions = vec![(0, cfg.sets - tct_sets), (cfg.sets - tct_sets, tct_sets)];
        cfg
    }
}

/// Per-partition observability counters (Fig. 6a reports DPLLC misses).
#[derive(Debug, Clone, Copy, Default)]
pub struct DpllcStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub writebacks: u64,
}

#[derive(Debug, Clone, Copy, Default)]
struct Line {
    valid: bool,
    dirty: bool,
    tag: u64,
    last_used: u64,
}

/// The cache state machine (timing handled by `HyperramPath`).
pub struct Dpllc {
    cfg: DpllcConfig,
    /// `sets x ways` line array.
    lines: Vec<Line>,
    use_clock: u64,
    /// Stats per part_id (index-capped).
    pub stats: Vec<DpllcStats>,
}

/// Result of a lookup+allocate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    Hit,
    /// Miss; `writeback` true when a dirty victim must go to memory.
    Miss { writeback: bool },
}

impl Dpllc {
    pub fn new(cfg: DpllcConfig) -> Self {
        let lines = vec![Line::default(); cfg.sets * cfg.ways];
        let n_parts = cfg.partitions.len().max(1);
        Self {
            cfg,
            lines,
            use_clock: 0,
            stats: vec![DpllcStats::default(); n_parts],
        }
    }

    /// Reprogram the partition table (hypervisor write to the config
    /// registers). Contents of all sets are preserved; only indexing
    /// changes, as in the hardware.
    pub fn repartition(&mut self, partitions: Vec<(usize, usize)>) {
        for &(first, n) in &partitions {
            assert!(first + n <= self.cfg.sets, "partition out of range");
            assert!(n > 0, "empty partition");
        }
        let n_parts = partitions.len();
        self.cfg.partitions = partitions;
        self.stats.resize(n_parts, DpllcStats::default());
    }

    fn partition(&self, part_id: u8) -> (usize, usize) {
        *self
            .cfg
            .partitions
            .get(part_id as usize)
            .unwrap_or(&self.cfg.partitions[0])
    }

    fn set_index(&self, addr: u64, part_id: u8) -> usize {
        let (first, n) = self.partition(part_id);
        let line = addr / self.cfg.line_bytes;
        first + (line as usize % n)
    }

    fn tag_of(&self, addr: u64) -> u64 {
        addr / self.cfg.line_bytes
    }

    fn stat_mut(&mut self, part_id: u8) -> &mut DpllcStats {
        let idx = (part_id as usize).min(self.stats.len() - 1);
        &mut self.stats[idx]
    }

    /// Non-destructive probe: would `addr` hit right now? (No LRU or
    /// stats update — used by the controller's hit-port admission.)
    pub fn probe(&self, addr: u64, part_id: u8) -> bool {
        let set = self.set_index(addr, part_id);
        let tag = self.tag_of(addr);
        let base = set * self.cfg.ways;
        (0..self.cfg.ways).any(|w| {
            let line = &self.lines[base + w];
            line.valid && line.tag == tag
        })
    }

    /// Look up `addr` on behalf of `part_id`; allocates on miss (reads
    /// and writes both allocate, as in the write-back LLC).
    pub fn access(&mut self, addr: u64, part_id: u8, write: bool) -> Access {
        self.use_clock += 1;
        let clock = self.use_clock;
        let set = self.set_index(addr, part_id);
        let tag = self.tag_of(addr);
        let base = set * self.cfg.ways;
        let ways = self.cfg.ways;

        // Hit path.
        for w in 0..ways {
            let line = &mut self.lines[base + w];
            if line.valid && line.tag == tag {
                line.last_used = clock;
                line.dirty |= write;
                self.stat_mut(part_id).hits += 1;
                return Access::Hit;
            }
        }
        // Miss: pick invalid way or LRU victim.
        let mut victim = 0;
        let mut best = u64::MAX;
        for w in 0..ways {
            let line = &self.lines[base + w];
            if !line.valid {
                victim = w;
                break;
            }
            if line.last_used < best {
                best = line.last_used;
                victim = w;
            }
        }
        let line = &mut self.lines[base + victim];
        let writeback = line.valid && line.dirty;
        let evicted = line.valid;
        *line = Line {
            valid: true,
            dirty: write,
            tag,
            last_used: clock,
        };
        let st = self.stat_mut(part_id);
        st.misses += 1;
        if evicted {
            st.evictions += 1;
        }
        if writeback {
            st.writebacks += 1;
        }
        Access::Miss { writeback }
    }

    /// Selective partition flush: invalidate only `part_id`'s sets,
    /// returning the number of dirty lines written back. Other
    /// partitions are untouched (isolation-preserving).
    pub fn flush_partition(&mut self, part_id: u8) -> u64 {
        let (first, n) = self.partition(part_id);
        let mut writebacks = 0;
        for set in first..first + n {
            for w in 0..self.cfg.ways {
                let line = &mut self.lines[set * self.cfg.ways + w];
                if line.valid && line.dirty {
                    writebacks += 1;
                }
                *line = Line::default();
            }
        }
        writebacks
    }

    /// Fraction of valid lines within a partition (occupancy probe).
    pub fn occupancy(&self, part_id: u8) -> f64 {
        let (first, n) = self.partition(part_id);
        let total = n * self.cfg.ways;
        let valid = (first..first + n)
            .flat_map(|s| (0..self.cfg.ways).map(move |w| s * self.cfg.ways + w))
            .filter(|&i| self.lines[i].valid)
            .count();
        valid as f64 / total as f64
    }

    pub fn line_bytes(&self) -> u64 {
        self.cfg.line_bytes
    }

    pub fn sets(&self) -> usize {
        self.cfg.sets
    }

    pub fn ways(&self) -> usize {
        self.cfg.ways
    }

    /// The absolute set index `addr` maps to inside `part_id`'s
    /// partition — the same arithmetic `access`/`probe` use, exposed so
    /// the trace layer's line-fill events (and the working-set profiler
    /// built on them) can never drift from the cache model.
    pub fn set_of(&self, addr: u64, part_id: u8) -> usize {
        self.set_index(addr, part_id)
    }

    /// `(first_set, n_sets)` for `part_id` (unknown ids fall back to the
    /// default partition, exactly like `access`).
    pub fn partition_of(&self, part_id: u8) -> (usize, usize) {
        self.partition(part_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_fill() {
        let mut c = Dpllc::new(DpllcConfig::carfield());
        assert!(matches!(c.access(0x1000, 0, false), Access::Miss { .. }));
        assert_eq!(c.access(0x1000, 0, false), Access::Hit);
        assert_eq!(c.access(0x1008, 0, false), Access::Hit, "same line");
        assert!(matches!(c.access(0x1040, 0, false), Access::Miss { .. }));
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = Dpllc::new(DpllcConfig::carfield());
        let sets = c.sets() as u64;
        let line = c.line_bytes();
        // Fill all 8 ways of set 0, then one more -> evicts the first.
        for w in 0..9u64 {
            c.access(w * sets * line, 0, false);
        }
        assert!(matches!(c.access(0, 0, false), Access::Miss { .. }), "way 0 evicted");
        assert_eq!(c.access(8 * sets * line, 0, false), Access::Hit);
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = Dpllc::new(DpllcConfig::carfield());
        let sets = c.sets() as u64;
        let line = c.line_bytes();
        c.access(0, 0, true); // dirty fill
        for w in 1..9u64 {
            let r = c.access(w * sets * line, 0, false);
            if w == 8 {
                assert_eq!(r, Access::Miss { writeback: true });
            }
        }
    }

    #[test]
    fn partitions_are_isolated() {
        let mut c = Dpllc::new(DpllcConfig::split(0.5));
        // TCT (part 1) fills a working set.
        for i in 0..64u64 {
            c.access(i * 64, 1, false);
        }
        // Interferer (part 0) streams a huge footprint.
        for i in 0..100_000u64 {
            c.access(i * 64, 0, false);
        }
        // TCT still hits everything.
        for i in 0..64u64 {
            assert_eq!(c.access(i * 64, 1, false), Access::Hit, "line {i} evicted");
        }
    }

    #[test]
    fn shared_partition_thrashes() {
        let mut c = Dpllc::new(DpllcConfig::carfield());
        for i in 0..64u64 {
            c.access(i * 64, 0, false);
        }
        // Same partition interferer evicts the working set.
        for i in 1000..(1000 + 100_000u64) {
            c.access(i * 64, 0, false);
        }
        let mut misses = 0;
        for i in 0..64u64 {
            if matches!(c.access(i * 64, 0, false), Access::Miss { .. }) {
                misses += 1;
            }
        }
        assert!(misses > 48, "only {misses} misses — no thrashing?");
    }

    #[test]
    fn selective_flush_spares_other_partitions() {
        let mut c = Dpllc::new(DpllcConfig::split(0.5));
        for i in 0..32u64 {
            c.access(i * 64, 0, true);
            c.access(i * 64, 1, false);
        }
        let wb = c.flush_partition(0);
        assert!(wb > 0, "dirty lines must write back");
        assert!(c.occupancy(0) == 0.0);
        assert!(c.occupancy(1) > 0.0);
        // Partition 1 unaffected.
        for i in 0..32u64 {
            assert_eq!(c.access(i * 64, 1, false), Access::Hit);
        }
    }

    #[test]
    fn repartition_live() {
        let mut c = Dpllc::new(DpllcConfig::carfield());
        c.repartition(vec![(0, 128), (128, 128)]);
        assert!(matches!(c.access(0, 1, false), Access::Miss { .. }));
        assert_eq!(c.access(0, 1, false), Access::Hit);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn repartition_validates() {
        let mut c = Dpllc::new(DpllcConfig::carfield());
        c.repartition(vec![(0, 300)]);
    }

    #[test]
    fn stats_track_by_partition() {
        let mut c = Dpllc::new(DpllcConfig::split(0.25));
        c.access(0, 1, false);
        c.access(0, 1, false);
        c.access(64, 0, false);
        assert_eq!(c.stats[1].misses, 1);
        assert_eq!(c.stats[1].hits, 1);
        assert_eq!(c.stats[0].misses, 1);
    }

    #[test]
    fn set_of_matches_access_indexing() {
        let c = Dpllc::new(DpllcConfig::split(0.375)); // 96-set TCT partition
        assert_eq!(c.ways(), 8);
        assert_eq!(c.partition_of(1), (160, 96));
        assert_eq!(c.partition_of(0), (0, 160));
        // part 1 indexes only its own sets: first + (line % n).
        assert_eq!(c.set_of(0, 1), 160);
        assert_eq!(c.set_of(64, 1), 161);
        assert_eq!(c.set_of(96 * 64, 1), 160, "wraps at the partition size");
        // Unknown ids fall back to partition 0, like access().
        assert_eq!(c.set_of(64, 42), c.set_of(64, 0));
    }

    #[test]
    fn unknown_part_id_falls_back_to_default() {
        let mut c = Dpllc::new(DpllcConfig::carfield());
        assert!(matches!(c.access(0, 42, false), Access::Miss { .. }));
        assert_eq!(c.access(0, 42, false), Access::Hit);
    }
}

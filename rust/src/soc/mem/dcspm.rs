//! Dynamically Configurable L2 Scratchpad Memory (DCSPM) — paper Fig. 2b.
//!
//! 1MiB on-chip SPM, 32 physical banks, two AXI4 subordinate ports,
//! 128b/cyc aggregate bandwidth (2 x 64b ports). Two addressing modes,
//! selected *per access* through aliased address windows (zero-latency
//! runtime reconfiguration):
//!
//! - **interleaved** (default alias): consecutive 64b words spread across
//!   banks — best average bandwidth for NCTs sharing data, but two
//!   concurrent streams collide statistically on banks;
//! - **contiguous** (alias bit set): the address space maps linearly onto
//!   banks, so disjoint buffers live in disjoint banks and two streams
//!   form *interference-free private paths* (Fig. 6b R-E4).
//!
//! Port mapping: in contiguous mode the low half of the SPM is served by
//! port 0 and the high half by port 1; in interleaved mode any free port
//! serves any burst. Bank conflicts stall the losing port for one cycle
//! (priority alternates each cycle for fairness).

use super::super::axi::{Burst, Completion, Target, TargetModel};
use super::super::clock::{Cycle, Domain};
use crate::trace::{TraceBuf, TraceEvent, TraceKind};

/// Address bit that selects the contiguous (bank-isolated) alias window.
pub const CONTIG_ALIAS_BIT: u64 = 1 << 28;

/// SPM capacity and banking (paper §II).
pub const CAPACITY: u64 = 1 << 20; // 1 MiB
pub const N_BANKS: u64 = 32;
pub const BANK_SIZE: u64 = CAPACITY / N_BANKS; // 32 KiB
const WORD: u64 = 8; // 64b words

/// Observability counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct DcspmStats {
    pub beats_served: u64,
    pub bank_conflicts: u64,
    pub bursts: u64,
}

#[derive(Debug)]
struct InFlight {
    burst: Burst,
    beats_done: u32,
}

/// The two-port banked scratchpad.
pub struct Dcspm {
    ports: [Option<InFlight>; 2],
    pub stats: DcspmStats,
    /// Cycles with at least one port in service (the per-resource
    /// service-mode counter; replayed exactly by `fast_forward`).
    busy_cycles: u64,
    /// Completion pipeline latency (SPM macro + AXI return).
    resp_latency: Cycle,
    /// Trace sink for cross-port bank-conflict events. Conflicts only
    /// happen with both ports busy — a state `next_event` refuses to
    /// skip — so the stream is identical under naive and event-driven
    /// stepping.
    trace: TraceBuf,
}

impl Dcspm {
    pub fn new() -> Self {
        Self {
            ports: [None, None],
            stats: DcspmStats::default(),
            busy_cycles: 0,
            resp_latency: 1,
            trace: None,
        }
    }

    /// Effective SPM offset (strips the alias bit).
    fn offset(addr: u64) -> u64 {
        (addr & !CONTIG_ALIAS_BIT) % CAPACITY
    }

    fn is_contiguous(addr: u64) -> bool {
        addr & CONTIG_ALIAS_BIT != 0
    }

    /// Bank index for byte `offset` under the access mode of `addr`.
    pub fn bank_of(addr: u64, beat_offset: u64) -> u64 {
        let off = Self::offset(addr) + beat_offset * WORD;
        if Self::is_contiguous(addr) {
            (off / BANK_SIZE) % N_BANKS
        } else {
            (off / WORD) % N_BANKS
        }
    }

    /// The AXI subordinate port a burst must use.
    ///
    /// The *interleaved* alias is one shared subordinate (port 0): all
    /// initiators' bursts serialize on its AXI side even though the
    /// banks behind it are many — which is exactly why two clusters
    /// sharing L2 data interfere (Fig. 6b R-E2). Each *contiguous* alias
    /// half is its own subordinate port: disjoint buffers get disjoint
    /// ports + banks — the interference-free private path (R-E4).
    fn required_port(burst: &Burst) -> Option<usize> {
        Some(Self::port_of_addr(burst.addr))
    }

    /// Subordinate port serving `addr` (WCET hook: streams on the same
    /// port serialize; streams on different ports only interact through
    /// bank conflicts).
    pub fn port_of_addr(addr: u64) -> usize {
        if Self::is_contiguous(addr) {
            (Self::offset(addr) / (CAPACITY / 2)) as usize
        } else {
            0
        }
    }

    /// The contiguous-alias half `addr` is pinned to, or `None` for the
    /// interleaved alias (which spreads across every bank). Two streams
    /// can bank-conflict only when their spans overlap (WCET hook).
    pub fn bank_half_of_addr(addr: u64) -> Option<u64> {
        if Self::is_contiguous(addr) {
            Some(Self::offset(addr) / (CAPACITY / 2))
        } else {
            None
        }
    }

    /// WCET service model: port cycles for a burst of `beats`, one beat
    /// per cycle plus the response edge; a conflicting stream on the
    /// other port can steal every other beat slot (priority alternates
    /// by cycle parity), doubling the worst case.
    ///
    /// Owning clock domain: **system**. The DCSPM is the tightly-coupled
    /// on-chip L2, clocked with the host/interconnect domain (unlike the
    /// HyperRAM/DPLLC path, which lives in the fixed-frequency uncore) —
    /// so this cost scales with the system voltage, and the bound layer
    /// converts it to wall-clock through the system clock.
    pub fn worst_burst_cycles(beats: u32, conflict_possible: bool) -> Cycle {
        let b = beats as Cycle;
        (if conflict_possible { 2 * b } else { b }) + 1
    }
}

impl Default for Dcspm {
    fn default() -> Self {
        Self::new()
    }
}

impl TargetModel for Dcspm {
    fn target(&self) -> Target {
        Target::Dcspm
    }

    /// One arbitration lane per subordinate port, so contention on one
    /// port never skews round-robin fairness on the other.
    fn lanes(&self) -> usize {
        2
    }

    fn lane_of(&self, burst: &Burst) -> usize {
        Self::port_of_addr(burst.addr)
    }

    fn can_accept(&self, burst: &Burst) -> bool {
        match Self::required_port(burst) {
            Some(p) => self.ports[p].is_none(),
            None => self.ports.iter().any(|p| p.is_none()),
        }
    }

    fn start(&mut self, burst: Burst, _now: Cycle) {
        let slot = match Self::required_port(&burst) {
            Some(p) => p,
            None => self
                .ports
                .iter()
                .position(|p| p.is_none())
                .expect("start() without can_accept()"),
        };
        debug_assert!(self.ports[slot].is_none());
        self.stats.bursts += 1;
        self.ports[slot] = Some(InFlight {
            burst,
            beats_done: 0,
        });
    }

    fn tick(&mut self, now: Cycle, done: &mut Vec<Completion>) {
        if self.ports.iter().any(|p| p.is_some()) {
            self.busy_cycles += 1;
        }
        // Priority alternates by cycle parity so neither port starves
        // under persistent conflicts.
        let first = (now & 1) as usize;
        let mut bank_used: Option<u64> = None;
        for k in 0..2 {
            let p = (first + k) % 2;
            let Some(inf) = &mut self.ports[p] else {
                continue;
            };
            let bank = Self::bank_of(inf.burst.addr, inf.beats_done as u64);
            if bank_used == Some(bank) {
                self.stats.bank_conflicts += 1;
                if let Some(tb) = self.trace.as_deref_mut() {
                    tb.push(TraceEvent {
                        at: now,
                        domain: Domain::System,
                        initiator: inf.burst.initiator,
                        target: Some(Target::Dcspm),
                        lane: p as u8,
                        tag: inf.burst.tag,
                        kind: TraceKind::BankConflict,
                    });
                }
                continue; // stalled this cycle
            }
            bank_used = Some(bank);
            inf.beats_done += 1;
            self.stats.beats_served += 1;
            if inf.beats_done >= inf.burst.beats {
                done.push(Completion::of(&inf.burst, now + self.resp_latency));
                self.ports[p] = None;
            }
        }
    }

    fn idle(&self) -> bool {
        self.ports.iter().all(|p| p.is_none())
    }

    fn set_trace(&mut self, buf: TraceBuf) {
        self.trace = buf;
    }

    fn take_trace(&mut self) -> Vec<TraceEvent> {
        match self.trace.as_deref_mut() {
            Some(v) => std::mem::take(v),
            None => Vec::new(),
        }
    }

    /// With a single busy port there is no bank contention: service is
    /// exactly one beat per cycle and the completion tick is knowable, so
    /// the window up to it can be skipped (beats are replayed by
    /// `fast_forward`). With both ports busy, conflicts depend on
    /// per-cycle bank positions — stay cycle-accurate.
    fn next_event(&self, now: Cycle) -> Option<Cycle> {
        let mut busy = self.ports.iter().flatten();
        let first = busy.next()?;
        if busy.next().is_some() {
            return Some(now); // two streams: possible bank conflicts
        }
        let remaining = (first.burst.beats - first.beats_done) as Cycle;
        Some(now + remaining - 1)
    }

    /// Replay the beats a naive run would have served in `[from, to)`.
    /// Only reachable with at most one busy port (see `next_event`), so
    /// the one-beat-per-cycle rate is exact.
    fn fast_forward(&mut self, from: Cycle, to: Cycle) {
        let delta = to - from;
        let mut served = 0u64;
        for inflight in self.ports.iter_mut().flatten() {
            debug_assert!(delta < (inflight.burst.beats - inflight.beats_done) as Cycle);
            inflight.beats_done += delta as u32;
            served += delta;
        }
        self.stats.beats_served += served;
        // Port occupancy is constant across a replayable window (no
        // grant, no completion inside it), so the busy count a naive
        // run would accumulate is exactly the window length.
        if served > 0 {
            self.busy_cycles += delta;
        }
    }

    fn busy_cycles(&self) -> u64 {
        self.busy_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::axi::InitiatorId;

    fn read(addr: u64, beats: u32, who: u8) -> Burst {
        Burst::read(InitiatorId(who), Target::Dcspm, addr, beats)
    }

    fn run(d: &mut Dcspm, bursts: Vec<Burst>, cycles: Cycle) -> Vec<Completion> {
        let mut pending: Vec<Burst> = bursts;
        let mut done = Vec::new();
        for now in 0..cycles {
            pending.retain(|b| {
                if d.can_accept(b) {
                    d.start(b.clone(), now);
                    false
                } else {
                    true
                }
            });
            d.tick(now, &mut done);
        }
        done
    }

    #[test]
    fn single_burst_takes_beats_plus_latency() {
        let mut d = Dcspm::new();
        let done = run(&mut d, vec![read(0, 8, 0).with_tag(1)], 20);
        assert_eq!(done.len(), 1);
        // 8 beats starting at cycle 0 -> last beat at cycle 7, +1 resp.
        assert_eq!(done[0].finished_at, 8);
    }

    #[test]
    fn port_and_bank_wcet_helpers() {
        use crate::soc::axi::TargetModel;
        // Interleaved alias: always port 0, spans every bank.
        assert_eq!(Dcspm::port_of_addr(0x1000), 0);
        assert_eq!(Dcspm::bank_half_of_addr(0x1000), None);
        // Contiguous halves map to their own port + bank half.
        assert_eq!(Dcspm::port_of_addr(CONTIG_ALIAS_BIT), 0);
        assert_eq!(Dcspm::bank_half_of_addr(CONTIG_ALIAS_BIT), Some(0));
        assert_eq!(Dcspm::port_of_addr(CONTIG_ALIAS_BIT + CAPACITY / 2), 1);
        assert_eq!(
            Dcspm::bank_half_of_addr(CONTIG_ALIAS_BIT + CAPACITY / 2),
            Some(1)
        );
        // Service model: one beat per cycle + response; conflicts double.
        assert_eq!(Dcspm::worst_burst_cycles(16, false), 17);
        assert_eq!(Dcspm::worst_burst_cycles(16, true), 33);
        // One arbitration lane per port.
        let d = Dcspm::new();
        assert_eq!(d.lanes(), 2);
        assert_eq!(d.lane_of(&read(CONTIG_ALIAS_BIT + CAPACITY / 2, 8, 0)), 1);
        assert_eq!(d.lane_of(&read(0, 8, 0)), 0);
    }

    #[test]
    fn busy_cycles_counts_only_service_cycles() {
        let mut d = Dcspm::new();
        let done = run(&mut d, vec![read(0, 8, 0).with_tag(1)], 20);
        assert_eq!(done.len(), 1);
        // Busy exactly while the burst was in service (cycles 0..8);
        // the 12 idle tail cycles must not count.
        assert_eq!(d.busy_cycles(), 8);
        // A fast-forwarded window replays the same accounting.
        let mut f = Dcspm::new();
        f.start(read(0, 8, 0), 0);
        f.fast_forward(0, 7);
        let mut out = Vec::new();
        f.tick(7, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(f.busy_cycles(), d.busy_cycles());
    }

    #[test]
    fn interleaved_mode_spreads_banks() {
        assert_eq!(Dcspm::bank_of(0, 0), 0);
        assert_eq!(Dcspm::bank_of(0, 1), 1);
        assert_eq!(Dcspm::bank_of(0, 31), 31);
        assert_eq!(Dcspm::bank_of(0, 32), 0);
    }

    #[test]
    fn contiguous_mode_pins_banks() {
        let base = CONTIG_ALIAS_BIT;
        assert_eq!(Dcspm::bank_of(base, 0), 0);
        // A whole bank's worth of consecutive words stays in bank 0.
        assert_eq!(Dcspm::bank_of(base, (BANK_SIZE / WORD) - 1), 0);
        assert_eq!(Dcspm::bank_of(base + BANK_SIZE, 0), 1);
    }

    #[test]
    fn two_interleaved_streams_serialize_on_shared_port() {
        let mut d = Dcspm::new();
        // The interleaved alias is one shared AXI subordinate: two
        // concurrent streams serialize burst-by-burst (the Fig. 6b R-E2
        // interference channel).
        let done = run(
            &mut d,
            vec![read(0, 64, 0).with_tag(1), read(0, 64, 1).with_tag(2)],
            400,
        );
        assert_eq!(done.len(), 2);
        let f1 = done.iter().find(|c| c.tag == 1).unwrap().finished_at;
        let f2 = done.iter().find(|c| c.tag == 2).unwrap().finished_at;
        // Second stream waits out the first's full 64-beat burst.
        assert!((f2 as i64 - f1 as i64).unsigned_abs() >= 64, "f1={f1} f2={f2}");
    }

    #[test]
    fn contiguous_disjoint_buffers_are_conflict_free() {
        let mut d = Dcspm::new();
        // Buffer A in low half (port 0), buffer B in high half (port 1).
        let a = read(CONTIG_ALIAS_BIT, 64, 0).with_tag(1);
        let b = read(CONTIG_ALIAS_BIT + CAPACITY / 2, 64, 1).with_tag(2);
        let done = run(&mut d, vec![a, b], 400);
        assert_eq!(done.len(), 2);
        assert_eq!(d.stats.bank_conflicts, 0);
        // Both finished concurrently: full 2-port bandwidth.
        assert_eq!(done[0].finished_at, done[1].finished_at);
    }

    #[test]
    fn contiguous_same_half_serializes() {
        let mut d = Dcspm::new();
        let a = read(CONTIG_ALIAS_BIT, 16, 0).with_tag(1);
        let b = read(CONTIG_ALIAS_BIT + 4096, 16, 1).with_tag(2);
        let done = run(&mut d, vec![a, b], 400);
        assert_eq!(done.len(), 2);
        // Port 0 serves them back to back.
        let t1 = done.iter().find(|c| c.tag == 1).unwrap().finished_at;
        let t2 = done.iter().find(|c| c.tag == 2).unwrap().finished_at;
        assert!((t2 as i64 - t1 as i64).unsigned_abs() >= 16);
    }

    #[test]
    fn contiguous_same_bank_conflicts_alternate() {
        let mut d = Dcspm::new();
        // Two contiguous streams in the SAME half contend for port 0 and
        // serialize; neither starves.
        let a = read(CONTIG_ALIAS_BIT, 32, 0).with_tag(1);
        let b = read(CONTIG_ALIAS_BIT + 64, 32, 1).with_tag(2);
        let done = run(&mut d, vec![a, b], 400);
        assert_eq!(done.len(), 2);
    }

    #[test]
    fn aggregate_bandwidth_two_beats_per_cycle_in_contiguous_mode() {
        let mut d = Dcspm::new();
        // Disjoint halves -> both ports stream concurrently: 128b/cyc.
        let a = read(CONTIG_ALIAS_BIT, 128, 0).with_tag(1);
        let b = read(CONTIG_ALIAS_BIT + CAPACITY / 2, 128, 1).with_tag(2);
        let done = run(&mut d, vec![a, b], 200);
        assert_eq!(done.len(), 2);
        // 256 beats total served in ~128 cycles.
        assert!(done.iter().all(|c| c.finished_at <= 130));
    }
}

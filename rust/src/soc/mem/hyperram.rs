//! HyperRAM path: DPLLC-fronted external memory with deterministic
//! HyperBUS timing (paper: "400Mb/s deterministic access time HyperBUS
//! memory controller", two external HyperRAM chips).
//!
//! The `HyperramPath` is the crossbar target for the `Target::Hyperram`
//! address space. Every burst is decomposed into 64B cache lines; each
//! line is looked up in the DPLLC under the burst's `part_id`:
//!
//! - hit  -> served at LLC pipeline latency;
//! - miss -> the line is fetched over the (single) HyperBUS channel with
//!   deterministic open+stream timing; dirty victims add a writeback.
//!
//! The channel serves one line transfer at a time — the serialization
//! point that makes an unregulated DMA catastrophic for a TCT (Fig. 6a).

use super::super::axi::{Burst, Completion, Target, TargetModel};
use super::super::clock::{Cycle, Domain};
use super::dpllc::{Access, Dpllc, DpllcConfig};
use crate::trace::{TraceBuf, TraceEvent, TraceKind};

/// Deterministic HyperBUS timing in **uncore cycles**.
///
/// The HyperBUS PHY, the memory controller and the DPLLC pipeline live
/// in the fixed-frequency uncore clock domain ([`Domain::Uncore`]):
/// these constants do not stretch when the core domains voltage-scale.
/// On the seed's single timebase (uncore coupled to the system clock)
/// uncore cycles and system cycles coincide, so every number below reads
/// exactly as it did before the domain split.
#[derive(Debug, Clone, Copy)]
pub struct HyperRamTiming {
    /// Command + access latency for a line whose row is not open.
    pub t_row_miss: Cycle,
    /// Reduced latency when the previous access hit the same row.
    pub t_row_hit: Cycle,
    /// Cycles per 64b beat on the 8b-DDR HyperBUS (8B @ ~400MB/s vs the
    /// ~640MHz system clock => ~2 cycles/beat).
    pub beat_cycles: Cycle,
    /// Row (page) size in bytes.
    pub row_bytes: u64,
    /// LLC hit pipeline latency.
    pub llc_hit: Cycle,
    /// Worst-case transient-retry overhead charged per line fill (0 on
    /// the fault-free path). A `FaultPlan` with line retries inflates
    /// this to `retries_per_line * line_retry_cost(..)` — the channel
    /// cycles of a full row-miss re-fetch per retry — so
    /// `worst_lines_cost` stays a sound per-target service model under
    /// injection.
    pub line_retry_overhead: Cycle,
}

impl HyperRamTiming {
    pub fn carfield() -> Self {
        Self {
            t_row_miss: 24,
            t_row_hit: 8,
            beat_cycles: 2,
            row_bytes: 1024,
            llc_hit: 4,
            line_retry_overhead: 0,
        }
    }

    /// Channel cycles one transient retry of a `line_bytes` line costs:
    /// the HyperBUS aborts and re-issues the whole line transfer with a
    /// fresh row open (the deterministic worst case — row locality is
    /// lost on the retry).
    pub fn line_retry_cost(&self, line_bytes: u64) -> Cycle {
        self.t_row_miss + self.line_stream_cycles(line_bytes)
    }

    /// The same timing with a per-line retry overhead — the bound
    /// engine's inflation hook for faulted scenarios.
    pub fn with_retry_overhead(mut self, overhead: Cycle) -> Self {
        self.line_retry_overhead = overhead;
        self
    }

    /// Channel cycles to stream one `line_bytes` cache line (excluding
    /// the row open).
    pub fn line_stream_cycles(&self, line_bytes: u64) -> Cycle {
        (line_bytes / 8) * self.beat_cycles
    }

    /// Worst-case number of distinct rows `lines` sequentially-addressed
    /// lines can span (worst alignment against the row boundaries).
    pub fn worst_rows_of(&self, lines: u64, line_bytes: u64) -> u64 {
        if lines <= 1 {
            return lines;
        }
        let per_row = (self.row_bytes / line_bytes).max(1);
        1 + (lines - 1).div_ceil(per_row)
    }

    /// WCET service model: the most channel cycles (uncore domain)
    /// `lines` sequential line fetches served back to back can take —
    /// the first line of each spanned row pays the full row open, the
    /// rest row-hit. With `dirty_possible` every fill may additionally
    /// drain a dirty victim (a symmetric write, paper-deterministic like
    /// the fill itself).
    ///
    /// This is the per-target worst-case characterization the `wcet`
    /// bound engine composes with TSU arrival curves and crossbar
    /// arbitration bounds; the bound layer converts it to wall-clock
    /// through the uncore clock, never the system clock.
    pub fn worst_lines_cost(&self, lines: u64, line_bytes: u64, dirty_possible: bool) -> Cycle {
        if lines == 0 {
            return 0;
        }
        let rows = self.worst_rows_of(lines, line_bytes);
        let stream = self.line_stream_cycles(line_bytes);
        let mut cost = lines * stream + rows * self.t_row_miss + (lines - rows) * self.t_row_hit;
        if dirty_possible {
            cost += lines * (self.t_row_miss + stream);
        }
        // Transient-retry inflation: every line may pay the full retry
        // overhead (the simulator injects on at most every n-th fill, so
        // measured service stays under this worst case).
        cost + lines * self.line_retry_overhead
    }
}

/// Per-path counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct PathStats {
    pub line_fills: u64,
    pub writebacks: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub bursts: u64,
    /// Transient line retries injected by a fault plan.
    pub retries: u64,
    /// Uncore cycles with work in flight (queue, channel or hit port) —
    /// the measured-utilization feed for the uncore power domain.
    pub busy_cycles: u64,
}

#[derive(Debug)]
struct Serving {
    burst: Burst,
    /// Line-granular plan: remaining line base addresses to process.
    lines_left: u32,
    next_line_addr: u64,
    /// Busy-until for the current line operation.
    line_done_at: Cycle,
    /// Whether the current line op has been scheduled.
    line_active: bool,
}

/// Command-queue depth of the memory controller (bursts admitted behind
/// the one in service) — part of the WCET structural interference bound.
pub const QUEUE_DEPTH: usize = 4;

/// DPLLC + HyperBUS channel as one crossbar target.
///
/// The memory controller admits up to `queue_depth` bursts into its
/// command queue (FIFO service). A deeply-pipelined DMA fills this queue,
/// so a TCT refill granted *after* it waits out the whole queue — the
/// core of Fig. 6a's 225x unregulated degradation.
pub struct HyperramPath {
    pub llc: Dpllc,
    timing: HyperRamTiming,
    current: Option<Serving>,
    /// Admitted-but-not-yet-serving bursts (controller command queue).
    queue: std::collections::VecDeque<Burst>,
    pub queue_depth: usize,
    /// Parallel LLC hit port: bursts whose lines ALL hit are served from
    /// the cache SRAM without touching the HyperBUS channel at all —
    /// which is what makes a DPLLC partition effective even while a DMA
    /// monopolizes the external channel (Fig. 6a partition row).
    hit_port: Option<(Burst, Cycle)>,
    last_row: Option<u64>,
    pub stats: PathStats,
    /// When true the LLC is bypassed entirely (uncached region) — used
    /// by ablation benches.
    pub bypass_llc: bool,
    /// Fault injection: every n-th line fill suffers a transient retry
    /// burst (0 = never). Counter-based, so the injected sequence is a
    /// pure function of the fill sequence — bit-identical under naive
    /// and event-driven stepping and across sweep threads.
    fault_retry_every: u64,
    fault_retries_per_line: u32,
    fault_fill_counter: u64,
    /// Trace sink for line-fill/retry events (uncore-local timestamps).
    /// Fills are only scheduled in cycles `next_event` pins, so the
    /// stream is identical under naive and event-driven stepping.
    trace: TraceBuf,
}

impl HyperramPath {
    pub fn new(cfg: DpllcConfig, timing: HyperRamTiming) -> Self {
        Self {
            llc: Dpllc::new(cfg),
            timing,
            current: None,
            queue: Default::default(),
            queue_depth: QUEUE_DEPTH,
            hit_port: None,
            last_row: None,
            stats: PathStats::default(),
            bypass_llc: false,
            fault_retry_every: 0,
            fault_retries_per_line: 0,
            fault_fill_counter: 0,
            trace: None,
        }
    }

    /// Arm deterministic transient-retry injection: every `every`-th
    /// line fill (counting from `phase`, a seed-derived offset) pays
    /// `per_line` retries, each a full row-miss re-fetch of the line.
    pub fn set_fault_retries(&mut self, every: u64, per_line: u32, phase: u64) {
        self.fault_retry_every = every;
        self.fault_retries_per_line = per_line;
        self.fault_fill_counter = phase;
    }

    /// Line base addresses a burst touches.
    fn lines_of(&self, burst: &Burst) -> (u64, u32) {
        let line = self.llc.line_bytes();
        let first = burst.addr / line * line;
        let last = (burst.end_addr().saturating_sub(1)) / line * line;
        (first, ((last - first) / line + 1) as u32)
    }

    /// Whether every line of `burst` currently hits the LLC.
    fn all_hit(&self, burst: &Burst) -> bool {
        if self.bypass_llc {
            return false;
        }
        let (first, n) = self.lines_of(burst);
        (0..n as u64).all(|i| {
            self.llc
                .probe(first + i * self.llc.line_bytes(), burst.part_id)
        })
    }

    pub fn carfield() -> Self {
        Self::new(DpllcConfig::carfield(), HyperRamTiming::carfield())
    }

    /// Deterministic line-fetch duration given row locality.
    fn line_fetch_cycles(&mut self, line_addr: u64) -> Cycle {
        let row = line_addr / self.timing.row_bytes;
        let beats = self.llc.line_bytes() / 8;
        let open = if self.last_row == Some(row) {
            self.stats.row_hits += 1;
            self.timing.t_row_hit
        } else {
            self.stats.row_misses += 1;
            self.timing.t_row_miss
        };
        self.last_row = Some(row);
        open + beats * self.timing.beat_cycles
    }

    /// Schedule the next line of the in-flight burst; returns busy-until.
    fn schedule_line(&mut self, now: Cycle) {
        let Some(cur) = self.current.as_mut() else {
            return;
        };
        if cur.line_active || cur.lines_left == 0 {
            return;
        }
        let line_addr = cur.next_line_addr;
        let part = cur.burst.part_id;
        let write = cur.burst.write;
        let (mut dur, fill, wb) = if self.bypass_llc {
            let cur_mut = self.current.as_mut().unwrap();
            let _ = cur_mut;
            let d = self.line_fetch_cycles(line_addr);
            (d, true, false)
        } else {
            match self.llc.access(line_addr, part, write) {
                Access::Hit => (self.timing.llc_hit, false, false),
                Access::Miss { writeback } => {
                    let mut d = self.line_fetch_cycles(line_addr);
                    if writeback {
                        // Victim drains before the fill on the single channel.
                        d += self.line_fetch_cycles(line_addr); // symmetric cost
                    }
                    (d, true, writeback)
                }
            }
        };
        let mut retry_cycles: Cycle = 0;
        if fill {
            self.stats.line_fills += 1;
            // Seeded transient retry: the affected fill re-fetches the
            // line `per_line` times. Strictly less than the analytic
            // inflation (which charges every line), so injection can
            // only keep measured service under the faulted bound.
            if self.fault_retry_every > 0 {
                self.fault_fill_counter += 1;
                if self.fault_fill_counter % self.fault_retry_every == 0 {
                    retry_cycles = self.fault_retries_per_line as Cycle
                        * self.timing.line_retry_cost(self.llc.line_bytes());
                    dur += retry_cycles;
                    self.stats.retries += self.fault_retries_per_line as u64;
                }
            }
        }
        if wb {
            self.stats.writebacks += 1;
        }
        if let Some(tb) = self.trace.as_deref_mut() {
            let cur = self.current.as_ref().unwrap();
            tb.push(TraceEvent {
                at: now,
                domain: Domain::Uncore,
                initiator: cur.burst.initiator,
                target: Some(Target::Hyperram),
                lane: 0,
                tag: cur.burst.tag,
                kind: TraceKind::LineFill {
                    hit: !fill,
                    dirty_victim: wb,
                    retry_cycles,
                    service_cycles: dur,
                    line: line_addr / self.llc.line_bytes(),
                    set: self.llc.set_of(line_addr, part) as u32,
                },
            });
        }
        let cur = self.current.as_mut().unwrap();
        cur.line_done_at = now + dur;
        cur.line_active = true;
    }
}

impl TargetModel for HyperramPath {
    fn target(&self) -> Target {
        Target::Hyperram
    }

    /// DPLLC + HyperBUS belong to the fixed-frequency uncore domain: the
    /// crossbar steps this model on the uncore cycle grid.
    fn domain(&self) -> Domain {
        Domain::Uncore
    }

    fn busy_cycles(&self) -> u64 {
        self.stats.busy_cycles
    }

    fn set_trace(&mut self, buf: TraceBuf) {
        self.trace = buf;
    }

    fn take_trace(&mut self) -> Vec<TraceEvent> {
        match self.trace.as_deref_mut() {
            Some(v) => std::mem::take(v),
            None => Vec::new(),
        }
    }

    /// Two arbitration lanes: the parallel LLC hit port and the channel
    /// command queue. Without the split, continuous hit-port grants
    /// would re-park a shared round-robin pointer and let one initiator
    /// monopolize the command queue (unbounded — and unanalyzable —
    /// queueing delay for everyone else).
    ///
    /// `lane_of` depends on the hit port's occupancy, so when two
    /// all-hit bursts contend in one cycle the loser re-routes to the
    /// queue lane on the *next* grant cycle (one extra cycle, inside the
    /// WCET engine's per-transaction edges budget).
    fn lanes(&self) -> usize {
        2
    }

    fn lane_of(&self, burst: &Burst) -> usize {
        if self.hit_port.is_none() && self.all_hit(burst) {
            1
        } else {
            0
        }
    }

    fn can_accept(&self, burst: &Burst) -> bool {
        if self.hit_port.is_none() && self.all_hit(burst) {
            return true;
        }
        self.queue.len() < self.queue_depth
    }

    fn start(&mut self, burst: Burst, now: Cycle) {
        self.stats.bursts += 1;
        // Fast path: an all-hit burst is served straight from the cache
        // SRAM, in parallel with whatever the channel is doing.
        if self.hit_port.is_none() && self.all_hit(&burst) {
            let (first, n) = self.lines_of(&burst);
            for i in 0..n as u64 {
                let addr = first + i * self.llc.line_bytes();
                let r = self.llc.access(addr, burst.part_id, burst.write);
                debug_assert_eq!(r, Access::Hit);
                // One hit event per line so a capture carries the *full*
                // DPLLC access stream — the working-set profiler's
                // hit-rate denominators depend on it. Lane 1 mirrors the
                // arbitration lane the burst was granted on.
                if let Some(tb) = self.trace.as_deref_mut() {
                    tb.push(TraceEvent {
                        at: now,
                        domain: Domain::Uncore,
                        initiator: burst.initiator,
                        target: Some(Target::Hyperram),
                        lane: 1,
                        tag: burst.tag,
                        kind: TraceKind::LineFill {
                            hit: true,
                            dirty_victim: false,
                            retry_cycles: 0,
                            service_cycles: self.timing.llc_hit,
                            line: addr / self.llc.line_bytes(),
                            set: self.llc.set_of(addr, burst.part_id) as u32,
                        },
                    });
                }
            }
            let done_at = now + self.timing.llc_hit + n as Cycle;
            self.hit_port = Some((burst, done_at));
            return;
        }
        debug_assert!(self.queue.len() < self.queue_depth);
        self.queue.push_back(burst);
    }

    fn tick(&mut self, now: Cycle, done: &mut Vec<Completion>) {
        if !self.idle() {
            self.stats.busy_cycles += 1;
        }
        // Hit port completes independently of the channel.
        if let Some((b, t)) = &self.hit_port {
            if now + 1 >= *t {
                done.push(Completion::of(b, *t));
                self.hit_port = None;
            }
        }
        // Pull the next queued burst into channel service.
        if self.current.is_none() {
            if let Some(burst) = self.queue.pop_front() {
                let (first_line, n_lines) = self.lines_of(&burst);
                self.current = Some(Serving {
                    next_line_addr: first_line,
                    lines_left: n_lines,
                    line_done_at: 0,
                    line_active: false,
                    burst,
                });
                self.schedule_line(now);
            }
        }
        let Some(cur) = self.current.as_mut() else {
            return;
        };
        if cur.line_active && now + 1 >= cur.line_done_at {
            cur.line_active = false;
            cur.lines_left -= 1;
            cur.next_line_addr += self.llc.line_bytes();
            if cur.lines_left == 0 {
                done.push(Completion::of(&cur.burst, now + 1));
                self.current = None;
                return;
            }
        }
        self.schedule_line(now);
    }

    fn idle(&self) -> bool {
        self.current.is_none() && self.queue.is_empty() && self.hit_port.is_none()
    }

    /// The channel's timing is fully deterministic: the next observable
    /// tick is the hit-port completion or the in-flight line's last
    /// cycle (`tick` acts when `now + 1 >= done_at`, i.e. at `done_at -
    /// 1`). Every earlier tick is a no-op, so the window is skippable
    /// with no replay needed.
    fn next_event(&self, now: Cycle) -> Option<Cycle> {
        use super::super::clock::merge_event;
        let mut earliest: Option<Cycle> = None;
        if let Some((_, done_at)) = &self.hit_port {
            earliest = merge_event(earliest, done_at.saturating_sub(1).max(now));
        }
        match &self.current {
            Some(cur) if cur.line_active => {
                earliest = merge_event(earliest, cur.line_done_at.saturating_sub(1).max(now));
            }
            // A current burst with no scheduled line, or a queued burst
            // with the channel free: the very next tick makes progress.
            Some(_) => earliest = merge_event(earliest, now),
            None if !self.queue.is_empty() => earliest = merge_event(earliest, now),
            None => {}
        }
        earliest
    }

    /// Replay the per-cycle busy accounting over a skipped window: the
    /// path's occupancy is constant across a quiescent window (a queued
    /// burst with a free channel wakes the very next cycle, so skipped
    /// windows only ever cover a static in-service or fully-idle state).
    fn fast_forward(&mut self, from: Cycle, to: Cycle) {
        if !self.idle() {
            self.stats.busy_cycles += to - from;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::axi::InitiatorId;

    fn read(addr: u64, beats: u32) -> Burst {
        Burst::read(InitiatorId(0), Target::Hyperram, addr, beats)
    }

    fn run_one(p: &mut HyperramPath, b: Burst, start: Cycle) -> Completion {
        assert!(p.can_accept(&b));
        p.start(b, start);
        let mut done = Vec::new();
        let mut now = start;
        while done.is_empty() {
            p.tick(now, &mut done);
            now += 1;
            assert!(now < start + 1_000_000, "no completion");
        }
        done[0]
    }

    #[test]
    fn worst_case_service_model_brackets_observed_timing() {
        let t = HyperRamTiming::carfield();
        // Single line, worst case: full row open + 8 beats x 2 cycles.
        assert_eq!(t.worst_lines_cost(1, 64, false), 40);
        // Sequential lines amortize the row open: 12 lines span at most
        // 2 rows under worst alignment.
        assert_eq!(t.worst_rows_of(12, 64), 2);
        assert_eq!(t.worst_lines_cost(12, 64, false), 12 * 16 + 2 * 24 + 10 * 8);
        // 32 lines (a 256-beat fragment) span at most 3 rows.
        assert_eq!(t.worst_lines_cost(32, 64, false), 32 * 16 + 3 * 24 + 29 * 8);
        // Dirty victims double the channel time per fill.
        assert_eq!(
            t.worst_lines_cost(1, 64, true),
            40 + t.t_row_miss + t.line_stream_cycles(64)
        );
        // The model upper-bounds the measured single-line fetch (40
        // cycles at most, see cold_line_pays_row_miss_plus_stream).
        let mut p = HyperramPath::carfield();
        let c = run_one(&mut p, read(0, 8).with_tag(1), 0);
        assert!(c.finished_at <= t.worst_lines_cost(1, 64, false) + 2);
    }

    #[test]
    fn hit_port_and_queue_are_separate_lanes() {
        use crate::soc::axi::TargetModel;
        let mut p = HyperramPath::carfield();
        let miss = read(0, 8);
        assert_eq!(p.lanes(), 2);
        assert_eq!(p.lane_of(&miss), 0, "cold burst goes to the queue lane");
        run_one(&mut p, read(0, 8), 0); // warm the line
        let hit = read(0, 8);
        assert_eq!(p.lane_of(&hit), 1, "warm burst rides the hit-port lane");
    }

    #[test]
    fn cold_line_pays_row_miss_plus_stream() {
        let mut p = HyperramPath::carfield();
        let c = run_one(&mut p, read(0, 8).with_tag(1), 0);
        // 24 (row miss) + 8 beats * 2 = 40, +1 completion edge.
        assert!((40..=42).contains(&c.finished_at), "{}", c.finished_at);
        assert_eq!(p.stats.line_fills, 1);
    }

    #[test]
    fn warm_line_hits_llc() {
        let mut p = HyperramPath::carfield();
        run_one(&mut p, read(0, 8), 0);
        let c = run_one(&mut p, read(0, 8).with_tag(2), 1000);
        // LLC hit latency only.
        assert!(c.finished_at - 1000 <= 6, "{}", c.finished_at);
    }

    #[test]
    fn multi_line_burst_fetches_each_line() {
        let mut p = HyperramPath::carfield();
        // 32 beats = 256B = 4 lines.
        let c = run_one(&mut p, read(0, 32), 0);
        assert_eq!(p.stats.line_fills, 4);
        // First line: row miss; next three: row hits (same 1KiB row).
        assert_eq!(p.stats.row_misses, 1);
        assert_eq!(p.stats.row_hits, 3);
        let expect = (24 + 16) + 3 * (8 + 16);
        assert!(
            (c.finished_at as i64 - expect as i64).abs() <= 4,
            "{} vs {expect}",
            c.finished_at
        );
    }

    #[test]
    fn row_crossing_pays_again() {
        let mut p = HyperramPath::carfield();
        run_one(&mut p, read(0, 8), 0);
        let before = p.stats.row_misses;
        run_one(&mut p, read(4096, 8), 1000); // different row
        assert_eq!(p.stats.row_misses, before + 1);
    }

    #[test]
    fn dirty_writeback_doubles_channel_time() {
        let mut p = HyperramPath::carfield();
        // Dirty-fill a line, then evict it by filling 8 more tags of the
        // same set (8 ways).
        let sets = p.llc.sets() as u64;
        let stride = sets * 64;
        run_one(&mut p, Burst::write(InitiatorId(0), Target::Hyperram, 0, 8), 0);
        for w in 1..=8u64 {
            let c0 = 1000 * w;
            let c = run_one(&mut p, read(w * stride, 8), c0);
            if w == 8 {
                // This fill evicted the dirty line: channel time doubled.
                assert!(c.finished_at - c0 > 60, "{}", c.finished_at - c0);
            }
        }
        assert!(p.stats.writebacks >= 1);
    }

    #[test]
    fn controller_queue_admits_then_backpressures() {
        let mut p = HyperramPath::carfield();
        for i in 0..4 {
            let b = read(i * 4096, 8);
            assert!(p.can_accept(&b), "queue slot {i}");
            p.start(b, 0);
        }
        assert!(!p.can_accept(&read(0x10000, 8)), "queue full");
        assert!(!p.idle());
    }

    #[test]
    fn queued_bursts_serve_fifo() {
        let mut p = HyperramPath::carfield();
        for i in 0..3u64 {
            p.start(read(i * 4096, 8).with_tag(i + 1), 0);
        }
        let mut done = Vec::new();
        let mut now = 0;
        while done.len() < 3 && now < 100_000 {
            p.tick(now, &mut done);
            now += 1;
        }
        let tags: Vec<u64> = done.iter().map(|c| c.tag).collect();
        assert_eq!(tags, vec![1, 2, 3]);
    }

    #[test]
    fn injected_retries_slow_fills_but_stay_under_the_inflated_model() {
        let t = HyperRamTiming::carfield();
        let per_retry = t.line_retry_cost(64);
        assert_eq!(per_retry, 24 + 16, "row-miss re-fetch of one line");
        // The inflated service model adds the overhead to every line...
        let inflated = t.with_retry_overhead(2 * per_retry);
        assert_eq!(
            inflated.worst_lines_cost(12, 64, false),
            t.worst_lines_cost(12, 64, false) + 12 * 2 * per_retry
        );
        // ...while the injector hits only every n-th fill: measured
        // completion stays under the inflated bound, above the clean one.
        let mut p = HyperramPath::carfield();
        p.set_fault_retries(1, 2, 0); // every fill, worst phase
        let c = run_one(&mut p, read(0, 8).with_tag(1), 0);
        assert_eq!(p.stats.retries, 2);
        assert!(c.finished_at > 42, "retries must cost channel time");
        assert!(c.finished_at <= inflated.worst_lines_cost(1, 64, false) + 2);
        // Unarmed paths are bit-identical to the fault-free seed.
        let mut q = HyperramPath::carfield();
        let c2 = run_one(&mut q, read(0, 8).with_tag(1), 0);
        assert!((40..=42).contains(&c2.finished_at));
        assert_eq!(q.stats.retries, 0);
    }

    #[test]
    fn trace_records_full_access_stream_with_line_and_set() {
        use crate::trace::armed;
        let mut p = HyperramPath::carfield();
        p.set_trace(armed());
        run_one(&mut p, read(0, 32).with_tag(1), 0); // 4 cold lines
        run_one(&mut p, read(0, 32).with_tag(2), 1000); // all-hit burst
        let ev = p.take_trace();
        let fills: Vec<_> = ev
            .iter()
            .filter_map(|e| match e.kind {
                TraceKind::LineFill { hit, line, set, .. } => Some((hit, line, set, e.lane)),
                _ => None,
            })
            .collect();
        assert_eq!(fills.len(), 8, "4 misses + 4 hit-port hits");
        assert_eq!(fills.iter().filter(|f| !f.0).count(), 4);
        for (i, &(hit, line, set, lane)) in fills.iter().enumerate() {
            assert_eq!(hit, i >= 4, "misses first, then the warm burst");
            assert_eq!(line, (i as u64) % 4, "64B-granular line address");
            assert_eq!(set as usize, p.llc.set_of(line * 64, 0), "cache-model set");
            assert_eq!(lane, if hit { 1 } else { 0 }, "hit port rides lane 1");
        }
    }

    #[test]
    fn bypass_mode_always_streams() {
        let mut p = HyperramPath::carfield();
        p.bypass_llc = true;
        run_one(&mut p, read(0, 8), 0);
        let c = run_one(&mut p, read(0, 8).with_tag(2), 1000);
        assert!(c.finished_at - 1000 >= 20, "no LLC shortcut in bypass");
    }

    #[test]
    fn part_ids_flow_to_llc_stats() {
        let mut p = HyperramPath::new(DpllcConfig::split(0.5), HyperRamTiming::carfield());
        run_one(&mut p, read(0, 8).with_part(1), 0);
        assert_eq!(p.llc.stats[1].misses, 1);
        assert_eq!(p.llc.stats[0].misses, 0);
    }
}

//! Constant-latency peripheral region (UART/SPI/GPIO/... of Fig. 1).
//!
//! Single outstanding transaction, fixed access latency — enough to model
//! register-file style peripheral traffic in the scenarios. The
//! peripheral island sits on the fixed-frequency uncore clock with the
//! HyperBUS PHY: its access latency is a device property, priced in
//! **uncore cycles** and invariant under core DVFS.

use super::super::axi::{Burst, Completion, Target, TargetModel};
use super::super::clock::{Cycle, Domain};

pub struct Peripheral {
    latency: Cycle,
    current: Option<(Burst, Cycle)>,
    pub accesses: u64,
    /// Uncore cycles with a transaction in flight (activity counter).
    pub busy: u64,
}

impl Peripheral {
    /// Register-file access latency (uncore cycles) the coordinator
    /// programs (`Scheduler::targets`, `SocSim::carfield_targets`) —
    /// also the value the WCET engine composes with.
    pub const DEFAULT_LATENCY: Cycle = 20;

    pub fn new(latency: Cycle) -> Self {
        Self {
            latency,
            current: None,
            accesses: 0,
            busy: 0,
        }
    }

    /// WCET service model: fixed access latency plus one uncore cycle
    /// per beat.
    pub fn worst_burst_cycles(&self, beats: u32) -> Cycle {
        self.latency + beats as Cycle
    }
}

impl TargetModel for Peripheral {
    fn target(&self) -> Target {
        Target::Peripheral
    }

    /// The peripheral island shares the fixed uncore clock.
    fn domain(&self) -> Domain {
        Domain::Uncore
    }

    fn busy_cycles(&self) -> u64 {
        self.busy
    }

    fn can_accept(&self, _burst: &Burst) -> bool {
        self.current.is_none()
    }

    fn start(&mut self, burst: Burst, now: Cycle) {
        self.accesses += 1;
        let done_at = now + self.latency + burst.beats as Cycle;
        self.current = Some((burst, done_at));
    }

    fn tick(&mut self, now: Cycle, done: &mut Vec<Completion>) {
        if let Some((b, t)) = &self.current {
            self.busy += 1;
            if now + 1 >= *t {
                done.push(Completion::of(b, *t));
                self.current = None;
            }
        }
    }

    fn idle(&self) -> bool {
        self.current.is_none()
    }

    /// Fixed-latency service: nothing happens until the completion tick.
    fn next_event(&self, now: Cycle) -> Option<Cycle> {
        self.current
            .as_ref()
            .map(|(_, done_at)| done_at.saturating_sub(1).max(now))
    }

    /// Occupancy is static across a quiescent window; replay the
    /// per-cycle busy accounting.
    fn fast_forward(&mut self, from: Cycle, to: Cycle) {
        if self.current.is_some() {
            self.busy += to - from;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::axi::InitiatorId;

    #[test]
    fn wcet_service_model_matches_observed_latency() {
        let mut p = Peripheral::new(Peripheral::DEFAULT_LATENCY);
        assert_eq!(p.worst_burst_cycles(1), 21);
        let b = Burst::read(InitiatorId(0), Target::Peripheral, 0, 1);
        p.start(b, 0);
        let mut done = Vec::new();
        for now in 0..30 {
            p.tick(now, &mut done);
        }
        assert_eq!(done[0].finished_at, p.worst_burst_cycles(1));
    }

    #[test]
    fn fixed_latency_access() {
        let mut p = Peripheral::new(20);
        let b = Burst::read(InitiatorId(0), Target::Peripheral, 0, 1).with_tag(5);
        assert!(p.can_accept(&b));
        p.start(b, 0);
        let mut done = Vec::new();
        for now in 0..30 {
            p.tick(now, &mut done);
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finished_at, 21);
        assert_eq!(p.accesses, 1);
    }

    #[test]
    fn serializes() {
        let mut p = Peripheral::new(5);
        p.start(Burst::read(InitiatorId(0), Target::Peripheral, 0, 1), 0);
        assert!(!p.can_accept(&Burst::read(InitiatorId(1), Target::Peripheral, 0, 1)));
    }
}

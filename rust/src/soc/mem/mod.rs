//! Memory endpoints: the L2 DCSPM, the DPLLC-fronted HyperRAM path and a
//! constant-latency peripheral region.

pub mod dcspm;
pub mod dpllc;
pub mod hyperram;
pub mod peripheral;

pub use dcspm::{Dcspm, DcspmStats, CONTIG_ALIAS_BIT};
pub use dpllc::{Dpllc, DpllcConfig, DpllcStats};
pub use hyperram::{HyperRamTiming, HyperramPath};
pub use peripheral::Peripheral;

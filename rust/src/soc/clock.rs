//! Clock domains and the cycle timebase.
//!
//! The SoC operates across three clock domains, each driven by a
//! dedicated PLL (paper §II): the host/system domain, the vector-cluster
//! domain and the AMR-cluster domain. The simulator steps a single
//! *system* cycle counter; per-domain progress is derived from the
//! domain's frequency ratio against the system clock, which is how the
//! RTL's clock-domain crossings average out at the transaction level.

/// Simulation time in system-clock cycles.
pub type Cycle = u64;

/// Merge a pending event time into an accumulator, keeping the earliest
/// (shared by the event-driven `next_event` implementations).
pub fn merge_event(earliest: Option<Cycle>, t: Cycle) -> Option<Cycle> {
    Some(match earliest {
        None => t,
        Some(e) => e.min(t),
    })
}

/// The three PLL-driven clock domains (paper Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Domain {
    /// Host + interconnect + memory system ("system" clock).
    System,
    /// Dual-RVVU vector cluster.
    Vector,
    /// 12-core AMR integer cluster.
    Amr,
}

/// One clock domain's operating point.
#[derive(Debug, Clone, Copy)]
pub struct ClockDomain {
    pub domain: Domain,
    /// Current frequency in MHz.
    pub freq_mhz: f64,
}

impl ClockDomain {
    pub fn new(domain: Domain, freq_mhz: f64) -> Self {
        assert!(freq_mhz > 0.0, "frequency must be positive");
        Self { domain, freq_mhz }
    }

    /// Convert a cycle count in this domain to nanoseconds.
    pub fn cycles_to_ns(&self, cycles: Cycle) -> f64 {
        cycles as f64 * 1e3 / self.freq_mhz
    }

    /// Convert nanoseconds to (rounded-up) cycles in this domain.
    pub fn ns_to_cycles(&self, ns: f64) -> Cycle {
        (ns * self.freq_mhz / 1e3).ceil() as Cycle
    }

    /// Cycles in *this* domain elapsed while `sys_cycles` system cycles
    /// pass at `sys` — the transaction-level CDC model.
    pub fn from_system(&self, sys_cycles: Cycle, sys: &ClockDomain) -> Cycle {
        (sys_cycles as f64 * self.freq_mhz / sys.freq_mhz).round() as Cycle
    }

    /// System cycles needed to cover `cycles` of this domain.
    pub fn to_system(&self, cycles: Cycle, sys: &ClockDomain) -> Cycle {
        (cycles as f64 * sys.freq_mhz / self.freq_mhz).ceil() as Cycle
    }
}

/// The PLL trio with the paper's nominal frequencies.
#[derive(Debug, Clone, Copy)]
pub struct ClockTree {
    pub system: ClockDomain,
    pub vector: ClockDomain,
    pub amr: ClockDomain,
}

impl ClockTree {
    /// Nominal 0.8V operating point: host 1GHz-class domains scaled per
    /// the paper's corners (CVA6 @ 1GHz max, vector 1GHz max, AMR 900MHz
    /// max at 1.1V; nominal 0.8V runs proportionally lower).
    pub fn nominal() -> Self {
        Self {
            system: ClockDomain::new(Domain::System, 640.0),
            vector: ClockDomain::new(Domain::Vector, 550.0),
            amr: ClockDomain::new(Domain::Amr, 540.0),
        }
    }

    /// Max-performance point (1.1V).
    pub fn max_perf() -> Self {
        Self {
            system: ClockDomain::new(Domain::System, 1000.0),
            vector: ClockDomain::new(Domain::Vector, 1000.0),
            amr: ClockDomain::new(Domain::Amr, 900.0),
        }
    }

    pub fn get(&self, d: Domain) -> &ClockDomain {
        match d {
            Domain::System => &self.system,
            Domain::Vector => &self.vector,
            Domain::Amr => &self.amr,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_event_keeps_earliest() {
        assert_eq!(merge_event(None, 7), Some(7));
        assert_eq!(merge_event(Some(3), 7), Some(3));
        assert_eq!(merge_event(Some(9), 7), Some(7));
    }

    #[test]
    fn ns_round_trip() {
        let d = ClockDomain::new(Domain::System, 1000.0); // 1 GHz -> 1ns/cyc
        assert_eq!(d.cycles_to_ns(1000), 1000.0);
        assert_eq!(d.ns_to_cycles(1000.0), 1000);
    }

    #[test]
    fn cross_domain_scaling() {
        let sys = ClockDomain::new(Domain::System, 1000.0);
        let amr = ClockDomain::new(Domain::Amr, 500.0);
        // 100 system cycles at half frequency = 50 AMR cycles.
        assert_eq!(amr.from_system(100, &sys), 50);
        // 50 AMR cycles need 100 system cycles.
        assert_eq!(amr.to_system(50, &sys), 100);
    }

    #[test]
    fn to_system_rounds_up() {
        let sys = ClockDomain::new(Domain::System, 900.0);
        let amr = ClockDomain::new(Domain::Amr, 700.0);
        let sys_cycles = amr.to_system(100, &sys);
        assert!(sys_cycles as f64 * 700.0 / 900.0 >= 99.999);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_frequency_rejected() {
        ClockDomain::new(Domain::System, 0.0);
    }

    #[test]
    fn nominal_tree_has_all_domains() {
        let t = ClockTree::nominal();
        assert_eq!(t.get(Domain::Vector).domain, Domain::Vector);
        assert!(t.get(Domain::Amr).freq_mhz > 0.0);
    }
}

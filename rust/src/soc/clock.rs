//! Clock domains and the multi-rate timebase.
//!
//! The SoC operates across four clock domains (paper §II): the
//! host/system domain, the vector-cluster domain and the AMR-cluster
//! domain are each driven by a dedicated DVFS-scaled PLL; the **uncore**
//! domain (HyperBUS PHY + HyperRAM memory controller + DPLLC service
//! pipeline, plus the peripheral island) runs on its own fixed-frequency
//! clock, decoupled from the voltage-scaled core domains.
//!
//! The simulator steps a single *system* cycle counter as its master
//! grid. Cluster progress is derived from the domain's frequency ratio
//! against the system clock inside the cluster FSMs (transaction-level
//! CDC averaging). Uncore-domain targets are stepped on their *own*
//! cycle grid by the crossbar: a [`RateConverter`] maps system edges to
//! uncore edges exactly (integer rational arithmetic — no float drift
//! over hundred-million-cycle runs), so with the uncore pinned to the
//! system frequency the conversion is the identity and the seed's
//! single-timebase behaviour is recovered bit-identically.

/// Simulation time in clock cycles of some domain (the master counter
/// `SocSim::now` is in *system* cycles).
pub type Cycle = u64;

/// The paper's fixed uncore frequency in MHz: the HyperBUS PHY and
/// memory subsystem are clocked at the peak system frequency and stay
/// there while the core domains voltage-scale — which is what makes
/// memory service wall-clock-invariant under core DVFS. The
/// single-timebase seed corresponds to the uncore *coupled* to the
/// system clock (ratio 1), which remains the default; decoupling is the
/// explicit opt-in of [`crate::power::OperatingPoint::with_uncore_mhz`].
pub const UNCORE_MHZ: f64 = 1000.0;

/// Merge a pending event time into an accumulator, keeping the earliest
/// (shared by the event-driven `next_event` implementations).
pub fn merge_event(earliest: Option<Cycle>, t: Cycle) -> Option<Cycle> {
    Some(match earliest {
        None => t,
        Some(e) => e.min(t),
    })
}

/// The four clock domains (paper Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Domain {
    /// Host cores + interconnect + TSU shapers ("system" clock).
    System,
    /// Dual-RVVU vector cluster.
    Vector,
    /// 12-core AMR integer cluster.
    Amr,
    /// HyperBUS PHY + HyperRAM controller + DPLLC pipeline + peripheral
    /// island — fixed-frequency, excluded from the DVFS voltage grid.
    Uncore,
}

/// One clock domain's operating point.
#[derive(Debug, Clone, Copy)]
pub struct ClockDomain {
    pub domain: Domain,
    /// Current frequency in MHz.
    pub freq_mhz: f64,
}

impl ClockDomain {
    pub fn new(domain: Domain, freq_mhz: f64) -> Self {
        assert!(freq_mhz > 0.0, "frequency must be positive");
        Self { domain, freq_mhz }
    }

    /// Convert a cycle count in this domain to nanoseconds.
    pub fn cycles_to_ns(&self, cycles: Cycle) -> f64 {
        cycles as f64 * 1e3 / self.freq_mhz
    }

    /// Convert nanoseconds to (rounded-up) cycles in this domain.
    pub fn ns_to_cycles(&self, ns: f64) -> Cycle {
        (ns * self.freq_mhz / 1e3).ceil() as Cycle
    }

    /// Cycles in *this* domain elapsed while `sys_cycles` system cycles
    /// pass at `sys` — the transaction-level CDC model.
    pub fn from_system(&self, sys_cycles: Cycle, sys: &ClockDomain) -> Cycle {
        (sys_cycles as f64 * self.freq_mhz / sys.freq_mhz).round() as Cycle
    }

    /// System cycles needed to cover `cycles` of this domain.
    pub fn to_system(&self, cycles: Cycle, sys: &ClockDomain) -> Cycle {
        (cycles as f64 * sys.freq_mhz / self.freq_mhz).ceil() as Cycle
    }
}

/// Exact edge arithmetic between a local (target-domain) cycle grid and
/// the system master grid: `num / den` is the local-over-system
/// frequency ratio as a reduced integer rational, so repeated
/// conversions can never accumulate float drift and the 1:1 case is the
/// literal identity. The simulator's multi-rate stepping
/// ([`crate::soc::axi::xbar::Crossbar`]) runs every boundary crossing
/// (grant, service, completion, event skip) through one of these.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RateConverter {
    num: u64,
    den: u64,
}

fn gcd(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a.max(1)
}

impl RateConverter {
    /// The identity converter (local grid == system grid) — the seed's
    /// single timebase.
    pub fn lockstep() -> Self {
        Self { num: 1, den: 1 }
    }

    /// Converter for a local domain at `f_local` MHz against the system
    /// clock at `f_sys` MHz. Frequencies are snapped to 1 kHz resolution
    /// before reduction so curve-interpolated values stay exact.
    pub fn new(f_local: f64, f_sys: f64) -> Self {
        assert!(
            f_local > 0.0 && f_sys > 0.0,
            "rate converter needs positive frequencies"
        );
        let num = (f_local * 1e3).round() as u64;
        let den = (f_sys * 1e3).round() as u64;
        assert!(num > 0 && den > 0, "frequency below converter resolution");
        let g = gcd(num, den);
        Self {
            num: num / g,
            den: den / g,
        }
    }

    /// True when the local grid is the system grid (identity).
    pub fn is_lockstep(&self) -> bool {
        self.num == self.den
    }

    /// Local cycles per system cycle (observability / bench metrics).
    pub fn ratio(&self) -> f64 {
        self.num as f64 / self.den as f64
    }

    /// Local edges elapsed strictly before system edge `sys`:
    /// `floor(sys * num / den)`. The local cycles processed during
    /// system step `s` are exactly `local_of(s) .. local_of(s + 1)`.
    pub fn local_of(&self, sys: Cycle) -> Cycle {
        (sys as u128 * self.num as u128 / self.den as u128) as Cycle
    }

    /// The system step during which local cycle `local` is processed:
    /// the unique `s` with `local_of(s) <= local < local_of(s + 1)`.
    pub fn system_step_of(&self, local: Cycle) -> Cycle {
        // local_of(s) <= local  <=>  s * num < (local + 1) * den
        // so the covering step is ceil((local + 1) * den / num) - 1.
        let n = (local as u128 + 1) * self.den as u128;
        (n.div_ceil(self.num as u128) - 1) as Cycle
    }

    /// The system edge at or after local edge `local` — the timestamp a
    /// local-domain event carries once it crosses into the system
    /// domain (identity at lockstep): `ceil(local * den / num)`.
    pub fn to_system_edge(&self, local: Cycle) -> Cycle {
        let n = local as u128 * self.den as u128;
        n.div_ceil(self.num as u128) as Cycle
    }
}

/// The PLL quartet: the three voltage-scaled core-domain PLLs plus the
/// fixed-frequency uncore clock.
#[derive(Debug, Clone, Copy)]
pub struct ClockTree {
    pub system: ClockDomain,
    pub vector: ClockDomain,
    pub amr: ClockDomain,
    /// The uncore (memory-subsystem) clock. Coupled trees pin it to the
    /// system frequency (the seed's single timebase); decoupled trees
    /// park it at a fixed frequency regardless of the system voltage.
    pub uncore: ClockDomain,
}

impl ClockTree {
    /// Derive the PLL trio from the published DVFS curves at per-domain
    /// supply voltages — the single source of truth for every operating
    /// point (the governor's [`OperatingPoint`] builds its tree here).
    /// The uncore clock is *coupled* (pinned to the derived system
    /// frequency); use [`ClockTree::with_uncore_mhz`] to decouple it.
    ///
    /// [`OperatingPoint`]: crate::power::OperatingPoint
    pub fn at_voltages(v_system: f64, v_vector: f64, v_amr: f64) -> Self {
        use crate::soc::power::DvfsCurve;
        let system = ClockDomain::new(Domain::System, DvfsCurve::host().freq_mhz(v_system));
        let uncore = ClockDomain::new(Domain::Uncore, system.freq_mhz);
        Self {
            system,
            uncore,
            vector: ClockDomain::new(Domain::Vector, DvfsCurve::vector().freq_mhz(v_vector)),
            amr: ClockDomain::new(Domain::Amr, DvfsCurve::amr().freq_mhz(v_amr)),
        }
    }

    /// The same tree with the uncore PLL parked at `freq_mhz` (fixed,
    /// independent of the system voltage).
    pub fn with_uncore_mhz(mut self, freq_mhz: f64) -> Self {
        self.uncore = ClockDomain::new(Domain::Uncore, freq_mhz);
        self
    }

    /// Whether the uncore runs on its own grid (decoupled from the
    /// system clock).
    pub fn uncore_decoupled(&self) -> bool {
        self.uncore.freq_mhz != self.system.freq_mhz
    }

    /// Nominal 0.8V operating point, curve-sourced: vector 550MHz and
    /// AMR 540MHz exactly as before; the system domain moves from the
    /// previously hardcoded 640MHz to the host curve's 610MHz at 0.8V
    /// (the old value corresponded to ~0.82V on the published corners —
    /// a documented delta, not a behaviour change: nothing in the
    /// simulator consumed the constant).
    pub fn nominal() -> Self {
        use crate::soc::power::NOMINAL_V;
        Self::at_voltages(NOMINAL_V, NOMINAL_V, NOMINAL_V)
    }

    /// Max-performance point (1.1V): 1000/1000/900MHz, bit-identical to
    /// the previously hardcoded values — now read off the curve corners.
    pub fn max_perf() -> Self {
        use crate::soc::power::MAX_V;
        Self::at_voltages(MAX_V, MAX_V, MAX_V)
    }

    pub fn get(&self, d: Domain) -> &ClockDomain {
        match d {
            Domain::System => &self.system,
            Domain::Vector => &self.vector,
            Domain::Amr => &self.amr,
            Domain::Uncore => &self.uncore,
        }
    }

    /// Domain frequency over system frequency — the `freq_ratio` the
    /// cluster FSMs and the WCET compute bounds both consume (cluster
    /// cycles elapsed per system cycle).
    pub fn ratio_to_system(&self, d: Domain) -> f64 {
        self.get(d).freq_mhz / self.system.freq_mhz
    }

    /// The exact edge converter from `d`'s grid to the system grid.
    pub fn converter(&self, d: Domain) -> RateConverter {
        if self.get(d).freq_mhz == self.system.freq_mhz {
            RateConverter::lockstep()
        } else {
            RateConverter::new(self.get(d).freq_mhz, self.system.freq_mhz)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_event_keeps_earliest() {
        assert_eq!(merge_event(None, 7), Some(7));
        assert_eq!(merge_event(Some(3), 7), Some(3));
        assert_eq!(merge_event(Some(9), 7), Some(7));
    }

    #[test]
    fn ns_round_trip() {
        let d = ClockDomain::new(Domain::System, 1000.0); // 1 GHz -> 1ns/cyc
        assert_eq!(d.cycles_to_ns(1000), 1000.0);
        assert_eq!(d.ns_to_cycles(1000.0), 1000);
    }

    #[test]
    fn cross_domain_scaling() {
        let sys = ClockDomain::new(Domain::System, 1000.0);
        let amr = ClockDomain::new(Domain::Amr, 500.0);
        // 100 system cycles at half frequency = 50 AMR cycles.
        assert_eq!(amr.from_system(100, &sys), 50);
        // 50 AMR cycles need 100 system cycles.
        assert_eq!(amr.to_system(50, &sys), 100);
    }

    #[test]
    fn to_system_rounds_up() {
        let sys = ClockDomain::new(Domain::System, 900.0);
        let amr = ClockDomain::new(Domain::Amr, 700.0);
        let sys_cycles = amr.to_system(100, &sys);
        assert!(sys_cycles as f64 * 700.0 / 900.0 >= 99.999);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_frequency_rejected() {
        ClockDomain::new(Domain::System, 0.0);
    }

    #[test]
    fn nominal_tree_has_all_domains() {
        let t = ClockTree::nominal();
        assert_eq!(t.get(Domain::Vector).domain, Domain::Vector);
        assert!(t.get(Domain::Amr).freq_mhz > 0.0);
        // The default tree couples the uncore to the system clock — the
        // seed's single timebase.
        assert_eq!(t.get(Domain::Uncore).freq_mhz, t.system.freq_mhz);
        assert!(!t.uncore_decoupled());
        assert!(t.converter(Domain::Uncore).is_lockstep());
    }

    #[test]
    fn trees_are_curve_sourced() {
        // Corners read straight off the published DVFS curves: max_perf
        // reproduces the old hardcoded 1000/1000/900 bit-identically;
        // nominal keeps vector 550 / AMR 540 and moves the system domain
        // to the curve's 610MHz @ 0.8V (documented delta from 640).
        let m = ClockTree::max_perf();
        assert_eq!(m.system.freq_mhz, 1000.0);
        assert_eq!(m.vector.freq_mhz, 1000.0);
        assert_eq!(m.amr.freq_mhz, 900.0);
        let n = ClockTree::nominal();
        assert!((n.vector.freq_mhz - 550.0).abs() < 1e-9);
        assert!((n.amr.freq_mhz - 540.0).abs() < 1e-9);
        assert!((n.system.freq_mhz - 610.0).abs() < 1e-9);
    }

    #[test]
    fn ratio_to_system_scales_cluster_progress() {
        let t = ClockTree::max_perf();
        assert_eq!(t.ratio_to_system(Domain::System), 1.0);
        assert_eq!(t.ratio_to_system(Domain::Vector), 1.0);
        assert!((t.ratio_to_system(Domain::Amr) - 0.9).abs() < 1e-12);
        let low = ClockTree::at_voltages(0.6, 0.6, 0.6);
        assert!((low.ratio_to_system(Domain::Vector) - 250.0 / 350.0).abs() < 1e-12);
    }

    #[test]
    fn decoupled_uncore_keeps_its_frequency() {
        let t = ClockTree::at_voltages(0.6, 0.6, 0.6).with_uncore_mhz(UNCORE_MHZ);
        assert!(t.uncore_decoupled());
        assert_eq!(t.uncore.freq_mhz, 1000.0);
        assert!((t.ratio_to_system(Domain::Uncore) - 1000.0 / 350.0).abs() < 1e-12);
    }

    #[test]
    fn rate_converter_identity_at_lockstep() {
        let r = RateConverter::lockstep();
        for s in [0u64, 1, 7, 1_000_000_007] {
            assert_eq!(r.local_of(s), s);
            assert_eq!(r.system_step_of(s), s);
        }
        assert!(r.is_lockstep());
        // Equal frequencies reduce to the identity even when derived
        // from interpolated (non-integer-MHz) values.
        let pinned = RateConverter::new(676.4705882352941, 676.4705882352941);
        assert!(pinned.is_lockstep());
        assert_eq!(pinned.local_of(123_456_789), 123_456_789);
    }

    #[test]
    fn rate_converter_partitions_local_cycles_exactly() {
        // Every local cycle is processed in exactly one system step, for
        // faster and slower local grids alike (including non-integer
        // ratios such as 1000/610).
        for (fl, fs) in [(1000.0, 350.0), (1000.0, 610.0), (350.0, 1000.0), (610.0, 915.0)] {
            let r = RateConverter::new(fl, fs);
            let mut covered: Cycle = 0;
            for s in 0..10_000u64 {
                let lo = r.local_of(s);
                let hi = r.local_of(s + 1);
                assert_eq!(lo, covered, "gap or overlap at step {s} ({fl}/{fs})");
                for l in lo..hi {
                    assert_eq!(r.system_step_of(l), s, "local {l} misplaced ({fl}/{fs})");
                }
                covered = hi;
            }
            // Long-run total matches the exact rational count.
            assert_eq!(r.local_of(10_000), covered);
            let expect = (10_000f64 * fl / fs).floor() as u64;
            assert!(
                (covered as i64 - expect as i64).abs() <= 1,
                "drift: {covered} vs {expect}"
            );
        }
    }

    #[test]
    fn rate_converter_faster_local_grid_counts_multiple_edges() {
        let r = RateConverter::new(1000.0, 500.0); // 2 local edges per step
        assert_eq!(r.local_of(3) - r.local_of(2), 2);
        assert_eq!(r.system_step_of(5), 2);
        let slow = RateConverter::new(500.0, 1000.0); // 1 edge per 2 steps
        assert_eq!(slow.local_of(1) - slow.local_of(0), 0);
        assert_eq!(slow.local_of(2) - slow.local_of(0), 1);
        assert_eq!(slow.system_step_of(0), 1, "local 0 processed in step 1");
    }
}

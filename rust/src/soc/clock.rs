//! Clock domains and the cycle timebase.
//!
//! The SoC operates across three clock domains, each driven by a
//! dedicated PLL (paper §II): the host/system domain, the vector-cluster
//! domain and the AMR-cluster domain. The simulator steps a single
//! *system* cycle counter; per-domain progress is derived from the
//! domain's frequency ratio against the system clock, which is how the
//! RTL's clock-domain crossings average out at the transaction level.

/// Simulation time in system-clock cycles.
pub type Cycle = u64;

/// Merge a pending event time into an accumulator, keeping the earliest
/// (shared by the event-driven `next_event` implementations).
pub fn merge_event(earliest: Option<Cycle>, t: Cycle) -> Option<Cycle> {
    Some(match earliest {
        None => t,
        Some(e) => e.min(t),
    })
}

/// The three PLL-driven clock domains (paper Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Domain {
    /// Host + interconnect + memory system ("system" clock).
    System,
    /// Dual-RVVU vector cluster.
    Vector,
    /// 12-core AMR integer cluster.
    Amr,
}

/// One clock domain's operating point.
#[derive(Debug, Clone, Copy)]
pub struct ClockDomain {
    pub domain: Domain,
    /// Current frequency in MHz.
    pub freq_mhz: f64,
}

impl ClockDomain {
    pub fn new(domain: Domain, freq_mhz: f64) -> Self {
        assert!(freq_mhz > 0.0, "frequency must be positive");
        Self { domain, freq_mhz }
    }

    /// Convert a cycle count in this domain to nanoseconds.
    pub fn cycles_to_ns(&self, cycles: Cycle) -> f64 {
        cycles as f64 * 1e3 / self.freq_mhz
    }

    /// Convert nanoseconds to (rounded-up) cycles in this domain.
    pub fn ns_to_cycles(&self, ns: f64) -> Cycle {
        (ns * self.freq_mhz / 1e3).ceil() as Cycle
    }

    /// Cycles in *this* domain elapsed while `sys_cycles` system cycles
    /// pass at `sys` — the transaction-level CDC model.
    pub fn from_system(&self, sys_cycles: Cycle, sys: &ClockDomain) -> Cycle {
        (sys_cycles as f64 * self.freq_mhz / sys.freq_mhz).round() as Cycle
    }

    /// System cycles needed to cover `cycles` of this domain.
    pub fn to_system(&self, cycles: Cycle, sys: &ClockDomain) -> Cycle {
        (cycles as f64 * sys.freq_mhz / self.freq_mhz).ceil() as Cycle
    }
}

/// The PLL trio with the paper's nominal frequencies.
#[derive(Debug, Clone, Copy)]
pub struct ClockTree {
    pub system: ClockDomain,
    pub vector: ClockDomain,
    pub amr: ClockDomain,
}

impl ClockTree {
    /// Derive the PLL trio from the published DVFS curves at per-domain
    /// supply voltages — the single source of truth for every operating
    /// point (the governor's [`OperatingPoint`] builds its tree here).
    ///
    /// [`OperatingPoint`]: crate::power::OperatingPoint
    pub fn at_voltages(v_system: f64, v_vector: f64, v_amr: f64) -> Self {
        use crate::soc::power::DvfsCurve;
        Self {
            system: ClockDomain::new(Domain::System, DvfsCurve::host().freq_mhz(v_system)),
            vector: ClockDomain::new(Domain::Vector, DvfsCurve::vector().freq_mhz(v_vector)),
            amr: ClockDomain::new(Domain::Amr, DvfsCurve::amr().freq_mhz(v_amr)),
        }
    }

    /// Nominal 0.8V operating point, curve-sourced: vector 550MHz and
    /// AMR 540MHz exactly as before; the system domain moves from the
    /// previously hardcoded 640MHz to the host curve's 610MHz at 0.8V
    /// (the old value corresponded to ~0.82V on the published corners —
    /// a documented delta, not a behaviour change: nothing in the
    /// simulator consumed the constant).
    pub fn nominal() -> Self {
        use crate::soc::power::NOMINAL_V;
        Self::at_voltages(NOMINAL_V, NOMINAL_V, NOMINAL_V)
    }

    /// Max-performance point (1.1V): 1000/1000/900MHz, bit-identical to
    /// the previously hardcoded values — now read off the curve corners.
    pub fn max_perf() -> Self {
        use crate::soc::power::MAX_V;
        Self::at_voltages(MAX_V, MAX_V, MAX_V)
    }

    pub fn get(&self, d: Domain) -> &ClockDomain {
        match d {
            Domain::System => &self.system,
            Domain::Vector => &self.vector,
            Domain::Amr => &self.amr,
        }
    }

    /// Domain frequency over system frequency — the `freq_ratio` the
    /// cluster FSMs and the WCET compute bounds both consume (cluster
    /// cycles elapsed per system cycle).
    pub fn ratio_to_system(&self, d: Domain) -> f64 {
        self.get(d).freq_mhz / self.system.freq_mhz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_event_keeps_earliest() {
        assert_eq!(merge_event(None, 7), Some(7));
        assert_eq!(merge_event(Some(3), 7), Some(3));
        assert_eq!(merge_event(Some(9), 7), Some(7));
    }

    #[test]
    fn ns_round_trip() {
        let d = ClockDomain::new(Domain::System, 1000.0); // 1 GHz -> 1ns/cyc
        assert_eq!(d.cycles_to_ns(1000), 1000.0);
        assert_eq!(d.ns_to_cycles(1000.0), 1000);
    }

    #[test]
    fn cross_domain_scaling() {
        let sys = ClockDomain::new(Domain::System, 1000.0);
        let amr = ClockDomain::new(Domain::Amr, 500.0);
        // 100 system cycles at half frequency = 50 AMR cycles.
        assert_eq!(amr.from_system(100, &sys), 50);
        // 50 AMR cycles need 100 system cycles.
        assert_eq!(amr.to_system(50, &sys), 100);
    }

    #[test]
    fn to_system_rounds_up() {
        let sys = ClockDomain::new(Domain::System, 900.0);
        let amr = ClockDomain::new(Domain::Amr, 700.0);
        let sys_cycles = amr.to_system(100, &sys);
        assert!(sys_cycles as f64 * 700.0 / 900.0 >= 99.999);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_frequency_rejected() {
        ClockDomain::new(Domain::System, 0.0);
    }

    #[test]
    fn nominal_tree_has_all_domains() {
        let t = ClockTree::nominal();
        assert_eq!(t.get(Domain::Vector).domain, Domain::Vector);
        assert!(t.get(Domain::Amr).freq_mhz > 0.0);
    }

    #[test]
    fn trees_are_curve_sourced() {
        // Corners read straight off the published DVFS curves: max_perf
        // reproduces the old hardcoded 1000/1000/900 bit-identically;
        // nominal keeps vector 550 / AMR 540 and moves the system domain
        // to the curve's 610MHz @ 0.8V (documented delta from 640).
        let m = ClockTree::max_perf();
        assert_eq!(m.system.freq_mhz, 1000.0);
        assert_eq!(m.vector.freq_mhz, 1000.0);
        assert_eq!(m.amr.freq_mhz, 900.0);
        let n = ClockTree::nominal();
        assert!((n.vector.freq_mhz - 550.0).abs() < 1e-9);
        assert!((n.amr.freq_mhz - 540.0).abs() < 1e-9);
        assert!((n.system.freq_mhz - 610.0).abs() < 1e-9);
    }

    #[test]
    fn ratio_to_system_scales_cluster_progress() {
        let t = ClockTree::max_perf();
        assert_eq!(t.ratio_to_system(Domain::System), 1.0);
        assert_eq!(t.ratio_to_system(Domain::Vector), 1.0);
        assert!((t.ratio_to_system(Domain::Amr) - 0.9).abs() < 1e-12);
        let low = ClockTree::at_voltages(0.6, 0.6, 0.6);
        assert!((low.ratio_to_system(Domain::Vector) - 250.0 / 350.0).abs() < 1e-12);
    }
}

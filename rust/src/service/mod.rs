//! Admission as a service: the sharded bound-aware packing pipeline.
//!
//! The paper's predictability machinery makes the *analytic* admission
//! test (`Scheduler::admit`) cost microseconds while a validating
//! simulation costs milliseconds — a ~100x asymmetry. This module
//! turns that asymmetry into a service: a seeded queue of 10^5–10^6
//! scenario requests is admitted, packed into co-resident mixes,
//! governed to energy-minimal operating points, and confirmed by one
//! batched simulation sweep — with the expensive stages bounded to
//! deterministic prefixes and the cheap analytic stage doing all the
//! heavy lifting.
//!
//! - [`request`] — seeded request synthesis: `wcet::fuzz` mixes
//!   profiled solo and stamped with bound-derived cycle deadlines.
//! - [`pack`] — the [`PackHeuristic`] race: first-fit-decreasing on
//!   demand vs best-fit on the binding resource's slack, both layered
//!   over a scalar pre-filter plus the exact admission probe (with an
//!   optional budget-capped autotune rescue for rejected merges).
//! - [`pipeline`] — fixed-size batches fanned across worker threads
//!   with an order-preserving merge (bit-identical at any shard
//!   count), then the capped govern stage (shared
//!   [`UtilizationLibrary`](crate::power::UtilizationLibrary) — repeat
//!   shapes skip the measurement sweep) and the single batched
//!   validation sweep.
//!
//! `experiments::packing` / `carfield pack` / `make pack` drive the
//! pipeline and gate its invariants; `tests/packing_determinism.rs`
//! pins shard- and step-mode-invariance; the `packing` section of
//! `BENCH_perf_hotpath.json` tracks sustained admissions/sec and
//! heuristic win-rates at depth 10^5 and 10^6.

pub mod pack;
pub mod pipeline;
pub mod request;

pub use pack::{BestFitSlack, FirstFitDecreasing, PackConfig, PackHeuristic, PackStats};
pub use pipeline::{
    run, GovernedMix, PackedMix, ServiceConfig, ServiceReport, ValidationRow,
};
pub use request::{synthesize, ScenarioRequest};

//! Bound-aware bin packing: co-residency under the analytic admission
//! test.
//!
//! A *bin* is a set of requests proposed to run co-resident as one
//! merged scenario (tasks renamed `r{id}.{name}`, one initiator slot
//! each — placement is physical, so bins cap at
//! [`PackConfig::max_members`] requests). Feasibility is layered:
//!
//! 1. **Scalar pre-filter** — the sum of member demands must stay
//!    under [`PackConfig::demand_cap`]; a bin that fails never costs
//!    an exact probe.
//! 2. **Exact probe** — `Scheduler::admit` on the merged scenario is
//!    the authoritative oracle: interference is recomputed for the
//!    *combined* mix, so a filter-passing candidate can still be
//!    rejected (and a rejection can optionally be *rescued* by a
//!    budget-capped [`Autotuner`] pass that searches for a stronger
//!    isolation tuning admitting the merged mix).
//!
//! Two heuristics race behind the [`PackHeuristic`] trait:
//! first-fit-decreasing on demand, and best-fit on the binding
//! resource's slack (tightest post-insertion [`min_slack`] wins).
//! Both are deterministic; the racer keeps whichever packed the batch
//! into fewer mixes (ties go to first-fit-decreasing) and records
//! whether they disagreed on the assignment at all.

use crate::coordinator::{AdmissionDecision, Autotuner, Scenario, Scheduler, SocTuning};
use crate::wcet::{min_slack, Resource};

use super::request::ScenarioRequest;

/// Knobs for one packing pass.
#[derive(Debug, Clone)]
pub struct PackConfig {
    /// Hard cap on co-resident requests per mix (each request task
    /// occupies one physical initiator slot).
    pub max_members: usize,
    /// Scalar pre-filter: candidate bins whose demand sum would exceed
    /// this skip the exact probe outright.
    pub demand_cap: f64,
    /// Best-fit probe window: how many filter-passing open bins the
    /// slack heuristic admit-probes per request.
    pub probe_window: usize,
    /// Autotune evaluation budget for rescuing a rejected merged probe
    /// (0 disables rescue — the bench's high-depth setting).
    pub rescue_evaluations: u64,
    /// Rescue attempts per heuristic per batch (bounds worst-case
    /// packing latency; the first N rejected probes get the tuner).
    pub rescue_attempts: u64,
}

impl Default for PackConfig {
    fn default() -> Self {
        Self {
            max_members: 4,
            demand_cap: 1.0,
            probe_window: 4,
            rescue_evaluations: 0,
            rescue_attempts: 8,
        }
    }
}

/// Aggregate probe accounting across a packing pass (summed over both
/// racing heuristics). Pure counters — deterministic for a fixed
/// request stream.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PackStats {
    /// Exact `Scheduler::admit` probes issued.
    pub probes: u64,
    /// Candidate bins the scalar demand filter discarded (probes
    /// avoided).
    pub filtered: u64,
    /// Probes the exact test rejected (the filter's false positives).
    pub rejected: u64,
    /// Rescue passes attempted on rejected probes.
    pub rescues: u64,
    /// Rescue passes that found an admitting tuning.
    pub rescued: u64,
}

impl PackStats {
    pub fn add(&mut self, other: &PackStats) {
        self.probes += other.probes;
        self.filtered += other.filtered;
        self.rejected += other.rejected;
        self.rescues += other.rescues;
        self.rescued += other.rescued;
    }
}

/// One packed bin: members (batch-local request indices), the tuning
/// the merged mix is admitted under, and the admitting decision.
#[derive(Debug, Clone)]
pub struct Bin {
    pub members: Vec<usize>,
    /// Sum of member demands (the filter's running scalar).
    pub demand: f64,
    pub tuning: SocTuning,
    /// The admitting decision at `(members, tuning)`.
    pub decision: AdmissionDecision,
    /// Tightest per-task slack in the merged mix (`i64::MAX` when no
    /// member task carries a deadline).
    pub min_slack: i64,
    /// Binding resource of the min-slack task.
    pub binding: Resource,
    /// Whether a budgeted autotune pass re-tuned this bin.
    pub rescued: bool,
}

/// Build the merged co-residency scenario for `members` under
/// `tuning`: every member task joins, renamed `r{request id}.{name}`
/// so reports and bounds stay attributable per request.
pub fn merge(
    name: &str,
    requests: &[ScenarioRequest],
    members: &[usize],
    tuning: SocTuning,
) -> Scenario {
    let mut s = Scenario::new(name, tuning);
    for &m in members {
        let req = &requests[m];
        for task in &req.scenario.tasks {
            let mut t = task.clone();
            t.name = format!("r{}.{}", req.id, t.name);
            s.tasks.push(t);
        }
    }
    s
}

fn bin_from(
    requests: &[ScenarioRequest],
    members: Vec<usize>,
    merged: &Scenario,
    tuning: SocTuning,
    decision: AdmissionDecision,
    rescued: bool,
) -> Bin {
    let demand = members.iter().map(|&m| requests[m].demand).sum();
    // Deadlines live on the merged tasks and no operating point is
    // pinned, so the slack probe is tuning-independent — the merged
    // scenario from the admission probe serves even when a rescue
    // changed the tuning.
    let (min_slack, binding) = match min_slack(merged, &decision.report) {
        Some(p) => (p.slack, p.binding),
        None => (i64::MAX, Resource::Compute),
    };
    Bin {
        members,
        demand,
        tuning,
        decision,
        min_slack,
        binding,
        rescued,
    }
}

/// Exact-probe a request into a bin: merge, admit, optionally rescue.
/// Returns the grown bin on success.
fn try_fit(
    requests: &[ScenarioRequest],
    bin: &Bin,
    req_idx: usize,
    cfg: &PackConfig,
    stats: &mut PackStats,
    rescue_left: &mut u64,
) -> Option<Bin> {
    let mut members = bin.members.clone();
    members.push(req_idx);
    let probe = merge("pack-probe", requests, &members, bin.tuning);
    stats.probes += 1;
    let decision = Scheduler::admit(&probe);
    if decision.admitted {
        return Some(bin_from(
            requests,
            members,
            &probe,
            bin.tuning,
            decision,
            bin.rescued,
        ));
    }
    stats.rejected += 1;
    if cfg.rescue_evaluations > 0 && *rescue_left > 0 {
        *rescue_left -= 1;
        stats.rescues += 1;
        let tuner = Autotuner::budgeted(cfg.rescue_evaluations);
        if let Ok(outcome) = tuner.tune(&probe) {
            stats.rescued += 1;
            return Some(bin_from(
                requests,
                members,
                &probe,
                outcome.tuning,
                outcome.decision,
                true,
            ));
        }
    }
    None
}

/// Open a fresh bin holding only `req_idx` at the request's own
/// tuning. Admitted by construction: the request's deadlines were
/// stamped from its solo bounds with headroom >= 1.2, and renaming
/// tasks changes nothing the bound engine reads.
fn singleton(requests: &[ScenarioRequest], req_idx: usize, stats: &mut PackStats) -> Bin {
    let members = vec![req_idx];
    let tuning = requests[req_idx].scenario.tuning;
    let probe = merge("singleton-probe", requests, &members, tuning);
    stats.probes += 1;
    let decision = Scheduler::admit(&probe);
    debug_assert!(
        decision.admitted,
        "solo-admissible request rejected as a singleton: {}",
        decision.summary()
    );
    bin_from(requests, members, &probe, tuning, decision, false)
}

/// A deterministic packing heuristic over one batch of requests.
pub trait PackHeuristic: Sync {
    fn name(&self) -> &'static str;
    fn pack(
        &self,
        requests: &[ScenarioRequest],
        cfg: &PackConfig,
        stats: &mut PackStats,
    ) -> Vec<Bin>;
}

/// Classical first-fit-decreasing on demand: requests sorted by
/// descending demand (ties broken by queue position — a total,
/// deterministic order), each placed into the first open bin that
/// passes the filter and the exact probe.
pub struct FirstFitDecreasing;

impl FirstFitDecreasing {
    pub const NAME: &'static str = "first-fit-decreasing";
}

impl PackHeuristic for FirstFitDecreasing {
    fn name(&self) -> &'static str {
        Self::NAME
    }

    fn pack(
        &self,
        requests: &[ScenarioRequest],
        cfg: &PackConfig,
        stats: &mut PackStats,
    ) -> Vec<Bin> {
        let mut order: Vec<usize> = (0..requests.len()).collect();
        order.sort_by(|&a, &b| {
            requests[b]
                .demand
                .total_cmp(&requests[a].demand)
                .then(a.cmp(&b))
        });
        let mut bins: Vec<Bin> = Vec::new();
        let mut rescue_left = cfg.rescue_attempts;
        for &i in &order {
            let d = requests[i].demand;
            let mut placed = false;
            for bin in bins.iter_mut() {
                if bin.members.len() >= cfg.max_members {
                    continue;
                }
                if bin.demand + d > cfg.demand_cap {
                    stats.filtered += 1;
                    continue;
                }
                if let Some(grown) = try_fit(requests, bin, i, cfg, stats, &mut rescue_left) {
                    *bin = grown;
                    placed = true;
                    break;
                }
            }
            if !placed {
                bins.push(singleton(requests, i, stats));
            }
        }
        bins
    }
}

/// Best-fit on the binding resource's slack: requests in queue order,
/// each probed against up to [`PackConfig::probe_window`]
/// filter-passing open bins; the admitting bin with the *tightest*
/// post-insertion [`min_slack`] wins (ties go to the lowest bin
/// index). Packing tight-first keeps slack-rich bins open for the
/// requests that actually need them.
pub struct BestFitSlack;

impl BestFitSlack {
    pub const NAME: &'static str = "best-fit-slack";
}

impl PackHeuristic for BestFitSlack {
    fn name(&self) -> &'static str {
        Self::NAME
    }

    fn pack(
        &self,
        requests: &[ScenarioRequest],
        cfg: &PackConfig,
        stats: &mut PackStats,
    ) -> Vec<Bin> {
        let mut bins: Vec<Bin> = Vec::new();
        let mut rescue_left = cfg.rescue_attempts;
        for i in 0..requests.len() {
            let d = requests[i].demand;
            let mut best: Option<(usize, Bin)> = None;
            let mut probed = 0usize;
            for (b, bin) in bins.iter().enumerate() {
                if probed >= cfg.probe_window {
                    break;
                }
                if bin.members.len() >= cfg.max_members {
                    continue;
                }
                if bin.demand + d > cfg.demand_cap {
                    stats.filtered += 1;
                    continue;
                }
                probed += 1;
                if let Some(grown) = try_fit(requests, bin, i, cfg, stats, &mut rescue_left) {
                    let tighter = best
                        .as_ref()
                        .map(|(_, cur)| grown.min_slack < cur.min_slack)
                        .unwrap_or(true);
                    if tighter {
                        best = Some((b, grown));
                    }
                }
            }
            match best {
                Some((b, grown)) => bins[b] = grown,
                None => bins.push(singleton(requests, i, stats)),
            }
        }
        bins
    }
}

/// Outcome of racing the two heuristics over one batch.
#[derive(Debug, Clone)]
pub struct RaceOutcome {
    /// The winning packing (fewer mixes; ties keep first-fit).
    pub bins: Vec<Bin>,
    pub winner: &'static str,
    pub ffd_bins: usize,
    pub slack_bins: usize,
    /// The canonical assignments differed (strict wins included).
    pub disagreed: bool,
    pub stats: PackStats,
}

/// Canonical assignment form for disagreement detection: per-bin
/// member id sets, order-normalized, so two packings compare equal
/// exactly when they co-locate the same requests.
fn canonical(bins: &[Bin], requests: &[ScenarioRequest]) -> Vec<Vec<u64>> {
    let mut shape: Vec<Vec<u64>> = bins
        .iter()
        .map(|b| {
            let mut ids: Vec<u64> = b.members.iter().map(|&m| requests[m].id).collect();
            ids.sort_unstable();
            ids
        })
        .collect();
    shape.sort();
    shape
}

/// Race both heuristics over one batch and keep the better packing.
pub fn race(requests: &[ScenarioRequest], cfg: &PackConfig) -> RaceOutcome {
    let mut stats = PackStats::default();
    let ffd = FirstFitDecreasing.pack(requests, cfg, &mut stats);
    let slack = BestFitSlack.pack(requests, cfg, &mut stats);
    let disagreed = canonical(&ffd, requests) != canonical(&slack, requests);
    let (ffd_bins, slack_bins) = (ffd.len(), slack.len());
    let (bins, winner) = if slack_bins < ffd_bins {
        (slack, BestFitSlack::NAME)
    } else {
        (ffd, FirstFitDecreasing::NAME)
    };
    RaceOutcome {
        bins,
        winner,
        ffd_bins,
        slack_bins,
        disagreed,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::request::synthesize;

    fn batch(n: u64, base: u64) -> Vec<ScenarioRequest> {
        (0..n).map(|i| synthesize(i, base + i)).collect()
    }

    #[test]
    fn merge_renames_and_preserves_deadlines() {
        let reqs = batch(2, 11);
        let tuning = reqs[0].scenario.tuning;
        let merged = merge("m", &reqs, &[0, 1], tuning);
        let expected: usize = reqs.iter().map(|r| r.scenario.tasks.len()).sum();
        assert_eq!(merged.tasks.len(), expected);
        for (r, req) in reqs.iter().enumerate() {
            for task in &req.scenario.tasks {
                let name = format!("r{}.{}", req.id, task.name);
                let t = merged
                    .tasks
                    .iter()
                    .find(|t| t.name == name)
                    .unwrap_or_else(|| panic!("missing {name} (request {r})"));
                assert_eq!(t.deadline, task.deadline);
            }
        }
    }

    #[test]
    fn heuristics_pack_every_request_exactly_once() {
        let reqs = batch(12, 101);
        let cfg = PackConfig::default();
        for h in [&FirstFitDecreasing as &dyn PackHeuristic, &BestFitSlack] {
            let mut stats = PackStats::default();
            let bins = h.pack(&reqs, &cfg, &mut stats);
            let mut seen: Vec<usize> = bins.iter().flat_map(|b| b.members.clone()).collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..reqs.len()).collect::<Vec<_>>(), "{}", h.name());
            for b in &bins {
                assert!(b.decision.admitted, "{}: unadmitted bin", h.name());
                assert!(b.min_slack >= 0, "{}: negative slack packed", h.name());
                assert!(b.members.len() <= cfg.max_members);
            }
            assert!(stats.probes > 0);
        }
    }

    #[test]
    fn race_is_deterministic() {
        let reqs = batch(10, 777);
        let cfg = PackConfig::default();
        let a = race(&reqs, &cfg);
        let b = race(&reqs, &cfg);
        assert_eq!(canonical(&a.bins, &reqs), canonical(&b.bins, &reqs));
        assert_eq!(a.winner, b.winner);
        assert_eq!(a.stats, b.stats);
        assert!(a.bins.len() <= reqs.len());
    }
}

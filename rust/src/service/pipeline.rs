//! The sharded admission-and-packing pipeline.
//!
//! Stage layout (deterministic end to end):
//!
//! 1. **Shard + pack** — the queue is cut into fixed-size batches
//!    ([`ServiceConfig::batch`] requests, *independent of the thread
//!    count*: bins never span a batch boundary, so the work
//!    decomposition is a function of the queue alone). Batches fan out
//!    over `sweep::parallel_map`, each synthesizing its requests,
//!    racing the two packing heuristics and returning packed mixes.
//!    The order-preserving merge then assigns global mix ids — results
//!    are bit-identical at any shard count.
//! 2. **Govern** — the first [`ServiceConfig::govern_cap`] mixes run
//!    through `Governor::govern_certified_with` against one shared
//!    [`UtilizationLibrary`], so repeated mix shapes skip the
//!    measurement sweep. Sequential by design: the library is shared
//!    state, and a deterministic prefix beats a nondeterministic
//!    everything.
//! 3. **Validate** — the first [`ServiceConfig::validate_cap`] mixes
//!    (at their governed points when stage 2 covered them) are
//!    confirmed by **one** batched `sweep::run_scenarios_mode` call:
//!    every measured makespan must sit within its analytic bound and
//!    every deadline must hold.
//!
//! Caps are deterministic prefixes and are reported loudly (mix
//! counts, capped counts) — never silent. Memory is bounded at depth
//! 10^6 by generating requests inside their batch (dropped after
//! packing) and retaining merged scenarios only for the mixes the
//! govern/validate prefixes can reach.

use crate::coordinator::sweep;
use crate::coordinator::{Scenario, SocTuning, StepMode};
use crate::power::governor::Governor;
use crate::power::{OperatingPoint, UtilizationLibrary};
use crate::soc::clock::Cycle;
use crate::wcet::Resource;

use super::pack::{self, PackConfig, PackStats};
use super::request::{self, ScenarioRequest};

/// Domain separation for the hot-shape pool draws.
const HOT_SALT: u64 = 0x707_5EED_0000_0001;

/// Pipeline configuration. The default is the bench's high-depth
/// shape: full packing, govern/validate prefixes on, rescue off.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Queue depth: how many seeded requests to admit and pack.
    pub depth: usize,
    /// Base seed for the whole queue (request seeds derive from it).
    pub seed: u64,
    /// Worker threads for the pack fan-out and the validation sweep.
    /// Results are bit-identical at any value.
    pub threads: usize,
    /// Batch size (requests per shard unit). Fixed relative to the
    /// queue — NOT derived from `threads` — so the packing work
    /// decomposition, and therefore every result, is thread-invariant.
    pub batch: usize,
    /// 1-in-N requests re-draw their seed from the hot-shape pool
    /// (0 disables): the repeat-customer traffic that makes the
    /// governor's certificate library earn its keep.
    pub hot_rate: u64,
    /// Number of distinct hot shapes.
    pub hot_pool: u64,
    /// Govern the first N merged mixes (0 skips the stage).
    pub govern_cap: usize,
    /// Validate the first N merged mixes with the batched sweep
    /// (0 skips the stage).
    pub validate_cap: usize,
    /// Stepping core for the validation sweep (all three are
    /// bit-identical; pick on wall clock).
    pub mode: StepMode,
    pub pack: PackConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            depth: 100_000,
            seed: 1,
            threads: sweep::default_threads(),
            batch: 256,
            hot_rate: 4,
            hot_pool: 8,
            govern_cap: 32,
            validate_cap: 64,
            mode: StepMode::default(),
            pack: PackConfig::default(),
        }
    }
}

/// splitmix64 finalizer — the per-request seed mixer (the repo's
/// `XorShift` is a *stream* generator; this is a pure hash so request
/// `id` can be mapped to a seed on any thread without shared state).
fn mix64(seed: u64, x: u64) -> u64 {
    let mut z = seed ^ x.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The fuzz seed for request `id`: unique per id, except that roughly
/// 1-in-`hot_rate` requests re-draw from the `hot_pool` shapes.
pub fn request_seed(cfg: &ServiceConfig, id: u64) -> u64 {
    let z = mix64(cfg.seed, id);
    if cfg.hot_rate > 0 && z % cfg.hot_rate == 0 {
        mix64(cfg.seed ^ HOT_SALT, z % cfg.hot_pool.max(1))
    } else {
        z
    }
}

/// One packed co-residency mix after the global merge.
#[derive(Debug, Clone)]
pub struct PackedMix {
    /// Global mix id (queue order; stable across shard counts).
    pub id: usize,
    /// Member request ids.
    pub members: Vec<u64>,
    /// Sum of member demands.
    pub demand: f64,
    /// The tuning the merged mix is admitted under.
    pub tuning: SocTuning,
    /// Tightest per-task admission slack (cycles).
    pub min_slack: i64,
    /// Binding resource of the min-slack task.
    pub binding: Resource,
    pub rescued: bool,
    /// Per deadline task: (merged name, completion bound, deadline) in
    /// cycles at the mix tuning — the soundness ledger.
    pub checks: Vec<(String, Cycle, Cycle)>,
    /// The merged scenario, retained only for mixes the govern or
    /// validate prefix can reach (memory stays bounded at depth 10^6).
    pub scenario: Option<Scenario>,
}

/// One governed mix: the lowest common operating point the certified
/// governor found for the merged co-residency scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct GovernedMix {
    pub mix: usize,
    pub op: OperatingPoint,
    pub tuning: SocTuning,
    /// Modeled energy saved vs the max-performance baseline.
    pub saved_pct: Option<f64>,
    /// The certificate library answered the shape lookup (measurement
    /// sweep skipped).
    pub from_library: bool,
    /// Every shipped point simulation-confirmed inside the certified
    /// flow.
    pub confirmed: bool,
    /// Per deadline task: (name, bound at the governed clocks,
    /// deadline) in system cycles.
    pub bounds: Vec<(String, Cycle, Cycle)>,
}

/// One row of the batched validation sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct ValidationRow {
    pub mix: usize,
    /// Ran at the governed (tuning, op) rather than as packed.
    pub governed: bool,
    /// Per bounded task: (name, measured makespan, completion bound).
    pub checks: Vec<(String, Cycle, Cycle)>,
    /// Every measured makespan within its analytic bound.
    pub sound: bool,
    pub deadlines_met: bool,
}

/// What one batch hands back to the merge.
struct BatchPack {
    mixes: Vec<PackedMix>,
    ffd_bins: usize,
    slack_bins: usize,
    disagreed: bool,
    stats: PackStats,
}

/// The pipeline's full, deterministic output (every field is a pure
/// function of the config — wall clock never leaks in).
#[derive(Debug, Clone)]
pub struct ServiceReport {
    pub depth: usize,
    pub seed: u64,
    pub batches: usize,
    pub mode: StepMode,
    pub mixes: Vec<PackedMix>,
    pub stats: PackStats,
    /// Batches first-fit-decreasing packed strictly tighter.
    pub ffd_wins: u64,
    /// Batches best-fit-on-slack packed strictly tighter.
    pub slack_wins: u64,
    /// Batches with equal mix counts.
    pub ties: u64,
    /// Batches where the two assignments differed at all.
    pub disagreements: u64,
    pub governed: Vec<GovernedMix>,
    /// Mixes the governor could not place (no deadline, or exhausted).
    pub govern_failures: u64,
    pub library_hits: u64,
    pub library_misses: u64,
    pub library_len: usize,
    pub validations: Vec<ValidationRow>,
}

impl ServiceReport {
    pub fn packed(&self) -> usize {
        self.mixes.len()
    }

    /// Requests per packed mix (>= 1.0; higher = tighter packing).
    pub fn packing_ratio(&self) -> f64 {
        self.depth as f64 / self.mixes.len().max(1) as f64
    }

    /// Mixes holding more than one request (the packer's actual wins).
    pub fn multi_request_mixes(&self) -> usize {
        self.mixes.iter().filter(|m| m.members.len() > 1).count()
    }

    /// Every packed mix analytically admitted: non-negative slack and
    /// every per-task bound within its deadline.
    pub fn all_admitted(&self) -> bool {
        self.mixes.iter().all(|m| {
            m.min_slack >= 0 && m.checks.iter().all(|(_, bound, deadline)| bound <= deadline)
        })
    }

    /// Every validation row sound with deadlines met (vacuously true
    /// with `validate_cap = 0`; gate on `validations.len()` too).
    pub fn validation_sound(&self) -> bool {
        self.validations.iter().all(|v| v.sound && v.deadlines_met)
    }

    /// Canonical packed assignment (member-id sets per mix, in queue
    /// order) — the shard-invariance test's comparison key.
    pub fn assignments(&self) -> Vec<Vec<u64>> {
        self.mixes.iter().map(|m| m.members.clone()).collect()
    }

    pub fn disagreement_rate(&self) -> f64 {
        self.disagreements as f64 / self.batches.max(1) as f64
    }

    pub fn library_hit_rate(&self) -> f64 {
        let total = self.library_hits + self.library_misses;
        if total == 0 {
            0.0
        } else {
            self.library_hits as f64 / total as f64
        }
    }
}

/// Pack one batch: synthesize its requests, race the heuristics, and
/// strip the working bins down to [`PackedMix`]es (merged scenarios
/// retained only when `keep_scenarios`).
fn pack_batch(cfg: &ServiceConfig, batch_idx: usize, keep_scenarios: bool) -> BatchPack {
    let lo = batch_idx * cfg.batch;
    let hi = ((batch_idx + 1) * cfg.batch).min(cfg.depth);
    let requests: Vec<ScenarioRequest> = (lo..hi)
        .map(|id| request::synthesize(id as u64, request_seed(cfg, id as u64)))
        .collect();
    let outcome = pack::race(&requests, &cfg.pack);
    let mixes = outcome
        .bins
        .into_iter()
        .map(|bin| {
            let members: Vec<u64> = bin.members.iter().map(|&m| requests[m].id).collect();
            // Soundness ledger: merged-name deadlines vs the admitting
            // decision's bounds (cycle currency — no operating point).
            let mut checks = Vec::new();
            for &m in &bin.members {
                let req = &requests[m];
                for (task, _, deadline) in &req.checks {
                    let name = format!("r{}.{}", req.id, task);
                    let bound = bin
                        .decision
                        .report
                        .bound_for(&name)
                        .completion_cycles(None)
                        .expect("admitted deadline task has a completion bound");
                    checks.push((name, bound, *deadline));
                }
            }
            let scenario = keep_scenarios
                .then(|| pack::merge("mix", &requests, &bin.members, bin.tuning));
            PackedMix {
                id: usize::MAX, // assigned at the global merge
                members,
                demand: bin.demand,
                tuning: bin.tuning,
                min_slack: bin.min_slack,
                binding: bin.binding,
                rescued: bin.rescued,
                checks,
                scenario,
            }
        })
        .collect();
    BatchPack {
        mixes,
        ffd_bins: outcome.ffd_bins,
        slack_bins: outcome.slack_bins,
        disagreed: outcome.disagreed,
        stats: outcome.stats,
    }
}

/// Run the full pipeline. Deterministic: for a fixed config (any
/// `threads`, any `mode`) the report's packed assignments, governed
/// points and validation rows are bit-identical.
pub fn run(cfg: &ServiceConfig) -> ServiceReport {
    let batch = cfg.batch.max(1);
    let n_batches = cfg.depth.div_ceil(batch);
    let keep_needed = cfg.govern_cap.max(cfg.validate_cap);
    // A batch of B requests yields at least B / max_members mixes, so
    // batch k's first global mix id is >= k * that floor — batches
    // past the govern/validate horizon provably never need their
    // merged scenarios (conservative: extra batches may keep them).
    let min_mixes_per_batch = (batch / cfg.pack.max_members.max(1)).max(1);
    let batch_ids: Vec<usize> = (0..n_batches).collect();
    let packs: Vec<BatchPack> = sweep::parallel_map(&batch_ids, cfg.threads, |&k| {
        pack_batch(cfg, k, k * min_mixes_per_batch < keep_needed)
    });

    // Deterministic order-preserving merge: global mix ids in batch
    // order, scenarios dropped past the prefix horizon.
    let mut mixes: Vec<PackedMix> = Vec::new();
    let mut stats = PackStats::default();
    let (mut ffd_wins, mut slack_wins, mut ties, mut disagreements) = (0u64, 0u64, 0u64, 0u64);
    for bp in packs {
        stats.add(&bp.stats);
        if bp.slack_bins < bp.ffd_bins {
            slack_wins += 1;
        } else if bp.ffd_bins < bp.slack_bins {
            ffd_wins += 1;
        } else {
            ties += 1;
        }
        if bp.disagreed {
            disagreements += 1;
        }
        for mut mix in bp.mixes {
            mix.id = mixes.len();
            if mix.id >= keep_needed {
                mix.scenario = None;
            } else if let Some(s) = mix.scenario.as_mut() {
                s.name = format!("mix-{}", mix.id);
            }
            mixes.push(mix);
        }
    }

    // Stage 2: govern the prefix against one shared certificate
    // library (sequential — deterministic library state).
    let governor = Governor::default();
    let mut library = UtilizationLibrary::new();
    let mut governed: Vec<GovernedMix> = Vec::new();
    let mut govern_failures = 0u64;
    for mix in mixes.iter().take(cfg.govern_cap) {
        let Some(s) = &mix.scenario else { break };
        let hits_before = library.hits;
        match governor.govern_certified_with(s, &mut library) {
            Ok(c) => {
                let choice = &c.certified;
                let clocks = choice.op.clock_tree();
                let mut bounds = Vec::new();
                for (task, _, deadline) in &mix.checks {
                    if let Some(b) = choice
                        .decision
                        .report
                        .bound_for(task)
                        .completion_cycles(Some(&clocks))
                    {
                        bounds.push((task.clone(), b, *deadline));
                    }
                }
                governed.push(GovernedMix {
                    mix: mix.id,
                    op: choice.op,
                    tuning: choice.tuning,
                    saved_pct: choice.energy_saved_pct(),
                    from_library: library.hits > hits_before,
                    confirmed: c.confirmed(),
                    bounds,
                });
            }
            Err(_) => govern_failures += 1,
        }
    }

    // Stage 3: one batched validation sweep over the prefix, governed
    // mixes at their governed (tuning, op).
    struct ValidationJob {
        mix: usize,
        governed: bool,
        scenario: Scenario,
        bounds: Vec<(String, Cycle, Cycle)>,
    }
    let mut jobs: Vec<ValidationJob> = Vec::new();
    for mix in mixes.iter().take(cfg.validate_cap) {
        let Some(s) = &mix.scenario else { break };
        let job = match governed.iter().find(|g| g.mix == mix.id) {
            Some(g) => ValidationJob {
                mix: mix.id,
                governed: true,
                scenario: s.clone().with_tuning(g.tuning).with_op_point(g.op),
                bounds: g.bounds.clone(),
            },
            None => ValidationJob {
                mix: mix.id,
                governed: false,
                scenario: s.clone(),
                bounds: mix.checks.clone(),
            },
        };
        jobs.push(job);
    }
    let scenarios: Vec<Scenario> = jobs.iter().map(|j| j.scenario.clone()).collect();
    let reports = sweep::run_scenarios_mode(&scenarios, cfg.threads, cfg.mode);
    let validations: Vec<ValidationRow> = jobs
        .iter()
        .zip(&reports)
        .map(|(job, report)| {
            let mut sound = true;
            let mut checks = Vec::new();
            for (task, bound, _) in &job.bounds {
                let t = report.task(task);
                sound &= t.makespan > 0 && t.makespan <= *bound;
                checks.push((task.clone(), t.makespan, *bound));
            }
            ValidationRow {
                mix: job.mix,
                governed: job.governed,
                checks,
                sound,
                deadlines_met: report.all_deadlines_met(),
            }
        })
        .collect();

    ServiceReport {
        depth: cfg.depth,
        seed: cfg.seed,
        batches: n_batches,
        mode: cfg.mode,
        mixes,
        stats,
        ffd_wins,
        slack_wins,
        ties,
        disagreements,
        governed,
        govern_failures,
        library_hits: library.hits,
        library_misses: library.misses,
        library_len: library.len(),
        validations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(depth: usize, threads: usize) -> ServiceConfig {
        ServiceConfig {
            depth,
            seed: 5,
            threads,
            batch: 16,
            govern_cap: 0,
            validate_cap: 4,
            ..ServiceConfig::default()
        }
    }

    #[test]
    fn hot_pool_repeats_shapes() {
        let cfg = ServiceConfig::default();
        let seeds: std::collections::BTreeSet<u64> =
            (0..256u64).map(|id| request_seed(&cfg, id)).collect();
        assert!(
            seeds.len() < 256,
            "hot pool produced no repeated request shapes"
        );
        // And the cold majority stays diverse.
        assert!(seeds.len() > 128, "only {} distinct shapes", seeds.len());
    }

    #[test]
    fn pipeline_packs_governs_and_validates() {
        let cfg = ServiceConfig {
            govern_cap: 1,
            ..tiny(48, 2)
        };
        let r = run(&cfg);
        assert_eq!(r.batches, 3);
        let packed_requests: usize = r.mixes.iter().map(|m| m.members.len()).sum();
        assert_eq!(packed_requests, 48, "every request packed exactly once");
        assert!(r.packed() <= 48);
        assert!(r.all_admitted(), "an inadmissible mix was packed");
        assert_eq!(r.validations.len(), 4);
        assert!(r.validation_sound(), "{:?}", r.validations);
        assert!(r.governed.len() + r.govern_failures as usize == 1);
        if let Some(g) = r.governed.first() {
            assert!(g.confirmed, "governed point not simulation-confirmed");
            assert!(r.validations.iter().any(|v| v.mix == g.mix && v.governed));
        }
    }

    #[test]
    fn scenarios_kept_only_for_the_prefix() {
        let r = run(&tiny(64, 1));
        let keep = 4usize; // max(govern_cap, validate_cap)
        for m in &r.mixes {
            if m.id >= keep {
                assert!(m.scenario.is_none(), "mix {} kept its scenario", m.id);
            }
        }
        assert!(
            r.mixes.iter().take(keep).all(|m| m.scenario.is_some()),
            "prefix mixes must keep their scenarios"
        );
    }
}

//! Scenario-request synthesis: the admission service's seeded input
//! stream.
//!
//! Each request wraps one `wcet::fuzz` scenario, profiled exactly once
//! by the analytic bound engine at its own tuning ("solo"), and
//! stamped with *cycle* deadlines derived from the solo completion
//! bounds — deadline = ceil(bound x headroom) with headroom drawn in
//! [1.2, 4.0) from a domain-separated RNG, so every request is
//! admissible alone by construction and its *demand* (the largest
//! bound/deadline fraction across its deadline tasks) spans roughly
//! [0.25, 0.83]. Demand is the scalar the packing pre-filter sums;
//! the binding resource of the dominant task is what the slack
//! heuristic bins on.
//!
//! Everything is a pure function of `(id, seed)`: the same pair yields
//! the same request on any thread, which is what makes the sharded
//! pipeline's results bit-identical at any shard count.

use crate::coordinator::Scenario;
use crate::soc::clock::Cycle;
use crate::util::XorShift;
use crate::wcet::{self, Resource};

/// Domain separation for the deadline-headroom draws, mirroring
/// `wcet::fuzz::random_fault_plan`: stamping deadlines never perturbs
/// the scenario generator's own stream.
const HEADROOM_SALT: u64 = 0xDEAD_11E5_0000_0001;

/// One admission request: a fuzzed mix profiled solo and stamped with
/// bound-derived cycle deadlines.
#[derive(Debug, Clone)]
pub struct ScenarioRequest {
    /// Global queue position (stable across shard counts).
    pub id: u64,
    /// The `wcet::fuzz` seed the mix was generated from.
    pub seed: u64,
    /// The deadline-stamped scenario (original task names; the packer
    /// renames on merge).
    pub scenario: Scenario,
    /// max over deadline tasks of solo bound / deadline, in (0, 1] —
    /// 0.0 for the rare mix whose critical tasks are all unbounded
    /// (they then carry no deadline and constrain nothing).
    pub demand: f64,
    /// Binding resource of the dominant (max-demand) task.
    pub binding: Resource,
    /// Per deadline task: (name, solo completion bound, deadline) in
    /// cycles at the request's own tuning.
    pub checks: Vec<(String, Cycle, Cycle)>,
}

/// Synthesize the deterministic request for `(id, seed)`: generate the
/// fuzz mix, bound it once, stamp deadlines.
pub fn synthesize(id: u64, seed: u64) -> ScenarioRequest {
    let mut scenario = wcet::fuzz::random_scenario(seed);
    scenario.name = format!("req-{id}");
    let report = wcet::analyze(&scenario);
    let mut headroom_rng = XorShift::new(seed ^ HEADROOM_SALT);
    let mut demand = 0.0f64;
    let mut binding = Resource::Compute;
    let mut checks = Vec::new();
    for task in &mut scenario.tasks {
        if !task.criticality.is_time_critical() {
            continue;
        }
        let b = report.bound_for(&task.name);
        // One headroom draw per *critical* task (bounded or not), so
        // the draw order is a function of the mix shape alone.
        let headroom = 1.2 + 2.8 * headroom_rng.unit_f64();
        let Some(bound) = b.completion_cycles(None) else {
            continue;
        };
        let deadline = ((bound as f64 * headroom).ceil() as Cycle).max(bound);
        task.deadline = deadline;
        let d = bound as f64 / deadline as f64;
        if d > demand {
            demand = d;
            binding = b.completion_binding;
        }
        checks.push((task.name.clone(), bound, deadline));
    }
    ScenarioRequest {
        id,
        seed,
        scenario,
        demand,
        binding,
        checks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Scheduler;

    #[test]
    fn synthesis_is_deterministic_and_solo_admissible() {
        for seed in 1..24u64 {
            let a = synthesize(7, seed);
            let b = synthesize(7, seed);
            assert_eq!(a.checks, b.checks, "seed {seed} not deterministic");
            assert_eq!(a.demand, b.demand);
            assert!((0.0..=1.0).contains(&a.demand), "demand {}", a.demand);
            for (task, bound, deadline) in &a.checks {
                assert!(bound <= deadline, "{task}: {bound} > {deadline}");
            }
            // Deadlines were derived from the solo bounds, so the
            // request alone must pass the admission test.
            let d = Scheduler::admit(&a.scenario);
            assert!(d.admitted, "seed {seed}: {}", d.summary());
        }
    }

    #[test]
    fn most_requests_carry_deadlines() {
        let stamped = (1..64u64)
            .filter(|&s| !synthesize(s, s).checks.is_empty())
            .count();
        assert!(stamped >= 48, "only {stamped}/63 requests have deadlines");
    }
}

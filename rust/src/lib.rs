//! Carfield-sim: a cycle-approximate, three-layer reproduction of the
//! Carfield SoC — "A Reliable, Time-Predictable Heterogeneous SoC for
//! AI-Enhanced Mixed-Criticality Edge Applications".
//!
//! Layering:
//! - **L3 (this crate)**: the mixed-criticality coordinator plus every
//!   hardware substrate the paper depends on, modelled cycle-approximately
//!   in Rust: AXI4 interconnect, traffic shaper (TSU), partitionable LLC
//!   (DPLLC), configurable L2 scratchpad (DCSPM), HyperRAM, DMA engines,
//!   host/safe/secure domains, the AMR reliability cluster and the vector
//!   cluster.
//! - **L2/L1 (build-time Python)**: JAX model + Pallas kernels, AOT-lowered
//!   to HLO text in `artifacts/`, loaded and executed at runtime through
//!   the XLA PJRT CPU client (`runtime` module). Python is never on the
//!   simulated request path.
//!
//! Execution model (three stepping cores, bit-identical by construction):
//! - **Naive stepping** — `SocSim::step` ticks every initiator, TSU and
//!   target each system cycle; the cycle-accurate reference.
//! - **Event-driven stepping** — the second oracle. Every component
//!   exposes `next_event(now)` (TSU release times, HyperRAM line edges,
//!   compute-FSM completion times, ...); when the crossbar is idle,
//!   `SocSim::step_fast` jumps `now` straight to the earliest pending
//!   event and replays per-cycle counters through `fast_forward` hooks.
//!   `tests/event_driven_equivalence.rs` asserts bit-identical
//!   `ScenarioReport`s against naive stepping, and
//!   `SocSim::validate_skips` cross-checks every skip window at runtime.
//! - **Wheel stepping** — the promoted default for `Scheduler::run`,
//!   the sweeps and every experiment driver: a structure-of-arrays
//!   event wheel whose per-cycle work touches only fired slots and
//!   whose completion-delivery path replays lazily through the same
//!   arrays. Debug builds cross-check every `Scheduler::run` against
//!   the event-driven oracle; `tests/wheel_equivalence.rs` pins the
//!   three-way matrix in release.
//! - **Parallel sweeps** — `coordinator::sweep` fans independent
//!   scenario grids (Fig. 3c/5/6a/6b) across `std::thread::scope`
//!   workers, order-preserving and deterministic (`CARFIELD_THREADS`
//!   pins the worker count).
//! - **Analytical WCET bounds** — the `wcet` module derives per-task
//!   upper bounds on memory latency and completion time *without
//!   simulating* (TSU arrival curves x crossbar arbitration x per-target
//!   worst-case service models); `Scheduler::admit` turns them into
//!   bound-aware admission control, and `experiments::bounds` /
//!   `carfield wcet` validate bound-vs-measured on the Fig. 6 grids.
//! - **Bound-driven auto-tuning** — `coordinator::policy::SocTuning`
//!   opens the isolation registers (TSU knobs, DPLLC partition split,
//!   DCSPM aliasing) into a searchable space with the legacy four-policy
//!   ladder as named points; `coordinator::autotune` searches it on a
//!   rejected admission (coordinate descent over the binding resource's
//!   knob, coarse-lattice fallback) for the least-restrictive tuning
//!   whose bounds admit the mix — `experiments::autotune` / `carfield
//!   autotune` compare mixes-admitted against the fixed ladder and
//!   validate every winner with one simulation.
//! - **Bound-driven DVFS** — `power::OperatingPoint` carries per-domain
//!   supply voltages whose clock trees derive from the published
//!   `DvfsCurve`s; scenarios carry an optional operating point (the
//!   timebase refactor: cluster compute scales by the PLL ratio in both
//!   the simulator and the WCET compute bounds, deadlines become
//!   expressible in nanoseconds and convert through the point's system
//!   clock). `power::governor` searches the (operating point x tuning)
//!   product — autotune re-run per voltage candidate — for the
//!   energy-minimal pair whose recomputed bounds meet every deadline
//!   inside the 1.2W envelope; `experiments::energy` / `carfield dvfs`
//!   sweep the Fig. 6 deadline grids through it.
//!
//! - **Interference tracing** — the `trace` module arms
//!   zero-cost-when-disabled event hooks at every shared-resource
//!   decision point (TSU releases, crossbar grants/W-holds, HyperRAM
//!   line fills, DCSPM bank conflicts, AMR fault recoveries) and folds
//!   them into a per-task interference ledger keyed by the WCET
//!   `Resource` axis; `carfield trace` prints measured-vs-bound *gap
//!   attribution* per Fig. 6a row and exports JSONL + Perfetto sinks.
//!
//! - **Working-set certificates** — line-fill events carry line/set
//!   address tags, so `trace::profiles_of` folds a capture into
//!   per-task occupancy profiles (per-set fills re-summing exactly to
//!   the observed total) with an exclusive-partition replay fit curve;
//!   `PartitionCertificate`s minted from the curve unlock the WCET
//!   engine's certificate-backed warm bounds (`analyze_certified`) and
//!   the autotuner's parked `tct_sets` axis (`autotune_certified`);
//!   `carfield workingset` demos the admission flip no cold bound can
//!   produce, validated by one partitioned simulation.
//!
//! - **Admission as a service** — the `service` module turns the
//!   admit-vs-simulate cost asymmetry (microseconds vs milliseconds)
//!   into a high-throughput pipeline: seeded scenario requests are
//!   packed into co-resident mixes under the analytic admission test
//!   (first-fit-decreasing racing best-fit on the binding resource's
//!   slack, behind a common `PackHeuristic` trait), each packed mix is
//!   governed to its lowest common operating point through the
//!   `UtilizationLibrary` certificate store, and the packed schedules
//!   are confirmed by one batched wheel sweep. Sharded across worker
//!   threads with an order-preserving merge — results are bit-identical
//!   at any shard count (`tests/packing_determinism.rs`); `carfield
//!   pack` / `make pack` drive it at 10^4–10^6 queue depths.
//!
//! Perf target (tracked by `make bench` → `BENCH_perf_hotpath.json`):
//! >= 60 simulated Mcyc/s on the Fig. 6a TCT+DMA topology via the
//! event-driven path (>= 3x the naive 20 Mcyc/s target it replaces).
//! The `tracing_overhead` bench section gates the disabled-tracing path
//! at >= 95% of that throughput.

pub mod coordinator;
pub mod experiments;
pub mod power;
pub mod runtime;
pub mod service;
pub mod soc;
pub mod trace;
pub mod util;
pub mod wcet;

pub use runtime::ArtifactRuntime;

//! Carfield-sim: a cycle-approximate, three-layer reproduction of the
//! Carfield SoC — "A Reliable, Time-Predictable Heterogeneous SoC for
//! AI-Enhanced Mixed-Criticality Edge Applications".
//!
//! Layering:
//! - **L3 (this crate)**: the mixed-criticality coordinator plus every
//!   hardware substrate the paper depends on, modelled cycle-approximately
//!   in Rust: AXI4 interconnect, traffic shaper (TSU), partitionable LLC
//!   (DPLLC), configurable L2 scratchpad (DCSPM), HyperRAM, DMA engines,
//!   host/safe/secure domains, the AMR reliability cluster and the vector
//!   cluster.
//! - **L2/L1 (build-time Python)**: JAX model + Pallas kernels, AOT-lowered
//!   to HLO text in `artifacts/`, loaded and executed at runtime through
//!   the XLA PJRT CPU client (`runtime` module). Python is never on the
//!   simulated request path.

pub mod coordinator;
pub mod experiments;
pub mod runtime;
pub mod soc;
pub mod util;

pub use runtime::ArtifactRuntime;

//! Latency/jitter statistics for the interference experiments.
//!
//! The paper reports task latency, *jitter* and cache-miss counts
//! (Fig. 6); `Summary` collects per-iteration samples and derives the
//! usual aggregates.

/// Streaming sample collector with percentile support.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
            / self.samples.len() as f64)
            .sqrt()
    }

    /// Max - min: the paper's "jitter" for TCT latency.
    pub fn jitter(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.max() - self.min()
    }

    /// Linear-interpolated percentile, `q` in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            self.sorted = true;
        }
        let pos = q / 100.0 * (self.samples.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.samples[lo]
        } else {
            let frac = pos - lo as f64;
            self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
        }
    }
}

/// Geometric mean of ratios — used in the comparison tables.
pub fn geomean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    (values.iter().map(|v| v.ln()).sum::<f64>() / values.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled() -> Summary {
        let mut s = Summary::new();
        for v in [4.0, 1.0, 3.0, 2.0, 5.0] {
            s.push(v);
        }
        s
    }

    #[test]
    fn mean_min_max() {
        let s = filled();
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.jitter(), 4.0);
    }

    #[test]
    fn std_population() {
        let s = filled();
        assert!((s.std() - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let mut s = filled();
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(50.0), 3.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.percentile(25.0), 2.0);
    }

    #[test]
    fn empty_is_safe() {
        let mut s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert!(s.is_empty());
    }

    #[test]
    fn geomean_of_ratios() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }
}

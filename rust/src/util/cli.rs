//! Minimal CLI argument parser (`--key value` / `--flag` / positionals).
//!
//! Replaces `clap` (unavailable in the offline vendor set) for the
//! coordinator binary and the example drivers.

use std::collections::HashMap;

/// Parsed command line: subcommand, `--key value` options, bare flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable) — skips argv[0].
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if let Some(eq) = key.find('=') {
                    out.options
                        .insert(key[..eq].to_string(), key[eq + 1..].to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let val = iter.next().unwrap();
                    out.options.insert(key.to_string(), val);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process command line.
    pub fn from_env() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed option with default; panics with a clear message on junk.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => default,
            Some(raw) => raw
                .parse()
                .unwrap_or_else(|e| panic!("--{name}={raw}: {e}")),
        }
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("fig6a --voltage 0.8 --mode dlm run");
        assert_eq!(a.subcommand(), Some("fig6a"));
        assert_eq!(a.get("voltage"), Some("0.8"));
        assert_eq!(a.get("mode"), Some("dlm"));
        assert_eq!(a.positional, vec!["fig6a", "run"]);
    }

    #[test]
    fn flags_without_values() {
        let a = parse("bench --verbose --json");
        assert!(a.flag("verbose"));
        assert!(a.flag("json"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("--period=1024 --budget=64");
        assert_eq!(a.get_parse::<u64>("period", 0), 1024);
        assert_eq!(a.get_parse::<u64>("budget", 0), 64);
    }

    #[test]
    fn typed_defaults() {
        let a = parse("run");
        assert_eq!(a.get_parse::<f64>("voltage", 0.8), 0.8);
        assert_eq!(a.get_or("mode", "indip"), "indip");
    }

    #[test]
    #[should_panic(expected = "--n=abc")]
    fn junk_panics() {
        let a = parse("--n abc");
        let _ = a.get_parse::<u32>("n", 0);
    }
}

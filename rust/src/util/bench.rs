//! Minimal `cargo bench` harness (criterion is unavailable offline).
//!
//! Every bench target is `harness = false` and uses [`BenchRunner`] to
//! time named sections with warmup + repeated samples, printing
//! mean/min/max wall-clock per iteration plus any domain metrics the
//! experiment reports.
//!
//! Perf trajectory: when the `CARFIELD_BENCH_JSON` environment variable
//! names a file, `finish()` additionally writes every timed section and
//! metric there as JSON (hand-rolled — no serde offline), so CI can
//! track numbers like the simulator's Mcyc/s across PRs (`make bench`
//! records `BENCH_perf_hotpath.json` at the repo root).

use std::time::Instant;

/// Timing collector for one bench binary.
pub struct BenchRunner {
    pub name: &'static str,
    results: Vec<(String, f64, f64, f64, usize)>,
    metrics: Vec<(String, f64, String)>,
}

impl BenchRunner {
    pub fn new(name: &'static str) -> Self {
        println!("\n### bench: {name}");
        Self {
            name,
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// Time `f` over `iters` iterations (after 1 warmup); returns the
    /// last iteration's output.
    pub fn time<T>(&mut self, label: &str, iters: usize, mut f: impl FnMut() -> T) -> T {
        let mut out = f(); // warmup (also primes caches/compilation)
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            out = f();
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = samples.iter().cloned().fold(0.0f64, f64::max);
        println!("{label:<44} {mean:>10.3} ms/iter (min {min:.3}, max {max:.3}, n={iters})");
        self.results.push((label.to_string(), mean, min, max, iters));
        out
    }

    /// Like [`BenchRunner::time`], additionally returning the mean
    /// wall-clock seconds per iteration of the section just timed — for
    /// derived throughput metrics (Mcyc/s, speedups) without callers
    /// re-measuring with their own `Instant`.
    pub fn time_with_mean<T>(
        &mut self,
        label: &str,
        iters: usize,
        f: impl FnMut() -> T,
    ) -> (T, f64) {
        let out = self.time(label, iters, f);
        let mean_ms = self.results.last().map(|r| r.1).unwrap_or(0.0);
        (out, mean_ms / 1e3)
    }

    /// Report a derived scalar metric (throughput, factor, ...).
    pub fn metric(&mut self, label: &str, value: f64, unit: &str) {
        println!("{label:<44} {value:>10.3} {unit}");
        self.metrics
            .push((label.to_string(), value, unit.to_string()));
    }

    /// Render everything recorded so far as a JSON document.
    fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        fn num(v: f64) -> String {
            if v.is_finite() {
                format!("{v}")
            } else {
                "null".to_string()
            }
        }
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", esc(self.name)));
        out.push_str("  \"sections\": [\n");
        for (i, (label, mean, min, max, iters)) in self.results.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"label\": \"{}\", \"mean_ms\": {}, \"min_ms\": {}, \"max_ms\": {}, \"iters\": {}}}{}\n",
                esc(label),
                num(*mean),
                num(*min),
                num(*max),
                iters,
                if i + 1 < self.results.len() { "," } else { "" }
            ));
        }
        out.push_str("  ],\n  \"metrics\": [\n");
        for (i, (label, value, unit)) in self.metrics.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"label\": \"{}\", \"value\": {}, \"unit\": \"{}\"}}{}\n",
                esc(label),
                num(*value),
                esc(unit),
                if i + 1 < self.metrics.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    pub fn finish(self) {
        if let Ok(path) = std::env::var("CARFIELD_BENCH_JSON") {
            if !path.is_empty() {
                match std::fs::write(&path, self.to_json()) {
                    Ok(()) => println!("bench results written to {path}"),
                    Err(e) => eprintln!("could not write {path}: {e}"),
                }
            }
        }
        println!(
            "### bench {}: {} sections, {} metrics",
            self.name,
            self.results.len(),
            self.metrics.len()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_returns_output_and_records() {
        let mut b = BenchRunner::new("self-test");
        let v = b.time("square", 3, || 7 * 7);
        assert_eq!(v, 49);
        assert_eq!(b.results.len(), 1);
        b.metric("meaning", 42.0, "units");
        b.finish();
    }

    #[test]
    fn json_is_well_formed_enough() {
        let mut b = BenchRunner::new("json-test");
        b.time("noop", 1, || ());
        b.metric("simulated cycles/sec", 61.5, "Mcyc/s (target >= 60)");
        let j = b.to_json();
        assert!(j.contains("\"bench\": \"json-test\""));
        assert!(j.contains("\"label\": \"noop\""));
        assert!(j.contains("\"unit\": \"Mcyc/s (target >= 60)\""));
        // Balanced braces/brackets (cheap structural sanity check).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }
}

//! Minimal `cargo bench` harness (criterion is unavailable offline).
//!
//! Every bench target is `harness = false` and uses [`BenchRunner`] to
//! time named sections with warmup + repeated samples, printing
//! mean/min/max wall-clock per iteration plus any domain metrics the
//! experiment reports.

use std::time::Instant;

/// Timing collector for one bench binary.
pub struct BenchRunner {
    pub name: &'static str,
    results: Vec<(String, f64, f64, f64, usize)>,
}

impl BenchRunner {
    pub fn new(name: &'static str) -> Self {
        println!("\n### bench: {name}");
        Self {
            name,
            results: Vec::new(),
        }
    }

    /// Time `f` over `iters` iterations (after 1 warmup); returns the
    /// last iteration's output.
    pub fn time<T>(&mut self, label: &str, iters: usize, mut f: impl FnMut() -> T) -> T {
        let mut out = f(); // warmup (also primes caches/compilation)
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            out = f();
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = samples.iter().cloned().fold(0.0f64, f64::max);
        println!("{label:<44} {mean:>10.3} ms/iter (min {min:.3}, max {max:.3}, n={iters})");
        self.results.push((label.to_string(), mean, min, max, iters));
        out
    }

    /// Report a derived scalar metric (throughput, factor, ...).
    pub fn metric(&self, label: &str, value: f64, unit: &str) {
        println!("{label:<44} {value:>10.3} {unit}");
    }

    pub fn finish(self) {
        println!("### bench {}: {} sections", self.name, self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_returns_output_and_records() {
        let mut b = BenchRunner::new("self-test");
        let v = b.time("square", 3, || 7 * 7);
        assert_eq!(v, 49);
        assert_eq!(b.results.len(), 1);
        b.metric("meaning", 42.0, "units");
        b.finish();
    }
}

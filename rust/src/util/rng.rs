//! Deterministic xorshift64* PRNG.
//!
//! Used for fault injection, workload jitter and property-style test
//! sweeps. Deterministic seeding keeps every experiment reproducible —
//! a hard requirement for a time-predictability paper's artifact.

/// xorshift64* generator (Vigna). Not cryptographic; plenty for
/// simulation stimulus.
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    /// Create from a non-zero seed (zero is remapped).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)`; `bound` must be > 0.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift bounded sampling (Lemire); bias is negligible for
        // simulation purposes.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli event with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn in_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f32 in `[-scale, scale)` — stimulus for functional runs.
    pub fn symmetric_f32(&mut self, scale: f32) -> f32 {
        (self.unit_f64() as f32 * 2.0 - 1.0) * scale
    }

    /// Fill a buffer with symmetric values (e.g. artifact inputs).
    pub fn fill_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.symmetric_f32(scale)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_remapped() {
        let mut r = XorShift::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = XorShift::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn unit_in_range() {
        let mut r = XorShift::new(9);
        for _ in 0..10_000 {
            let v = r.unit_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn chance_rate_reasonable() {
        let mut r = XorShift::new(11);
        let hits = (0..100_000).filter(|_| r.chance(0.25)).count();
        assert!((23_000..27_000).contains(&hits), "{hits}");
    }

    #[test]
    fn in_range_inclusive() {
        let mut r = XorShift::new(13);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.in_range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn fill_f32_bounded() {
        let mut r = XorShift::new(15);
        for v in r.fill_f32(1000, 8.0) {
            assert!(v >= -8.0 && v < 8.0);
        }
    }
}

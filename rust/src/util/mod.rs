//! Small self-contained utilities: deterministic PRNG, statistics, and a
//! tiny CLI argument parser.
//!
//! The offline vendored crate set has no `rand`, `clap`, `criterion` or
//! `proptest`, so these hand-rolled equivalents back the fault-injection
//! campaigns, the property-style tests and the bench harness (documented
//! in DESIGN.md "Substitutions").

pub mod bench;
pub mod cli;
pub mod rng;
pub mod stats;

pub use rng::XorShift;
pub use stats::Summary;

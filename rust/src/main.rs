//! `carfield` — CLI for the Carfield-sim reproduction.
//!
//! Subcommands:
//! - `boot`                — run the secure-boot chain and report timing;
//! - `fig3c|fig5|fig6a|fig6b|fig7|fig8|micro`
//!                         — regenerate a figure/table of the paper;
//! - `wcet`                — analytical WCET bounds vs measured worst
//!                           case on the fig6a/fig6b grids, plus a
//!                           bound-aware admission demo
//!                           (`--threads N` pins the sweep width);
//! - `autotune`            — bound-driven tuning-space search: mixes
//!                           admitted by the fixed four-policy ladder vs
//!                           the auto-tuner (`--deadline N` tunes the
//!                           fig6a reference mix for one deadline and
//!                           prints the winner + validating simulation);
//! - `dvfs`                — bound-driven DVFS governor: the fig6a/fig6b
//!                           deadline grids searched for energy-minimal
//!                           provably-safe operating points, plus the
//!                           decoupled-uncore grid (fixed memory clock:
//!                           wall-clock memory bounds invariant under
//!                           core DVFS; `--certified-activity` adds the
//!                           measured-utilization feedback showcase;
//!                           `--deadline-ns N` governs the fig6a mix
//!                           for one wall-clock deadline);
//! - `faults`              — deterministic fault-injection grid: k-fault
//!                           admission verdicts (AMR lockstep recoveries,
//!                           HyperRAM retries, ECC scrub traffic) checked
//!                           against seeded faulted simulations on an
//!                           availability × deadline sweep;
//! - `trace`               — bound gap attribution: the fig6a grid
//!                           re-run with event tracing armed, measured
//!                           per-resource interference cycles printed
//!                           next to the WCET breakdown terms, and the
//!                           JSONL + Perfetto sinks written to `--out D`
//!                           (default `target/trace`; `--threads N`
//!                           pins the sweep width);
//! - `workingset`          — trace-driven working-set profiles on the
//!                           fig6a grid, a partition-fit certificate
//!                           minted from the TCT's measured fit curve,
//!                           and the admission flip it buys: a deadline
//!                           every cold-bound `tct_sets` setting rejects
//!                           but the certified warm path admits,
//!                           validated by one partitioned simulation
//!                           (certificate JSON written to `--out D`,
//!                           default `target/workingset`);
//! - `pack`                — admission as a service: a seeded queue of
//!                           `--depth N` scenario requests (default
//!                           10^5) packed into co-resident mixes by the
//!                           racing bound-aware heuristics, governed to
//!                           the lowest common operating point, and
//!                           confirmed by one batched validation sweep
//!                           (`--seed N` reseeds the queue, `--threads
//!                           N` pins the shard width — results are
//!                           bit-identical at any width);
//! - `all`                 — run every experiment in sequence;
//! - `artifacts [--dir D]` — list AOT artifacts and smoke-execute one;
//! - `infer [--dir D]`     — run the QNN MLP artifact through the PJRT
//!                           runtime with deterministic inputs;
//! - `scenario`            — run a custom mixed-criticality scenario
//!                           (`--policy none|tsu|partition|private`).

use carfield::coordinator::task::Criticality;
use carfield::coordinator::{IsolationPolicy, McTask, Scenario, Scheduler, Workload};
use carfield::experiments as exp;
use carfield::runtime::ArtifactRuntime;
use carfield::soc::amr::IntPrecision;
use carfield::soc::dma::DmaJob;
use carfield::soc::hostd::TctSpec;
use carfield::soc::secd::SecureDomain;
use carfield::soc::vector::FpFormat;
use carfield::util::cli::Args;

fn main() {
    let args = Args::from_env();
    match args.subcommand() {
        Some("boot") => cmd_boot(),
        Some("fig3c") => exp::fig3c::print(&exp::fig3c::run()),
        Some("fig5") => exp::fig5::print(&exp::fig5::run()),
        Some("fig6a") => exp::fig6a::print(&exp::fig6a::run()),
        Some("fig6b") => exp::fig6b::print(&exp::fig6b::run()),
        Some("fig7") => exp::fig7::print(&exp::fig7::run()),
        Some("fig8") => exp::fig8::print(&exp::fig8::run()),
        Some("micro") => exp::micro::print(&exp::micro::run()),
        Some("wcet") => cmd_wcet(&args),
        Some("autotune") => cmd_autotune(&args),
        Some("dvfs") => cmd_dvfs(&args),
        Some("faults") => cmd_faults(),
        Some("trace") => cmd_trace(&args),
        Some("workingset") => cmd_workingset(&args),
        Some("pack") => cmd_pack(&args),
        Some("all") => {
            exp::fig3c::print(&exp::fig3c::run());
            exp::fig5::print(&exp::fig5::run());
            exp::fig6a::print(&exp::fig6a::run());
            exp::fig6b::print(&exp::fig6b::run());
            exp::fig7::print(&exp::fig7::run());
            exp::fig8::print(&exp::fig8::run());
            exp::micro::print(&exp::micro::run());
            exp::bounds::print(&exp::bounds::run());
            exp::autotune::print(&exp::autotune::run());
            exp::energy::print(&exp::energy::run());
            exp::reliability::print(&exp::reliability::run());
        }
        Some("artifacts") => cmd_artifacts(&args),
        Some("infer") => cmd_infer(&args),
        Some("scenario") => cmd_scenario(&args),
        _ => {
            eprintln!(
                "usage: carfield <boot|fig3c|fig5|fig6a|fig6b|fig7|fig8|micro|wcet|autotune|dvfs|faults|trace|workingset|pack|all|artifacts|infer|scenario> [options]"
            );
            std::process::exit(2);
        }
    }
}

fn cmd_wcet(args: &Args) {
    let threads = args.get_parse("threads", carfield::coordinator::sweep::default_threads());
    exp::bounds::print(&exp::bounds::run_with_threads(threads));
}

fn cmd_autotune(args: &Args) {
    use carfield::coordinator::autotune;
    if args.get("deadline").is_none() {
        let r = exp::autotune::run();
        exp::autotune::print(&r);
        // The smoke gate: every validating simulation must confirm its
        // winner, and the tuner must actually beat the fixed ladder
        // (otherwise a bound-engine regression that exhausts every
        // search would pass vacuously with zero validations).
        let unsound = r
            .rows
            .iter()
            .filter_map(|row| row.validation.as_ref())
            .any(|v| !v.confirmed());
        if unsound {
            eprintln!("autotune validation failed: a winning tuning missed its bound or deadline");
            std::process::exit(1);
        }
        if r.tuned_admitted <= r.ladder_admitted {
            eprintln!(
                "autotune regression: tuner admitted {} mixes vs the ladder's {}",
                r.tuned_admitted, r.ladder_admitted
            );
            std::process::exit(1);
        }
        return;
    }
    let deadline = args.get_parse("deadline", 800_000u64);
    let scenario = exp::autotune::reference_mix(deadline);
    println!(
        "tuning the fig6a reference mix (hard TCT deadline {deadline} cycles vs the endless \
         system-DMA interferer), starting from {}",
        scenario.tuning.describe()
    );
    match autotune::autotune(&scenario) {
        Ok(outcome) => {
            let relaxed = outcome.relaxed.map_or(String::new(), |r| {
                format!(" (relaxed binding resource: {})", r.describe())
            });
            println!(
                "{:?} found {} after {} analytic evaluations{}",
                outcome.strategy,
                outcome.tuning.describe(),
                outcome.evaluations,
                relaxed
            );
            println!("{}", outcome.decision.summary());
            let v = autotune::validate(&scenario, &outcome);
            for (task, measured, bound) in &v.checks {
                println!(
                    "validating simulation: {task} measured {measured} <= bound {bound}{}",
                    if measured <= bound { "" } else { "  ** VIOLATED **" }
                );
            }
            println!(
                "validation {}",
                if v.confirmed() { "CONFIRMED" } else { "FAILED" }
            );
            if !v.confirmed() {
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("autotune failed: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_dvfs(args: &Args) {
    use carfield::power::governor;
    if args.get("deadline-ns").is_none() {
        let r = exp::energy::run();
        exp::energy::print(&r);
        // The smoke gate: every governed winner must be confirmed by its
        // validating simulation inside the power envelope, and the grid
        // must actually demonstrate a sub-nominal point with a real
        // energy saving (else a regression that pins everything to 1.1V
        // would pass vacuously).
        if !r.all_confirmed() {
            eprintln!(
                "dvfs validation failed: a governed point missed its bound, \
                 deadline or the 1.2W envelope"
            );
            std::process::exit(1);
        }
        if r.governed == 0 {
            eprintln!("dvfs regression: no mix was governable");
            std::process::exit(1);
        }
        match r.best_sub_nominal_saving() {
            Some((saving, _)) if saving >= 30.0 => {}
            other => {
                eprintln!(
                    "dvfs regression: no sub-nominal winner with >=30% energy \
                     saving (best: {other:?})"
                );
                std::process::exit(1);
            }
        }
        // Decoupled-uncore grid: memory-bound rows must be wall-clock
        // frequency-invariant under core DVFS, every winner confirmed,
        // and at least one deadline unpinned from its coupled voltage.
        let u = exp::energy::run_uncore();
        exp::energy::print_uncore(&u);
        if !u.all_confirmed() {
            eprintln!("uncore dvfs validation failed: a decoupled winner was refuted");
            std::process::exit(1);
        }
        if !u.memory_bound_is_flat() {
            eprintln!(
                "uncore regression: the memory-bound fig6a wall-clock bound scales with \
                 core voltage ({:.1}ns @0.60V vs {:.1}ns @1.10V)",
                u.mem_ns_low_v, u.mem_ns_peak_v
            );
            std::process::exit(1);
        }
        if u.unpinned().is_empty() {
            eprintln!("uncore regression: decoupling unpinned no deadline");
            std::process::exit(1);
        }
        if args.flag("certified-activity") {
            let c = exp::energy::run_certified();
            exp::energy::print_certified(&c);
            // The dual-critical showcase is deterministic: the
            // worst-case gate must block it and the measured
            // certificate must rescue it, simulation-confirmed. Any
            // other outcome is a regression in the feedback path.
            match &c.outcome {
                Ok(choice) if choice.confirmed() && choice.unlocked() => {}
                Ok(_) => {
                    eprintln!(
                        "certified-activity regression: certified winner unconfirmed \
                         or no voltage unlocked"
                    );
                    std::process::exit(1);
                }
                Err(e) => {
                    eprintln!(
                        "certified-activity regression: the measured certificate \
                         failed to rescue the dual-critical showcase ({e})"
                    );
                    std::process::exit(1);
                }
            }
        }
        return;
    }
    let deadline_ns = args.get_parse("deadline-ns", 2_500_000.0f64);
    let scenario = exp::energy::reference_mix_ns(deadline_ns);
    println!(
        "governing the fig6a reference mix (hard TCT deadline {deadline_ns} ns vs the endless \
         system-DMA interferer)"
    );
    match governor::govern(&scenario) {
        Ok(choice) => {
            println!(
                "selected {} with {} ({:?}; {} voltage points, {} analytic evaluations)",
                choice.op.describe(),
                choice.tuning.describe(),
                choice.strategy,
                choice.points_evaluated,
                choice.evaluations
            );
            for (task, bound_ns, deadline_ns) in &choice.checks_ns {
                println!("  {task}: completion bound {bound_ns:.0}ns <= deadline {deadline_ns:.0}ns");
            }
            println!(
                "modeled: {:.1}mW / {:.4}mJ over the bound window{}",
                choice.modeled.total_power_mw,
                choice.modeled.total_energy_mj,
                choice
                    .energy_saved_pct()
                    .map_or(String::new(), |s| format!(" ({s:.0}% saved vs max_perf)"))
            );
            let v = governor::validate(&scenario, &choice);
            for (task, measured, bound) in &v.checks {
                println!(
                    "validating simulation: {task} measured {measured} <= bound {bound}{}",
                    if measured <= bound { "" } else { "  ** VIOLATED **" }
                );
            }
            println!(
                "measured power {:.1}mW ({} envelope); validation {}",
                v.measured.total_power_mw,
                if v.measured.within_envelope() {
                    "within"
                } else {
                    "OVER"
                },
                if v.confirmed() { "CONFIRMED" } else { "FAILED" }
            );
            if !v.confirmed() {
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("dvfs governor failed: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_faults() {
    let r = exp::reliability::run();
    exp::reliability::print(&r);
    // The smoke gate: every seeded faulted simulation must stay under
    // its k-fault bound, and the grid must actually exercise the fault
    // dimension — at least one knife-edge cell flipped by the k-term
    // alone and at least one rejection attributed to the recovery
    // budget (else a regression that zeroes the fault term would pass
    // vacuously with an all-admitted grid).
    if r.rows.is_empty() {
        eprintln!("faults regression: the availability grid is empty");
        std::process::exit(1);
    }
    if !r.all_sound() {
        eprintln!("faults validation failed: a seeded simulation exceeded its k-fault bound");
        std::process::exit(1);
    }
    if r.k_flips == 0 {
        eprintln!("faults regression: no cell flipped from admitted@k=0 to rejected@k=1");
        std::process::exit(1);
    }
    if r.fault_bound_rejections == 0 {
        eprintln!("faults regression: no rejection was attributed to the fault-recovery budget");
        std::process::exit(1);
    }
}

fn cmd_trace(args: &Args) {
    let threads = args.get_parse("threads", carfield::coordinator::sweep::default_threads());
    let r = exp::trace::run_with_threads(threads);
    exp::trace::print(&r);
    let out = args.get_or("out", "target/trace");
    match exp::trace::write_sinks(&r, out) {
        Ok(n) => println!("wrote {n} sink file(s) to {out}/"),
        Err(e) => {
            eprintln!("cannot write trace sinks to {out}: {e}");
            std::process::exit(1);
        }
    }
    // The smoke gate: the ledger invariants and the zero-cost-when-
    // disabled contract are what make the traces *evidence* — a run
    // that breaks either is a regression, not a report.
    if !r.all_sound() {
        eprintln!(
            "trace validation failed: a ledger row broke its sum-to-makespan \
             or measured<=bound invariant"
        );
        std::process::exit(1);
    }
    if !r.reports_unperturbed {
        eprintln!("trace regression: arming tracing perturbed a ScenarioReport");
        std::process::exit(1);
    }
    if let Some(e) = &r.sink_error {
        eprintln!("trace sink validation failed: {e}");
        std::process::exit(1);
    }
    if r.rows.is_empty() {
        eprintln!("trace regression: the attribution table is empty");
        std::process::exit(1);
    }
}

fn cmd_workingset(args: &Args) {
    let threads = args.get_parse("threads", carfield::coordinator::sweep::default_threads());
    let r = exp::workingset::run_with_threads(threads);
    exp::workingset::print(&r);
    let out = args.get_or("out", "target/workingset");
    match exp::workingset::write_certificates(&r, out) {
        Ok(n) => println!("wrote {n} certificate file(s) to {out}/"),
        Err(e) => {
            eprintln!("cannot write certificates to {out}: {e}");
            std::process::exit(1);
        }
    }
    // The smoke gates: the exact-sum profile invariant, the
    // cold-rejected/certified-admitted flip, and the simulation-backed
    // certificate soundness are what make the profiles *evidence* — a
    // run missing any of them is a regression, not a report.
    if !r.profiles_exact() {
        eprintln!(
            "workingset validation failed: a profile's per-set rows no longer \
             re-sum exactly to the observed line fills"
        );
        std::process::exit(1);
    }
    if r.certificate.is_none() {
        eprintln!("workingset regression: the fig6a TCT minted no partition certificate");
        std::process::exit(1);
    }
    if !r.flip_demonstrated() {
        eprintln!(
            "workingset regression: no fig6a mix was rejected by every cold-bound \
             tct_sets setting yet admitted through the certificate"
        );
        std::process::exit(1);
    }
    if !r.validated() {
        eprintln!(
            "workingset validation failed: the certified winner's simulation missed \
             its warm bound, its deadline, or the certified fill budget"
        );
        std::process::exit(1);
    }
}

fn cmd_pack(args: &Args) {
    let depth = args.get_parse("depth", 100_000usize);
    let seed = args.get_parse("seed", 1u64);
    let threads = args.get_parse("threads", carfield::coordinator::sweep::default_threads());
    let r = exp::packing::run_with(depth, seed, threads);
    exp::packing::print(&r);
    // The smoke gates: co-residency is what distinguishes a *packer*
    // from one-scenario-per-slot dispatch, the admission and validation
    // gates are the service's soundness claim, and the race accounting
    // catches a heuristic silently dropping out of the comparison.
    if !r.co_residency() {
        eprintln!("pack regression: no packed mix holds more than one request");
        std::process::exit(1);
    }
    if !r.all_admitted() {
        eprintln!(
            "pack validation failed: a packed mix has negative binding slack \
             or a per-task bound past its deadline"
        );
        std::process::exit(1);
    }
    if !r.validation_sound() {
        eprintln!(
            "pack validation failed: the batched sweep refuted a packed mix \
             (measured makespan past its bound or a deadline missed)"
        );
        std::process::exit(1);
    }
    if !r.race_accounted() {
        eprintln!("pack regression: heuristic win/tie counts do not cover every batch");
        std::process::exit(1);
    }
}

fn cmd_boot() {
    let mut sd = SecureDomain::new();
    let mut now = 0;
    while !sd.booted() {
        sd.tick(now);
        now += 1;
    }
    println!(
        "secure boot complete: {} cycles (stages: ROM hash, signature verify, firmware load)",
        now
    );
}

fn artifact_dir(args: &Args) -> String {
    args.get_or("dir", "artifacts").to_string()
}

fn cmd_artifacts(args: &Args) {
    let mut rt = match ArtifactRuntime::new(artifact_dir(args)) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot create PJRT runtime: {e:#}");
            std::process::exit(1);
        }
    };
    println!("platform: {}", rt.platform());
    let names = rt.available();
    if names.is_empty() {
        println!("no artifacts found — run `make artifacts` first");
        return;
    }
    for name in &names {
        match rt.load(name) {
            Ok(exe) => println!("  {:<16} inputs: {:?}", name, exe.input_shapes()),
            Err(e) => println!("  {:<16} LOAD FAILED: {e:#}", name),
        }
    }
}

fn cmd_infer(args: &Args) {
    let mut rt = ArtifactRuntime::new(artifact_dir(args)).expect("PJRT runtime");
    let exe = rt.load("qnn_mlp").expect("load qnn_mlp artifact");
    let mut rng = carfield::util::XorShift::new(args.get_parse("seed", 7u64));
    let bufs: Vec<Vec<f32>> = exe
        .input_shapes()
        .iter()
        .map(|s| {
            let n: usize = s.iter().product();
            rng.fill_f32(n, 8.0).iter().map(|v| v.round()).collect()
        })
        .collect();
    let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
    let t0 = std::time::Instant::now();
    let out = exe.run_f32(&refs).expect("execute qnn_mlp");
    let dt = t0.elapsed();
    let logits = &out[0];
    for b in 0..4 {
        let row = &logits[b * 32..b * 32 + 10];
        let arg = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        println!("sample {b}: class {arg} logits[..4]={:?}", &row[..4]);
    }
    println!("inference (batch 32) in {dt:?} on the PJRT CPU client");
}

fn cmd_scenario(args: &Args) {
    let policy = match args.get_or("policy", "none") {
        "none" => IsolationPolicy::NoIsolation,
        "tsu" => IsolationPolicy::TsuRegulation,
        "partition" => IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: args.get_parse("partition-pct", 50u8),
        },
        "private" => IsolationPolicy::PrivatePaths,
        other => {
            eprintln!("unknown policy {other}");
            std::process::exit(2);
        }
    };
    if let Err(e) = policy.validate() {
        eprintln!("invalid policy: {e}");
        std::process::exit(2);
    }
    let mut scenario = Scenario::new("cli", policy);
    if !args.flag("no-tct") {
        scenario = scenario.with_task(McTask::new(
            "tct",
            Criticality::Hard,
            Workload::HostTct(TctSpec::fig6a()),
        ));
    }
    if !args.flag("no-dma") {
        scenario = scenario.with_task(McTask::new(
            "dma",
            Criticality::BestEffort,
            Workload::DmaCopy(DmaJob::interferer()),
        ));
    }
    if args.flag("amr") {
        scenario = scenario.with_task(McTask::new(
            "amr",
            Criticality::Safety,
            Workload::AmrMatMul {
                precision: IntPrecision::Int8,
                m: 96,
                k: 96,
                n: 96,
                tile: 8,
            },
        ));
    }
    if args.flag("vector") {
        scenario = scenario.with_task(McTask::new(
            "vec",
            Criticality::BestEffort,
            Workload::VectorMatMul {
                format: FpFormat::Fp16,
                m: 256,
                k: 256,
                n: 256,
                tile: 32,
            },
        ));
    }
    let report = Scheduler::run(&scenario);
    println!("{}", report.to_markdown());
}

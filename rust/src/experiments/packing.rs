//! Admission-service demo (`carfield pack`): drive the sharded
//! bound-aware packing pipeline over a seeded request queue and gate
//! its invariants.
//!
//! Gates (all fail the CLI loudly):
//!
//! 1. **Co-residency** — at least one packed mix holds more than one
//!    request (the packer beat one-scenario-per-slot).
//! 2. **Admission** — every packed mix is analytically admitted:
//!    non-negative binding slack and every per-task completion bound
//!    within its deadline.
//! 3. **Validation** — the batched sweep's prefix confirms the bounds:
//!    every measured makespan within its analytic bound, every
//!    deadline met.
//! 4. **Reporting** — heuristic win/disagreement accounting covers
//!    every batch (wins + ties == batches).

use crate::coordinator::metrics::print_table;
use crate::service::{self, ServiceConfig, ServiceReport};

/// The whole `carfield pack` run.
pub struct PackingResult {
    pub report: ServiceReport,
    pub threads: usize,
}

impl PackingResult {
    /// Gate 1: the packer produced at least one multi-request mix.
    pub fn co_residency(&self) -> bool {
        self.report.multi_request_mixes() >= 1
    }

    /// Gate 2: every packed mix analytically admitted.
    pub fn all_admitted(&self) -> bool {
        self.report.packed() > 0 && self.report.all_admitted()
    }

    /// Gate 3: a non-empty validation prefix, all rows sound.
    pub fn validation_sound(&self) -> bool {
        !self.report.validations.is_empty() && self.report.validation_sound()
    }

    /// Gate 4: the heuristic race accounted for every batch.
    pub fn race_accounted(&self) -> bool {
        self.report.ffd_wins + self.report.slack_wins + self.report.ties
            == self.report.batches as u64
    }
}

/// Run the pipeline at `depth` with the CLI's rescue-enabled packing
/// profile (the bench uses `ServiceConfig::default()` directly, with
/// rescue off, to keep the 10^5/10^6 timings clean).
pub fn run_with(depth: usize, seed: u64, threads: usize) -> PackingResult {
    let mut cfg = ServiceConfig {
        depth,
        seed,
        threads,
        ..ServiceConfig::default()
    };
    cfg.pack.rescue_evaluations = 96;
    let report = service::run(&cfg);
    PackingResult { report, threads }
}

/// Print the service tables.
pub fn print(r: &PackingResult) {
    let rep = &r.report;
    print_table(
        "admission service — queue summary",
        &["metric", "value"],
        &[
            vec!["requests".into(), format!("{}", rep.depth)],
            vec!["batches".into(), format!("{}", rep.batches)],
            vec!["threads".into(), format!("{}", r.threads)],
            vec!["packed mixes".into(), format!("{}", rep.packed())],
            vec![
                "multi-request mixes".into(),
                format!("{}", rep.multi_request_mixes()),
            ],
            vec![
                "packing ratio".into(),
                format!("{:.3} req/mix", rep.packing_ratio()),
            ],
            vec!["admit probes".into(), format!("{}", rep.stats.probes)],
            vec![
                "probes filtered (scalar)".into(),
                format!("{}", rep.stats.filtered),
            ],
            vec![
                "probes rejected (exact)".into(),
                format!("{}", rep.stats.rejected),
            ],
            vec![
                "rescues attempted/won".into(),
                format!("{}/{}", rep.stats.rescues, rep.stats.rescued),
            ],
        ],
    );
    print_table(
        "heuristic race (per batch)",
        &["heuristic", "strict wins", "share"],
        &[
            vec![
                "first-fit-decreasing".into(),
                format!("{}", rep.ffd_wins),
                format!("{:.1}%", 100.0 * rep.ffd_wins as f64 / rep.batches.max(1) as f64),
            ],
            vec![
                "best-fit-slack".into(),
                format!("{}", rep.slack_wins),
                format!(
                    "{:.1}%",
                    100.0 * rep.slack_wins as f64 / rep.batches.max(1) as f64
                ),
            ],
            vec![
                "ties (equal mix count)".into(),
                format!("{}", rep.ties),
                format!("{:.1}%", 100.0 * rep.ties as f64 / rep.batches.max(1) as f64),
            ],
            vec![
                "assignment disagreements".into(),
                format!("{}", rep.disagreements),
                format!("{:.1}%", 100.0 * rep.disagreement_rate()),
            ],
        ],
    );
    let governed_rows: Vec<Vec<String>> = rep
        .governed
        .iter()
        .map(|g| {
            vec![
                format!("mix-{}", g.mix),
                g.op.describe(),
                g.tuning.describe(),
                g.saved_pct
                    .map(|p| format!("{p:.1}%"))
                    .unwrap_or_else(|| "-".into()),
                if g.from_library { "hit" } else { "miss" }.into(),
                if g.confirmed { "yes" } else { "NO" }.into(),
            ]
        })
        .collect();
    if !governed_rows.is_empty() {
        print_table(
            "governed prefix (lowest common operating point per mix)",
            &["mix", "operating point", "tuning", "saved", "library", "confirmed"],
            &governed_rows,
        );
        println!(
            "  certificate library: {} shapes, {} hits / {} misses ({:.1}% hit rate), {} govern failures",
            rep.library_len,
            rep.library_hits,
            rep.library_misses,
            100.0 * rep.library_hit_rate(),
            rep.govern_failures,
        );
    }
    let validation_rows: Vec<Vec<String>> = rep
        .validations
        .iter()
        .map(|v| {
            let worst = v
                .checks
                .iter()
                .map(|(_, measured, bound)| *measured as f64 / (*bound).max(1) as f64)
                .fold(0.0f64, f64::max);
            vec![
                format!("mix-{}", v.mix),
                if v.governed { "governed" } else { "as-packed" }.into(),
                format!("{}", v.checks.len()),
                format!("{:.3}", worst),
                if v.sound { "yes" } else { "NO" }.into(),
                if v.deadlines_met { "yes" } else { "NO" }.into(),
            ]
        })
        .collect();
    if !validation_rows.is_empty() {
        print_table(
            "validation sweep (measured vs bound)",
            &["mix", "point", "tasks", "worst meas/bound", "sound", "deadlines"],
            &validation_rows,
        );
    }
    // No silent caps: say exactly how far the deep stages reached.
    println!(
        "  deep stages: {} of {} mixes governed, {} validated (deterministic prefixes)",
        rep.governed.len(),
        rep.packed(),
        rep.validations.len(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_queue_passes_every_gate() {
        // Hand-built config: debug builds double-run every validating
        // simulation, so keep the deep-stage prefixes tiny here (the
        // CI smoke runs `run_with` at depth 10^4 in release).
        let mut cfg = ServiceConfig {
            depth: 48,
            seed: 9,
            threads: 2,
            batch: 16,
            govern_cap: 1,
            validate_cap: 4,
            ..ServiceConfig::default()
        };
        cfg.pack.rescue_evaluations = 32;
        let r = PackingResult {
            report: service::run(&cfg),
            threads: 2,
        };
        assert!(r.co_residency(), "no multi-request mix packed");
        assert!(r.all_admitted());
        assert!(r.validation_sound(), "{:?}", r.report.validations);
        assert!(r.race_accounted());
        print(&r); // smoke the tables
    }
}

//! Fig. 7 — comparison against SoA heterogeneous SoCs for
//! mixed-criticality systems.
//!
//! Most rows are feature claims; the quantitative row is interrupt
//! latency: 6 cycles (CV32RT + CLIC) vs 12 (NXP i.MXRT1170), 20 (ST
//! Stellar), and ~50 for [10]'s plain CLINT path — the paper quotes
//! 2x / 3.3x / 8.3x advantages. We *measure* our latency from the CLIC
//! model and a TCLS interrupt drill, and tabulate the rest.

use crate::soc::hostd::VClic;
use crate::soc::safed::Tcls;

/// A competitor column of the table.
#[derive(Debug, Clone)]
pub struct SocColumn {
    pub name: &'static str,
    pub irq_latency_cycles: u64,
    pub hw_cache_partitioning: bool,
    pub predictable_onchip_comm: bool,
    pub dynamic_spm: bool,
    pub hw_virtualization: bool,
    pub ai_accel: bool,
    pub safe_domain_lockstep: bool,
    pub rtos_plus_gpos: bool,
}

#[derive(Debug, Clone)]
pub struct Fig7Result {
    pub columns: Vec<SocColumn>,
    /// Our measured interrupt latency (drilled, not just a constant).
    pub measured_irq_latency: u64,
    /// Ratios vs each competitor.
    pub irq_advantage: Vec<(&'static str, f64)>,
}

/// Run an interrupt drill: assert an IRQ against the TCLS CLIC model and
/// count cycles to first handler commit.
fn measure_irq_latency() -> u64 {
    let tcls = Tcls::new();
    // The CLIC pipeline is deterministic; drill a few times and verify
    // the WCET equals the constant (that determinism *is* the claim).
    let mut worst = 0;
    for _ in 0..32 {
        worst = worst.max(tcls.irq_latency());
    }
    worst
}

pub fn run() -> Fig7Result {
    let columns = vec![
        SocColumn {
            name: "NXP i.MXRT1170",
            irq_latency_cycles: 12,
            hw_cache_partitioning: false,
            predictable_onchip_comm: false,
            dynamic_spm: false,
            hw_virtualization: false,
            ai_accel: false,
            safe_domain_lockstep: false,
            rtos_plus_gpos: false,
        },
        SocColumn {
            name: "ST Stellar / VLSI23",
            irq_latency_cycles: 20,
            hw_cache_partitioning: false,
            predictable_onchip_comm: true,
            dynamic_spm: false,
            hw_virtualization: false,
            ai_accel: false,
            safe_domain_lockstep: true,
            rtos_plus_gpos: false,
        },
        SocColumn {
            name: "Renesas ISSCC19",
            irq_latency_cycles: 0, // n.a. in the paper
            hw_cache_partitioning: false,
            predictable_onchip_comm: false,
            dynamic_spm: false,
            hw_virtualization: true,
            ai_accel: false,
            safe_domain_lockstep: true,
            rtos_plus_gpos: false,
        },
        SocColumn {
            name: "TCAS-I 24 (nano-UAV)",
            irq_latency_cycles: 50,
            hw_cache_partitioning: false,
            predictable_onchip_comm: false,
            dynamic_spm: false,
            hw_virtualization: true,
            ai_accel: true,
            safe_domain_lockstep: false,
            rtos_plus_gpos: true,
        },
        SocColumn {
            name: "This work (Carfield)",
            irq_latency_cycles: 6,
            hw_cache_partitioning: true,
            predictable_onchip_comm: true,
            dynamic_spm: true,
            hw_virtualization: true,
            ai_accel: true,
            safe_domain_lockstep: true,
            rtos_plus_gpos: true,
        },
    ];
    let measured = measure_irq_latency();
    let irq_advantage = columns
        .iter()
        .filter(|c| c.name != "This work (Carfield)" && c.irq_latency_cycles > 0)
        .map(|c| (c.name, c.irq_latency_cycles as f64 / measured as f64))
        .collect();
    Fig7Result {
        columns,
        measured_irq_latency: measured,
        irq_advantage,
    }
}

pub fn print(r: &Fig7Result) {
    use crate::coordinator::metrics::print_table;
    let yn = |b: bool| if b { "yes" } else { "-" }.to_string();
    print_table(
        "Fig. 7: SoC comparison (time-predictability features + interrupt latency)",
        &[
            "SoC", "irq cyc", "LLC part", "pred comm", "dyn SPM", "HW virt", "AI accel",
            "lockstep", "RTOS+GPOS",
        ],
        &r.columns
            .iter()
            .map(|c| {
                vec![
                    c.name.to_string(),
                    if c.irq_latency_cycles == 0 {
                        "n.a.".into()
                    } else {
                        c.irq_latency_cycles.to_string()
                    },
                    yn(c.hw_cache_partitioning),
                    yn(c.predictable_onchip_comm),
                    yn(c.dynamic_spm),
                    yn(c.hw_virtualization),
                    yn(c.ai_accel),
                    yn(c.safe_domain_lockstep),
                    yn(c.rtos_plus_gpos),
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!("measured IRQ latency: {} cycles", r.measured_irq_latency);
    for (name, adv) in &r.irq_advantage {
        println!("  vs {name}: {adv:.1}x faster");
    }
    let v = VClic::carfield();
    println!(
        "vCLIC: same-VG {} cycles, cross-VG {} cycles (no hypervisor exit)",
        v.latency(0, 0),
        v.latency(0, 1)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn irq_ratios_match_paper() {
        let r = run();
        assert_eq!(r.measured_irq_latency, 6);
        let get = |name: &str| {
            r.irq_advantage
                .iter()
                .find(|(n, _)| *n == name)
                .map(|(_, v)| *v)
                .unwrap()
        };
        assert!((get("NXP i.MXRT1170") - 2.0).abs() < 1e-9);
        assert!((get("ST Stellar / VLSI23") - 3.33).abs() < 0.01);
        assert!((get("TCAS-I 24 (nano-UAV)") - 8.33).abs() < 0.01);
    }

    #[test]
    fn only_this_work_has_all_predictability_features() {
        let r = run();
        for c in &r.columns {
            let all = c.hw_cache_partitioning && c.predictable_onchip_comm && c.dynamic_spm;
            if c.name == "This work (Carfield)" {
                assert!(all);
            } else {
                assert!(!all, "{} should not have everything", c.name);
            }
        }
    }
}

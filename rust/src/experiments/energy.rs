//! DVFS governor grid (`carfield dvfs`): the Fig. 6 deadline grids run
//! through the bound-driven governor.
//!
//! Deadlines are expressed in wall-clock nanoseconds (the cycle grids of
//! `experiments::autotune` priced at the 1GHz max-performance clock, so
//! the numbers line up 1:1 with the cycle story). Slack-rich mixes land
//! on low-voltage points at a large modeled energy saving vs `max_perf`;
//! tight deadlines pin to 1.1V; deadlines below the bound floor exhaust
//! with the closest miss named — and every governed point is provably
//! admissible, confirmed by one validating simulation with measured
//! energy columns.

use crate::coordinator::task::Criticality;
use crate::coordinator::{IsolationPolicy, McTask, Scenario, Workload};
use crate::power::governor::{
    self, CertifiedChoice, GovernError, Governor, GovernorChoice, GovernorValidation,
};
use crate::power::OperatingPoint;
use crate::soc::clock::Cycle;
use crate::soc::power::NOMINAL_V;
use crate::wcet;

/// Deadlines swept for the fig6a host mix, in nanoseconds. Mirrors the
/// autotune cycle grid at the 1GHz peak clock; the 430us point is the
/// pin-to-peak showcase (the tightest admitting bound is ~413us at
/// 1.1V, so no lower voltage can carry it).
pub const HOST_DEADLINES_NS: [f64; 6] = [
    350_000.0,
    430_000.0,
    550_000.0,
    800_000.0,
    1_200_000.0,
    2_500_000.0,
];

/// Deadline for the fig6b cluster mix (ns). Generous enough to admit
/// from the second grid step up (the bound floor is ~154k cycles), so
/// the energy argmin lands sub-nominal; the best-effort vector domain
/// is floored on every candidate, which is also what keeps high-voltage
/// candidates inside the envelope (uniform 1.1V — 747mW AMR + 600mW
/// vector — would bust 1.2W).
pub const CLUSTER_DEADLINE_NS: f64 = 400_000.0;

fn with_ns_deadline(mut s: Scenario, deadline_ns: f64) -> Scenario {
    for t in s.tasks.iter_mut() {
        if t.criticality.is_time_critical() {
            t.deadline = 0;
            t.deadline_ns = deadline_ns;
        }
    }
    s
}

/// The fig6a reference mix with a wall-clock deadline.
pub fn reference_mix_ns(deadline_ns: f64) -> Scenario {
    with_ns_deadline(crate::experiments::autotune::reference_mix(0), deadline_ns)
}

/// The fig6b cluster mix with a wall-clock deadline.
pub fn cluster_mix_ns(deadline_ns: f64) -> Scenario {
    with_ns_deadline(crate::experiments::autotune::cluster_mix(0), deadline_ns)
}

/// A dual-critical cluster mix: *both* clusters carry hard deadlines,
/// so neither can be parked at the grid floor and the fully-active
/// worst case at peak voltage (747mW AMR + 600mW vector + host/uncore
/// floors) deterministically busts the 1.2W envelope — the mix the
/// certified-activity feedback exists to rescue. The AMR job is much
/// shorter than the vector job, so its *measured* duty cycle over the
/// mix's span is small and the certified gate fits peak voltage.
pub fn dual_cluster_mix_ns(deadline_ns: f64) -> Scenario {
    use crate::soc::amr::IntPrecision;
    use crate::soc::vector::FpFormat;
    let s = Scenario::new("dual-cluster-mix", crate::coordinator::SocTuning::tsu_regulation())
        .with_task(McTask::new(
            "amr-tct",
            Criticality::Hard,
            Workload::AmrMatMul {
                precision: IntPrecision::Int8,
                m: 96,
                k: 96,
                n: 96,
                tile: 8,
            },
        ))
        .with_task(McTask::new(
            "vec-tct",
            Criticality::Hard,
            Workload::VectorMatMul {
                format: FpFormat::Fp16,
                m: 256,
                k: 256,
                n: 256,
                tile: 32,
            },
        ));
    with_ns_deadline(s, deadline_ns)
}

/// The bound floor of the dual-critical mix at `op`, in nanoseconds:
/// its interference-free PrivatePaths bounds (own cost is
/// tuning-invariant and interference is non-negative, so no tuning in
/// the space can beat this floor). Used to derive a deadline that is
/// feasible *only* at peak voltage.
pub fn dual_cluster_floor_ns(op: OperatingPoint) -> f64 {
    let probe = dual_cluster_mix_ns(10_000_000.0)
        .with_tuning(IsolationPolicy::PrivatePaths)
        .with_op_point(op);
    let report = wcet::analyze(&probe);
    let tree = op.clock_tree();
    report
        .bounds
        .iter()
        .filter_map(|b| b.completion_ns(&tree))
        .fold(0.0, f64::max)
}

/// One mix's governor verdict + validating simulation.
pub struct DvfsRow {
    pub mix: String,
    pub deadline_ns: f64,
    pub outcome: Result<GovernorChoice, GovernError>,
    pub validation: Option<GovernorValidation>,
}

pub struct DvfsResult {
    pub rows: Vec<DvfsRow>,
    /// Mixes the governor found an admissible point for.
    pub governed: usize,
    /// Analytic admission evaluations across every search.
    pub total_evaluations: u64,
    /// Voltage points searched across every mix.
    pub total_points: u64,
    /// Wall-clock of the analytic searches only (no simulation).
    pub search_seconds: f64,
    /// Validation-simulation cycles (bench throughput metric).
    pub sim_cycles: Cycle,
}

impl DvfsResult {
    /// Every governed winner inside the envelope and confirmed by its
    /// validating simulation (measured <= bound, deadlines met, measured
    /// power <= 1.2W). Exhausted rows are vacuously fine.
    pub fn all_confirmed(&self) -> bool {
        self.rows.iter().all(|r| match (&r.outcome, &r.validation) {
            (Ok(c), Some(v)) => c.modeled.within_envelope() && v.confirmed(),
            (Ok(_), None) => false,
            (Err(_), _) => true,
        })
    }

    /// Best modeled energy saving among sub-nominal (< 0.8V system)
    /// winners: `(saving %, winner system voltage)`.
    pub fn best_sub_nominal_saving(&self) -> Option<(f64, f64)> {
        self.rows
            .iter()
            .filter_map(|r| r.outcome.as_ref().ok())
            .filter(|c| c.op.v_system < NOMINAL_V)
            .filter_map(|c| c.energy_saved_pct().map(|s| (s, c.op.v_system)))
            .max_by(|a, b| a.0.partial_cmp(&b.0).expect("savings are finite"))
    }
}

/// The grid's scenario list.
fn grid() -> Vec<(Scenario, f64)> {
    let mut mixes: Vec<(Scenario, f64)> = HOST_DEADLINES_NS
        .iter()
        .map(|&ns| (reference_mix_ns(ns), ns))
        .collect();
    mixes.push((cluster_mix_ns(CLUSTER_DEADLINE_NS), CLUSTER_DEADLINE_NS));
    mixes
}

pub fn run() -> DvfsResult {
    let mut rows = Vec::new();
    let mut governed = 0usize;
    let mut total_evaluations = 0u64;
    let mut total_points = 0u64;
    let mut search_seconds = 0.0f64;
    let mut sim_cycles = 0;
    for (scenario, deadline_ns) in grid() {
        let t0 = std::time::Instant::now();
        let outcome = governor::govern(&scenario);
        search_seconds += t0.elapsed().as_secs_f64();
        let validation = match &outcome {
            Ok(c) => {
                governed += 1;
                total_evaluations += c.evaluations;
                total_points += c.points_evaluated;
                let v = governor::validate(&scenario, c);
                sim_cycles += v.report.cycles;
                Some(v)
            }
            Err(GovernError::Exhausted {
                points_evaluated,
                evaluations,
                ..
            }) => {
                total_evaluations += evaluations;
                total_points += points_evaluated;
                None
            }
            Err(GovernError::NoDeadline) => None,
        };
        rows.push(DvfsRow {
            mix: scenario.name.clone(),
            deadline_ns,
            outcome,
            validation,
        });
    }
    DvfsResult {
        rows,
        governed,
        total_evaluations,
        total_points,
        search_seconds,
        sim_cycles,
    }
}

pub fn print(r: &DvfsResult) {
    use crate::coordinator::metrics::print_table;
    print_table(
        "DVFS governor: energy-minimal provably-safe operating points (fig6a/fig6b deadline grids; E vs the max_perf baseline)",
        &[
            "mix", "deadline", "point", "tuning", "bound", "P model", "E model",
            "saved", "sim: measured <= bound / P measured",
        ],
        &r.rows
            .iter()
            .map(|row| {
                let (point, tuning, bound, p_model, e_model, saved) = match &row.outcome {
                    Ok(c) => (
                        c.op.describe(),
                        c.tuning.describe(),
                        c.checks_ns
                            .iter()
                            .map(|(_, b, _)| format!("{b:.0}ns"))
                            .collect::<Vec<_>>()
                            .join("; "),
                        format!("{:.0}mW", c.modeled.total_power_mw),
                        format!("{:.3}mJ", c.modeled.total_energy_mj),
                        c.energy_saved_pct()
                            .map_or("-".to_string(), |s| format!("{s:.0}%")),
                    ),
                    Err(e) => (
                        "EXHAUSTED".to_string(),
                        e.to_string(),
                        "-".to_string(),
                        "-".to_string(),
                        "-".to_string(),
                        "-".to_string(),
                    ),
                };
                let sim = match &row.validation {
                    Some(v) => {
                        let checks = v
                            .checks
                            .iter()
                            .map(|(task, measured, bound)| {
                                format!(
                                    "{task}: {measured} <= {bound}{}",
                                    if *measured <= *bound { "" } else { " VIOLATED" }
                                )
                            })
                            .collect::<Vec<_>>()
                            .join("; ");
                        format!(
                            "{checks} / {:.0}mW{}",
                            v.measured.total_power_mw,
                            if v.measured.within_envelope() {
                                ""
                            } else {
                                " OVER ENVELOPE"
                            }
                        )
                    }
                    None => "-".to_string(),
                };
                vec![
                    row.mix.clone(),
                    format!("{:.0}us", row.deadline_ns / 1e3),
                    point,
                    tuning,
                    bound,
                    p_model,
                    e_model,
                    saved,
                    sim,
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!(
        "\nmixes governed: {}/{}; {} voltage points, {} analytic evaluations in {:.1} ms \
         ({:.0} points/sec); all winners confirmed: {}",
        r.governed,
        r.rows.len(),
        r.total_points,
        r.total_evaluations,
        r.search_seconds * 1e3,
        r.total_points as f64 / r.search_seconds.max(1e-9),
        r.all_confirmed()
    );
    if let Some((saving, v)) = r.best_sub_nominal_saving() {
        println!(
            "best sub-nominal showcase: {saving:.0}% modeled energy saved vs max_perf at {v:.2}V"
        );
    }
}

// ---------------------------------------------------------------------
// Decoupled-uncore grid: the same deadlines under the fixed-frequency
// memory subsystem.
// ---------------------------------------------------------------------

/// Wall-clock memory-latency bound of the regulated fig6a TCT with the
/// uncore decoupled, at the grid floor and peak: `(low_v_ns, peak_v_ns,
/// memory_bound)`. Frequency-invariance evidence — only the few
/// system-side edge/CDC-sync cycles may stretch (~13% at the 0.6V
/// floor vs 2.9x for the coupled model), and the row must genuinely be
/// memory-bound (completion dominated by the HyperRAM channel).
pub fn memory_bound_flatness() -> (f64, f64, bool) {
    let at = |v: f64| {
        let op = OperatingPoint::uniform(v)
            .expect("grid voltage")
            .decoupled_uncore();
        let s = reference_mix_ns(800_000.0).with_op_point(op);
        let r = wcet::analyze(&s);
        let b = r.bound_for("tct");
        // "Memory-bound" means the *completion* bound is governed by
        // the HyperRAM channel (busy-window on the uncore service) —
        // the per-transaction mem binding alone would be true for this
        // mix by construction and prove nothing about the flat row.
        (
            b.mem_ns(&op.clock_tree()),
            b.completion_binding == wcet::Resource::HyperramChannel,
        )
    };
    let (low_ns, low_mem) = at(0.6);
    let (peak_ns, peak_mem) = at(1.1);
    (low_ns, peak_ns, low_mem && peak_mem)
}

/// One deadline's coupled-vs-decoupled governor comparison.
pub struct UncoreRow {
    pub mix: String,
    pub deadline_ns: f64,
    /// Winning system voltage of the seed (coupled-uncore) governor.
    pub coupled_v: Option<f64>,
    pub outcome: Result<GovernorChoice, GovernError>,
    pub validation: Option<GovernorValidation>,
}

pub struct UncoreDvfsResult {
    pub rows: Vec<UncoreRow>,
    /// Regulated fig6a memory bound at 0.6V / 1.1V, uncore decoupled.
    pub mem_ns_low_v: f64,
    pub mem_ns_peak_v: f64,
    /// The flatness rows really are memory-bound.
    pub memory_bound: bool,
}

impl UncoreDvfsResult {
    /// Every decoupled winner confirmed by its validating simulation.
    pub fn all_confirmed(&self) -> bool {
        self.rows.iter().all(|r| match (&r.outcome, &r.validation) {
            (Ok(c), Some(v)) => c.modeled.within_envelope() && v.confirmed(),
            (Ok(_), None) => false,
            (Err(_), _) => true,
        })
    }

    /// Memory wall-clock bound invariant under core DVFS: within the
    /// system-side edge + CDC-sync margin (~13% of this bound at the
    /// 0.6V floor — at the 1.1V anchor the grids coincide and the sync
    /// margin vanishes), instead of the coupled model's 2.9x stretch.
    pub fn memory_bound_is_flat(&self) -> bool {
        self.memory_bound
            && self.mem_ns_low_v >= self.mem_ns_peak_v
            && self.mem_ns_low_v <= self.mem_ns_peak_v * 1.15
    }

    /// Rows where the coupled governor pinned a strictly higher system
    /// voltage than the decoupled one needs — i.e. deadlines whose
    /// low-voltage points the cycle-constant model falsely rejected:
    /// `(deadline_ns, coupled_v, decoupled_v)`.
    pub fn unpinned(&self) -> Vec<(f64, f64, f64)> {
        self.rows
            .iter()
            .filter_map(|r| match (&r.outcome, r.coupled_v) {
                (Ok(c), Some(cv)) if cv > c.op.v_system + 1e-9 => {
                    Some((r.deadline_ns, cv, c.op.v_system))
                }
                _ => None,
            })
            .collect()
    }
}

/// The fig6a/fig6b deadline grids re-governed with the uncore parked at
/// its fixed 1000MHz clock. Memory-bound rows' wall-clock bounds no
/// longer scale with the core voltage, so deadlines the coupled
/// governor could only carry at high voltage now admit low-voltage
/// points (each confirmed by a validating simulation).
pub fn run_uncore() -> UncoreDvfsResult {
    let decoupled = Governor::decoupled();
    let coupled = Governor::default();
    let mut rows = Vec::new();
    for (scenario, deadline_ns) in grid() {
        // The coupled winner is cheap (analytic search only): it is the
        // comparison column, not a shipped point.
        let coupled_v = coupled.govern(&scenario).ok().map(|c| c.op.v_system);
        let outcome = decoupled.govern(&scenario);
        let validation = outcome
            .as_ref()
            .ok()
            .map(|c| governor::validate(&scenario, c));
        rows.push(UncoreRow {
            mix: scenario.name.clone(),
            deadline_ns,
            coupled_v,
            outcome,
            validation,
        });
    }
    let (mem_ns_low_v, mem_ns_peak_v, memory_bound) = memory_bound_flatness();
    UncoreDvfsResult {
        rows,
        mem_ns_low_v,
        mem_ns_peak_v,
        memory_bound,
    }
}

pub fn print_uncore(r: &UncoreDvfsResult) {
    use crate::coordinator::metrics::print_table;
    print_table(
        "Decoupled uncore (fixed 1000MHz memory clock): coupled vs decoupled governor winners",
        &[
            "mix", "deadline", "coupled V", "decoupled point", "bound (wall-clock)",
            "sim: measured <= bound",
        ],
        &r.rows
            .iter()
            .map(|row| {
                let coupled = row
                    .coupled_v
                    .map_or("EXHAUSTED".to_string(), |v| format!("{v:.2}V"));
                let (point, bound) = match &row.outcome {
                    Ok(c) => (
                        c.op.describe(),
                        c.checks_ns
                            .iter()
                            .map(|(_, b, _)| format!("{b:.0}ns"))
                            .collect::<Vec<_>>()
                            .join("; "),
                    ),
                    Err(_) => ("EXHAUSTED".to_string(), "-".to_string()),
                };
                let sim = match &row.validation {
                    Some(v) => v
                        .checks
                        .iter()
                        .map(|(task, measured, bound)| {
                            format!(
                                "{task}: {measured} <= {bound}{}",
                                if *measured <= *bound { "" } else { " VIOLATED" }
                            )
                        })
                        .collect::<Vec<_>>()
                        .join("; "),
                    None => "-".to_string(),
                };
                vec![
                    row.mix.clone(),
                    format!("{:.0}us", row.deadline_ns / 1e3),
                    coupled,
                    point,
                    bound,
                    sim,
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!(
        "\nmemory-bound fig6a row, wall-clock memory bound under core DVFS: \
         {:.1}ns @0.60V vs {:.1}ns @1.10V ({}) — frequency-invariant: {}",
        r.mem_ns_low_v,
        r.mem_ns_peak_v,
        if r.memory_bound {
            "HyperRAM-channel-bound"
        } else {
            "NOT memory-bound"
        },
        r.memory_bound_is_flat()
    );
    for (deadline, cv, dv) in r.unpinned() {
        println!(
            "deadline {:.0}us: coupled governor pinned {cv:.2}V (cycle-constant memory model \
             rejected every lower point); decoupled uncore admits {dv:.2}V",
            deadline / 1e3
        );
    }
}

// ---------------------------------------------------------------------
// Certified-activity showcase (`--certified-activity`).
// ---------------------------------------------------------------------

pub struct CertifiedResult {
    /// The peak-voltage bound floor the deadline was derived from (ns).
    pub floor_ns: f64,
    /// The derived peak-only deadline (2% above the floor: feasible at
    /// 1.1V, infeasible at 1.05V where every bound stretches ~7%).
    pub deadline_ns: f64,
    pub outcome: Result<CertifiedChoice, GovernError>,
}

/// Measured-utilization feedback on the dual-critical cluster mix: the
/// fully-active envelope gate deterministically blocks peak voltage
/// (747 + 600 + floors > 1.2W), and the deadline — derived from the
/// mix's own bound floor — is feasible *only* at peak. The worst-case
/// pass therefore exhausts; the certified pass measures the real duty
/// cycles from a baseline run and re-governs with them.
pub fn run_certified() -> CertifiedResult {
    let floor_ns = dual_cluster_floor_ns(OperatingPoint::max_perf());
    let deadline_ns = floor_ns * 1.02;
    let outcome = Governor::default().govern_certified(&dual_cluster_mix_ns(deadline_ns));
    CertifiedResult {
        floor_ns,
        deadline_ns,
        outcome,
    }
}

pub fn print_certified(r: &CertifiedResult) {
    println!(
        "\n== Certified-activity feedback (dual-critical cluster mix, deadline {:.0}us = \
         bound floor {:.0}us + 2%)",
        r.deadline_ns / 1e3,
        r.floor_ns / 1e3
    );
    match &r.outcome {
        Ok(c) => {
            match &c.worst_case {
                Some((wc, _)) => println!(
                    "worst-case activity gate: governed at {}",
                    wc.op.describe()
                ),
                None => println!(
                    "worst-case activity gate: EXHAUSTED (fully-active 747mW AMR + 600mW \
                     vector busts the 1.2W envelope at the only feasible voltage)"
                ),
            }
            println!(
                "certified activity bound (measured): sys {:.2} / vec {:.2} / amr {:.2} / \
                 uncore {:.2}",
                c.certified_utils.system,
                c.certified_utils.vector,
                c.certified_utils.amr,
                c.certified_utils.uncore
            );
            println!(
                "certified gate: governed at {} — modeled {:.0}mW within the envelope; \
                 unlocked higher voltage: {}",
                c.certified.op.describe(),
                c.certified.modeled.total_power_mw,
                c.unlocked()
            );
            println!(
                "validating simulation: measured {:.0}mW ({} envelope); confirmed: {}",
                c.certified_validation.measured.total_power_mw,
                if c.certified_validation.measured.within_envelope() {
                    "within"
                } else {
                    "OVER"
                },
                c.confirmed()
            );
        }
        Err(e) => println!(
            "certificate insufficient: {e} (measured duty cycles still bust the envelope \
             at the only feasible voltage)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One grid execution, all shape properties (run() re-simulates
    /// every validation; the groups share one result).
    #[test]
    fn grid_shows_savings_pins_and_exhaustion() {
        let r = run();
        assert!(r.all_confirmed(), "a governed winner failed validation");
        assert!(r.governed >= 5, "only {} rows governed", r.governed);
        let host_row = |ns: f64| {
            r.rows
                .iter()
                .find(|row| row.mix == "fig6a-mix" && row.deadline_ns == ns)
                .expect("grid row")
        };
        // Below the bound floor: exhausted with the closest miss named.
        assert!(host_row(350_000.0).outcome.is_err());
        // No slack below peak: pinned to 1.1V, still provably admitted.
        let pinned = host_row(430_000.0).outcome.as_ref().expect("feasible at peak");
        assert_eq!(pinned.op.v_system, 1.1, "{}", pinned.op.describe());
        // Slack-rich: a deep sub-nominal point at a large saving.
        let slack = host_row(2_500_000.0).outcome.as_ref().expect("slack-rich");
        assert!(slack.op.v_system <= 0.65, "{}", slack.op.describe());
        assert!(
            slack.energy_saved_pct().expect("baseline") >= 30.0,
            "{:?}%",
            slack.energy_saved_pct()
        );
        let (best_saving, v) = r.best_sub_nominal_saving().expect("showcase row");
        assert!(best_saving >= 30.0 && v < NOMINAL_V);
        // More slack never selects a higher-voltage (higher-energy)
        // point: winner voltage is monotone along the deadline grid.
        let winners: Vec<f64> = HOST_DEADLINES_NS
            .iter()
            .filter_map(|&ns| {
                host_row(ns)
                    .outcome
                    .as_ref()
                    .ok()
                    .map(|c| c.op.v_system)
            })
            .collect();
        assert!(winners.len() >= 4);
        for w in winners.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "voltage not monotone: {winners:?}");
        }
        // The cluster mix governs with the best-effort vector domain
        // floored, and the energy argmin keeps the critical domains
        // sub-peak.
        let cluster = r
            .rows
            .iter()
            .find(|row| row.mix == "fig6b-mix")
            .expect("cluster row");
        let c = cluster.outcome.as_ref().expect("cluster governable");
        assert_eq!(c.op.v_vector, 0.6, "{}", c.op.describe());
        assert!(c.op.v_system < 1.1, "{}", c.op.describe());
    }

    /// The decoupled-uncore grid: memory-bound wall-clock bounds stay
    /// flat under core DVFS, every winner is sim-confirmed, and at
    /// least one deadline the coupled governor pinned to a high voltage
    /// now admits a lower point (the cycle-constant model's false
    /// rejection, fixed).
    #[test]
    fn uncore_grid_unpins_memory_bound_deadlines() {
        let r = run_uncore();
        assert!(r.all_confirmed(), "a decoupled winner failed validation");
        assert!(
            r.memory_bound_is_flat(),
            "memory bound scaled with core voltage: {:.1}ns @0.6V vs {:.1}ns @1.1V \
             (memory-bound: {})",
            r.mem_ns_low_v,
            r.mem_ns_peak_v,
            r.memory_bound
        );
        let unpinned = r.unpinned();
        assert!(
            !unpinned.is_empty(),
            "no deadline was unpinned by decoupling the uncore"
        );
        // The 800us row is the canonical showcase: the coupled governor
        // needed 0.75V (the 0.60V bound, stretched through the 350MHz
        // clock, overshot 800us); decoupled, the uncore share of the
        // bound is wall-clock-constant and a strictly lower voltage
        // admits.
        let row = r
            .rows
            .iter()
            .find(|row| row.mix == "fig6a-mix" && row.deadline_ns == 800_000.0)
            .expect("800us grid row");
        let c = row.outcome.as_ref().expect("decoupled 800us governable");
        let coupled_v = row.coupled_v.expect("coupled 800us governable");
        assert!(
            c.op.v_system < coupled_v,
            "decoupling should lower the 800us winner: {} vs {coupled_v:.2}V",
            c.op.describe()
        );
    }

    /// Certified-activity rescue of the deterministic dual-critical
    /// showcase: the worst-case gate cannot govern the peak-only
    /// deadline (747 + 600mW fully active busts the envelope at the
    /// only feasible voltage), and the measured certificate *must*
    /// rescue it — the short AMR job's duty cycle over the vector
    /// job's span leaves the certified gate hundreds of mW of
    /// headroom (cross-validated by /tmp/wcet_proto/uncore_mirror.py).
    #[test]
    fn certified_activity_rescues_the_dual_critical_mix() {
        let r = run_certified();
        assert!(r.floor_ns > 0.0);
        let c = r
            .outcome
            .as_ref()
            .unwrap_or_else(|e| panic!("certificate failed to rescue the showcase: {e}"));
        assert!(
            c.worst_case.is_none(),
            "the fully-active gate must block the peak-only deadline"
        );
        assert!(c.unlocked());
        assert_eq!(c.certified.op.v_amr, 1.1, "{}", c.certified.op.describe());
        assert!(c.confirmed(), "certified winner failed validation");
        // The certificate is a real measurement, not worst case: the
        // short AMR job cannot be busy across the whole mix span.
        assert!(
            c.certified_utils.amr < 1.0,
            "amr util {} should reflect its short duty cycle",
            c.certified_utils.amr
        );
    }
}

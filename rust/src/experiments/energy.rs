//! DVFS governor grid (`carfield dvfs`): the Fig. 6 deadline grids run
//! through the bound-driven governor.
//!
//! Deadlines are expressed in wall-clock nanoseconds (the cycle grids of
//! `experiments::autotune` priced at the 1GHz max-performance clock, so
//! the numbers line up 1:1 with the cycle story). Slack-rich mixes land
//! on low-voltage points at a large modeled energy saving vs `max_perf`;
//! tight deadlines pin to 1.1V; deadlines below the bound floor exhaust
//! with the closest miss named — and every governed point is provably
//! admissible, confirmed by one validating simulation with measured
//! energy columns.

use crate::coordinator::Scenario;
use crate::power::governor::{self, GovernError, GovernorChoice, GovernorValidation};
use crate::soc::clock::Cycle;
use crate::soc::power::NOMINAL_V;

/// Deadlines swept for the fig6a host mix, in nanoseconds. Mirrors the
/// autotune cycle grid at the 1GHz peak clock; the 430us point is the
/// pin-to-peak showcase (the tightest admitting bound is ~413us at
/// 1.1V, so no lower voltage can carry it).
pub const HOST_DEADLINES_NS: [f64; 6] = [
    350_000.0,
    430_000.0,
    550_000.0,
    800_000.0,
    1_200_000.0,
    2_500_000.0,
];

/// Deadline for the fig6b cluster mix (ns). Generous enough to admit
/// from the second grid step up (the bound floor is ~154k cycles), so
/// the energy argmin lands sub-nominal; the best-effort vector domain
/// is floored on every candidate, which is also what keeps high-voltage
/// candidates inside the envelope (uniform 1.1V — 747mW AMR + 600mW
/// vector — would bust 1.2W).
pub const CLUSTER_DEADLINE_NS: f64 = 400_000.0;

fn with_ns_deadline(mut s: Scenario, deadline_ns: f64) -> Scenario {
    for t in s.tasks.iter_mut() {
        if t.criticality.is_time_critical() {
            t.deadline = 0;
            t.deadline_ns = deadline_ns;
        }
    }
    s
}

/// The fig6a reference mix with a wall-clock deadline.
pub fn reference_mix_ns(deadline_ns: f64) -> Scenario {
    with_ns_deadline(crate::experiments::autotune::reference_mix(0), deadline_ns)
}

/// The fig6b cluster mix with a wall-clock deadline.
pub fn cluster_mix_ns(deadline_ns: f64) -> Scenario {
    with_ns_deadline(crate::experiments::autotune::cluster_mix(0), deadline_ns)
}

/// One mix's governor verdict + validating simulation.
pub struct DvfsRow {
    pub mix: String,
    pub deadline_ns: f64,
    pub outcome: Result<GovernorChoice, GovernError>,
    pub validation: Option<GovernorValidation>,
}

pub struct DvfsResult {
    pub rows: Vec<DvfsRow>,
    /// Mixes the governor found an admissible point for.
    pub governed: usize,
    /// Analytic admission evaluations across every search.
    pub total_evaluations: u64,
    /// Voltage points searched across every mix.
    pub total_points: u64,
    /// Wall-clock of the analytic searches only (no simulation).
    pub search_seconds: f64,
    /// Validation-simulation cycles (bench throughput metric).
    pub sim_cycles: Cycle,
}

impl DvfsResult {
    /// Every governed winner inside the envelope and confirmed by its
    /// validating simulation (measured <= bound, deadlines met, measured
    /// power <= 1.2W). Exhausted rows are vacuously fine.
    pub fn all_confirmed(&self) -> bool {
        self.rows.iter().all(|r| match (&r.outcome, &r.validation) {
            (Ok(c), Some(v)) => c.modeled.within_envelope() && v.confirmed(),
            (Ok(_), None) => false,
            (Err(_), _) => true,
        })
    }

    /// Best modeled energy saving among sub-nominal (< 0.8V system)
    /// winners: `(saving %, winner system voltage)`.
    pub fn best_sub_nominal_saving(&self) -> Option<(f64, f64)> {
        self.rows
            .iter()
            .filter_map(|r| r.outcome.as_ref().ok())
            .filter(|c| c.op.v_system < NOMINAL_V)
            .filter_map(|c| c.energy_saved_pct().map(|s| (s, c.op.v_system)))
            .max_by(|a, b| a.0.partial_cmp(&b.0).expect("savings are finite"))
    }
}

/// The grid's scenario list.
fn grid() -> Vec<(Scenario, f64)> {
    let mut mixes: Vec<(Scenario, f64)> = HOST_DEADLINES_NS
        .iter()
        .map(|&ns| (reference_mix_ns(ns), ns))
        .collect();
    mixes.push((cluster_mix_ns(CLUSTER_DEADLINE_NS), CLUSTER_DEADLINE_NS));
    mixes
}

pub fn run() -> DvfsResult {
    let mut rows = Vec::new();
    let mut governed = 0usize;
    let mut total_evaluations = 0u64;
    let mut total_points = 0u64;
    let mut search_seconds = 0.0f64;
    let mut sim_cycles = 0;
    for (scenario, deadline_ns) in grid() {
        let t0 = std::time::Instant::now();
        let outcome = governor::govern(&scenario);
        search_seconds += t0.elapsed().as_secs_f64();
        let validation = match &outcome {
            Ok(c) => {
                governed += 1;
                total_evaluations += c.evaluations;
                total_points += c.points_evaluated;
                let v = governor::validate(&scenario, c);
                sim_cycles += v.report.cycles;
                Some(v)
            }
            Err(GovernError::Exhausted {
                points_evaluated,
                evaluations,
                ..
            }) => {
                total_evaluations += evaluations;
                total_points += points_evaluated;
                None
            }
            Err(GovernError::NoDeadline) => None,
        };
        rows.push(DvfsRow {
            mix: scenario.name.clone(),
            deadline_ns,
            outcome,
            validation,
        });
    }
    DvfsResult {
        rows,
        governed,
        total_evaluations,
        total_points,
        search_seconds,
        sim_cycles,
    }
}

pub fn print(r: &DvfsResult) {
    use crate::coordinator::metrics::print_table;
    print_table(
        "DVFS governor: energy-minimal provably-safe operating points (fig6a/fig6b deadline grids; E vs the max_perf baseline)",
        &[
            "mix", "deadline", "point", "tuning", "bound", "P model", "E model",
            "saved", "sim: measured <= bound / P measured",
        ],
        &r.rows
            .iter()
            .map(|row| {
                let (point, tuning, bound, p_model, e_model, saved) = match &row.outcome {
                    Ok(c) => (
                        c.op.describe(),
                        c.tuning.describe(),
                        c.checks_ns
                            .iter()
                            .map(|(_, b, _)| format!("{b:.0}ns"))
                            .collect::<Vec<_>>()
                            .join("; "),
                        format!("{:.0}mW", c.modeled.total_power_mw),
                        format!("{:.3}mJ", c.modeled.total_energy_mj),
                        c.energy_saved_pct()
                            .map_or("-".to_string(), |s| format!("{s:.0}%")),
                    ),
                    Err(e) => (
                        "EXHAUSTED".to_string(),
                        e.to_string(),
                        "-".to_string(),
                        "-".to_string(),
                        "-".to_string(),
                        "-".to_string(),
                    ),
                };
                let sim = match &row.validation {
                    Some(v) => {
                        let checks = v
                            .checks
                            .iter()
                            .map(|(task, measured, bound)| {
                                format!(
                                    "{task}: {measured} <= {bound}{}",
                                    if *measured <= *bound { "" } else { " VIOLATED" }
                                )
                            })
                            .collect::<Vec<_>>()
                            .join("; ");
                        format!(
                            "{checks} / {:.0}mW{}",
                            v.measured.total_power_mw,
                            if v.measured.within_envelope() {
                                ""
                            } else {
                                " OVER ENVELOPE"
                            }
                        )
                    }
                    None => "-".to_string(),
                };
                vec![
                    row.mix.clone(),
                    format!("{:.0}us", row.deadline_ns / 1e3),
                    point,
                    tuning,
                    bound,
                    p_model,
                    e_model,
                    saved,
                    sim,
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!(
        "\nmixes governed: {}/{}; {} voltage points, {} analytic evaluations in {:.1} ms \
         ({:.0} points/sec); all winners confirmed: {}",
        r.governed,
        r.rows.len(),
        r.total_points,
        r.total_evaluations,
        r.search_seconds * 1e3,
        r.total_points as f64 / r.search_seconds.max(1e-9),
        r.all_confirmed()
    );
    if let Some((saving, v)) = r.best_sub_nominal_saving() {
        println!(
            "best sub-nominal showcase: {saving:.0}% modeled energy saved vs max_perf at {v:.2}V"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One grid execution, all shape properties (run() re-simulates
    /// every validation; the groups share one result).
    #[test]
    fn grid_shows_savings_pins_and_exhaustion() {
        let r = run();
        assert!(r.all_confirmed(), "a governed winner failed validation");
        assert!(r.governed >= 5, "only {} rows governed", r.governed);
        let host_row = |ns: f64| {
            r.rows
                .iter()
                .find(|row| row.mix == "fig6a-mix" && row.deadline_ns == ns)
                .expect("grid row")
        };
        // Below the bound floor: exhausted with the closest miss named.
        assert!(host_row(350_000.0).outcome.is_err());
        // No slack below peak: pinned to 1.1V, still provably admitted.
        let pinned = host_row(430_000.0).outcome.as_ref().expect("feasible at peak");
        assert_eq!(pinned.op.v_system, 1.1, "{}", pinned.op.describe());
        // Slack-rich: a deep sub-nominal point at a large saving.
        let slack = host_row(2_500_000.0).outcome.as_ref().expect("slack-rich");
        assert!(slack.op.v_system <= 0.65, "{}", slack.op.describe());
        assert!(
            slack.energy_saved_pct().expect("baseline") >= 30.0,
            "{:?}%",
            slack.energy_saved_pct()
        );
        let (best_saving, v) = r.best_sub_nominal_saving().expect("showcase row");
        assert!(best_saving >= 30.0 && v < NOMINAL_V);
        // More slack never selects a higher-voltage (higher-energy)
        // point: winner voltage is monotone along the deadline grid.
        let winners: Vec<f64> = HOST_DEADLINES_NS
            .iter()
            .filter_map(|&ns| {
                host_row(ns)
                    .outcome
                    .as_ref()
                    .ok()
                    .map(|c| c.op.v_system)
            })
            .collect();
        assert!(winners.len() >= 4);
        for w in winners.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "voltage not monotone: {winners:?}");
        }
        // The cluster mix governs with the best-effort vector domain
        // floored, and the energy argmin keeps the critical domains
        // sub-peak.
        let cluster = r
            .rows
            .iter()
            .find(|row| row.mix == "fig6b-mix")
            .expect("cluster row");
        let c = cluster.outcome.as_ref().expect("cluster governable");
        assert_eq!(c.op.v_vector, 0.6, "{}", c.op.describe());
        assert!(c.op.v_system < 1.1, "{}", c.op.describe());
    }
}

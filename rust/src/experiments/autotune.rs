//! Autotune grid (`carfield autotune`): mixes admitted by the fixed
//! four-policy ladder vs the bound-driven tuner.
//!
//! Reference mixes are the Fig. 6 interference scenarios with deadlines
//! swept across the achievable range: loose deadlines are feasible on
//! the ladder itself, mid-range deadlines are rejected by *all four*
//! fixed policies yet admitted by a tighter throttle point the tuner
//! finds, and deadlines below the knob space's floor exhaust the search
//! with a documented best-effort report. Every admitted tuning is
//! confirmed by one real simulation (measured <= bound, deadline met).

use crate::coordinator::autotune::{self, TuneError, TuneOutcome, TuneValidation};
use crate::coordinator::task::Criticality;
use crate::coordinator::{IsolationPolicy, McTask, Scenario, Scheduler, SocTuning, Workload};
use crate::soc::amr::IntPrecision;
use crate::soc::clock::Cycle;
use crate::soc::dma::DmaJob;
use crate::soc::hostd::TctSpec;
use crate::soc::vector::FpFormat;

/// The four fixed regimes the tuner competes against.
pub const LADDER: [IsolationPolicy; 4] = [
    IsolationPolicy::NoIsolation,
    IsolationPolicy::TsuRegulation,
    IsolationPolicy::TsuPlusLlcPartition {
        tct_fraction_percent: 50,
    },
    IsolationPolicy::PrivatePaths,
];

/// Deadlines swept for the fig6a host mix (cycles).
pub const HOST_DEADLINES: [Cycle; 6] = [350_000, 450_000, 550_000, 800_000, 1_200_000, 2_500_000];

/// Deadline for the fig6b cluster mix (cycles).
pub const CLUSTER_DEADLINE: Cycle = 170_000;

/// The fig6a reference mix: a hard TCT with `deadline` against the
/// endless system-DMA interferer, starting from the ladder's strongest
/// throttle point.
pub fn reference_mix(deadline: Cycle) -> Scenario {
    Scenario::new("fig6a-mix", SocTuning::tsu_regulation())
        .with_task(
            McTask::new(
                "tct",
                Criticality::Hard,
                Workload::HostTct(TctSpec::fig6a()),
            )
            .with_deadline(deadline),
        )
        .with_task(McTask::new(
            "dma",
            Criticality::BestEffort,
            Workload::DmaCopy(DmaJob::interferer()),
        ))
}

/// The fig6b cluster mix: the safety AMR TCT sharing AXI + DCSPM with
/// the best-effort vector cluster.
pub fn cluster_mix(deadline: Cycle) -> Scenario {
    Scenario::new("fig6b-mix", SocTuning::tsu_regulation())
        .with_task(
            McTask::new(
                "amr-tct",
                Criticality::Safety,
                Workload::AmrMatMul {
                    precision: IntPrecision::Int8,
                    m: 96,
                    k: 96,
                    n: 96,
                    tile: 8,
                },
            )
            .with_deadline(deadline),
        )
        .with_task(McTask::new(
            "vec-nct",
            Criticality::BestEffort,
            Workload::VectorMatMul {
                format: FpFormat::Fp16,
                m: 256,
                k: 256,
                n: 256,
                tile: 32,
            },
        ))
}

/// One mix's ladder-vs-tuner comparison.
pub struct AutotuneRow {
    pub mix: String,
    pub deadline: Cycle,
    /// How many of the four fixed policies admit the mix.
    pub ladder_admits: usize,
    pub outcome: Result<TuneOutcome, TuneError>,
    /// Simulation-backed confirmation of an admitted tuning.
    pub validation: Option<TuneValidation>,
}

pub struct AutotuneResult {
    pub rows: Vec<AutotuneRow>,
    /// Mixes at least one fixed policy admits.
    pub ladder_admitted: usize,
    /// Mixes the tuner admits.
    pub tuned_admitted: usize,
    /// Analytic evaluations across every search.
    pub total_evaluations: u64,
    /// Mean evaluations per successfully tuned mix.
    pub mean_iterations: f64,
    /// Wall-clock of the analytic searches only (no simulation).
    pub search_seconds: f64,
    pub evals_per_sec: f64,
    /// Validation-simulation cycles (bench throughput metric).
    pub sim_cycles: Cycle,
}

/// The grid's scenario list.
fn grid() -> Vec<Scenario> {
    let mut mixes: Vec<Scenario> = HOST_DEADLINES.iter().map(|&d| reference_mix(d)).collect();
    mixes.push(cluster_mix(CLUSTER_DEADLINE));
    mixes
}

pub fn run() -> AutotuneResult {
    let mut rows = Vec::new();
    let mut total_evaluations = 0u64;
    let mut tuned_admitted = 0usize;
    let mut ladder_admitted = 0usize;
    let mut sim_cycles = 0;
    let mut search_seconds = 0.0f64;
    for scenario in grid() {
        let ladder_admits = LADDER
            .iter()
            .filter(|&&p| Scheduler::admit(&scenario.clone().with_tuning(p)).admitted)
            .count();
        if ladder_admits > 0 {
            ladder_admitted += 1;
        }
        // Time only the analytic search; the validating simulation below
        // is accounted separately (sim_cycles).
        let t0 = std::time::Instant::now();
        let outcome = autotune::autotune(&scenario);
        search_seconds += t0.elapsed().as_secs_f64();
        let deadline = scenario
            .tasks
            .iter()
            .map(|t| t.deadline)
            .find(|&d| d > 0)
            .unwrap_or(0);
        let validation = match &outcome {
            Ok(o) => {
                total_evaluations += o.evaluations;
                tuned_admitted += 1;
                let v = autotune::validate(&scenario, o);
                sim_cycles += v.report.cycles;
                Some(v)
            }
            Err(e) => {
                total_evaluations += e.evaluations;
                None
            }
        };
        rows.push(AutotuneRow {
            mix: scenario.name.clone(),
            deadline,
            ladder_admits,
            outcome,
            validation,
        });
    }
    let mean_iterations = if tuned_admitted > 0 {
        rows.iter()
            .filter_map(|r| r.outcome.as_ref().ok())
            .map(|o| o.evaluations as f64)
            .sum::<f64>()
            / tuned_admitted as f64
    } else {
        0.0
    };
    let evals_per_sec = total_evaluations as f64 / search_seconds.max(1e-9);
    AutotuneResult {
        rows,
        ladder_admitted,
        tuned_admitted,
        total_evaluations,
        mean_iterations,
        search_seconds,
        evals_per_sec,
        sim_cycles,
    }
}

pub fn print(r: &AutotuneResult) {
    use crate::coordinator::metrics::print_table;
    print_table(
        "Autotune: fixed four-policy ladder vs bound-driven tuning (per mix: policies admitting / tuner verdict / sim confirmation)",
        &[
            "mix", "deadline", "ladder", "tuner", "tuning", "relaxed resource", "evals",
            "sim: measured <= bound",
        ],
        &r.rows
            .iter()
            .map(|row| {
                let (tuner, tuning, relaxed, evals) = match &row.outcome {
                    Ok(o) => (
                        format!("{:?}", o.strategy),
                        o.tuning.describe(),
                        o.relaxed.map_or("-".to_string(), |b| b.describe().to_string()),
                        o.evaluations.to_string(),
                    ),
                    Err(e) => (
                        "EXHAUSTED".to_string(),
                        format!(
                            "best bound {}",
                            e.best_bound.map_or("none".to_string(), |b| b.to_string())
                        ),
                        e.binding.describe().to_string(),
                        e.evaluations.to_string(),
                    ),
                };
                let sim = match &row.validation {
                    Some(v) => v
                        .checks
                        .iter()
                        .map(|(task, measured, bound)| {
                            format!(
                                "{task}: {measured} <= {bound}{}",
                                if *measured <= *bound { "" } else { " VIOLATED" }
                            )
                        })
                        .collect::<Vec<_>>()
                        .join("; "),
                    None => "-".to_string(),
                };
                vec![
                    row.mix.clone(),
                    row.deadline.to_string(),
                    format!("{}/4", row.ladder_admits),
                    tuner,
                    tuning,
                    relaxed,
                    evals,
                    sim,
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!(
        "\nmixes admitted: ladder {}/{} vs tuner {}/{}; {} analytic evaluations in {:.1} ms \
         ({:.0} evals/sec, mean {:.1} iterations to admission)",
        r.ladder_admitted,
        r.rows.len(),
        r.tuned_admitted,
        r.rows.len(),
        r.total_evaluations,
        r.search_seconds * 1e3,
        r.evals_per_sec,
        r.mean_iterations
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::autotune::SearchStrategy;
    use crate::coordinator::TsuKnobs;
    use crate::wcet::Resource;

    /// One grid execution, three property groups (the grid is
    /// deterministic and each run() re-simulates every validation, so
    /// the groups share one result instead of re-running it).
    #[test]
    fn tuner_admits_mixes_the_whole_ladder_rejects() {
        let r = run();
        assert!(
            r.tuned_admitted > r.ladder_admitted,
            "tuner {} vs ladder {}",
            r.tuned_admitted,
            r.ladder_admitted
        );
        assert!(r.total_evaluations > 0);
        assert!(r.mean_iterations >= 1.0);

        // The showcase mix: rejected by all four fixed policies, admitted
        // by the descent, which names the formerly binding resource and
        // lands on the least-restrictive feasible throttle; the
        // validating simulation confirms measured <= bound.
        let row = r
            .rows
            .iter()
            .find(|row| row.mix == "fig6a-mix" && row.deadline == 800_000)
            .expect("showcase row");
        assert_eq!(row.ladder_admits, 0, "every fixed policy must reject");
        let o = row.outcome.as_ref().expect("tunable");
        assert_eq!(o.strategy, SearchStrategy::CoordinateDescent);
        assert_eq!(o.relaxed, Some(Resource::HyperramChannel));
        assert_eq!(o.tuning.nct_tsu, TsuKnobs::regulated(8, 64, 512));
        let v = row.validation.as_ref().expect("validated");
        assert!(v.sound, "measured exceeded bound: {:?}", v.checks);
        assert!(v.deadlines_met);

        // The cluster mix relaxes the DCSPM port via the free aliasing
        // flip rather than by throttling anyone.
        let row = r
            .rows
            .iter()
            .find(|row| row.mix == "fig6b-mix")
            .expect("cluster row");
        let o = row.outcome.as_ref().expect("tunable");
        assert_eq!(o.relaxed, Some(Resource::DcspmPort));
        assert!(o.tuning.dcspm_private_paths, "aliasing flip expected");
        assert_eq!(o.strategy, SearchStrategy::CoordinateDescent);
        let v = row.validation.as_ref().expect("validated");
        assert!(v.confirmed(), "{:?}", v.checks);

        // A deadline below the knob-space floor exhausts the search with
        // a best-effort report and no validation simulation.
        let row = r
            .rows
            .iter()
            .find(|row| row.deadline == 350_000)
            .expect("floor row");
        assert_eq!(row.ladder_admits, 0);
        let e = row.outcome.as_ref().expect_err("below the knob floor");
        assert!(e.best_bound.is_some());
        assert!(row.validation.is_none());
    }

    #[test]
    fn grid_is_deterministic() {
        let a = run();
        let b = run();
        for (x, y) in a.rows.iter().zip(&b.rows) {
            match (&x.outcome, &y.outcome) {
                (Ok(ox), Ok(oy)) => {
                    assert_eq!(ox.tuning, oy.tuning);
                    assert_eq!(ox.evaluations, oy.evaluations);
                }
                (Err(ex), Err(ey)) => assert_eq!(ex.evaluations, ey.evaluations),
                _ => panic!("verdict flipped between runs"),
            }
        }
        assert_eq!(a.total_evaluations, b.total_evaluations);
    }
}

//! Bound gap attribution (`carfield trace`): the fig6a isolation grid
//! re-run with event tracing armed, the captured streams folded into
//! per-task interference ledgers, and every ledger row laid next to the
//! WCET engine's per-[`Resource`] `CostSplit` term.
//!
//! The point of the exercise: the completion bound and the measured
//! makespan decompose along the *same* resource axis, so the table
//! shows not just *that* the bound is pessimistic but *where* — which
//! shared resource's worst-case term carries the slack ("bound gap
//! attribution"). Each row also names the resource with the largest
//! bound − measured gap: that is the term a tighter analysis (or a
//! different isolation knob) would attack first.
//!
//! Three gates ride along, mirroring the other experiment smoke gates:
//!
//! 1. **Ledger invariant** — every measured column re-sums exactly to
//!    the task's observed makespan (nothing double-counted, nothing
//!    dropped);
//! 2. **Soundness per term** — no measured resource row exceeds its
//!    bound term (a missing bound term counts as zero, so interference
//!    the analysis failed to price at all fails loudly);
//! 3. **Non-perturbation** — the traced run's `ScenarioReport` is
//!    bit-identical to the untraced run's, and both sinks (JSONL,
//!    Perfetto `trace_event` JSON) pass the schema validator.

use crate::coordinator::metrics::print_table;
use crate::coordinator::{sweep, Scheduler};
use crate::experiments::fig6a;
use crate::soc::clock::Cycle;
use crate::trace::{to_jsonl, to_perfetto, validate_json, validate_jsonl, InterferenceLedger, TraceCapture};
use crate::wcet::{analyze, Resource, TaskBound};

/// Schema keys every JSONL event line must carry (kind-specific fields
/// ride on top).
pub const JSONL_KEYS: [&str; 8] = [
    "scenario",
    "kind",
    "sys",
    "at",
    "domain",
    "initiator",
    "lane",
    "tag",
];

/// Fixed print order for the attribution rows — structural interference
/// first, own compute and the fault budget last (matches the ledger's
/// and the breakdown's row order).
const ROW_ORDER: [Resource; 7] = [
    Resource::TsuShaping,
    Resource::WChannel,
    Resource::HyperramChannel,
    Resource::DcspmPort,
    Resource::Peripheral,
    Resource::Compute,
    Resource::FaultRecovery,
];

/// One resource's measured-vs-bound pairing for one task.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GapRow {
    pub resource: Resource,
    /// Ledger cycles attributed to this resource (system cycles).
    pub measured: Cycle,
    /// The breakdown's `CostSplit` term, as a lock-step cycle total —
    /// exact on the fig6a grid, which runs without an operating point.
    pub bound: Cycle,
}

impl GapRow {
    pub fn gap(&self) -> Cycle {
        self.bound.saturating_sub(self.measured)
    }
}

/// The gap-attribution table for one task of one scenario row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskAttribution {
    pub scenario: String,
    pub task: String,
    pub makespan: Cycle,
    /// Completion bound (k-fault term included), lock-step cycles.
    pub bound_total: Cycle,
    pub rows: Vec<GapRow>,
    /// The resource whose bound term carries the largest slack.
    pub most_pessimistic: Option<Resource>,
    /// Gate 1: the measured column re-sums to the makespan.
    pub sums_to_makespan: bool,
    /// Gate 2: no measured row exceeds its bound term, and the makespan
    /// stays under the total bound.
    pub sound: bool,
}

/// The whole `carfield trace` run: one attribution per fig6a grid row
/// plus the raw captures (for the sink files) and the gate verdicts.
pub struct TraceResult {
    pub rows: Vec<TaskAttribution>,
    pub captures: Vec<TraceCapture>,
    /// Gate 3a: every traced report was bit-identical to its untraced
    /// twin.
    pub reports_unperturbed: bool,
    /// Gate 3b: every capture's JSONL and Perfetto serializations
    /// passed the schema validator (`None` when they all did).
    pub sink_error: Option<String>,
    /// Total traced simulated cycles (bench throughput metric).
    pub sim_cycles: Cycle,
}

impl TraceResult {
    pub fn all_sound(&self) -> bool {
        self.rows.iter().all(|r| r.sound && r.sums_to_makespan)
    }

    pub fn sinks_valid(&self) -> bool {
        self.sink_error.is_none()
    }
}

/// Fold one task's ledger and bound into the attribution table. Rows
/// appear when either side is nonzero; `Compute` always appears (a task
/// with zero compute attribution would itself be suspicious).
fn attribution(
    scenario: &str,
    ledger: &crate::trace::TaskLedger,
    bound: &TaskBound,
) -> TaskAttribution {
    // Lock-step totals throughout: the fig6a grid runs without an
    // operating point, so system and uncore grids coincide and the
    // plain sum is exact (same convention the fig6a tables use).
    let bound_rows = bound.breakdown_with_fault();
    let term = |r: Resource| -> Cycle {
        bound_rows
            .iter()
            .find(|(res, _)| *res == r)
            .map(|(_, c)| c.lockstep_total())
            .unwrap_or(0)
    };
    let rows: Vec<GapRow> = ROW_ORDER
        .iter()
        .map(|&resource| GapRow {
            resource,
            measured: ledger.measured(resource),
            bound: term(resource),
        })
        .filter(|row| row.measured > 0 || row.bound > 0 || row.resource == Resource::Compute)
        .collect();
    let bound_total: Cycle = rows.iter().map(|r| r.bound).sum();
    let most_pessimistic = rows
        .iter()
        .max_by_key(|r| (r.gap(), /* stable tie-break */ std::cmp::Reverse(r.measured)))
        .filter(|r| r.gap() > 0)
        .map(|r| r.resource);
    let sound = ledger.makespan <= bound_total && rows.iter().all(|r| r.measured <= r.bound);
    TaskAttribution {
        scenario: scenario.to_string(),
        task: ledger.task.clone(),
        makespan: ledger.makespan,
        bound_total,
        rows,
        most_pessimistic,
        sums_to_makespan: ledger.sums_to_makespan(),
        sound,
    }
}

pub fn run() -> TraceResult {
    run_with_threads(sweep::default_threads())
}

pub fn run_with_threads(threads: usize) -> TraceResult {
    let grid = fig6a::scenario_grid();
    // Each worker runs its scenario twice — traced and untraced — so
    // the non-perturbation gate compares full reports, not samples.
    let runs = sweep::parallel_map(&grid, threads, |s| {
        let (report, cap) = Scheduler::run_traced(s);
        let baseline = Scheduler::run(s);
        (report, cap, baseline)
    });
    let mut rows = Vec::new();
    let mut captures = Vec::new();
    let mut reports_unperturbed = true;
    let mut sink_error = None;
    let mut sim_cycles = 0;
    for (scenario, (report, cap, baseline)) in grid.iter().zip(runs) {
        reports_unperturbed &= report == baseline;
        sim_cycles += report.cycles;
        if sink_error.is_none() {
            if let Err(e) = validate_json(&to_perfetto(&cap)) {
                sink_error = Some(format!("{}: perfetto: {e}", scenario.name));
            } else if let Err(e) = validate_jsonl(&to_jsonl(&cap), &JSONL_KEYS) {
                sink_error = Some(format!("{}: jsonl: {e}", scenario.name));
            }
        }
        let ledger = InterferenceLedger::build(&cap);
        let wcet = analyze(scenario);
        // Attribute every task the WCET engine bounded (on fig6a that
        // is the hard TCT; the endless interferer has no bound and no
        // finite makespan to decompose).
        for tb in &wcet.bounds {
            if tb.completion_bound.is_none() {
                continue;
            }
            if let Some(tl) = ledger.task(&tb.task) {
                rows.push(attribution(&scenario.name, tl, tb));
            }
        }
        captures.push(cap);
    }
    TraceResult {
        rows,
        captures,
        reports_unperturbed,
        sink_error,
        sim_cycles,
    }
}

/// Write both sinks per captured scenario into `dir` and return the
/// file count (`<scenario>.jsonl` + `<scenario>.perfetto.json`).
pub fn write_sinks(r: &TraceResult, dir: &str) -> std::io::Result<usize> {
    std::fs::create_dir_all(dir)?;
    let mut n = 0;
    for cap in &r.captures {
        let base = std::path::Path::new(dir).join(&cap.scenario);
        std::fs::write(base.with_extension("jsonl"), to_jsonl(cap))?;
        std::fs::write(base.with_extension("perfetto.json"), to_perfetto(cap))?;
        n += 2;
    }
    Ok(n)
}

pub fn print(r: &TraceResult) {
    for a in &r.rows {
        print_table(
            &format!(
                "{} / {}: measured vs bound, per resource (makespan {}, bound {})",
                a.scenario, a.task, a.makespan, a.bound_total
            ),
            &["resource", "measured", "bound", "gap", "of bound"],
            &a.rows
                .iter()
                .map(|row| {
                    let share = if a.bound_total > 0 {
                        100.0 * row.gap() as f64 / a.bound_total as f64
                    } else {
                        0.0
                    };
                    vec![
                        row.resource.describe().to_string(),
                        row.measured.to_string(),
                        row.bound.to_string(),
                        row.gap().to_string(),
                        format!("{share:.1}%"),
                    ]
                })
                .collect::<Vec<_>>(),
        );
        match a.most_pessimistic {
            Some(res) => println!(
                "most pessimism: {} ({} of {} slack cycles){}",
                res.describe(),
                a.rows
                    .iter()
                    .find(|row| row.resource == res)
                    .map_or(0, GapRow::gap),
                a.bound_total.saturating_sub(a.makespan),
                if a.sound { "" } else { "  ** UNSOUND **" }
            ),
            None => println!("bound is exact (no slack)"),
        }
    }
    println!(
        "\n{} attribution row(s) over {} traced scenario(s); ledgers {}; reports {}; sinks {}",
        r.rows.len(),
        r.captures.len(),
        if r.all_sound() {
            "sum to makespan and stay under their bound terms"
        } else {
            "VIOLATED an invariant"
        },
        if r.reports_unperturbed {
            "bit-identical with tracing off"
        } else {
            "PERTURBED by tracing"
        },
        if r.sinks_valid() { "valid" } else { "INVALID" },
    );
    if let Some(e) = &r.sink_error {
        println!("sink validation error: {e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One grid execution, all acceptance properties (the grid is
    /// deterministic, so the assertions share a single run).
    #[test]
    fn fig6a_attribution_is_sound_and_unperturbed() {
        let r = run_with_threads(2);
        // One bounded task ("tct") per fig6a grid row.
        assert_eq!(r.rows.len(), fig6a::scenario_grid().len());
        assert!(r.all_sound(), "a ledger row broke an invariant");
        assert!(r.reports_unperturbed, "tracing perturbed a report");
        assert!(r.sinks_valid(), "{:?}", r.sink_error);
        for a in &r.rows {
            assert_eq!(a.task, "tct");
            assert!(a.makespan > 0);
            // Compute is always attributed: the TCT's think cycles are
            // real work, not interference.
            assert!(a.rows.iter().any(|row| {
                row.resource == Resource::Compute && row.measured > 0 && row.bound > 0
            }));
            // The bound is an upper bound with slack on this grid, so
            // something must carry the pessimism.
            assert!(a.bound_total >= a.makespan);
            assert!(a.most_pessimistic.is_some(), "{a:?}");
        }
        // The contended unregulated row's slack lives on the memory
        // path, not on compute: the structural per-access worst case
        // (full queue + every competitor's turn) rarely materializes.
        let unregulated = r
            .rows
            .iter()
            .find(|a| a.scenario.contains("unregulated"))
            .expect("fig6a unregulated row");
        assert!(
            matches!(
                unregulated.most_pessimistic,
                Some(Resource::HyperramChannel) | Some(Resource::WChannel)
            ),
            "{unregulated:?}"
        );
    }

    #[test]
    fn sink_files_land_on_disk() {
        let r = run_with_threads(1);
        let dir = std::env::temp_dir().join("carfield-trace-test");
        let dir = dir.to_str().expect("utf-8 temp path");
        let n = write_sinks(&r, dir).expect("write sinks");
        assert_eq!(n, 2 * r.captures.len());
        let first = std::path::Path::new(dir).join(format!("{}.jsonl", r.captures[0].scenario));
        assert!(first.exists());
        std::fs::remove_dir_all(dir).ok();
    }
}

//! Fig. 5 — voltage/frequency/power and performance/efficiency sweeps of
//! the AMR (a, b) and vector (c, d) clusters, 0.6V–1.1V.
//!
//! Anchor points from the paper:
//! - AMR: 304.9 GOPS @ 2b, 1.1V/900MHz (161.4 in DLM); 1.6 TOPS/W @
//!   0.6V/300MHz (1.1 in DLM).
//! - Vector: 122 GFLOPS @ FP8, 1.1V/1GHz; 1.1 TFLOPS/W @ 0.6V/250MHz.

use crate::soc::amr::{AmrCluster, AmrMode, IntPrecision};
use crate::soc::power::DvfsCurve;
use crate::soc::vector::{FpFormat, VectorCluster};

/// One sweep point for the AMR cluster.
#[derive(Debug, Clone)]
pub struct AmrPoint {
    pub v: f64,
    pub freq_mhz: f64,
    pub power_mw: f64,
    /// GOPS per precision in INDIP, same order as `IntPrecision::ALL`.
    pub gops_indip: Vec<f64>,
    pub gops_dlm: Vec<f64>,
    /// GOPS/W at 2b (the headline efficiency), INDIP and DLM.
    pub eff_2b_indip: f64,
    pub eff_2b_dlm: f64,
}

/// One sweep point for the vector cluster.
#[derive(Debug, Clone)]
pub struct VectorPoint {
    pub v: f64,
    pub freq_mhz: f64,
    pub power_mw: f64,
    /// GFLOPS per format (matmul), order of `FpFormat::ALL`.
    pub gflops: Vec<f64>,
    /// FFT GFLOPS at FP32 (the DSP series in Fig. 5c).
    pub fft_gflops_fp32: f64,
    pub eff_fp8: f64,
}

#[derive(Debug, Clone)]
pub struct Fig5Result {
    pub amr: Vec<AmrPoint>,
    pub vector: Vec<VectorPoint>,
}

/// Sweep voltages 0.6..=1.1 in 0.05 steps.
pub fn voltages() -> Vec<f64> {
    (0..=10).map(|i| 0.6 + i as f64 * 0.05).collect()
}

/// One grid point: both clusters evaluated at voltage `v`.
fn point(v: f64, amr_curve: &DvfsCurve, vec_curve: &DvfsCurve) -> (AmrPoint, VectorPoint) {
    let amr = AmrPoint {
        v,
        freq_mhz: amr_curve.freq_mhz(v),
        power_mw: amr_curve.power_at_v(v, 1.0),
        gops_indip: IntPrecision::ALL
            .iter()
            .map(|&p| AmrCluster::peak_gops(p, AmrMode::Indip, v))
            .collect(),
        gops_dlm: IntPrecision::ALL
            .iter()
            .map(|&p| AmrCluster::peak_gops(p, AmrMode::Dlm, v))
            .collect(),
        eff_2b_indip: AmrCluster::efficiency_gops_w(IntPrecision::Int2, AmrMode::Indip, v),
        eff_2b_dlm: AmrCluster::efficiency_gops_w(IntPrecision::Int2, AmrMode::Dlm, v),
    };
    let vector = VectorPoint {
        v,
        freq_mhz: vec_curve.freq_mhz(v),
        power_mw: vec_curve.power_at_v(v, 1.0),
        gflops: FpFormat::ALL
            .iter()
            .map(|&f| VectorCluster::peak_gflops(f, v))
            .collect(),
        fft_gflops_fp32: VectorCluster::peak_gflops(FpFormat::Fp32, v)
            * crate::soc::vector::FFT_UTIL,
        eff_fp8: VectorCluster::efficiency_gflops_w(FpFormat::Fp8, v),
    };
    (amr, vector)
}

pub fn run() -> Fig5Result {
    use crate::coordinator::sweep;
    let amr_curve = DvfsCurve::amr();
    let vec_curve = DvfsCurve::vector();
    // The grid is independent points like the other figures, but each
    // point is a handful of closed-form float ops — thread fan-out would
    // cost more than the work, so this sweep stays on the serial path
    // (threads = 1 short-circuits to a plain in-order map).
    let vs = voltages();
    let points = sweep::parallel_map(&vs, 1, |&v| point(v, &amr_curve, &vec_curve));
    let (amr, vector): (Vec<AmrPoint>, Vec<VectorPoint>) = points.into_iter().unzip();
    Fig5Result { amr, vector }
}

pub fn print(r: &Fig5Result) {
    use crate::coordinator::metrics::print_table;
    print_table(
        "Fig. 5a/b: AMR sweep (paper peaks: 304.9 GOPS @1.1V, 1607 GOPS/W @0.6V)",
        &["V", "MHz", "mW", "GOPS 8b", "GOPS 2b", "2b DLM", "GOPS/W 2b", "DLM"],
        &r.amr
            .iter()
            .map(|p| {
                vec![
                    format!("{:.2}", p.v),
                    format!("{:.0}", p.freq_mhz),
                    format!("{:.0}", p.power_mw),
                    format!("{:.1}", p.gops_indip[1]),
                    format!("{:.1}", p.gops_indip[6]),
                    format!("{:.1}", p.gops_dlm[6]),
                    format!("{:.0}", p.eff_2b_indip),
                    format!("{:.0}", p.eff_2b_dlm),
                ]
            })
            .collect::<Vec<_>>(),
    );
    print_table(
        "Fig. 5c/d: vector sweep (paper peaks: 122 GFLOPS FP8 @1.1V, 1069 GFLOPS/W @0.6V)",
        &["V", "MHz", "mW", "FP64", "FP32", "FP16", "FP8", "FFT32", "GF/W FP8"],
        &r.vector
            .iter()
            .map(|p| {
                vec![
                    format!("{:.2}", p.v),
                    format!("{:.0}", p.freq_mhz),
                    format!("{:.0}", p.power_mw),
                    format!("{:.1}", p.gflops[0]),
                    format!("{:.1}", p.gflops[1]),
                    format!("{:.1}", p.gflops[2]),
                    format!("{:.1}", p.gflops[4]),
                    format!("{:.1}", p.fft_gflops_fp32),
                    format!("{:.0}", p.eff_fp8),
                ]
            })
            .collect::<Vec<_>>(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_match_paper() {
        let r = run();
        let hi = r.amr.last().unwrap();
        assert!((hi.v - 1.1).abs() < 1e-9);
        // 2b is ALL[6].
        assert!((hi.gops_indip[6] - 304.9).abs() / 304.9 < 0.01, "{}", hi.gops_indip[6]);
        assert!((hi.gops_dlm[6] - 161.4).abs() / 161.4 < 0.01);
        let lo = &r.amr[0];
        assert!((lo.eff_2b_indip - 1607.0).abs() / 1607.0 < 0.05);
        assert!((lo.eff_2b_dlm - 1093.0).abs() / 1093.0 < 0.30, "{}", lo.eff_2b_dlm);

        let vhi = r.vector.last().unwrap();
        assert!((vhi.gflops[4] - 121.8).abs() / 121.8 < 0.01);
        let vlo = &r.vector[0];
        assert!((vlo.eff_fp8 - 1068.7).abs() / 1068.7 < 0.06, "{}", vlo.eff_fp8);
    }

    #[test]
    fn performance_monotonic_in_voltage() {
        let r = run();
        for w in r.amr.windows(2) {
            assert!(w[1].gops_indip[6] > w[0].gops_indip[6]);
        }
        for w in r.vector.windows(2) {
            assert!(w[1].gflops[4] > w[0].gflops[4]);
        }
    }

    #[test]
    fn efficiency_monotonic_down_in_voltage() {
        let r = run();
        for w in r.amr.windows(2) {
            assert!(w[1].eff_2b_indip < w[0].eff_2b_indip);
        }
        for w in r.vector.windows(2) {
            assert!(w[1].eff_fp8 < w[0].eff_fp8);
        }
    }

    #[test]
    fn precision_scaling_doubles() {
        let r = run();
        let hi = r.amr.last().unwrap();
        // int8 -> int4 -> int2 roughly doubles each step.
        let r84 = hi.gops_indip[4] / hi.gops_indip[1];
        let r42 = hi.gops_indip[6] / hi.gops_indip[4];
        assert!((1.7..2.3).contains(&r84), "{r84}");
        assert!((1.7..2.3).contains(&r42), "{r42}");
    }
}

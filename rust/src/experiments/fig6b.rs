//! Fig. 6b — AMR cluster (reliable-mode TCT) and vector cluster (NCT)
//! sharing AXI + DCSPM, both in double-buffering.
//!
//! The paper's four regimes:
//! - **R-E1** isolated: AMR alone, full performance;
//! - **R-E2** unregulated sharing: AMR drops 12.2x;
//! - **R-E3** TSU favours AMR: 95% of isolated, NCT degrades;
//! - **R-E4** DCSPM aliased private paths: both at isolated performance,
//!   zero overhead.

use crate::coordinator::task::Criticality;
use crate::coordinator::{sweep, McTask, Scenario, SocTuning, Workload};
use crate::soc::amr::IntPrecision;
use crate::soc::clock::Cycle;
use crate::soc::vector::FpFormat;

#[derive(Debug, Clone)]
pub struct Regime {
    pub label: &'static str,
    /// AMR effective MAC/cyc.
    pub amr_mac_per_cyc: f64,
    /// AMR performance as % of isolated.
    pub amr_pct_of_isolated: f64,
    /// Vector effective FLOP/cyc (0 when absent).
    pub vec_flop_per_cyc: f64,
    pub vec_pct_of_isolated: f64,
}

#[derive(Debug, Clone)]
pub struct Fig6bResult {
    pub regimes: Vec<Regime>,
    /// Total simulated cycles across the grid (bench throughput metric).
    pub sim_cycles: Cycle,
}

/// The AMR TCT: DLM (reliable mode), low arithmetic intensity so the
/// DMA stream matters — a streaming QNN layer shape.
fn amr_task() -> McTask {
    McTask::new(
        "amr-tct",
        Criticality::Safety,
        Workload::AmrMatMul {
            precision: IntPrecision::Int8,
            m: 96,
            k: 96,
            n: 96,
            tile: 8,
        },
    )
}

/// The vector NCT: a large-tile FP MatMul whose DMA bursts are long.
fn vector_task() -> McTask {
    McTask::new(
        "vec-nct",
        Criticality::BestEffort,
        Workload::VectorMatMul {
            format: FpFormat::Fp16,
            m: 256,
            k: 256,
            n: 256,
            tile: 32,
        },
    )
}

/// The figure's scenario grid, in fixed order: the two isolated
/// baselines, then the three sharing regimes.
pub fn scenario_grid() -> Vec<Scenario> {
    vec![
        Scenario::new("amr-isolated", SocTuning::no_isolation()).with_task(amr_task()),
        Scenario::new("vec-isolated", SocTuning::no_isolation()).with_task(vector_task()),
        Scenario::new("r-e2-unregulated", SocTuning::no_isolation())
            .with_task(amr_task())
            .with_task(vector_task()),
        Scenario::new("r-e3-tsu", SocTuning::tsu_regulation())
            .with_task(amr_task())
            .with_task(vector_task()),
        Scenario::new("r-e4-private-paths", SocTuning::private_paths())
            .with_task(amr_task())
            .with_task(vector_task()),
    ]
}

pub fn run() -> Fig6bResult {
    run_with_threads(sweep::default_threads())
}

/// Run the grid across up to `threads` workers (identical results for
/// any thread count).
pub fn run_with_threads(threads: usize) -> Fig6bResult {
    let grid = scenario_grid();
    let reports = sweep::run_scenarios(&grid, threads);
    let sim_cycles = reports.iter().map(|r| r.cycles).sum();
    let amr_of = |idx: usize| reports[idx].task("amr-tct").extra_value("mac_per_cyc").unwrap();
    let vec_of = |idx: usize| reports[idx].task("vec-nct").extra_value("flop_per_cyc").unwrap();
    let amr_iso = amr_of(0);
    let vec_iso = vec_of(1);
    let (amr_e2, vec_e2) = (amr_of(2), vec_of(2));
    let (amr_e3, vec_e3) = (amr_of(3), vec_of(3));
    let (amr_e4, vec_e4) = (amr_of(4), vec_of(4));
    let mk = |label, amr: f64, vec: f64| Regime {
        label,
        amr_mac_per_cyc: amr,
        amr_pct_of_isolated: amr / amr_iso * 100.0,
        vec_flop_per_cyc: vec,
        vec_pct_of_isolated: if vec > 0.0 { vec / vec_iso * 100.0 } else { 0.0 },
    };
    Fig6bResult {
        regimes: vec![
            mk("R-E1 isolated", amr_iso, 0.0),
            mk("R-E2 unregulated sharing", amr_e2, vec_e2),
            mk("R-E3 TSU favours AMR", amr_e3, vec_e3),
            mk("R-E4 DCSPM private paths", amr_e4, vec_e4),
        ],
        sim_cycles,
    }
}

pub fn print(r: &Fig6bResult) {
    use crate::coordinator::metrics::print_table;
    print_table(
        "Fig. 6b: AMR TCT vs vector NCT on shared AXI+DCSPM (paper: 12.2x drop, 95% with TSU, 100% with aliasing)",
        &["regime", "AMR MAC/cyc", "AMR % isolated", "vec FLOP/cyc", "vec % isolated"],
        &r.regimes
            .iter()
            .map(|x| {
                vec![
                    x.label.to_string(),
                    format!("{:.1}", x.amr_mac_per_cyc),
                    format!("{:.0}%", x.amr_pct_of_isolated),
                    format!("{:.1}", x.vec_flop_per_cyc),
                    if x.vec_flop_per_cyc > 0.0 {
                        format!("{:.0}%", x.vec_pct_of_isolated)
                    } else {
                        "-".into()
                    },
                ]
            })
            .collect::<Vec<_>>(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper() {
        let r = run();
        let e1 = &r.regimes[0];
        let e2 = &r.regimes[1];
        let e3 = &r.regimes[2];
        let e4 = &r.regimes[3];
        // R-E2: severe drop (paper 12.2x => ~8%; accept < 30%).
        assert!(
            e2.amr_pct_of_isolated < 30.0,
            "unregulated kept {:.0}%",
            e2.amr_pct_of_isolated
        );
        // R-E3: TSU restores most of it (paper 95%; accept > 80%) while
        // the vector NCT pays.
        assert!(
            e3.amr_pct_of_isolated > 80.0,
            "TSU restored only {:.0}%",
            e3.amr_pct_of_isolated
        );
        assert!(
            e3.vec_pct_of_isolated < e2.vec_pct_of_isolated,
            "NCT should degrade under regulation"
        );
        // R-E4: private paths restore ~everything for both.
        assert!(
            e4.amr_pct_of_isolated > 90.0,
            "private paths gave {:.0}%",
            e4.amr_pct_of_isolated
        );
        assert!(
            e4.vec_pct_of_isolated > 85.0,
            "vector should also be near-isolated, got {:.0}%",
            e4.vec_pct_of_isolated
        );
        assert!(e1.amr_mac_per_cyc > 0.0);
    }
}

//! Fig. 8 — comparison against SoA edge-AI and vector processors: peak
//! performance, energy efficiency and area efficiency per precision.
//!
//! Our columns come from the calibrated cluster + DVFS models evaluated
//! at the paper's corners; competitor columns are the published numbers
//! the paper compares against. Tests assert the paper's headline ratios
//! (e.g. 3.4x INDIP / 1.8x DLM over [10] at uniform 8/4/2b, 6.4x area
//! efficiency, 2.2x / 3x over [21] on FP16).

use crate::soc::amr::{AmrCluster, AmrMode, IntPrecision};
use crate::soc::vector::{FpFormat, VectorCluster};

/// Die areas from the paper (mm^2, Intel 16).
pub const AMR_AREA_MM2: f64 = 1.17;
pub const VECTOR_AREA_MM2: f64 = 1.14;

/// Our integer rows (per precision tier).
#[derive(Debug, Clone)]
pub struct IntRow {
    pub tier: &'static str,
    pub gops_indip: f64,
    pub gops_dlm: f64,
    pub gops_w_indip: f64,
    pub gops_w_dlm: f64,
    pub gops_mm2: f64,
}

/// Our FP rows.
#[derive(Debug, Clone)]
pub struct FpRow {
    pub fmt: FpFormat,
    pub gflops: f64,
    pub gflops_w: f64,
    pub gflops_mm2: f64,
}

/// Published competitor peaks used for the ratio claims.
#[derive(Debug, Clone)]
pub struct Competitor {
    pub name: &'static str,
    /// (8b, 4b, 2b) GOPS, zero when unsupported.
    pub int_gops: (f64, f64, f64),
    /// (8b, 4b, 2b) GOPS/mm2.
    pub int_gops_mm2: (f64, f64, f64),
    /// FP16 GFLOPS / GFLOPS/W / GFLOPS/mm2 (zero when n.a.).
    pub fp16: (f64, f64, f64),
}

#[derive(Debug, Clone)]
pub struct Fig8Result {
    pub int_rows: Vec<IntRow>,
    pub fp_rows: Vec<FpRow>,
    pub competitors: Vec<Competitor>,
}

pub fn competitors() -> Vec<Competitor> {
    vec![
        Competitor {
            name: "TCAS-I 24 [10]",
            int_gops: (26.0, 50.0, 90.0),
            int_gops_mm2: (11.8, 22.7, 40.9),
            fp16: (7.9, 120.0, 3.6),
        },
        Competitor {
            name: "JSSCC23 [18]",
            int_gops: (16.0, 0.0, 0.0),
            int_gops_mm2: (3.4, 0.0, 0.0),
            fp16: (0.0, 0.0, 0.0),
        },
        Competitor {
            name: "JSSCC22 [21]",
            int_gops: (0.0, 0.0, 0.0),
            int_gops_mm2: (0.0, 0.0, 0.0),
            fp16: (368.4, 209.5, 17.9),
        },
        Competitor {
            name: "ISSCC24 [22]",
            int_gops: (31.8, 0.0, 0.0),
            int_gops_mm2: (7.95, 0.0, 0.0),
            fp16: (25.3, 230.1, 6.3),
        },
    ]
}

pub fn run() -> Fig8Result {
    let tiers = [
        ("8b", IntPrecision::Int8),
        ("4b", IntPrecision::Int4),
        ("2b", IntPrecision::Int2),
    ];
    let int_rows = tiers
        .iter()
        .map(|&(tier, p)| {
            let gops_indip = AmrCluster::peak_gops(p, AmrMode::Indip, 1.1);
            let gops_dlm = AmrCluster::peak_gops(p, AmrMode::Dlm, 1.1);
            IntRow {
                tier,
                gops_indip,
                gops_dlm,
                gops_w_indip: AmrCluster::efficiency_gops_w(p, AmrMode::Indip, 0.6),
                gops_w_dlm: AmrCluster::efficiency_gops_w(p, AmrMode::Dlm, 0.6),
                gops_mm2: gops_indip / AMR_AREA_MM2,
            }
        })
        .collect();
    let fp_rows = [FpFormat::Fp64, FpFormat::Fp32, FpFormat::Fp16, FpFormat::Fp8]
        .iter()
        .map(|&f| {
            let g = VectorCluster::peak_gflops(f, 1.1);
            FpRow {
                fmt: f,
                gflops: g,
                gflops_w: VectorCluster::efficiency_gflops_w(f, 0.6),
                gflops_mm2: g / VECTOR_AREA_MM2,
            }
        })
        .collect();
    Fig8Result {
        int_rows,
        fp_rows,
        competitors: competitors(),
    }
}

pub fn print(r: &Fig8Result) {
    use crate::coordinator::metrics::print_table;
    print_table(
        "Fig. 8 (ours): AMR integer peaks (paper: 78.5/152.3/304.9 GOPS; 413.6/802.6/1607 GOPS/W; 67.1/130.2/260.7 GOPS/mm2)",
        &["tier", "GOPS", "GOPS DLM", "GOPS/W", "GOPS/W DLM", "GOPS/mm2"],
        &r.int_rows
            .iter()
            .map(|x| {
                vec![
                    x.tier.to_string(),
                    format!("{:.1}", x.gops_indip),
                    format!("{:.1}", x.gops_dlm),
                    format!("{:.0}", x.gops_w_indip),
                    format!("{:.0}", x.gops_w_dlm),
                    format!("{:.1}", x.gops_mm2),
                ]
            })
            .collect::<Vec<_>>(),
    );
    print_table(
        "Fig. 8 (ours): vector FP peaks (paper: 15.7/31.3/61.5/121.8 GFLOPS; ...; 13.7/27.5/54/106.8 GFLOPS/mm2)",
        &["fmt", "GFLOPS", "GFLOPS/W", "GFLOPS/mm2"],
        &r.fp_rows
            .iter()
            .map(|x| {
                vec![
                    format!("{:?}", x.fmt),
                    format!("{:.1}", x.gflops),
                    format!("{:.0}", x.gflops_w),
                    format!("{:.1}", x.gflops_mm2),
                ]
            })
            .collect::<Vec<_>>(),
    );
    // Headline ratios (paper quotes them at the 2b tier).
    let ours2 = &r.int_rows[2];
    let tcas = &r.competitors[0];
    println!(
        "vs [10] 2b: INDIP {:.1}x, DLM {:.1}x, area eff {:.1}x",
        ours2.gops_indip / tcas.int_gops.2,
        ours2.gops_dlm / tcas.int_gops.2,
        ours2.gops_mm2 / tcas.int_gops_mm2.2
    );
    let fp16 = r
        .fp_rows
        .iter()
        .find(|x| x.fmt == FpFormat::Fp16)
        .unwrap();
    let jsscc22 = &r.competitors[2];
    println!(
        "vs [21] FP16: energy eff {:.1}x, area eff {:.1}x",
        fp16.gflops_w / jsscc22.fp16.1,
        fp16.gflops_mm2 / jsscc22.fp16.2
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn our_numbers_match_paper() {
        let r = run();
        let pairs = [
            (r.int_rows[0].gops_indip, 78.5),
            (r.int_rows[1].gops_indip, 152.3),
            (r.int_rows[2].gops_indip, 304.9),
            (r.int_rows[2].gops_dlm, 161.4),
            (r.int_rows[2].gops_mm2, 260.7),
        ];
        for (got, want) in pairs {
            assert!((got - want).abs() / want < 0.02, "{got} vs {want}");
        }
        let fp8 = r.fp_rows.iter().find(|x| x.fmt == FpFormat::Fp8).unwrap();
        assert!((fp8.gflops - 121.8).abs() / 121.8 < 0.01);
        assert!((fp8.gflops_mm2 - 106.8).abs() / 106.8 < 0.01);
    }

    #[test]
    fn headline_ratios_match_paper() {
        let r = run();
        // Paper: "In DLM ... 1.8x better performance (3.4x in INDIP) on
        // uniform 8b/4b/2b MatMuls, with 6.4x better area efficiency" —
        // the quoted factors hold at the uniform 2b tier.
        let ours2 = &r.int_rows[2];
        let tcas = &r.competitors[0];
        let indip_ratio = ours2.gops_indip / tcas.int_gops.2;
        let dlm_ratio = ours2.gops_dlm / tcas.int_gops.2;
        let area_ratio = ours2.gops_mm2 / tcas.int_gops_mm2.2;
        assert!((indip_ratio - 3.4).abs() < 0.35, "{indip_ratio}");
        assert!((dlm_ratio - 1.8).abs() < 0.25, "{dlm_ratio}");
        assert!((area_ratio - 6.4).abs() < 0.8, "{area_ratio}");
        // Paper: vs [18] "2.6x higher performance in DLM" (8b, 16 GOPS).
        let ours8 = &r.int_rows[0];
        let jsscc23 = &r.competitors[1];
        let dlm_vs_18 = ours8.gops_dlm / jsscc23.int_gops.0;
        assert!((dlm_vs_18 - 2.6).abs() < 0.3, "{dlm_vs_18}");
        // Paper: vs [21] FP16 "2.2x and 3x higher energy and area eff".
        let fp16 = r.fp_rows.iter().find(|x| x.fmt == FpFormat::Fp16).unwrap();
        let jsscc22 = &r.competitors[2];
        assert!((fp16.gflops_w / jsscc22.fp16.1 - 2.2).abs() < 0.3);
        assert!((fp16.gflops_mm2 / jsscc22.fp16.2 - 3.0).abs() < 0.4);
    }
}

//! Bound-vs-measured validation table (`carfield wcet`).
//!
//! Runs the Fig. 6a and Fig. 6b scenario grids, computes the analytical
//! WCET bounds for every critical task, and reports measured worst case
//! vs bound for both the memory-latency and the completion-time bound.
//! Acceptance: every bound is *sound* (measured <= bound) and the
//! memory-latency bounds on the TSU-regulated rows are *tight*
//! (bound <= 2x the measured worst case). Completion bounds are
//! cache-cold worst cases: tight for the transfer-dominated cluster
//! rows, deliberately pessimistic for the host TCT whose warm
//! iterations hit the DPLLC (a sound static analysis cannot assume
//! cache hits in a shared partition).

use crate::coordinator::{sweep, Scenario, Scheduler};
use crate::experiments::{fig6a, fig6b};
use crate::soc::clock::Cycle;
use crate::wcet::{analyze, Resource};

/// One critical task in one grid scenario.
#[derive(Debug, Clone)]
pub struct BoundRow {
    pub scenario: String,
    pub task: String,
    /// Policy regulates NCT arrival (TSU or TSU+partition rows) — the
    /// rows the tightness criterion applies to.
    pub regulated_policy: bool,
    /// Measured worst single-transaction latency.
    pub measured_worst_mem: f64,
    pub mem_bound: Cycle,
    pub measured_makespan: Cycle,
    pub completion_bound: Option<Cycle>,
    pub binding: Resource,
}

impl BoundRow {
    pub fn mem_sound(&self) -> bool {
        self.measured_worst_mem <= self.mem_bound as f64
    }

    pub fn completion_sound(&self) -> bool {
        match self.completion_bound {
            Some(b) => self.measured_makespan <= b,
            None => true,
        }
    }

    /// Bound over measured worst (1.0 = exact, <= 2.0 = tight).
    pub fn mem_tightness(&self) -> f64 {
        self.mem_bound as f64 / self.measured_worst_mem.max(1.0)
    }

    pub fn completion_tightness(&self) -> f64 {
        match self.completion_bound {
            Some(b) => b as f64 / (self.measured_makespan.max(1)) as f64,
            None => f64::INFINITY,
        }
    }
}

#[derive(Debug, Clone)]
pub struct BoundsResult {
    pub rows: Vec<BoundRow>,
    /// Total simulated cycles for the measured side (bench metric).
    pub sim_cycles: Cycle,
    /// Mean memory-latency tightness across all rows.
    pub mean_tightness: f64,
}

/// The combined fig6a + fig6b scenario grid the table is computed over.
pub fn scenario_grid() -> Vec<Scenario> {
    fig6a::scenario_grid()
        .into_iter()
        .chain(fig6b::scenario_grid())
        .collect()
}

pub fn run() -> BoundsResult {
    run_with_threads(sweep::default_threads())
}

/// Measure the grids (parallel sweep) and bound them analytically.
pub fn run_with_threads(threads: usize) -> BoundsResult {
    let grid = scenario_grid();
    let reports = sweep::run_scenarios(&grid, threads);
    let sim_cycles = reports.iter().map(|r| r.cycles).sum();
    let mut rows = Vec::new();
    for (scenario, report) in grid.iter().zip(&reports) {
        let wr = analyze(scenario);
        for tb in &wr.bounds {
            let t = report.task(&tb.task);
            let measured = t
                .extra_value("access_max")
                .or_else(|| t.extra_value("mem_max"))
                .unwrap_or(0.0);
            let regulated_policy = scenario.tuning.nct_tsu.is_regulated();
            rows.push(BoundRow {
                scenario: scenario.name.clone(),
                task: tb.task.clone(),
                regulated_policy,
                measured_worst_mem: measured,
                mem_bound: tb.mem_cycles(scenario.clocks().as_ref()),
                measured_makespan: t.makespan,
                completion_bound: tb.completion_cycles(scenario.clocks().as_ref()),
                binding: tb.mem_binding,
            });
        }
    }
    let ratios: Vec<f64> = rows
        .iter()
        .filter(|r| r.measured_worst_mem > 0.0)
        .map(|r| r.mem_tightness())
        .collect();
    let mean_tightness = if ratios.is_empty() {
        0.0
    } else {
        ratios.iter().sum::<f64>() / ratios.len() as f64
    };
    BoundsResult {
        rows,
        sim_cycles,
        mean_tightness,
    }
}

pub fn print(r: &BoundsResult) {
    use crate::coordinator::metrics::print_table;
    print_table(
        "WCET bounds vs measured (fig6a + fig6b grids; sound: measured <= bound; tight on regulated rows: bound <= 2x measured)",
        &[
            "scenario", "task", "mem worst", "mem bound", "ratio", "makespan",
            "completion bound", "ratio", "binding resource",
        ],
        &r.rows
            .iter()
            .map(|row| {
                vec![
                    row.scenario.clone(),
                    row.task.clone(),
                    format!("{:.0}", row.measured_worst_mem),
                    row.mem_bound.to_string(),
                    format!(
                        "{:.2}x{}",
                        row.mem_tightness(),
                        if row.mem_sound() { "" } else { " UNSOUND" }
                    ),
                    row.measured_makespan.to_string(),
                    row.completion_bound
                        .map_or("endless".to_string(), |b| b.to_string()),
                    match row.completion_bound {
                        Some(_) => format!(
                            "{:.2}x{}",
                            row.completion_tightness(),
                            if row.completion_sound() { "" } else { " UNSOUND" }
                        ),
                        None => "-".to_string(),
                    },
                    format!("{:?}", row.binding),
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!(
        "\nmean memory-latency tightness: {:.2}x over {} rows ({} simulated cycles)",
        r.mean_tightness,
        r.rows.len(),
        r.sim_cycles
    );

    // Admission-control demo: the same deadline is feasible under TSU
    // regulation and provably infeasible unregulated.
    let (admit_ok, admit_bad) = admission_demo_scenarios();
    println!(
        "\n== bound-aware admission (deadline {} cycles)",
        admit_ok.tasks[0].deadline
    );
    println!("  {}", Scheduler::admit(&admit_ok).summary());
    println!("  {}", Scheduler::admit(&admit_bad).summary());
}

/// The fig6a "tsu-regulated" and "unregulated" scenarios with a deadline
/// the bound engine can prove feasible for the former only.
pub fn admission_demo_scenarios() -> (Scenario, Scenario) {
    const DEADLINE: u64 = 2_000_000;
    let mut grid = fig6a::scenario_grid();
    let mut take = |name: &str| {
        let idx = grid
            .iter()
            .position(|s| s.name == name)
            .unwrap_or_else(|| panic!("fig6a grid lost the `{name}` scenario"));
        let mut s = grid.swap_remove(idx);
        s.tasks[0].deadline = DEADLINE;
        s
    };
    (take("tsu-regulated"), take("unregulated"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_bounds_sound_everywhere_and_tight_on_regulated_rows() {
        let r = run();
        assert!(r.rows.len() >= 10, "expected a row per critical task");
        for row in &r.rows {
            assert!(
                row.mem_sound(),
                "{}::{} mem UNSOUND: measured {} > bound {}",
                row.scenario,
                row.task,
                row.measured_worst_mem,
                row.mem_bound
            );
            assert!(
                row.completion_sound(),
                "{}::{} completion UNSOUND: makespan {} > bound {:?}",
                row.scenario,
                row.task,
                row.measured_makespan,
                row.completion_bound
            );
            assert!(row.measured_makespan > 0, "{} task drained", row.scenario);
        }
        for row in r.rows.iter().filter(|r| r.regulated_policy) {
            assert!(
                row.measured_worst_mem > 0.0,
                "{} has latency samples",
                row.scenario
            );
            assert!(
                row.mem_tightness() <= 2.0,
                "{}::{} NOT TIGHT: bound {} > 2x measured {}",
                row.scenario,
                row.task,
                row.mem_bound,
                row.measured_worst_mem
            );
        }
        assert!(r.mean_tightness >= 1.0);
        assert!(r.sim_cycles > 0);
    }

    #[test]
    fn admission_demo_scenarios_disagree() {
        let (ok, bad) = admission_demo_scenarios();
        assert_eq!(ok.name, "tsu-regulated");
        assert_eq!(bad.name, "unregulated");
        assert!(Scheduler::admit(&ok).admitted);
        assert!(!Scheduler::admit(&bad).admitted);
    }
}

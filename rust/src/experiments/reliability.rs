//! Reliability grid (`carfield faults`): availability × deadline sweep
//! under deterministic fault injection with k-fault admission.
//!
//! The grid crosses the two Fig. 6 interference topologies with fault
//! rates, k-fault hypotheses and deadlines (including a derived
//! knife-edge deadline equal to each mix's fault-free bound, so the
//! k-term's effect on the verdict is visible by construction). Every
//! row is both *admitted analytically* — does the mix meet its deadline
//! with up to k recoveries priced in? — and *validated by one seeded
//! faulted simulation*: the measured-under-injection makespan must stay
//! under the k-fault completion bound. Rejections are attributed: if
//! the nominal bound fits the deadline but the k-fault bound does not,
//! the binding resource is [`Resource::FaultRecovery`] — faults, not
//! load, are what reject the mix.

use crate::coordinator::{FaultPlan, Scenario, Scheduler, ScrubConfig};
use crate::experiments::autotune::{cluster_mix, reference_mix};
use crate::soc::clock::Cycle;
use crate::wcet::Resource;

/// AMR lockstep-mismatch rates swept (events per kilocycle window).
pub const FAULT_RATES: [f64; 3] = [0.0, 0.5, 2.0];

/// Re-execution hypotheses swept (faults the admission test must cover).
pub const K_FAULTS: [u32; 3] = [0, 1, 2];

/// The injection plan for one (rate, k) grid cell. Rates above zero
/// also arm the transient HyperRAM retry knob (denser retries at the
/// harsher rate) and the background ECC scrub engine, so the whole
/// fault surface scales together along the rate axis.
pub fn plan_for(seed: u64, rate: f64, k: u32) -> FaultPlan {
    let mut plan = FaultPlan::new(seed).with_amr_rate(rate).with_k(k);
    if rate > 0.0 {
        let per_line = if rate >= 1.0 { 2 } else { 1 };
        plan = plan
            .with_retries(64, per_line)
            .with_scrub(ScrubConfig::carfield());
    }
    plan
}

/// One grid cell: an admission verdict plus its seeded-sim validation.
#[derive(Debug, Clone, PartialEq)]
pub struct ReliabilityRow {
    pub mix: String,
    /// The critical task whose bound/makespan the row tracks.
    pub task: String,
    pub deadline: Cycle,
    pub rate: f64,
    pub k: u32,
    pub admitted: bool,
    /// k-fault completion bound for the critical task.
    pub bound: Option<Cycle>,
    /// Binding resource of the first rejection (`None` when admitted).
    pub binding: Option<Resource>,
    /// Rejected *because of the k-fault term* (nominal bound fits).
    pub fault_binding: bool,
    /// Measured makespan under seeded injection.
    pub measured: Cycle,
    pub deadline_met: bool,
    pub faults_detected: u64,
    pub faults_silent: u64,
    pub recovery_cycles: u64,
    /// Measured-under-injection <= k-fault bound (vacuously true only
    /// for endless/unbounded tasks, which the grid does not contain).
    pub sound: bool,
}

pub struct ReliabilityResult {
    pub rows: Vec<ReliabilityRow>,
    /// Fraction of grid rows whose critical deadline held under
    /// injection — the measured availability across the sweep.
    pub availability: f64,
    /// (mix, deadline, rate) cells admitted at k=0 but rejected at k=1:
    /// the re-execution budget alone flips the verdict.
    pub k_flips: usize,
    /// Rejections attributed to [`Resource::FaultRecovery`].
    pub fault_bound_rejections: usize,
    /// Total simulated cycles (bench throughput metric).
    pub sim_cycles: Cycle,
}

impl ReliabilityResult {
    /// Every grid row's seeded simulation stayed under its k-fault bound.
    pub fn all_sound(&self) -> bool {
        self.rows.iter().all(|r| r.sound)
    }
}

/// The mix's fault-free completion bound — the knife-edge deadline.
fn nominal_bound(mix: &Scenario, task: &str) -> Cycle {
    let decision = Scheduler::admit(mix);
    let clocks = mix.clocks();
    decision
        .report
        .bound_for(task)
        .completion_cycles(clocks.as_ref())
        .expect("grid mixes are bounded")
}

/// The grid's mix list: (critical task, deadline, scenario builder).
fn grid() -> Vec<(&'static str, Cycle, fn(Cycle) -> Scenario)> {
    let host_edge = nominal_bound(&reference_mix(1), "tct");
    let cluster_edge = nominal_bound(&cluster_mix(1), "amr-tct");
    vec![
        ("tct", host_edge, reference_mix as fn(Cycle) -> Scenario),
        ("tct", 2 * host_edge, reference_mix),
        ("amr-tct", cluster_edge, cluster_mix as fn(Cycle) -> Scenario),
        ("amr-tct", 2 * cluster_edge, cluster_mix),
    ]
}

pub fn run() -> ReliabilityResult {
    let mut rows = Vec::new();
    let mut sim_cycles = 0;
    for (mix_idx, (task, deadline, build)) in grid().into_iter().enumerate() {
        for (rate_idx, &rate) in FAULT_RATES.iter().enumerate() {
            for &k in &K_FAULTS {
                // Deterministic per-cell seed: position in the grid, no
                // wall clock anywhere.
                let seed = 0x5EED + (mix_idx as u64) * 100 + (rate_idx as u64) * 10 + k as u64;
                let scenario = build(deadline).with_faults(plan_for(seed, rate, k));
                let decision = Scheduler::admit(&scenario);
                let clocks = scenario.clocks();
                let bound = decision
                    .report
                    .bound_for(task)
                    .completion_cycles(clocks.as_ref());
                let rejection = decision.rejections.first();
                let report = Scheduler::run(&scenario);
                sim_cycles += report.cycles;
                let tr = report.task(task);
                let extra = |key: &str| tr.extra_value(key).unwrap_or(0.0) as u64;
                rows.push(ReliabilityRow {
                    mix: scenario.name.clone(),
                    task: task.to_string(),
                    deadline,
                    rate,
                    k,
                    admitted: decision.admitted,
                    bound,
                    binding: rejection.map(|r| r.binding),
                    fault_binding: rejection.is_some_and(|r| r.binding == Resource::FaultRecovery),
                    measured: tr.makespan,
                    deadline_met: tr.deadline_met,
                    faults_detected: extra("faults"),
                    faults_silent: extra("faults_silent"),
                    recovery_cycles: extra("recovery_cycles"),
                    sound: match bound {
                        Some(b) => tr.makespan > 0 && tr.makespan <= b,
                        None => false,
                    },
                });
            }
        }
    }
    let availability =
        rows.iter().filter(|r| r.deadline_met).count() as f64 / rows.len().max(1) as f64;
    let k_flips = rows
        .iter()
        .filter(|r| r.k == 0 && r.admitted)
        .filter(|r0| {
            rows.iter().any(|r1| {
                r1.k == 1
                    && !r1.admitted
                    && r1.mix == r0.mix
                    && r1.deadline == r0.deadline
                    && r1.rate == r0.rate
            })
        })
        .count();
    let fault_bound_rejections = rows.iter().filter(|r| r.fault_binding).count();
    ReliabilityResult {
        rows,
        availability,
        k_flips,
        fault_bound_rejections,
        sim_cycles,
    }
}

pub fn print(r: &ReliabilityResult) {
    use crate::coordinator::metrics::print_table;
    print_table(
        "Reliability: k-fault admission vs seeded injection (availability × deadline grid)",
        &[
            "mix", "deadline", "rate", "k", "verdict", "bound", "measured", "faults",
            "recovery", "sound",
        ],
        &r.rows
            .iter()
            .map(|row| {
                let verdict = if row.admitted {
                    "ADMIT".to_string()
                } else {
                    format!("REJECT ({})", row.binding.map_or("?", |b| b.describe()))
                };
                vec![
                    row.mix.clone(),
                    row.deadline.to_string(),
                    format!("{:.1}/kcyc", row.rate),
                    row.k.to_string(),
                    verdict,
                    row.bound.map_or("-".to_string(), |b| b.to_string()),
                    format!(
                        "{}{}",
                        row.measured,
                        if row.deadline_met { "" } else { " LATE" }
                    ),
                    format!("{}+{}s", row.faults_detected, row.faults_silent),
                    row.recovery_cycles.to_string(),
                    if row.sound { "yes" } else { "VIOLATED" }.to_string(),
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!(
        "\navailability {:.3} across {} rows; {} k-flip cell(s) (admitted at k=0, rejected at \
         k=1); {} rejection(s) bound by the fault-recovery budget",
        r.availability,
        r.rows.len(),
        r.k_flips,
        r.fault_bound_rejections
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One grid execution, all acceptance properties (the grid is
    /// deterministic, so the assertions share a single run).
    #[test]
    fn grid_is_sound_and_the_k_term_flips_a_knife_edge_cell() {
        let r = run();
        assert!(!r.rows.is_empty());
        assert!(r.all_sound(), "a seeded sim exceeded its k-fault bound");

        // The knife-edge deadline equals the fault-free bound, so the
        // k=1 hypothesis alone must flip the lockstep mix's verdict —
        // and the rejection must be attributed to the recovery budget,
        // not to nominal load.
        assert!(r.k_flips >= 1, "no admitted@k=0 -> rejected@k=1 cell");
        assert!(r.fault_bound_rejections >= 1);
        let edge = r
            .rows
            .iter()
            .find(|row| row.mix == "fig6b-mix" && row.rate == 0.0 && row.k == 1)
            .expect("knife-edge cell");
        assert!(!edge.admitted && edge.fault_binding, "{edge:?}");

        // Quiet cells (rate 0, k 0) really are quiet: nothing injected,
        // nothing recovered, verdict is the fault-free engine's.
        for row in r.rows.iter().filter(|row| row.rate == 0.0 && row.k == 0) {
            assert!(row.admitted, "{row:?}");
            assert_eq!(row.faults_detected + row.faults_silent, 0);
            assert_eq!(row.recovery_cycles, 0);
        }

        // The harsh column actually injects on the lockstep mix and the
        // seeded recovery cycles are visible in the report.
        let harsh = r
            .rows
            .iter()
            .find(|row| row.mix == "fig6b-mix" && row.rate == 2.0 && row.k == 2)
            .expect("harsh cell");
        assert!(harsh.faults_detected >= 1, "{harsh:?}");
        assert!(harsh.recovery_cycles > 0);
        assert!(harsh.sound);
    }
}

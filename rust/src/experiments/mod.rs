//! Experiment harnesses — one per table/figure in the paper's evaluation.
//!
//! Each module regenerates the corresponding figure's rows/series from
//! the simulator and returns structured results (so tests and benches can
//! assert the *shape*: who wins, by roughly what factor, where crossovers
//! fall). `cargo bench` targets print them; `carfield fig*` runs them
//! from the CLI. `bounds` is the WCET validation table (`carfield
//! wcet`): analytical bound vs measured worst case on the Fig. 6 grids.
//! `autotune` is the ladder-vs-tuner comparison (`carfield autotune`):
//! mixes admitted by the fixed four policies vs the bound-driven search.
//! `energy` is the DVFS governor grid (`carfield dvfs`): deadline grids
//! through the energy-minimal provably-safe operating-point search.
//! `reliability` is the fault-injection grid (`carfield faults`):
//! k-fault admission verdicts validated by seeded faulted simulation
//! across an availability × deadline sweep. `trace` is the bound
//! gap-attribution table (`carfield trace`): the fig6a grid traced into
//! per-resource interference ledgers laid next to the WCET breakdown.
//! `workingset` is the partition-fit flip demo (`carfield workingset`):
//! traced working-set profiles minted into partition certificates that
//! admit a fig6a mix every cold bound rejects, simulation-validated.
//! `packing` is the admission-service demo (`carfield pack`): a seeded
//! request queue driven through the sharded bound-aware packing
//! pipeline (heuristic race, governed prefix, batched validation
//! sweep), gated on co-residency, admission and validation soundness.

pub mod autotune;
pub mod bounds;
pub mod energy;
pub mod fig3c;
pub mod fig5;
pub mod fig6a;
pub mod fig6b;
pub mod fig7;
pub mod fig8;
pub mod micro;
pub mod packing;
pub mod reliability;
pub mod trace;
pub mod workingset;

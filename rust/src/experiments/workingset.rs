//! Working-set observability (`carfield workingset`): the fig6a
//! isolation grid traced into per-task [`WorkingSetProfile`]s, a
//! [`PartitionCertificate`] minted from the TCT's measured fit curve,
//! and the certificate driving the autotuner's parked `tct_sets` axis
//! through an admission flip no cold bound can produce.
//!
//! The demo runs in four phases:
//!
//! 1. **Profile** — every fig6a grid scenario re-run with tracing armed,
//!    each capture folded into per-task profiles. Gate: every profile's
//!    per-set rows re-sum *exactly* to the line fills counted straight
//!    off the raw event stream (the same exact-sum discipline as the
//!    interference ledger).
//! 2. **Mint** — the TCT profile's partition-fit curve certifies every
//!    exclusive partition size clearing the warm-hit threshold, keyed by
//!    workload shape and stored in a [`CertificateLibrary`].
//! 3. **Flip** — the cold knob space's bound floor `B_cold` for the
//!    fig6a reference mix is measured (every throttle/aliasing point
//!    exhausts at a 1-cycle deadline, reporting its best near-miss), and
//!    a demo deadline is pinned *between* the certified warm bound and
//!    `B_cold`: every cold-bound `tct_sets` variant of the winning
//!    tuning still rejects, while [`autotune_certified`] admits via the
//!    certificate-backed warm path ([`SearchStrategy::CertifiedPartition`]).
//! 4. **Validate** — one traced simulation of the certified winner:
//!    makespan within the warm completion bound, deadline met, and the
//!    partitioned run's observed fills at most (in fact exactly) the
//!    certificate's `max_fills` — the replay is exact arithmetic, not an
//!    estimate.
//!
//! [`autotune_certified`]: crate::coordinator::autotune::autotune_certified

use crate::coordinator::autotune::{self, SearchStrategy, TuneError, TuneOutcome};
use crate::coordinator::metrics::print_table;
use crate::coordinator::{sweep, Scheduler, SocTuning};
use crate::experiments::{autotune as mixes, fig6a};
use crate::soc::clock::Cycle;
use crate::soc::hostd::TctSpec;
use crate::trace::{
    profiles_of, shape_key, CertificateLibrary, PartitionCertificate, TraceKind,
    WorkingSetProfile, CERT_WARM_THRESHOLD_PPM,
};

/// One profiled task of one traced grid scenario.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProfileRow {
    pub scenario: String,
    pub profile: WorkingSetProfile,
    /// Line-fill allocations counted directly off the raw event stream
    /// (independently of the profile fold).
    pub observed_fills: u64,
    /// Gate 1: `sums_exactly()` holds *and* the profile's fill total
    /// matches the raw count.
    pub exact: bool,
}

/// One cold-bound admission verdict at the demo deadline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ColdVerdict {
    pub tct_sets: usize,
    /// The cold completion bound for the TCT under this variant.
    pub bound: Option<Cycle>,
    pub admitted: bool,
}

/// The validating simulation of the certified winner.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WsValidation {
    pub makespan: Cycle,
    /// The certificate-backed warm completion bound the winner carries.
    pub warm_bound: Cycle,
    pub deadline: Cycle,
    pub certified_sets: u32,
    /// The certificate's fill budget for that size.
    pub max_fills: u64,
    /// Fills the partitioned traced run actually performed.
    pub partitioned_fills: u64,
    pub within_bound: bool,
    pub deadline_met: bool,
    /// The replay-exactness showcase: observed == predicted.
    pub fills_exact: bool,
}

/// The whole `carfield workingset` run.
pub struct WorkingSetResult {
    pub profile_rows: Vec<ProfileRow>,
    pub certificate: Option<PartitionCertificate>,
    /// Best (smallest) cold completion bound anywhere in the knob
    /// space — the floor the flip must dip under.
    pub cold_floor: Cycle,
    /// Evaluations the cold exhaustion spent establishing the floor.
    pub cold_evaluations: u64,
    /// The demo deadline, pinned between warm bound and cold floor.
    pub deadline: Cycle,
    /// Cold admission verdicts at `deadline`, one per `tct_sets`
    /// setting (0 plus every certified size) of the winning tuning.
    pub cold_verdicts: Vec<ColdVerdict>,
    pub outcome: Result<TuneOutcome, TuneError>,
    pub validation: Option<WsValidation>,
    /// Total simulated cycles (bench throughput metric).
    pub sim_cycles: Cycle,
}

impl WorkingSetResult {
    /// Gate 1: every profile row re-sums exactly.
    pub fn profiles_exact(&self) -> bool {
        !self.profile_rows.is_empty() && self.profile_rows.iter().all(|r| r.exact)
    }

    /// Gate 3: every cold `tct_sets` variant rejects while the
    /// certified search admits.
    pub fn flip_demonstrated(&self) -> bool {
        matches!(&self.outcome, Ok(o) if o.strategy == SearchStrategy::CertifiedPartition)
            && !self.cold_verdicts.is_empty()
            && self.cold_verdicts.iter().all(|v| !v.admitted)
    }

    /// Gate 4: the winner's simulation confirmed bound, deadline and
    /// fill budget.
    pub fn validated(&self) -> bool {
        self.validation
            .as_ref()
            .is_some_and(|v| v.within_bound && v.deadline_met && v.partitioned_fills <= v.max_fills)
    }
}

/// Raw per-initiator fill count, straight off the events (the
/// cross-check side of gate 1).
fn raw_fills(cap: &crate::trace::TraceCapture, initiator: crate::soc::axi::InitiatorId) -> u64 {
    cap.events
        .iter()
        .filter(|e| {
            e.initiator == initiator && matches!(e.kind, TraceKind::LineFill { hit: false, .. })
        })
        .count() as u64
}

pub fn run() -> WorkingSetResult {
    run_with_threads(sweep::default_threads())
}

pub fn run_with_threads(threads: usize) -> WorkingSetResult {
    // Phase 1: trace the fig6a grid and fold every capture.
    let grid = fig6a::scenario_grid();
    let runs = sweep::parallel_map(&grid, threads, Scheduler::run_traced);
    let mut profile_rows = Vec::new();
    let mut sim_cycles = 0;
    let mut tct_profile: Option<WorkingSetProfile> = None;
    for (scenario, (report, cap)) in grid.iter().zip(&runs) {
        sim_cycles += report.cycles;
        for profile in profiles_of(cap) {
            let observed_fills = raw_fills(cap, profile.initiator);
            let exact = profile.sums_exactly() && profile.fills == observed_fills;
            // The minting source: the TCT's stream under TSU regulation
            // (any row would do — the replayed stream is tuning-
            // independent — but the regulated row is the one the
            // reference mix starts from).
            if scenario.name == "tsu-regulated" && profile.task == "tct" {
                tct_profile = Some(profile.clone());
            }
            profile_rows.push(ProfileRow {
                scenario: scenario.name.clone(),
                profile,
                observed_fills,
                exact,
            });
        }
    }

    // Phase 2: mint the certificate for the fig6a TCT shape.
    let key = shape_key(&TctSpec::fig6a());
    let certificate = tct_profile
        .as_ref()
        .and_then(|p| PartitionCertificate::mint(p, &key));
    let mut lib = CertificateLibrary::new();
    if let Some(cert) = &certificate {
        lib.insert(cert.clone());
    }

    // Phase 3a: the cold knob space's bound floor — a 1-cycle deadline
    // forces exhaustion, whose near-miss report is the tightest cold
    // bound any throttle/aliasing point achieves.
    let (cold_floor, cold_evaluations) = match autotune::autotune(&mixes::reference_mix(1)) {
        Err(e) => (e.best_bound.unwrap_or(0), e.evaluations),
        // A 1-cycle deadline admitting is an engine regression; leave
        // the floor at 0 so every downstream gate fails loudly.
        Ok(_) => (0, 0),
    };

    // Phase 3b: probe the certified warm bound just under the floor,
    // then pin the demo deadline at the midpoint of the two bounds —
    // comfortably under everything cold, comfortably over warm.
    let warm_probe = (cold_floor > 1)
        .then(|| autotune::autotune_certified(&mixes::reference_mix(cold_floor - 1), &mut lib))
        .and_then(|o| o.ok());
    let probe_warm = warm_probe
        .as_ref()
        .and_then(|o| o.decision.report.bound_for("tct").completion_cycles(None))
        .unwrap_or(0);
    let deadline = if probe_warm > 0 && probe_warm < cold_floor {
        probe_warm + (cold_floor - probe_warm) / 2
    } else {
        cold_floor.saturating_sub(1).max(1)
    };

    // Phase 3c: the flip itself, at the demo deadline.
    let demo = mixes::reference_mix(deadline);
    let outcome = autotune::autotune_certified(&demo, &mut lib);
    let base = match &outcome {
        Ok(o) => o.tuning,
        Err(_) => demo.tuning,
    };
    let mut set_ladder: Vec<usize> = vec![0];
    if let Some(cert) = &certificate {
        set_ladder.extend(cert.entries.iter().map(|e| e.sets as usize));
    }
    let cold_verdicts: Vec<ColdVerdict> = set_ladder
        .into_iter()
        .map(|tct_sets| {
            let variant = demo.clone().with_tuning(SocTuning { tct_sets, ..base });
            let decision = Scheduler::admit(&variant);
            ColdVerdict {
                tct_sets,
                bound: decision.report.bound_for("tct").completion_cycles(None),
                admitted: decision.admitted,
            }
        })
        .collect();

    // Phase 4: one traced simulation of the certified winner.
    let validation = match (&outcome, &certificate) {
        (Ok(o), Some(cert)) => {
            let (report, cap) = Scheduler::run_traced(&demo.clone().with_tuning(o.tuning));
            sim_cycles += report.cycles;
            let makespan = report.task("tct").makespan;
            let warm_bound = o
                .decision
                .report
                .bound_for("tct")
                .completion_cycles(None)
                .unwrap_or(0);
            let certified_sets = o.tuning.tct_sets as u32;
            let max_fills = cert.entry_for(certified_sets).map_or(0, |e| e.max_fills);
            let partitioned_fills = profiles_of(&cap)
                .iter()
                .find(|p| p.task == "tct")
                .map_or(0, |p| p.fills);
            Some(WsValidation {
                makespan,
                warm_bound,
                deadline,
                certified_sets,
                max_fills,
                partitioned_fills,
                within_bound: warm_bound > 0 && makespan <= warm_bound,
                deadline_met: report.all_deadlines_met(),
                fills_exact: partitioned_fills == max_fills,
            })
        }
        _ => None,
    };

    WorkingSetResult {
        profile_rows,
        certificate,
        cold_floor,
        cold_evaluations,
        deadline,
        cold_verdicts,
        outcome,
        validation,
        sim_cycles,
    }
}

/// Write every minted certificate (here: one) as JSON into `dir`,
/// returning the file count — the persistable-evidence sink next to the
/// trace sinks.
pub fn write_certificates(r: &WorkingSetResult, dir: &str) -> std::io::Result<usize> {
    std::fs::create_dir_all(dir)?;
    let mut n = 0;
    if let Some(cert) = &r.certificate {
        let path = std::path::Path::new(dir).join("fig6a-tct.cert.json");
        std::fs::write(path, cert.to_json())?;
        n += 1;
    }
    Ok(n)
}

pub fn print(r: &WorkingSetResult) {
    print_table(
        "Working-set profiles (fig6a grid, traced): per-set rows re-sum exactly to observed fills",
        &[
            "scenario", "task", "fills", "hits", "distinct", "refills", "min fit sets", "exact",
        ],
        &r.profile_rows
            .iter()
            .map(|row| {
                vec![
                    row.scenario.clone(),
                    row.profile.task.clone(),
                    row.profile.fills.to_string(),
                    row.profile.hits.to_string(),
                    row.profile.distinct_lines.to_string(),
                    row.profile.reuse.refills.to_string(),
                    row.profile
                        .minimal_fitting_sets(CERT_WARM_THRESHOLD_PPM)
                        .map_or("-".into(), |s| s.to_string()),
                    if row.exact { "yes".into() } else { "NO".into() },
                ]
            })
            .collect::<Vec<_>>(),
    );
    match &r.certificate {
        Some(cert) => print_table(
            &format!(
                "Partition certificate: {} ({} accesses, {} distinct lines, {} ways)",
                cert.shape_key, cert.accesses, cert.distinct_lines, cert.ways
            ),
            &["sets", "max fills", "warm hit ppm"],
            &cert
                .entries
                .iter()
                .map(|e| {
                    vec![
                        e.sets.to_string(),
                        e.max_fills.to_string(),
                        e.warm_hit_ppm.to_string(),
                    ]
                })
                .collect::<Vec<_>>(),
        ),
        None => println!("no certificate minted (no partition size cleared the warm threshold)"),
    }
    println!(
        "\ncold knob-space floor: best bound {} after {} evaluations; demo deadline {}",
        r.cold_floor, r.cold_evaluations, r.deadline
    );
    print_table(
        "Admission flip: cold bound per tct_sets setting vs the certified search",
        &["tct_sets", "cold bound (tct)", "cold verdict"],
        &r.cold_verdicts
            .iter()
            .map(|v| {
                vec![
                    v.tct_sets.to_string(),
                    v.bound.map_or("-".into(), |b| b.to_string()),
                    if v.admitted { "ADMITTED".into() } else { "rejected".into() },
                ]
            })
            .collect::<Vec<_>>(),
    );
    match &r.outcome {
        Ok(o) => println!(
            "certified search: {:?} found {} after {} evaluations (warm bound {})",
            o.strategy,
            o.tuning.describe(),
            o.evaluations,
            o.decision
                .report
                .bound_for("tct")
                .completion_cycles(None)
                .unwrap_or(0),
        ),
        Err(e) => println!("certified search EXHAUSTED: {e}"),
    }
    match &r.validation {
        Some(v) => println!(
            "validating simulation: makespan {} <= warm bound {} ({}), deadline {} {}, \
             fills {} vs certified max {}{}",
            v.makespan,
            v.warm_bound,
            if v.within_bound { "ok" } else { "VIOLATED" },
            v.deadline,
            if v.deadline_met { "met" } else { "MISSED" },
            v.partitioned_fills,
            v.max_fills,
            if v.fills_exact {
                " (replay exact)"
            } else if v.partitioned_fills <= v.max_fills {
                ""
            } else {
                "  ** OVER BUDGET **"
            },
        ),
        None => println!("no validating simulation (certified search failed)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One grid execution, all four phase gates (the demo is
    /// deterministic, so the assertions share a single run).
    #[test]
    fn certificate_flips_an_admission_no_cold_bound_allows() {
        let r = run_with_threads(2);
        assert!(r.profiles_exact(), "a profile row broke the exact-sum gate");
        // Every grid scenario contributed at least the TCT's profile.
        assert!(r.profile_rows.len() >= fig6a::scenario_grid().len());

        // The fig6a TCT: 768 distinct lines over 8 ways fit exactly in
        // 96 sets, so the certified ladder starts there, fills are
        // compulsory-only, and the warm hit rate is perfect.
        let cert = r.certificate.as_ref().expect("fig6a TCT certifies");
        assert_eq!(cert.minimal().sets, 96);
        assert_eq!(cert.minimal().max_fills, 768);
        assert_eq!(cert.minimal().warm_hit_ppm, 1_000_000);

        // The flip: a real cold floor, a deadline strictly below it,
        // every cold tct_sets variant rejecting, the certified search
        // admitting on a certified size.
        assert!(r.cold_floor > 0, "cold exhaustion produced no near-miss");
        assert!(r.deadline < r.cold_floor);
        assert!(r.flip_demonstrated(), "no cold-rejected/certified-admitted flip");
        let o = r.outcome.as_ref().expect("certified search admits");
        assert_eq!(o.strategy, SearchStrategy::CertifiedPartition);
        assert!(cert.entry_for(o.tuning.tct_sets as u32).is_some());
        assert!(o.evaluations > r.cold_evaluations, "certified axis never probed");

        // The validating simulation: measured within the warm bound,
        // deadline met, and the partitioned fills land exactly on the
        // replay's prediction (the replay is arithmetic, not a model).
        let v = r.validation.as_ref().expect("validated");
        assert!(r.validated(), "{v:?}");
        assert!(v.warm_bound < r.cold_floor, "warm bound must dip under the cold floor");
        assert_eq!(v.partitioned_fills, v.max_fills, "replay exactness broke");
    }

    #[test]
    fn certificate_sink_lands_on_disk() {
        let r = run_with_threads(2);
        let dir = std::env::temp_dir().join("carfield-workingset-test");
        let dir = dir.to_str().expect("utf-8 temp path");
        let n = write_certificates(&r, dir).expect("write certificates");
        assert_eq!(n, 1);
        let json = std::fs::read_to_string(
            std::path::Path::new(dir).join("fig6a-tct.cert.json"),
        )
        .expect("read back");
        crate::trace::validate_json(&json).expect("valid JSON");
        std::fs::remove_dir_all(dir).ok();
    }
}

//! §II micro-claims: the scattered quantitative statements in the
//! architecture section, each drilled by a focused micro-experiment.
//!
//! - CLIC interrupt latency: 6 cycles;
//! - TSU write buffer adds at most 1 cycle;
//! - DCSPM aliased-mode switching costs zero extra latency;
//! - vector cluster speedup over HOSTD: 23.8x (FP64) to 190.3x (FP8);
//! - secure boot completes deterministically.

use crate::soc::axi::{Burst, InitiatorId, Target, TargetModel};
use crate::soc::mem::dcspm::CONTIG_ALIAS_BIT;
use crate::soc::mem::Dcspm;
use crate::soc::safed::Tcls;
use crate::soc::secd::SecureDomain;
use crate::soc::tsu::{Tsu, TsuConfig};
use crate::soc::vector::{FpFormat, VectorCluster};

#[derive(Debug, Clone)]
pub struct MicroResult {
    pub clic_latency: u64,
    pub wb_overhead_cycles: u64,
    pub dcspm_interleaved_latency: u64,
    pub dcspm_contiguous_latency: u64,
    pub vector_speedup_fp64: f64,
    pub vector_speedup_fp8: f64,
    pub boot_cycles: u64,
}

/// Measure a single-burst DCSPM access latency under an address mode.
fn dcspm_latency(alias: bool) -> u64 {
    let mut d = Dcspm::new();
    let addr = if alias { CONTIG_ALIAS_BIT } else { 0 };
    let b = Burst::read(InitiatorId(0), Target::Dcspm, addr, 8).with_tag(1);
    assert!(d.can_accept(&b));
    d.start(b, 0);
    let mut done = Vec::new();
    let mut now = 0;
    while done.is_empty() {
        d.tick(now, &mut done);
        now += 1;
    }
    done[0].finished_at
}

/// Measure WB overhead: write release time with and without WB.
fn wb_overhead() -> u64 {
    let mk = |wb: bool| {
        let mut tsu = Tsu::new(TsuConfig {
            wb_enable: wb,
            wb_capacity_beats: 64,
            ..TsuConfig::passthrough()
        });
        let w = Burst::write(InitiatorId(0), Target::Dcspm, 0, 8);
        tsu.submit(w, 0);
        let mut out = Vec::new();
        let mut now = 0;
        while out.is_empty() {
            tsu.release(now, &mut out);
            now += 1;
            assert!(now < 100);
        }
        now - 1
    };
    mk(true) - mk(false)
}

pub fn run() -> MicroResult {
    MicroResult {
        clic_latency: Tcls::new().irq_latency(),
        wb_overhead_cycles: wb_overhead(),
        dcspm_interleaved_latency: dcspm_latency(false),
        dcspm_contiguous_latency: dcspm_latency(true),
        vector_speedup_fp64: VectorCluster::speedup_vs_host(FpFormat::Fp64),
        vector_speedup_fp8: VectorCluster::speedup_vs_host(FpFormat::Fp8),
        boot_cycles: SecureDomain::boot_cycles(),
    }
}

pub fn print(r: &MicroResult) {
    println!("\n== micro-claims (paper section II)");
    println!("CLIC interrupt latency        : {} cycles (paper: 6)", r.clic_latency);
    println!("TSU write-buffer overhead     : {} cycle(s) (paper: <=1)", r.wb_overhead_cycles);
    println!(
        "DCSPM latency interleaved/contig: {} / {} cycles (paper: zero extra)",
        r.dcspm_interleaved_latency, r.dcspm_contiguous_latency
    );
    println!(
        "vector speedup vs HOSTD        : {:.1}x (FP64) .. {:.1}x (FP8) (paper: 23.8x-190.3x)",
        r.vector_speedup_fp64, r.vector_speedup_fp8
    );
    println!("secure boot                    : {} cycles (deterministic)", r.boot_cycles);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn micro_claims_hold() {
        let r = run();
        assert_eq!(r.clic_latency, 6);
        assert!(r.wb_overhead_cycles <= 1, "WB adds {} cycles", r.wb_overhead_cycles);
        assert_eq!(
            r.dcspm_interleaved_latency, r.dcspm_contiguous_latency,
            "aliasing must cost zero extra latency"
        );
        assert!((r.vector_speedup_fp64 - 23.8).abs() / 23.8 < 0.05);
        assert!((r.vector_speedup_fp8 - 190.3).abs() / 190.3 < 0.05);
        assert!(r.boot_cycles > 0);
    }
}

//! Fig. 6a — interference-aware execution of a HOSTD TCT accessing
//! HyperRAM while the system DMA interferes.
//!
//! Paper narrative reproduced:
//! - unregulated interference degrades TCT latency by ~225x vs isolated;
//! - programming the TSU (GBS + TRU) recovers ~44.4x vs unregulated;
//! - a >=50% DPLLC partition brings the TCT to ~75% of isolated
//!   performance;
//! - the TSU write buffer adds at most 1 cycle.

use crate::coordinator::task::Criticality;
use crate::coordinator::{sweep, McTask, Scenario, SocTuning, Workload};
use crate::soc::clock::Cycle;
use crate::soc::dma::DmaJob;
use crate::soc::hostd::TctSpec;

/// One measured regime.
#[derive(Debug, Clone)]
pub struct Regime {
    pub label: String,
    /// Mean TCT iteration latency (cycles).
    pub latency: f64,
    pub jitter: f64,
    pub l1_misses: f64,
    /// Degradation factor vs isolated.
    pub vs_isolated: f64,
}

#[derive(Debug, Clone)]
pub struct Fig6aResult {
    pub regimes: Vec<Regime>,
    /// (partition %, latency, % of isolated performance).
    pub partition_sweep: Vec<(u8, f64, f64)>,
    /// Total simulated cycles across the whole grid (throughput metric
    /// for the bench drivers: simulated Mcyc per wall-clock second).
    pub sim_cycles: Cycle,
}

fn tct() -> McTask {
    McTask::new(
        "tct",
        Criticality::Hard,
        Workload::HostTct(TctSpec::fig6a()),
    )
}

fn dma() -> McTask {
    McTask::new(
        "sys-dma",
        Criticality::BestEffort,
        Workload::DmaCopy(DmaJob::interferer()),
    )
}

/// DPLLC partition points swept by the figure.
pub const PARTITION_POINTS: [u8; 4] = [12, 25, 50, 75];

/// The figure's full scenario grid, in fixed order: isolated,
/// unregulated, TSU-regulated, then one TSU+partition scenario per
/// partition point. Exposed so the sweep bench and the equivalence tests
/// can run exactly the grid the figure runs.
pub fn scenario_grid() -> Vec<Scenario> {
    let mut grid = vec![
        Scenario::new("isolated", SocTuning::no_isolation()).with_task(tct()),
        Scenario::new("unregulated", SocTuning::no_isolation())
            .with_task(tct())
            .with_task(dma()),
        Scenario::new("tsu-regulated", SocTuning::tsu_regulation())
            .with_task(tct())
            .with_task(dma()),
    ];
    for pct in PARTITION_POINTS {
        grid.push(
            Scenario::new(
                &format!("tsu+partition-{pct}"),
                SocTuning::tsu_plus_llc_partition(pct),
            )
            .with_task(tct())
            .with_task(dma()),
        );
    }
    grid
}

pub fn run() -> Fig6aResult {
    run_with_threads(sweep::default_threads())
}

/// Run the whole grid, fanning the independent scenarios across up to
/// `threads` workers. Results are identical for any thread count.
pub fn run_with_threads(threads: usize) -> Fig6aResult {
    let grid = scenario_grid();
    let reports = sweep::run_scenarios(&grid, threads);
    let sim_cycles = reports.iter().map(|r| r.cycles).sum();
    let pick = |idx: usize| {
        let t = reports[idx].task("tct");
        (
            t.mean_latency,
            t.jitter,
            t.extra_value("l1_misses").unwrap_or(0.0),
        )
    };
    let (iso, iso_j, iso_m) = pick(0);
    let (unreg, unreg_j, unreg_m) = pick(1);
    let (reg, reg_j, reg_m) = pick(2);
    let mut regimes = vec![
        Regime {
            label: "isolated (no interference)".into(),
            latency: iso,
            jitter: iso_j,
            l1_misses: iso_m,
            vs_isolated: 1.0,
        },
        Regime {
            label: "unregulated interference".into(),
            latency: unreg,
            jitter: unreg_j,
            l1_misses: unreg_m,
            vs_isolated: unreg / iso,
        },
        Regime {
            label: "TSU regulated (GBS+TRU)".into(),
            latency: reg,
            jitter: reg_j,
            l1_misses: reg_m,
            vs_isolated: reg / iso,
        },
    ];
    let mut partition_sweep = Vec::new();
    for (k, &pct) in PARTITION_POINTS.iter().enumerate() {
        let (lat, j, m) = pick(3 + k);
        partition_sweep.push((pct, lat, iso / lat * 100.0));
        if pct == 50 {
            regimes.push(Regime {
                label: "TSU + 50% DPLLC partition".into(),
                latency: lat,
                jitter: j,
                l1_misses: m,
                vs_isolated: lat / iso,
            });
        }
    }
    Fig6aResult {
        regimes,
        partition_sweep,
        sim_cycles,
    }
}

pub fn print(r: &Fig6aResult) {
    use crate::coordinator::metrics::print_table;
    print_table(
        "Fig. 6a: TCT latency under DMA interference (paper: 225x unreg, 44.4x TSU recovery, 75% with >=50% partition)",
        &["regime", "latency", "jitter", "vs isolated"],
        &r.regimes
            .iter()
            .map(|x| {
                vec![
                    x.label.clone(),
                    format!("{:.0}", x.latency),
                    format!("{:.0}", x.jitter),
                    format!("{:.1}x", x.vs_isolated),
                ]
            })
            .collect::<Vec<_>>(),
    );
    print_table(
        "Fig. 6a: DPLLC partition sweep",
        &["TCT partition %", "latency", "% of isolated perf"],
        &r.partition_sweep
            .iter()
            .map(|(p, l, f)| vec![p.to_string(), format!("{l:.0}"), format!("{f:.0}%")])
            .collect::<Vec<_>>(),
    );
}

/// Derived headline factors (used by tests and EXPERIMENTS.md).
pub struct Headline {
    pub unregulated_degradation: f64,
    pub tsu_recovery: f64,
    pub partition50_pct_of_isolated: f64,
}

pub fn headline(r: &Fig6aResult) -> Headline {
    let iso = r.regimes[0].latency;
    let unreg = r.regimes[1].latency;
    let reg = r.regimes[2].latency;
    let p50 = r
        .partition_sweep
        .iter()
        .find(|(p, _, _)| *p == 50)
        .map(|(_, l, _)| *l)
        .unwrap();
    Headline {
        unregulated_degradation: unreg / iso,
        tsu_recovery: unreg / reg,
        partition50_pct_of_isolated: iso / p50 * 100.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper() {
        let r = run();
        let h = headline(&r);
        // Unregulated degradation is catastrophic (paper: 225x; we accept
        // the same order of magnitude).
        assert!(
            h.unregulated_degradation > 50.0,
            "unregulated only {:.1}x",
            h.unregulated_degradation
        );
        // TSU recovers by tens of x (paper: 44.4x).
        assert!(h.tsu_recovery > 10.0, "TSU recovery only {:.1}x", h.tsu_recovery);
        // >=50% partition restores a large fraction of isolated perf
        // (paper: 75%).
        assert!(
            h.partition50_pct_of_isolated > 50.0,
            "partition gives only {:.0}%",
            h.partition50_pct_of_isolated
        );
        // Partition sweep is monotone: more sets -> better.
        for w in r.partition_sweep.windows(2) {
            assert!(w[1].2 >= w[0].2 * 0.95, "{:?}", r.partition_sweep);
        }
    }
}

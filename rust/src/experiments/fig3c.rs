//! Fig. 3c — AMR performance: mode-switch costs, lockstep penalties, and
//! HFR vs software recovery.
//!
//! Paper claims reproduced here:
//! - reconfiguration between modes takes 82–183 cycles;
//! - DLM penalty 1.89x, TLM 2.85x vs INDIP;
//! - 23.1 MAC/cyc (DLM) and 15.3 MAC/cyc (TLM) on 8b MatMuls;
//! - HFR restores a faulty core in 24 cycles; TLM+HFR is 15x faster than
//!   TLM software recovery; DLM+HFR avoids cluster reboots.

use crate::soc::amr::{
    AmrCluster, AmrMode, AmrTask, IntPrecision, Recovery, HFR_RESTORE_CYCLES, SW_RECOVERY_CYCLES,
};
use crate::soc::axi::{InitiatorId, TargetModel};
use crate::soc::mem::Dcspm;
use crate::soc::tsu::TsuConfig;
use crate::soc::SocSim;

/// One row of the mode table.
#[derive(Debug, Clone)]
pub struct ModeRow {
    pub mode: AmrMode,
    pub mac_per_cyc_8b: f64,
    pub penalty_vs_indip: f64,
    pub makespan: u64,
}

/// One row of the recovery table.
#[derive(Debug, Clone)]
pub struct RecoveryRow {
    pub label: &'static str,
    pub mode: AmrMode,
    pub recovery: Recovery,
    pub per_fault_cycles: u64,
    pub faults: u64,
    pub total_recovery_cycles: u64,
}

#[derive(Debug, Clone)]
pub struct Fig3cResult {
    /// (from, to, cycles) for all mode transitions.
    pub switch_matrix: Vec<(AmrMode, AmrMode, u64)>,
    pub modes: Vec<ModeRow>,
    pub recovery: Vec<RecoveryRow>,
    /// Total simulated cycles across all runs (bench throughput metric).
    pub sim_cycles: u64,
}

fn bench_task() -> AmrTask {
    AmrTask {
        precision: IntPrecision::Int8,
        m: 128,
        k: 128,
        n: 128,
        tile: 32,
        src_base: 0,
        dst_base: 0x8_0000,
        part_id: 0,
    }
}

fn run_mode(mode: AmrMode, recovery: Recovery, fault_rate: f64) -> crate::soc::amr::AmrStats {
    let mut cluster = AmrCluster::new(InitiatorId(0)).with_seed(0x3C + mode.active_cores() as u64);
    cluster.mode = mode;
    cluster.recovery = recovery;
    cluster.fault_per_kcycle = fault_rate;
    cluster.submit(bench_task(), 0);
    let mut soc = SocSim::new(1, vec![Box::new(Dcspm::new()) as Box<dyn TargetModel>]);
    soc.attach(Box::new(cluster), TsuConfig::passthrough());
    assert!(soc.run_until_done(100_000_000), "AMR task never drained");
    let c: &mut AmrCluster = soc.initiator_mut(InitiatorId(0));
    c.stats
}

/// Run the full Fig. 3c reproduction. The seven simulator runs behind
/// the mode and recovery tables are independent, so they fan out across
/// threads (results are identical to the serial sweep).
pub fn run() -> Fig3cResult {
    use crate::coordinator::sweep;
    use AmrMode::*;
    // (a) switch matrix.
    let mut switch_matrix = Vec::new();
    for from in [Indip, Dlm, Tlm] {
        for to in [Indip, Dlm, Tlm] {
            if from != to {
                switch_matrix.push((from, to, AmrMode::switch_cycles(from, to)));
            }
        }
    }
    let threads = sweep::default_threads();
    // (b) per-mode throughput on the 8b MatMul.
    let mode_list = [Indip, Dlm, Tlm];
    let mode_stats = sweep::parallel_map(&mode_list, threads, |&mode| {
        run_mode(mode, Recovery::Hfr, 0.0)
    });
    let base_rate = mode_stats[0].effective_mac_per_cyc(0);
    let mut sim_cycles = 0;
    let mut modes = Vec::new();
    for (mode, stats) in mode_list.iter().zip(&mode_stats) {
        let rate = stats.effective_mac_per_cyc(0);
        sim_cycles += stats.finished_at;
        modes.push(ModeRow {
            mode: *mode,
            mac_per_cyc_8b: rate,
            penalty_vs_indip: base_rate / rate,
            makespan: stats.finished_at,
        });
    }
    // (c) recovery comparison under a fixed fault rate.
    let rate = 0.5;
    let configs = [
        ("DLM + HFR", Dlm, Recovery::Hfr, HFR_RESTORE_CYCLES),
        ("TLM + HFR", Tlm, Recovery::Hfr, HFR_RESTORE_CYCLES),
        ("TLM + SW recovery", Tlm, Recovery::Software, SW_RECOVERY_CYCLES),
        (
            "DLM reboot (no HFR)",
            Dlm,
            Recovery::RebootOnly,
            crate::soc::amr::REBOOT_CYCLES,
        ),
    ];
    let recovery_stats =
        sweep::parallel_map(&configs, threads, |&(_, mode, rec, _)| run_mode(mode, rec, rate));
    let mut recovery = Vec::new();
    for (&(label, mode, rec, per_fault), stats) in configs.iter().zip(&recovery_stats) {
        sim_cycles += stats.finished_at;
        recovery.push(RecoveryRow {
            label,
            mode,
            recovery: rec,
            per_fault_cycles: per_fault,
            faults: stats.faults_detected,
            total_recovery_cycles: stats.recovery_cycles,
        });
    }
    Fig3cResult {
        switch_matrix,
        modes,
        recovery,
        sim_cycles,
    }
}

/// Print the figure in the same terms the paper uses.
pub fn print(r: &Fig3cResult) {
    use crate::coordinator::metrics::print_table;
    print_table(
        "Fig. 3c (i): AMR mode reconfiguration cycles (paper: 82-183)",
        &["from", "to", "cycles"],
        &r.switch_matrix
            .iter()
            .map(|(f, t, c)| vec![format!("{f:?}"), format!("{t:?}"), c.to_string()])
            .collect::<Vec<_>>(),
    );
    print_table(
        "Fig. 3c (ii): 8b MatMul throughput per mode (paper: 43.6 / 23.1 / 15.3 MAC/cyc)",
        &["mode", "MAC/cyc", "penalty vs INDIP"],
        &r.modes
            .iter()
            .map(|m| {
                vec![
                    format!("{:?}", m.mode),
                    format!("{:.1}", m.mac_per_cyc_8b),
                    format!("{:.2}x", m.penalty_vs_indip),
                ]
            })
            .collect::<Vec<_>>(),
    );
    print_table(
        "Fig. 3c (iii): recovery cost (paper: HFR 24 cyc, TLM SW 15x slower)",
        &["config", "cycles/fault", "faults", "total recovery cyc"],
        &r.recovery
            .iter()
            .map(|x| {
                vec![
                    x.label.to_string(),
                    x.per_fault_cycles.to_string(),
                    x.faults.to_string(),
                    x.total_recovery_cycles.to_string(),
                ]
            })
            .collect::<Vec<_>>(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_numbers() {
        let r = run();
        // Switch range.
        for &(_, _, c) in &r.switch_matrix {
            assert!((82..=183).contains(&c));
        }
        // Mode rates (compute-bound task => effective ~= nominal).
        let dlm = r.modes.iter().find(|m| m.mode == AmrMode::Dlm).unwrap();
        assert!((dlm.mac_per_cyc_8b - 23.1).abs() < 1.5, "{}", dlm.mac_per_cyc_8b);
        assert!((dlm.penalty_vs_indip - 1.89).abs() < 0.15);
        let tlm = r.modes.iter().find(|m| m.mode == AmrMode::Tlm).unwrap();
        assert!((tlm.mac_per_cyc_8b - 15.3).abs() < 1.2, "{}", tlm.mac_per_cyc_8b);
        assert!((tlm.penalty_vs_indip - 2.85).abs() < 0.25);
        // Recovery: TLM SW is 15x HFR per fault.
        let hfr = r.recovery.iter().find(|x| x.label == "TLM + HFR").unwrap();
        let sw = r
            .recovery
            .iter()
            .find(|x| x.label == "TLM + SW recovery")
            .unwrap();
        assert_eq!(sw.per_fault_cycles, 15 * hfr.per_fault_cycles);
    }
}

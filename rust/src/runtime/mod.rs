//! XLA PJRT runtime: loads AOT-compiled HLO text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! The interchange format is HLO *text* (not serialized `HloModuleProto`):
//! jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.

mod client;

pub use client::{ArtifactRuntime, LoadedExecutable};

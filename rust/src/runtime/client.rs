//! PJRT client wrapper: one process-wide CPU client, one compiled
//! executable per artifact, f32 in / f32 out convenience entry points.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// A compiled HLO artifact ready to execute on the PJRT CPU client.
pub struct LoadedExecutable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Shapes of the f32 input parameters, in parameter order.
    input_shapes: Vec<Vec<usize>>,
}

impl LoadedExecutable {
    /// Artifact name (file stem).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Declared input shapes (row-major, f32).
    pub fn input_shapes(&self) -> &[Vec<usize>] {
        &self.input_shapes
    }

    /// Execute with row-major f32 buffers; returns all outputs flattened
    /// to f32 vectors. The artifact was lowered with `return_tuple=True`,
    /// so the single result literal is a tuple we decompose.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.input_shapes.len() {
            return Err(anyhow!(
                "artifact `{}` expects {} inputs, got {}",
                self.name,
                self.input_shapes.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, shape) in inputs.iter().zip(&self.input_shapes) {
            let expect: usize = shape.iter().product();
            if buf.len() != expect {
                return Err(anyhow!(
                    "artifact `{}`: input buffer has {} elements, shape {:?} needs {}",
                    self.name,
                    buf.len(),
                    shape,
                    expect
                ));
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let elements = result.to_tuple()?;
        let mut outs = Vec::with_capacity(elements.len());
        for lit in elements {
            outs.push(lit.to_vec::<f32>()?);
        }
        Ok(outs)
    }
}

/// Process-wide artifact runtime: owns the PJRT CPU client and a cache of
/// compiled executables keyed by artifact name.
pub struct ArtifactRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, std::sync::Arc<LoadedExecutable>>,
}

impl ArtifactRuntime {
    /// Create a runtime rooted at `dir` (usually `artifacts/`).
    pub fn new<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            dir: dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    /// PJRT platform name (e.g. "cpu" / "Host").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (or fetch from cache) the artifact `<dir>/<name>.hlo.txt`.
    ///
    /// Input shapes are parsed from the sidecar `<name>.meta` file written
    /// by `aot.py` (one `dim0xdim1x...` token per input, whitespace
    /// separated), falling back to parsing the HLO ENTRY signature.
    pub fn load(&mut self, name: &str) -> Result<std::sync::Arc<LoadedExecutable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact `{name}`"))?;

        let meta_path = self.dir.join(format!("{name}.meta"));
        let input_shapes = parse_meta(&meta_path)
            .or_else(|_| parse_entry_shapes(&hlo_path))
            .with_context(|| format!("determining input shapes for `{name}`"))?;

        let loaded = std::sync::Arc::new(LoadedExecutable {
            name: name.to_string(),
            exe,
            input_shapes,
        });
        self.cache.insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Names of `.hlo.txt` artifacts present in the artifact directory.
    pub fn available(&self) -> Vec<String> {
        let mut names = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for entry in rd.flatten() {
                let fname = entry.file_name().to_string_lossy().to_string();
                if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        names
    }
}

/// Parse the `.meta` sidecar: whitespace-separated `AxBxC` tokens.
fn parse_meta(path: &Path) -> Result<Vec<Vec<usize>>> {
    let text = std::fs::read_to_string(path)?;
    let mut shapes = Vec::new();
    for tok in text.split_whitespace() {
        let dims: Result<Vec<usize>, _> = tok.split('x').map(|d| d.parse::<usize>()).collect();
        shapes.push(dims?);
    }
    if shapes.is_empty() {
        return Err(anyhow!("empty meta file"));
    }
    Ok(shapes)
}

/// Fallback: scrape `f32[AxB]` parameter shapes from the HLO ENTRY line.
fn parse_entry_shapes(path: &Path) -> Result<Vec<Vec<usize>>> {
    let text = std::fs::read_to_string(path)?;
    let entry = text
        .lines()
        .find(|l| l.trim_start().starts_with("ENTRY"))
        .ok_or_else(|| anyhow!("no ENTRY line in HLO"))?;
    let mut shapes = Vec::new();
    let mut rest = entry;
    while let Some(pos) = rest.find("f32[") {
        rest = &rest[pos + 4..];
        let end = rest.find(']').ok_or_else(|| anyhow!("unterminated shape"))?;
        let dims: Result<Vec<usize>, _> =
            rest[..end].split(',').map(|d| d.trim().parse::<usize>()).collect();
        shapes.push(dims?);
        rest = &rest[end..];
        // Stop before the `->` result shape.
        if let Some(arrow) = entry.find("->") {
            let consumed = entry.len() - rest.len();
            if consumed > arrow {
                shapes.pop();
                break;
            }
        }
    }
    if shapes.is_empty() {
        return Err(anyhow!("no f32 parameter shapes found in ENTRY"));
    }
    Ok(shapes)
}

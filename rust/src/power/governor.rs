//! The bound-driven DVFS governor: energy-minimal, provably-safe
//! operating points.
//!
//! Closes the loop the ROADMAP asked for: the WCET engine's completion
//! bounds are recomputed analytically (microseconds per candidate) at
//! every voltage point of the grid, so the governor can search the
//! (operating point x `SocTuning`) product — reusing
//! [`coordinator::autotune`] for the isolation half at each voltage —
//! and return the lowest-energy pair whose recomputed bound still meets
//! every nanosecond deadline *and* whose worst-case modeled power stays
//! inside the 1.2W envelope. No simulation runs during the search;
//! [`validate`] confirms the winner with one real execution (measured <=
//! bound, deadlines met, measured power within the envelope).
//!
//! Search order (deterministic): one candidate point per grid voltage,
//! ascending. The candidate runs the system domain and every cluster
//! domain hosting time-critical work at the grid voltage, and parks the
//! rest — cluster domains hosting only best-effort work (their TSU
//! arrival curves are frequency-invariant, so no admitted bound can
//! depend on their clock; the autotune at the actual candidate point
//! re-proves it anyway) and idle domains — at the grid floor. Flooring
//! *before* the envelope gate matters: a mix whose critical path needs
//! a high voltage must not be reported infeasible just because the
//! *uniform* high-voltage point would bust the envelope when the
//! best-effort domain it would never run fast was the power hog. A
//! candidate is skipped when its worst-case modeled power still exceeds
//! the envelope, rejected when no tuning admits its cycle budgets, and
//! the winner is the modeled-energy argmin among the admitted (energy
//! per unit work grows ~V^alpha, so ties resolve to the lower voltage
//! by the ascending scan).
//!
//! Fault awareness rides along for free: probe scenarios are clones of
//! the governed scenario, so a [`FaultPlan`] — and with it the k-fault
//! re-execution term in every recomputed bound — survives into each
//! candidate's admission test. The energy-minimal point the governor
//! returns is therefore provably safe *under up to k recoveries*, and
//! since recovery cycles are system-domain they stretch with the
//! candidate's core voltage exactly like the compute they re-run.
//!
//! [`coordinator::autotune`]: crate::coordinator::autotune
//! [`FaultPlan`]: crate::coordinator::FaultPlan

use crate::coordinator::autotune::{self, SearchStrategy, TuneOutcome};
use crate::coordinator::{
    AdmissionDecision, McTask, Scenario, ScenarioReport, Scheduler, SocTuning,
};
use crate::power::certificates::UtilizationLibrary;
use crate::power::energy::{self, DomainUtilization, EnergyReport, SOC_ENVELOPE_MW};
use crate::power::op_point::{OperatingPoint, VOLTAGE_GRID};
use crate::soc::clock::{Cycle, Domain};

/// The deterministic bound-driven DVFS search.
#[derive(Debug, Clone)]
pub struct Governor {
    /// Voltage candidates for the critical domains, ascending (defaults
    /// to the paper's 0.6–1.1V ladder).
    pub grid: Vec<f64>,
    /// Park cluster domains hosting only best-effort (or no) work at
    /// the grid floor instead of the candidate voltage.
    pub refine_nct_domains: bool,
    /// Park the uncore (memory subsystem) at this fixed frequency on
    /// every candidate point. `None` keeps it coupled to the system
    /// clock — the seed timebase, where every memory service constant
    /// stretches with the system voltage. The uncore is *excluded from
    /// the voltage grid*: the governor never searches over it.
    pub uncore_mhz: Option<f64>,
    /// Optional certified per-domain activity bound for the envelope
    /// gate (and candidate energy models), replacing the worst-case
    /// fully-active profile. Fed from a validating run's measured
    /// utilization ([`Governor::govern_certified`]); activity factors
    /// are duty-cycle ratios and carry across nearby operating points,
    /// and the winner is still confirmed by its own validating
    /// simulation (measured power <= envelope) before use.
    pub activity_bound: Option<DomainUtilization>,
}

impl Default for Governor {
    fn default() -> Self {
        Self {
            grid: VOLTAGE_GRID.to_vec(),
            refine_nct_domains: true,
            uncore_mhz: None,
            activity_bound: None,
        }
    }
}

impl Governor {
    /// The decoupled-uncore governor: candidates park the memory
    /// subsystem at the fixed [`UNCORE_MHZ`] clock, so memory-bound
    /// wall-clock bounds stay flat as the core voltage drops.
    ///
    /// [`UNCORE_MHZ`]: crate::soc::clock::UNCORE_MHZ
    pub fn decoupled() -> Self {
        Self {
            uncore_mhz: Some(crate::soc::clock::UNCORE_MHZ),
            ..Self::default()
        }
    }
}

/// Why the governor could not pick a point.
#[derive(Debug, Clone)]
pub enum GovernError {
    /// No time-critical task carries a deadline — nothing to govern
    /// against (run at whatever point you like; there is no proof
    /// obligation).
    NoDeadline,
    /// Every grid point was envelope-blocked or tuning-exhausted.
    Exhausted {
        /// Voltage points whose tuning space was searched.
        points_evaluated: u64,
        /// Analytic admission evaluations spent across all searches.
        evaluations: u64,
        /// Grid points skipped because worst-case modeled power exceeds
        /// the 1.2W envelope.
        envelope_blocked: u64,
        /// Closest miss seen anywhere: `(voltage, bound, cycle budget)`.
        best: Option<(f64, Cycle, Cycle)>,
    },
}

impl std::fmt::Display for GovernError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GovernError::NoDeadline => write!(
                f,
                "no time-critical task carries a deadline: the governor \
                 has no bound to prove and nothing to optimize against"
            ),
            GovernError::Exhausted {
                points_evaluated,
                evaluations,
                envelope_blocked,
                best,
            } => {
                write!(
                    f,
                    "no operating point admits the mix: {points_evaluated} \
                     voltage points searched ({evaluations} analytic \
                     evaluations), {envelope_blocked} envelope-blocked"
                )?;
                if let Some((v, bound, budget)) = best {
                    write!(
                        f,
                        "; closest miss at {v:.2}V: bound {bound} > cycle \
                         budget {budget}"
                    )?;
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for GovernError {}

/// The energy reference the winner is compared against: the same mix at
/// the 1.1V max-performance corner with its own autotuned isolation.
#[derive(Debug, Clone)]
pub struct Baseline {
    pub tuning: SocTuning,
    pub modeled: EnergyReport,
}

/// A governed operating point: provably admissible, energy-minimal over
/// the searched space.
#[derive(Debug, Clone)]
pub struct GovernorChoice {
    pub op: OperatingPoint,
    pub tuning: SocTuning,
    /// How the isolation half of the pair was found at the winning point.
    pub strategy: SearchStrategy,
    /// The admitting decision at `(op, tuning)` — carries every bound.
    pub decision: AdmissionDecision,
    /// `(task, completion bound ns, deadline ns)` per deadline task.
    pub checks_ns: Vec<(String, f64, f64)>,
    /// Worst completion bound among deadline tasks (system cycles): the
    /// execution window the modeled energy integrates over.
    pub bound_cycles: Cycle,
    /// Modeled power/energy at the winner (worst-case utilization).
    pub modeled: EnergyReport,
    pub baseline: Option<Baseline>,
    /// Voltage points whose tuning space was actually searched.
    pub points_evaluated: u64,
    /// Analytic admission evaluations across every autotune run.
    pub evaluations: u64,
}

impl GovernorChoice {
    /// Modeled energy saved vs the max-performance baseline, percent.
    pub fn energy_saved_pct(&self) -> Option<f64> {
        self.baseline.as_ref().map(|b| {
            (1.0 - self.modeled.total_energy_mj / b.modeled.total_energy_mj) * 100.0
        })
    }
}

/// One admissible `(point, tuning)` candidate during the search.
struct Candidate {
    op: OperatingPoint,
    outcome: TuneOutcome,
    modeled: EnergyReport,
    bound_cycles: Cycle,
}

impl Governor {
    /// Search the (operating point x tuning) space for the lowest-energy
    /// pair whose recomputed bounds meet every deadline within the power
    /// envelope. Purely analytic — validate the winner with [`validate`].
    pub fn govern(&self, scenario: &Scenario) -> Result<GovernorChoice, GovernError> {
        let governed: Vec<&McTask> = scenario
            .tasks
            .iter()
            .filter(|t| {
                t.criticality.is_time_critical() && (t.deadline > 0 || t.deadline_ns > 0.0)
            })
            .collect();
        if governed.is_empty() {
            return Err(GovernError::NoDeadline);
        }
        let utils = self
            .activity_bound
            .unwrap_or_else(|| DomainUtilization::analytic(scenario));
        let mut points_evaluated = 0u64;
        let mut evaluations = 0u64;
        let mut envelope_blocked = 0u64;
        // Closest miss in *wall-clock* terms: gaps at different points
        // run at different clocks, so raw cycle gaps do not compare.
        let mut near_miss: Option<(f64, Cycle, Cycle)> = None;
        let mut near_gap_ns = f64::INFINITY;
        let mut best: Option<Candidate> = None;

        for &v in &self.grid {
            let op = self.candidate_op(scenario, v);
            // Envelope gate before any search: a point whose worst-case
            // modeled power busts the budget is inadmissible outright.
            if energy::modeled_power_mw(&op, utils) > SOC_ENVELOPE_MW {
                envelope_blocked += 1;
                continue;
            }
            points_evaluated += 1;
            let probe = scenario.clone().with_op_point(op);
            match autotune::autotune(&probe) {
                Ok(outcome) => {
                    evaluations += outcome.evaluations;
                    let candidate = self.candidate(scenario, op, outcome, utils);
                    let better = match &best {
                        None => true,
                        Some(b) => {
                            candidate.modeled.total_energy_mj < b.modeled.total_energy_mj
                        }
                    };
                    if better {
                        best = Some(candidate);
                    }
                }
                Err(e) => {
                    evaluations += e.evaluations;
                    if let Some(bound) = e.best_bound {
                        let gap_ns = op
                            .clock_tree()
                            .system
                            .cycles_to_ns(bound.saturating_sub(e.deadline));
                        if gap_ns < near_gap_ns {
                            near_gap_ns = gap_ns;
                            near_miss = Some((v, bound, e.deadline));
                        }
                    }
                }
            }
        }

        let Some(winner) = best else {
            return Err(GovernError::Exhausted {
                points_evaluated,
                evaluations,
                envelope_blocked,
                best: near_miss,
            });
        };

        // Reference energy: the same mix at max_perf with its own
        // autotuned isolation (no envelope gate — it is a yardstick, not
        // a candidate). Carries the same uncore configuration so the
        // comparison is apples to apples.
        let base_op = self.apply_uncore(OperatingPoint::max_perf());
        let baseline = match autotune::autotune(&scenario.clone().with_op_point(base_op)) {
            Ok(o) => {
                evaluations += o.evaluations;
                let bound = worst_bound_cycles(scenario, &base_op, &o);
                Some(Baseline {
                    tuning: o.tuning,
                    modeled: energy::model(&base_op, utils, bound),
                })
            }
            Err(e) => {
                evaluations += e.evaluations;
                None
            }
        };

        let clocks = winner.op.clock_tree();
        let checks_ns = governed
            .iter()
            .map(|t| {
                let dl = t.deadline_cycles(Some(&clocks));
                // Exact wall-clock bound: per-domain cycles convert
                // through their own clocks (a decoupled uncore's service
                // does not stretch with the system voltage).
                let bound_ns = winner
                    .outcome
                    .decision
                    .report
                    .bound_for(&t.name)
                    .completion_ns(&clocks)
                    .expect("admitted deadline task has a finite bound");
                (t.name.clone(), bound_ns, clocks.system.cycles_to_ns(dl))
            })
            .collect();
        Ok(GovernorChoice {
            op: winner.op,
            tuning: winner.outcome.tuning,
            strategy: winner.outcome.strategy,
            decision: winner.outcome.decision,
            checks_ns,
            bound_cycles: winner.bound_cycles,
            modeled: winner.modeled,
            baseline,
            points_evaluated,
            evaluations,
        })
    }

    /// Apply this governor's uncore configuration to an operating point
    /// (a fixed parked frequency, or coupled when `uncore_mhz` is None).
    fn apply_uncore(&self, op: OperatingPoint) -> OperatingPoint {
        match self.uncore_mhz {
            Some(mhz) => op
                .with_uncore_mhz(mhz)
                .expect("governor uncore frequency validated at construction"),
            None => op,
        }
    }

    /// The candidate point for grid voltage `v`: the system domain and
    /// every cluster domain hosting time-critical work run at `v`;
    /// cluster domains hosting only best-effort work — whose TSU
    /// arrival curves are frequency-invariant, so no critical bound can
    /// depend on their clock (the autotune at the candidate point
    /// re-proves admissibility regardless) — and idle domains park at
    /// the grid floor (retention). The uncore rides along per
    /// [`Governor::uncore_mhz`] — it is never part of the grid.
    /// Flooring happens *before* the envelope gate so a high-voltage
    /// critical path stays reachable even when the uniform point would
    /// bust the power budget.
    fn candidate_op(&self, scenario: &Scenario, v: f64) -> OperatingPoint {
        let mut op = self.apply_uncore(OperatingPoint::uniform(v).expect("grid voltage on every curve"));
        if !self.refine_nct_domains {
            return op;
        }
        // The true grid minimum — not `first()`, which would silently
        // park domains at peak voltage on an unsorted custom grid.
        let floor = self.grid.iter().copied().fold(v, f64::min);
        for d in [Domain::Vector, Domain::Amr] {
            let hosts_critical = scenario.tasks.iter().any(|t| {
                t.criticality.is_time_critical() && energy::domain_of(&t.workload) == d
            });
            if hosts_critical {
                continue; // never slow a domain on the critical path
            }
            if let Ok(parked) = op.with_voltage(d, floor) {
                op = parked;
            }
        }
        op
    }

    fn candidate(
        &self,
        scenario: &Scenario,
        op: OperatingPoint,
        outcome: TuneOutcome,
        utils: DomainUtilization,
    ) -> Candidate {
        let bound_cycles = worst_bound_cycles(scenario, &op, &outcome);
        let modeled = energy::model(&op, utils, bound_cycles);
        Candidate {
            op,
            outcome,
            modeled,
            bound_cycles,
        }
    }
}

/// Worst completion bound among deadline-carrying tasks, in system
/// cycles at `op`'s clocks — the execution window modeled energy
/// integrates over.
fn worst_bound_cycles(scenario: &Scenario, op: &OperatingPoint, outcome: &TuneOutcome) -> Cycle {
    let clocks = op.clock_tree();
    scenario
        .tasks
        .iter()
        .filter(|t| t.criticality.is_time_critical() && t.deadline_cycles(Some(&clocks)) > 0)
        .filter_map(|t| {
            outcome
                .decision
                .report
                .bound_for(&t.name)
                .completion_cycles(Some(&clocks))
        })
        .max()
        .unwrap_or(0)
}

/// Convenience entry point with the default grid.
pub fn govern(scenario: &Scenario) -> Result<GovernorChoice, GovernError> {
    Governor::default().govern(scenario)
}

/// Simulation-backed confirmation of a governed point: one real run at
/// `(op, tuning)` — every bounded critical task must measure within its
/// completion bound, every deadline must hold, and the *measured*
/// (activity-counter-derived) power must sit inside the envelope.
#[derive(Debug, Clone)]
pub struct GovernorValidation {
    pub report: ScenarioReport,
    /// `(task, measured makespan, completion bound)` per bounded task.
    pub checks: Vec<(String, Cycle, Cycle)>,
    pub sound: bool,
    pub deadlines_met: bool,
    /// Measured power/energy of the validating run.
    pub measured: EnergyReport,
}

impl GovernorValidation {
    pub fn confirmed(&self) -> bool {
        self.sound && self.deadlines_met && self.measured.within_envelope()
    }
}

pub fn validate(scenario: &Scenario, choice: &GovernorChoice) -> GovernorValidation {
    let s = scenario
        .clone()
        .with_tuning(choice.tuning)
        .with_op_point(choice.op);
    let report = Scheduler::run(&s);
    let clocks = choice.op.clock_tree();
    let mut checks = Vec::new();
    let mut sound = true;
    for b in &choice.decision.report.bounds {
        if let Some(bound) = b.completion_cycles(Some(&clocks)) {
            let t = report.task(&b.task);
            sound &= t.makespan > 0 && t.makespan <= bound;
            checks.push((b.task.clone(), t.makespan, bound));
        }
    }
    let deadlines_met = report.all_deadlines_met();
    let measured = energy::measure(&s, &report, &choice.op);
    GovernorValidation {
        report,
        checks,
        sound,
        deadlines_met,
        measured,
    }
}

/// Outcome of the two-pass certified-activity flow
/// ([`Governor::govern_certified`], the `--certified-activity` CLI path).
#[derive(Debug, Clone)]
pub struct CertifiedChoice {
    /// The worst-case-activity pass, when it found a point at all
    /// (`None` when every candidate was envelope-blocked or
    /// tuning-exhausted — exactly the case the certificate rescues).
    pub worst_case: Option<(GovernorChoice, GovernorValidation)>,
    /// The measured per-domain utilization fed back as the certificate.
    pub certified_utils: DomainUtilization,
    /// The re-governed choice under the certified activity bound.
    pub certified: GovernorChoice,
    pub certified_validation: GovernorValidation,
}

impl CertifiedChoice {
    /// Every shipped point simulation-confirmed (bounds, deadlines and
    /// *measured* power — the safety net that keeps an optimistic
    /// certificate from ever shipping an envelope violation).
    pub fn confirmed(&self) -> bool {
        self.certified_validation.confirmed()
            && self
                .worst_case
                .as_ref()
                .map(|(_, v)| v.confirmed())
                .unwrap_or(true)
    }

    /// Did the certificate admit a faster (higher-voltage) point than
    /// the worst-case gate allowed — or govern a mix the worst-case
    /// pass could not govern at all?
    pub fn unlocked(&self) -> bool {
        match &self.worst_case {
            None => true,
            Some((wc, _)) => {
                self.certified.op.v_system > wc.op.v_system + 1e-9
                    || self.certified.op.v_vector > wc.op.v_vector + 1e-9
                    || self.certified.op.v_amr > wc.op.v_amr + 1e-9
            }
        }
    }
}

impl Governor {
    /// Measured-utilization feedback (`--certified-activity`): govern
    /// with the worst-case fully-active profile, confirm the winner by
    /// simulation, then feed that run's *measured* per-domain
    /// utilization back as a certified activity bound and search again.
    /// The certified envelope gate admits high-voltage candidates the
    /// worst case blocked (e.g. a dual-critical cluster mix whose
    /// deadline is only feasible at peak voltage); the certified winner
    /// is itself simulation-confirmed before anyone acts on it.
    ///
    /// When the worst-case pass exhausts (no point both admits the
    /// deadlines and fits the fully-active envelope), the certificate
    /// is measured from one run at the max-performance baseline tuning
    /// instead — a measurement probe, not a shipped point.
    pub fn govern_certified(&self, scenario: &Scenario) -> Result<CertifiedChoice, GovernError> {
        let worst_case = match self.govern(scenario) {
            Ok(choice) => {
                let v = validate(scenario, &choice);
                Some((choice, v))
            }
            Err(GovernError::NoDeadline) => return Err(GovernError::NoDeadline),
            Err(GovernError::Exhausted { .. }) => None,
        };
        let certified_utils = match &worst_case {
            Some((choice, v)) => {
                let s = scenario.clone().with_op_point(choice.op);
                DomainUtilization::measured(&s, &v.report)
            }
            None => {
                // Measurement probe at the max-perf baseline (best
                // available tuning; the scenario's own if autotune also
                // exhausts).
                let base_op = self.apply_uncore(OperatingPoint::max_perf());
                let probe = scenario.clone().with_op_point(base_op);
                let tuning = autotune::autotune(&probe)
                    .map(|o| o.tuning)
                    .unwrap_or(scenario.tuning);
                let report = Scheduler::run(&probe.clone().with_tuning(tuning));
                DomainUtilization::measured(&probe, &report)
            }
        };
        let certified_governor = Governor {
            activity_bound: Some(certified_utils),
            ..self.clone()
        };
        let certified = certified_governor.govern(scenario)?;
        let certified_validation = validate(scenario, &certified);
        Ok(CertifiedChoice {
            worst_case,
            certified_utils,
            certified,
            certified_validation,
        })
    }

    /// [`Governor::govern_certified`] with a persistent certificate
    /// store ([`UtilizationLibrary`]): when the library already holds a
    /// certificate for this `(governor, scenario)` workload shape, the
    /// measurement sweep — the worst-case govern pass and its
    /// validating/probe simulations — is skipped entirely and the
    /// stored utilization is re-governed directly. The certified winner
    /// is still confirmed by its own validating simulation, so a reused
    /// certificate can relax the envelope gate but never ship an
    /// unvalidated point. A miss runs the full certified flow and files
    /// the fresh certificate.
    pub fn govern_certified_with(
        &self,
        scenario: &Scenario,
        library: &mut UtilizationLibrary,
    ) -> Result<CertifiedChoice, GovernError> {
        let key = UtilizationLibrary::shape_key(self, scenario);
        if let Some(certified_utils) = library.lookup(&key) {
            let certified_governor = Governor {
                activity_bound: Some(certified_utils),
                ..self.clone()
            };
            let certified = certified_governor.govern(scenario)?;
            let certified_validation = validate(scenario, &certified);
            return Ok(CertifiedChoice {
                worst_case: None,
                certified_utils,
                certified,
                certified_validation,
            });
        }
        let choice = self.govern_certified(scenario)?;
        library.insert(key, choice.certified_utils);
        Ok(choice)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::energy::{cluster_mix_ns, reference_mix_ns};

    #[test]
    fn slack_rich_deadline_lands_on_the_lowest_voltage() {
        // 2.5ms of slack on the fig6a mix: the governor throttles the
        // DMA harder in exchange for 0.6V — provably admissible, at a
        // large modeled energy saving vs max_perf.
        let s = reference_mix_ns(2_500_000.0);
        let c = govern(&s).expect("slack-rich mix is governable");
        assert_eq!(c.op.v_system, 0.6, "{}", c.op.describe());
        assert!(c.decision.admitted);
        assert!(c.modeled.within_envelope());
        let saved = c.energy_saved_pct().expect("baseline exists");
        assert!(saved >= 30.0, "only {saved:.1}% saved");
        for (task, bound_ns, deadline_ns) in &c.checks_ns {
            assert!(bound_ns <= deadline_ns, "{task}: {bound_ns} > {deadline_ns}");
        }
        let v = validate(&s, &c);
        assert!(v.confirmed(), "sim refuted the winner: {:?}", v.checks);
    }

    #[test]
    fn tight_deadline_pins_to_peak_voltage() {
        // 430us leaves no slack below 1.1V (the tightest admitting
        // tuning's bound is ~413k cycles): the governor must pin to the
        // peak point and still prove admissibility.
        let s = reference_mix_ns(430_000.0);
        let c = govern(&s).expect("feasible at peak voltage");
        assert_eq!(c.op.v_system, 1.1, "{}", c.op.describe());
        assert!(c.modeled.within_envelope());
        let v = validate(&s, &c);
        assert!(v.confirmed(), "{:?}", v.checks);
    }

    #[test]
    fn impossible_deadline_reports_the_closest_miss() {
        let s = reference_mix_ns(350_000.0);
        let e = govern(&s).expect_err("350us is below the bound floor");
        assert!(e.to_string().contains("closest miss"), "{e}");
        match e {
            GovernError::Exhausted {
                points_evaluated,
                best,
                ..
            } => {
                assert!(points_evaluated > 0);
                let (v, bound, budget) = best.expect("finite bounds were seen");
                assert_eq!(v, 1.1, "closest miss is at the fastest point");
                assert!(bound > budget);
            }
            other => panic!("expected Exhausted, got {other}"),
        }
    }

    #[test]
    fn deadline_free_mixes_are_rejected_loudly() {
        let mut s = reference_mix_ns(800_000.0);
        for t in s.tasks.iter_mut() {
            t.deadline = 0;
            t.deadline_ns = 0.0;
        }
        assert!(matches!(govern(&s), Err(GovernError::NoDeadline)));
    }

    #[test]
    fn governor_is_deterministic() {
        let s = reference_mix_ns(800_000.0);
        let a = govern(&s).expect("governable");
        let b = govern(&s).expect("governable");
        assert_eq!(a.op, b.op);
        assert_eq!(a.tuning, b.tuning);
        assert_eq!(a.evaluations, b.evaluations);
        assert_eq!(a.modeled.total_energy_mj, b.modeled.total_energy_mj);
    }

    #[test]
    fn decoupled_uncore_admits_sub_peak_for_a_high_voltage_deadline() {
        // 470us on the fig6a mix: the cycle-constant (coupled) model's
        // bound floor is ~412.6k cycles, which only fits the wall-clock
        // budget once the system clock reaches ~878MHz — the coupled
        // governor needs a >=1.0V point. Decoupled, the ~85%-uncore
        // bound stays flat in wall clock as the core slows, so a much
        // lower system voltage carries the same deadline — the memory
        // path no longer falsely slows down with the core.
        let s = reference_mix_ns(470_000.0);
        let coupled = govern(&s).expect("feasible at high voltage");
        assert!(
            coupled.op.v_system >= 1.0,
            "cycle-constant model should need a high voltage: {}",
            coupled.op.describe()
        );
        let dec = Governor::decoupled()
            .govern(&s)
            .expect("decoupled uncore must admit below the coupled winner");
        assert!(
            dec.op.v_system <= 0.95 && dec.op.v_system < coupled.op.v_system,
            "decoupling should unpin the voltage: {} vs {}",
            dec.op.describe(),
            coupled.op.describe()
        );
        assert!(dec.decision.admitted);
        let v = validate(&s, &dec);
        assert!(v.confirmed(), "sim refuted the decoupled winner: {:?}", v.checks);
        // The wall-clock bound report stays under the deadline exactly.
        for (task, bound_ns, deadline_ns) in &dec.checks_ns {
            assert!(bound_ns <= deadline_ns, "{task}: {bound_ns} > {deadline_ns}");
        }
    }

    #[test]
    fn decoupled_governor_is_deterministic_and_never_worse() {
        // On every grid deadline the decoupled governor's winner is at
        // most the coupled winner's voltage (memory no longer stretches
        // with the core clock, so nothing gets harder to admit).
        for deadline_ns in [550_000.0, 800_000.0, 2_500_000.0] {
            let s = reference_mix_ns(deadline_ns);
            let coupled = govern(&s).expect("coupled governable");
            let dec = Governor::decoupled().govern(&s).expect("decoupled governable");
            assert!(
                dec.op.v_system <= coupled.op.v_system + 1e-9,
                "decoupling raised the winning voltage at {deadline_ns}ns: {} vs {}",
                dec.op.describe(),
                coupled.op.describe()
            );
            let again = Governor::decoupled().govern(&s).expect("deterministic");
            assert_eq!(dec.op, again.op);
            assert_eq!(dec.evaluations, again.evaluations);
        }
    }

    #[test]
    fn certified_activity_flow_is_confirmed_and_never_slower() {
        let s = cluster_mix_ns(400_000.0);
        let c = Governor::default()
            .govern_certified(&s)
            .expect("cluster mix governable");
        assert!(c.confirmed(), "a certified pass failed validation");
        // Certified utils are a real measurement: inside [0, 1], with
        // the hosting domains actually active.
        assert!(c.certified_utils.amr > 0.0 && c.certified_utils.amr <= 1.0);
        assert!(c.certified_utils.vector <= 1.0);
        // The certificate only relaxes the envelope gate: the certified
        // winner is the worst-case winner or a faster point, never a
        // slower one.
        if let Some((wc, _)) = &c.worst_case {
            assert!(
                c.certified.op.v_system + 1e-9 >= wc.op.v_system,
                "certificate selected a slower point: {} vs {}",
                c.certified.op.describe(),
                wc.op.describe()
            );
        }
    }

    #[test]
    fn certificate_library_hit_skips_the_sweep_deterministically() {
        let s = cluster_mix_ns(400_000.0);
        let g = Governor::default();
        let mut lib = UtilizationLibrary::new();
        let miss = g.govern_certified_with(&s, &mut lib).expect("governable");
        assert_eq!((lib.hits, lib.misses), (0, 1));
        assert_eq!(lib.len(), 1);
        let hit = g.govern_certified_with(&s, &mut lib).expect("governable");
        assert_eq!((lib.hits, lib.misses), (1, 1));
        assert_eq!(lib.len(), 1, "a hit must not file a duplicate");
        // The hit path skipped the measurement sweep...
        assert!(hit.worst_case.is_none(), "hit still ran the worst-case pass");
        // ...reused the certificate bit-exactly...
        assert_eq!(hit.certified_utils, miss.certified_utils);
        // ...and re-derived the same confirmed point deterministically.
        assert_eq!(hit.certified.op, miss.certified.op);
        assert_eq!(hit.certified.tuning, miss.certified.tuning);
        assert!(hit.confirmed(), "a library-backed pass failed validation");
        // A renamed copy of the same mix is the same shape — a hit.
        let mut renamed = s.clone();
        renamed.name = "renamed-mix".to_string();
        let _ = g.govern_certified_with(&renamed, &mut lib).expect("governable");
        assert_eq!((lib.hits, lib.misses), (2, 1));
        // A different deadline is a different shape — a miss.
        let other = cluster_mix_ns(800_000.0);
        let _ = g.govern_certified_with(&other, &mut lib);
        assert_eq!(lib.misses, 2);
        assert_eq!(lib.len(), 2);
    }

    #[test]
    fn cluster_mix_floors_the_nct_vector_domain() {
        // fig6b: the AMR TCT is critical, the vector matmul is best
        // effort — its arrival curve does not depend on its clock, so
        // every candidate parks the vector domain at the grid floor
        // while the critical AMR domain rides the grid voltage. (The
        // flooring is also what keeps high-voltage candidates inside
        // the envelope: uniform 1.1V would model 747mW AMR + 600mW
        // vector and bust 1.2W.)
        let s = cluster_mix_ns(400_000.0);
        let c = govern(&s).expect("cluster mix governable");
        assert_eq!(c.op.v_vector, 0.6, "{}", c.op.describe());
        assert_eq!(
            c.op.v_amr, c.op.v_system,
            "the critical AMR domain must ride the candidate voltage"
        );
        assert!(
            c.op.v_system < 0.8,
            "slack at 400us should land sub-nominal: {}",
            c.op.describe()
        );
        assert!(c.modeled.within_envelope());
        let v = validate(&s, &c);
        assert!(v.confirmed(), "{:?}", v.checks);
    }
}

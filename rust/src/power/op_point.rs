//! DVFS operating points: per-domain supply voltages with curve-derived
//! clock trees.
//!
//! An [`OperatingPoint`] is the governor's search atom: one supply
//! voltage per PLL domain (system/host, vector cluster, AMR cluster).
//! Frequencies are never stored — they are *derived* from the published
//! [`DvfsCurve`]s through [`ClockTree::at_voltages`], so an operating
//! point can never carry a voltage/frequency pair the silicon model does
//! not support. Construction validates every voltage against its curve
//! (NaN and out-of-range rejected loudly, in the [`DvfsError`] style).
//!
//! The **uncore** domain (HyperBUS PHY + memory controller + DPLLC) is
//! *not* on the voltage grid: it either stays coupled to the system
//! clock (the default — the seed's single timebase, bit-identical) or is
//! parked at a fixed frequency via [`OperatingPoint::with_uncore_mhz`] /
//! [`OperatingPoint::decoupled_uncore`], in which case memory service
//! time is wall-clock-invariant under core DVFS.

use crate::soc::clock::{ClockTree, Domain, UNCORE_MHZ};
use crate::soc::power::{DvfsCurve, DvfsError, MAX_V, NOMINAL_V};

/// The governor's voltage ladder: the paper's 0.6–1.1V sweep in 50mV
/// steps (exact literals — no float accumulation).
pub const VOLTAGE_GRID: [f64; 11] = [
    0.60, 0.65, 0.70, 0.75, 0.80, 0.85, 0.90, 0.95, 1.00, 1.05, 1.10,
];

/// One DVFS operating point: a supply voltage per voltage-scaled clock
/// domain, plus the (optional) fixed uncore frequency.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatingPoint {
    pub v_system: f64,
    pub v_vector: f64,
    pub v_amr: f64,
    /// Fixed uncore (memory-subsystem) frequency in MHz. `None` keeps
    /// the uncore coupled to the system clock — the seed's single
    /// timebase, bit-identical to the pre-split model. The governor
    /// never varies this: the uncore is excluded from the voltage grid.
    pub uncore_mhz: Option<f64>,
}

impl OperatingPoint {
    /// The curve a voltage-scaled domain's voltage is validated against
    /// and its frequency/power derived from. The uncore is not
    /// voltage-scaled and has no curve (its power follows its clock
    /// linearly — [`uncore_power_mw`]).
    ///
    /// [`uncore_power_mw`]: crate::soc::power::uncore_power_mw
    pub fn curve(d: Domain) -> DvfsCurve {
        match d {
            Domain::System => DvfsCurve::host(),
            Domain::Vector => DvfsCurve::vector(),
            Domain::Amr => DvfsCurve::amr(),
            Domain::Uncore => panic!(
                "the uncore domain is fixed-frequency: it has no DVFS \
                 curve and is excluded from the voltage grid"
            ),
        }
    }

    /// Build a point from per-domain voltages, validating each against
    /// its domain's curve.
    pub fn new(v_system: f64, v_vector: f64, v_amr: f64) -> Result<Self, DvfsError> {
        Ok(Self {
            v_system: Self::curve(Domain::System).validate_voltage(v_system)?,
            v_vector: Self::curve(Domain::Vector).validate_voltage(v_vector)?,
            v_amr: Self::curve(Domain::Amr).validate_voltage(v_amr)?,
            uncore_mhz: None,
        })
    }

    /// Park the uncore at a fixed `mhz`, decoupling the memory
    /// subsystem from the system voltage (validated: positive, finite).
    pub fn with_uncore_mhz(mut self, mhz: f64) -> Result<Self, DvfsError> {
        if !mhz.is_finite() || mhz <= 0.0 {
            return Err(DvfsError::UncoreFrequencyInvalid { mhz });
        }
        self.uncore_mhz = Some(mhz);
        Ok(self)
    }

    /// The paper's decoupled configuration: the uncore parked at the
    /// fixed [`UNCORE_MHZ`] PHY clock regardless of the core voltages.
    pub fn decoupled_uncore(self) -> Self {
        self.with_uncore_mhz(UNCORE_MHZ)
            .expect("UNCORE_MHZ is positive and finite")
    }

    /// Every domain at the same supply voltage.
    pub fn uniform(v: f64) -> Result<Self, DvfsError> {
        Self::new(v, v, v)
    }

    /// The paper's balanced 0.8V point.
    pub fn nominal() -> Self {
        Self::uniform(NOMINAL_V).expect("nominal voltage is on every curve")
    }

    /// The 1.1V max-performance corner (1000/1000/900MHz).
    pub fn max_perf() -> Self {
        Self::uniform(MAX_V).expect("peak voltage is on every curve")
    }

    pub fn voltage(&self, d: Domain) -> f64 {
        match d {
            Domain::System => self.v_system,
            Domain::Vector => self.v_vector,
            Domain::Amr => self.v_amr,
            Domain::Uncore => panic!(
                "the uncore domain is fixed-frequency: it carries no \
                 supply-voltage knob"
            ),
        }
    }

    /// Replace one voltage-scaled domain's voltage (validated).
    pub fn with_voltage(mut self, d: Domain, v: f64) -> Result<Self, DvfsError> {
        let v = Self::curve(d).validate_voltage(v)?;
        match d {
            Domain::System => self.v_system = v,
            Domain::Vector => self.v_vector = v,
            Domain::Amr => self.v_amr = v,
            Domain::Uncore => unreachable!("curve() rejects the uncore domain"),
        }
        Ok(self)
    }

    /// The PLL tree this point programs (curve-derived frequencies; the
    /// uncore clock pinned to the system frequency when coupled, parked
    /// at `uncore_mhz` when decoupled). All cycle/nanosecond conversion
    /// goes through this tree (`ClockDomain::cycles_to_ns`,
    /// `McTask::deadline_cycles`) — one implementation of the
    /// sound-direction rounding, not two.
    pub fn clock_tree(&self) -> ClockTree {
        let tree = ClockTree::at_voltages(self.v_system, self.v_vector, self.v_amr);
        match self.uncore_mhz {
            Some(mhz) => tree.with_uncore_mhz(mhz),
            None => tree,
        }
    }

    /// Compact human-readable form for reports.
    pub fn describe(&self) -> String {
        let core = if self.v_system == self.v_vector && self.v_system == self.v_amr {
            format!("{:.2}V", self.v_system)
        } else {
            format!(
                "sys {:.2}V / vec {:.2}V / amr {:.2}V",
                self.v_system, self.v_vector, self.v_amr
            )
        };
        match self.uncore_mhz {
            Some(mhz) => format!("{core} (uncore {mhz:.0}MHz fixed)"),
            None => core,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_points_are_all_valid_and_ascending() {
        for w in VOLTAGE_GRID.windows(2) {
            assert!(w[0] < w[1]);
        }
        for &v in &VOLTAGE_GRID {
            let op = OperatingPoint::uniform(v).expect("grid voltage valid");
            let t = op.clock_tree();
            assert!(t.system.freq_mhz >= 350.0 && t.system.freq_mhz <= 1000.0);
        }
    }

    #[test]
    fn named_points_derive_the_published_trees() {
        let m = OperatingPoint::max_perf().clock_tree();
        assert_eq!(m.system.freq_mhz, 1000.0);
        assert_eq!(m.amr.freq_mhz, 900.0);
        let n = OperatingPoint::nominal().clock_tree();
        assert!((n.vector.freq_mhz - 550.0).abs() < 1e-9);
    }

    #[test]
    fn invalid_voltages_are_rejected_per_domain() {
        assert!(OperatingPoint::new(0.8, 0.8, 1.3).is_err());
        assert!(OperatingPoint::uniform(f64::NAN).is_err());
        let op = OperatingPoint::nominal();
        assert!(op.with_voltage(Domain::Vector, 0.2).is_err());
        let low = op.with_voltage(Domain::Vector, 0.6).unwrap();
        assert_eq!(low.v_vector, 0.6);
        assert_eq!(low.v_system, NOMINAL_V);
    }

    #[test]
    fn clock_tree_prices_cycles_per_point() {
        // 1GHz system clock at max_perf: 1 cycle = 1ns, exactly.
        let fast = OperatingPoint::max_perf().clock_tree();
        assert_eq!(fast.system.cycles_to_ns(430_000), 430_000.0);
        // At 0.6V (350MHz) the same cycle count spans more wall clock.
        let slow = OperatingPoint::uniform(0.6).unwrap().clock_tree();
        assert!(slow.system.cycles_to_ns(430_000) > 1_200_000.0);
    }

    #[test]
    fn describe_is_compact() {
        assert_eq!(OperatingPoint::nominal().describe(), "0.80V");
        let mixed = OperatingPoint::new(0.9, 0.6, 0.9).unwrap();
        assert_eq!(mixed.describe(), "sys 0.90V / vec 0.60V / amr 0.90V");
        let dec = OperatingPoint::nominal().decoupled_uncore();
        assert_eq!(dec.describe(), "0.80V (uncore 1000MHz fixed)");
    }

    #[test]
    fn uncore_defaults_coupled_and_decouples_explicitly() {
        // Coupled (default): the tree pins the uncore to the system
        // clock — the seed's single timebase.
        let coupled = OperatingPoint::uniform(0.6).unwrap().clock_tree();
        assert!(!coupled.uncore_decoupled());
        assert_eq!(coupled.uncore.freq_mhz, coupled.system.freq_mhz);
        // Decoupled: the uncore stays at 1000MHz while the system domain
        // drops to 350MHz — memory service is wall-clock-invariant.
        let dec = OperatingPoint::uniform(0.6).unwrap().decoupled_uncore().clock_tree();
        assert!(dec.uncore_decoupled());
        assert_eq!(dec.uncore.freq_mhz, 1000.0);
        assert_eq!(dec.system.freq_mhz, 350.0);
        // At the 1.1V corner the decoupled uncore coincides with the
        // system clock: the seed timebase is the pinned special case.
        let peak = OperatingPoint::max_perf().decoupled_uncore().clock_tree();
        assert!(!peak.uncore_decoupled());
    }

    #[test]
    fn invalid_uncore_frequency_is_rejected() {
        use crate::soc::power::DvfsError;
        let op = OperatingPoint::nominal();
        assert_eq!(
            op.with_uncore_mhz(0.0).unwrap_err(),
            DvfsError::UncoreFrequencyInvalid { mhz: 0.0 }
        );
        assert!(op.with_uncore_mhz(f64::NAN).is_err());
        assert!(op.with_uncore_mhz(-500.0).is_err());
    }
}

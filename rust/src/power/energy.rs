//! SoC power/energy accounting at an operating point.
//!
//! Bridges the per-cluster [`DvfsCurve`] power laws (Fig. 5/8 substrate)
//! and the coordinator: per-domain activity factors — worst-case for the
//! governor's analytic search, measured from `SocSim` activity counters
//! for a finished run — feed the (previously unused) [`EnergyMeter`] so
//! every report gains modeled power and integrated energy columns, and
//! the 1.2W envelope becomes a checkable predicate.

use crate::coordinator::{Scenario, ScenarioReport, Workload};
use crate::power::op_point::OperatingPoint;
use crate::soc::axi::Target;
use crate::soc::clock::{Cycle, Domain};
use crate::soc::power::{uncore_power_mw, EnergyMeter};

/// The paper's SoC power envelope (sub-2W budget, 1.2W achieved).
pub const SOC_ENVELOPE_MW: f64 = 1200.0;

/// Domain iteration order for reports: the three voltage-scaled PLL
/// domains plus the fixed-frequency uncore.
pub const DOMAINS: [Domain; 4] = [Domain::System, Domain::Vector, Domain::Amr, Domain::Uncore];

/// The clock domain a workload draws *core* power in. Host TCTs and the
/// system DMA live on the host/system domain; the clusters own theirs.
/// (Memory-path activity is charged to the uncore separately — see
/// [`touches_uncore`].)
pub fn domain_of(workload: &Workload) -> Domain {
    match workload {
        Workload::AmrMatMul { .. } => Domain::Amr,
        Workload::VectorMatMul { .. } | Workload::VectorFft { .. } => Domain::Vector,
        Workload::HostTct(_) | Workload::DmaCopy(_) => Domain::System,
    }
}

/// Whether a workload puts traffic on the fixed-clock memory path
/// (HyperRAM/DPLLC channel or the peripheral island) — the analytic
/// worst case charges the uncore fully active for such tasks.
pub fn touches_uncore(workload: &Workload) -> bool {
    let uncore_target = |t: Target| matches!(t, Target::Hyperram | Target::Peripheral);
    match workload {
        Workload::HostTct(_) => true, // HyperRAM walker by construction
        Workload::DmaCopy(job) => {
            uncore_target(job.src) || job.dst.map(uncore_target).unwrap_or(false)
        }
        Workload::AmrMatMul { .. }
        | Workload::VectorMatMul { .. }
        | Workload::VectorFft { .. } => false, // DCSPM-resident tiles
    }
}

/// Per-domain activity factors in [0, 1].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DomainUtilization {
    pub system: f64,
    pub vector: f64,
    pub amr: f64,
    /// Fixed-clock memory path (HyperRAM/DPLLC + peripheral island).
    pub uncore: f64,
}

impl DomainUtilization {
    pub const IDLE: DomainUtilization = DomainUtilization {
        system: 0.0,
        vector: 0.0,
        amr: 0.0,
        uncore: 0.0,
    };

    pub fn get(&self, d: Domain) -> f64 {
        match d {
            Domain::System => self.system,
            Domain::Vector => self.vector,
            Domain::Amr => self.amr,
            Domain::Uncore => self.uncore,
        }
    }

    fn set(&mut self, d: Domain, util: f64) {
        match d {
            Domain::System => self.system = util,
            Domain::Vector => self.vector = util,
            Domain::Amr => self.amr = util,
            Domain::Uncore => self.uncore = util,
        }
    }

    /// Worst-case activity for the analytic search: any domain hosting a
    /// task is charged fully active (the uncore whenever any task puts
    /// traffic on the memory path); empty domains sit at the idle
    /// floor. Conservative by construction — the envelope verdict can
    /// only improve when measured activity replaces it.
    pub fn analytic(scenario: &Scenario) -> Self {
        let mut u = Self::IDLE;
        for task in &scenario.tasks {
            u.set(domain_of(&task.workload), 1.0);
            if touches_uncore(&task.workload) {
                u.uncore = 1.0;
            }
        }
        u
    }

    /// Measured activity of a finished run, from the simulator's
    /// activity counters: cluster domains are active for their makespan
    /// minus memory-stall cycles (clock-gated while the tile streamer
    /// waits); the host/system domain for each task's makespan (endless
    /// DMA interferers run wall-to-wall; finite DMA jobs count their
    /// first-issue-to-drain span); the uncore for the memory path's own
    /// non-idle cycles, on its own clock grid (the scenario's clock
    /// tree converts — pass the scenario the run actually executed).
    pub fn measured(scenario: &Scenario, report: &ScenarioReport) -> Self {
        let total = report.cycles.max(1) as f64;
        let mut busy = Self::IDLE;
        for task in &scenario.tasks {
            let t = report.task(&task.name);
            let d = domain_of(&task.workload);
            let cycles = match &task.workload {
                Workload::DmaCopy(job) if job.looping => report.cycles as f64,
                Workload::HostTct(_) | Workload::DmaCopy(_) => t.makespan as f64,
                Workload::AmrMatMul { .. }
                | Workload::VectorMatMul { .. }
                | Workload::VectorFft { .. } => {
                    let stall = t.extra_value("stall_cycles").unwrap_or(0.0);
                    (t.makespan as f64 - stall).max(0.0)
                }
            };
            busy.set(d, busy.get(d) + cycles);
        }
        // Uncore activity counts in uncore cycles; the run spanned
        // `cycles * (f_uncore / f_system)` of them (ratio 1 on the
        // lock-step timebase).
        let uncore_ratio = scenario
            .clocks()
            .map(|t| t.ratio_to_system(Domain::Uncore))
            .unwrap_or(1.0);
        let uncore_total = (total * uncore_ratio).max(1.0);
        Self {
            system: (busy.system / total).min(1.0),
            vector: (busy.vector / total).min(1.0),
            amr: (busy.amr / total).min(1.0),
            uncore: (report.uncore_busy_cycles as f64 / uncore_total).min(1.0),
        }
    }
}

/// One domain's share of an [`EnergyReport`].
#[derive(Debug, Clone)]
pub struct DomainPower {
    pub domain: Domain,
    pub voltage: f64,
    pub freq_mhz: f64,
    pub util: f64,
    pub power_mw: f64,
    pub energy_mj: f64,
}

/// Modeled SoC power and integrated energy over a window of system
/// cycles at one operating point.
#[derive(Debug, Clone)]
pub struct EnergyReport {
    pub domains: Vec<DomainPower>,
    pub total_power_mw: f64,
    pub total_energy_mj: f64,
    /// Wall-clock seconds the window spans at the point's system clock.
    pub seconds: f64,
}

impl EnergyReport {
    /// Within the paper's 1.2W SoC envelope?
    pub fn within_envelope(&self) -> bool {
        self.total_power_mw <= SOC_ENVELOPE_MW
    }
}

/// Model power per domain at `op` with `utils` activity, integrating
/// energy over `cycles` system cycles through the [`EnergyMeter`].
pub fn model(op: &OperatingPoint, utils: DomainUtilization, cycles: Cycle) -> EnergyReport {
    let tree = op.clock_tree();
    let sys_mhz = tree.system.freq_mhz;
    let mut domains = Vec::with_capacity(DOMAINS.len());
    let mut total_power_mw = 0.0;
    let mut total_energy_mj = 0.0;
    for d in DOMAINS {
        let util = utils.get(d);
        let (voltage, freq_mhz, power_mw) = match d {
            // The uncore is not voltage-scaled: power follows its clock
            // linearly (the system clock when coupled, the fixed PHY
            // clock when decoupled) on the always-on supply.
            Domain::Uncore => (
                crate::soc::power::NOMINAL_V,
                tree.uncore.freq_mhz,
                uncore_power_mw(tree.uncore.freq_mhz, util),
            ),
            _ => {
                let curve = OperatingPoint::curve(d);
                let voltage = op.voltage(d);
                let freq_mhz = curve.freq_mhz(voltage);
                (voltage, freq_mhz, curve.power_mw(voltage, freq_mhz, util))
            }
        };
        // Every domain is powered for the same wall-clock window, which
        // the system clock defines: integrate at the system frequency.
        let mut meter = EnergyMeter::default();
        meter.add(power_mw, cycles, sys_mhz);
        total_power_mw += power_mw;
        total_energy_mj += meter.energy_mj;
        domains.push(DomainPower {
            domain: d,
            voltage,
            freq_mhz,
            util,
            power_mw,
            energy_mj: meter.energy_mj,
        });
    }
    EnergyReport {
        domains,
        total_power_mw,
        total_energy_mj,
        seconds: cycles as f64 / (sys_mhz * 1e6),
    }
}

/// Modeled SoC power (mW) at `op` with `utils` — the governor's
/// envelope gate, no integration window needed.
pub fn modeled_power_mw(op: &OperatingPoint, utils: DomainUtilization) -> f64 {
    model(op, utils, 0).total_power_mw
}

/// Measured energy of one finished run: activity from the simulator's
/// counters, power from the curves, integrated over the run's cycles.
pub fn measure(scenario: &Scenario, report: &ScenarioReport, op: &OperatingPoint) -> EnergyReport {
    model(op, DomainUtilization::measured(scenario, report), report.cycles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::Criticality;
    use crate::coordinator::{McTask, Scheduler, SocTuning};
    use crate::soc::dma::DmaJob;
    use crate::soc::hostd::TctSpec;

    fn host_mix() -> Scenario {
        Scenario::new("e", SocTuning::tsu_regulation())
            .with_task(McTask::new(
                "tct",
                Criticality::Hard,
                Workload::HostTct(TctSpec {
                    accesses: 64,
                    iterations: 2,
                    ..TctSpec::fig6a()
                }),
            ))
            .with_task(McTask::new(
                "dma",
                Criticality::BestEffort,
                Workload::DmaCopy(DmaJob::interferer()),
            ))
    }

    #[test]
    fn analytic_utilization_charges_only_hosting_domains() {
        let u = DomainUtilization::analytic(&host_mix());
        assert_eq!(u.system, 1.0);
        assert_eq!(u.vector, 0.0);
        assert_eq!(u.amr, 0.0);
        // Both the TCT (HyperRAM walker) and the DMA (HyperRAM source)
        // put traffic on the memory path: the uncore is charged active.
        assert_eq!(u.uncore, 1.0);
    }

    #[test]
    fn cluster_only_mixes_leave_the_uncore_idle() {
        use crate::soc::amr::IntPrecision;
        let s = Scenario::new("c", SocTuning::tsu_regulation()).with_task(McTask::new(
            "amr",
            Criticality::Hard,
            Workload::AmrMatMul {
                precision: IntPrecision::Int8,
                m: 64,
                k: 64,
                n: 64,
                tile: 16,
            },
        ));
        let u = DomainUtilization::analytic(&s);
        assert_eq!(u.amr, 1.0);
        assert_eq!(u.uncore, 0.0, "DCSPM tiles never touch the memory path");
    }

    #[test]
    fn idle_domains_cost_only_their_floor() {
        let op = OperatingPoint::max_perf();
        let u = DomainUtilization::analytic(&host_mix());
        let r = model(&op, u, 1_000_000);
        let vec_row = r.domains.iter().find(|d| d.domain == Domain::Vector).unwrap();
        assert_eq!(vec_row.power_mw, 1.5, "idle vector = retention floor");
        // Host mix at full tilt stays far inside the envelope even at
        // peak voltage — the clusters are what the envelope constrains.
        assert!(r.within_envelope(), "{} mW", r.total_power_mw);
        assert!(r.total_power_mw > 300.0);
        // 1M cycles at 1GHz = 1ms.
        assert!((r.seconds - 1e-3).abs() < 1e-12);
        assert!((r.total_energy_mj - r.total_power_mw * 1e-3).abs() < 1e-9);
    }

    #[test]
    fn full_cluster_activity_at_peak_voltage_busts_the_envelope() {
        // The Fig. 8 peak numbers: AMR 747mW + vector 600mW alone exceed
        // 1.2W — exactly why the governor's envelope gate must see
        // per-domain utilization instead of a blanket worst case.
        let op = OperatingPoint::max_perf();
        let all = DomainUtilization {
            system: 1.0,
            vector: 1.0,
            amr: 1.0,
            uncore: 1.0,
        };
        assert!(modeled_power_mw(&op, all) > SOC_ENVELOPE_MW);
        let clusters_halved = OperatingPoint::new(1.1, 0.8, 0.8).unwrap();
        assert!(modeled_power_mw(&clusters_halved, all) < SOC_ENVELOPE_MW);
    }

    #[test]
    fn measured_utilization_reflects_the_run() {
        let s = host_mix();
        let report = Scheduler::run(&s);
        let u = DomainUtilization::measured(&s, &report);
        // The looping DMA keeps the system domain busy wall-to-wall.
        assert_eq!(u.system, 1.0);
        assert_eq!(u.vector, 0.0);
        // The DMA hammers the HyperRAM channel: the uncore measures hot.
        assert!(u.uncore > 0.5, "uncore util {}", u.uncore);
        assert!(u.uncore <= 1.0);
        let op = OperatingPoint::nominal();
        let m = measure(&s, &report, &op);
        assert!(m.total_energy_mj > 0.0);
        assert!(m.within_envelope());
        let unc_row = m.domains.iter().find(|d| d.domain == Domain::Uncore).unwrap();
        assert!(unc_row.power_mw > crate::soc::power::UNCORE_IDLE_MW);
    }
}

//! Persistence of measured activity certificates across governor runs.
//!
//! [`Governor::govern_certified`] pays for its certificate with a
//! measurement sweep: a full worst-case govern pass plus at least one
//! validating simulation before the measured [`DomainUtilization`] can
//! be fed back as the activity bound. Fleet-style admission re-packing
//! re-governs the *same workload shapes* over and over (new deadlines,
//! new co-runners arriving in the same mix families), so the
//! certificate — a duty-cycle ratio, not a timing — is the part worth
//! keeping.
//!
//! [`UtilizationLibrary`] is that store: a deterministic map from a
//! *workload shape key* (governor search space + everything about the
//! scenario that can steer measured activity, excluding task names) to
//! the certified utilization. [`Governor::govern_certified_with`]
//! consults it and, on a hit, skips the measurement sweep entirely —
//! the certified point is still simulation-confirmed before anyone
//! acts on it, so a stale certificate can relax the envelope gate but
//! never ship an unvalidated point.
//!
//! [`Governor::govern_certified`]: crate::power::governor::Governor::govern_certified
//! [`Governor::govern_certified_with`]: crate::power::governor::Governor::govern_certified_with

use std::collections::BTreeMap;
use std::fmt::Write;

use crate::coordinator::Scenario;
use crate::power::energy::DomainUtilization;
use crate::power::governor::Governor;

/// A deterministic certificate store keyed by workload shape.
///
/// Backed by a `BTreeMap` so iteration (and any future serialization)
/// is ordered and reproducible. Hit/miss counters are plain
/// observability — they never influence behaviour.
#[derive(Debug, Clone, Default)]
pub struct UtilizationLibrary {
    entries: BTreeMap<String, DomainUtilization>,
    /// Lookups answered from the library (measurement sweep skipped).
    pub hits: u64,
    /// Lookups that fell through to a full certified pass.
    pub misses: u64,
}

impl UtilizationLibrary {
    pub fn new() -> Self {
        Self::default()
    }

    /// The shape key a `(governor, scenario)` pair files its
    /// certificate under: the governor's search space and every
    /// scenario field that can steer measured activity — tuning, the
    /// pinned operating point, the fault plan, the cycle budget and
    /// each task's (criticality, workload, deadline) triple. Task
    /// *names* are deliberately excluded: renaming a mix does not
    /// change what the counters measure.
    pub fn shape_key(governor: &Governor, scenario: &Scenario) -> String {
        let mut key = String::new();
        write!(
            key,
            "grid={:?};refine={};uncore={:?};tuning={:?};op={:?};faults={:?};budget={}",
            governor.grid,
            governor.refine_nct_domains,
            governor.uncore_mhz,
            scenario.tuning,
            scenario.op_point,
            scenario.fault_plan(),
            scenario.max_cycles,
        )
        .expect("writing to a String cannot fail");
        for t in &scenario.tasks {
            write!(
                key,
                "|task={:?}/{:?}/d{}/dns{:?}",
                t.criticality, t.workload, t.deadline, t.deadline_ns
            )
            .expect("writing to a String cannot fail");
        }
        key
    }

    /// Look up a certificate, counting the outcome.
    pub fn lookup(&mut self, key: &str) -> Option<DomainUtilization> {
        match self.entries.get(key).copied() {
            Some(u) => {
                self.hits += 1;
                Some(u)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// File (or refresh) a certificate under `key`.
    pub fn insert(&mut self, key: String, utils: DomainUtilization) {
        self.entries.insert(key, utils);
    }

    /// Fraction of lookups answered from the library (0.0 before any
    /// lookup) — the admission service's "repeat shapes skip the
    /// measurement sweep" observability number.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

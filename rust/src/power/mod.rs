//! Bound-driven DVFS: the energy half of the coordinator.
//!
//! The paper's headline constraint is the sub-2W (1.2W achieved) power
//! envelope; this module closes the loop between the Fig. 5/8 power
//! substrate ([`crate::soc::power`]) and the time-predictability stack:
//!
//! - [`op_point`]: per-domain supply voltages whose clock trees are
//!   *derived* from the published DVFS curves (no stored frequencies);
//! - [`energy`]: per-domain utilization (analytic worst case, or
//!   measured from `SocSim` activity counters) feeding the
//!   [`EnergyMeter`](crate::soc::power::EnergyMeter), plus the 1.2W
//!   envelope predicate;
//! - [`governor`]: the search over the (operating point x
//!   [`SocTuning`](crate::coordinator::SocTuning)) product — WCET bounds
//!   recomputed analytically at every V/f candidate, isolation re-tuned
//!   per point via [`coordinator::autotune`], winner = lowest modeled
//!   energy that provably meets every deadline inside the envelope, and
//!   confirmed by one real simulation;
//! - [`certificates`]: a persistent [`UtilizationLibrary`] keyed by
//!   workload shape, so repeat certified govern runs reuse a measured
//!   activity certificate instead of re-running the measurement sweep.
//!
//! `experiments::energy` / `carfield dvfs` sweep the Fig. 6 deadline
//! grids through the governor; `tests/governor_soundness.rs` fuzzes the
//! soundness of every governed point.
//!
//! [`coordinator::autotune`]: crate::coordinator::autotune

pub mod certificates;
pub mod energy;
pub mod governor;
pub mod op_point;

pub use certificates::UtilizationLibrary;
pub use energy::{DomainPower, DomainUtilization, EnergyReport, SOC_ENVELOPE_MW};
pub use governor::{
    govern, validate, CertifiedChoice, GovernError, Governor, GovernorChoice, GovernorValidation,
};
pub use op_point::{OperatingPoint, VOLTAGE_GRID};

//! Property-style sweeps over the coordinator: random task mixes must
//! always produce complete, internally-consistent reports, and the
//! isolation-policy ladder must order TCT latency correctly.

use carfield::coordinator::task::Criticality;
use carfield::coordinator::{IsolationPolicy, McTask, Scenario, Scheduler, Workload};
use carfield::soc::amr::IntPrecision;
use carfield::soc::dma::DmaJob;
use carfield::soc::hostd::TctSpec;
use carfield::soc::vector::FpFormat;
use carfield::util::XorShift;

fn random_task(rng: &mut XorShift, idx: usize) -> McTask {
    let crit = match rng.below(4) {
        0 => Criticality::Safety,
        1 => Criticality::Hard,
        2 => Criticality::Soft,
        _ => Criticality::BestEffort,
    };
    let name = format!("t{idx}");
    match rng.below(4) {
        0 => McTask::new(
            &name,
            crit,
            Workload::AmrMatMul {
                precision: [IntPrecision::Int8, IntPrecision::Int4, IntPrecision::Int2]
                    [rng.below(3) as usize],
                m: 32 * rng.in_range(1, 3) as u32,
                k: 32,
                n: 32,
                tile: 16,
            },
        ),
        1 => McTask::new(
            &name,
            crit,
            Workload::VectorMatMul {
                format: [FpFormat::Fp32, FpFormat::Fp16, FpFormat::Fp8][rng.below(3) as usize],
                m: 64,
                k: 64,
                n: 64,
                tile: 32,
            },
        ),
        2 => McTask::new(
            &name,
            crit,
            Workload::VectorFft {
                format: FpFormat::Fp32,
                n: 256,
                batch: rng.in_range(1, 8) as u32,
            },
        ),
        _ => McTask::new(
            &name,
            Criticality::Hard,
            Workload::HostTct(TctSpec {
                accesses: 64 * rng.in_range(1, 4) as u32,
                iterations: 2,
                ..TctSpec::fig6a()
            }),
        ),
    }
}

#[test]
fn random_scenarios_always_complete_with_consistent_reports() {
    let mut rng = XorShift::new(0xC0DE);
    for case in 0..12 {
        let policy = match rng.below(4) {
            0 => IsolationPolicy::NoIsolation,
            1 => IsolationPolicy::TsuRegulation,
            2 => IsolationPolicy::TsuPlusLlcPartition {
                tct_fraction_percent: rng.in_range(10, 90) as u8,
            },
            _ => IsolationPolicy::PrivatePaths,
        };
        let n_tasks = rng.in_range(1, 4) as usize;
        let mut scenario = Scenario::new(&format!("rand{case}"), policy);
        for i in 0..n_tasks {
            scenario = scenario.with_task(random_task(&mut rng, i));
        }
        let report = Scheduler::run(&scenario);
        assert_eq!(report.tasks.len(), n_tasks, "case {case}");
        assert!(report.cycles < scenario.max_cycles, "case {case}: hit budget");
        for t in &report.tasks {
            // Every measured (non-dma) task must have finished.
            if t.kind != "dma-copy" {
                assert!(
                    t.makespan > 0 || t.kind == "host-tct",
                    "case {case}: {} never finished: {}",
                    t.name,
                    report.to_markdown()
                );
            }
            if t.deadline == 0 {
                assert!(t.deadline_met, "deadline-free tasks are always met");
            }
        }
        // Markdown rendering never panics and contains every task.
        let md = report.to_markdown();
        for t in &report.tasks {
            assert!(md.contains(&t.name));
        }
    }
}

#[test]
fn policy_ladder_orders_tct_latency() {
    let tct = || {
        McTask::new(
            "tct",
            Criticality::Hard,
            Workload::HostTct(TctSpec {
                accesses: 512,
                iterations: 4,
                ..TctSpec::fig6a()
            }),
        )
    };
    let dma = || {
        McTask::new(
            "dma",
            Criticality::BestEffort,
            Workload::DmaCopy(DmaJob::interferer()),
        )
    };
    let lat = |policy| {
        let s = Scenario::new("ladder", policy).with_task(tct()).with_task(dma());
        Scheduler::run(&s).task("tct").mean_latency
    };
    let none = lat(IsolationPolicy::NoIsolation);
    let tsu = lat(IsolationPolicy::TsuRegulation);
    let part = lat(IsolationPolicy::TsuPlusLlcPartition {
        tct_fraction_percent: 50,
    });
    assert!(tsu < none, "TSU must improve: {none:.0} -> {tsu:.0}");
    assert!(part < none, "partition must improve: {none:.0} -> {part:.0}");
    assert!(
        part <= tsu * 1.1,
        "partition should not regress vs TSU alone: {tsu:.0} -> {part:.0}"
    );
}

#[test]
fn safety_tasks_get_lockstep_and_pay_for_it() {
    let run = |crit| {
        let s = Scenario::new("lockstep", IsolationPolicy::NoIsolation).with_task(McTask::new(
            "ai",
            crit,
            Workload::AmrMatMul {
                precision: IntPrecision::Int8,
                m: 64,
                k: 64,
                n: 64,
                tile: 32,
            },
        ));
        Scheduler::run(&s).task("ai").makespan
    };
    let safety = run(Criticality::Safety); // DLM
    let soft = run(Criticality::Soft); // INDIP
    let ratio = safety as f64 / soft as f64;
    assert!(
        (1.5..2.2).contains(&ratio),
        "DLM penalty should be ~1.89x, got {ratio:.2}"
    );
}

#[test]
fn reports_survive_extreme_deadlines() {
    let mk = |deadline| {
        let s = Scenario::new("dl", IsolationPolicy::NoIsolation).with_task(
            McTask::new(
                "ai",
                Criticality::Hard,
                Workload::AmrMatMul {
                    precision: IntPrecision::Int2,
                    m: 32,
                    k: 32,
                    n: 32,
                    tile: 16,
                },
            )
            .with_deadline(deadline),
        );
        Scheduler::run(&s)
    };
    assert!(!mk(1).all_deadlines_met());
    assert!(mk(u64::MAX / 2).all_deadlines_met());
}
